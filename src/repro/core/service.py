"""AIGC service requests and scenario generation (Sec. II / IV constants).

K devices, deadlines uniform in [tau_min, tau_max] (paper: 7..20 s),
spectral efficiency eta_k uniform in [5, 10] bit/s/Hz, total bandwidth
B = 40 kHz, content size S identical across services (one generated
image; default 3 KiB ~= a 32x32 PNG).

Beyond the paper's static batch (docs/SCENARIOS.md):

  * ``arrival`` — request submission time (s).  The paper's setting is
    ``arrival == 0`` for every service (the default); a Poisson process
    (``make_scenario(..., arrival_rate=...)``) turns the same scenario
    into the *online* admission problem solved by ``repro.core.online``.
  * ``content_bits`` — optional per-service content size overriding the
    scenario-level value (heterogeneous outputs: thumbnails vs. 4K).
  * ``servers`` — optional list of ``EdgeServer`` cells (per-server
    compute speed, bandwidth budget, capacity) turning the single-server
    problem into placement x per-cell allocation
    (``repro.core.multiserver``).  ``None`` is the paper's one server
    owning the whole budget.
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

import numpy as np

from repro.core.delay_model import DelayModel

DEFAULT_BANDWIDTH_HZ = 40_000.0
DEFAULT_CONTENT_BITS = 3 * 1024 * 8.0


@dataclasses.dataclass(frozen=True)
class ServiceRequest:
    id: int
    deadline: float            # tau_k, end-to-end, relative to arrival (s)
    spectral_eff: float        # eta_k (bit/s/Hz)
    arrival: float = 0.0       # submission time (0 = the paper's static batch)
    content_bits: Optional[float] = None   # per-service S; None = scenario's

    def tx_delay(self, bandwidth_hz: float,
                 content_bits: float = DEFAULT_CONTENT_BITS) -> float:
        """D_ct = S / (B_k * eta_k)  (Eqs. 8, 11).

        ``content_bits`` is the scenario-level default; a per-service
        ``self.content_bits`` takes precedence when set.
        """
        bits = self.content_bits if self.content_bits is not None \
            else content_bits
        rate = bandwidth_hz * self.spectral_eff
        return bits / max(rate, 1e-12)


@dataclasses.dataclass(frozen=True)
class EdgeServer:
    """One edge cell: its own compute speed, bandwidth budget and
    (optional) capacity cap on how many services it may host.

    ``speed`` is relative throughput (1.0 = the calibrated baseline
    hardware): a server twice as fast halves every per-batch delay, so
    the effective delay model scales both ``a`` and ``b`` by 1/speed.
    """
    id: int
    bandwidth_hz: float = DEFAULT_BANDWIDTH_HZ   # the cell's own budget
    speed: float = 1.0                           # relative compute speed
    capacity: Optional[int] = None               # max services (None = inf)

    def delay_model(self, base: DelayModel) -> DelayModel:
        """The base delay model as seen on this server's hardware."""
        if self.speed == 1.0:
            return base
        return DelayModel(a=base.a / self.speed, b=base.b / self.speed)

    def has_room(self, n_assigned: int) -> bool:
        return self.capacity is None or n_assigned < self.capacity


@dataclasses.dataclass(frozen=True)
class Scenario:
    services: List[ServiceRequest]
    total_bandwidth_hz: float = DEFAULT_BANDWIDTH_HZ
    content_bits: float = DEFAULT_CONTENT_BITS
    servers: Optional[List[EdgeServer]] = None   # None = one implicit server

    @property
    def K(self) -> int:
        return len(self.services)

    @property
    def is_static(self) -> bool:
        """True when every request is present at t=0 (the paper's setting)."""
        return all(s.arrival == 0.0 for s in self.services)

    @property
    def n_servers(self) -> int:
        return len(self.servers) if self.servers else 1

    @property
    def server_list(self) -> List[EdgeServer]:
        """The effective cells: ``servers``, or the paper's single
        implicit server owning the whole bandwidth budget."""
        if self.servers:
            return list(self.servers)
        return [EdgeServer(id=0, bandwidth_hz=self.total_bandwidth_hz)]


def make_scenario(K: int = 20, tau_min: float = 7.0, tau_max: float = 20.0,
                  eta_min: float = 5.0, eta_max: float = 10.0,
                  total_bandwidth_hz: float = DEFAULT_BANDWIDTH_HZ,
                  content_bits: float = DEFAULT_CONTENT_BITS,
                  arrival_rate: Optional[float] = None,
                  content_bits_range: Optional[Tuple[float, float]] = None,
                  n_servers: int = 1,
                  server_speed_range: Optional[Tuple[float, float]] = None,
                  server_capacity: Optional[int] = None,
                  seed: int = 0) -> Scenario:
    """Sample a K-service scenario (Sec. IV constants by default).

    arrival_rate: requests/s of a Poisson arrival process; service k
        arrives at the k-th arrival epoch (cumulative Exp(1/rate)
        inter-arrival gaps).  ``None`` (default) keeps every arrival at
        t=0 — the paper's static batch, bit-identical to older seeds.
    content_bits_range: (lo, hi) uniform per-service content sizes
        (heterogeneous outputs); ``None`` keeps the shared scenario size.
    n_servers: number of edge cells; the total bandwidth is split
        equally across cells.  ``1`` (default) keeps ``servers=None`` —
        the paper's single-server scenario, bit-identical to older
        seeds (and the multi-server pipeline on it reproduces the
        single-server results exactly; tests/test_multiserver.py).
    server_speed_range: (lo, hi) uniform per-server relative compute
        speeds; ``None`` makes every server baseline speed (1.0).
    server_capacity: per-server cap on hosted services (``None`` = no
        cap); placements must respect it.

    Per-server speed/capacity are honoured by the multi-server pipeline
    (``MultiServerProvisioner`` / ``repro.core.multiserver``) — with
    one explicit server included.  The paper's single-server
    ``Provisioner`` / ``simulate_online`` never read ``servers``, so
    passing speed/capacity while staying on the single-server path has
    no effect there.
    """
    rng = np.random.default_rng(seed)
    services = [
        ServiceRequest(
            id=k,
            deadline=float(rng.uniform(tau_min, tau_max)),
            spectral_eff=float(rng.uniform(eta_min, eta_max)),
        )
        for k in range(K)
    ]
    # extra draws happen *after* the base loop so a given seed yields the
    # same deadlines/spectral efficiencies with or without these features
    if arrival_rate is not None:
        assert arrival_rate > 0, "arrival_rate must be positive (requests/s)"
        gaps = rng.exponential(1.0 / arrival_rate, size=K)
        arrivals = np.cumsum(gaps)
        services = [dataclasses.replace(s, arrival=float(t))
                    for s, t in zip(services, arrivals)]
    if content_bits_range is not None:
        lo, hi = content_bits_range
        bits = rng.uniform(lo, hi, size=K)
        services = [dataclasses.replace(s, content_bits=float(b))
                    for s, b in zip(services, bits)]
    assert n_servers >= 1, "n_servers must be >= 1"
    servers = None
    if n_servers > 1 or server_speed_range is not None \
            or server_capacity is not None:
        speeds = np.ones(n_servers)
        if server_speed_range is not None:
            lo, hi = server_speed_range
            speeds = rng.uniform(lo, hi, size=n_servers)
        servers = [EdgeServer(id=m,
                              bandwidth_hz=total_bandwidth_hz / n_servers,
                              speed=float(speeds[m]),
                              capacity=server_capacity)
                   for m in range(n_servers)]
    return Scenario(services=services,
                    total_bandwidth_hz=total_bandwidth_hz,
                    content_bits=content_bits,
                    servers=servers)
