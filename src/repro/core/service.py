"""AIGC service requests and scenario generation (Sec. II / IV constants).

K devices, deadlines uniform in [tau_min, tau_max] (paper: 7..20 s),
spectral efficiency eta_k uniform in [5, 10] bit/s/Hz, total bandwidth
B = 40 kHz, content size S identical across services (one generated
image; default 3 KiB ~= a 32x32 PNG).
"""

from __future__ import annotations

import dataclasses
from typing import List

import numpy as np

DEFAULT_BANDWIDTH_HZ = 40_000.0
DEFAULT_CONTENT_BITS = 3 * 1024 * 8.0


@dataclasses.dataclass(frozen=True)
class ServiceRequest:
    id: int
    deadline: float            # tau_k, end-to-end (s)
    spectral_eff: float        # eta_k (bit/s/Hz)

    def tx_delay(self, bandwidth_hz: float,
                 content_bits: float = DEFAULT_CONTENT_BITS) -> float:
        """D_ct = S / (B_k * eta_k)  (Eqs. 8, 11)."""
        rate = bandwidth_hz * self.spectral_eff
        return content_bits / max(rate, 1e-12)


@dataclasses.dataclass(frozen=True)
class Scenario:
    services: List[ServiceRequest]
    total_bandwidth_hz: float = DEFAULT_BANDWIDTH_HZ
    content_bits: float = DEFAULT_CONTENT_BITS

    @property
    def K(self) -> int:
        return len(self.services)


def make_scenario(K: int = 20, tau_min: float = 7.0, tau_max: float = 20.0,
                  eta_min: float = 5.0, eta_max: float = 10.0,
                  total_bandwidth_hz: float = DEFAULT_BANDWIDTH_HZ,
                  content_bits: float = DEFAULT_CONTENT_BITS,
                  seed: int = 0) -> Scenario:
    rng = np.random.default_rng(seed)
    services = [
        ServiceRequest(
            id=k,
            deadline=float(rng.uniform(tau_min, tau_max)),
            spectral_eff=float(rng.uniform(eta_min, eta_max)),
        )
        for k in range(K)
    ]
    return Scenario(services=services,
                    total_bandwidth_hz=total_bandwidth_hz,
                    content_bits=content_bits)
