"""Closed-loop plan execution: measure -> refit -> replan on real wall-clock.

The planner and the executors meet only through the affine delay model
g(X) = aX + b (Eq. 4).  ``ExecutionLoop`` closes that loop: it drives a
``BatchPlan`` on a *session* (a stepwise executor handle — the real DDIM
U-Net, a ServingEngine decode stream, or the synthetic
``SimulatedSession``), one batch at a time, and

  * records per-batch ``(batch_size, wall_clock)`` telemetry,
  * refits the delay model online (rolling least squares over the last
    W batches, ``repro.core.delay_model.RollingDelayFit``),
  * when the relative predicted-vs-measured batch delay drifts past a
    tolerance, replans the *residual* scenario through the same
    offset-aware path as ``_ServerTrack`` (executed steps credited as
    offsets, retired-with-progress services transmit immediately,
    no-resurrection invariants hold) and retargets the session's
    remaining schedules.

Time inside the loop is measured, not simulated: completion instants,
deadline verdicts and the reported makespan all come from the session's
wall-clock.  Transmission stays analytic (``ServiceRequest.tx_delay``
under the adopting allocation) — the radio link is not executed here.

Sessions are duck-typed (``repro.api.execution`` registers the concrete
factories in the EXECUTORS registry):

    run_batch(ids, timed=True) -> measured seconds
    retarget(totals)              # new TOTAL step counts, >= executed
    finish() -> {id: content}
"""

from __future__ import annotations

import collections
import dataclasses
import os
from typing import Dict, List, Optional

import numpy as np

from repro.core import arrays
from repro.core.bandwidth import make_plan
from repro.core.delay_model import DelayModel, RollingDelayFit
from repro.core.online import _ServiceState, offset_aware
from repro.core.plan import BatchPlan
from repro.core.quality_model import PowerLawFID, QualityModel
from repro.core.service import Scenario
from repro.core.simulator import ServiceOutcome

_TIE = 1e-6   # deadline slack, matches repro.core.simulator

#: Denoising execution engines (``repro.diffusion``): ``"dict"`` is the
#: per-service-latent reference path, ``"bucketed"`` the device-resident
#: padded-bucket engine (docs/PERFORMANCE.md, "The execution engine").
EXEC_ENGINES = ("dict", "bucketed")


def exec_engine_default() -> str:
    """Process-default execution engine for the denoising executor —
    the ``REPRO_EXEC_ENGINE`` environment variable, else ``"dict"``
    (the bit-exact-per-row reference path)."""
    return os.environ.get("REPRO_EXEC_ENGINE", "dict")


def shape_bucket(n: int) -> int:
    """Power-of-two padded batch-size bucket (min 2).

    This is the shape grid the bucketed denoising executor compiles
    one gather->DDIM-step->scatter program per, and the grid
    ``ExecutionLoop`` telemetry groups measured per-batch wall-clock
    by (so drift is attributable to ``groupnorm_silu`` /
    ``flash_attention`` batch-shape regimes).  Plans whose batches
    never exceed ``K_max`` services touch at most
    ``ceil(log2(K_max))`` buckets — the trace bound the recompile
    tests pin."""
    return max(2, 1 << max(0, int(n - 1).bit_length()))


class SimulatedSession:
    """Synthetic executor: per-batch wall-clock drawn from a hidden
    *true* ``DelayModel`` (optional multiplicative noise, deterministic
    per seed).  Lets the closed loop — drift detection, refit,
    replanning, crediting — be exercised in milliseconds without a
    U-Net; content is each service's final step count."""

    def __init__(self, plan: BatchPlan, true_delay: DelayModel,
                 noise: float = 0.0, seed: int = 0):
        self.true_delay = true_delay
        self.noise = float(noise)
        self._rng = np.random.default_rng(seed)
        self.steps_done: Dict[int, int] = {
            k: 0 for k in plan.steps_completed}
        self._totals: Dict[int, int] = {
            k: int(v) for k, v in plan.steps_completed.items()}

    def run_batch(self, ks, timed: bool = False) -> float:
        for k in ks:
            if self.steps_done[k] >= self._totals[k]:
                raise ValueError(
                    f"service {k} has no remaining steps")
        dt = self.true_delay.g(len(ks))
        if self.noise:
            dt = max(dt * (1.0 + self.noise *
                           float(self._rng.standard_normal())), 1e-9)
        for k in ks:
            self.steps_done[k] += 1
        return dt

    def retarget(self, totals: Dict[int, int]) -> None:
        for k, total in totals.items():
            if total < self.steps_done[k]:
                raise ValueError(
                    f"service {k}: retarget total {total} < "
                    f"{self.steps_done[k]} steps already executed")
            self._totals[k] = int(total)

    def finish(self) -> Dict[int, int]:
        return dict(self.steps_done)

    def telemetry(self) -> dict:
        return {"exec_engine": "simulated"}


@dataclasses.dataclass
class BatchRecord:
    """One executed batch: what the planning model predicted vs what the
    session measured."""
    index: int
    size: int
    predicted_s: float
    measured_s: float
    t_start: float
    t_end: float


@dataclasses.dataclass
class ExecutionResult:
    """Outcome of one ``ExecutionLoop.run``: measured-time per-service
    outcomes plus the telemetry the loop collected."""
    outcomes: List[ServiceOutcome]
    records: List[BatchRecord]
    content: Dict
    delay: DelayModel            # model in force at the end (refit)
    mean_fid: float
    outage_rate: float
    delivered_fid: float         # late content scores fid(0)
    wall_clock: float            # measured generation makespan
    replans: int
    refits: int
    mode: str
    executed_log: List[tuple]
    exec_engine: str = ""                # engine the session reported
    session_telemetry: Optional[dict] = None   # session.telemetry()

    @property
    def timings(self) -> List[tuple]:
        """(batch_size, seconds) telemetry — the shape
        ``ProvisionReport.refit_delay`` consumes."""
        return [(r.size, r.measured_s) for r in self.records]

    def per_bucket(self) -> Dict[int, dict]:
        """Measured per-batch wall-clock grouped by ``shape_bucket``:
        ``{bucket: {batches, total_s, mean_s, min_s, predicted_s}}``.
        Drift in one bucket and not another points at the kernels'
        batch-shape regime (``groupnorm_silu`` / ``flash_attention``
        specialize per padded batch shape), not at the affine model."""
        out: Dict[int, dict] = {}
        for r in self.records:
            b = out.setdefault(shape_bucket(r.size), {
                "batches": 0, "total_s": 0.0, "min_s": float("inf"),
                "predicted_s": 0.0})
            b["batches"] += 1
            b["total_s"] += r.measured_s
            b["min_s"] = min(b["min_s"], r.measured_s)
            b["predicted_s"] += r.predicted_s
        for b in out.values():
            b["mean_s"] = b["total_s"] / b["batches"]
        return out

    def predicted_wall(self, model: Optional[DelayModel] = None) -> float:
        """Sum of g(X_n) over the executed batch sizes under ``model``
        (default: the final refit model) — compare with ``wall_clock``
        to judge how well the affine model explains this hardware."""
        m = model if model is not None else self.delay
        return float(sum(m.g(r.size) for r in self.records))

    def summary(self) -> str:
        return (f"[execution {self.mode}] batches={len(self.records)} "
                f"wall={self.wall_clock:.3f}s "
                f"predicted={self.predicted_wall():.3f}s "
                f"replans={self.replans} refits={self.refits} | "
                f"mean_fid={self.mean_fid:.3f} "
                f"delivered_fid={self.delivered_fid:.3f} "
                f"outage={self.outage_rate:.1%}")

    def to_dict(self) -> dict:
        return {
            "kind": "execution",
            "mode": self.mode,
            "mean_fid": float(self.mean_fid),
            "outage_rate": float(self.outage_rate),
            "delivered_fid": float(self.delivered_fid),
            "makespan": float(self.wall_clock),
            "replans": int(self.replans),
            "refits": int(self.refits),
            "delay": {"a": float(self.delay.a), "b": float(self.delay.b)},
            "exec_engine": self.exec_engine,
            "telemetry": {
                "batches": len(self.records),
                "timings": [[int(s), float(d)] for s, d in self.timings],
                "wall_clock": float(self.wall_clock),
                "predicted_wall": float(self.predicted_wall()),
                "per_bucket": {
                    str(b): {k: (int(v) if k == "batches" else float(v))
                             for k, v in agg.items()}
                    for b, agg in sorted(self.per_bucket().items())},
                "session": self.session_telemetry,
            },
        }


class ExecutionLoop:
    """Drive a planned batch schedule on a session, refit the delay
    model from measured wall-clock, and (in ``mode="closed"``) replan
    mid-flight when prediction drifts.

    ``mode="open"`` executes the plan as given — telemetry and the
    rolling refit still run (so ``result.delay`` reflects the hardware)
    but the schedule is never changed.  ``mode="closed"`` additionally
    replans through the offset-aware residual path whenever the mean
    relative error of the last ``min_batches`` batches exceeds
    ``drift_tol``; ``headroom`` inflates the refit model used for
    replanning so the new schedule keeps slack against timing noise.
    """

    def __init__(self, scenario: Scenario, plan: BatchPlan, alloc,
                 session, *, delay: Optional[DelayModel] = None,
                 quality: Optional[QualityModel] = None,
                 scheduler=None, allocator=None, mode: str = "closed",
                 window: int = 32, drift_tol: float = 0.25,
                 min_batches: int = 3, max_replans: int = 8,
                 headroom: float = 1.0, validate: bool = True,
                 engine: Optional[str] = None,
                 exec_engine: Optional[str] = None):
        if mode not in ("open", "closed"):
            raise ValueError(f"mode must be 'open' or 'closed', "
                             f"got {mode!r}")
        if mode == "closed" and (scheduler is None or allocator is None):
            raise ValueError("mode='closed' needs scheduler= and "
                             "allocator= to replan with")
        self.scenario = scenario
        self.session = session
        self.scheduler = scheduler
        self.allocator = allocator
        self.delay = delay if delay is not None else DelayModel()
        self.quality = quality if quality is not None else PowerLawFID()
        self.mode = mode
        self.drift_tol = float(drift_tol)
        self.min_batches = int(min_batches)
        self.max_replans = int(max_replans)
        self.headroom = float(headroom)
        self.validate = validate
        self.engine = engine
        # the denoising-session engine the session was opened with —
        # recorded for telemetry; the session itself (already built by
        # the caller) is what actually implements it
        self.exec_engine = exec_engine

        alloc = np.asarray(alloc, dtype=np.float64)
        self.alloc_map: Dict[int, float] = {
            s.id: float(alloc[i]) for i, s in enumerate(scenario.services)}
        self.states: Dict[int, _ServiceState] = {
            s.id: _ServiceState(s, admitted=True)
            for s in scenario.services}
        self.pending = {k for k, T in plan.steps_completed.items()
                        if T > 0}
        self.batches = list(plan.batches)
        self.last = self._last_batch_of(self.batches)
        self.i = 0

        self.fit = RollingDelayFit(window=window, prior=self.delay)
        self._drift: "collections.deque[float]" = collections.deque(
            maxlen=self.min_batches)
        self.records: List[BatchRecord] = []
        self.executed_log: List[tuple] = []
        self.replans = 0
        self.refits = 0

    @staticmethod
    def _last_batch_of(batches) -> Dict[int, int]:
        last: Dict[int, int] = {}
        for n, batch in enumerate(batches):
            for k, _ in batch:
                last[k] = n
        return last

    def _complete(self, st: _ServiceState, t: float,
                  bandwidth: float) -> None:
        st.gen_end = t
        st.bandwidth = bandwidth
        st.tx_dur = st.svc.tx_delay(bandwidth, self.scenario.content_bits)
        st.tx_end = t + st.tx_dur
        self.pending.discard(st.svc.id)

    # -- the loop ---------------------------------------------------------

    def run(self) -> ExecutionResult:
        t = 0.0
        while self.i < len(self.batches):
            ks = [k for k, _ in self.batches[self.i]]
            predicted = self.delay.g(len(ks))
            dt = float(self.session.run_batch(ks, timed=True))
            t_end = t + dt
            for k in ks:
                st = self.states[k]
                st.steps_done += 1
                self.executed_log.append((t, k, st.steps_done))
            self.records.append(BatchRecord(
                index=len(self.records), size=len(ks),
                predicted_s=predicted, measured_s=dt,
                t_start=t, t_end=t_end))
            for k in ks:
                if self.last.get(k) == self.i:
                    self._complete(self.states[k], t_end,
                                   self.alloc_map[k])
            self.fit.observe(len(ks), dt)
            self._drift.append(abs(dt - predicted) /
                               max(predicted, 1e-12))
            t = t_end
            self.i += 1
            if (self.mode == "closed" and self.pending
                    and self.i < len(self.batches)
                    and len(self._drift) >= self.min_batches
                    and self.replans < self.max_replans
                    and float(np.mean(self._drift)) > self.drift_tol):
                self._replan(t)
        return self._finalize(t)

    def _replan(self, t: float) -> None:
        """Refit from the telemetry window, replan the residual scenario
        (executed steps as offsets — exactly the ``_ServerTrack``
        crediting), adopt it, and retarget the session."""
        self.delay = self.fit.model(headroom=self.headroom)
        self.refits += 1
        scn = self.scenario
        residual = [
            dataclasses.replace(
                self.states[s.id].svc,
                deadline=self.states[s.id].abs_deadline - t,
                arrival=0.0)
            for s in scn.services if s.id in self.pending]
        B = scn.total_bandwidth_hz
        reserved = sum(st.bandwidth for st in self.states.values()
                       if st.gen_complete and st.tx_end > t)
        res_scn = Scenario(services=residual,
                           total_bandwidth_hz=max(B - reserved,
                                                  1e-6 * B),
                           content_bits=scn.content_bits)
        offsets = [self.states[s.id].steps_done
                   for s in res_scn.services]
        scheduler, quality = offset_aware(self.scheduler, self.quality,
                                          offsets)
        with arrays.engine_scope(self.engine):
            alloc = np.asarray(self.allocator(
                res_scn, scheduler, self.delay, quality))
            tp, plan = make_plan(res_scn, alloc, scheduler, self.delay,
                                 quality)
        if self.validate:
            plan.validate(gen_deadlines=tp)
        self.replans += 1

        self.alloc_map.update(
            {s.id: float(alloc[j])
             for j, s in enumerate(res_scn.services)})
        self.batches = list(plan.batches)
        self.last = self._last_batch_of(self.batches)
        self.i = 0
        self._drift.clear()
        # a partially-generated service the new plan gives no further
        # steps is done denoising: transmit what it has, now
        for k in sorted(self.pending):
            st = self.states[k]
            if st.steps_done > 0 and \
                    plan.steps_completed.get(k, 0) == 0:
                self._complete(st, t, self.alloc_map[k])
        self.session.retarget(
            {s.id: self.states[s.id].steps_done +
             int(plan.steps_completed.get(s.id, 0))
             for s in res_scn.services})

    def _finalize(self, t: float) -> ExecutionResult:
        # defensively settle any straggler with banked steps (cannot
        # happen when every plan runs to completion, but cheap to hold)
        for k in sorted(self.pending):
            st = self.states[k]
            if st.steps_done > 0 and not st.gen_complete:
                self._complete(st, t, self.alloc_map[k])
        content = self.session.finish()
        if self.fit.ready:
            # final refit from the telemetry window, in both modes —
            # result.delay always reflects the measured hardware
            self.delay = self.fit.model()
            self.refits += 1
        outcomes = []
        for s in self.scenario.services:
            st = self.states[s.id]
            T = st.steps_done
            if st.gen_complete:
                gen = st.gen_end - s.arrival
                tx = st.tx_dur
                e2e = gen + tx
                met = T > 0 and e2e <= s.deadline + _TIE
            else:
                gen = tx = e2e = 0.0
                met = False
            outcomes.append(ServiceOutcome(
                id=s.id, deadline=s.deadline, steps=T, gen_delay=gen,
                tx_delay=tx, e2e_delay=e2e, fid=self.quality.fid(T),
                met_deadline=met))
        mean_fid = float(np.mean([o.fid for o in outcomes])) \
            if outcomes else float("nan")
        outage = float(np.mean([0.0 if o.met_deadline else 1.0
                                for o in outcomes])) if outcomes else 0.0
        fid0 = self.quality.fid(0)
        delivered = float(np.mean(
            [o.fid if o.met_deadline else fid0 for o in outcomes])) \
            if outcomes else float("nan")
        tele_fn = getattr(self.session, "telemetry", None)
        session_tele = tele_fn() if callable(tele_fn) else None
        exec_engine = self.exec_engine or \
            (session_tele or {}).get("exec_engine", "")
        return ExecutionResult(
            outcomes=outcomes, records=self.records, content=content,
            delay=self.delay, mean_fid=mean_fid, outage_rate=outage,
            delivered_fid=delivered, wall_clock=t, replans=self.replans,
            refits=self.refits, mode=self.mode,
            executed_log=self.executed_log, exec_engine=exec_engine,
            session_telemetry=session_tele)
