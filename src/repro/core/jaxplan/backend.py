"""The "jax" planner engine: jitted outer searches behind the same
entry points the vec/scalar engines dispatch through.

Each search runs its candidate sweep as one jit-compiled kernel
(``repro.core.jaxplan.kernels``), scores the resulting ``(L, K)``
count matrix — vectorized in jax when the quality model is the
paper's ``PowerLawFID``, through the exact scalar calls otherwise —
applies the scalar searches' first-strictly-better selection rule,
and materializes only the winning candidate via the exact NumPy
single-level pass from ``repro.core.arrays``.  Returned plans are
therefore always valid ``BatchPlan``s built by the same code the
other engines use; what may differ from the vec/scalar engines —
within the documented tolerance (docs/PERFORMANCE.md) — is *which*
candidate wins when two levels score within ~1e-12 of each other.
"""

from __future__ import annotations

from typing import Dict, Sequence

import numpy as np

from repro.core import arrays
from repro.core.delay_model import DelayModel
from repro.core.jaxplan import kernels
from repro.core.plan import BatchPlan
from repro.core.quality_model import PowerLawFID


def _score(Tc: np.ndarray, quality) -> np.ndarray:
    """Row scores for a count matrix: the jitted power-law fast path
    for a bare ``PowerLawFID``, else ``arrays.score_rows`` (exact,
    deduplicated).  Wrapped objectives — notably the online
    replanner's ``_OffsetQuality``, whose ``mean_fid`` shifts counts
    by per-service progress and applies the doomed rule — must NOT be
    unwrapped here: ``offset_plan`` below reconstructs that objective
    explicitly; every other wrapper goes through its own ``mean_fid``.
    """
    if type(quality) is PowerLawFID:
        return kernels.powerlaw_scores(Tc, quality, None)
    return arrays.score_rows(Tc, quality)


def _first_best(qs: np.ndarray) -> int:
    """First candidate strictly better (by 1e-12) than everything
    before it — the scalar searches' selection rule, on host."""
    best_i, best_q = -1, float("inf")
    for i, q in enumerate(qs.tolist()):
        if q < best_q - 1e-12:
            best_i, best_q = i, q
    return best_i


def stacking(services, tau_prime: Dict[int, float], delay: DelayModel,
             quality, t_star_max: int = 0) -> BatchPlan:
    """Algorithm 1 with the outer T* search as one jitted sweep; the
    winning level is materialized by the exact NumPy pass."""
    ids = [s.id for s in services]
    if t_star_max <= 0:
        t_star_max = max(1, max(delay.max_steps(tau_prime[k])
                                for k in ids))
    arr = arrays.ServiceArrays.build(ids, tau_prime)
    levels = np.arange(1, t_star_max + 1, dtype=np.int64)
    Tc, _ = kernels.clustered_counts(arr.tau_prime, arr.offsets, levels,
                                     delay, ids=arr.ids)
    best = _first_best(_score(Tc, quality))
    assert best >= 0
    return arrays.stacking_pass_vec(ids, tau_prime, delay,
                                    int(levels[best]))


def equal_steps(services, tau_prime: Dict[int, float], delay: DelayModel,
                quality) -> BatchPlan:
    """The balanced baseline with its shared-target search as one
    jitted lockstep sweep (row l targets T* = l + 1 for everyone)."""
    ids = [s.id for s in services]
    feasible = [k for k in ids if delay.max_steps(tau_prime[k]) > 0]
    t_max = max([delay.max_steps(tau_prime[k]) for k in feasible],
                default=1)
    arr = arrays.ServiceArrays.build(ids, tau_prime)
    levels = np.arange(1, max(1, t_max) + 1, dtype=np.int64)
    targets = np.broadcast_to(levels[:, None],
                              (levels.size, arr.K)).copy()
    Tc, _ = kernels.lockstep_counts(arr.tau_prime, targets, delay)
    best = _first_best(_score(Tc, quality))
    assert best >= 0
    level = int(levels[best])
    return arrays.offset_pass_vec(ids, tau_prime, delay,
                                  {k: level for k in ids})


def offset_plan(ids: Sequence[int], tau_prime: Dict[int, float],
                delay: DelayModel, oq, off: Dict[int, int],
                level_max: int, t_new_max: int) -> BatchPlan:
    """``StackingOffset``'s three candidate families, each swept as
    one jitted kernel and scored under the progress-aware objective
    (``_OffsetQuality`` semantics: ``fid(done + new)`` with the doomed
    rule), with the scalar tie rule — objective first, shorter
    makespan among objective-equal candidates."""
    arr = arrays.ServiceArrays.build(ids, tau_prime, off)
    off_vec = arr.offsets
    doomed = np.zeros(arr.K, dtype=bool)
    for i in getattr(oq, "doomed", ()):
        doomed[i] = True
    # the _OffsetQuality objective, reconstructed for the jitted
    # scorer: fid(offset + new) with doomed -> fid(0), offsets and
    # doomed exactly as ``oq`` carries them (positionally aligned with
    # ``ids``).  Non-power-law bases take the exact score_rows path.
    base = getattr(oq, "base", None)
    if type(base) is not PowerLawFID:
        base = None

    def score(Tc, offsets):
        if base is not None:
            return kernels.powerlaw_scores(Tc, base, offsets, doomed)
        return arrays.score_rows(Tc, oq)

    state = {"q": oq.mean_fid([0] * len(ids)), "ms": 0.0,
             "pick": None}        # None = the all-retire empty plan

    def consider(q: float, ms: float, pick) -> None:
        if q < state["q"] - 1e-12 or \
                (q < state["q"] + 1e-12 and ms < state["ms"] - 1e-12):
            state.update(q=q, ms=ms, pick=pick)

    levels = np.arange(1, level_max + 1, dtype=np.int64)
    # family 1 — Algorithm 1 clustered on TOTAL counts
    Tc1, ms1 = kernels.clustered_counts(arr.tau_prime, off_vec, levels,
                                        delay, ids=arr.ids)
    for i, q in enumerate(score(Tc1, off_vec).tolist()):
        consider(q, float(ms1[i]), ("clustered", i))

    # family 2 — lockstep water-filling over the total-step level
    targets = np.maximum(levels[:, None] - off_vec[None, :], 0)
    nonzero = targets.any(axis=1)
    Tc2, ms2 = kernels.lockstep_counts(arr.tau_prime, targets, delay)
    for i, q in enumerate(score(Tc2, off_vec).tolist()):
        if nonzero[i]:
            consider(q, float(ms2[i]), ("lockstep", i))

    # family 3 — shared-NEW-horizon Algorithm 1 candidates
    levels3 = np.arange(1, t_new_max + 1, dtype=np.int64)
    Tc3, ms3 = kernels.clustered_counts(
        arr.tau_prime, np.zeros(arr.K, dtype=np.int64), levels3, delay,
        ids=arr.ids)
    for i, q in enumerate(score(Tc3, off_vec).tolist()):
        consider(q, float(ms3[i]), ("shared", i))

    pick = state["pick"]
    if pick is None:
        return BatchPlan(batches=[], start_times=[],
                         steps_completed={k: 0 for k in ids},
                         delay=delay)
    family, i = pick
    if family == "clustered":
        return arrays.stacking_pass_vec(ids, tau_prime, delay,
                                        int(levels[i]), offsets=off)
    if family == "lockstep":
        tgt = {k: max(0, int(levels[i]) - off.get(k, 0)) for k in ids}
        return arrays.offset_pass_vec(ids, tau_prime, delay, tgt)
    return arrays.stacking_pass_vec(ids, tau_prime, delay,
                                    int(levels3[i]))
