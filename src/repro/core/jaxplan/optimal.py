"""Array-native exact search: ``repro.core.optimal``'s memoized DP
re-expressed as a breadth-first sweep over integer state levels, with
the per-level expansion and scoring jit-compiled.

The scalar DP recurses over (n_batches, sorted (tau', steps) pairs)
with an lru_cache.  Two observations turn that into fixed-shape array
work:

* With services pinned in tau'-ascending order, a state is just the
  int64 steps vector, *canonicalized* by sorting steps within each
  equal-tau' group — exactly the scalar DP's sorted-tuple key.  BFS
  depth == n_batches, so ``np.unique`` over a level's state rows IS
  the memoization.
* Budgets shrink by the shared elapsed time a*S + b*n, so the active
  set is always a suffix of the tau'-sorted order and the scalar DP's
  "batch the m tightest actives" move is "+1 to the first m of that
  suffix" — one masked add, vmappable over every (state, m) pair.

Since stopping is allowed in every state, the optimum is the minimum
stop-value over all reachable states; parents are tracked per level so
the winning batch-size sequence can be replayed through the scalar
member rule into an executable ``BatchPlan``.  The objective equals
the scalar DP's within float tolerance; among exactly tied optima the
reconstructed plan may legitimately differ.
"""

from __future__ import annotations

from typing import Dict, List, Sequence, Tuple

import numpy as np

import jax
import jax.numpy as jnp
from jax.experimental import enable_x64

from repro.core.delay_model import DelayModel
from repro.core.jaxplan.kernels import _bucket
from repro.core.plan import BatchPlan
from repro.core.quality_model import QualityModel

_EPS = 1e-12      # same affordability slack as repro.core.optimal


def _expand_core(states, valid, taus, group, fid_table, depth, a, b):
    """One BFS level, jitted: stop-values of every state plus all
    (state, m) children in canonical form with their feasibility.
    ``states (N, K) int64``-> ``(stop_v (N,), children (N, K, K),
    feas (N, K))`` where children[i, m-1] batches the m tightest
    actives of state i."""
    N, K = states.shape
    g1 = a * 1 + b
    elapsed = a * states.sum(axis=-1) + b * depth
    active = taus[None, :] - elapsed[:, None] + _EPS >= g1
    n_active = active.sum(axis=-1)
    fa = jnp.argmax(active, axis=-1)            # first index of the suffix
    tight = taus[fa]                            # tightest active budget

    ms = jnp.arange(1, K + 1, dtype=jnp.int64)
    feas = (ms[None, :] <= n_active[:, None]) \
        & (tight[:, None] - elapsed[:, None] + _EPS
           >= a * ms[None, :].astype(jnp.float64) + b) \
        & valid[:, None]

    j = jnp.arange(K, dtype=jnp.int64)
    add = (j[None, None, :] >= fa[:, None, None]) \
        & (j[None, None, :] < fa[:, None, None] + ms[None, :, None])
    children = states[:, None, :] + add.astype(jnp.int64)

    # canonicalize: steps sorted within each equal-tau group (groups are
    # contiguous and position-ascending, so one keyed sort per row does it)
    big = jnp.int64(fid_table.shape[0])
    children = jnp.sort(group[None, None, :] * big + children,
                        axis=-1) % big

    stop_v = jnp.where(valid,
                       fid_table[states].sum(axis=-1), jnp.inf)
    return stop_v, children, feas


_expand_jit = jax.jit(_expand_core)


def _search(taus: np.ndarray, delay: DelayModel, quality: QualityModel
            ) -> Tuple[float, List[Tuple[np.ndarray, np.ndarray]], int, int]:
    """BFS over canonical states.  Returns (best stop-value, per-level
    (parent_idx, m) arrays, best depth, best index-at-depth)."""
    K = taus.size
    a, b = delay.a, delay.b
    g1 = delay.min_task_delay()
    assert g1 > 0, "degenerate delay model: g(1) must be positive"
    # any service's step count is bounded: its s-th step cannot start
    # before (s-1) earlier batches ran, each costing >= g1 elapsed
    s_max = int(float(taus.max(initial=0.0)) / g1) + 4
    fid_table = np.array([quality.fid(s) for s in range(s_max + 1)],
                         dtype=np.float64)
    _, group = np.unique(taus, return_inverse=True)
    group = group.astype(np.int64)

    states = np.zeros((1, K), dtype=np.int64)
    parents: List[Tuple[np.ndarray, np.ndarray]] = []
    best_v, best_d, best_i = np.inf, 0, 0
    depth = 0
    while states.shape[0]:
        N = states.shape[0]
        Np = _bucket(N)
        st_p = np.zeros((Np, K), dtype=np.int64)
        st_p[:N] = states
        valid = np.zeros(Np, dtype=bool)
        valid[:N] = True
        with enable_x64():
            stop_v, children, feas = _expand_jit(
                st_p, valid, taus, group, fid_table, np.int64(depth),
                a, b)
        stop_v = np.asarray(stop_v)
        i = int(np.argmin(stop_v))
        if stop_v[i] < best_v - _EPS:
            best_v, best_d, best_i = float(stop_v[i]), depth, i

        pidx, midx = np.nonzero(np.asarray(feas))
        if pidx.size == 0:
            break
        flat = np.asarray(children)[pidx, midx]
        states, first = np.unique(flat, axis=0, return_index=True)
        parents.append((pidx[first], midx[first] + 1))
        depth += 1
    return best_v, parents, best_d, best_i


def _batch_sizes(parents, depth: int, idx: int) -> List[int]:
    """Backtrack the winning state to the root, yielding the batch-size
    sequence that reaches it."""
    ms: List[int] = []
    while depth > 0:
        pidx, m = parents[depth - 1]
        ms.append(int(m[idx]))
        idx = int(pidx[idx])
        depth -= 1
    ms.reverse()
    return ms


def optimal_mean_fid(tau_prime: Sequence[float], delay: DelayModel,
                     quality: QualityModel, max_steps: int = 60,
                     grid: float = 1e-3) -> float:
    """Exact minimum mean FID, BFS/jit variant of
    ``repro.core.optimal.optimal_mean_fid`` (same unused legacy args)."""
    taus = np.sort(np.asarray([float(t) for t in tau_prime],
                              dtype=np.float64))
    best_v, _, _, _ = _search(taus, delay, quality)
    return best_v / max(1, taus.size)


def optimal_plan(services, tau_prime: Dict[int, float], delay: DelayModel,
                 quality: QualityModel, *,
                 max_services: int = 8) -> BatchPlan:
    """Exact-search scheduler, BFS/jit variant of
    ``repro.core.optimal.optimal_plan``: same objective (within float
    tolerance), same member rule when replaying the winning batch-size
    sequence, so the plan passes ``validate(gen_deadlines=tau_prime)``."""
    ids = [s.id for s in services]
    K = len(ids)
    assert K <= max_services, \
        f"optimal_plan is exact search; K={K} > {max_services}"
    taus = np.sort(np.asarray([float(tau_prime[k]) for k in ids],
                              dtype=np.float64))
    _, parents, best_d, best_i = _search(taus, delay, quality)
    ms = _batch_sizes(parents, best_d, best_i)

    a, b = delay.a, delay.b
    g1 = delay.min_task_delay()
    Tc = {k: 0 for k in ids}
    batches, starts = [], []
    for n, m in enumerate(ms):
        elapsed = a * sum(Tc.values()) + b * n
        pairs = sorted((float(tau_prime[k]), Tc[k], k) for k in ids)
        members = [k for t, _, k in pairs
                   if t - elapsed + _EPS >= g1][:m]
        batches.append([(k, Tc[k]) for k in members])
        starts.append(elapsed)
        for k in members:
            Tc[k] += 1
    return BatchPlan(batches=batches, start_times=starts,
                     steps_completed=Tc, delay=delay)
