"""``plan_many`` — the whole Algorithm-1 T* search, vmapped over a
stack of scenarios and compiled to one XLA program.

Scenario sweeps and MPC-style lookahead need thousands of *small*
plans, and at that scale Python dispatch — not arithmetic — is what
the vec engine pays for per scenario.  Stacking the scenarios into a
``(S, K)`` tau' matrix (padded to a common K, with a validity mask)
amortizes everything: one jitted call runs the clustered sweep, the
power-law scoring and the first-best selection for all S scenarios at
once and returns per-scenario winning levels, completed counts,
objectives and makespans.

Scenario rows are independent — a padded service (``valid=False`` or
tau'=0) never joins a batch and never contributes to the objective.
Tau' ties inside a scenario are broken by position (the batched
equivalent of the service-id tie-break, exact when ids are
0..K-1 in position order, which is how scenario samplers build
instances).  Materializing the ragged batch lists for a chosen
scenario stays a per-scenario call: ``arrays.stacking_pass_vec(ids,
tau_prime, delay, int(res.best_level[i]))``.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from repro.core.delay_model import DelayModel
from repro.core.jaxplan import kernels
from repro.core.quality_model import PowerLawFID


@dataclasses.dataclass(frozen=True)
class PlanManyResult:
    """Per-scenario outputs of one batched T* search."""
    best_level: np.ndarray   # (S,) int64 — winning T* per scenario
    steps: np.ndarray        # (S, K) int64 — completed counts T_k
    mean_fid: np.ndarray     # (S,) float64 — objective at the winner
    makespan: np.ndarray     # (S,) float64 — busy time at the winner

    @property
    def num_scenarios(self) -> int:
        return self.best_level.shape[0]


def _check_inputs(tau_prime: np.ndarray, quality,
                  offsets: Optional[np.ndarray],
                  valid: Optional[np.ndarray]):
    """Shared input normalization of ``plan_many`` and its sharded
    twin: ``(S, K)`` float64 budgets with padding masked inert, int64
    offsets, bool validity."""
    tau_prime = np.atleast_2d(np.asarray(tau_prime, dtype=np.float64))
    S, K = tau_prime.shape
    if not isinstance(quality, PowerLawFID):
        raise TypeError("plan_many scores inside the jitted kernel and "
                        "supports PowerLawFID objectives only; use the "
                        "per-scenario stacking() entry point for custom "
                        "quality models")
    off = np.zeros((S, K), dtype=np.int64) if offsets is None \
        else np.broadcast_to(np.asarray(offsets, dtype=np.int64),
                             (S, K)).copy()
    vd = np.ones((S, K), dtype=bool) if valid is None \
        else np.broadcast_to(np.asarray(valid, dtype=bool), (S, K)).copy()
    taup0 = np.where(vd, tau_prime, 0.0)    # padded services are inert
    return taup0, off, vd, S, K


def _pad_stack(taup0: np.ndarray, off: np.ndarray, vd: np.ndarray,
               delay: DelayModel, t_star_max: int, Sp: int):
    """Pad a normalized ``(S, K)`` stack out to ``(Sp, Kp)`` (K to its
    power-of-two bucket, S to the caller's row count — a bucket for the
    single-device path, a device-divisible multiple for the sharded
    one) and derive every host-side kernel input: padded arrays, tie
    ranks, F thresholds, the padded level grid, the key shift and the
    static radix-selection bit count."""
    S, K = taup0.shape
    if t_star_max <= 0:
        loosest = float(taup0.max(initial=0.0))
        t_star_max = max(1, delay.max_steps(loosest))
    levels = np.arange(1, t_star_max + 1, dtype=np.int64)
    Kp, Lp = kernels._bucket(K), kernels._bucket(levels.size)
    taup_p = np.zeros((Sp, Kp), dtype=np.float64)
    taup_p[:S, :K] = taup0
    off_p = np.zeros((Sp, Kp), dtype=np.int64)
    off_p[:S, :K] = off
    vd_p = np.zeros((Sp, Kp), dtype=bool)
    vd_p[:S, :K] = vd
    lv_p = kernels._pad_tail(levels, Lp, int(levels[-1]))
    shift = np.int64(max(Kp, 1).bit_length())
    tie = kernels._tie_ranks(taup_p)
    f_thr = kernels._f_threshold(taup_p, off_p, lv_p, int(shift),
                                 delay.a + delay.b)
    kb = kernels._key_bits(taup_p, off_p, int(shift),
                           delay.a + delay.b)
    return taup_p, off_p, vd_p, tie, f_thr, lv_p, shift, kb


def plan_many(tau_prime: np.ndarray, *, delay: DelayModel,
              quality: PowerLawFID,
              offsets: Optional[np.ndarray] = None,
              valid: Optional[np.ndarray] = None,
              t_star_max: int = 0,
              devices=None) -> PlanManyResult:
    """Plan S stacked scenarios in a single jitted call.

    ``tau_prime`` is ``(S, K)`` denoising budgets, K padded to the
    widest scenario; ``valid`` (same shape, default all-true) masks the
    padding; ``offsets`` (int, same shape) carries already-completed
    steps for replanning sweeps.  ``quality`` must be a ``PowerLawFID``
    (the paper's objective) — scoring runs inside the fused kernel.
    ``t_star_max=0`` sizes the candidate grid from the loosest budget.

    ``devices`` shards the scenario axis: ``None`` (default) runs on
    one device, an int n uses the first n local devices, a sequence of
    jax devices uses exactly those (``repro.core.jaxplan.sharded``;
    results match the single-device call within the documented 1e-9
    mean-FID tolerance).
    """
    if devices is not None:
        from repro.core.jaxplan import sharded
        return sharded.plan_many_sharded(
            tau_prime, delay=delay, quality=quality, offsets=offsets,
            valid=valid, t_star_max=t_star_max, devices=devices)
    taup0, off, vd, S, K = _check_inputs(tau_prime, quality, offsets,
                                         valid)
    # bucket-pad every axis so sweeps of varying width reuse jits
    taup_p, off_p, vd_p, tie, f_thr, lv_p, shift, kb = _pad_stack(
        taup0, off, vd, delay, t_star_max, kernels._bucket(S))

    with kernels.enable_x64():
        best_i, counts, best_q, ms = kernels._plan_many_core(
            taup_p, off_p, vd_p, tie, f_thr, lv_p, shift,
            delay.a, delay.b, quality.alpha, quality.beta,
            quality.gamma, quality.fid_at_zero, kb)
    best_i = np.asarray(best_i)[:S]
    return PlanManyResult(
        best_level=lv_p[np.maximum(best_i, 0)].astype(np.int64),
        steps=np.asarray(counts)[:S, :K],
        mean_fid=np.asarray(best_q)[:S],
        makespan=np.asarray(ms)[:S],
    )


def _replan_prep(taup0: np.ndarray, soff: np.ndarray, vd: np.ndarray,
                 dm: np.ndarray, delay: DelayModel, t_star_max: int,
                 Sp: int):
    """Host-side inputs of the replan block: ``_pad_stack`` with the
    pass offsets zeroed (the shared-horizon residual pass), the score
    offsets / doomed mask padded alongside, and the per-scenario
    level-validity mask capping each row's candidate grid at its own
    t_star_max — the grid the per-cell ``stacking_vec`` search sweeps,
    so winner selection sees the same candidate set."""
    S, K = taup0.shape
    step = delay.a + delay.b
    loosest = taup0.max(axis=-1, initial=0.0)
    caps = np.maximum(1, np.where(loosest > 0, loosest / step,
                                  0.0).astype(np.int64))
    if t_star_max > 0:
        caps = np.minimum(caps, t_star_max)
    taup_p, _, vd_p, tie, f_thr, lv_p, shift, kb = _pad_stack(
        taup0, np.zeros_like(soff), vd, delay,
        int(caps.max(initial=1)), Sp)
    Kp = taup_p.shape[1]
    soff_p = np.zeros((Sp, Kp), dtype=np.int64)
    soff_p[:S, :K] = soff
    dm_p = np.zeros((Sp, Kp), dtype=bool)
    dm_p[:S, :K] = dm
    caps_p = np.ones(Sp, dtype=np.int64)
    caps_p[:S] = caps
    lv_ok = lv_p[None, :] <= caps_p[:, None]
    return taup_p, soff_p, vd_p, dm_p, tie, f_thr, lv_p, lv_ok, shift, kb


def replan_many(tau_prime: np.ndarray, *, delay: DelayModel,
                quality: PowerLawFID,
                offsets: Optional[np.ndarray] = None,
                doomed: Optional[np.ndarray] = None,
                valid: Optional[np.ndarray] = None,
                t_star_max: int = 0,
                devices=None) -> PlanManyResult:
    """Batched *residual* replans: S concurrent shared-horizon replans
    (the ``repro.core.online`` semantics) in one jitted call.

    Differs from ``plan_many`` in exactly the ways a mid-flight replan
    differs from a fresh plan: the clustered pass runs with ZERO
    offsets over the residual budgets (``offsets`` never join the
    candidate family), candidates are scored progress-aware as
    ``fid(offsets + counts)`` with ``doomed`` services pinned at
    ``fid(0)`` (the ``online._OffsetQuality`` objective; pass
    ``doomed[s, k] = offsets[s, k] > 0 and tau_prime[s, k] < 0``), and
    each scenario's candidate grid is capped at its own t_star_max so
    winner selection matches the per-cell search row for row.  With
    all-zero offsets this is ``plan_many`` plus the per-scenario grid
    cap.  ``devices`` shards the scenario axis exactly like
    ``plan_many(devices=...)``.
    """
    if devices is not None:
        from repro.core.jaxplan import sharded
        return sharded.replan_many_sharded(
            tau_prime, delay=delay, quality=quality, offsets=offsets,
            doomed=doomed, valid=valid, t_star_max=t_star_max,
            devices=devices)
    taup0, soff, vd, S, K = _check_inputs(tau_prime, quality, offsets,
                                          valid)
    dm = np.zeros((S, K), dtype=bool) if doomed is None \
        else np.broadcast_to(np.asarray(doomed, dtype=bool),
                             (S, K)).copy()
    (taup_p, soff_p, vd_p, dm_p, tie, f_thr, lv_p, lv_ok, shift,
     kb) = _replan_prep(taup0, soff, vd, dm, delay, t_star_max,
                        kernels._bucket(S))
    with kernels.enable_x64():
        best_i, counts, best_q, ms = kernels._replan_many_core(
            taup_p, soff_p, vd_p, dm_p, tie, f_thr, lv_p, lv_ok, shift,
            delay.a, delay.b, quality.alpha, quality.beta,
            quality.gamma, quality.fid_at_zero, kb)
    best_i = np.asarray(best_i)[:S]
    return PlanManyResult(
        best_level=lv_p[np.maximum(best_i, 0)].astype(np.int64),
        steps=np.asarray(counts)[:S, :K],
        mean_fid=np.asarray(best_q)[:S],
        makespan=np.asarray(ms)[:S],
    )
