"""``repro.core.jaxplan`` — the jit-compiled "jax" planner engine.

Importing this package registers ``engine="jax"`` with
``repro.core.arrays``' engine registry; the existing dispatch
(``set_engine`` / ``engine_scope`` / per-call ``engine=`` kwargs /
``REPRO_PLANNER_ENGINE=jax``) then routes the planner entry points
here.  The import is lazy and optional: ``arrays`` only probes this
package when someone asks for an engine it does not know, so a repo
checkout without jax keeps working untouched (requesting ``"jax"``
there raises a ValueError naming the missing backend).

Layout:

* ``kernels``  — jitted ``(L, K)`` sweeps (``lax.while_loop`` rounds,
  every candidate level advancing together) + scoring/selection.
* ``backend``  — the engine entry points (``stacking``,
  ``equal_steps``, ``offset_plan``) that the vec/scalar dispatch
  sites call through ``arrays.engine_impl("jax")``.
* ``batched``  — ``plan_many``: the whole T* search vmapped over
  ~10^3 stacked scenarios in one jitted call.
* ``sharded``  — ``plan_many_sharded``: the scenario axis split across
  devices with ``shard_map`` (``plan_many(..., devices=...)`` routes
  here; pmap fallback on older jax).
* ``optimal``  — the exact DP as a jitted breadth-first sweep.

Equivalence contract: objectives match the NumPy reference within the
tolerance documented in docs/PERFORMANCE.md ("jax engine"), never bit
for bit — XLA may reassociate reductions.  Returned ``BatchPlan``s are
always materialized by the exact NumPy single-level passes, so they
satisfy the paper's constraints regardless of engine.
"""

from __future__ import annotations

import types

import jax as _jax  # noqa: F401 — fail fast (ImportError) when absent

from repro.core import arrays as _arrays
from repro.core.jaxplan import backend, batched, kernels, optimal, sharded
from repro.core.jaxplan.backend import equal_steps, offset_plan, stacking
from repro.core.jaxplan.batched import (PlanManyResult, plan_many,
                                        replan_many)
from repro.core.jaxplan.optimal import optimal_mean_fid, optimal_plan
from repro.core.jaxplan.sharded import (plan_many_sharded,
                                        replan_many_sharded,
                                        resolve_devices)

#: what ``arrays.engine_impl("jax")`` hands to the dispatch sites
IMPL = types.SimpleNamespace(
    name="jax",
    stacking=stacking,
    equal_steps=equal_steps,
    offset_plan=offset_plan,
    optimal_plan=optimal_plan,
    optimal_mean_fid=optimal_mean_fid,
    plan_many=plan_many,
    plan_many_sharded=plan_many_sharded,
    replan_many=replan_many,
    replan_many_sharded=replan_many_sharded,
)

_arrays.register_engine("jax", IMPL)

__all__ = [
    "IMPL",
    "PlanManyResult",
    "backend",
    "batched",
    "equal_steps",
    "kernels",
    "offset_plan",
    "optimal",
    "optimal_mean_fid",
    "optimal_plan",
    "plan_many",
    "plan_many_sharded",
    "replan_many",
    "replan_many_sharded",
    "resolve_devices",
    "sharded",
    "stacking",
]
