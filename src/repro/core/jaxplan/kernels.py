"""jnp ports of the (L, K) planning sweeps: ``lax.while_loop`` over
fixed-shape per-round state, every T*/water-level candidate advancing
together — the jit-compiled counterpart of ``repro.core.arrays``'
``_clustered_rounds`` / ``_lockstep_rounds``.

The kernels mirror the NumPy sweeps operation for operation (same
float64 arithmetic, same ``1e-12`` epsilons, same composite integer
sort keys), but jit/XLA may reassociate reductions and ``pow`` may
differ from libm in the last ulp, so the contract is *tolerance*
equivalence of objectives — never bit identity (that stays the NumPy
vec engine's contract against the scalar reference).  See
docs/PERFORMANCE.md ("jax engine").

Only completed *counts* and makespans come out of the jitted loops:
batch lists are inherently ragged, so the winning candidate is
materialized afterwards by the exact NumPy single-level pass
(``arrays.stacking_pass_vec`` / ``arrays.offset_pass_vec``) — the jax
engine spends its time where the work is, scoring L x K x rounds, and
returns plans constructed by the same code every other engine uses.

All public helpers here take/return NumPy arrays and run the jitted
core under ``jax.experimental.enable_x64`` so the planner's float64
semantics never leak x64 config into the rest of the process (the
Pallas denoiser kernels stay float32).  Shapes are padded to
power-of-two buckets (``_bucket``) so online replans — whose residual
K and level count shrink every event — reuse a handful of compiled
variants instead of recompiling per instant.
"""

from __future__ import annotations

from typing import Optional, Tuple

import numpy as np

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import enable_x64

from repro.core.delay_model import DelayModel

# same sentinel as repro.core.arrays._TP_INF (not imported: this module
# must stay importable while arrays is mid-initialization during an
# env-var backend probe)
_TP_INF = np.int64(1) << 62


def _bucket(n: int) -> int:
    """Padded-shape buckets that bound jit recompilation across
    shrinking replan instances: powers of two (min 8) up to 4096 —
    the regime online replans churn through — then multiples of 2048,
    where population-scale sweeps would otherwise pay up to 2x padding
    for one extra compiled variant (K=10^4 pads to 10240, not 16384)."""
    if n <= 4096:
        return max(8, 1 << max(0, int(n - 1).bit_length()))
    return 2048 * ((int(n) + 2047) // 2048)


# -------------------------------------------------------------------------
# Per-round selection: the x_n-th smallest composite key, sort-free
# -------------------------------------------------------------------------
#
# The batching step needs ONE number per candidate row: the x_n-th
# smallest composite key (``Tp * M + tie``), which is the membership
# threshold of the round's batch.  A full ``jnp.sort`` over the (L, K)
# key table delivers it but dominates the whole kernel at K = 10^4 on
# CPU (XLA's sort is scalar per row; NumPy's beats it, which is why
# the single-scenario jax row used to lose to vec at that size).  The
# keys are bounded non-negative integers with a host-computable bit
# width, so a bitwise (radix) *selection* finds the same threshold in
# ``key_bits`` fused compare-and-count passes — no ordering of the
# inactive tail, no data movement, and it vectorizes over every
# candidate row and (under vmap) every scenario at once.

def _select_kth_key(key, x_n, key_bits):
    """The ``x_n``-th smallest value of ``key`` along the last axis,
    per row, via bitwise binary search: the largest ``T`` with
    ``count(key < T) < x_n`` over a monotone predicate IS that order
    statistic when keys are unique integers (they are: every active
    key embeds a distinct tie rank, and x_n never exceeds the active
    count, so the sentinel tail is never selected).  ``key_bits`` (a
    static python int) bounds the real-key domain; rows with
    ``x_n == 0`` return 0 and must be masked by the caller (the scalar
    path's ``thr = -1`` rule)."""

    one = jnp.ones((), dtype=key.dtype)

    def bit_step(i, thr):
        bit = (key_bits - 1 - i).astype(key.dtype)
        cand = thr | jnp.left_shift(one, bit)
        cnt = jnp.sum(key < cand[..., None], axis=-1, dtype=jnp.int64)
        return jnp.where(cnt < x_n, cand, thr)

    thr0 = jnp.zeros(key.shape[:-1], dtype=key.dtype)
    return lax.fori_loop(0, key_bits, bit_step, thr0)


def _sort_kth_key(key, x_n):
    """Reference selection via the full composite-key sort (the
    pre-sharding scheme), kept for the decision-identity property
    tests in tests/test_jaxplan_properties.py."""
    sorted_key = jnp.sort(key, axis=-1)
    return jnp.take_along_axis(sorted_key,
                               jnp.maximum(x_n - 1, 0)[..., None],
                               axis=-1)[..., 0]


def _key_bits(taup0: np.ndarray, off: np.ndarray, shift: int,
              step_cost: float) -> int:
    """Static bit width of the composite-key domain for a (possibly
    scenario-stacked) instance: real keys are ``Tp * M + tie`` with
    ``Tp <= tp_bound`` (the same bound ``_f_threshold`` clamps to), so
    every active key fits in this many bits and the radix selection's
    trip count is a host-side constant the jit cache can key on."""
    M = np.int64(1) << np.int64(shift)
    te0_max = np.int64(np.max(np.maximum(taup0, 0.0), initial=0.0)
                       / step_cost)
    tp_bound = np.int64(np.max(off, initial=0) if off.size else 0) \
        + 2 * te0_max + 4
    return max(1, int((tp_bound + 1) * M - 1).bit_length())


# -------------------------------------------------------------------------
# The clustered (Algorithm-1) sweep
# -------------------------------------------------------------------------

#: level rows per independently-converging while_loop (divides every
#: ``_bucket`` size).  Per-level round counts are heavily skewed — deep
#: levels converge in 2-4 rounds while shallow ones take 30+ — so one
#: lockstep loop over all L rows pays max_rounds * L row-rounds.
#: Chunking the level axis into CHUNK-row loops (run sequentially by
#: ``lax.map``) pays only sum(chunk_max * CHUNK), a ~3x cut at K=10^4,
#: and makes the L padding nearly free (pad chunks converge instantly).
_LEVEL_CHUNK = 4


def _clustered_chunk(taup0, off, levels, tie, f_thr, shift, a, b,
                     key_bits):
    """One scenario's Algorithm-1 rounds over one chunk of candidate
    levels: ``(taup0 (K,), off (K,), levels (Lc,), tie (K,), f_thr
    (Lc,))`` -> ``(Tc (Lc, K) int64, makespan (Lc,) float64)``.
    Literal port of ``arrays._clustered_rounds`` minus history
    recording, with the per-round full sort replaced by the
    decision-identical radix selection (``key_bits`` is the static
    trip count)."""
    L, K = levels.shape[0], taup0.shape[0]
    g1 = a * 1 + b                       # delay.min_task_delay()
    step_cost = a + b
    # composite keys fit in ``key_bits`` (static, host-derived), so the
    # integer round state — keys, counts, Tp — runs in int32 whenever
    # the domain allows: identical integer arithmetic, half the memory
    # traffic of int64 on the K=10^4 sweeps the radix selection serves
    idt = jnp.int32 if key_bits <= 31 else jnp.int64
    sent = jnp.asarray(jnp.iinfo(idt).max, idt)  # past every real key
    M = (jnp.int64(1) << shift).astype(idt)
    tie_i = tie.astype(idt)
    f_thr_i = f_thr.astype(idt)          # values bounded by key_bits
    off_i = off.astype(idt)

    lv_pos = levels > 0
    lv_f = levels.astype(jnp.float64)
    b_lv = b * lv_f
    a_lv = a * jnp.maximum(lv_f, 1.0)

    taup = jnp.tile(taup0, (L, 1))
    Tc = jnp.zeros((L, K), dtype=idt)
    active = jnp.tile(taup0 >= g1, (L, 1))
    t = jnp.zeros((L,), dtype=jnp.float64)

    def cond(state):
        _, _, active, _ = state
        return active.any()

    def body(state):
        taup, Tc, active, t = state
        # ---- clustering (Eqs. 15-18, offset-shifted) -----------------
        Te = (taup / step_cost).astype(idt)
        Tp = off_i[None, :] + Tc + Te
        key = jnp.where(active, Tp * M + tie_i[None, :], sent)

        n_active = active.sum(axis=-1, dtype=jnp.int64)
        F = key <= f_thr_i[:, None]
        n_F = F.sum(axis=-1, dtype=jnp.int64)

        # ---- packing (Eqs. 19-20) ------------------------------------
        te_max = jnp.max(jnp.where(F, Te, -1), axis=-1)
        tau_min = jnp.min(jnp.where(F, taup, jnp.inf), axis=-1)
        cap_f = jnp.floor((tau_min - b * te_max)
                          / (a * jnp.maximum(te_max, 1)))
        tp_min = jnp.right_shift(key.min(axis=-1), shift.astype(idt))
        cap_nf = jnp.floor((step_cost * tp_min - b_lv) / a_lv)
        x_f = jnp.where(te_max > 0,
                        jnp.maximum(n_F, jnp.minimum(n_active, cap_f)),
                        n_F)
        x_nf = jnp.minimum(n_active,
                           jnp.where(lv_pos, jnp.maximum(1, cap_nf),
                                     n_active))
        x_n = jnp.where(n_F > 0, x_f, x_nf)
        x_n = jnp.maximum(1, jnp.minimum(x_n, n_active))
        x_n = jnp.where(n_active > 0, x_n, 0).astype(jnp.int64)

        # ---- batching -------------------------------------------------
        # membership threshold = the x_n-th smallest key, selected
        # without sorting (see _select_kth_key above)
        thr = _select_kth_key(key, x_n, key_bits)
        thr = jnp.where(x_n > 0, thr, jnp.asarray(-1, idt))
        packed0 = key <= thr[:, None]

        def drop_cond(s):
            packed, _, n_packed = s
            g = a * n_packed + b
            return (packed & (taup + 1e-12 < g[:, None])).any()

        def drop_body(s):
            packed, act, n_packed = s
            g = a * n_packed + b
            drop = packed & (taup + 1e-12 < g[:, None])
            packed = packed & ~drop         # cannot afford this batch ->
            act = act & ~drop               # service is finished
            n_packed = packed.sum(axis=-1, dtype=jnp.int64)
            return packed, act, n_packed

        packed, active, n_packed = lax.while_loop(
            drop_cond, drop_body, (packed0, active, x_n))

        has_batch = n_packed > 0
        g = a * n_packed + b
        t = t + jnp.where(has_batch, g, 0.0)
        adv = active & has_batch[:, None]   # wall clock advances for all
        taup = taup - jnp.where(adv, g[:, None], 0.0)      # (Eq. 15)
        Tc = Tc + packed.astype(idt)
        # services that can no longer fit even a dedicated batch are done
        active = active & (taup + 1e-12 >= g1)
        return taup, Tc, active, t

    _, Tc, _, t = lax.while_loop(cond, body, (taup, Tc, active, t))
    return Tc.astype(jnp.int64), t


def _clustered_core(taup0, off, levels, tie, f_thr, shift, a, b,
                    key_bits):
    """All L candidate levels, as ``_LEVEL_CHUNK``-row chunks swept
    sequentially (``lax.map``) so each chunk's while_loop stops when
    ITS levels converge instead of riding along to the globally
    slowest row.  Row arithmetic is identical to one big lockstep
    loop — chunks are independent level rows of the same instance."""
    L, K = levels.shape[0], taup0.shape[0]
    if L % _LEVEL_CHUNK:                  # non-bucket L: one lockstep loop
        return _clustered_chunk(taup0, off, levels, tie, f_thr, shift,
                                a, b, key_bits)
    C = L // _LEVEL_CHUNK

    def one_chunk(xs):
        lv_c, f_thr_c = xs
        return _clustered_chunk(taup0, off, lv_c, tie, f_thr_c, shift,
                                a, b, key_bits)

    Tc, t = lax.map(one_chunk, (levels.reshape(C, _LEVEL_CHUNK),
                                f_thr.reshape(C, _LEVEL_CHUNK)))
    return Tc.reshape(L, K), t.reshape(L)


# -------------------------------------------------------------------------
# The lockstep sweep (equal_steps / offset_pass targets)
# -------------------------------------------------------------------------

def _lockstep_core(taup0, targets, a, b):
    """One scenario's lockstep rounds over all L target rows:
    ``(taup0 (K,), targets (L, K) int64)`` -> ``(Tc, makespan)``.
    Literal port of ``arrays._lockstep_rounds``."""
    L, K = targets.shape
    g1 = a * 1 + b

    taup = jnp.tile(taup0, (L, 1))
    Tc = jnp.zeros((L, K), dtype=jnp.int64)
    active = (targets > 0) & (taup0 >= g1)[None, :]
    t = jnp.zeros((L,), dtype=jnp.float64)

    def cond(state):
        _, _, active, _ = state
        return active.any()

    def body(state):
        taup, Tc, active, t = state

        def drop_cond(s):
            act, n = s
            g = a * n + b
            return (act & (taup + 1e-12 < g[:, None])).any()

        def drop_body(s):
            act, n = s
            g = a * n + b
            drop = act & (taup + 1e-12 < g[:, None])
            act = act & ~drop
            return act, act.sum(axis=-1, dtype=jnp.int64)

        n0 = active.sum(axis=-1, dtype=jnp.int64)
        active, n = lax.while_loop(drop_cond, drop_body, (active, n0))

        has_batch = n > 0
        g = a * n + b
        t = t + jnp.where(has_batch, g, 0.0)
        taup = taup - jnp.where(active, g[:, None], 0.0)
        Tc = Tc + active.astype(jnp.int64)
        active = active & (Tc < targets) & (taup + 1e-12 >= g1)
        return taup, Tc, active, t

    _, Tc, _, t = lax.while_loop(cond, body, (taup, Tc, active, t))
    return Tc, t


# -------------------------------------------------------------------------
# Scoring + selection (inside jit, PowerLawFID only)
# -------------------------------------------------------------------------

def _powerlaw_rows(Tc, offsets, valid, doomed, alpha, beta, gamma, fid0):
    """Masked progress-aware mean FID of every row of a ``(L, K)``
    count matrix: ``fid(offset + count)`` with the ``doomed -> fid(0)``
    rule, averaged over ``valid`` services only (pad rows excluded)."""
    tot = Tc + offsets[None, :]
    f = jnp.where(tot > 0,
                  alpha * tot.astype(jnp.float64) ** (-beta) + gamma,
                  fid0)
    f = jnp.where(doomed[None, :], fid0, f)
    f = jnp.where(valid[None, :], f, 0.0)
    return f.sum(axis=-1) / jnp.maximum(valid.sum(axis=-1), 1)


def _first_best(qs, valid_rows):
    """The scalar outer searches' selection rule — the FIRST candidate
    strictly better (by 1e-12) than everything before it — as a scan.
    ``valid_rows`` masks padded/disallowed candidates out entirely."""
    L = qs.shape[0]

    def step(carry, xi):
        best_i, best_q = carry
        i, q, ok = xi
        take = ok & (q < best_q - 1e-12)
        return (jnp.where(take, i, best_i),
                jnp.where(take, q, best_q)), None

    (bi, bq), _ = lax.scan(
        step, (jnp.int64(-1), jnp.float64(jnp.inf)),
        (jnp.arange(L, dtype=jnp.int64), qs, valid_rows))
    return bi, bq


# -------------------------------------------------------------------------
# Host-side preparation + jitted wrappers
# -------------------------------------------------------------------------

def _tie_ranks(taup0: np.ndarray,
               ids: Optional[np.ndarray] = None) -> np.ndarray:
    """The round-invariant (tau', id) tie-break of ``arrays``, as an
    integer rank per service.  ``ids`` breaks tau' ties for the
    single-scenario wrappers; batched callers (row position == id)
    rely on the stable argsort instead."""
    if ids is not None:
        order = np.lexsort((ids, taup0))
        tie = np.empty(taup0.size, dtype=np.int64)
        tie[order] = np.arange(taup0.size, dtype=np.int64)
        return tie
    order = np.argsort(taup0, axis=-1, kind="stable")
    tie = np.empty_like(order, dtype=np.int64)
    np.put_along_axis(tie, order,
                      np.broadcast_to(
                          np.arange(taup0.shape[-1], dtype=np.int64),
                          order.shape).copy(), axis=-1)
    return tie


def _f_threshold(taup0: np.ndarray, off: np.ndarray, levels: np.ndarray,
                 shift: int, step_cost: float) -> np.ndarray:
    """The priority-cluster membership threshold in composite-key
    space (``key <= lv*M + (M-1)  <=>  Tp <= lv``), clamped to the Tp
    bound so the int64 keys stay far from overflow.  Batched over a
    leading scenario axis when present."""
    M = np.int64(1) << shift
    te0_max = np.floor(np.max(np.maximum(taup0, 0.0), axis=-1)
                       / step_cost).astype(np.int64)
    tp_bound = (off.max(axis=-1) if off.size else np.int64(0)) \
        + 2 * te0_max + 4
    assert int(np.max(tp_bound, initial=0) + 2) * int(M) < int(_TP_INF), \
        "key space overflow"
    lv = levels[..., :] if taup0.ndim == 1 else levels[None, :]
    bound = tp_bound if taup0.ndim == 1 else tp_bound[:, None]
    return np.where(lv >= 0, np.minimum(lv, bound) * M + (M - 1),
                    np.int64(-1))


def _pad_tail(arr: np.ndarray, n: int, value) -> np.ndarray:
    """Pad the last axis out to ``n`` with ``value``."""
    if arr.shape[-1] == n:
        return arr
    pad = [(0, 0)] * (arr.ndim - 1) + [(0, n - arr.shape[-1])]
    return np.pad(arr, pad, constant_values=value)


_clustered_jit = jax.jit(_clustered_core, static_argnums=(8,))
_lockstep_jit = jax.jit(_lockstep_core)


def clustered_counts(taup0: np.ndarray, off: np.ndarray,
                     levels: np.ndarray, delay: DelayModel,
                     ids: Optional[np.ndarray] = None
                     ) -> Tuple[np.ndarray, np.ndarray]:
    """Jit-compiled Algorithm-1 sweep for one scenario: completed
    counts ``(L, K)`` + makespan ``(L,)`` for every candidate level at
    once.  Inputs/outputs are NumPy; shapes are bucket-padded (extra
    services inactive at tau'=0, extra levels duplicating the last
    real level) and the padding stripped from the result."""
    taup0 = np.asarray(taup0, dtype=np.float64)
    off = np.asarray(off, dtype=np.int64)
    levels = np.asarray(levels, dtype=np.int64)
    K, L = taup0.size, levels.size
    Kp, Lp = _bucket(K), _bucket(L)
    taup_p = _pad_tail(taup0, Kp, 0.0)
    off_p = _pad_tail(off, Kp, 0)
    lv_p = _pad_tail(levels, Lp, int(levels[-1]) if L else 1)
    shift = np.int64(max(Kp, 1).bit_length())
    ids_p = None if ids is None else \
        _pad_tail(np.asarray(ids, dtype=np.int64), Kp,
                  int(np.max(ids, initial=0)) + 1)
    tie = _tie_ranks(taup_p, ids_p)
    f_thr = _f_threshold(taup_p, off_p, lv_p, int(shift), delay.a + delay.b)
    kb = _key_bits(taup_p, off_p, int(shift), delay.a + delay.b)
    with enable_x64():
        Tc, t = _clustered_jit(taup_p, off_p, lv_p, tie, f_thr, shift,
                               delay.a, delay.b, kb)
    return np.asarray(Tc)[:L, :K], np.asarray(t)[:L]


def lockstep_counts(taup0: np.ndarray, targets: np.ndarray,
                    delay: DelayModel) -> Tuple[np.ndarray, np.ndarray]:
    """Jit-compiled lockstep sweep for one scenario: counts + makespan
    for every ``(L, K)`` additional-step target row at once (padded
    services carry target 0, so they never join a batch)."""
    taup0 = np.asarray(taup0, dtype=np.float64)
    targets = np.asarray(targets, dtype=np.int64)
    L, K = targets.shape
    Kp, Lp = _bucket(K), _bucket(L)
    taup_p = _pad_tail(taup0, Kp, 0.0)
    tg_p = _pad_tail(targets, Kp, 0)
    tg_p = np.pad(tg_p, [(0, Lp - L), (0, 0)], constant_values=0)
    with enable_x64():
        Tc, t = _lockstep_jit(taup_p, tg_p, delay.a, delay.b)
    return np.asarray(Tc)[:L, :K], np.asarray(t)[:L]


def powerlaw_scores(Tc: np.ndarray, quality, offsets: Optional[np.ndarray],
                    doomed: Optional[np.ndarray] = None,
                    valid: Optional[np.ndarray] = None) -> np.ndarray:
    """Vectorized row scores for a PowerLawFID-based objective (the
    fast path of the jax engine's outer searches); callers fall back to
    ``arrays.score_rows`` for arbitrary quality models."""
    Tc = np.asarray(Tc)
    K = Tc.shape[-1]
    off = np.zeros(K, np.int64) if offsets is None \
        else np.asarray(offsets, np.int64)
    dm = np.zeros(K, bool) if doomed is None else np.asarray(doomed, bool)
    vd = np.ones(K, bool) if valid is None else np.asarray(valid, bool)
    with enable_x64():
        qs = _powerlaw_jit(Tc, off, vd, dm, quality.alpha, quality.beta,
                           quality.gamma, quality.fid_at_zero)
    return np.asarray(qs)


_powerlaw_jit = jax.jit(_powerlaw_rows)


# One fused T* search over S stacked scenarios: vmapped clustered
# sweep -> masked power-law scoring -> first-best scan, all in a
# single call.  ``_plan_many_block`` is the unjitted body so the
# sharded entry point (repro.core.jaxplan.sharded) can wrap the SAME
# computation in shard_map/pmap per device; ``_plan_many_core`` is the
# single-device jit (the ``plan_many`` core).
def _plan_many_block(taup0, off, valid, tie, f_thr, levels, shift,
                     a, b, alpha, beta, gamma, fid0, key_bits):
    Tc, t = jax.vmap(
        _clustered_core,
        in_axes=(0, 0, None, 0, 0, None, None, None, None))(
            taup0, off, levels, tie, f_thr, shift, a, b, key_bits)
    qs = jax.vmap(_powerlaw_rows,
                  in_axes=(0, 0, 0, None, None, None, None, None))(
        Tc, off, valid, jnp.zeros(taup0.shape[-1], bool),
        alpha, beta, gamma, fid0)
    L = levels.shape[0]
    best_i, best_q = jax.vmap(_first_best, in_axes=(0, None))(
        qs, jnp.ones((L,), bool))
    idx = jnp.maximum(best_i, 0)
    counts = jnp.take_along_axis(Tc, idx[:, None, None], axis=1)[:, 0, :]
    ms = jnp.take_along_axis(t, idx[:, None], axis=1)[:, 0]
    return best_i, counts, best_q, ms


_plan_many_core = jax.jit(_plan_many_block, static_argnums=(13,))


# The REPLAN variant of the fused search — the online event loop's
# shared-horizon semantics, batched over concurrent replans.
# ``_plan_many_block`` folds the offsets into the clustered pass itself
# (the offset-native candidate family of ``stacking_offset``); a
# residual replan in ``repro.core.online`` instead reruns Algorithm 1
# with ZERO offsets over the residual budgets and only *scores*
# candidates progress-aware — ``fid(done + new)`` with the
# ``doomed -> fid(0)`` rule (``online._OffsetQuality``) — and each
# scenario's candidate grid stops at its own t_star_max (``lv_ok``),
# exactly the level set the per-cell ``stacking_vec`` search sweeps.
# The fleet harness (repro.core.fleet) batches every concurrent cell
# replan of a tick through this block in one jitted call.
def _replan_many_block(taup0, score_off, valid, doomed, tie, f_thr,
                       levels, lv_ok, shift, a, b, alpha, beta, gamma,
                       fid0, key_bits):
    pass_off = jnp.zeros(taup0.shape, dtype=score_off.dtype)
    Tc, t = jax.vmap(
        _clustered_core,
        in_axes=(0, 0, None, 0, 0, None, None, None, None))(
            taup0, pass_off, levels, tie, f_thr, shift, a, b, key_bits)
    qs = jax.vmap(_powerlaw_rows,
                  in_axes=(0, 0, 0, 0, None, None, None, None))(
        Tc, score_off, valid, doomed, alpha, beta, gamma, fid0)
    best_i, best_q = jax.vmap(_first_best)(qs, lv_ok)
    idx = jnp.maximum(best_i, 0)
    counts = jnp.take_along_axis(Tc, idx[:, None, None], axis=1)[:, 0, :]
    ms = jnp.take_along_axis(t, idx[:, None], axis=1)[:, 0]
    return best_i, counts, best_q, ms


_replan_many_core = jax.jit(_replan_many_block, static_argnums=(15,))
