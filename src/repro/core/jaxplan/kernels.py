"""jnp ports of the (L, K) planning sweeps: ``lax.while_loop`` over
fixed-shape per-round state, every T*/water-level candidate advancing
together — the jit-compiled counterpart of ``repro.core.arrays``'
``_clustered_rounds`` / ``_lockstep_rounds``.

The kernels mirror the NumPy sweeps operation for operation (same
float64 arithmetic, same ``1e-12`` epsilons, same composite integer
sort keys), but jit/XLA may reassociate reductions and ``pow`` may
differ from libm in the last ulp, so the contract is *tolerance*
equivalence of objectives — never bit identity (that stays the NumPy
vec engine's contract against the scalar reference).  See
docs/PERFORMANCE.md ("jax engine").

Only completed *counts* and makespans come out of the jitted loops:
batch lists are inherently ragged, so the winning candidate is
materialized afterwards by the exact NumPy single-level pass
(``arrays.stacking_pass_vec`` / ``arrays.offset_pass_vec``) — the jax
engine spends its time where the work is, scoring L x K x rounds, and
returns plans constructed by the same code every other engine uses.

All public helpers here take/return NumPy arrays and run the jitted
core under ``jax.experimental.enable_x64`` so the planner's float64
semantics never leak x64 config into the rest of the process (the
Pallas denoiser kernels stay float32).  Shapes are padded to
power-of-two buckets (``_bucket``) so online replans — whose residual
K and level count shrink every event — reuse a handful of compiled
variants instead of recompiling per instant.
"""

from __future__ import annotations

from functools import partial
from typing import Optional, Tuple

import numpy as np

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import enable_x64

from repro.core.delay_model import DelayModel

# same sentinel as repro.core.arrays._TP_INF (not imported: this module
# must stay importable while arrays is mid-initialization during an
# env-var backend probe)
_TP_INF = np.int64(1) << 62


def _bucket(n: int) -> int:
    """Round up to a power of two (min 8): the padded-shape buckets
    that bound jit recompilation across shrinking replan instances."""
    return max(8, 1 << max(0, int(n - 1).bit_length()))


# -------------------------------------------------------------------------
# The clustered (Algorithm-1) sweep
# -------------------------------------------------------------------------

def _clustered_core(taup0, off, levels, tie, f_thr, shift, a, b):
    """One scenario's Algorithm-1 rounds over all L candidate levels:
    ``(taup0 (K,), off (K,), levels (L,), tie (K,), f_thr (L,))`` ->
    ``(Tc (L, K) int64, makespan (L,) float64)``.  Literal port of
    ``arrays._clustered_rounds`` minus history recording."""
    L, K = levels.shape[0], taup0.shape[0]
    g1 = a * 1 + b                       # delay.min_task_delay()
    step_cost = a + b
    M = jnp.left_shift(jnp.int64(1), shift)

    lv_pos = levels > 0
    lv_f = levels.astype(jnp.float64)
    b_lv = b * lv_f
    a_lv = a * jnp.maximum(lv_f, 1.0)

    taup = jnp.tile(taup0, (L, 1))
    Tc = jnp.zeros((L, K), dtype=jnp.int64)
    active = jnp.tile(taup0 >= g1, (L, 1))
    t = jnp.zeros((L,), dtype=jnp.float64)

    def cond(state):
        _, _, active, _ = state
        return active.any()

    def body(state):
        taup, Tc, active, t = state
        # ---- clustering (Eqs. 15-18, offset-shifted) -----------------
        Te = (taup / step_cost).astype(jnp.int64)
        Tp = off[None, :] + Tc + Te
        key = jnp.where(active, Tp * M + tie[None, :], _TP_INF)

        n_active = active.sum(axis=-1, dtype=jnp.int64)
        F = key <= f_thr[:, None]
        n_F = F.sum(axis=-1, dtype=jnp.int64)

        # ---- packing (Eqs. 19-20) ------------------------------------
        te_max = jnp.max(jnp.where(F, Te, -1), axis=-1)
        tau_min = jnp.min(jnp.where(F, taup, jnp.inf), axis=-1)
        cap_f = jnp.floor((tau_min - b * te_max)
                          / (a * jnp.maximum(te_max, 1)))
        tp_min = jnp.right_shift(key.min(axis=-1), shift)
        cap_nf = jnp.floor((step_cost * tp_min - b_lv) / a_lv)
        x_f = jnp.where(te_max > 0,
                        jnp.maximum(n_F, jnp.minimum(n_active, cap_f)),
                        n_F)
        x_nf = jnp.minimum(n_active,
                           jnp.where(lv_pos, jnp.maximum(1, cap_nf),
                                     n_active))
        x_n = jnp.where(n_F > 0, x_f, x_nf)
        x_n = jnp.maximum(1, jnp.minimum(x_n, n_active))
        x_n = jnp.where(n_active > 0, x_n, 0).astype(jnp.int64)

        # ---- batching -------------------------------------------------
        sorted_key = jnp.sort(key, axis=-1)
        thr = jnp.take_along_axis(sorted_key,
                                  jnp.maximum(x_n - 1, 0)[:, None],
                                  axis=-1)[:, 0]
        thr = jnp.where(x_n > 0, thr, jnp.int64(-1))
        packed0 = key <= thr[:, None]

        def drop_cond(s):
            packed, _, n_packed = s
            g = a * n_packed + b
            return (packed & (taup + 1e-12 < g[:, None])).any()

        def drop_body(s):
            packed, act, n_packed = s
            g = a * n_packed + b
            drop = packed & (taup + 1e-12 < g[:, None])
            packed = packed & ~drop         # cannot afford this batch ->
            act = act & ~drop               # service is finished
            n_packed = packed.sum(axis=-1, dtype=jnp.int64)
            return packed, act, n_packed

        packed, active, n_packed = lax.while_loop(
            drop_cond, drop_body, (packed0, active, x_n))

        has_batch = n_packed > 0
        g = a * n_packed + b
        t = t + jnp.where(has_batch, g, 0.0)
        adv = active & has_batch[:, None]   # wall clock advances for all
        taup = taup - jnp.where(adv, g[:, None], 0.0)      # (Eq. 15)
        Tc = Tc + packed.astype(jnp.int64)
        # services that can no longer fit even a dedicated batch are done
        active = active & (taup + 1e-12 >= g1)
        return taup, Tc, active, t

    _, Tc, _, t = lax.while_loop(cond, body, (taup, Tc, active, t))
    return Tc, t


# -------------------------------------------------------------------------
# The lockstep sweep (equal_steps / offset_pass targets)
# -------------------------------------------------------------------------

def _lockstep_core(taup0, targets, a, b):
    """One scenario's lockstep rounds over all L target rows:
    ``(taup0 (K,), targets (L, K) int64)`` -> ``(Tc, makespan)``.
    Literal port of ``arrays._lockstep_rounds``."""
    L, K = targets.shape
    g1 = a * 1 + b

    taup = jnp.tile(taup0, (L, 1))
    Tc = jnp.zeros((L, K), dtype=jnp.int64)
    active = (targets > 0) & (taup0 >= g1)[None, :]
    t = jnp.zeros((L,), dtype=jnp.float64)

    def cond(state):
        _, _, active, _ = state
        return active.any()

    def body(state):
        taup, Tc, active, t = state

        def drop_cond(s):
            act, n = s
            g = a * n + b
            return (act & (taup + 1e-12 < g[:, None])).any()

        def drop_body(s):
            act, n = s
            g = a * n + b
            drop = act & (taup + 1e-12 < g[:, None])
            act = act & ~drop
            return act, act.sum(axis=-1, dtype=jnp.int64)

        n0 = active.sum(axis=-1, dtype=jnp.int64)
        active, n = lax.while_loop(drop_cond, drop_body, (active, n0))

        has_batch = n > 0
        g = a * n + b
        t = t + jnp.where(has_batch, g, 0.0)
        taup = taup - jnp.where(active, g[:, None], 0.0)
        Tc = Tc + active.astype(jnp.int64)
        active = active & (Tc < targets) & (taup + 1e-12 >= g1)
        return taup, Tc, active, t

    _, Tc, _, t = lax.while_loop(cond, body, (taup, Tc, active, t))
    return Tc, t


# -------------------------------------------------------------------------
# Scoring + selection (inside jit, PowerLawFID only)
# -------------------------------------------------------------------------

def _powerlaw_rows(Tc, offsets, valid, doomed, alpha, beta, gamma, fid0):
    """Masked progress-aware mean FID of every row of a ``(L, K)``
    count matrix: ``fid(offset + count)`` with the ``doomed -> fid(0)``
    rule, averaged over ``valid`` services only (pad rows excluded)."""
    tot = Tc + offsets[None, :]
    f = jnp.where(tot > 0,
                  alpha * tot.astype(jnp.float64) ** (-beta) + gamma,
                  fid0)
    f = jnp.where(doomed[None, :], fid0, f)
    f = jnp.where(valid[None, :], f, 0.0)
    return f.sum(axis=-1) / jnp.maximum(valid.sum(axis=-1), 1)


def _first_best(qs, valid_rows):
    """The scalar outer searches' selection rule — the FIRST candidate
    strictly better (by 1e-12) than everything before it — as a scan.
    ``valid_rows`` masks padded/disallowed candidates out entirely."""
    L = qs.shape[0]

    def step(carry, xi):
        best_i, best_q = carry
        i, q, ok = xi
        take = ok & (q < best_q - 1e-12)
        return (jnp.where(take, i, best_i),
                jnp.where(take, q, best_q)), None

    (bi, bq), _ = lax.scan(
        step, (jnp.int64(-1), jnp.float64(jnp.inf)),
        (jnp.arange(L, dtype=jnp.int64), qs, valid_rows))
    return bi, bq


# -------------------------------------------------------------------------
# Host-side preparation + jitted wrappers
# -------------------------------------------------------------------------

def _tie_ranks(taup0: np.ndarray,
               ids: Optional[np.ndarray] = None) -> np.ndarray:
    """The round-invariant (tau', id) tie-break of ``arrays``, as an
    integer rank per service.  ``ids`` breaks tau' ties for the
    single-scenario wrappers; batched callers (row position == id)
    rely on the stable argsort instead."""
    if ids is not None:
        order = np.lexsort((ids, taup0))
        tie = np.empty(taup0.size, dtype=np.int64)
        tie[order] = np.arange(taup0.size, dtype=np.int64)
        return tie
    order = np.argsort(taup0, axis=-1, kind="stable")
    tie = np.empty_like(order, dtype=np.int64)
    np.put_along_axis(tie, order,
                      np.broadcast_to(
                          np.arange(taup0.shape[-1], dtype=np.int64),
                          order.shape).copy(), axis=-1)
    return tie


def _f_threshold(taup0: np.ndarray, off: np.ndarray, levels: np.ndarray,
                 shift: int, step_cost: float) -> np.ndarray:
    """The priority-cluster membership threshold in composite-key
    space (``key <= lv*M + (M-1)  <=>  Tp <= lv``), clamped to the Tp
    bound so the int64 keys stay far from overflow.  Batched over a
    leading scenario axis when present."""
    M = np.int64(1) << shift
    te0_max = np.floor(np.max(np.maximum(taup0, 0.0), axis=-1)
                       / step_cost).astype(np.int64)
    tp_bound = (off.max(axis=-1) if off.size else np.int64(0)) \
        + 2 * te0_max + 4
    assert int(np.max(tp_bound, initial=0) + 2) * int(M) < int(_TP_INF), \
        "key space overflow"
    lv = levels[..., :] if taup0.ndim == 1 else levels[None, :]
    bound = tp_bound if taup0.ndim == 1 else tp_bound[:, None]
    return np.where(lv >= 0, np.minimum(lv, bound) * M + (M - 1),
                    np.int64(-1))


def _pad_tail(arr: np.ndarray, n: int, value) -> np.ndarray:
    """Pad the last axis out to ``n`` with ``value``."""
    if arr.shape[-1] == n:
        return arr
    pad = [(0, 0)] * (arr.ndim - 1) + [(0, n - arr.shape[-1])]
    return np.pad(arr, pad, constant_values=value)


_clustered_jit = jax.jit(_clustered_core)
_lockstep_jit = jax.jit(_lockstep_core)


def clustered_counts(taup0: np.ndarray, off: np.ndarray,
                     levels: np.ndarray, delay: DelayModel,
                     ids: Optional[np.ndarray] = None
                     ) -> Tuple[np.ndarray, np.ndarray]:
    """Jit-compiled Algorithm-1 sweep for one scenario: completed
    counts ``(L, K)`` + makespan ``(L,)`` for every candidate level at
    once.  Inputs/outputs are NumPy; shapes are bucket-padded (extra
    services inactive at tau'=0, extra levels duplicating the last
    real level) and the padding stripped from the result."""
    taup0 = np.asarray(taup0, dtype=np.float64)
    off = np.asarray(off, dtype=np.int64)
    levels = np.asarray(levels, dtype=np.int64)
    K, L = taup0.size, levels.size
    Kp, Lp = _bucket(K), _bucket(L)
    taup_p = _pad_tail(taup0, Kp, 0.0)
    off_p = _pad_tail(off, Kp, 0)
    lv_p = _pad_tail(levels, Lp, int(levels[-1]) if L else 1)
    shift = np.int64(max(Kp, 1).bit_length())
    ids_p = None if ids is None else \
        _pad_tail(np.asarray(ids, dtype=np.int64), Kp,
                  int(np.max(ids, initial=0)) + 1)
    tie = _tie_ranks(taup_p, ids_p)
    f_thr = _f_threshold(taup_p, off_p, lv_p, int(shift), delay.a + delay.b)
    with enable_x64():
        Tc, t = _clustered_jit(taup_p, off_p, lv_p, tie, f_thr, shift,
                               delay.a, delay.b)
    return np.asarray(Tc)[:L, :K], np.asarray(t)[:L]


def lockstep_counts(taup0: np.ndarray, targets: np.ndarray,
                    delay: DelayModel) -> Tuple[np.ndarray, np.ndarray]:
    """Jit-compiled lockstep sweep for one scenario: counts + makespan
    for every ``(L, K)`` additional-step target row at once (padded
    services carry target 0, so they never join a batch)."""
    taup0 = np.asarray(taup0, dtype=np.float64)
    targets = np.asarray(targets, dtype=np.int64)
    L, K = targets.shape
    Kp, Lp = _bucket(K), _bucket(L)
    taup_p = _pad_tail(taup0, Kp, 0.0)
    tg_p = _pad_tail(targets, Kp, 0)
    tg_p = np.pad(tg_p, [(0, Lp - L), (0, 0)], constant_values=0)
    with enable_x64():
        Tc, t = _lockstep_jit(taup_p, tg_p, delay.a, delay.b)
    return np.asarray(Tc)[:L, :K], np.asarray(t)[:L]


def powerlaw_scores(Tc: np.ndarray, quality, offsets: Optional[np.ndarray],
                    doomed: Optional[np.ndarray] = None,
                    valid: Optional[np.ndarray] = None) -> np.ndarray:
    """Vectorized row scores for a PowerLawFID-based objective (the
    fast path of the jax engine's outer searches); callers fall back to
    ``arrays.score_rows`` for arbitrary quality models."""
    Tc = np.asarray(Tc)
    K = Tc.shape[-1]
    off = np.zeros(K, np.int64) if offsets is None \
        else np.asarray(offsets, np.int64)
    dm = np.zeros(K, bool) if doomed is None else np.asarray(doomed, bool)
    vd = np.ones(K, bool) if valid is None else np.asarray(valid, bool)
    with enable_x64():
        qs = _powerlaw_jit(Tc, off, vd, dm, quality.alpha, quality.beta,
                           quality.gamma, quality.fid_at_zero)
    return np.asarray(qs)


_powerlaw_jit = jax.jit(_powerlaw_rows)


# One fused jitted T* search over S stacked scenarios: vmapped
# clustered sweep -> masked power-law scoring -> first-best scan, all
# in a single call (the ``plan_many`` core).
@partial(jax.jit, static_argnums=())
def _plan_many_core(taup0, off, valid, tie, f_thr, levels, shift,
                    a, b, alpha, beta, gamma, fid0):
    Tc, t = jax.vmap(
        _clustered_core,
        in_axes=(0, 0, None, 0, 0, None, None, None))(
            taup0, off, levels, tie, f_thr, shift, a, b)
    qs = jax.vmap(_powerlaw_rows,
                  in_axes=(0, 0, 0, None, None, None, None, None))(
        Tc, off, valid, jnp.zeros(taup0.shape[-1], bool),
        alpha, beta, gamma, fid0)
    L = levels.shape[0]
    best_i, best_q = jax.vmap(_first_best, in_axes=(0, None))(
        qs, jnp.ones((L,), bool))
    idx = jnp.maximum(best_i, 0)
    counts = jnp.take_along_axis(Tc, idx[:, None, None], axis=1)[:, 0, :]
    ms = jnp.take_along_axis(t, idx[:, None], axis=1)[:, 0]
    return best_i, counts, best_q, ms
