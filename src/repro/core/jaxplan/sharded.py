"""``plan_many`` sharded over devices: the scenario axis split across
host/accelerator devices with ``shard_map``, one jitted program per
(device set, key-bit) combination.

``plan_many`` (repro.core.jaxplan.batched) already amortizes Python
dispatch by stacking ~10^3 scenarios into one jitted call, but that
call still runs on a single device.  Fleet-scale replanning — every
cell of an edge deployment replanned each tick — wants the scenario
axis spread over whatever devices the host exposes (real accelerators,
or CPU host devices via
``XLA_FLAGS=--xla_force_host_platform_device_count=8``).  Scenario
rows are fully independent, so the split is embarrassingly parallel:

* the S axis is padded to ``n_devices * bucket(ceil(S / n))`` — the
  padding rows are all-invalid scenarios that plan to nothing and are
  stripped from the result (a device whose shard is entirely padding
  simply converges in zero rounds);
* each device runs the SAME fused search (``kernels._plan_many_block``:
  vmapped clustered sweep -> masked power-law scoring -> first-best
  scan) on its block; no cross-device communication is needed, so the
  per-row arithmetic is identical to the single-device call and the
  equivalence contract stays the documented 1e-9 mean-FID tolerance
  against single-device ``plan_many`` and the vec loop
  (tests/test_jaxplan_sharded.py enforces it at device counts 1/2/8);
* compiled programs are cached per (device tuple, radix key bits), so
  repeated replan ticks at a stable fleet size pay compilation once.

Where ``shard_map`` is unavailable (older jax), the module falls back
to a ``pmap`` of the same block over a leading device axis — same
padding, same results; ``_BACKEND`` records which path is active and
the tests exercise the fallback by pinning it.
"""

from __future__ import annotations

from functools import lru_cache, partial
from typing import Optional, Sequence, Union

import numpy as np

import jax

from repro.core.delay_model import DelayModel
from repro.core.jaxplan import kernels
from repro.core.jaxplan.batched import (PlanManyResult, _check_inputs,
                                        _pad_stack, _replan_prep)
from repro.core.quality_model import PowerLawFID

try:
    from jax.experimental.shard_map import shard_map
    from jax.sharding import Mesh, PartitionSpec as P
    _BACKEND = "shard_map"
except ImportError:                       # pragma: no cover - old jax
    shard_map = Mesh = P = None
    _BACKEND = "pmap"

#: scenarios-per-device type of the ``devices=`` knob
Devices = Union[None, int, Sequence]


def resolve_devices(devices: Devices = None):
    """The device list a sharded plan will run on: ``None``/``0`` =
    every local device, an int n = the first n local devices (failing
    loudly when the host exposes fewer), or an explicit sequence of
    jax devices passed through as-is."""
    if devices is None or devices == 0:
        return list(jax.devices())
    if isinstance(devices, int):
        avail = jax.devices()
        if devices < 0 or devices > len(avail):
            raise ValueError(
                f"devices={devices} requested but only {len(avail)} "
                f"jax device(s) are configured; on CPU, export "
                f"XLA_FLAGS=--xla_force_host_platform_device_count=N "
                f"before jax initializes")
        return avail[:devices]
    devs = list(devices)
    if not devs:
        raise ValueError("devices must name at least one jax device")
    return devs


@lru_cache(maxsize=None)
def _sharded_fn(devs: tuple, key_bits: int, backend: str):
    """The compiled sharded search for one device set: shard_map (or
    the pmap fallback) of ``kernels._plan_many_block`` with the
    scenario axis split across ``devs``.  Cached so replan ticks at a
    stable fleet size reuse one executable."""
    block = partial(kernels._plan_many_block, key_bits=key_bits)
    if backend == "shard_map" and shard_map is not None:
        mesh = Mesh(np.array(devs), ("s",))
        sharded = P("s")
        fn = shard_map(
            block, mesh=mesh,
            in_specs=(sharded, sharded, sharded, sharded, sharded,
                      P(None), P(), P(), P(), P(), P(), P(), P()),
            out_specs=(sharded, sharded, sharded, sharded),
            # the block is replication-free by construction (every
            # output is P("s")-sharded); the checker has no rule for
            # lax.while_loop, so it must be told rather than asked
            check_rep=False)
        return jax.jit(fn), "shard_map"
    # pmap fallback: same block over an explicit leading device axis
    fn = jax.pmap(block, devices=devs,
                  in_axes=(0, 0, 0, 0, 0, None, None, None, None,
                           None, None, None, None))
    return fn, "pmap"


@lru_cache(maxsize=None)
def _sharded_replan_fn(devs: tuple, key_bits: int, backend: str):
    """The compiled sharded REPLAN search (``_replan_many_block``) for
    one device set: same split as ``_sharded_fn`` plus the two extra
    per-row inputs (doomed mask, per-scenario level validity)."""
    block = partial(kernels._replan_many_block, key_bits=key_bits)
    if backend == "shard_map" and shard_map is not None:
        mesh = Mesh(np.array(devs), ("s",))
        sharded = P("s")
        fn = shard_map(
            block, mesh=mesh,
            in_specs=(sharded, sharded, sharded, sharded, sharded,
                      sharded, P(None), sharded, P(), P(), P(), P(),
                      P(), P(), P()),
            out_specs=(sharded, sharded, sharded, sharded),
            check_rep=False)
        return jax.jit(fn), "shard_map"
    fn = jax.pmap(block, devices=devs,
                  in_axes=(0, 0, 0, 0, 0, 0, None, 0, None, None,
                           None, None, None, None, None))
    return fn, "pmap"


def plan_many_sharded(tau_prime: np.ndarray, *, delay: DelayModel,
                      quality: PowerLawFID,
                      offsets: Optional[np.ndarray] = None,
                      valid: Optional[np.ndarray] = None,
                      t_star_max: int = 0,
                      devices: Devices = None) -> PlanManyResult:
    """``plan_many`` with the scenario axis sharded across devices.

    Same inputs and result type as ``plan_many`` plus the ``devices``
    knob (see ``resolve_devices``).  S is padded up to a multiple of
    the device count with all-invalid scenario rows; the padding is
    masked inside the kernel and stripped from the result, so S need
    not be divisible by (or even as large as) the device count.
    """
    devs = resolve_devices(devices)
    D = len(devs)
    taup0, off, vd, S, K = _check_inputs(tau_prime, quality, offsets,
                                         valid)
    # pad S to D equal blocks, each a power-of-two bucket so a growing
    # fleet reuses a handful of compiled variants per device count
    rows = kernels._bucket(max(1, -(-S // D)))
    taup_p, off_p, vd_p, tie, f_thr, lv_p, shift, kb = _pad_stack(
        taup0, off, vd, delay, t_star_max, D * rows)

    fn, backend = _sharded_fn(tuple(devs), kb, _BACKEND)
    args = (taup_p, off_p, vd_p, tie, f_thr)
    if backend == "pmap":                 # explicit leading device axis
        args = tuple(a.reshape((D, rows) + a.shape[1:]) for a in args)
    with kernels.enable_x64():
        best_i, counts, best_q, ms = fn(
            *args, lv_p, shift, delay.a, delay.b, quality.alpha,
            quality.beta, quality.gamma, quality.fid_at_zero)
    best_i, counts = np.asarray(best_i), np.asarray(counts)
    best_q, ms = np.asarray(best_q), np.asarray(ms)
    if backend == "pmap":                 # collapse the device axis
        best_i = best_i.reshape(-1)
        counts = counts.reshape((-1,) + counts.shape[2:])
        best_q, ms = best_q.reshape(-1), ms.reshape(-1)
    best_i = best_i[:S]
    return PlanManyResult(
        best_level=lv_p[np.maximum(best_i, 0)].astype(np.int64),
        steps=counts[:S, :K],
        mean_fid=best_q[:S],
        makespan=ms[:S],
    )


def replan_many_sharded(tau_prime: np.ndarray, *, delay: DelayModel,
                        quality: PowerLawFID,
                        offsets: Optional[np.ndarray] = None,
                        doomed: Optional[np.ndarray] = None,
                        valid: Optional[np.ndarray] = None,
                        t_star_max: int = 0,
                        devices: Devices = None) -> PlanManyResult:
    """``replan_many`` with the scenario axis sharded across devices —
    the shared-horizon residual-replan semantics of ``plan_many_sharded``
    (see ``repro.core.jaxplan.batched.replan_many`` for the contract)."""
    devs = resolve_devices(devices)
    D = len(devs)
    taup0, soff, vd, S, K = _check_inputs(tau_prime, quality, offsets,
                                          valid)
    dm = np.zeros((S, K), dtype=bool) if doomed is None \
        else np.broadcast_to(np.asarray(doomed, dtype=bool),
                             (S, K)).copy()
    rows = kernels._bucket(max(1, -(-S // D)))
    (taup_p, soff_p, vd_p, dm_p, tie, f_thr, lv_p, lv_ok, shift,
     kb) = _replan_prep(taup0, soff, vd, dm, delay, t_star_max,
                        D * rows)

    fn, backend = _sharded_replan_fn(tuple(devs), kb, _BACKEND)
    args = (taup_p, soff_p, vd_p, dm_p, tie, f_thr)
    lv_ok_arg = lv_ok
    if backend == "pmap":                 # explicit leading device axis
        args = tuple(a.reshape((D, rows) + a.shape[1:]) for a in args)
        lv_ok_arg = lv_ok.reshape((D, rows) + lv_ok.shape[1:])
    with kernels.enable_x64():
        best_i, counts, best_q, ms = fn(
            *args, lv_p, lv_ok_arg, shift, delay.a, delay.b,
            quality.alpha, quality.beta, quality.gamma,
            quality.fid_at_zero)
    best_i, counts = np.asarray(best_i), np.asarray(counts)
    best_q, ms = np.asarray(best_q), np.asarray(ms)
    if backend == "pmap":                 # collapse the device axis
        best_i = best_i.reshape(-1)
        counts = counts.reshape((-1,) + counts.shape[2:])
        best_q, ms = best_q.reshape(-1), ms.reshape(-1)
    best_i = best_i[:S]
    return PlanManyResult(
        best_level=lv_p[np.maximum(best_i, 0)].astype(np.int64),
        steps=counts[:S, :K],
        mean_fid=best_q[:S],
        makespan=ms[:S],
    )
