"""Population-scale fleet simulation: thousands of cells, streaming
metrics, batched replans.

``repro.core.multiserver`` holds a ``_ServiceState`` object per service
and one ``_ServerTrack`` per cell — exact, but the object graph tops
out at benchmark scale.  This module runs the same provisioning
pipeline (per-cell P1 allocate -> P2 STACKING plan -> admission) over a
*fleet*: per-cell arrival processes (``repro.core.traffic``) generate
the load, per-cell event state lives in plain arrays/dicts of scalars
with **no per-service object retained after completion** (a completed
service leaves behind one streamed metric sample and, until its
content clears the air, a ``(tx_end, bandwidth)`` reservation), and
metrics aggregate online (running mean FID, outage rate, delay
percentiles from a fixed-size reservoir), so memory is bounded by the
number of *concurrently live* services, not the horizon.

Two execution modes:

``mode="event"``
    The exact online semantics of ``simulate_online_multi`` with
    placement pinned to each arrival's home cell: every arrival
    triggers a residual replan of its cell (shrunken deadlines,
    progress offsets, the ``doomed -> fid(0)`` objective, reserved
    transmission bandwidth).  Cells are independent, so the loop runs
    in lockstep *rounds* — round r replans every cell seeing its r-th
    arrival — and when the planning engine exposes a batched entry
    point (``engine="jax"``: ``jaxplan.replan_many``), all of a
    round's replans compile into ONE jitted call per distinct cell
    speed.  On an overlapping configuration this mode reproduces
    ``simulate_online_multi`` within the repo's 1e-9 mean-FID
    contract (tests/test_fleet.py).

``mode="epoch"``
    Batch-window provisioning for population scale: arrivals queue per
    epoch of width ``epoch`` and each cell plans its queue ONCE at
    ``t_plan = max(cell busy-until, latest queued arrival)``, so plans
    run to completion, no service is ever replanned (offsets never
    arise) and the entire epoch's planning across all cells is one
    batched ``replan_many`` call.  A service's outcome is final the
    moment its cell is planned, which is what makes >= 10^6 services
    tractable (benchmarks/fleet.py).  A configuration whose arrivals
    are spaced so that every plan drains before the next arrival (one
    arrival per epoch per cell) is *exactly* the event-mode run —
    the cross-mode test uses ``TraceArrivals`` (chunk-independent) to
    enforce it.

Only closed-form allocators (``"equal"``, ``"inv_se"``) are supported:
search allocators (pso, coordinate) run the scheduler inside their
fitness loop, which defeats batching; they remain available through
the per-scenario ``repro.core.multiserver`` path.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Callable, Dict, List, Optional, Tuple, Union

import numpy as np

from repro.core import arrays
from repro.core import stacking as stacking_mod
from repro.core.delay_model import DelayModel
from repro.core.online import _OffsetQuality
from repro.core.quality_model import PowerLawFID, QualityModel
from repro.core.service import EdgeServer, Scenario, ServiceRequest
from repro.core.simulator import ServiceOutcome
from repro.core.traffic import ArrivalProcess

_TIE = 1e-6           # deadline slack, matches repro.core.online
_B_FLOOR = 1e-6       # uncommitted-bandwidth floor, matches online

#: fleet admission policy: (cell index, projected ServiceOutcome) -> admit?
FleetAdmissionFn = Callable[[int, ServiceOutcome], bool]


# -------------------------------------------------------------------------
# Streaming metrics
# -------------------------------------------------------------------------

class ReservoirQuantiles:
    """Fixed-size uniform reservoir (Vitter's Algorithm R) over a
    stream of floats; percentiles come from the sample.  O(capacity)
    memory regardless of stream length, deterministic under the seed."""

    def __init__(self, capacity: int = 4096, seed: int = 0):
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        self.capacity = capacity
        self._rng = np.random.default_rng([seed, 0xE5])
        self._buf = np.empty(capacity, dtype=np.float64)
        self.count = 0

    def add(self, x: float) -> None:
        n = self.count
        if n < self.capacity:
            self._buf[n] = x
        else:
            j = int(self._rng.integers(0, n + 1))
            if j < self.capacity:
                self._buf[j] = x
        self.count = n + 1

    def percentile(self, q: float) -> float:
        if self.count == 0:
            return float("nan")
        return float(np.percentile(
            self._buf[:min(self.count, self.capacity)], q))


class FleetMetrics:
    """Online aggregation of per-service outcomes: one ``observe`` per
    completed service, O(1) state plus the delay reservoir."""

    def __init__(self, seed: int = 0, reservoir: int = 4096):
        self.arrivals = 0
        self.admitted = 0
        self.rejected = 0
        self.completed = 0
        self.outages = 0
        self.mean_fid = 0.0          # running mean over completed
        self.delays = ReservoirQuantiles(capacity=reservoir, seed=seed)

    def observe(self, fid: float, met: bool, e2e: float) -> None:
        self.completed += 1
        self.mean_fid += (fid - self.mean_fid) / self.completed
        if not met:
            self.outages += 1
        if e2e > 0.0:
            self.delays.add(e2e)

    @property
    def outage_rate(self) -> float:
        return self.outages / self.completed if self.completed else 0.0

    @property
    def reject_rate(self) -> float:
        return self.rejected / self.arrivals if self.arrivals else 0.0


# -------------------------------------------------------------------------
# Fleet configuration
# -------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class FleetCell:
    """One edge cell of the fleet: an ``EdgeServer`` worth of hardware
    plus the arrival process generating its local load (``None`` when
    the cell is fed only by the fleet's shared stream)."""
    bandwidth_hz: float
    speed: float = 1.0
    capacity: Optional[int] = None
    process: Optional[ArrivalProcess] = None

    def server(self, idx: int) -> EdgeServer:
        return EdgeServer(id=idx, bandwidth_hz=self.bandwidth_hz,
                          speed=self.speed, capacity=self.capacity)


@dataclasses.dataclass(frozen=True)
class FleetScenario:
    """A fleet: cells + load + the per-service attribute distributions.

    Service attributes are drawn from each cell's own substream
    (``np.random.default_rng([seed, cell])`` — arrivals first, then
    one uniform deadline and one uniform spectral efficiency per
    arrival), so a fleet run is reproducible from ``seed`` alone and
    cells are statistically independent.  ``shared_process`` adds a
    fleet-wide stream routed to cells by a placement policy
    (``simulate_fleet(placement=...)``); it draws from the substream
    ``[seed, n_cells]``.
    """
    cells: Tuple[FleetCell, ...]
    horizon: float
    seed: int = 0
    deadline_range: Tuple[float, float] = (1.0, 3.0)
    spectral_eff_range: Tuple[float, float] = (1.0, 4.0)
    content_bits: float = 2.0e6
    shared_process: Optional[ArrivalProcess] = None

    def __post_init__(self):
        object.__setattr__(self, "cells", tuple(self.cells))
        if not self.cells:
            raise ValueError("a fleet needs at least one cell")
        if not (self.horizon > 0 and math.isfinite(self.horizon)):
            raise ValueError(f"horizon must be finite and > 0, got "
                             f"{self.horizon}")
        for name in ("deadline_range", "spectral_eff_range"):
            lo, hi = getattr(self, name)
            if not (0 < lo <= hi):
                raise ValueError(f"{name} must satisfy 0 < lo <= hi, "
                                 f"got ({lo}, {hi})")

    @property
    def n_cells(self) -> int:
        return len(self.cells)


@dataclasses.dataclass(frozen=True)
class FleetResult:
    """Aggregate outcome of one fleet run — streaming statistics only,
    never per-service records."""
    mode: str
    engine: str
    arrivals: int
    admitted: int
    rejected: int
    completed: int
    mean_fid: float
    outage_rate: float
    reject_rate: float
    delay_p50: float
    delay_p95: float
    delay_p99: float
    peak_live_rows: int        # max concurrently-held service rows
    replans: int               # planner invocations (rows, not calls)
    planner_calls: int         # batched planner calls actually issued


# -------------------------------------------------------------------------
# Arrival sampling
# -------------------------------------------------------------------------

def _cell_rngs(fleet: FleetScenario, cell: int):
    """One cell's two substreams: arrival times and per-service
    attributes.  Attributes live on their own stream, drawn as one
    ``(n, 2)`` uniform block per window — ``Generator.random``
    consumes the stream sequentially, so any chunking of the horizon
    yields the same attribute sequence for the same arrival sequence
    (exact for ``TraceArrivals``, which the cross-mode equivalence
    test relies on)."""
    return (np.random.default_rng([fleet.seed, cell]),
            np.random.default_rng([fleet.seed, cell, 1]))


def _sample_cell(fleet: FleetScenario, proc: Optional[ArrivalProcess],
                 arr_rng: np.random.Generator,
                 attr_rng: np.random.Generator, t0: float, t1: float):
    """Arrivals + per-service attributes on ``[t0, t1)``:
    ``(times, deadlines, spectral_effs)``."""
    if proc is None:
        z = np.empty(0)
        return z, z.copy(), z.copy()
    times = proc.sample(arr_rng, t0, t1)
    u = attr_rng.random((times.size, 2))
    dlo, dhi = fleet.deadline_range
    elo, ehi = fleet.spectral_eff_range
    deadlines = dlo + (dhi - dlo) * u[:, 0]
    se = elo + (ehi - elo) * u[:, 1]
    return times, deadlines, se


# -------------------------------------------------------------------------
# Allocators (closed-form only — must match repro.core.bandwidth op
# for op so the event mode stays inside the equivalence contract)
# -------------------------------------------------------------------------

def _alloc_equal(B: float, se: np.ndarray) -> np.ndarray:
    return np.full(se.size, B / se.size)


def _alloc_inv_se(B: float, se: np.ndarray) -> np.ndarray:
    inv = 1.0 / se
    return B * inv / inv.sum()


_ALLOCATORS = {"equal": _alloc_equal, "inv_se": _alloc_inv_se}


def _resolve_allocator(allocator) -> Callable:
    if callable(allocator):
        return allocator
    try:
        return _ALLOCATORS[allocator]
    except KeyError:
        raise ValueError(
            f"fleet allocator {allocator!r} unknown; closed-form "
            f"choices are {sorted(_ALLOCATORS)} (search allocators "
            f"like pso/coordinate re-run the scheduler per fitness "
            f"evaluation and cannot be batched — use "
            f"repro.core.multiserver for those)") from None


# -------------------------------------------------------------------------
# Per-cell event state (mode="event")
# -------------------------------------------------------------------------

class _Svc:
    """The minimal service view the planning stack needs (an ``.id``);
    built transiently per replan, never retained."""
    __slots__ = ("id",)

    def __init__(self, sid: int):
        self.id = sid


class _CellState:
    """One cell's half of the event loop — the ``_ServerTrack``
    semantics over scalars and short parallel lists instead of
    ``_ServiceState`` objects.  ``live`` maps id -> [arrival,
    abs_deadline, spectral_eff, steps_done] for admitted services whose
    generation is incomplete (insertion order == admission order ==
    ascending id, the scenario-order invariant every tie-break relies
    on); ``reserved`` holds (id, tx_end, bandwidth) for content still
    in the air."""

    __slots__ = ("idx", "cfg", "delay", "live", "reserved", "t_free",
                 "plan", "admitted_total", "replans")

    def __init__(self, idx: int, cfg: FleetCell, base_delay: DelayModel):
        self.idx = idx
        self.cfg = cfg
        self.delay = cfg.server(idx).delay_model(base_delay)
        self.live: Dict[int, list] = {}
        self.reserved: List[tuple] = []       # (id, tx_end, bandwidth)
        self.t_free = 0.0
        self.plan = None    # (t0, starts, batches, last_batch_of, alloc, next)
        self.admitted_total = 0
        self.replans = 0

    @property
    def rows(self) -> int:
        return len(self.live) + len(self.reserved)

    # -- execution --------------------------------------------------------

    def _complete(self, sid: int, t: float, bandwidth: float,
                  bits: float, quality: QualityModel,
                  metrics: FleetMetrics) -> None:
        arrival, absdl, se, steps = self.live.pop(sid)
        tx_dur = bits / max(bandwidth * se, 1e-12)
        tx_end = t + tx_dur
        self.reserved.append((sid, tx_end, bandwidth))
        gen = t - arrival
        e2e = gen + tx_dur
        deadline = absdl - arrival
        metrics.observe(quality.fid(steps),
                        steps > 0 and e2e <= deadline + _TIE, e2e)

    def execute_until(self, t_limit: float, bits: float,
                      quality: QualityModel,
                      metrics: FleetMetrics) -> None:
        """Run every batch starting strictly before ``t_limit``
        (committed batches always finish; one starting exactly at an
        arrival instant stays replannable — the online rule)."""
        if self.plan is None:
            return
        t0, starts, batches, last_of, alloc, nxt = self.plan
        while nxt < len(batches) and t0 + starts[nxt] < t_limit:
            batch = batches[nxt]
            end = t0 + starts[nxt] + self.delay.g(len(batch))
            for sid, _ in batch:
                st = self.live.get(sid)
                if st is None:
                    continue
                st[3] += 1
                if nxt == last_of[sid]:
                    self._complete(sid, end, alloc[sid], bits,
                                   quality, metrics)
            self.t_free = max(self.t_free, end)
            nxt += 1
        self.plan = (t0, starts, batches, last_of, alloc, nxt)

    # -- replanning -------------------------------------------------------

    def residual(self, t_free: float, new: Optional[tuple],
                 allocator: Callable, bits: float):
        """The residual planning inputs at ``t_free``: ids (pending +
        the candidate arrival last), residual budgets tau', offsets,
        doomed mask and the per-service allocation — the array form of
        ``_ServerTrack.residual_scenario`` + ``tau_prime_of``."""
        self.reserved = [r for r in self.reserved if r[1] > t_free]
        ids = list(self.live.keys())
        rows = [self.live[k] for k in ids]
        if new is not None:
            sid, arrival, deadline, se_new = new
            ids.append(sid)
            rows.append([arrival, arrival + deadline, se_new, 0])
        K = len(ids)
        rd = np.array([r[1] - t_free for r in rows], dtype=np.float64)
        se = np.array([r[2] for r in rows], dtype=np.float64)
        off = np.array([r[3] for r in rows], dtype=np.int64)
        B = self.cfg.bandwidth_hz
        reserved = sum(bw for _, _, bw in
                       sorted(self.reserved))      # id order, like states
        alloc = np.asarray(allocator(max(B - reserved, _B_FLOOR * B), se),
                           dtype=np.float64)
        taup = rd - bits / np.maximum(alloc * se, 1e-12)
        doomed = (off > 0) & (taup < 0)
        assert alloc.shape == (K,)
        return ids, rows, taup, off, doomed, alloc

    def plan_cell(self, ids, taup, off, doomed, quality, engine: str,
                  t_star_max: int = 0):
        """One residual plan through the per-scenario engine dispatch
        (the vec/scalar path; the jax path batches across cells and
        materializes with ``best_level`` via the same call)."""
        tp = {k: float(t) for k, t in zip(ids, taup)}
        svcs = [_Svc(k) for k in ids]
        q = quality
        if off.any():
            q = _OffsetQuality(quality, [int(o) for o in off])
            q.doomed = {i for i in range(len(ids)) if doomed[i]}
        self.replans += 1
        if t_star_max > 0:   # winner level already known (batched search)
            return arrays.stacking_pass_vec(ids, tp, self.delay,
                                            t_star_max)
        return stacking_mod.stacking(svcs, tp, self.delay, q,
                                     engine=engine)

    def adopt(self, t0: float, plan, ids, rows, alloc, new_id: int,
              bits: float, quality, metrics) -> None:
        """Accept the arrival: install the plan, then settle
        partially-generated services it gives no further steps
        (transmit what they have, now)."""
        sid_new = new_id
        if sid_new not in self.live:
            i = ids.index(sid_new)
            self.live[sid_new] = rows[i]
            self.admitted_total += 1
        last_of: Dict[int, int] = {}
        for n, batch in enumerate(plan.batches):
            for k, _ in batch:
                last_of[k] = n
        alloc_by_id = {k: float(a) for k, a in zip(ids, alloc)}
        self.plan = (t0, plan.start_times, plan.batches, last_of,
                     alloc_by_id, 0)
        for k in sorted(self.live.keys()):
            st = self.live[k]
            if st[3] > 0 and plan.steps_completed.get(k, 0) == 0:
                self._complete(k, t0, alloc_by_id[k], bits, quality,
                               metrics)

    def flush(self, bits: float, quality: QualityModel,
              metrics: FleetMetrics) -> None:
        """End of horizon: run the remaining batches, then emit outage
        rows for services that never completed generation (the
        ``_collect_result`` T==0 rule)."""
        self.execute_until(math.inf, bits, quality, metrics)
        for sid in list(self.live.keys()):
            arrival, absdl, se, steps = self.live.pop(sid)
            metrics.observe(quality.fid(steps), False, 0.0)


def _project_new(sid: int, plan, t0: float, arrival: float,
                 deadline: float, se: float, alloc_of: float,
                 bits: float, quality: QualityModel) -> ServiceOutcome:
    """``online._project`` for the arriving service: the outcome it
    gets if the trial plan runs uninterrupted."""
    T = plan.steps_completed.get(sid, 0)
    if T > 0:
        t_done = 0.0
        for t_n, batch in zip(plan.start_times, plan.batches):
            if any(kk == sid for kk, _ in batch):
                t_done = t_n + plan.delay.g(len(batch))
        gen = (t0 + t_done) - arrival
        tx = bits / max(alloc_of * se, 1e-12)
    else:
        gen = tx = 0.0
    e2e = gen + tx
    return ServiceOutcome(
        id=sid, deadline=deadline, steps=T, gen_delay=gen, tx_delay=tx,
        e2e_delay=e2e, fid=quality.fid(T),
        met_deadline=(T > 0 and e2e <= deadline + _TIE))


# -------------------------------------------------------------------------
# The fleet driver
# -------------------------------------------------------------------------

def _batched_replans(requests: List[dict], cells: List[_CellState],
                     quality, devices) -> int:
    """Run every gathered replan request through ONE ``replan_many``
    call per distinct cell speed (rows of one call must share a delay
    model), writing ``best_level`` back into each request.  Returns the
    number of planner calls issued."""
    from repro.core import jaxplan
    by_delay: Dict[tuple, List[dict]] = {}
    for req in requests:
        d = cells[req["cell"]].delay
        by_delay.setdefault((d.a, d.b), []).append(req)
    calls = 0
    for (a, b), group in by_delay.items():
        Kmax = max(len(r["ids"]) for r in group)
        S = len(group)
        taup = np.zeros((S, Kmax), dtype=np.float64)
        off = np.zeros((S, Kmax), dtype=np.int64)
        dm = np.zeros((S, Kmax), dtype=bool)
        vd = np.zeros((S, Kmax), dtype=bool)
        for i, r in enumerate(group):
            k = len(r["ids"])
            taup[i, :k] = r["taup"]
            off[i, :k] = r["off"]
            dm[i, :k] = r["doomed"]
            vd[i, :k] = True
        res = jaxplan.replan_many(
            taup, delay=DelayModel(a=a, b=b), quality=quality,
            offsets=off, doomed=dm, valid=vd, devices=devices)
        calls += 1
        for i, r in enumerate(group):
            r["best_level"] = int(res.best_level[i])
    return calls


def _run_event(fleet: FleetScenario, cells: List[_CellState],
               allocator: Callable, admission: Optional[FleetAdmissionFn],
               delay: DelayModel, quality: QualityModel,
               metrics: FleetMetrics, engine: str, batched: bool,
               devices) -> Tuple[int, int]:
    """Lockstep event rounds: round r handles each cell's r-th arrival
    (cells are independent, so per-cell order is the only order that
    matters).  Returns (peak_live_rows, planner_calls)."""
    bits = fleet.content_bits
    streams = []
    next_id = 0
    order = []   # (arrival, cell) sorted -> global ids in arrival order
    for c in range(fleet.n_cells):
        t, dl, se = _sample_cell(fleet, fleet.cells[c].process,
                                 *_cell_rngs(fleet, c),
                                 0.0, fleet.horizon)
        streams.append((t, dl, se))
        order.extend((float(t[i]), c, i) for i in range(t.size))
    order.sort()
    ids_of = {}
    for arrival, c, i in order:           # global ids in (arrival, cell)
        ids_of[(c, i)] = next_id          # order -> per-cell ascending
        next_id += 1
    cursors = [0] * fleet.n_cells
    peak = 0
    planner_calls = 0
    while True:
        requests = []
        for c, cell in enumerate(cells):
            t, dl, se = streams[c]
            i = cursors[c]
            if i >= t.size:
                continue
            t_arr = float(t[i])
            sid = ids_of[(c, i)]
            cell.execute_until(t_arr, bits, quality, metrics)
            metrics.arrivals += 1
            cfg = fleet.cells[c]
            if cfg.capacity is not None and \
                    cell.admitted_total >= cfg.capacity:
                metrics.rejected += 1     # force-reject, no trial replan
                cursors[c] += 1
                continue
            t_free = max(t_arr, cell.t_free)
            new = (sid, t_arr, float(dl[i]), float(se[i]))
            ids, rows, taup, off, doomed, alloc = cell.residual(
                t_free, new, allocator, bits)
            requests.append(dict(
                cell=c, ids=ids, rows=rows, taup=taup, off=off,
                doomed=doomed, alloc=alloc, t_free=t_free, new=new,
                best_level=0))
            cursors[c] += 1
        if not requests:
            break
        if batched and requests:
            planner_calls += _batched_replans(requests, cells, quality,
                                              devices)
        for req in requests:
            cell = cells[req["cell"]]
            if not batched:
                planner_calls += 1
            plan = cell.plan_cell(req["ids"], req["taup"], req["off"],
                                  req["doomed"], quality, engine,
                                  t_star_max=req["best_level"])
            sid, t_arr, deadline, se_new = req["new"]
            alloc_of = float(req["alloc"][req["ids"].index(sid)])
            admit = True
            if admission is not None:
                projected = _project_new(
                    sid, plan, req["t_free"], t_arr, deadline, se_new,
                    alloc_of, bits, quality)
                admit = bool(admission(req["cell"], projected))
            if admit:
                metrics.admitted += 1
                cell.adopt(req["t_free"], plan, req["ids"], req["rows"],
                           req["alloc"], sid, bits, quality, metrics)
            else:
                metrics.rejected += 1
        peak = max(peak, sum(cell.rows for cell in cells))
    for cell in cells:
        cell.flush(bits, quality, metrics)
    return peak, planner_calls


def _place_shared(fleet: FleetScenario, placement: str, times, busy,
                  queued, t0: float, t1: float) -> np.ndarray:
    """Route a shared-stream chunk to cells.  ``round_robin`` cycles;
    ``least_busy`` greedily picks the earliest-free least-loaded cell;
    ``rate_aware`` additionally weighs each cell's OWN arrival
    process's forecast load for the window (``mean_rate``), steering
    shared traffic away from cells about to be busy with local
    arrivals — the arrival-process-aware policy."""
    n = fleet.n_cells
    if placement == "round_robin":
        start = queued.sum()
        return (start + np.arange(times.size)) % n
    forecast = np.zeros(n)
    if placement == "rate_aware":
        span = max(t1 - t0, 1e-12)
        for c, cfg in enumerate(fleet.cells):
            if cfg.process is not None:
                forecast[c] = cfg.process.mean_rate(t0, t1) * span
    elif placement != "least_busy":
        raise ValueError(f"placement {placement!r} unknown; choose "
                         f"round_robin, least_busy or rate_aware")
    load = queued.astype(np.float64) + forecast
    out = np.empty(times.size, dtype=np.int64)
    for i, t in enumerate(times):
        c = int(np.lexsort((np.arange(n), load,
                            np.maximum(busy, t)))[0])
        out[i] = c
        load[c] += 1.0
    return out


def _run_epoch(fleet: FleetScenario, cells: List[_CellState],
               allocator: Callable, admission: Optional[FleetAdmissionFn],
               delay: DelayModel, quality: QualityModel,
               metrics: FleetMetrics, engine: str, batched: bool,
               devices, epoch: float,
               placement: str) -> Tuple[int, int]:
    """Batch-window provisioning (module docstring): one plan per cell
    per epoch, all epochs' planning batched when the engine allows."""
    bits = fleet.content_bits
    rngs = [_cell_rngs(fleet, c) for c in range(fleet.n_cells)]
    shared_arr = np.random.default_rng([fleet.seed, fleet.n_cells])
    shared_attr = np.random.default_rng([fleet.seed, fleet.n_cells, 1])
    n_epochs = max(1, int(math.ceil(fleet.horizon / epoch)))
    busy = np.zeros(fleet.n_cells)
    next_id = 0
    peak = 0
    planner_calls = 0
    for e in range(n_epochs):
        t0, t1 = e * epoch, min((e + 1) * epoch, fleet.horizon)
        queues: List[list] = [[] for _ in range(fleet.n_cells)]
        for c in range(fleet.n_cells):
            t, dl, se = _sample_cell(fleet, fleet.cells[c].process,
                                     *rngs[c], t0, t1)
            for i in range(t.size):
                queues[c].append((float(t[i]), float(dl[i]),
                                  float(se[i])))
        if fleet.shared_process is not None:
            t, dl, se = _sample_cell(fleet, fleet.shared_process,
                                     shared_arr, shared_attr, t0, t1)
            homes = _place_shared(
                fleet, placement, t, busy,
                np.array([len(q) for q in queues]), t0, t1)
            for i in range(t.size):
                queues[int(homes[i])].append(
                    (float(t[i]), float(dl[i]), float(se[i])))
        peak = max(peak, sum(len(q) for q in queues)
                   + sum(len(cl.reserved) for cl in cells))
        requests = []
        for c, queue in enumerate(queues):
            if not queue:
                continue
            queue.sort()
            metrics.arrivals += len(queue)
            cfg = fleet.cells[c]
            if cfg.capacity is not None:
                room = cfg.capacity - cells[c].admitted_total
                if len(queue) > max(room, 0):
                    metrics.rejected += len(queue) - max(room, 0)
                    queue = queue[:max(room, 0)]
                    if not queue:
                        continue
            cell = cells[c]
            cell.admitted_total += len(queue)
            t_plan = max(float(busy[c]), queue[-1][0])
            cell.reserved = [r for r in cell.reserved if r[1] > t_plan]
            rd = np.array([arr + dl - t_plan for arr, dl, _ in queue])
            se = np.array([s for _, _, s in queue])
            B = cfg.bandwidth_hz
            reserved = sum(bw for _, _, bw in sorted(cell.reserved))
            alloc = np.asarray(allocator(
                max(B - reserved, _B_FLOOR * B), se), dtype=np.float64)
            taup = rd - bits / np.maximum(alloc * se, 1e-12)
            ids = list(range(next_id, next_id + len(queue)))
            next_id += len(queue)
            requests.append(dict(
                cell=c, ids=ids, queue=queue, taup=taup,
                off=np.zeros(len(queue), dtype=np.int64),
                doomed=np.zeros(len(queue), dtype=bool),
                alloc=alloc, t_plan=t_plan, best_level=0))
        if batched and requests:
            planner_calls += _batched_replans(requests, cells, quality,
                                              devices)
        for req in requests:
            c = req["cell"]
            cell = cells[c]
            if not batched:
                planner_calls += 1
            plan = cell.plan_cell(req["ids"], req["taup"], req["off"],
                                  req["doomed"], quality, engine,
                                  t_star_max=req["best_level"])
            t_plan = req["t_plan"]
            ids, queue, alloc = req["ids"], req["queue"], req["alloc"]
            if admission is not None:
                keep = []
                for i, sid in enumerate(ids):
                    arr, dl, se_i = queue[i]
                    p = _project_new(sid, plan, t_plan, arr, dl, se_i,
                                     float(alloc[i]), bits, quality)
                    if admission(c, p):
                        keep.append(i)
                    else:
                        metrics.rejected += 1
                        cell.admitted_total -= 1
                if len(keep) != len(ids):
                    if not keep:
                        continue
                    ids = [ids[i] for i in keep]
                    queue = [queue[i] for i in keep]
                    se = np.array([q[2] for q in queue])
                    rd = np.array([arr + dl - t_plan
                                   for arr, dl, _ in queue])
                    B = fleet.cells[c].bandwidth_hz
                    reserved = sum(bw for _, _, bw in
                                   sorted(cell.reserved))
                    alloc = np.asarray(allocator(
                        max(B - reserved, _B_FLOOR * B), se),
                        dtype=np.float64)
                    taup = rd - bits / np.maximum(alloc * se, 1e-12)
                    plan = cell.plan_cell(
                        ids, taup,
                        np.zeros(len(ids), dtype=np.int64),
                        np.zeros(len(ids), dtype=bool),
                        quality, engine, t_star_max=0)
                    planner_calls += 1
            metrics.admitted += len(ids)
            # plans run to completion: finalize every outcome now
            ends: Dict[int, float] = {}
            t_last = 0.0
            for t_n, batch in zip(plan.start_times, plan.batches):
                end = t_n + plan.delay.g(len(batch))
                t_last = max(t_last, end)
                for k, _ in batch:
                    ends[k] = end
            for i, sid in enumerate(ids):
                arr, dl, se_i = queue[i]
                T = plan.steps_completed.get(sid, 0)
                if T > 0:
                    gen_end = t_plan + ends[sid]
                    tx = bits / max(float(alloc[i]) * se_i, 1e-12)
                    e2e = (gen_end - arr) + tx
                    cell.reserved.append((sid, gen_end + tx,
                                          float(alloc[i])))
                    metrics.observe(quality.fid(T),
                                    e2e <= dl + _TIE, e2e)
                else:
                    metrics.observe(quality.fid(0), False, 0.0)
            busy[c] = max(t_plan + t_last, float(busy[c]))
    return peak, planner_calls


def simulate_fleet(fleet: FleetScenario, *,
                   allocator: Union[str, Callable] = "equal",
                   admission: Optional[FleetAdmissionFn] = None,
                   delay: Optional[DelayModel] = None,
                   quality: Optional[QualityModel] = None,
                   mode: str = "epoch",
                   epoch: Optional[float] = None,
                   placement: str = "least_busy",
                   engine: Optional[str] = None,
                   devices=None,
                   reservoir: int = 4096) -> FleetResult:
    """Simulate the fleet end-to-end with streaming metrics (module
    docstring for the two modes).

    ``allocator`` is a closed-form P1 split (``"equal"``/``"inv_se"``
    or a callable ``(available_hz, spectral_effs) -> alloc``);
    ``admission`` an optional per-cell policy ``(cell, projected
    ServiceOutcome) -> bool`` (None = admit all); ``engine`` the
    planner engine (``repro.core.arrays`` registry; an engine exposing
    ``replan_many`` — ``"jax"`` — gets every concurrent replan batched
    into one jitted call, optionally sharded via ``devices``);
    ``placement`` routes the fleet's shared stream, if any.  ``epoch``
    defaults to ``horizon / 64``.
    """
    delay = delay if delay is not None else DelayModel()
    quality = quality if quality is not None else PowerLawFID()
    alloc_fn = _resolve_allocator(allocator)
    eng = arrays.resolve_engine(engine)
    impl = arrays.engine_impl(eng)
    batched = impl is not None and hasattr(impl, "replan_many")
    if mode not in ("event", "epoch"):
        raise ValueError(f"mode must be 'event' or 'epoch', got {mode!r}")
    if not isinstance(quality, PowerLawFID) and batched:
        batched = False      # batched scoring is PowerLawFID-only
    metrics = FleetMetrics(seed=fleet.seed, reservoir=reservoir)
    cells = [_CellState(c, cfg, delay)
             for c, cfg in enumerate(fleet.cells)]
    if mode == "event":
        if fleet.shared_process is not None:
            raise ValueError("mode='event' runs per-cell arrival "
                             "processes only; shared streams need "
                             "mode='epoch' (where placement applies)")
        peak, calls = _run_event(fleet, cells, alloc_fn, admission,
                                 delay, quality, metrics, eng, batched,
                                 devices)
    else:
        width = epoch if epoch is not None else fleet.horizon / 64.0
        if not width > 0:
            raise ValueError(f"epoch width must be > 0, got {width}")
        peak, calls = _run_epoch(fleet, cells, alloc_fn, admission,
                                 delay, quality, metrics, eng, batched,
                                 devices, width, placement)
    return FleetResult(
        mode=mode, engine=eng,
        arrivals=metrics.arrivals, admitted=metrics.admitted,
        rejected=metrics.rejected, completed=metrics.completed,
        mean_fid=metrics.mean_fid, outage_rate=metrics.outage_rate,
        reject_rate=metrics.reject_rate,
        delay_p50=metrics.delays.percentile(50),
        delay_p95=metrics.delays.percentile(95),
        delay_p99=metrics.delays.percentile(99),
        peak_live_rows=peak,
        replans=sum(c.replans for c in cells),
        planner_calls=calls)


# -------------------------------------------------------------------------
# Cross-validation against the object-graph simulator
# -------------------------------------------------------------------------

def fleet_to_scenario(fleet: FleetScenario
                      ) -> Tuple[Scenario, List[int]]:
    """Materialize a (small) fleet into a multi-server ``Scenario`` +
    per-service cell assignment, for cross-checking ``simulate_fleet``
    against ``simulate_online_multi``: same single-window arrival
    sampling as ``mode="event"``, global service ids in (arrival,
    cell) order — per-cell ids ascend with arrival time, the invariant
    both simulators' tie-breaks share.  Pin the returned assignment
    through a placement function and the two simulators must agree on
    mean FID within 1e-9 (tests/test_fleet.py; the `fleet` benchmark
    suite gates it)."""
    if fleet.shared_process is not None:
        raise ValueError("fleet_to_scenario covers per-cell processes "
                         "only (shared streams are epoch-mode)")
    pool = []
    for c in range(fleet.n_cells):
        t, dl, se = _sample_cell(fleet, fleet.cells[c].process,
                                 *_cell_rngs(fleet, c),
                                 0.0, fleet.horizon)
        pool.extend((float(t[i]), c, float(dl[i]), float(se[i]))
                    for i in range(t.size))
    pool.sort(key=lambda r: (r[0], r[1]))
    services, assignment = [], []
    for sid, (arrival, c, deadline, se) in enumerate(pool):
        services.append(ServiceRequest(
            id=sid, deadline=deadline, spectral_eff=se,
            arrival=arrival))
        assignment.append(c)
    servers = [cfg.server(c) for c, cfg in enumerate(fleet.cells)]
    scn = Scenario(services=services, content_bits=fleet.content_bits,
                   total_bandwidth_hz=sum(s.bandwidth_hz
                                          for s in servers),
                   servers=servers)
    return scn, assignment
