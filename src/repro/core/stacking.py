"""STACKING (Algorithm 1): clustering -> packing -> batching, with an outer
linear search over the auxiliary target T*.

The two empirical insights it encodes (Sec. III-B):
  (i)  b >> a in g(X) = aX + b  =>  batches should be as large as possible;
  (ii) early denoising steps improve quality far more than later ones
       =>  step counts should be *balanced* across services.

T* is the expected per-service step count; services whose best-case final
step count T'_k falls at or below T* form the priority cluster F.

Quality-function-agnostic: the inner pass never evaluates FID; only the
outer search does, through whatever QualityModel is supplied.
"""

from __future__ import annotations

import math
from typing import Dict, List, Optional, Sequence

from repro.core import arrays
from repro.core.delay_model import DelayModel
from repro.core.plan import BatchPlan
from repro.core.quality_model import QualityModel
from repro.core.service import ServiceRequest


def stacking_pass(service_ids: Sequence[int], tau_prime: Dict[int, float],
                  delay: DelayModel, t_star: int,
                  offsets: Optional[Dict[int, int]] = None) -> BatchPlan:
    """One clustering-packing-batching sweep for a fixed T* (Alg. 1 l.3-7).

    ``offsets`` (steps a service already executed before this plan,
    default zero) shift the projected counts ``Tp`` the priority
    cluster is formed on, turning T* into a *total*-step water level —
    the offset-native sweep of ``repro.core.offset``.  With no offsets
    this is the paper's Algorithm 1 inner pass exactly.
    """
    a, b = delay.a, delay.b
    off = offsets or {}
    taup = {k: float(tau_prime[k]) for k in service_ids}
    Tc = {k: 0 for k in service_ids}
    active = [k for k in service_ids if taup[k] >= delay.min_task_delay()]

    batches: List[List] = []
    start_times: List[float] = []
    t = 0.0

    while active:
        # ---- clustering (Eqs. 15-18, offset-shifted) ---------------------
        Te = {k: delay.max_steps(taup[k]) for k in active}
        Tp = {k: off.get(k, 0) + Tc[k] + Te[k] for k in active}
        order = sorted(active, key=lambda k: (Tp[k], taup[k], k))
        F = [k for k in order if Tp[k] <= t_star]

        # ---- packing (Eqs. 19-20) ----------------------------------------
        if F:
            te_max = max(Te[k] for k in F)
            tau_min = min(taup[k] for k in F)
            if te_max > 0:
                cap = math.floor((tau_min - b * te_max) / (a * te_max))
                x_n = max(len(F), min(len(active), cap))
            else:
                x_n = len(F)
        else:
            tp_min = min(Tp[k] for k in active)
            cap = math.floor(((a + b) * tp_min - b * t_star) / (a * t_star)) \
                if t_star > 0 else len(active)
            # an empty priority cluster forces tp_min > t_star, so cap
            # >= 1 whenever t_star >= 1 (the only levels the outer
            # searches sweep).  The explicit clamp states that
            # invariant here rather than leaving a degenerate negative
            # cap to be absorbed — identically — by the generic
            # max(1, ...) below, where the branch's reasoning is lost
            x_n = min(len(active), max(1, cap))
        x_n = max(1, min(x_n, len(active)))

        # ---- batching -----------------------------------------------------
        packed = order[:x_n]
        while packed:
            g = delay.g(len(packed))
            drop = [k for k in packed if taup[k] + 1e-12 < g]
            if not drop:
                break
            for k in drop:                      # cannot afford this batch ->
                packed.remove(k)                # service is finished
                active.remove(k)
        if not packed:
            continue

        g = delay.g(len(packed))
        batches.append([(k, Tc[k]) for k in packed])
        start_times.append(t)
        t += g
        for k in active:                         # wall clock advances for all
            taup[k] -= g                         # (Eq. 15)
        for k in packed:
            Tc[k] += 1
        # services that can no longer fit even a dedicated batch are done
        active = [k for k in active
                  if taup[k] + 1e-12 >= delay.min_task_delay()]

    return BatchPlan(batches=batches, start_times=start_times,
                     steps_completed=Tc, delay=delay)


def stacking(services: Sequence[ServiceRequest],
             tau_prime: Dict[int, float], delay: DelayModel,
             quality: QualityModel, t_star_max: int = 0,
             engine: Optional[str] = None) -> BatchPlan:
    """Algorithm 1: search T* in 1..T*max, keep the best mean quality.

    ``engine`` selects the implementation: ``"vec"`` (the process
    default — ``repro.core.arrays``, all T* candidates swept as one
    batched array kernel), ``"scalar"`` (this module's reference
    loop), or any registered backend such as ``"jax"``
    (``repro.core.jaxplan``, jit-compiled).  vec and scalar return
    bit-identical plans (tests/test_arrays.py enforces it); registered
    backends match within their documented tolerance
    (tests/test_jaxplan.py).
    """
    eng = arrays.resolve_engine(engine)
    impl = arrays.engine_impl(eng)
    if impl is not None:
        return impl.stacking(services, tau_prime, delay, quality,
                             t_star_max)
    if eng == "vec":
        return arrays.stacking_vec(services, tau_prime, delay, quality,
                                   t_star_max)
    ids = [s.id for s in services]
    if t_star_max <= 0:
        t_star_max = max(1, max(delay.max_steps(tau_prime[k]) for k in ids))

    best_plan, best_q = None, float("inf")
    for t_star in range(1, t_star_max + 1):
        plan = stacking_pass(ids, tau_prime, delay, t_star)
        q = quality.mean_fid([plan.steps_completed[k] for k in ids])
        if q < best_q - 1e-12:
            best_plan, best_q = plan, q
    assert best_plan is not None
    return best_plan
