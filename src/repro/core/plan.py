"""Batch-denoising plan IR.

A plan is the solution of problem (P2): an ordered list of batches, each a
set of (service_id, step_index) denoising tasks, with start times.  It maps
1:1 onto the paper's decision variables:

    x_{k,n}^s = 1  <=>  (k, s) in batches[n]
    t_n            =   start_times[n]
    T_k            =   steps_completed[k]

``validate`` checks the paper's constraints (1), (2), (6), (7) plus the
per-service generation deadline (14) — the property-based tests drive it.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Tuple

from repro.core.delay_model import DelayModel


@dataclasses.dataclass
class BatchPlan:
    batches: List[List[Tuple[int, int]]]     # batches[n] = [(k, s), ...]
    start_times: List[float]                 # t_n
    steps_completed: Dict[int, int]          # T_k
    delay: DelayModel

    @property
    def num_batches(self) -> int:
        return len(self.batches)

    def batch_sizes(self) -> List[int]:
        return [len(b) for b in self.batches]

    def completion_time(self, k: int) -> float:
        """D_k^cg (Eq. 5): end time of service k's last batch."""
        t_done = 0.0
        for t_n, batch in zip(self.start_times, self.batches):
            if any(kk == k for kk, _ in batch):
                t_done = t_n + self.delay.g(len(batch))
        return t_done

    def makespan(self) -> float:
        if not self.batches:
            return 0.0
        return self.start_times[-1] + self.delay.g(len(self.batches[-1]))

    def validate(self, gen_deadlines: Dict[int, float] = None,
                 tol: float = 1e-7) -> None:
        """Raise AssertionError on any violated constraint."""
        seen = set()
        for n, batch in enumerate(self.batches):
            assert len(batch) > 0, f"empty batch {n}"
            ks = [k for k, _ in batch]
            assert len(set(ks)) == len(ks), \
                f"service repeated within batch {n}"
            for task in batch:
                assert task not in seen, f"task {task} scheduled twice"  # (2)
                seen.add(task)

        # (2) completeness: every step 0..T_k-1 scheduled exactly once
        for k, T in self.steps_completed.items():
            for s in range(T):
                assert (k, s) in seen, f"missing task ({k},{s})"
        assert len(seen) == sum(self.steps_completed.values()), \
            "extra tasks beyond T_k"

        # (6) sequential batches: t_{n+1} >= t_n + g(X_n)
        for n in range(len(self.batches) - 1):
            end = self.start_times[n] + self.delay.g(len(self.batches[n]))
            assert self.start_times[n + 1] >= end - tol, \
                f"batch {n + 1} starts before batch {n} ends"

        # (7) per-service precedence: step s completes before s+1 starts
        task_batch = {}
        for n, batch in enumerate(self.batches):
            for k, s in batch:
                task_batch[(k, s)] = n
        for (k, s), n in task_batch.items():
            nxt = task_batch.get((k, s + 1))
            if nxt is not None:
                end = self.start_times[n] + self.delay.g(len(self.batches[n]))
                assert self.start_times[nxt] >= end - tol, \
                    f"service {k}: step {s + 1} starts before step {s} ends"

        # (14) generation deadline
        if gen_deadlines:
            for k, tau in gen_deadlines.items():
                T = self.steps_completed.get(k, 0)
                if T > 0:
                    assert self.completion_time(k) <= tau + tol, \
                        f"service {k} finishes at " \
                        f"{self.completion_time(k):.3f} > tau'={tau:.3f}"
