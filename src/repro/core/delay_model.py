"""Per-batch denoising delay model — the paper's Eq. (4):

    g(X) = a * X + b * ||X||_0

a = marginal per-task compute slope, b = fixed overhead (weight
loading / kernel launch on GPU; weight streaming HBM->VMEM on TPU).
The paper measures a=0.0240, b=0.3543 s for DDIM/CIFAR-10 on an RTX-3050;
``fit`` re-derives (a, b) from measurements on any hardware (benchmarks/
fig1a does this on this container's CPU), and ``tpu_estimate`` derives the
analytic TPU v5e counterpart from model size / FLOPs (DESIGN.md §3).
"""

from __future__ import annotations

import collections
import dataclasses
from typing import Optional, Sequence, Tuple

import numpy as np

# Paper's measured constants (Fig. 1a).
PAPER_A = 0.0240
PAPER_B = 0.3543


@dataclasses.dataclass(frozen=True)
class DelayModel:
    a: float = PAPER_A
    b: float = PAPER_B

    def g(self, batch_size: int) -> float:
        """Delay of one denoising batch of the given size (Eq. 4)."""
        if batch_size <= 0:
            return 0.0
        return self.a * batch_size + self.b

    def min_task_delay(self) -> float:
        return self.g(1)

    def max_steps(self, budget: float) -> int:
        """T^e in Eq. (16): tasks completable in `budget` seconds assuming
        dedicated (size-1) batches."""
        if budget <= 0:
            return 0
        return int(budget / (self.a + self.b))

    def scaled(self, factor: float) -> "DelayModel":
        """This model with both coefficients inflated by ``factor`` —
        headroom for planning against a freshly refit model."""
        return DelayModel(a=self.a * factor, b=self.b * factor)

    def refit(self, batch_sizes: Sequence[int],
              delays: Sequence[float]) -> "DelayModel":
        """Incremental refit from measured ``(batch_size, seconds)``
        telemetry (the PR-1 calibrate→replan hook, now usable mid-run).

        With two or more distinct batch sizes this is the clamped
        least-squares fit (a >= 0 so bigger batches never look cheaper,
        b > 0 so g stays positive).  With a single distinct size the
        slope is unobservable, so the current (a, b) shape is kept and
        both coefficients are rescaled so g matches the mean measured
        delay at that size — enough to correct a uniform speed
        misestimate from one batch size alone.
        """
        x = np.asarray(batch_sizes, dtype=np.float64)
        y = np.asarray(delays, dtype=np.float64)
        if x.shape != y.shape or x.size == 0:
            raise ValueError("refit needs matching, non-empty "
                             "batch_sizes/delays")
        if np.unique(x).size >= 2:
            m = fit(x, y)
            # a gets a tiny positive floor, not zero: the planners
            # divide by it (packing caps, Eqs. 19-20)
            a, b = max(m.a, 1e-9), m.b
        else:
            predicted = self.g(int(x[0]))
            ratio = float(np.mean(y)) / max(predicted, 1e-12)
            a, b = self.a * ratio, self.b * ratio
        return DelayModel(a=float(a), b=float(max(b, 1e-9)))


def fit(batch_sizes: Sequence[int], delays: Sequence[float]) -> DelayModel:
    """Least-squares fit of (a, b) — the paper's Fig. 1a fitting step."""
    x = np.asarray(batch_sizes, dtype=np.float64)
    y = np.asarray(delays, dtype=np.float64)
    assert x.shape == y.shape and x.size >= 2
    A = np.stack([x, np.ones_like(x)], axis=1)
    (a, b), *_ = np.linalg.lstsq(A, y, rcond=None)
    return DelayModel(a=float(a), b=float(b))


class RollingDelayFit:
    """Rolling least-squares window over measured per-batch delays.

    ``ExecutionLoop`` feeds it one ``(batch_size, seconds)`` pair per
    executed batch; ``model()`` returns the refit ``DelayModel`` over
    the last ``window`` observations (falling back to the prior's
    shape when only one distinct batch size has been seen — see
    ``DelayModel.refit``).
    """

    def __init__(self, window: int = 64,
                 prior: Optional[DelayModel] = None):
        if window < 2:
            raise ValueError(f"window must be >= 2, got {window}")
        self.window = int(window)
        self.prior = prior if prior is not None else DelayModel()
        self._obs: "collections.deque[Tuple[int, float]]" = \
            collections.deque(maxlen=self.window)

    def observe(self, batch_size: int, seconds: float) -> None:
        self._obs.append((int(batch_size), float(seconds)))

    def __len__(self) -> int:
        return len(self._obs)

    @property
    def ready(self) -> bool:
        return len(self._obs) >= 2

    def model(self, headroom: float = 1.0) -> DelayModel:
        """Refit over the window; ``headroom > 1`` inflates the result
        so replans keep slack against timing noise."""
        if not self._obs:
            return self.prior.scaled(headroom)
        sizes = [s for s, _ in self._obs]
        secs = [d for _, d in self._obs]
        return self.prior.refit(sizes, secs).scaled(headroom)


def tpu_estimate(flops_per_sample: float, param_bytes: float,
                 *, peak_flops: float = 197e12, hbm_bw: float = 819e9,
                 chips: int = 1, overhead: float = 1.4e-3) -> DelayModel:
    """Analytic v5e delay model (DESIGN.md §3).

    b ~= weight-streaming floor: every step the full parameter set crosses
         HBM once regardless of batch size (plus a fixed launch overhead);
    a ~= per-sample compute slope at peak MXU throughput.
    """
    a = flops_per_sample / (peak_flops * chips)
    b = param_bytes / (hbm_bw * chips) + overhead
    return DelayModel(a=float(a), b=float(b))
