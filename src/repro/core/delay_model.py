"""Per-batch denoising delay model — the paper's Eq. (4):

    g(X) = a * X + b * ||X||_0

a = marginal per-task compute slope, b = fixed overhead (weight
loading / kernel launch on GPU; weight streaming HBM->VMEM on TPU).
The paper measures a=0.0240, b=0.3543 s for DDIM/CIFAR-10 on an RTX-3050;
``fit`` re-derives (a, b) from measurements on any hardware (benchmarks/
fig1a does this on this container's CPU), and ``tpu_estimate`` derives the
analytic TPU v5e counterpart from model size / FLOPs (DESIGN.md §3).
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

# Paper's measured constants (Fig. 1a).
PAPER_A = 0.0240
PAPER_B = 0.3543


@dataclasses.dataclass(frozen=True)
class DelayModel:
    a: float = PAPER_A
    b: float = PAPER_B

    def g(self, batch_size: int) -> float:
        """Delay of one denoising batch of the given size (Eq. 4)."""
        if batch_size <= 0:
            return 0.0
        return self.a * batch_size + self.b

    def min_task_delay(self) -> float:
        return self.g(1)

    def max_steps(self, budget: float) -> int:
        """T^e in Eq. (16): tasks completable in `budget` seconds assuming
        dedicated (size-1) batches."""
        if budget <= 0:
            return 0
        return int(budget / (self.a + self.b))


def fit(batch_sizes: Sequence[int], delays: Sequence[float]) -> DelayModel:
    """Least-squares fit of (a, b) — the paper's Fig. 1a fitting step."""
    x = np.asarray(batch_sizes, dtype=np.float64)
    y = np.asarray(delays, dtype=np.float64)
    assert x.shape == y.shape and x.size >= 2
    A = np.stack([x, np.ones_like(x)], axis=1)
    (a, b), *_ = np.linalg.lstsq(A, y, rcond=None)
    return DelayModel(a=float(a), b=float(b))


def tpu_estimate(flops_per_sample: float, param_bytes: float,
                 *, peak_flops: float = 197e12, hbm_bw: float = 819e9,
                 chips: int = 1, overhead: float = 1.4e-3) -> DelayModel:
    """Analytic v5e delay model (DESIGN.md §3).

    b ~= weight-streaming floor: every step the full parameter set crosses
         HBM once regardless of batch size (plus a fixed launch overhead);
    a ~= per-sample compute slope at peak MXU throughput.
    """
    a = flops_per_sample / (peak_flops * chips)
    b = param_bytes / (hbm_bw * chips) + overhead
    return DelayModel(a=float(a), b=float(b))
