"""Exact reference solver for tiny instances (beyond-paper §Beyond):
exhaustive search over batch-size sequences to measure STACKING's
optimality gap on problem (P2).

State: sorted vector of remaining generation budgets; at each decision
point the server picks how many of the tightest-budget active services to
batch next (services with the smallest remaining budget are always the
ones at risk — batching any other subset of the same size is dominated,
because step counts enter quality symmetrically and budgets only shrink).
Memoized over (rounded budgets, step counts); exponential worst case, only
used with K <= 6 and coarse budgets in tests/benchmarks.
"""

from __future__ import annotations

import functools
from typing import Dict, Sequence, Tuple

from repro.core.delay_model import DelayModel
from repro.core.quality_model import QualityModel


def optimal_mean_fid(tau_prime: Sequence[float], delay: DelayModel,
                     quality: QualityModel, max_steps: int = 60,
                     grid: float = 1e-3) -> float:
    """Exact minimum mean FID over all batch schedules (small K only)."""
    K = len(tau_prime)
    g1 = delay.min_task_delay()

    @functools.lru_cache(maxsize=1_000_000)
    def best(state: Tuple[Tuple[int, int], ...]) -> float:
        # state: sorted tuple of (budget_ticks, steps_done)
        active = [(b, s) for b, s in state if b * grid >= g1]
        if not active:
            return sum(quality.fid(s) for _, s in state)
        # choose a batch = the m tightest active services, m = 1..len
        active_sorted = sorted(active)
        inactive = [x for x in state if x[0] * grid < g1]
        best_v = float("inf")
        for m in range(1, len(active_sorted) + 1):
            g = delay.g(m)
            ticks = int(round(g / grid))
            # all active budgets shrink; the m tightest gain one step
            nxt = []
            for i, (b, s) in enumerate(active_sorted):
                nb = b - ticks
                ns = s + 1 if i < m else s
                if nb * grid < g1 and i < m and b * grid < g:
                    # cannot afford the batch it was packed into -> it
                    # wouldn't be packed; skip this m entirely
                    break
                nxt.append((max(nb, 0), ns))
            else:
                v = best(tuple(sorted(nxt + inactive)))
                if v < best_v:
                    best_v = v
                continue
            # infeasible m (a packed service couldn't afford the batch)
        # also allowed: stop now
        stop_v = sum(quality.fid(s) for _, s in state)
        best_v = min(best_v, stop_v)
        return best_v

    state = tuple(sorted(
        (int(t / grid), 0) for t in tau_prime))
    # cap steps via budget: irrelevant for small instances
    return best(state) / K
