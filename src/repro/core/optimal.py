"""Exact reference solver for tiny instances (beyond-paper §Beyond):
exhaustive search over batch-size sequences to measure STACKING's
optimality gap on problem (P2).

Because the delay model is affine (g(X) = aX + b), the elapsed time of
any schedule prefix is *exactly* a*S + b*N where S = tasks scheduled so
far and N = batches so far — both integers.  The DP therefore needs no
time discretization: feasibility checks are exact, and the same
memoized recursion backs both ``optimal_mean_fid`` (the scalar bound)
and ``optimal_plan`` (the registry's ``"optimal"`` scheduler, which
reconstructs an executable ``BatchPlan`` from the DP's decisions).

At each decision point the server batches the m tightest-budget active
services (batching any other subset of the same size is dominated,
because step counts enter quality symmetrically and budgets only
shrink).  Memoized over (batch count, sorted (deadline, steps) pairs);
exponential worst case, only used with small K in tests/benchmarks.
"""

from __future__ import annotations

import functools
from typing import Dict, Optional, Sequence, Tuple

from repro.core import arrays
from repro.core.delay_model import DelayModel
from repro.core.plan import BatchPlan
from repro.core.quality_model import QualityModel

# affordability slack, matching the schedulers' float convention
# (see stacking.py: ``taup[k] + 1e-12 < g`` means "cannot afford")
_EPS = 1e-12


def _make_dp(delay: DelayModel, quality: QualityModel):
    """Exact memoized DP.  ``best(n_batches, state)`` returns
    (minimum total FID reachable, best next batch size m; m=0 = stop),
    where state is a sorted tuple of (tau_prime, steps_done) pairs."""
    a, b = delay.a, delay.b
    g1 = delay.min_task_delay()
    assert g1 > 0, "degenerate delay model: g(1) must be positive"

    @functools.lru_cache(maxsize=2_000_000)
    def best(n_batches: int,
             state: Tuple[Tuple[float, int], ...]) -> Tuple[float, int]:
        elapsed = a * sum(s for _, s in state) + b * n_batches
        stop_v = sum(quality.fid(s) for _, s in state)
        # active = can still afford a dedicated batch; budgets shrink with
        # the common elapsed time, so "tightest" = smallest tau_prime
        active = sorted((t, s) for t, s in state
                        if t - elapsed + _EPS >= g1)
        if not active:
            return stop_v, 0
        inactive = [x for x in state if x[0] - elapsed + _EPS < g1]
        best_v, best_m = stop_v, 0
        for m in range(1, len(active) + 1):
            if active[0][0] - elapsed + _EPS < delay.g(m):
                break          # the tightest member cannot afford this
                               # batch; larger batches only cost more
            nxt = [(t, s + 1 if i < m else s)
                   for i, (t, s) in enumerate(active)]
            v, _ = best(n_batches + 1, tuple(sorted(nxt + inactive)))
            if v < best_v - _EPS:
                best_v, best_m = v, m
        return best_v, best_m

    return best


def optimal_mean_fid(tau_prime: Sequence[float], delay: DelayModel,
                     quality: QualityModel, max_steps: int = 60,
                     grid: float = 1e-3,
                     engine: Optional[str] = None) -> float:
    """Exact minimum mean FID over all batch schedules (small K only).

    ``max_steps``/``grid`` are retained for call-site compatibility but
    unused: the affine delay model makes the DP exact without either.
    ``engine`` follows the planner-engine convention: ``None``/``vec``/
    ``scalar`` run this module's memoized DP; a registered backend
    (e.g. ``"jax"``) runs its own exact search, equal within float
    tolerance.
    """
    impl = arrays.engine_impl(arrays.resolve_engine(engine))
    if impl is not None:
        return impl.optimal_mean_fid(tau_prime, delay, quality,
                                     max_steps, grid)
    K = len(tau_prime)
    best = _make_dp(delay, quality)
    v, _ = best(0, tuple(sorted((float(t), 0) for t in tau_prime)))
    return v / K


def optimal_plan(services, tau_prime: Dict[int, float], delay: DelayModel,
                 quality: QualityModel, *,
                 max_services: int = 8,
                 engine: Optional[str] = None) -> BatchPlan:
    """Exact-search *scheduler*: reconstructs an executable ``BatchPlan``
    from the DP's decisions.  Its mean FID equals ``optimal_mean_fid``
    and the plan passes ``BatchPlan.validate(gen_deadlines=tau_prime)``.
    Exponential worst case — refuses K > ``max_services``.  ``engine``
    as in ``optimal_mean_fid`` (registered backends run their own exact
    search; among exactly tied optima the plans may differ).
    """
    impl = arrays.engine_impl(arrays.resolve_engine(engine))
    if impl is not None:
        return impl.optimal_plan(services, tau_prime, delay, quality,
                                 max_services=max_services)
    ids = [s.id for s in services]
    K = len(ids)
    assert K <= max_services, \
        f"optimal_plan is exact search; K={K} > {max_services}"
    best = _make_dp(delay, quality)
    g1 = delay.min_task_delay()
    a, b = delay.a, delay.b

    Tc = {k: 0 for k in ids}
    batches, starts = [], []
    n_batches = 0
    while True:
        elapsed = a * sum(Tc.values()) + b * n_batches
        pairs = sorted((float(tau_prime[k]), Tc[k], k) for k in ids)
        _, m = best(n_batches, tuple((t, s) for t, s, _ in pairs))
        if m == 0:
            break
        members = [k for t, _, k in pairs
                   if t - elapsed + _EPS >= g1][:m]
        batches.append([(k, Tc[k]) for k in members])
        starts.append(elapsed)
        for k in members:
            Tc[k] += 1
        n_batches += 1
    return BatchPlan(batches=batches, start_times=starts,
                     steps_completed=Tc, delay=delay)
