"""Event-driven online admission simulator (docs/SCENARIOS.md).

The paper provisions a *static* batch: all K requests are known at t=0,
one bandwidth allocation (P1) and one batch-denoising plan (P2) serve
them all.  ``simulate_online`` relaxes exactly one assumption — requests
arrive over time (``ServiceRequest.arrival``) — and replays the paper's
pipeline as an event loop:

  arrival(k)   -> admission decision (pluggable policy, given a *trial*
                  replan that includes k) -> on admit, adopt the trial
                  plan; on reject, keep the current plan untouched
  batch start  -> the batch is committed ("in-flight"): a later arrival
                  can replan everything scheduled after it, but never
                  preempt it
  generation   -> the service's last scheduled batch completes; its
                  content transmits over the bandwidth the adopting
                  replan gave it

Replanning semantics (the residual scenario):

  * remaining end-to-end budget of a live service is its absolute
    deadline minus the replan instant (deadlines shrink as time passes);
  * denoising steps already executed are kept — the replanned batches
    schedule *additional* steps and final quality is ``fid(done + new)``;
  * the scheduler's outer search itself scores plans with the unshifted
    quality model ("progress-agnostic" objective) because the Scheduler
    protocol evaluates anonymous step-count lists; the executed steps
    still count toward the reported outcome.  With every arrival at t=0
    there is nothing in flight, so the online path reproduces the static
    ``simulate`` bit-for-bit (tests/test_online.py enforces it).

The loop is pure numpy + stdlib and fully deterministic: identical
scenarios, schedulers, allocators and admission policies yield identical
event sequences (arrival ties break by service id).

The per-server half of the loop (active plan, batch execution, residual
replanning) lives in ``_ServerTrack`` so the multi-server simulator
(``repro.core.multiserver``) can run one track per edge cell over the
same event loop; ``OnlineSimulation`` is the single-track instance.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Callable, Dict, List, Optional, Set

import numpy as np

from repro.core import arrays
from repro.core.bandwidth import make_plan
from repro.core.delay_model import DelayModel
from repro.core.plan import BatchPlan
from repro.core.quality_model import PowerLawFID, QualityModel
from repro.core.service import Scenario, ServiceRequest
from repro.core.simulator import ServiceOutcome

# (residual_scenario, scheduler, delay, quality) -> B_k array — the same
# calling convention as the repro.api Allocator protocol.
AllocatorFn = Callable[..., np.ndarray]
# (svc, projected ServiceOutcome, {id: _ServiceState}) -> admit?
AdmissionFn = Callable[..., bool]

_TIE = 1e-6   # deadline slack, matches repro.core.simulator


def _offset_native(scheduler) -> bool:
    """Does this scheduler implement the ``OffsetScheduler`` extension
    (a ``plan(services, tau_prime, delay, quality, offsets)`` method
    plus the ``supports_offsets`` marker)?  The explicit marker keeps a
    custom scheduler's unrelated ``plan`` helper from being mistaken
    for the protocol; duck-typed so ``repro.core`` never imports
    ``repro.api``."""
    return bool(getattr(scheduler, "supports_offsets", False)) \
        and callable(getattr(scheduler, "plan", None))


@dataclasses.dataclass
class AdmissionDecision:
    """One arrival's verdict, with the outcome the trial replan projected
    for it (what the admission policy saw)."""
    id: int
    arrival: float
    admitted: bool
    projected: ServiceOutcome


@dataclasses.dataclass
class OnlineResult:
    """Per-service outcomes for admitted requests (scenario order) plus
    the arrival-ordered admission log.  Delays are relative to each
    service's arrival, so at ``arrival == 0`` for all services the
    outcomes equal the static ``simulate`` result."""
    outcomes: List[ServiceOutcome]
    decisions: List[AdmissionDecision]
    mean_fid: float          # over admitted services
    outage_rate: float       # over admitted services
    reject_rate: float       # rejected / all arrivals
    # committed batch sequence as (start_time, [ids]) — what actually
    # ran, across every adopted replan.  Populated by the single-track
    # simulator (None for multi-server runs, where batches interleave
    # per cell); repro.api.execution replays it on a real executor.
    executed_batches: Optional[List] = None

    @property
    def admitted_ids(self) -> List[int]:
        return [o.id for o in self.outcomes]

    @property
    def rejected_ids(self) -> List[int]:
        return [d.id for d in self.decisions if not d.admitted]

    def summary(self) -> str:
        lines = [f"{'svc':>4} {'arr':>7} {'tau':>7} {'steps':>6} "
                 f"{'gen':>8} {'tx':>7} {'e2e':>8} {'fid':>8} ok"]
        arr = {d.id: d.arrival for d in self.decisions}
        for o in self.outcomes:
            lines.append(
                f"{o.id:>4} {arr.get(o.id, 0.0):7.2f} {o.deadline:7.2f} "
                f"{o.steps:6d} {o.gen_delay:8.3f} {o.tx_delay:7.3f} "
                f"{o.e2e_delay:8.3f} {o.fid:8.2f} "
                f"{'Y' if o.met_deadline else 'N'}")
        lines.append(
            f"admitted {len(self.outcomes)}/{len(self.decisions)}  "
            f"mean FID {self.mean_fid:.3f}  outage {self.outage_rate:.1%}  "
            f"reject {self.reject_rate:.1%}")
        return "\n".join(lines)


@dataclasses.dataclass
class _ServiceState:
    svc: ServiceRequest
    admitted: Optional[bool] = None     # None until its arrival is processed
    steps_done: int = 0
    gen_end: Optional[float] = None     # absolute generation-complete time
    tx_dur: float = 0.0                 # D_k^ct under the adopted bandwidth
    tx_end: Optional[float] = None
    bandwidth: float = 0.0              # B_k of the plan that finished it

    @property
    def abs_deadline(self) -> float:
        return self.svc.arrival + self.svc.deadline

    @property
    def gen_complete(self) -> bool:
        return self.gen_end is not None


class _OffsetQuality:
    """Progress-aware replanning objective.

    A replan schedules *additional* steps, but quality is a function of
    the running total, so a candidate step-count vector is scored as
    ``fid(done_k + new_k)``.  The Scheduler protocol evaluates anonymous
    count lists; by the ``make_plan`` convention those are in residual
    service order (stacking, equal_steps, single_instance and the P1
    ``evaluate`` fitness all comply), which is how ``offsets`` is keyed.
    A scheduler scoring a differently-ordered or partial list silently
    degrades to the progress-agnostic base objective, never crashes.
    Per-step ``fid`` stays unshifted (only ``optimal`` uses it, as a
    symmetric DP value).

    ``doomed`` closes an exploit: a partially-generated service whose
    residual generation budget went *negative* (its transmission alone
    overruns the deadline under the candidate bandwidth allocation) can
    never deliver on time, so its banked steps are worth ``fid(0)`` —
    otherwise allocators learn to strip bandwidth from nearly-finished
    services "for free" and their content arrives late.  The set is
    refreshed per scheduler invocation (it depends on the candidate
    allocation via tau'), matching the static objective where an
    infeasible service scores ``fid_at_zero``.
    """

    def __init__(self, base: QualityModel, offsets: List[int]):
        self.base = base
        self.offsets = offsets
        self.doomed: Set[int] = set()

    def refresh_doomed(self, services, tau_prime: Dict[int, float]) -> None:
        self.doomed = {i for i, s in enumerate(services)
                       if self.offsets[i] > 0 and tau_prime[s.id] < 0}

    def fid(self, steps: int) -> float:
        return self.base.fid(steps)

    def mean_fid(self, step_counts) -> float:
        if len(step_counts) != len(self.offsets):
            return float(np.mean([self.base.fid(t) for t in step_counts]))
        return float(np.mean([
            self.base.fid(0) if i in self.doomed
            else self.base.fid(self.offsets[i] + t)
            for i, t in enumerate(step_counts)]))


def offset_aware(scheduler, quality: QualityModel, offsets: List[int]):
    """Wrap ``(scheduler, quality)`` for a replan over services with
    already-executed steps (``offsets``, residual scenario order).

    With no executed steps the pair passes through unchanged.  Otherwise
    the quality model becomes the progress-aware ``_OffsetQuality`` and
    the scheduler is wrapped so every invocation first refreshes the
    doomed set for the candidate allocation's tau'; offset-native
    schedulers (``OffsetScheduler`` protocol) are dispatched through
    their ``plan(..., offsets)`` entry with the *base* quality model.
    Shared by ``_ServerTrack.replan`` and ``core.execution`` so both
    replan paths credit executed steps identically.
    """
    if not any(offsets):
        return scheduler, quality
    oq = _OffsetQuality(quality, offsets)

    if _offset_native(scheduler):
        # offset-native dispatch: the scheduler plans against
        # per-service progress itself (base quality model + offsets);
        # the _OffsetQuality wrapper still scores the allocator's
        # fitness evaluations so P1 stays progress-aware too
        def wrapped(services, tau_prime, delay, q,
                    _inner=scheduler, _oq=oq, _base=quality,
                    _off=offsets):
            _oq.refresh_doomed(services, tau_prime)
            return _inner.plan(services, tau_prime, delay, _base, _off)
    else:
        def wrapped(services, tau_prime, delay, q,
                    _inner=scheduler, _oq=oq):
            # every candidate allocation implies fresh tau' — mark
            # which in-progress services it starves before the inner
            # scheduler's own mean_fid evaluations run
            _oq.refresh_doomed(services, tau_prime)
            return _inner(services, tau_prime, delay, q)

    return wrapped, oq


@dataclasses.dataclass
class _ActivePlan:
    """An adopted replan: a BatchPlan anchored at absolute time ``t0``."""
    t0: float
    plan: BatchPlan
    alloc: Dict[int, float]             # id -> Hz under this plan
    last_batch_of: Dict[int, int]       # id -> index of its final batch
    next_batch: int = 0


def _anchor(t0: float, plan: BatchPlan, res_scn: Scenario,
            alloc: np.ndarray) -> _ActivePlan:
    last: Dict[int, int] = {}
    for n, batch in enumerate(plan.batches):
        for k, _ in batch:
            last[k] = n
    return _ActivePlan(
        t0=t0, plan=plan,
        alloc={s.id: float(alloc[i]) for i, s in enumerate(res_scn.services)},
        last_batch_of=last)


class _ServerTrack:
    """The per-server half of the event loop: one server's active plan,
    batch execution, and residual replanning.

    ``states`` is the simulation-wide state dict (shared across tracks
    in the multi-server case; each service only ever lives on one track,
    recorded in ``owned``).  ``bandwidth_hz`` is this cell's own budget
    and ``delay`` the delay model as seen on this server's hardware.
    """

    def __init__(self, scn: Scenario, bandwidth_hz: float, scheduler,
                 allocator: AllocatorFn, delay: DelayModel,
                 quality: QualityModel, states: Dict[int, _ServiceState],
                 validate: bool = True):
        self.scn = scn
        self.bandwidth_hz = bandwidth_hz
        self.scheduler = scheduler
        self.allocator = allocator
        self.delay = delay
        self.quality = quality
        self.states = states
        self.validate = validate

        # every id admitted here and not since handed off to another
        # cell (multiserver._migrate moves never-started services
        # between tracks); drives the reserved-bandwidth filter in
        # residual_scenario and the per-cell capacity count
        self.owned: Set[int] = set()
        self.pending: Set[int] = set()      # admitted, generation incomplete
        self.active: Optional[_ActivePlan] = None
        self.t_free = 0.0
        self.replan_count = 0
        # (t_start, service, cumulative step count) per executed task —
        # the replan-invariant tests read this (steps must be contiguous)
        self.executed_log: List[tuple] = []

    # -- event handlers --------------------------------------------------

    def _complete_generation(self, st: _ServiceState, t: float,
                             bandwidth: float) -> None:
        st.gen_end = t
        st.bandwidth = bandwidth
        st.tx_dur = st.svc.tx_delay(bandwidth, self.scn.content_bits)
        st.tx_end = t + st.tx_dur
        self.pending.discard(st.svc.id)

    def execute_until(self, t_limit: float) -> None:
        """Run every batch whose start time precedes ``t_limit``.

        A batch is committed atomically at its start instant: once
        started it always finishes (the "in-flight batch pinned" rule),
        so its end may land past ``t_limit``.  A batch starting exactly
        at an arrival instant has not started yet and stays replannable.
        """
        ap = self.active
        if ap is None:
            return
        starts, batches = ap.plan.start_times, ap.plan.batches
        while ap.next_batch < len(batches) and \
                ap.t0 + starts[ap.next_batch] < t_limit:
            n = ap.next_batch
            batch = batches[n]
            end = ap.t0 + starts[n] + ap.plan.delay.g(len(batch))
            for k, _ in batch:
                st = self.states[k]
                st.steps_done += 1
                self.executed_log.append(
                    (ap.t0 + starts[n], k, st.steps_done))
                if n == ap.last_batch_of[k]:
                    self._complete_generation(st, end, ap.alloc[k])
            self.t_free = max(self.t_free, end)
            ap.next_batch += 1

    def residual_scenario(self, ids: Set[int], t_free: float) -> Scenario:
        """Live services with deadlines shrunk to the replan instant
        (kept in scenario order so an all-at-t=0 replan sees exactly the
        static scenario).

        The bandwidth budget is only what is *uncommitted*: services
        whose content is still in the air at ``t_free`` keep the
        sub-band their adopting plan gave them, so the instantaneous sum
        over concurrent transmissions never exceeds this cell's channel
        (inductively: each replan hands out at most the remainder).
        With no arrivals after t=0 nothing is ever in flight at replan
        time and the full budget is allocated, as in the static paper
        setting."""
        residual = [
            dataclasses.replace(
                self.states[s.id].svc,
                deadline=self.states[s.id].abs_deadline - t_free,
                arrival=0.0)
            for s in self.scn.services if s.id in ids
        ]
        B = self.bandwidth_hz
        reserved = sum(st.bandwidth for st in self.states.values()
                       if st.svc.id in self.owned and st.gen_complete
                       and st.tx_end > t_free)
        return Scenario(services=residual,
                        total_bandwidth_hz=max(B - reserved, 1e-6 * B),
                        content_bits=self.scn.content_bits)

    def replan(self, ids: Set[int], t_free: float) -> _ActivePlan:
        """Allocate -> plan over the residual scenario, anchored at
        ``t_free`` (the instant this server frees up)."""
        res_scn = self.residual_scenario(ids, t_free)
        offsets = [self.states[s.id].steps_done for s in res_scn.services]
        scheduler, quality = offset_aware(self.scheduler, self.quality,
                                          offsets)
        alloc = np.asarray(self.allocator(
            res_scn, scheduler, self.delay, quality))
        tp, plan = make_plan(res_scn, alloc, scheduler, self.delay,
                             quality)
        if self.validate:
            plan.validate(gen_deadlines=tp)
        self.replan_count += 1
        return _anchor(t_free, plan, res_scn, alloc)

    def adopt(self, svc_id: int, trial: _ActivePlan) -> None:
        """Accept an arrival: the trial plan replaces this track's
        not-yet-started batches."""
        self.owned.add(svc_id)
        self.pending.add(svc_id)
        self.active = trial
        self._settle_no_step_services(trial)

    def _settle_no_step_services(self, ap: _ActivePlan) -> None:
        """A partially-generated service the new plan gives no further
        steps is done denoising: transmit what it has, now."""
        for k in sorted(self.pending):
            st = self.states[k]
            if st.steps_done > 0 and ap.plan.steps_completed.get(k, 0) == 0:
                self._complete_generation(st, ap.t0, ap.alloc[k])


def _project(svc: ServiceRequest, trial: _ActivePlan,
             quality: QualityModel, content_bits: float) -> ServiceOutcome:
    """The outcome ``svc`` gets if the trial plan runs uninterrupted —
    the evidence handed to the admission policy."""
    T = trial.plan.steps_completed.get(svc.id, 0)
    if T > 0:
        gen_abs = trial.t0 + trial.plan.completion_time(svc.id)
        gen = gen_abs - svc.arrival
        tx = svc.tx_delay(trial.alloc[svc.id], content_bits)
    else:
        gen = tx = 0.0
    e2e = gen + tx
    return ServiceOutcome(
        id=svc.id, deadline=svc.deadline, steps=T, gen_delay=gen,
        tx_delay=tx, e2e_delay=e2e, fid=quality.fid(T),
        met_deadline=(T > 0 and e2e <= svc.deadline + _TIE))


def batches_from_log(executed_log: List[tuple]) -> List[tuple]:
    """Reconstruct the committed batch sequence from a track's
    ``executed_log``: consecutive entries sharing a start instant are
    one batch (starts strictly increase across batches — each batch
    ends, and any replan anchors, after its own start)."""
    batches: List[tuple] = []
    for t_start, k, _ in executed_log:
        if batches and batches[-1][0] == t_start:
            batches[-1][1].append(k)
        else:
            batches.append((t_start, [k]))
    return batches


def _collect_result(scn: Scenario, states: Dict[int, _ServiceState],
                    decisions: List[AdmissionDecision],
                    quality: QualityModel) -> OnlineResult:
    """Final per-service outcomes + aggregates (shared by the single-
    and multi-server simulators)."""
    outcomes = []
    for s in scn.services:
        st = states[s.id]
        if not st.admitted:
            continue
        T = st.steps_done
        if st.gen_complete:
            gen = st.gen_end - s.arrival
            tx = st.tx_dur
            e2e = gen + tx
            met = T > 0 and e2e <= s.deadline + _TIE
        else:
            # never scheduled a single step (infeasible throughout):
            # mirrors the static simulator's T == 0 outage row
            gen = tx = e2e = 0.0
            met = False
        outcomes.append(ServiceOutcome(
            id=s.id, deadline=s.deadline, steps=T, gen_delay=gen,
            tx_delay=tx, e2e_delay=e2e, fid=quality.fid(T),
            met_deadline=met))
    mean_fid = float(np.mean([o.fid for o in outcomes])) \
        if outcomes else float("nan")
    outage = float(np.mean([0.0 if o.met_deadline else 1.0
                            for o in outcomes])) if outcomes else 0.0
    n = len(decisions)
    rejected = sum(1 for d in decisions if not d.admitted)
    return OnlineResult(outcomes=outcomes, decisions=decisions,
                        mean_fid=mean_fid, outage_rate=outage,
                        reject_rate=rejected / n if n else 0.0)


class OnlineSimulation:
    """One event-driven run; ``simulate_online`` is the functional entry.

    A single ``_ServerTrack`` covering the whole scenario; the
    multi-server sibling (``repro.core.multiserver``) runs one track per
    edge cell over the same arrival loop."""

    def __init__(self, scn: Scenario, scheduler, allocator: AllocatorFn,
                 delay: DelayModel, quality: QualityModel,
                 admission: AdmissionFn, validate: bool = True):
        self.scn = scn
        self.scheduler = scheduler
        self.allocator = allocator
        self.delay = delay
        self.quality = quality
        self.admission = admission
        self.validate = validate

        self.states: Dict[int, _ServiceState] = {
            s.id: _ServiceState(s) for s in scn.services}
        self.track = _ServerTrack(scn, scn.total_bandwidth_hz, scheduler,
                                  allocator, delay, quality, self.states,
                                  validate=validate)
        self.decisions: List[AdmissionDecision] = []

    # back-compat views onto the single track
    @property
    def pending(self) -> Set[int]:
        return self.track.pending

    @property
    def active(self) -> Optional[_ActivePlan]:
        return self.track.active

    @property
    def t_server_free(self) -> float:
        return self.track.t_free

    @property
    def replan_count(self) -> int:
        return self.track.replan_count

    # -- main loop -------------------------------------------------------

    def run(self) -> OnlineResult:
        tr = self.track
        for svc in sorted(self.scn.services,
                          key=lambda s: (s.arrival, s.id)):
            tr.execute_until(svc.arrival)
            t_free = max(svc.arrival, tr.t_free)
            trial = tr.replan(tr.pending | {svc.id}, t_free)
            projected = _project(svc, trial, self.quality,
                                 self.scn.content_bits)
            admit = bool(self.admission(svc, projected, self.states))
            self.states[svc.id].admitted = admit
            self.decisions.append(AdmissionDecision(
                id=svc.id, arrival=svc.arrival, admitted=admit,
                projected=projected))
            if admit:
                tr.adopt(svc.id, trial)
            # on reject the current plan keeps running untouched
        tr.execute_until(math.inf)
        result = _collect_result(self.scn, self.states, self.decisions,
                                 self.quality)
        result.executed_batches = batches_from_log(tr.executed_log)
        return result


def simulate_online(scn: Scenario, scheduler, allocator: AllocatorFn,
                    delay: Optional[DelayModel] = None,
                    quality: Optional[QualityModel] = None,
                    admission: Optional[AdmissionFn] = None,
                    validate: bool = True,
                    engine: Optional[str] = None) -> OnlineResult:
    """Event-driven arrivals + on-arrival replanning (module docstring).

    scheduler / allocator are plain callables with the repro.api
    protocol signatures; ``repro.api.online.OnlineProvisioner`` is the
    registry-aware front end.  ``admission`` defaults to admit-all.
    ``engine`` pins the planning engine (``"vec"``/``"scalar"``,
    ``repro.core.arrays``) for every replan of this run; ``None``
    keeps the process default.  Both engines produce bit-identical
    event sequences (tests/test_arrays.py).
    """
    if admission is None:
        admission = lambda svc, projected, states: True   # noqa: E731
    sim = OnlineSimulation(scn, scheduler, allocator,
                           delay if delay is not None else DelayModel(),
                           quality if quality is not None else PowerLawFID(),
                           admission, validate=validate)
    with arrays.engine_scope(engine):
        return sim.run()
