"""Arrival processes for population-scale fleet simulation.

The paper evaluates provisioning at tens of services with arrivals
listed by hand; a fleet of cells serving millions of users needs the
arrivals *generated* — and the interesting provisioning regimes are
exactly the non-homogeneous ones (diurnal load curves, flash crowds,
cells whose load moves together).  This module supplies those as small
stateless samplers with one shared contract:

``process.sample(rng, t0, t1) -> float64 array``
    strictly-sorted arrival times in the half-open window
    ``[t0, t1)``.  Samplers hold no mutable state; all randomness
    comes from the ``numpy.random.Generator`` handed in, so a fleet
    run is reproducible from its seed and each cell can own an
    independent stream (``np.random.default_rng([seed, cell])`` is the
    fleet convention).

Random processes are sampled *per window*: calling ``sample`` over
``[0, 10)`` and over ``[0, 5) + [5, 10)`` draws different (equally
distributed) realizations because the generator state advances
differently.  ``TraceArrivals`` is the exception — a trace is a fixed
set of timestamps, so its windows are exact set-partitions of the
trace and any chunking reproduces the same arrivals.  The fleet
harness leans on this to prove its event and epoch modes equivalent.

Processes are registered by name in ``repro.api.registry.ARRIVALS``
(see ``repro.api.fleet``); this module stays numpy-only.
"""

from __future__ import annotations

import csv
import json
import math
from dataclasses import dataclass, field
from pathlib import Path
from typing import Callable, List, Sequence, Union

import numpy as np

__all__ = [
    "ArrivalProcess",
    "PoissonProcess",
    "InhomogeneousPoisson",
    "DiurnalPoisson",
    "FlashCrowd",
    "TraceArrivals",
    "load_trace",
    "correlated_rates",
]


class ArrivalProcess:
    """Protocol: anything with ``sample(rng, t0, t1) -> sorted float64
    times in [t0, t1)``.  The classes below are the stock processes;
    user code can register anything satisfying this shape."""

    def sample(self, rng: np.random.Generator, t0: float,
               t1: float) -> np.ndarray:  # pragma: no cover - protocol
        raise NotImplementedError

    def mean_rate(self, t0: float, t1: float) -> float:
        """Expected arrivals per unit time over the window — used for
        sizing (epoch widths, benchmark budgets), not sampling."""
        raise NotImplementedError  # pragma: no cover - protocol


def _check_window(t0: float, t1: float) -> float:
    if not (math.isfinite(t0) and math.isfinite(t1)) or t1 < t0:
        raise ValueError(f"arrival window [{t0}, {t1}) is not a finite "
                         f"forward interval")
    return t1 - t0


@dataclass(frozen=True)
class PoissonProcess(ArrivalProcess):
    """Homogeneous Poisson arrivals at ``rate`` per unit time.

    Sampled the standard conditional-uniform way: the window count is
    one Poisson draw, the times are that many sorted uniforms — O(n)
    per window with no sequential exponential loop.
    """

    rate: float

    def __post_init__(self):
        if not (self.rate >= 0.0 and math.isfinite(self.rate)):
            raise ValueError(f"rate must be finite and >= 0, got "
                             f"{self.rate}")

    def sample(self, rng: np.random.Generator, t0: float,
               t1: float) -> np.ndarray:
        span = _check_window(t0, t1)
        n = rng.poisson(self.rate * span)
        return np.sort(t0 + span * rng.random(n))

    def mean_rate(self, t0: float, t1: float) -> float:
        _check_window(t0, t1)
        return self.rate


@dataclass(frozen=True)
class InhomogeneousPoisson(ArrivalProcess):
    """Poisson arrivals with a time-varying intensity ``rate_fn(t)``,
    sampled by thinning (Lewis & Shedler): draw homogeneous candidates
    at the envelope ``rate_max``, keep each with probability
    ``rate_fn(t) / rate_max``.  Exact for any intensity bounded by the
    envelope; a ``rate_fn`` exceeding it raises rather than silently
    under-sampling.
    """

    rate_fn: Callable[[np.ndarray], np.ndarray]
    rate_max: float

    def __post_init__(self):
        if not (self.rate_max > 0.0 and math.isfinite(self.rate_max)):
            raise ValueError(f"rate_max must be finite and > 0, got "
                             f"{self.rate_max}")

    def sample(self, rng: np.random.Generator, t0: float,
               t1: float) -> np.ndarray:
        span = _check_window(t0, t1)
        n = rng.poisson(self.rate_max * span)
        cand = np.sort(t0 + span * rng.random(n))
        rates = np.asarray(self.rate_fn(cand), dtype=np.float64)
        rates = np.broadcast_to(rates, cand.shape)
        if rates.size and (rates.max(initial=0.0) > self.rate_max
                           * (1 + 1e-12) or rates.min(initial=0.0) < 0):
            raise ValueError(
                f"rate_fn left [0, rate_max={self.rate_max}] on "
                f"[{t0}, {t1}); thinning would mis-sample — raise the "
                f"envelope")
        return cand[rng.random(cand.shape) * self.rate_max < rates]

    def mean_rate(self, t0: float, t1: float) -> float:
        span = _check_window(t0, t1)
        if span == 0.0:
            return 0.0
        # trapezoid over a fixed grid — sizing only, not sampling
        ts = np.linspace(t0, t1, 129)
        vals = np.broadcast_to(
            np.asarray(self.rate_fn(ts), dtype=np.float64), ts.shape)
        trapezoid = getattr(np, "trapezoid", None) or np.trapz
        return float(trapezoid(vals, ts) / span)


def DiurnalPoisson(base_rate: float, amplitude: float = 0.5,
                   period: float = 24.0,
                   phase: float = 0.0) -> InhomogeneousPoisson:
    """A diurnal load curve: intensity
    ``base_rate * (1 + amplitude * sin(2*pi*(t - phase) / period))``.

    ``amplitude`` in [0, 1] keeps the intensity nonnegative (1.0 means
    the trough hits zero — a fully off-peak quiet hour).
    """
    if not 0.0 <= amplitude <= 1.0:
        raise ValueError(f"amplitude must be in [0, 1], got {amplitude}")
    if not (base_rate >= 0.0 and math.isfinite(base_rate)):
        raise ValueError(f"base_rate must be finite and >= 0, got "
                         f"{base_rate}")
    if not (period > 0.0 and math.isfinite(period)):
        raise ValueError(f"period must be finite and > 0, got {period}")
    w = 2.0 * math.pi / period

    def rate_fn(t):
        return base_rate * (1.0 + amplitude * np.sin(w * (np.asarray(t)
                                                          - phase)))

    return InhomogeneousPoisson(
        rate_fn=rate_fn, rate_max=base_rate * (1.0 + amplitude)
        if base_rate > 0 else 1e-12)


def FlashCrowd(base_rate: float, peak_rate: float, start: float,
               duration: float) -> InhomogeneousPoisson:
    """A flash crowd: baseline Poisson load that jumps to ``peak_rate``
    on ``[start, start + duration)`` and snaps back — the arrival shape
    that stresses admission and batching the hardest (a whole window of
    deadlines lands on one cell at once)."""
    if peak_rate < base_rate:
        raise ValueError(f"peak_rate {peak_rate} < base_rate "
                         f"{base_rate}; a flash crowd is a surge")
    if not (base_rate >= 0.0 and math.isfinite(peak_rate)):
        raise ValueError("rates must be finite and >= 0")
    if duration < 0.0:
        raise ValueError(f"duration must be >= 0, got {duration}")
    end = start + duration

    def rate_fn(t):
        t = np.asarray(t)
        return np.where((t >= start) & (t < end), peak_rate, base_rate)

    return InhomogeneousPoisson(rate_fn=rate_fn,
                                rate_max=max(peak_rate, 1e-12))


@dataclass(frozen=True)
class TraceArrivals(ArrivalProcess):
    """Replay a fixed list of arrival timestamps.

    Deterministic and *chunk-independent*: sampling ``[t0, t1)`` simply
    slices the sorted trace, so any partition of a horizon reproduces
    exactly the same arrivals — the property the fleet harness uses to
    cross-check its event and epoch modes against each other.
    """

    times: np.ndarray = field()

    def __init__(self, times: Sequence[float]):
        arr = np.asarray(list(times), dtype=np.float64)
        if arr.ndim != 1:
            raise ValueError(f"trace must be a flat list of timestamps, "
                             f"got shape {arr.shape}")
        if arr.size and not np.isfinite(arr).all():
            raise ValueError("trace contains non-finite timestamps")
        object.__setattr__(self, "times", np.sort(arr))

    def sample(self, rng: np.random.Generator, t0: float,
               t1: float) -> np.ndarray:
        _check_window(t0, t1)
        lo = np.searchsorted(self.times, t0, side="left")
        hi = np.searchsorted(self.times, t1, side="left")
        return self.times[lo:hi].copy()

    def mean_rate(self, t0: float, t1: float) -> float:
        span = _check_window(t0, t1)
        if span == 0.0:
            return 0.0
        return float(self.sample(np.random.default_rng(0), t0,
                                 t1).size / span)


def _trace_time(raw, where: str) -> float:
    try:
        t = float(raw)
    except (TypeError, ValueError):
        raise ValueError(f"{where}: arrival time {raw!r} is not a "
                         f"number") from None
    if not math.isfinite(t) or t < 0.0:
        raise ValueError(f"{where}: arrival time {t!r} must be finite "
                         f"and >= 0")
    return t


def load_trace(path: Union[str, Path], cell: int = 0) -> TraceArrivals:
    """Load one cell's arrival trace from a CSV or JSON file.

    CSV schema: header ``cell,arrival`` (extra columns ignored), one
    row per arrival.  JSON schema: either a flat list of timestamps
    (single-cell traces) or ``{"<cell>": [t, ...], ...}`` keyed by cell
    index.  Malformed rows — missing columns, non-numeric or negative
    times, unknown structure — raise ``ValueError`` naming the file,
    the row, and what was wrong; a loader that silently drops rows
    would corrupt load shapes undetectably.
    """
    p = Path(path)
    if p.suffix.lower() == ".json":
        try:
            doc = json.loads(p.read_text())
        except json.JSONDecodeError as e:
            raise ValueError(f"{p}: not valid JSON ({e})") from None
        if isinstance(doc, list):
            if cell != 0:
                raise ValueError(f"{p}: flat JSON trace has no per-cell "
                                 f"keys but cell={cell} was requested")
            times = [_trace_time(t, f"{p}: entry {i}")
                     for i, t in enumerate(doc)]
        elif isinstance(doc, dict):
            key = str(cell)
            if key not in doc:
                raise ValueError(f"{p}: no trace for cell {cell} "
                                 f"(cells present: "
                                 f"{sorted(doc.keys())})")
            entries = doc[key]
            if not isinstance(entries, list):
                raise ValueError(f"{p}: cell {cell} entry must be a "
                                 f"list of timestamps, got "
                                 f"{type(entries).__name__}")
            times = [_trace_time(t, f"{p}: cell {cell} entry {i}")
                     for i, t in enumerate(entries)]
        else:
            raise ValueError(f"{p}: JSON trace must be a list of times "
                             f"or a cell->times object, got "
                             f"{type(doc).__name__}")
        return TraceArrivals(times)

    with p.open(newline="") as fh:
        reader = csv.DictReader(fh)
        cols = reader.fieldnames or []
        if "cell" not in cols or "arrival" not in cols:
            raise ValueError(f"{p}: CSV trace needs 'cell' and "
                             f"'arrival' columns, found {cols}")
        times: List[float] = []
        for i, row in enumerate(reader, start=2):   # 1 is the header
            raw_cell, raw_t = row.get("cell"), row.get("arrival")
            if raw_cell in (None, "") or raw_t in (None, ""):
                raise ValueError(f"{p}: row {i}: missing cell or "
                                 f"arrival value")
            try:
                row_cell = int(raw_cell)
            except ValueError:
                raise ValueError(f"{p}: row {i}: cell {raw_cell!r} is "
                                 f"not an integer") from None
            if row_cell == cell:
                times.append(_trace_time(raw_t, f"{p}: row {i}"))
    return TraceArrivals(times)


def correlated_rates(rng: np.random.Generator, n_cells: int,
                     base_rate: float,
                     correlation: float = 0.5,
                     spread: float = 0.3) -> np.ndarray:
    """Per-cell Poisson rates with correlated load: one shared
    log-normal factor (weight ``correlation``) plus an independent
    per-cell factor, scaled so every rate stays positive with mean
    ``base_rate``.  ``correlation=0`` gives independent cells,
    ``correlation=1`` moves the whole fleet together — the regime
    where arrival-aware placement matters most.
    """
    if not 0.0 <= correlation <= 1.0:
        raise ValueError(f"correlation must be in [0, 1], got "
                         f"{correlation}")
    if n_cells < 1:
        raise ValueError(f"n_cells must be >= 1, got {n_cells}")
    shared = rng.normal(0.0, spread)
    own = rng.normal(0.0, spread, size=n_cells)
    mix = correlation * shared + (1.0 - correlation) * own
    # exp(mix) has mean exp(var/2); divide it out so E[rate]=base_rate
    var = (correlation ** 2 + (1.0 - correlation) ** 2) * spread ** 2
    return base_rate * np.exp(mix - var / 2.0)
