"""End-to-end service simulator: composes a bandwidth allocation and a
batch-denoising plan into per-service timelines (Fig. 2a) and aggregate
quality (Figs. 2b/2c).

``ServiceOutcome`` is the shared per-service record: the static
``simulate`` below, the event-driven ``repro.core.online`` simulator and
its admission projections all emit it, so figure scripts and admission
policies read one schema."""

from __future__ import annotations

import dataclasses
from typing import List

import numpy as np

from repro.core.bandwidth import make_plan
from repro.core.delay_model import DelayModel
from repro.core.plan import BatchPlan
from repro.core.quality_model import QualityModel
from repro.core.service import Scenario


@dataclasses.dataclass
class ServiceOutcome:
    id: int
    deadline: float
    steps: int
    gen_delay: float          # D_k^cg
    tx_delay: float           # D_k^ct
    e2e_delay: float          # D_k^e2e
    fid: float
    met_deadline: bool


@dataclasses.dataclass
class SimResult:
    outcomes: List[ServiceOutcome]
    mean_fid: float
    outage_rate: float

    def summary(self) -> str:
        lines = [f"{'svc':>4} {'tau':>7} {'steps':>6} {'gen':>8} "
                 f"{'tx':>7} {'e2e':>8} {'fid':>8} ok"]
        for o in self.outcomes:
            lines.append(
                f"{o.id:>4} {o.deadline:7.2f} {o.steps:6d} "
                f"{o.gen_delay:8.3f} {o.tx_delay:7.3f} {o.e2e_delay:8.3f} "
                f"{o.fid:8.2f} {'Y' if o.met_deadline else 'N'}")
        lines.append(f"mean FID {self.mean_fid:.3f}  "
                     f"outage {self.outage_rate:.1%}")
        return "\n".join(lines)


def simulate(scn: Scenario, alloc: np.ndarray, plan: BatchPlan,
             quality: QualityModel) -> SimResult:
    outcomes = []
    for i, s in enumerate(scn.services):
        T = plan.steps_completed.get(s.id, 0)
        gen = plan.completion_time(s.id) if T > 0 else 0.0
        tx = s.tx_delay(alloc[i], scn.content_bits) if T > 0 else 0.0
        e2e = gen + tx
        outcomes.append(ServiceOutcome(
            id=s.id, deadline=s.deadline, steps=T, gen_delay=gen,
            tx_delay=tx, e2e_delay=e2e, fid=quality.fid(T),
            met_deadline=(T > 0 and e2e <= s.deadline + 1e-6)))
    mean_fid = float(np.mean([o.fid for o in outcomes]))
    outage = float(np.mean([0.0 if o.met_deadline else 1.0
                            for o in outcomes]))
    return SimResult(outcomes=outcomes, mean_fid=mean_fid,
                     outage_rate=outage)


def run_scheme(scn: Scenario, scheduler, delay: DelayModel,
               quality: QualityModel, alloc: np.ndarray) -> SimResult:
    _, plan = make_plan(scn, alloc, scheduler, delay, quality)
    return simulate(scn, alloc, plan, quality)
