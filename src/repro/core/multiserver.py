"""Multi-server provisioning: placement x per-cell bandwidth allocation.

The paper provisions ONE edge server: P1 splits one cell's bandwidth,
P2 plans one server's batches.  This module scales the same pipeline
out to M edge cells (``Scenario.servers``, each an ``EdgeServer`` with
its own bandwidth budget, compute speed and capacity):

  placement      assignment[k] = m — which cell hosts service k
                 (strategies live in ``repro.api.placements`` behind
                 the PLACEMENTS registry)
  per-cell P1    each cell's allocator splits *its own* budget across
                 the services placed there
  per-cell P2    each cell's scheduler plans its own batches under the
                 cell's delay model (speed-scaled)

``provision_multi`` is the static composition; ``simulate_online_multi``
replays it event-driven with one ``_ServerTrack`` per server atop the
``repro.core.online`` loop (arrivals route to a server at admission
time and stay there).  With one server both reproduce the existing
single-server ``simulate`` / ``simulate_online`` results exactly
(tests/test_multiserver.py enforces bit-equality).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Callable, Dict, List, Optional, Sequence

import numpy as np

from repro.core import arrays
from repro.core.bandwidth import evaluate, make_plan
from repro.core.delay_model import DelayModel
from repro.core.online import (AdmissionDecision, AdmissionFn, AllocatorFn,
                               OnlineResult, _collect_result, _project,
                               _ServerTrack, _ServiceState)
from repro.core.plan import BatchPlan
from repro.core.quality_model import PowerLawFID, QualityModel
from repro.core.service import EdgeServer, Scenario, ServiceRequest
from repro.core.simulator import ServiceOutcome, SimResult, simulate

# (svc, simulation) -> server index; the per-arrival routing hook of the
# online loop.  Static placements (full-assignment vectors) live in
# repro.api.placements.
OnlinePlacementFn = Callable[..., int]


def split_scenario(scn: Scenario,
                   assignment: Sequence[int]) -> List[Scenario]:
    """One single-cell sub-scenario per server: the services placed on
    it (scenario order preserved) under the cell's own bandwidth budget.

    ``assignment[i]`` is the server index of ``scn.services[i]``.
    Capacity caps are enforced here so every consumer of an assignment
    (static pipeline, placements, tests) shares one check.
    """
    servers = scn.server_list
    assignment = list(assignment)
    assert len(assignment) == scn.K, \
        f"assignment covers {len(assignment)} of {scn.K} services"
    subs = []
    for m, server in enumerate(servers):
        members = [s for s, a in zip(scn.services, assignment) if a == m]
        if server.capacity is not None:
            assert len(members) <= server.capacity, \
                f"server {m} hosts {len(members)} > capacity " \
                f"{server.capacity}"
        subs.append(Scenario(services=members,
                             total_bandwidth_hz=server.bandwidth_hz,
                             content_bits=scn.content_bits))
    bad = [a for a in assignment if not 0 <= a < len(servers)]
    assert not bad, f"assignment references unknown servers {bad}"
    return subs


def cell_objective(sub_scn: Scenario, scheduler, allocator,
                   delay: DelayModel, quality: QualityModel) -> float:
    """Summed FID of one cell under its own allocate -> plan pipeline
    (summed, not mean, so per-cell objectives add up to the system
    objective — what the placement searches compare)."""
    if not sub_scn.services:
        return 0.0
    alloc = np.asarray(allocator(sub_scn, scheduler, delay, quality))
    return evaluate(sub_scn, alloc, scheduler, delay, quality) * sub_scn.K


@dataclasses.dataclass
class ServerPlanReport:
    """One cell's share of a static multi-server round."""
    server: EdgeServer
    scenario: Scenario                 # the cell's sub-scenario
    allocation: np.ndarray             # B_k within the cell's budget
    tau_prime: Dict[int, float]
    plan: BatchPlan
    sim: SimResult


@dataclasses.dataclass
class MultiSimResult:
    """Per-server plans + the merged per-service view (scenario order)."""
    assignment: List[int]              # server index per service
    per_server: List[ServerPlanReport]   # non-empty cells only
    outcomes: List[ServiceOutcome]     # all services, scenario order
    mean_fid: float
    outage_rate: float


def _merge_outcomes(scn: Scenario,
                    per_server: List[ServerPlanReport]
                    ) -> List[ServiceOutcome]:
    by_id = {o.id: o for rep in per_server for o in rep.sim.outcomes}
    return [by_id[s.id] for s in scn.services]


def provision_multi(scn: Scenario, assignment: Sequence[int], scheduler,
                    allocator, delay: Optional[DelayModel] = None,
                    quality: Optional[QualityModel] = None,
                    validate: bool = True,
                    engine: Optional[str] = None) -> MultiSimResult:
    """Static multi-server pipeline: per-cell allocate -> plan ->
    simulate under a given placement.

    ``delay`` is the baseline hardware model; each cell plans with its
    speed-scaled version (``EdgeServer.delay_model``).  With one server
    and the identity assignment this is exactly the single-server
    ``run_scheme`` composition.  ``engine`` pins the planning engine
    for every cell's plan (``repro.core.arrays``; ``None`` = process
    default).
    """
    delay = delay if delay is not None else DelayModel()
    quality = quality if quality is not None else PowerLawFID()
    subs = split_scenario(scn, assignment)
    per_server = []
    with arrays.engine_scope(engine):
        for server, sub in zip(scn.server_list, subs):
            if not sub.services:
                continue
            cell_delay = server.delay_model(delay)
            alloc = np.asarray(allocator(sub, scheduler, cell_delay,
                                         quality))
            tp, plan = make_plan(sub, alloc, scheduler, cell_delay,
                                 quality)
            if validate:
                plan.validate(gen_deadlines=tp)
            per_server.append(ServerPlanReport(
                server=server, scenario=sub, allocation=alloc,
                tau_prime=tp, plan=plan,
                sim=simulate(sub, alloc, plan, quality)))
    outcomes = _merge_outcomes(scn, per_server)
    mean_fid = float(np.mean([o.fid for o in outcomes]))
    outage = float(np.mean([0.0 if o.met_deadline else 1.0
                            for o in outcomes]))
    return MultiSimResult(assignment=list(assignment),
                          per_server=per_server, outcomes=outcomes,
                          mean_fid=mean_fid, outage_rate=outage)


# -- online: one _ServerTrack per cell ------------------------------------

@dataclasses.dataclass
class MultiOnlineResult:
    """OnlineResult plus where every admitted service ran."""
    result: OnlineResult
    assignment: Dict[int, int]         # admitted service id -> server id
    handoffs: int = 0                  # cross-cell migrations performed
    handoff_log: List[tuple] = dataclasses.field(default_factory=list)
    # handoff_log entries: (t, service_id, from_server, to_server)

    @property
    def outcomes(self):
        return self.result.outcomes

    @property
    def mean_fid(self) -> float:
        return self.result.mean_fid

    @property
    def outage_rate(self) -> float:
        return self.result.outage_rate

    @property
    def reject_rate(self) -> float:
        return self.result.reject_rate


def earliest_free(svc: ServiceRequest,
                  sim: "MultiOnlineSimulation") -> int:
    """Default online placement: the server that frees up first among
    those with capacity room (ties by fewest hosted services, then by
    server id, so simultaneous arrivals spread instead of piling onto
    cell 0).  With one server this is the identity routing of the
    single-server loop."""
    candidates = [m for m, tr in enumerate(sim.tracks)
                  if sim.servers[m].has_room(len(tr.owned))]
    if not candidates:   # cluster full: the arrival loop force-rejects
        candidates = list(range(len(sim.tracks)))
    return min(candidates,
               key=lambda m: (sim.tracks[m].t_free,
                              len(sim.tracks[m].owned), m))


def best_projection(svc: ServiceRequest,
                    sim: "MultiOnlineSimulation") -> int:
    """Marginal-gain online placement: trial-replan the newcomer on every
    cell with room and route to the best projected outcome (feasible
    first, then lowest projected FID, then earliest generation end).

    Probe plans are stashed in ``sim`` so the arrival loop reuses the
    chosen cell's trial instead of re-solving it."""
    candidates = [m for m, tr in enumerate(sim.tracks)
                  if sim.servers[m].has_room(len(tr.owned))]
    if not candidates:   # cluster full: the arrival loop force-rejects
        candidates = list(range(len(sim.tracks)))
    best_m, best_key = candidates[0], None
    for m in candidates:
        tr = sim.tracks[m]
        t_free = max(svc.arrival, tr.t_free)
        trial = tr.replan(tr.pending | {svc.id}, t_free)
        tr.replan_count -= 1          # probing, not a real replan
        sim.offer_trial(svc.id, m, trial)
        p = _project(svc, trial, sim.quality, sim.scn.content_bits)
        key = (0 if p.met_deadline else 1, p.fid, p.e2e_delay, m)
        if best_key is None or key < best_key:
            best_m, best_key = m, key
    return best_m


class MultiOnlineSimulation:
    """The ``repro.core.online`` arrival loop over M server tracks.

    Each arrival is routed to one server by ``placement`` (an
    ``OnlinePlacementFn``), trial-replanned *on that server only*, and —
    if admitted — pinned there for life: batches execute on its cell's
    speed-scaled delay model and its content transmits over the cell's
    own bandwidth.  Other cells keep running untouched, which is what
    makes M cells an M-fold throughput axis.
    """

    def __init__(self, scn: Scenario, scheduler, allocator: AllocatorFn,
                 delay: DelayModel, quality: QualityModel,
                 admission: AdmissionFn,
                 placement: Optional[OnlinePlacementFn] = None,
                 handoff: bool = False, validate: bool = True):
        self.scn = scn
        self.quality = quality
        self.admission = admission
        self.placement = placement if placement is not None else \
            earliest_free
        self.handoff = handoff
        self.handoff_count = 0
        self.handoff_log: List[tuple] = []
        self.servers = scn.server_list
        self.states: Dict[int, _ServiceState] = {
            s.id: _ServiceState(s) for s in scn.services}
        self.tracks = [
            _ServerTrack(scn, sv.bandwidth_hz, scheduler, allocator,
                         sv.delay_model(delay), quality, self.states,
                         validate=validate)
            for sv in self.servers
        ]
        self.decisions: List[AdmissionDecision] = []
        self.assignment: Dict[int, int] = {}
        self._probed: Dict[tuple, object] = {}   # (svc_id, m) -> trial plan

    @property
    def replan_count(self) -> int:
        return sum(tr.replan_count for tr in self.tracks)

    def server_of(self, svc_id: int) -> Optional[int]:
        return self.assignment.get(svc_id)

    def offer_trial(self, svc_id: int, m: int, trial) -> None:
        """A placement that already trial-replanned ``svc`` on cell
        ``m`` (e.g. ``best_projection``) deposits the plan here; the
        arrival loop reuses it instead of re-solving.  Valid only
        within the current arrival (the loop clears the stash)."""
        self._probed[(svc_id, m)] = trial

    def _force_reject(self, svc: ServiceRequest) -> None:
        """Capacity is a hard constraint: an arrival routed to a full
        cell is rejected before any trial replan (the projected outcome
        is the zero-step outage row the admission policy would see)."""
        projected = ServiceOutcome(
            id=svc.id, deadline=svc.deadline, steps=0, gen_delay=0.0,
            tx_delay=0.0, e2e_delay=0.0, fid=self.quality.fid(0),
            met_deadline=False)
        self.states[svc.id].admitted = False
        self.decisions.append(AdmissionDecision(
            id=svc.id, arrival=svc.arrival, admitted=False,
            projected=projected))

    # -- cross-cell handoff ----------------------------------------------

    def _handoff_pass(self, t_now: float) -> None:
        """Migrate pending not-yet-started services to a better cell.

        Runs at each replan instant (after an arrival is processed).  A
        service that was admitted but has executed zero denoising steps
        is not bound to its cell by any progress, so it may move: every
        other cell with room trial-replans with the service included,
        and the service migrates to the best strictly better projected
        outcome (feasibility first, then FID, then generation end —
        the ``best_projection`` ordering).  Ties never move, so the
        pass cannot ping-pong; services with executed steps never move,
        so progress is never re-run (the no-resurrection invariant
        holds per track).  With one cell this is a no-op, preserving
        the single-server bit-exactness invariant.
        """
        candidates = sorted(
            k for tr in self.tracks for k in tr.pending
            if self.states[k].steps_done == 0)
        for k in candidates:
            src = self.assignment.get(k)
            if src is None:
                continue
            s_tr = self.tracks[src]
            svc = self.states[k].svc
            cur = _project(svc, s_tr.active, self.quality,
                           self.scn.content_bits)
            cur_key = (0 if cur.met_deadline else 1, cur.fid,
                       cur.e2e_delay)
            best = None
            for m, tr in enumerate(self.tracks):
                if m == src or not self.servers[m].has_room(
                        len(tr.owned)):
                    continue
                t_free = max(t_now, tr.t_free)
                trial = tr.replan(tr.pending | {k}, t_free)
                tr.replan_count -= 1          # probing, not a replan yet
                p = _project(svc, trial, self.quality,
                             self.scn.content_bits)
                key = (0 if p.met_deadline else 1, p.fid, p.e2e_delay, m)
                if key[:3] < cur_key and (best is None or key < best[0]):
                    best = (key, m, trial)
            if best is not None:
                self._migrate(k, src, best[1], best[2], t_now)

    def _migrate(self, k: int, src: int, dst: int, trial,
                 t_now: float) -> None:
        """Move service ``k`` (no executed steps) from cell ``src`` to
        ``dst``: the source replans without it, the destination adopts
        the trial plan that included it."""
        s_tr, d_tr = self.tracks[src], self.tracks[dst]
        s_tr.pending.discard(k)
        s_tr.owned.discard(k)
        remaining = set(s_tr.pending)
        if remaining:
            s_tr.active = s_tr.replan(remaining,
                                      max(t_now, s_tr.t_free))
            s_tr._settle_no_step_services(s_tr.active)
        else:
            s_tr.active = None
        d_tr.replan_count += 1                # the probe became real
        d_tr.adopt(k, trial)
        self.assignment[k] = dst
        self.handoff_count += 1
        self.handoff_log.append((t_now, k, src, dst))

    def run(self) -> MultiOnlineResult:
        for svc in sorted(self.scn.services,
                          key=lambda s: (s.arrival, s.id)):
            for tr in self.tracks:
                tr.execute_until(svc.arrival)
            m = int(self.placement(svc, self))
            tr = self.tracks[m]
            if not self.servers[m].has_room(len(tr.owned)):
                # enforced here, not just in the built-in routers, so a
                # custom placement can never oversubscribe a cell — the
                # online mirror of split_scenario's capacity assert
                self._probed.clear()
                self._force_reject(svc)
                continue
            t_free = max(svc.arrival, tr.t_free)
            trial = self._probed.get((svc.id, m))
            if trial is not None:
                tr.replan_count += 1   # the probe becomes the real replan
            else:
                trial = tr.replan(tr.pending | {svc.id}, t_free)
            self._probed.clear()
            projected = _project(svc, trial, self.quality,
                                 self.scn.content_bits)
            admit = bool(self.admission(svc, projected, self.states))
            self.states[svc.id].admitted = admit
            self.decisions.append(AdmissionDecision(
                id=svc.id, arrival=svc.arrival, admitted=admit,
                projected=projected))
            if admit:
                tr.adopt(svc.id, trial)
                self.assignment[svc.id] = m
            # on reject every track's plan keeps running untouched
            if self.handoff and len(self.tracks) > 1:
                self._handoff_pass(svc.arrival)
        for tr in self.tracks:
            tr.execute_until(math.inf)
        result = _collect_result(self.scn, self.states, self.decisions,
                                 self.quality)
        return MultiOnlineResult(result=result,
                                 assignment=dict(self.assignment),
                                 handoffs=self.handoff_count,
                                 handoff_log=list(self.handoff_log))


def simulate_online_multi(scn: Scenario, scheduler,
                          allocator: AllocatorFn,
                          delay: Optional[DelayModel] = None,
                          quality: Optional[QualityModel] = None,
                          admission: Optional[AdmissionFn] = None,
                          placement: Optional[OnlinePlacementFn] = None,
                          handoff: bool = False,
                          validate: bool = True,
                          engine: Optional[str] = None
                          ) -> MultiOnlineResult:
    """Event-driven arrivals over M edge cells (module docstring).

    ``placement`` routes each arrival to a server (default
    ``earliest_free``; ``best_projection`` trial-replans everywhere).
    ``handoff=True`` additionally runs a cross-cell handoff pass at
    every replan instant: pending services with no executed steps may
    migrate to a cell whose trial replan projects a strictly better
    outcome (``MultiOnlineResult.handoffs`` counts the moves).  With
    ``scn.n_servers == 1`` any placement (and the handoff pass, which
    has no other cell to probe) degenerates to the single-server
    ``simulate_online`` path bit-for-bit.  ``engine`` pins the
    planning engine for every track's replans (``repro.core.arrays``;
    ``None`` = process default).
    """
    if admission is None:
        admission = lambda svc, projected, states: True   # noqa: E731
    sim = MultiOnlineSimulation(
        scn, scheduler, allocator,
        delay if delay is not None else DelayModel(),
        quality if quality is not None else PowerLawFID(),
        admission, placement=placement, handoff=handoff,
        validate=validate)
    with arrays.engine_scope(engine):
        return sim.run()
