"""Array-native planning engine: the vectorized STACKING core.

Every planning hot path in this repo reduces to two inner sweeps:

  * the *clustered* sweep — Algorithm 1's clustering/packing/batching
    rounds for one auxiliary target T* (``stacking_pass``), optionally
    offset-shifted into total-step space (``repro.core.offset``);
  * the *lockstep* sweep — every still-short service joins every batch
    until it reaches a per-service target (``equal_steps`` and
    ``offset_pass`` are both instances).

The scalar reference implementations walk dict-keyed services in
while-loops, and the outer searches re-run them once per T* /
water-level candidate.  This module keeps a scenario's per-service
state (``tau_prime``, offsets, completed counts, active mask) in
contiguous NumPy arrays with an id<->index mapping (``ServiceArrays``)
and turns both sweeps into masked array kernels batched over ALL
candidate levels at once: state is ``(L, K)`` for L candidate levels x
K services, one python-level loop iteration per batch *round* (shared
by every candidate) instead of one per (candidate, round, service).
``Te``/``Tp`` tables, the priority-cluster split, the packing caps and
the unaffordable-member drop loop are all computed as whole-array ops.

Bit-identical by construction: the kernels perform the same float64
operations in the same order as the scalar loops (one subtraction per
wall-clock advance, the same 1e-12 epsilons, the same
(Tp, tau', id) sort keys), so plans — batches, start times,
``steps_completed``, objective — match the reference exactly;
``tests/test_arrays.py`` and the hypothesis suite enforce it across
the static, online, offset and multi-server entry points.

Engine selection: the consumers (``stacking``, ``equal_steps``,
``StackingOffset``, and the online/multi-server pipelines) dispatch on
the process-wide engine, ``"vec"`` by default.

    from repro.core import arrays
    arrays.set_engine("scalar")          # global: reference path
    with arrays.engine_scope("vec"):     # scoped override
        ...

or per call via their ``engine=`` parameter; the ``REPRO_PLANNER_ENGINE``
environment variable sets the process default.  The scalar path stays
the ground truth the vec engine is tested against (and what
``benchmarks/planner_speed.py`` measures the speedup over).

Plain NumPy on purpose: the state layout (flat arrays + masks, no
dicts) is exactly what a future jit/vmap backend needs — swapping
``np`` for ``jnp`` over fixed-shape ``(L, K)`` state is the intended
next step, not a rewrite.
"""

from __future__ import annotations

import contextlib
import dataclasses
import importlib
import os
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.delay_model import DelayModel
from repro.core.plan import BatchPlan

# int64 sentinel pushing inactive services past every real Tp in the
# (Tp, tau', id) lexsort; far below int64 overflow when summed with keys
_TP_INF = np.int64(1) << 62


# -------------------------------------------------------------------------
# Engine registry
# -------------------------------------------------------------------------
#
# One extensible name -> implementation map shared by ``set_engine``,
# ``resolve_engine`` and the ``REPRO_PLANNER_ENGINE`` guard.  The two
# built-in engines ("vec", "scalar") register ``None`` — their dispatch
# lives inline in the consumers — while optional backends register an
# implementation object whose attributes (``stacking``, ``equal_steps``,
# ``offset_plan``, ``optimal_plan``) the consumers call instead
# (``repro.core.jaxplan`` registers the "jax" engine this way).

_ENGINE_IMPLS: Dict[str, Optional[Any]] = {}
_BACKENDS_PROBED = False
_BACKEND_ERRORS: Dict[str, str] = {}
# optional backend modules probed on first unknown-engine lookup, so
# ``REPRO_PLANNER_ENGINE=jax`` (or ``set_engine("jax")``) works without
# anyone importing the backend first — and without paying its import
# cost when nobody asks for it
_OPTIONAL_BACKENDS = {"jax": "repro.core.jaxplan"}


def register_engine(name: str, impl: Optional[Any] = None) -> None:
    """Register a planner engine.  ``impl`` is ``None`` for the
    built-in engines (dispatched inline by the consumers) or a backend
    namespace providing ``stacking`` / ``equal_steps`` /
    ``offset_plan`` / ``optimal_plan`` entry points."""
    if name in _ENGINE_IMPLS and _ENGINE_IMPLS[name] is not impl:
        raise ValueError(f"planner engine {name!r} is already registered")
    _ENGINE_IMPLS[name] = impl


register_engine("vec")
register_engine("scalar")


def registered_engines() -> Tuple[str, ...]:
    """The currently registered engine names, sorted.  Optional
    backends appear once imported (or once first requested by name)."""
    return tuple(sorted(_ENGINE_IMPLS))


def _probe_backends() -> None:
    """Import the optional backend modules once so they can register
    their engines; a backend whose dependency is missing records the
    reason for the error message instead of failing the probe."""
    global _BACKENDS_PROBED
    if _BACKENDS_PROBED:
        return
    _BACKENDS_PROBED = True
    for eng, module in _OPTIONAL_BACKENDS.items():
        try:
            importlib.import_module(module)
        except ImportError as e:      # dependency absent: engine stays
            _BACKEND_ERRORS[eng] = str(e)   # unregistered, reason kept


def _require_engine(name: str) -> str:
    """Validate an engine name against the registry (probing optional
    backends on a miss), raising with the dynamic engine list."""
    if name not in _ENGINE_IMPLS:
        _probe_backends()
    if name not in _ENGINE_IMPLS:
        hint = (f" (backend unavailable: {_BACKEND_ERRORS[name]})"
                if name in _BACKEND_ERRORS else "")
        raise ValueError(
            f"unknown planner engine {name!r}; registered engines: "
            f"{', '.join(registered_engines())}{hint}")
    return name


def engine_impl(name: str) -> Optional[Any]:
    """The backend implementation registered for ``name`` (``None``
    for the built-in vec/scalar engines)."""
    return _ENGINE_IMPLS[_require_engine(name)]


def get_engine() -> str:
    """The process-wide planning engine ("vec" by default)."""
    return _ENGINE


def set_engine(name: str) -> None:
    """Select the process-wide planning engine."""
    global _ENGINE
    _ENGINE = _require_engine(name)


@contextlib.contextmanager
def engine_scope(name: Optional[str]):
    """Temporarily select an engine (``None`` = leave as-is).  The
    online/multi-server pipelines use this to honour their ``engine=``
    parameter around a whole event-driven run."""
    if name is None:
        yield
        return
    prev = get_engine()
    set_engine(name)
    try:
        yield
    finally:
        set_engine(prev)


def resolve_engine(engine: Optional[str]) -> str:
    """An explicit ``engine=`` argument, or the process default."""
    if engine is None:
        return get_engine()
    return _require_engine(engine)


# -------------------------------------------------------------------------
# Per-service state as contiguous arrays
# -------------------------------------------------------------------------

@dataclasses.dataclass
class ServiceArrays:
    """A scenario's per-service planning state in contiguous arrays,
    rows in the given service order (the ``make_plan`` convention every
    objective evaluation relies on)."""

    ids: np.ndarray          # (K,) int64 service ids
    tau_prime: np.ndarray    # (K,) float64 generation budgets
    offsets: np.ndarray      # (K,) int64 steps already executed
    index: Dict[int, int]    # id -> row

    @property
    def K(self) -> int:
        return int(self.ids.size)

    @classmethod
    def build(cls, service_ids: Sequence[int],
              tau_prime: Dict[int, float],
              offsets: Optional[Dict[int, int]] = None) -> "ServiceArrays":
        ids = np.asarray([int(k) for k in service_ids], dtype=np.int64)
        taup = np.asarray([float(tau_prime[int(k)]) for k in ids],
                          dtype=np.float64)
        if offsets:
            off = np.asarray([int(offsets.get(int(k), 0)) for k in ids],
                             dtype=np.int64)
        else:
            off = np.zeros(ids.size, dtype=np.int64)
        return cls(ids=ids, tau_prime=taup, offsets=off,
                   index={int(k): i for i, k in enumerate(ids)})


# -------------------------------------------------------------------------
# Kernels: (L, K) state, one python iteration per batch round
# -------------------------------------------------------------------------

def _clustered_rounds(ids: np.ndarray, taup0: np.ndarray, off: np.ndarray,
                      delay: DelayModel, levels: np.ndarray,
                      record: bool = False,
                      history: Optional[list] = None):
    """The Algorithm-1 clustering/packing/batching rounds, batched over
    candidate levels: row l plans against T* = ``levels[l]``.

    Returns ``(Tc, makespan, batches, start_times)`` — ``Tc`` is
    ``(L, K)`` completed counts, ``makespan`` ``(L,)``.  ``record=True``
    (single level only) additionally materializes the batch list in the
    scalar pass's exact order (sorted-cluster sequence).  ``history``
    (a caller-owned list) collects per-round ``(order, packed, x_n,
    has_batch)`` snapshots so the outer searches can replay ANY row's
    batch list afterwards (``_replay_clustered``) without re-running a
    pass for the winning candidate.
    """
    a, b = delay.a, delay.b
    levels = np.asarray(levels, dtype=np.int64)
    L, K = levels.size, taup0.size
    assert not record or L == 1, "batch recording needs a single level"
    g1 = delay.min_task_delay()          # == a * 1 + b
    step_cost = a + b                    # T^e divisor (size-1 batches)
    taup0 = np.asarray(taup0, dtype=np.float64)

    # The round-invariant tie-break: every committed batch subtracts the
    # SAME g from every active service of a row (Eq. 15) and inactive
    # services never re-activate, so pairwise tau' differences among
    # active services equal their initial differences — the (tau', id)
    # order the scalar sort breaks Tp ties with never changes.  Encoding
    # it once as an integer rank turns the per-round 3-key lexsort into
    # a values-only sort of ONE composite integer key,
    #     key = Tp * M + tie_rank        (unique per service),
    # whose x_n-th smallest value is a membership threshold.
    tie = np.empty(K, dtype=np.int64)
    tie[np.lexsort((ids, taup0))] = np.arange(K, dtype=np.int64)
    shift = int(max(K, 1).bit_length())
    M = np.int64(1) << shift

    taup = np.tile(taup0, (L, 1))
    Tc = np.zeros((L, K), dtype=np.int64)
    active = np.tile(taup0 >= g1, (L, 1))
    t = np.zeros(L, dtype=np.float64)
    off2 = off[None, :]
    # level-constant packing terms, hoisted out of the round loop (the
    # divisor clamp only changes masked-out values for levels <= 0)
    lv_pos = levels > 0
    b_lv = b * levels.astype(np.float64)
    a_lv = a * np.maximum(levels.astype(np.float64), 1.0)
    # the F threshold in key space: key <= lv*M + (M-1)  <=>  Tp <= lv.
    # Tp is bounded by off + 2*T^e0 + slack (T_c can't outgrow the
    # dedicated-batch bound), so clamping huge direct-call levels there
    # changes nothing and keeps the int64 key far from overflow
    te0_max = int(np.max(np.maximum(taup0, 0.0)) / step_cost) \
        if K else 0
    tp_bound = int(off.max() if K else 0) + 2 * te0_max + 4
    assert (tp_bound + 2) * int(M) < int(_TP_INF), "key space overflow"
    F_thr = np.where(levels >= 0,
                     np.minimum(levels, tp_bound) * M + (M - 1),
                     np.int64(-1))
    batches: List[List[Tuple[int, int]]] = []
    starts: List[float] = []

    while active.any():
        # ---- clustering (Eqs. 15-18, offset-shifted) ---------------------
        # T^e: tasks completable in the remaining budget on dedicated
        # batches — int() truncation == floor for the (positive) budgets
        # of live services; inactive entries compute garbage that every
        # consumer below masks out through the key sentinel
        Te = (taup / step_cost).astype(np.int64)
        Tp = off2 + Tc + Te
        key = np.where(active, Tp * M + tie, _TP_INF)

        n_active = active.sum(axis=-1)
        F = key <= F_thr[:, None]
        n_F = F.sum(axis=-1)

        # ---- packing (Eqs. 19-20) ----------------------------------------
        te_max = np.max(np.where(F, Te, -1), axis=-1)
        tau_min = np.min(np.where(F, taup, np.inf), axis=-1)
        cap_f = np.floor((tau_min - b * te_max)
                         / (a * np.maximum(te_max, 1)))
        tp_min = key.min(axis=-1) >> shift       # min Tp over active
        cap_nf = np.floor((step_cost * tp_min - b_lv) / a_lv)
        x_f = np.where(te_max > 0,
                       np.maximum(n_F, np.minimum(n_active, cap_f)),
                       n_F)
        # no-priority-cluster branch: F empty forces min Tp > T*, so
        # cap >= 1 whenever the level >= 1; the explicit clamp states
        # that invariant at the site (mirrors stacking_pass — the
        # generic max(1, ...) below would absorb a negative cap
        # identically, but without the branch's reasoning)
        x_nf = np.minimum(n_active,
                          np.where(lv_pos, np.maximum(1, cap_nf),
                                   n_active))
        x_n = np.where(n_F > 0, x_f, x_nf)
        x_n = np.maximum(1, np.minimum(x_n, n_active))
        x_n = np.where(n_active > 0, x_n, 0).astype(np.int64)

        # ---- batching -----------------------------------------------------
        # the x_n cheapest (Tp, tau', id) services per row == every key
        # at or below the x_n-th smallest (keys are unique; x_n never
        # exceeds n_active and inactive keys sit at the sentinel, so the
        # selection is all-active by construction)
        sorted_key = np.sort(key, axis=-1)
        thr = np.take_along_axis(sorted_key,
                                 np.maximum(x_n - 1, 0)[:, None],
                                 axis=-1)[:, 0]
        thr = np.where(x_n > 0, thr, np.int64(-1))
        packed = key <= thr[:, None]
        n_packed = x_n.copy()
        while True:
            g = a * n_packed + b
            drop = packed & (taup + 1e-12 < g[:, None])
            if not drop.any():
                break
            packed &= ~drop                 # cannot afford this batch ->
            active &= ~drop                 # service is finished
            n_packed = packed.sum(axis=-1)

        has_batch = n_packed > 0
        g = a * n_packed + b
        if record and has_batch[0]:
            idx = np.flatnonzero(packed[0])
            members = idx[np.argsort(key[0, idx])]
            batches.append([(int(ids[j]), int(Tc[0, j]))
                            for j in members])
            starts.append(float(t[0]))
        if history is not None:
            history.append((key, packed, has_batch))
        np.add(t, g, out=t, where=has_batch)
        adv = active & has_batch[:, None]    # wall clock advances for all
        np.subtract(taup, g[:, None], out=taup, where=adv)     # (Eq. 15)
        Tc += packed
        # services that can no longer fit even a dedicated batch are done
        active &= taup + 1e-12 >= g1

    return Tc, t, batches, starts


def _lockstep_rounds(ids: np.ndarray, taup0: np.ndarray,
                     targets: np.ndarray, delay: DelayModel,
                     record: bool = False,
                     history: Optional[list] = None):
    """The lockstep sweep (``offset_pass`` / ``equal_steps`` inner
    loop), batched over per-row target vectors: every service still
    short of ``targets[l, k]`` additional steps joins every batch of
    row l, unaffordable members dropping out with the steps they have.

    Same return convention as ``_clustered_rounds``; recorded batches
    list members in service order, as the scalar loops do; ``history``
    collects ``(active, has_batch)`` snapshots for ``_replay_lockstep``.
    """
    a, b = delay.a, delay.b
    targets = np.asarray(targets, dtype=np.int64)
    L, K = targets.shape
    assert not record or L == 1, "batch recording needs a single target row"
    g1 = delay.min_task_delay()

    taup = np.tile(np.asarray(taup0, dtype=np.float64), (L, 1))
    Tc = np.zeros((L, K), dtype=np.int64)
    active = (targets > 0) & (taup0 >= g1)[None, :]
    t = np.zeros(L, dtype=np.float64)
    batches: List[List[Tuple[int, int]]] = []
    starts: List[float] = []

    while active.any():
        # drop members that cannot afford the current shared batch
        n = active.sum(axis=-1)
        while True:
            g = a * n + b
            drop = active & (taup + 1e-12 < g[:, None])
            if not drop.any():
                break
            active &= ~drop
            n = active.sum(axis=-1)
        has_batch = n > 0
        g = a * n + b
        if record and has_batch[0]:
            members = np.flatnonzero(active[0])
            batches.append([(int(ids[j]), int(Tc[0, j]))
                            for j in members])
            starts.append(float(t[0]))
        if history is not None:
            history.append((active.copy(), has_batch))
        np.add(t, g, out=t, where=has_batch)
        np.subtract(taup, g[:, None], out=taup, where=active)
        Tc += active
        active &= (Tc < targets) & (taup + 1e-12 >= g1)

    return Tc, t, batches, starts


def _replay_clustered(ids: np.ndarray, w: int, history: list,
                      delay: DelayModel):
    """Reconstruct row ``w``'s batch list from a clustered sweep's
    per-round snapshots — the same (batches, start_times) the scalar
    pass records, without re-running the pass."""
    a, b = delay.a, delay.b
    Tc = np.zeros(ids.size, dtype=np.int64)
    batches: List[List[Tuple[int, int]]] = []
    starts: List[float] = []
    t = 0.0
    for key, packed, has_batch in history:
        if not has_batch[w]:
            continue
        idx = np.flatnonzero(packed[w])
        members = idx[np.argsort(key[w, idx])]
        batches.append([(int(ids[j]), int(Tc[j])) for j in members])
        starts.append(t)
        t += a * len(members) + b
        Tc[packed[w]] += 1
    return batches, starts


def _replay_lockstep(ids: np.ndarray, w: int, history: list,
                     delay: DelayModel):
    """Reconstruct row ``w``'s batch list from a lockstep sweep's
    per-round snapshots (members in service order, as the scalar
    loops record)."""
    a, b = delay.a, delay.b
    Tc = np.zeros(ids.size, dtype=np.int64)
    batches: List[List[Tuple[int, int]]] = []
    starts: List[float] = []
    t = 0.0
    for active, has_batch in history:
        if not has_batch[w]:
            continue
        members = np.flatnonzero(active[w])
        batches.append([(int(ids[j]), int(Tc[j])) for j in members])
        starts.append(t)
        t += a * len(members) + b
        Tc[active[w]] += 1
    return batches, starts


def score_rows(rows: np.ndarray, quality) -> np.ndarray:
    """``quality.mean_fid`` of every row of a ``(L, K)`` count matrix,
    evaluated through the exact scalar call (vectorizing the quality
    model itself is off the table: SIMD ``pow`` differs from libm in
    the last ulp) but with duplicate rows — very common across
    neighbouring T* levels — scored once."""
    uniq, inverse = np.unique(np.asarray(rows), axis=0,
                              return_inverse=True)
    qs = np.empty(uniq.shape[0], dtype=np.float64)
    for u, counts in enumerate(uniq.tolist()):
        qs[u] = quality.mean_fid(counts)
    return qs[inverse.ravel()]


def first_best(rows: np.ndarray, quality) -> Tuple[int, float]:
    """The scalar outer searches' selection rule — the FIRST candidate
    strictly better (by 1e-12) than everything before it — over the
    rows of a ``(L, K)`` count matrix."""
    best_i, best_q = -1, float("inf")
    for i, q in enumerate(score_rows(rows, quality).tolist()):
        if q < best_q - 1e-12:
            best_i, best_q = i, q
    return best_i, best_q


# -------------------------------------------------------------------------
# Batched sweeps (scoring) and single-candidate passes (materialization)
# -------------------------------------------------------------------------

def sweep_clustered(arr: ServiceArrays, delay: DelayModel,
                    levels: Sequence[int]
                    ) -> Tuple[np.ndarray, np.ndarray]:
    """Completed counts + makespan of the Algorithm-1 pass for every
    candidate level at once: ``(Tc (L, K), makespan (L,))``.  Row l
    equals ``stacking_pass(..., t_star=levels[l], offsets=...)``'s
    ``steps_completed`` / ``makespan()`` exactly."""
    Tc, t, _, _ = _clustered_rounds(arr.ids, arr.tau_prime, arr.offsets,
                                    delay, np.asarray(levels))
    return Tc, t


def sweep_lockstep(arr: ServiceArrays, delay: DelayModel,
                   targets: np.ndarray
                   ) -> Tuple[np.ndarray, np.ndarray]:
    """Completed counts + makespan of the lockstep pass for every
    target row at once (``targets`` is ``(L, K)`` *additional*-step
    targets aligned with ``arr`` rows)."""
    Tc, t, _, _ = _lockstep_rounds(arr.ids, arr.tau_prime,
                                   np.asarray(targets), delay)
    return Tc, t


def stacking_pass_vec(service_ids: Sequence[int],
                      tau_prime: Dict[int, float], delay: DelayModel,
                      t_star: int,
                      offsets: Optional[Dict[int, int]] = None
                      ) -> BatchPlan:
    """Drop-in vectorized ``stacking_pass``: one clustering-packing-
    batching sweep for a fixed T*, bit-identical to the scalar
    reference (same batches, same start times, same counts)."""
    arr = ServiceArrays.build(service_ids, tau_prime, offsets)
    Tc, _, batches, starts = _clustered_rounds(
        arr.ids, arr.tau_prime, arr.offsets, delay,
        np.asarray([t_star]), record=True)
    steps = {int(k): int(c) for k, c in zip(arr.ids, Tc[0])}
    return BatchPlan(batches=batches, start_times=starts,
                     steps_completed=steps, delay=delay)


def offset_pass_vec(service_ids: Sequence[int],
                    tau_prime: Dict[int, float], delay: DelayModel,
                    targets: Dict[int, int]) -> BatchPlan:
    """Drop-in vectorized ``repro.core.offset.offset_pass``: one
    lockstep sweep toward per-service additional-step targets."""
    arr = ServiceArrays.build(service_ids, tau_prime)
    tgt = np.asarray([[int(targets.get(int(k), 0)) for k in arr.ids]],
                     dtype=np.int64)
    Tc, _, batches, starts = _lockstep_rounds(arr.ids, arr.tau_prime,
                                              tgt, delay, record=True)
    steps = {int(k): int(c) for k, c in zip(arr.ids, Tc[0])}
    return BatchPlan(batches=batches, start_times=starts,
                     steps_completed=steps, delay=delay)


def stacking_vec(services, tau_prime: Dict[int, float], delay: DelayModel,
                 quality, t_star_max: int = 0) -> BatchPlan:
    """Algorithm 1 with the outer T* search as one batched sweep: all
    candidate levels share the per-round ``Te``/``Tp`` tables and
    advance together, then the first strictly-best level (the scalar
    search's tie rule) is materialized as the returned plan."""
    ids = [s.id for s in services]
    if t_star_max <= 0:
        t_star_max = max(1, max(delay.max_steps(tau_prime[k])
                                for k in ids))
    arr = ServiceArrays.build(ids, tau_prime)
    levels = np.arange(1, t_star_max + 1, dtype=np.int64)
    hist: list = []
    Tc, _, _, _ = _clustered_rounds(arr.ids, arr.tau_prime, arr.offsets,
                                    delay, levels, history=hist)

    best_i, _ = first_best(Tc, quality)
    assert best_i >= 0
    batches, starts = _replay_clustered(arr.ids, best_i, hist, delay)
    steps = {int(k): int(c) for k, c in zip(arr.ids, Tc[best_i])}
    return BatchPlan(batches=batches, start_times=starts,
                     steps_completed=steps, delay=delay)


def equal_steps_vec(services, tau_prime: Dict[int, float],
                    delay: DelayModel, quality) -> BatchPlan:
    """The balanced ``equal_steps`` baseline with its shared-target
    search as one batched lockstep sweep (row l targets T* = l + 1
    steps for every service), first strictly-best level materialized."""
    ids = [s.id for s in services]
    feasible = [k for k in ids if delay.max_steps(tau_prime[k]) > 0]
    t_max = max([delay.max_steps(tau_prime[k]) for k in feasible],
                default=1)
    arr = ServiceArrays.build(ids, tau_prime)
    levels = np.arange(1, max(1, t_max) + 1, dtype=np.int64)
    targets = np.broadcast_to(levels[:, None],
                              (levels.size, arr.K)).copy()
    hist: list = []
    Tc, _, _, _ = _lockstep_rounds(arr.ids, arr.tau_prime, targets,
                                   delay, history=hist)

    best_i, _ = first_best(Tc, quality)
    assert best_i >= 0
    batches, starts = _replay_lockstep(arr.ids, best_i, hist, delay)
    steps = {int(k): int(c) for k, c in zip(arr.ids, Tc[best_i])}
    return BatchPlan(batches=batches, start_times=starts,
                     steps_completed=steps, delay=delay)


# The process default, validated last so an optional backend named by
# the env var can be probed (and can import this partially-initialized
# module) with every definition above already bound.  A typo'd env var
# still fails loudly, at import time, listing the registered engines.
_ENGINE = _require_engine(os.environ.get("REPRO_PLANNER_ENGINE", "vec"))
