"""Bandwidth allocation across AIGC services — problem (P1).

Paper's method: particle swarm optimization (PSO [13]) over the bandwidth
simplex; each particle's fitness evaluates Q*(B_1..B_K) by running the
inner batch-denoising solver (STACKING) on the induced generation budgets
tau'_k = tau_k - S/(B_k eta_k).

Beyond-paper additions (DESIGN.md §7):
  * ``equal_allocate``       — the equal-split baseline from Sec. IV.
  * ``inv_se_allocate``      — closed-form equal-transmission-delay split
                               (B_k proportional to 1/eta_k): maximizes the
                               minimum generation budget; a strong, free
                               initialization for PSO.
  * ``coordinate_refine``    — deterministic pairwise transfer hill-climb,
                               cheaper and typically >= PSO quality.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict

import numpy as np

from repro.core.delay_model import DelayModel
from repro.core.quality_model import QualityModel
from repro.core.service import Scenario

# A scheduler takes (services, tau_prime, delay, quality) -> BatchPlan.
SchedulerFn = Callable[..., "BatchPlan"]


def tau_prime_of(scn: Scenario, alloc: np.ndarray) -> Dict[int, float]:
    return {
        s.id: s.deadline - s.tx_delay(alloc[i], scn.content_bits)
        for i, s in enumerate(scn.services)
    }


def make_plan(scn: Scenario, alloc: np.ndarray, scheduler: SchedulerFn,
              delay: DelayModel, quality: QualityModel):
    """Shared P1->P2 composition: generation budgets under an allocation,
    then the scheduler's batch plan.  Both ``evaluate`` (PSO fitness) and
    ``simulator.run_scheme`` route through here.

    Returns ``(tau_prime, plan)``.
    """
    tp = tau_prime_of(scn, alloc)
    return tp, scheduler(scn.services, tp, delay, quality)


def evaluate(scn: Scenario, alloc: np.ndarray, scheduler: SchedulerFn,
             delay: DelayModel, quality: QualityModel) -> float:
    """Mean FID achieved under a bandwidth allocation (lower = better)."""
    _, plan = make_plan(scn, alloc, scheduler, delay, quality)
    return quality.mean_fid(
        [plan.steps_completed[s.id] for s in scn.services])


def equal_allocate(scn: Scenario) -> np.ndarray:
    return np.full(scn.K, scn.total_bandwidth_hz / scn.K)


def inv_se_allocate(scn: Scenario) -> np.ndarray:
    """Equal transmission delay: B_k proportional to 1/eta_k."""
    inv = np.array([1.0 / s.spectral_eff for s in scn.services])
    return scn.total_bandwidth_hz * inv / inv.sum()


@dataclasses.dataclass
class PSOResult:
    alloc: np.ndarray
    fid: float
    history: list


def pso_allocate(scn: Scenario, scheduler: SchedulerFn, delay: DelayModel,
                 quality: QualityModel, *, num_particles: int = 24,
                 iters: int = 40, w: float = 0.72, c1: float = 1.5,
                 c2: float = 1.5, seed: int = 0,
                 min_frac: float = 1e-3) -> PSOResult:
    """PSO on the bandwidth simplex (the paper's Sec. III-C solver)."""
    rng = np.random.default_rng(seed)
    K, B = scn.K, scn.total_bandwidth_hz

    def project(x):
        x = np.clip(x, min_frac * B, None)
        return x * (B / x.sum())

    # seed the swarm with the two closed-form allocations + random simplex
    pts = [equal_allocate(scn), inv_se_allocate(scn)]
    while len(pts) < num_particles:
        pts.append(project(rng.dirichlet(np.ones(K)) * B))
    X = np.stack(pts)
    V = np.zeros_like(X)

    fit = np.array([evaluate(scn, x, scheduler, delay, quality) for x in X])
    pbest, pbest_fit = X.copy(), fit.copy()
    g = int(np.argmin(fit))
    gbest, gbest_fit = X[g].copy(), float(fit[g])
    history = [gbest_fit]

    for _ in range(iters):
        r1 = rng.random((num_particles, K))
        r2 = rng.random((num_particles, K))
        V = w * V + c1 * r1 * (pbest - X) + c2 * r2 * (gbest[None] - X)
        X = np.stack([project(x) for x in (X + V)])
        fit = np.array(
            [evaluate(scn, x, scheduler, delay, quality) for x in X])
        upd = fit < pbest_fit
        pbest[upd], pbest_fit[upd] = X[upd], fit[upd]
        g = int(np.argmin(pbest_fit))
        if pbest_fit[g] < gbest_fit:
            gbest, gbest_fit = pbest[g].copy(), float(pbest_fit[g])
        history.append(gbest_fit)

    return PSOResult(alloc=gbest, fid=gbest_fit, history=history)


def coordinate_refine(scn: Scenario, alloc: np.ndarray,
                      scheduler: SchedulerFn, delay: DelayModel,
                      quality: QualityModel, *, rounds: int = 6,
                      step_frac: float = 0.05,
                      min_frac: float = 1e-3) -> PSOResult:
    """Beyond-paper deterministic refinement: repeatedly try moving a slice
    of bandwidth from donor k to receiver j; keep improving moves."""
    B = scn.total_bandwidth_hz
    cur = alloc.copy()
    cur_fid = evaluate(scn, cur, scheduler, delay, quality)
    history = [cur_fid]
    K = scn.K
    step = step_frac * B
    for _ in range(rounds):
        improved = False
        for donor in range(K):
            for recv in range(K):
                # re-check per transfer: accepted moves within this sweep
                # shrink the donor, and repeated donations must never push
                # it through the min_frac floor (let alone negative)
                if cur[donor] - step < min_frac * B:
                    break
                if recv == donor:
                    continue
                cand = cur.copy()
                cand[donor] -= step
                cand[recv] += step
                f = evaluate(scn, cand, scheduler, delay, quality)
                if f < cur_fid - 1e-9:
                    cur, cur_fid = cand, f
                    improved = True
        history.append(cur_fid)
        if not improved:
            step /= 2.0
            if step < 1e-4 * B:
                break
    return PSOResult(alloc=cur, fid=cur_fid, history=history)
