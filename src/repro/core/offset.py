"""Offset-native STACKING: progress-aware replanning under churn.

The online replanner (``repro.core.online``) keeps per-service progress
``offsets`` (denoising steps already executed) and scores candidate
plans as ``fid(done + new)``.  Algorithm 1 itself, however, only
searches plans whose *new* step counts are balanced around a shared
horizon T* — a service at step 18/20 and one at step 2/20 are planned
against the same grid, wasting the paper's own insight that early steps
matter far more than later ones.

``StackingOffset`` plans natively in *total*-step space instead.  Its
outer search is a marginal-gain water-filling: because the quality
model is monotone with diminishing returns, granting the next step to
whichever service has the highest marginal gain
``fid(offset + t) - fid(offset + t + 1)`` until a common water level L
is reached is exactly the plan family "every service targets
``max(0, L - offset)`` additional steps".  Sweeping the level L
therefore *is* the greedy water-filling, with the schedule's time
feasibility enforced by the batching pass itself.  Each level is
realized two ways and both candidates scored:

  * *soft* (``offset_stacking_pass``) — Algorithm 1's clustering/
    packing sweep with the priority cluster formed on total projected
    counts, so nearly-done services sort behind the water level but
    stay live (a later replan can still extend them);
  * *hard* (``offset_pass``) — services at or above the level retire
    outright (zero new steps) and transmit their banked content, which
    frees batch slots but is irreversible once the plan is adopted
    (``_settle_no_step_services``).

Among objective-equal candidates the shorter makespan wins: replans
are myopic about future arrivals, and freeing the server earlier is
the one future-proofing signal available for free.

Two guard rails keep the scheduler safe to swap in anywhere:

  * with all-zero offsets it delegates to ``stacking`` outright, so the
    static path (and the first replan of any online run) is bit-for-bit
    Algorithm 1 — ``tests/test_offset.py`` enforces it;
  * with real progress it also scores Algorithm 1's own shared-horizon
    candidates (``stacking_pass`` over every T*) under the same
    progress-aware objective, so the chosen plan never scores worse
    than what the ``_OffsetQuality``-wrapped fallback would have
    picked.

The objective mirrors ``repro.core.online._OffsetQuality`` exactly,
including the ``doomed`` rule: a partially-generated service whose
residual generation budget went negative can never deliver on time, so
its banked steps score ``fid(0)`` — without this, retiring a service
"for free" by starving its bandwidth would look attractive.
"""

from __future__ import annotations

from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.core import arrays
from repro.core.delay_model import DelayModel
from repro.core.online import _OffsetQuality
from repro.core.plan import BatchPlan
from repro.core.quality_model import QualityModel
from repro.core.service import ServiceRequest
from repro.core.stacking import stacking, stacking_pass


def offset_stacking_pass(service_ids: Sequence[int],
                         tau_prime: Dict[int, float], delay: DelayModel,
                         t_star: int,
                         offsets: Dict[int, int]) -> BatchPlan:
    """Algorithm 1's clustering-packing-batching sweep with the
    priority cluster formed on *total* projected step counts
    (``stacking_pass`` with its ``offsets`` parameter — one
    implementation, re-exported here under the offset-native name).

    A service at step 18/20 projects past the T* water level and sorts
    to the back of the packing order, so it only receives further steps
    when batch capacity is free — soft deprioritization, never a hard
    (irreversible) retirement.  With all-zero offsets this is
    ``stacking_pass`` exactly.
    """
    return stacking_pass(service_ids, tau_prime, delay, t_star,
                         offsets=offsets)


def offset_pass(service_ids: Sequence[int], tau_prime: Dict[int, float],
                delay: DelayModel, targets: Dict[int, int]) -> BatchPlan:
    """One lockstep sweep toward per-service *additional*-step targets.

    Every service still short of its target joins every batch (insight
    (i): batches as large as possible); members that cannot afford the
    current shared batch drop out with the steps they have, exactly as
    in ``equal_steps`` — which this generalizes from one shared target
    to a per-service vector.
    """
    taup = {k: float(tau_prime[k]) for k in service_ids}
    Tc = {k: 0 for k in service_ids}
    active = [k for k in service_ids
              if targets.get(k, 0) > 0
              and taup[k] >= delay.min_task_delay()]

    batches: List[List] = []
    starts: List[float] = []
    t = 0.0
    while active:
        # drop members that cannot afford the current shared batch
        while active:
            g = delay.g(len(active))
            drop = [k for k in active if taup[k] + 1e-12 < g]
            if not drop:
                break
            for k in drop:
                active.remove(k)
        if not active:
            break
        g = delay.g(len(active))
        batches.append([(k, Tc[k]) for k in active])
        starts.append(t)
        t += g
        for k in active:
            taup[k] -= g
            Tc[k] += 1
        active = [k for k in active
                  if Tc[k] < targets[k]
                  and taup[k] + 1e-12 >= delay.min_task_delay()]
    return BatchPlan(batches=batches, start_times=starts,
                     steps_completed=Tc, delay=delay)


class StackingOffset:
    """Offset-native scheduler (module docstring).

    Satisfies both ``repro.api`` protocols: calling the instance is the
    plain ``Scheduler`` signature (zero offsets — delegates to
    ``stacking``); ``plan`` is the ``OffsetScheduler`` extension the
    online replanner dispatches to when per-service progress exists.
    ``offsets`` is positional, aligned with ``services`` — the same
    convention ``_OffsetQuality`` uses for anonymous step-count lists.
    """

    name = "stacking_offset"
    supports_offsets = True        # the OffsetScheduler dispatch marker

    def __init__(self, engine: Optional[str] = None):
        # None = the process-wide engine; "scalar" pins this instance
        # to the reference per-level passes, any other registered
        # engine name (e.g. "jax") pins its backend
        self.engine = engine

    def __call__(self, services: Sequence[ServiceRequest],
                 tau_prime: Dict[int, float], delay: DelayModel,
                 quality: QualityModel) -> BatchPlan:
        return self.plan(services, tau_prime, delay, quality,
                         [0] * len(services))

    def plan(self, services: Sequence[ServiceRequest],
             tau_prime: Dict[int, float], delay: DelayModel,
             quality: QualityModel,
             offsets: Sequence[int]) -> BatchPlan:
        engine = arrays.resolve_engine(self.engine)
        ids = [s.id for s in services]
        off = {k: int(o) for k, o in zip(ids, offsets)}
        if not any(off.values()):
            # no progress anywhere: the static problem, solved by the
            # paper's Algorithm 1 bit-for-bit
            return stacking(services, tau_prime, delay, quality,
                            engine=engine)

        # the one source of truth for the progress-aware objective
        # (offset-shifted mean FID + doomed rule): scoring through the
        # same class the replanner wraps non-native schedulers with is
        # what makes the family-3 "never worse than the wrapped
        # fallback" guarantee hold by construction
        oq = _OffsetQuality(quality, [off[k] for k in ids])
        oq.refresh_doomed(services, tau_prime)

        headroom = {k: delay.max_steps(max(tau_prime[k], 0.0))
                    for k in ids}
        level_max = max(off[k] + headroom[k] for k in ids)
        t_new_max = max(1, max(headroom.values()))
        impl = arrays.engine_impl(engine)
        if impl is not None:
            return impl.offset_plan(ids, tau_prime, delay, oq, off,
                                    level_max, t_new_max)
        if engine == "vec":
            return self._plan_vec(ids, tau_prime, delay, oq, off,
                                  level_max, t_new_max)
        return self._plan_scalar(ids, tau_prime, delay, oq, off,
                                 level_max, t_new_max)

    def _plan_scalar(self, ids, tau_prime, delay, oq, off,
                     level_max, t_new_max) -> BatchPlan:
        """Reference search: one scalar pass per candidate level."""

        def score(plan: BatchPlan) -> float:
            return oq.mean_fid([plan.steps_completed.get(k, 0)
                                for k in ids])

        # the all-retire plan: schedule nothing, transmit what is banked
        # (the water level sits below every offset) — rarely best, but
        # it is the correct degenerate candidate when no further step
        # fits any budget
        best_plan = BatchPlan(batches=[], start_times=[],
                              steps_completed={k: 0 for k in ids},
                              delay=delay)
        best_q, best_ms = score(best_plan), 0.0

        def better(q: float, ms: float) -> bool:
            # objective first; among objective-equal plans prefer the
            # shorter makespan — the server frees earlier, which only
            # helps whatever arrives next (replans are myopic about
            # future arrivals, so this is the one future-proofing
            # signal available for free)
            if q < best_q - 1e-12:
                return True
            return q < best_q + 1e-12 and ms < best_ms - 1e-12

        # family 1 — Algorithm 1 clustered on TOTAL counts: soft
        # deprioritization, nearly-done services sort behind the T*
        # water level but stay live (a future replan can still extend
        # them)
        for level in range(1, level_max + 1):
            plan = offset_stacking_pass(ids, tau_prime, delay, level, off)
            q, ms = score(plan), plan.makespan()
            if better(q, ms):
                best_plan, best_q, best_ms = plan, q, ms

        # family 2 — water-filling over the total-step level L: service
        # k targets max(0, L - offset_k) additional steps (the greedy
        # marginal-gain order realized as a plan family); services at or
        # above the level retire outright and transmit their banked
        # content
        for level in range(1, level_max + 1):
            targets = {k: max(0, level - off[k]) for k in ids}
            if not any(targets.values()):
                continue
            plan = offset_pass(ids, tau_prime, delay, targets)
            q, ms = score(plan), plan.makespan()
            if better(q, ms):
                best_plan, best_q, best_ms = plan, q, ms

        # family 3 — Algorithm 1's shared-NEW-horizon candidates under
        # the same objective: guarantees this scheduler never picks a
        # plan that scores worse than the _OffsetQuality-wrapped
        # `stacking` fallback would have
        for t_star in range(1, t_new_max + 1):
            plan = stacking_pass(ids, tau_prime, delay, t_star)
            q, ms = score(plan), plan.makespan()
            if better(q, ms):
                best_plan, best_q, best_ms = plan, q, ms
        return best_plan

    def _plan_vec(self, ids, tau_prime, delay, oq, off,
                  level_max, t_new_max) -> BatchPlan:
        """The same three candidate families as ``_plan_scalar``, each
        swept as ONE batched array kernel (``repro.core.arrays``) with
        per-round snapshots, scored row-wise under the identical
        objective/tie rules, and only the winner's batch list replayed.
        Bit-identical to the scalar search — tests/test_arrays.py."""
        arr = arrays.ServiceArrays.build(ids, tau_prime, off)
        state = {"q": oq.mean_fid([0] * len(ids)), "ms": 0.0,
                 "pick": None}        # None = the all-retire empty plan

        def consider(q: float, ms: float, pick) -> None:
            # the scalar `better` rule: objective first, then shorter
            # makespan among objective-equal candidates
            if q < state["q"] - 1e-12 or \
                    (q < state["q"] + 1e-12 and ms < state["ms"] - 1e-12):
                state.update(q=q, ms=ms, pick=pick)

        levels = np.arange(1, level_max + 1, dtype=np.int64)
        # family 1 — Algorithm 1 clustered on TOTAL counts
        h1: list = []
        Tc1, ms1, _, _ = arrays._clustered_rounds(
            arr.ids, arr.tau_prime, arr.offsets, delay, levels,
            history=h1)
        for i, q in enumerate(arrays.score_rows(Tc1, oq).tolist()):
            consider(q, float(ms1[i]), ("clustered", i))

        # family 2 — lockstep water-filling over the total-step level
        targets = np.maximum(levels[:, None] - arr.offsets[None, :], 0)
        nonzero = targets.any(axis=1)
        h2: list = []
        Tc2, ms2, _, _ = arrays._lockstep_rounds(
            arr.ids, arr.tau_prime, targets, delay, history=h2)
        for i, q in enumerate(arrays.score_rows(Tc2, oq).tolist()):
            if nonzero[i]:
                consider(q, float(ms2[i]), ("lockstep", i))

        # family 3 — shared-NEW-horizon Algorithm 1 candidates
        levels3 = np.arange(1, t_new_max + 1, dtype=np.int64)
        h3: list = []
        Tc3, ms3, _, _ = arrays._clustered_rounds(
            arr.ids, arr.tau_prime, np.zeros(arr.K, dtype=np.int64),
            delay, levels3, history=h3)
        for i, q in enumerate(arrays.score_rows(Tc3, oq).tolist()):
            consider(q, float(ms3[i]), ("shared", i))

        pick = state["pick"]
        if pick is None:
            return BatchPlan(batches=[], start_times=[],
                             steps_completed={k: 0 for k in ids},
                             delay=delay)
        family, i = pick
        if family == "clustered":
            counts, hist, replay = Tc1[i], h1, arrays._replay_clustered
        elif family == "lockstep":
            counts, hist, replay = Tc2[i], h2, arrays._replay_lockstep
        else:
            counts, hist, replay = Tc3[i], h3, arrays._replay_clustered
        batches, starts = replay(arr.ids, i, hist, delay)
        steps = {int(k): int(c) for k, c in zip(arr.ids, counts)}
        return BatchPlan(batches=batches, start_times=starts,
                         steps_completed=steps, delay=delay)


stacking_offset = StackingOffset()
