"""Baseline batch-denoising schedulers from Sec. IV.

* single_instance [14]: deadline-ascending, one service at a time, no
  batching.  (Given a per-service step target T* searched like Alg. 1 —
  a generous reading; the naive run-to-deadline variant is strictly worse.)
* greedy: everything in one batch, drop services as deadlines expire.
* fixed_size: batch size floor(K/2), tighter deadlines first, shrink when
  fewer services remain.

All share STACKING's time accounting so comparisons are apples-to-apples.
"""

from __future__ import annotations

from typing import Dict, Sequence

from repro.core.delay_model import DelayModel
from repro.core.plan import BatchPlan
from repro.core.quality_model import QualityModel
from repro.core.service import ServiceRequest


def single_instance(services: Sequence[ServiceRequest],
                    tau_prime: Dict[int, float], delay: DelayModel,
                    quality: QualityModel) -> BatchPlan:
    ids = sorted((s.id for s in services), key=lambda k: tau_prime[k])
    t_star_max = max(1, max(delay.max_steps(tau_prime[k]) for k in ids))
    g1 = delay.g(1)

    best_plan, best_q = None, float("inf")
    for t_star in range(1, t_star_max + 1):
        t = 0.0
        batches, starts, Tc = [], [], {k: 0 for k in ids}
        for k in ids:
            # service k runs dedicated size-1 batches until it reaches
            # t_star steps or its remaining deadline expires
            while Tc[k] < t_star and tau_prime[k] - t >= g1:
                batches.append([(k, Tc[k])])
                starts.append(t)
                t += g1
                Tc[k] += 1
        # counts in services order — the make_plan convention shared by
        # every quality.mean_fid call (progress-aware online replans
        # credit prior steps positionally, repro.core.online)
        q = quality.mean_fid([Tc[s.id] for s in services])
        if q < best_q - 1e-12:
            best_plan = BatchPlan(batches=batches, start_times=starts,
                                  steps_completed=Tc, delay=delay)
            best_q = q
    return best_plan


def greedy_batching(services: Sequence[ServiceRequest],
                    tau_prime: Dict[int, float], delay: DelayModel,
                    quality: QualityModel = None) -> BatchPlan:
    taup = {s.id: float(tau_prime[s.id]) for s in services}
    active = [s.id for s in services
              if taup[s.id] >= delay.min_task_delay()]
    batches, starts, Tc = [], [], {s.id: 0 for s in services}
    t = 0.0
    while active:
        # drop services that cannot afford the next full batch
        while active:
            g = delay.g(len(active))
            drop = [k for k in active if taup[k] + 1e-12 < g]
            if not drop:
                break
            for k in drop:
                active.remove(k)
        if not active:
            break
        g = delay.g(len(active))
        batches.append([(k, Tc[k]) for k in active])
        starts.append(t)
        t += g
        for k in active:
            taup[k] -= g
            Tc[k] += 1
        active = [k for k in active
                  if taup[k] + 1e-12 >= delay.min_task_delay()]
    return BatchPlan(batches=batches, start_times=starts,
                     steps_completed=Tc, delay=delay)


def fixed_size_batching(services: Sequence[ServiceRequest],
                        tau_prime: Dict[int, float], delay: DelayModel,
                        quality: QualityModel = None,
                        batch_size: int = 0) -> BatchPlan:
    K = len(services)
    size = batch_size or max(1, K // 2)
    taup = {s.id: float(tau_prime[s.id]) for s in services}
    active = [s.id for s in services
              if taup[s.id] >= delay.min_task_delay()]
    batches, starts, Tc = [], [], {s.id: 0 for s in services}
    t = 0.0
    while active:
        order = sorted(active, key=lambda k: (taup[k], k))
        packed = order[:min(size, len(order))]
        while packed:
            g = delay.g(len(packed))
            drop = [k for k in packed if taup[k] + 1e-12 < g]
            if not drop:
                break
            for k in drop:
                packed.remove(k)
                active.remove(k)
        if not packed:
            active = [k for k in active
                      if taup[k] + 1e-12 >= delay.min_task_delay()]
            continue
        g = delay.g(len(packed))
        batches.append([(k, Tc[k]) for k in packed])
        starts.append(t)
        t += g
        for k in active:
            taup[k] -= g
        for k in packed:
            Tc[k] += 1
        active = [k for k in active
                  if taup[k] + 1e-12 >= delay.min_task_delay()]
    return BatchPlan(batches=batches, start_times=starts,
                     steps_completed=Tc, delay=delay)
