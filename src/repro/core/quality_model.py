"""Content-quality-vs-steps model — the paper's Fig. 1b.

FID(T) follows a power law  FID(T) = alpha * T^(-beta) + gamma : quality
improves sharply over the first denoising steps, then levels off.  The
default constants are fitted to the DDIM paper's CIFAR-10 measurements
(DDIM eta=0: FID 13.36 / 6.84 / 4.67 / 4.16 at T = 10 / 20 / 50 / 100),
which is the same model/dataset the paper measures.

STACKING itself is *agnostic* to the quality function (the paper's key
claim); anything monotone-decreasing with diminishing returns works —
``QualityModel`` is the interface, ``PowerLawFID`` the paper's instance,
and ``fit_power_law`` reproduces the Fig. 1b fitting step from data.
"""

from __future__ import annotations

import dataclasses
from typing import Protocol, Sequence

import numpy as np


class QualityModel(Protocol):
    def fid(self, steps: int) -> float: ...


@dataclasses.dataclass(frozen=True)
class PowerLawFID:
    alpha: float = 491.0
    beta: float = 1.72
    gamma: float = 4.0
    fid_at_zero: float = 550.0   # FID of pure noise (service outage);
                                 # must dominate fid(1)=alpha+gamma=495

    def fid(self, steps: int) -> float:
        if steps <= 0:
            return self.fid_at_zero
        return self.alpha * steps ** (-self.beta) + self.gamma

    def mean_fid(self, step_counts: Sequence[int]) -> float:
        return float(np.mean([self.fid(t) for t in step_counts]))


def fit_power_law(steps: Sequence[int], fids: Sequence[float],
                  fid_at_zero: float = 550.0) -> PowerLawFID:
    """Fit alpha, beta, gamma by log-space least squares with a gamma grid
    (same functional form the paper fits in Fig. 1b)."""
    t = np.asarray(steps, dtype=np.float64)
    y = np.asarray(fids, dtype=np.float64)
    best = None
    for gamma in np.linspace(0.0, max(0.0, y.min() - 1e-3), 64):
        resid = y - gamma
        if (resid <= 0).any():
            continue
        A = np.stack([np.ones_like(t), np.log(t)], axis=1)
        (loga, negb), *_ = np.linalg.lstsq(A, np.log(resid), rcond=None)
        pred = gamma + np.exp(loga) * t ** negb
        err = float(((pred - y) ** 2).sum())
        if best is None or err < best[0]:
            best = (err, np.exp(loga), -negb, gamma)
    assert best is not None, "degenerate FID data"
    _, alpha, beta, gamma = best
    return PowerLawFID(alpha=float(alpha), beta=float(beta),
                       gamma=float(gamma), fid_at_zero=fid_at_zero)
