import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture x input shape)
on the production meshes with ShapeDtypeStruct inputs (no allocation),
and record memory/cost/collective analysis for the roofline report.

    PYTHONPATH=src python -m repro.launch.dryrun --arch tinyllama-1.1b \
        --shape decode_32k [--multi-pod] [--out artifacts/dryrun]
    PYTHONPATH=src python -m repro.launch.dryrun --all

The first two lines above MUST stay the first statements in this module:
jax locks the device count on first init, and only the dry-run wants 512
placeholder devices.
"""

import argparse
import dataclasses
import json
import re
import time
import traceback

import jax
import jax.numpy as jnp

from repro.config import (RunConfig, SHAPES, get_config, list_archs,
                          sharding_rules_for)
from repro.launch import shardings as shd
from repro.launch.hlo_cost import analyze_hlo
from repro.launch.mesh import make_production_mesh, mesh_axis_sizes
from repro.models import api
from repro.models.params import use_rules
from repro.training import optimizer as opt
from repro.training.train import make_train_step

# TPU v5e hardware constants (roofline denominators).
PEAK_FLOPS = 197e12          # bf16 per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3": 1, "f8e5m2": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1, "u64": 8, "u32": 4, "u16": 2,
    "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

COLLECTIVE_OPS = ("all-gather", "all-reduce", "reduce-scatter",
                  "all-to-all", "collective-permute")

_SHAPE_RE = re.compile(r"\b(f64|f32|f16|bf16|f8e4m3|f8e5m2|s64|s32|s16|s8"
                       r"|u64|u32|u16|u8|pred|c64|c128)\[([0-9,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


def collective_bytes(hlo_text: str) -> dict:
    """Sum operand bytes of every collective op in the optimized HLO."""
    out = {op: 0 for op in COLLECTIVE_OPS}
    counts = {op: 0 for op in COLLECTIVE_OPS}
    for line in hlo_text.splitlines():
        stripped = line.strip()
        for op in COLLECTIVE_OPS:
            # match "= <shape> op-name(" but not fused/custom-call names
            if f" {op}(" in stripped or f" {op}-start(" in stripped:
                lhs, _, rhs = stripped.partition(f" {op}")
                args = rhs[rhs.find("("):rhs.find(")") + 1] if ")" in rhs \
                    else rhs
                total = sum(_shape_bytes(d, dims)
                            for d, dims in _SHAPE_RE.findall(args))
                if total == 0:   # operands referenced without shapes: use lhs
                    total = sum(_shape_bytes(d, dims)
                                for d, dims in _SHAPE_RE.findall(lhs))
                out[op] += total
                counts[op] += 1
                break
    out["total"] = sum(out[op] for op in COLLECTIVE_OPS)
    out["counts"] = counts
    return out


# ---------------------------------------------------------------------------
# Per-(arch, shape) run configuration (DESIGN.md §5)
# ---------------------------------------------------------------------------

def run_for(cfg, shape, opt: bool = False) -> RunConfig:
    decode_window = 0
    shard_kv_seq = False
    fsdp = False
    remat = "none"
    if shape.kind == "train":
        fsdp = True
        remat = "group" if cfg.family in ("vlm", "hybrid", "ssm") \
            else "block"
    if shape.name == "long_500k":
        shard_kv_seq = cfg.family not in ("ssm",)   # xlstm has no kv cache
        if cfg.family != "ssm":
            # sub-quadratic requirement: sliding-window decode attention
            # for every arch with attention layers (DESIGN.md §4)
            decode_window = 8192
    if cfg.name == "llama-3.2-vision-90b" and shape.kind != "train":
        fsdp = True        # 180 GB bf16 / 16-way model = 11 GB/chip: too big
    kwargs = {}
    if opt:
        # §Perf change set (all semantics-preserving; tests/test_perf_variants)
        kwargs = dict(prefill_logits="last",
                      decode_inplace_cache=(shape.kind == "decode"),
                      # dynamic-slicing a SHARDED cache seq axis lowers to
                      # a cross-shard halo exchange that materializes full
                      # f32 buffers (measured: 5x regression) -- only slice
                      # when the cache is seq-replicated
                      decode_slice_reads=bool(decode_window)
                      and not shard_kv_seq,
                      decode_uniform_pos=(shape.kind == "decode"),
                      prefill_parallel_q=(shape.kind == "prefill"
                                          and cfg.num_heads % 16 != 0))
    return RunConfig(fsdp=fsdp, remat=remat, decode_window=decode_window,
                     shard_kv_seq=shard_kv_seq, **kwargs)


def rules_for(cfg, shape, run, mesh, opt: bool = False):
    sizes = mesh_axis_sizes(mesh)
    rules = sharding_rules_for(cfg, sizes, run)
    data_ways = sizes.get("data", 1) * sizes.get("pod", 1)
    if shape.global_batch % data_ways:
        rules["batch"] = None                 # e.g. long_500k batch=1
    if shape.kind == "train":
        rules["seq"] = ("model",)             # Megatron-style seq parallel
    if opt and shape.kind == "prefill" and rules.get("heads") is None \
            and "model" in sizes:
        # attention heads unshardable (minitron 24H/8KV, whisper 6H on a
        # 16-way model axis => attention fully replicated): shard the
        # SEQUENCE over the model axis instead -- flash-style q-block
        # parallelism; k/v all-gather per layer is the traded collective
        rules["seq"] = ("model",)
    if opt and shape.kind == "decode" and rules.get("kv_heads") is None \
            and "model" in sizes:
        # kv heads unshardable (e.g. tinyllama kv=4 on a 16-way model
        # axis): flash-decode-shard the cache SEQUENCE over the model axis
        # instead; softmax reductions lower to psum (§Perf).  Measured
        # 3.8-31x on decode_32k; do NOT stack onto an already data-sharded
        # sequence (long_500k) -- 256-way seq sharding of a B=1 cache
        # regressed 2-3x (cross-shard write/reduce overheads).
        if not (rules.get("kv_seq") or ()):
            rules["kv_seq"] = ("model",)
    return rules


# ---------------------------------------------------------------------------
# Lowering
# ---------------------------------------------------------------------------

def build_step(cfg, shape, run):
    if shape.kind == "train":
        step = make_train_step(cfg, run)

        def train_step(params, opt_state, batch):
            return step(params, opt_state, batch["tokens"], batch["labels"],
                        batch.get("extras"))
        return train_step
    if shape.kind == "prefill":
        pre = api.make_prefill_step(cfg, run, max_len=shape.seq_len)

        def prefill_step(params, batch):
            return pre(params, batch["tokens"], batch.get("extras"))
        return prefill_step
    dec = api.make_decode_step(cfg, run)

    def serve_step(params, batch):
        return dec(params, batch["token"], batch["cache"],
                   batch.get("extras"))
    return serve_step


def lower_one(arch: str, shape_name: str, multi_pod: bool,
              extra_rules: dict = None, opt: bool = False):
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    run = run_for(cfg, shape, opt=opt)
    mesh = make_production_mesh(multi_pod=multi_pod)
    rules = rules_for(cfg, shape, run, mesh, opt=opt)
    if extra_rules:
        rules.update(extra_rules)

    params_abs = api.abstract_model(cfg, jnp.bfloat16)
    batch_abs = api.input_specs(cfg, shape, run, abstract=True)
    p_pspec = shd.model_param_pspecs(cfg, rules, run.fsdp)
    b_pspec = shd.input_pspecs(cfg, shape, run, rules)

    step = build_step(cfg, shape, run)
    with mesh:
        with use_rules(rules):
            if shape.kind == "train":
                opt_abs = {
                    "step": jax.ShapeDtypeStruct((), jnp.int32),
                    "m": jax.tree_util.tree_map(
                        lambda x: jax.ShapeDtypeStruct(x.shape, jnp.float32),
                        params_abs),
                    "v": jax.tree_util.tree_map(
                        lambda x: jax.ShapeDtypeStruct(x.shape, jnp.float32),
                        params_abs),
                }
                o_pspec = shd.opt_state_pspecs(cfg, rules, run.fsdp)
                jitted = jax.jit(
                    step,
                    in_shardings=(shd.to_shardings(mesh, p_pspec),
                                  shd.to_shardings(mesh, o_pspec),
                                  shd.to_shardings(mesh, b_pspec)),
                    out_shardings=(shd.to_shardings(mesh, p_pspec),
                                   shd.to_shardings(mesh, o_pspec),
                                   None),
                )
                lowered = jitted.lower(params_abs, opt_abs, batch_abs)
            else:
                # decode: donate the cache so KV updates lower in-place
                # (production serving semantics; avoids a defensive
                # full-cache copy every step)
                donate = (1,) if shape.kind == "decode" else ()
                jitted = jax.jit(
                    step,
                    in_shardings=(shd.to_shardings(mesh, p_pspec),
                                  shd.to_shardings(mesh, b_pspec)),
                    donate_argnums=donate,
                )
                lowered = jitted.lower(params_abs, batch_abs)
            compiled = lowered.compile()
    return cfg, shape, run, mesh, lowered, compiled


def analyze(arch: str, shape_name: str, multi_pod: bool,
            extra_rules: dict = None, opt: bool = False) -> dict:
    t0 = time.time()
    cfg, shape, run, mesh, lowered, compiled = lower_one(
        arch, shape_name, multi_pod, extra_rules, opt=opt)
    compile_s = time.time() - t0
    chips = mesh.devices.size

    cost = compiled.cost_analysis() or {}
    flops = float(cost.get("flops", 0.0))
    bytes_accessed = float(cost.get("bytes accessed", 0.0))
    try:
        mem = compiled.memory_analysis()
        mem_info = {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "generated_code_bytes":
                getattr(mem, "generated_code_size_in_bytes", None),
        }
    except Exception as e:   # noqa: BLE001 - backend-dependent
        mem_info = {"error": str(e)}

    hlo = compiled.as_text()
    coll = collective_bytes(hlo)
    # Trip-count-aware re-analysis: XLA:CPU cost_analysis counts while
    # bodies once (scan-over-layers would be undercounted by L) and counts
    # a full-buffer touch per dynamic-update-slice (KV writes would be
    # overcounted by S).  hlo_cost fixes both; raw numbers kept alongside.
    # For decode shapes, also classify XLA:CPU copy-insertion artifacts on
    # the donated cache buffers (in-place on TPU): whitelist = per-shard
    # byte size of each cache leaf.
    artifact_sizes = None
    if shape.kind == "decode":
        rules = rules_for(cfg, shape, run, mesh, opt=opt)
        cache_abs = api.input_specs(cfg, shape, run,
                                    abstract=True)["cache"]
        cache_spec = shd.cache_pspecs(cfg, run, rules)
        from jax.sharding import PartitionSpec as _PS
        msizes = mesh_axis_sizes(mesh)
        sizes = []
        for leaf, spec in zip(
                jax.tree_util.tree_leaves(cache_abs),
                jax.tree_util.tree_leaves(
                    cache_spec,
                    is_leaf=lambda x: isinstance(x, _PS))):
            shards = 1
            for part in spec:
                for ax in ((part,) if isinstance(part, str)
                           else (part or ())):
                    shards *= msizes.get(ax, 1)
            n = 1
            for d in leaf.shape:
                n *= d
            sizes.append(n * leaf.dtype.itemsize // shards)
        artifact_sizes = [x for x in sizes if x >= 8e6]
    corr = analyze_hlo(hlo, artifact_sizes=artifact_sizes)

    # NOTE on normalization: the SPMD module is per-partition, so all HLO
    # numbers below are per-chip.
    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode"
                                   else 1)
    n_params = cfg.param_count()
    n_active = cfg.active_param_count()
    if shape.kind == "train":
        model_flops = 6.0 * n_active * tokens
    else:
        model_flops = 2.0 * n_active * tokens

    compute_s = corr["flops"] / PEAK_FLOPS         # per-chip
    memory_s = corr["bytes"] / HBM_BW
    collective_s = corr["collective_bytes"] / ICI_BW
    # TPU-adjusted: subtract XLA:CPU copy-insertion artifacts on
    # while-carried cache buffers (in-place on the real target; see
    # hlo_cost._model_alias_artifact_bytes)
    memory_s_tpu = max(corr["bytes"] - corr.get("alias_artifact_bytes",
                                                0.0), 0.0) / HBM_BW
    terms = {"compute_s": compute_s, "memory_s": memory_s,
             "collective_s": collective_s}
    dominant = max(terms, key=terms.get)
    useful = (model_flops / chips) / corr["flops"] if corr["flops"] else 0.0

    return {
        "arch": arch, "shape": shape_name, "opt": opt,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "chips": chips,
        "kind": shape.kind,
        "run": dataclasses.asdict(run),
        "compile_seconds": round(compile_s, 1),
        "hlo_flops_per_chip": corr["flops"],
        "hlo_bytes_per_chip": corr["bytes"],
        "collective_bytes_per_chip": corr["collective_bytes"],
        "collectives": {"counts": corr["collective_counts"]},
        "raw_cost_analysis": {
            "flops": flops, "bytes_accessed": bytes_accessed,
            "collective_bytes_textparse": coll["total"],
            "note": "uncorrected XLA numbers (while bodies counted once)",
        },
        "memory_analysis": mem_info,
        "roofline": {**terms, "dominant": dominant,
                     "memory_s_tpu_adjusted": memory_s_tpu,
                     "alias_artifact_bytes":
                         corr.get("alias_artifact_bytes", 0.0)},
        "model_flops_total": model_flops,
        "model_flops_per_chip": model_flops / chips,
        "useful_flops_ratio": useful,
        "params": n_params, "active_params": n_active,
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default="artifacts/dryrun")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--opt", action="store_true",
                    help="apply the §Perf change set (beyond-paper)")
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    archs = list_archs() if args.all or not args.arch else [args.arch]
    archs = [a for a in archs if a != "ddim-cifar10"]
    shapes = list(SHAPES) if args.all or not args.shape else [args.shape]
    meshes = [False, True] if (args.both_meshes or
                               (args.all and not args.multi_pod)) \
        else [args.multi_pod]

    failures = []
    for arch in archs:
        for shape_name in shapes:
            for mp in meshes:
                tag = f"{arch}_{shape_name}_{'2x16x16' if mp else '16x16'}"
                path = os.path.join(args.out, tag + ".json")
                if os.path.exists(path) and not args.force:
                    print(f"[skip] {tag}")
                    continue
                print(f"[lower+compile] {tag} ...", flush=True)
                try:
                    rec = analyze(arch, shape_name, mp, opt=args.opt)
                    with open(path, "w") as f:
                        json.dump(rec, f, indent=1)
                    r = rec["roofline"]
                    print(f"  ok in {rec['compile_seconds']}s  "
                          f"compute {r['compute_s']:.3e}s  "
                          f"memory {r['memory_s']:.3e}s  "
                          f"coll {r['collective_s']:.3e}s  "
                          f"dominant={r['dominant']}", flush=True)
                except Exception as e:   # noqa: BLE001
                    failures.append((tag, repr(e)))
                    print(f"  FAIL: {e}\n{traceback.format_exc()}",
                          flush=True)
    if failures:
        print(f"\n{len(failures)} failures:")
        for tag, err in failures:
            print(f"  {tag}: {err[:200]}")
        raise SystemExit(1)
    print("\nall dry-runs passed")


if __name__ == "__main__":
    main()
