"""Production mesh definitions.

Functions, not module-level constants, so importing never touches jax
device state (jax locks the device count on first backend init)."""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: (data=16, model=16) = 256 chips (TPU v5e pod).
    Multi-pod: (pod=2, data=16, model=16) = 512 chips."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model: int = 1):
    """Degenerate mesh over however many real devices exist (tests)."""
    n = len(jax.devices())
    assert n % model == 0
    return jax.make_mesh((n // model, model), ("data", "model"))


def mesh_axis_sizes(mesh) -> dict:
    return dict(zip(mesh.axis_names, mesh.devices.shape))
