"""Trip-count-aware HLO cost analysis.

``compiled.cost_analysis()`` on XLA:CPU counts every while-loop body ONCE,
so scan-over-layers models (all of ours — that is what makes 100-layer
configs compilable) are undercounted by the trip count.  This walker
parses the optimized HLO text and computes:

  * flops — dot/convolution FLOPs, with while bodies multiplied by their
    ``backend_config known_trip_count`` and fusion/call ops attributed the
    FLOPs of their called computation;
  * bytes — HBM traffic at *fusion boundaries* (operands + results of
    fusion/dot/collective/copy/gather/scatter ops; in-place
    dynamic-update-slice counts only the updated slice), which matches the
    TPU memory model far better than the built-in conservative analysis
    (which counts a full-buffer touch per DUS — catastrophically wrong for
    KV-cache writes);
  * collective_bytes — operand bytes of collective ops (multiplied through
    loops the same way).

Validated against unrolled references in tests/test_hlo_cost.py.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1, "u64": 8, "u32": 4, "u16": 2,
    "u8": 1, "pred": 1, "c64": 8, "c128": 16, "token": 0,
}

_SHAPE_RE = re.compile(
    r"\b(f64|f32|f16|bf16|f8e4m3fn|f8e5m2|s64|s32|s16|s8|u64|u32|u16|u8"
    r"|pred|c64|c128|token)\[([0-9,]*)\]")

_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(\(?[\w\[\],\s{}\/]*?\)?)\s+"
    r"([\w\-]+)\((.*)$")

_NAME_RE = re.compile(r"%([\w\.\-]+)")

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

_FREE_OPS = {
    "parameter", "constant", "get-tuple-element", "tuple", "bitcast",
    "reshape", "broadcast", "iota", "after-all", "partition-id",
    "replica-id", "bitcast-convert", "domain", "opt-barrier",
}


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _dims(shape_text: str) -> List[int]:
    m = _SHAPE_RE.search(shape_text)
    if not m:
        return []
    return [int(d) for d in m.group(2).split(",") if d]


def _split_top(args: str) -> List[str]:
    out, depth, cur = [], 0, ""
    for ch in args:
        if ch in "([{":
            depth += 1
        elif ch in ")]}":
            depth -= 1
        if ch == "," and depth == 0:
            out.append(cur.strip())
            cur = ""
        else:
            cur += ch
    if cur.strip():
        out.append(cur.strip())
    return out


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    collective_bytes: float = 0.0
    collective_counts: Dict[str, int] = dataclasses.field(
        default_factory=dict)

    def __iadd__(self, o: "Cost"):
        self.flops += o.flops
        self.bytes += o.bytes
        self.collective_bytes += o.collective_bytes
        for k, v in o.collective_counts.items():
            self.collective_counts[k] = self.collective_counts.get(k, 0) + v
        return self

    def scaled(self, n: float) -> "Cost":
        return Cost(self.flops * n, self.bytes * n,
                    self.collective_bytes * n,
                    {k: v * int(n) for k, v in
                     self.collective_counts.items()})


def _parse_op_line(s: str):
    """Parse '%name = <type> opcode(args), attrs' robustly: tuple types may
    contain nested parens and /*index=k*/ comments."""
    m = re.match(r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*", s)
    if not m:
        return None
    name = m.group(1)
    rest = s[m.end():]
    if rest.startswith("("):              # tuple result type
        depth = 0
        for i, ch in enumerate(rest):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    break
        result, rest = rest[:i + 1], rest[i + 1:].lstrip()
    else:                                  # scalar/array type: no spaces
        sp = rest.find(" ")
        if sp < 0:
            return None
        result, rest = rest[:sp], rest[sp + 1:].lstrip()
    om = re.match(r"([\w\-]+)\(", rest)
    if not om:
        return None
    opcode = om.group(1)
    body = rest[om.end():]
    depth, idx = 1, len(body)
    for i, ch in enumerate(body):
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
            if depth == 0:
                idx = i
                break
    return name, result, opcode, body[:idx], body[idx + 1:]


@dataclasses.dataclass
class _Op:
    name: str
    result: str
    opcode: str
    operands: List[str]       # operand NAMES
    attrs: str


class HloCostModel:
    def __init__(self, hlo_text: str):
        self.computations: Dict[str, List[_Op]] = {}
        self.shapes: Dict[str, str] = {}      # op name -> result shape text
        self.entry: Optional[str] = None
        self._parse(hlo_text)
        self._memo: Dict[str, Cost] = {}

    # ------------------------------------------------------------------
    def _parse(self, text: str):
        cur = None
        for raw in text.splitlines():
            line = raw.rstrip()
            s = line.strip()
            if not s:
                continue
            if s.endswith("{") and (") -> " in s or s.startswith("ENTRY")):
                # computation header: [ENTRY] %name (p: shape, ...) -> ret {
                m = re.match(r"^(ENTRY\s+)?%?([\w\.\-]+)\s*\((.*)\)\s*->",
                             s)
                if m:
                    cur = m.group(2)
                    self.computations[cur] = []
                    if m.group(1):
                        self.entry = cur
                    # parameter shapes from the header
                    for param in _split_top(m.group(3)):
                        pm = re.match(r"([\w\.\-]+)\s*:\s*(.+)", param)
                        if pm:
                            self.shapes[pm.group(1)] = pm.group(2)
                    continue
            if s == "}" or s.startswith("} "):
                cur = None
                continue
            if cur is None:
                continue
            parsed = _parse_op_line(s)
            if parsed is None:
                continue
            name, result, opcode, args, attrs = parsed
            operand_names = []
            for tok in _split_top(args):
                nm = _NAME_RE.search(tok)
                operand_names.append(nm.group(1) if nm else tok)
            self.shapes[name] = result
            self.computations[cur].append(
                _Op(name, result, opcode, operand_names, attrs))

    # ------------------------------------------------------------------
    def _op_shape(self, name: str) -> str:
        return self.shapes.get(name, "")

    def _operand_bytes(self, op: _Op) -> int:
        return sum(_shape_bytes(self._op_shape(o)) for o in op.operands)

    def _fusion_operand_bytes(self, op: _Op, called: List[str]) -> int:
        """Operand traffic of a fusion: a parameter consumed *only* by
        dynamic-slice ops inside the fused computation is charged at the
        slice size, not the full buffer (the KV-cache / scan-carry read
        pattern); everything else at full size."""
        if not called or called[0] not in self.computations:
            return self._operand_bytes(op)
        body = self.computations[called[0]]
        # map parameter index -> parameter op name
        params = {}
        for bop in body:
            if bop.opcode == "parameter":
                idx = int(bop.operands[0]) if bop.operands and \
                    bop.operands[0].isdigit() else len(params)
                params[idx] = bop.name
        total = 0
        for i, operand in enumerate(op.operands):
            full = _shape_bytes(self._op_shape(operand))
            pname = params.get(i)
            if pname is None:
                total += full
                continue
            uses = [bop for bop in body if pname in bop.operands]
            if uses and all(b.opcode == "dynamic-slice" and
                            b.operands and b.operands[0] == pname
                            for b in uses):
                total += sum(_shape_bytes(b.result) for b in uses)
            else:
                total += full
        return total

    def computation_cost(self, name: str) -> Cost:
        if name in self._memo:
            return self._memo[name]
        self._memo[name] = Cost()          # cycle guard
        total = Cost()
        for op in self.computations.get(name, []):
            total += self._op_cost(op)
        self._memo[name] = total
        return total

    def _called(self, attrs: str, key: str) -> List[str]:
        m = re.search(key + r"=\{([^}]*)\}", attrs)
        if m:
            return [x.strip().lstrip("%")
                    for x in m.group(1).split(",") if x.strip()]
        m = re.search(key + r"=%?([\w\.\-]+)", attrs)
        if m:
            return [m.group(1)]
        return []

    def _dot_flops(self, op: _Op) -> float:
        out_elems = 1
        for d in _dims(op.result):
            out_elems *= d
        m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", op.attrs)
        lhs_dims = _dims(self._op_shape(op.operands[0])) \
            if op.operands else []
        if not m or not lhs_dims:
            return 2.0 * out_elems
        k = 1
        for i in [int(x) for x in m.group(1).split(",") if x]:
            if i < len(lhs_dims):
                k *= lhs_dims[i]
        # batch dims shrink nothing: out already includes them
        return 2.0 * out_elems * k

    def _conv_flops(self, op: _Op) -> float:
        out_elems = 1
        for d in _dims(op.result):
            out_elems *= d
        kdims = _dims(self._op_shape(op.operands[1])) \
            if len(op.operands) > 1 else []
        if not kdims:
            return 2.0 * out_elems
        k = 1
        for d in kdims:
            k *= d
        k //= max(kdims)          # drop the output-feature dim
        return 2.0 * out_elems * max(k, 1)

    def _op_cost(self, op: _Op) -> Cost:
        c = Cost()
        if op.opcode in _FREE_OPS:
            return c

        if op.opcode == "while":
            n = 1
            tm = re.search(
                r'known_trip_count[^0-9]*"?n"?[^0-9]*([0-9]+)', op.attrs)
            if tm:
                n = int(tm.group(1))
            inner = Cost()
            for b in (self._called(op.attrs, "body")
                      + self._called(op.attrs, "condition")):
                inner += self.computation_cost(b)
            return inner.scaled(n)

        if op.opcode == "conditional":
            branches = (self._called(op.attrs, "branch_computations")
                        or self._called(op.attrs, "true_computation")
                        + self._called(op.attrs, "false_computation"))
            worst = Cost()
            for b in branches:
                bc = self.computation_cost(b)
                if bc.flops + bc.bytes > worst.flops + worst.bytes:
                    worst = bc
            return worst

        if op.opcode in ("call", "fusion", "async-start"):
            called = (self._called(op.attrs, "calls")
                      or self._called(op.attrs, "to_apply"))
            for b in called:
                inner = self.computation_cost(b)
                c.flops += inner.flops
                c.collective_bytes += inner.collective_bytes
                for k, v in inner.collective_counts.items():
                    c.collective_counts[k] = \
                        c.collective_counts.get(k, 0) + v
            c.bytes += _shape_bytes(op.result)
            c.bytes += self._fusion_operand_bytes(op, called)
            return c

        for coll in COLLECTIVES:
            if op.opcode.startswith(coll):
                b = self._operand_bytes(op)
                c.collective_bytes += b
                c.bytes += b + _shape_bytes(op.result)
                c.collective_counts[coll] = \
                    c.collective_counts.get(coll, 0) + 1
                return c

        if op.opcode == "dot":
            c.flops += self._dot_flops(op)
            c.bytes += _shape_bytes(op.result) + self._operand_bytes(op)
            return c

        if op.opcode == "convolution":
            c.flops += self._conv_flops(op)
            c.bytes += _shape_bytes(op.result) + self._operand_bytes(op)
            return c

        if op.opcode == "dynamic-update-slice":
            if len(op.operands) > 1:
                c.bytes += 2 * _shape_bytes(self._op_shape(op.operands[1]))
            return c

        if op.opcode == "dynamic-slice":
            c.bytes += 2 * _shape_bytes(op.result)
            return c

        if op.opcode in ("reduce", "reduce-window", "map", "sort"):
            n = 1
            for d in _dims(op.result):
                n *= d
            c.flops += float(n)
            c.bytes += _shape_bytes(op.result) + self._operand_bytes(op)
            return c

        # elementwise / data movement and anything else: boundary traffic
        c.bytes += _shape_bytes(op.result) + self._operand_bytes(op)
        return c

    def entry_cost(self) -> Cost:
        assert self.entry is not None, "no ENTRY computation found"
        return self.computation_cost(self.entry)


def analyze_hlo(hlo_text: str, artifact_sizes=None) -> dict:
    """artifact_sizes: byte sizes of donated in-place buffers (per-shard
    decode cache leaves).  Only while-carried buffers matching these sizes
    (or their f32 mirrors) are eligible for alias-artifact classification;
    None disables the adjustment (train/prefill)."""
    model = HloCostModel(hlo_text)
    c = model.entry_cost()
    return {
        "flops": c.flops,
        "bytes": c.bytes,
        "collective_bytes": c.collective_bytes,
        "collective_counts": c.collective_counts,
        "alias_artifact_bytes":
            model.alias_artifact_bytes(artifact_sizes)
            if artifact_sizes else 0.0,
    }


# ---------------------------------------------------------------------------
# CPU copy-insertion artifact accounting.
#
# XLA:CPU cannot alias a while-carried buffer that is dynamic-update-sliced
# and dynamic-sliced within one iteration: it inserts full-buffer copies
# (and, for bf16 scatters, an f32 mirror round-trip).  XLA:TPU's
# memory-space-aware buffer assignment performs these updates in place —
# the carried-KV-cache + per-layer DUS pattern is exactly how production
# TPU decoders (e.g. MaxText) work.  We classify per-iteration ops whose
# RESULT is a full while-carry-sized buffer and whose opcode is a copy /
# DUS-fusion / pure-convert as CPU lowering artifacts, and report their
# loop-scaled byte total so the roofline can show a TPU-adjusted memory
# term alongside the raw one.
# ---------------------------------------------------------------------------

def _artifact_opcode(op: _Op) -> bool:
    if op.opcode == "copy":
        return True
    if op.opcode == "fusion" and ("dynamic-update-slice" in op.name
                                  or "convert" in op.name
                                  or "select" in op.name):
        return True
    return False


def _carry_sizes(model: HloCostModel, whitelist) -> set:
    """Sizes of while-carried tuple elements that correspond to donated
    in-place buffers (whitelist of per-shard cache-leaf byte sizes), plus
    their f32 mirrors."""
    allowed = set()
    for b in whitelist:
        allowed.add(int(b))
        allowed.add(int(b) * 2)          # f32 mirror of a bf16 buffer
        allowed.add(int(b) * 4)          # f32 mirror of an int8 buffer
    sizes = set()
    for comp in model.computations.values():
        for op in comp:
            if op.opcode == "while":
                for dt, dims in _SHAPE_RE.findall(op.result):
                    n = 1
                    for d in dims.split(","):
                        if d:
                            n *= int(d)
                    b = n * _DTYPE_BYTES[dt]
                    if b in allowed:
                        sizes.add(b)
                        sizes.add(b * 2)
    return sizes


def _artifact_bytes_in(model: HloCostModel, comp: str, sizes: set,
                       memo: dict) -> float:
    if comp in memo:
        return memo[comp]
    memo[comp] = 0.0
    total = 0.0
    for op in model.computations.get(comp, []):
        if op.opcode == "while":
            n = 1
            tm = re.search(r'known_trip_count[^0-9]*"?n"?[^0-9]*([0-9]+)',
                           op.attrs)
            if tm:
                n = int(tm.group(1))
            for b in (model._called(op.attrs, "body")
                      + model._called(op.attrs, "condition")):
                total += n * _artifact_bytes_in(model, b, sizes, memo)
        elif _artifact_opcode(op):
            rb = _shape_bytes(op.result)
            if rb in sizes:
                # charge the boundary traffic this op contributed
                total += rb + sum(
                    _shape_bytes(model._op_shape(o)) for o in op.operands
                    if _shape_bytes(model._op_shape(o)) in sizes)
    memo[comp] = total
    return total


def _model_alias_artifact_bytes(model: HloCostModel, whitelist) -> float:
    sizes = _carry_sizes(model, whitelist or ())
    if not sizes:
        return 0.0
    return _artifact_bytes_in(model, model.entry, sizes, {})


HloCostModel.alias_artifact_bytes = _model_alias_artifact_bytes
