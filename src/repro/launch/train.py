"""Training launcher: pjit-sharded training of any assigned architecture
on whatever devices exist (host mesh), at a reduced or full config.

    PYTHONPATH=src python -m repro.launch.train --arch tinyllama-1.1b \
        --smoke --steps 50 [--model-parallel 2] [--ckpt /tmp/ck.npz]

On a real TPU slice the same code runs the full config with the
production sharding rules (DESIGN.md §5); on CPU use --smoke.
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as PS

from repro.config import RunConfig, get_config, sharding_rules_for, \
    smoke_variant
from repro.launch import shardings as shd
from repro.launch.mesh import make_host_mesh, mesh_axis_sizes
from repro.models import api
from repro.models.params import use_rules
from repro.training import checkpoint, optimizer as opt
from repro.training.data import DataConfig, batches
from repro.training.train import make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-friendly)")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--model-parallel", type=int, default=1)
    ap.add_argument("--remat", default="none")
    ap.add_argument("--ckpt", default="")
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = smoke_variant(cfg)
    run = RunConfig(remat=args.remat)

    mesh = make_host_mesh(model=args.model_parallel)
    sizes = mesh_axis_sizes(mesh)
    rules = sharding_rules_for(cfg, sizes, run)
    print(f"arch={cfg.name} params~{cfg.param_count() / 1e6:.1f}M "
          f"mesh={sizes} devices={len(jax.devices())}")

    params = api.init_model(cfg, jax.random.PRNGKey(0))
    opt_state = opt.init_state(params)
    ocfg = opt.AdamWConfig(lr=args.lr, warmup_steps=max(args.steps // 10, 1),
                           total_steps=args.steps)
    dc = DataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq_len,
                    global_batch=args.batch)
    data = batches(dc)
    extras = api.extra_input_specs(cfg, args.batch, abstract=False)

    p_spec = shd.model_param_pspecs(cfg, rules, run.fsdp)
    p_sh = shd.to_shardings(mesh, p_spec)
    batch_sh = NamedSharding(mesh, PS("data"))

    with mesh:
        with use_rules(rules):
            params = jax.device_put(params, p_sh)
            step_fn = jax.jit(
                make_train_step(cfg, run, ocfg),
                in_shardings=(p_sh, None, batch_sh, batch_sh, None))
            t0 = time.time()
            for i in range(args.steps):
                toks, labels = next(data)
                params, opt_state, m = step_fn(
                    params, opt_state, jnp.asarray(toks),
                    jnp.asarray(labels), extras)
                if i % args.log_every == 0 or i == args.steps - 1:
                    print(f"step {i:5d}  loss {float(m['loss']):.4f}  "
                          f"lr {float(m['lr']):.2e}  "
                          f"|g| {float(m['grad_norm']):.2f}")
            dt = time.time() - t0
    tok_s = args.steps * args.batch * args.seq_len / dt
    print(f"done: {args.steps} steps in {dt:.1f}s ({tok_s:.0f} tok/s)")

    if args.ckpt:
        checkpoint.save(args.ckpt, {"params": params, "opt": opt_state})
        print(f"checkpoint -> {args.ckpt}")


if __name__ == "__main__":
    main()
