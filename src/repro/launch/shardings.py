"""Sharding assignment for step-function inputs (params, optimizer state,
token batches, decode caches) from the logical-axis rules."""

from __future__ import annotations

from typing import Optional

import jax
from jax.sharding import NamedSharding, PartitionSpec as PS

from repro.config import ModelConfig, RunConfig, ShapeConfig
from repro.models import api


def _ax(rules: dict, name: Optional[str]):
    if name is None:
        return None
    m = rules.get(name)
    if m is None:
        return None
    if isinstance(m, (tuple, list)):
        return m[0] if len(m) == 1 else tuple(m)
    return m


def param_rules(rules: dict, fsdp: bool) -> dict:
    """Parameter sharding rules: FSDP additionally shards the embed axis of
    *weights* over the data axes (activations keep embed replicated)."""
    if not fsdp:
        return rules
    r = dict(rules)
    r["embed"] = ("data",)
    return r


def batch_spec(rules, *trailing):
    return PS(_ax(rules, "batch"), *[_ax(rules, t) for t in trailing])


def kv_spec(rules, lead_axes: int):
    """KV cache buffer (lead..., B, S, KV, D)."""
    return PS(*([None] * lead_axes), _ax(rules, "batch"),
              _ax(rules, "kv_seq"), _ax(rules, "kv_heads"), None)


def _kv_tree(rules, lead: int, kv_dtype: str, cross: bool = False):
    # cross-attention KV buffers hold the (short, often non-divisible)
    # vision/audio token axis — never sequence-sharded
    r = dict(rules, kv_seq=None) if cross else rules
    if kv_dtype == "int8":
        return {"q": kv_spec(r, lead),
                "s": PS(*([None] * lead), _ax(r, "batch"),
                        _ax(r, "kv_seq"), _ax(r, "kv_heads"))}
    return kv_spec(r, lead)


def cache_pspecs(cfg: ModelConfig, run: RunConfig, rules: dict):
    """PartitionSpec tree matching ``<model>.init_cache`` structurally."""
    b = _ax(rules, "batch")
    kvd = run.kv_cache_dtype
    if cfg.family in ("dense", "moe"):
        return {"pos": PS(b), "k": _kv_tree(rules, 1, kvd),
                "v": _kv_tree(rules, 1, kvd)}
    if cfg.family == "vlm":
        return {"pos": PS(b),
                "k": _kv_tree(rules, 2, kvd), "v": _kv_tree(rules, 2, kvd),
                "cross_k": _kv_tree(rules, 1, kvd, cross=True),
                "cross_v": _kv_tree(rules, 1, kvd, cross=True)}
    if cfg.family == "audio":
        return {"pos": PS(b),
                "k": _kv_tree(rules, 1, kvd), "v": _kv_tree(rules, 1, kvd),
                "cross_k": _kv_tree(rules, 1, kvd, cross=True),
                "cross_v": _kv_tree(rules, 1, kvd, cross=True)}
    if cfg.family == "hybrid":
        ssm_h = _ax(rules, "ssm_inner")   # heads of the inner dim
        return {"pos": PS(b),
                "k": _kv_tree(rules, 1, kvd), "v": _kv_tree(rules, 1, kvd),
                "ssm": {"conv": PS(None, None, b, None, None),
                        "ssm": PS(None, None, b, ssm_h, None, None)}}
    if cfg.family == "ssm":
        return {"pos": PS(b),
                "mlstm": {"conv": PS(None, b, None, _ax(rules, "ssm_inner")),
                          "mem": PS(None, b, None, None, None)},
                "slstm": {"cell": tuple(PS(None, b, None)
                                        for _ in range(4))}}
    raise ValueError(cfg.family)


def input_pspecs(cfg: ModelConfig, shape: ShapeConfig, run: RunConfig,
                 rules: dict):
    """PartitionSpec tree matching ``api.input_specs``."""
    specs = {}
    if shape.kind == "train":
        specs["tokens"] = batch_spec(rules, None)
        specs["labels"] = batch_spec(rules, None)
    elif shape.kind == "prefill":
        specs["tokens"] = batch_spec(rules, None)
    else:
        specs["token"] = batch_spec(rules, None)
        specs["cache"] = cache_pspecs(cfg, run, rules)
    if cfg.family == "audio":
        specs["extras"] = {"audio_frames": batch_spec(rules, None, None)}
    if cfg.family == "vlm":
        specs["extras"] = {"vision_embeds": batch_spec(rules, None, None)}
    return specs


def model_param_pspecs(cfg: ModelConfig, rules: dict, fsdp: bool):
    return api.model_pspecs(cfg, param_rules(rules, fsdp))


def opt_state_pspecs(cfg: ModelConfig, rules: dict, fsdp: bool):
    pspec = model_param_pspecs(cfg, rules, fsdp)
    return {"step": PS(), "m": pspec, "v": pspec}


def to_shardings(mesh, pspec_tree):
    return jax.tree_util.tree_map(
        lambda ps: NamedSharding(mesh, ps), pspec_tree,
        is_leaf=lambda x: isinstance(x, PS))
