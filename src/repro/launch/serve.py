"""Serving launcher: deadline-aware batched decoding with STACKING.

    PYTHONPATH=src python -m repro.launch.serve --arch tinyllama-1.1b \
        --smoke --requests 6 [--deadlines 0.2,0.5,1.0]

Submits synthetic prompts with heterogeneous deadlines, calibrates the
decode delay model on this hardware (the paper's Fig.-1a procedure),
plans token budgets with STACKING (Alg. 1), executes the plan with
batched decode steps, and reports per-request outcomes vs. greedy
batching.
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.config import RunConfig, get_config, smoke_variant
from repro.core.baselines import greedy_batching
from repro.core.service import ServiceRequest
from repro.models import api
from repro.serving.engine import ServingEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--deadlines", default="",
                    help="comma-separated seconds; default random 0.2-1.5")
    ap.add_argument("--prompt-len", type=int, default=12)
    ap.add_argument("--max-len", type=int, default=256)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = smoke_variant(cfg)
    params = api.init_model(cfg, jax.random.PRNGKey(0))
    run = RunConfig()
    extras = api.extra_input_specs(cfg, 1, abstract=False)
    eng = ServingEngine(cfg, params, run, max_len=args.max_len,
                        extras=extras)

    print("calibrating decode delay model...")
    dm = eng.measure_decode_delay(batch_sizes=(1, 2, 4))
    print(f"  g(X) = {dm.a * 1e3:.2f}ms * X + {dm.b * 1e3:.2f}ms")

    rng = np.random.default_rng(args.seed)
    if args.deadlines:
        deadlines = [float(x) for x in args.deadlines.split(",")]
    else:
        deadlines = sorted(rng.uniform(0.2, 1.5, args.requests).tolist())
    ids = [eng.submit(rng.integers(0, cfg.vocab_size,
                                   args.prompt_len).astype(np.int32), d)
           for d in deadlines]

    plan = eng.plan()
    plan.validate()
    t0 = time.time()
    out = eng.execute(plan)
    wall = time.time() - t0
    print(f"\nexecuted {plan.num_batches} batches in {wall:.2f}s wall")
    print(f"{'req':>4} {'deadline':>9} {'tokens':>7}")
    for rid, d in zip(ids, deadlines):
        print(f"{rid:>4} {d:9.2f} {len(out[rid]):7d}")

    svcs = [ServiceRequest(id=i, deadline=d, spectral_eff=1.0)
            for i, d in enumerate(deadlines)]
    tp = {s.id: s.deadline for s in svcs}
    greedy = greedy_batching(svcs, tp, eng.delay)
    q_st = eng.quality.mean_fid(list(plan.steps_completed.values()))
    q_gr = eng.quality.mean_fid(list(greedy.steps_completed.values()))
    print(f"\nmean quality penalty: stacking={q_st:.3f} greedy={q_gr:.3f}")


if __name__ == "__main__":
    main()
