"""Deadline-aware LLM serving engine driven by STACKING.

The paper's abstraction — iterative generation whose per-step cost is
affine in batch size and whose quality rises with step count — maps
directly onto autoregressive decoding: a "denoising task" becomes one
decode token (DESIGN.md §4).  The engine

  1. measures/accepts a DelayModel for decode steps (b = weight-stream
     cost, a = per-sequence slope — same structure as the paper's GPU
     measurement),
  2. plans token generation for all queued requests with STACKING under
     per-request deadlines,
  3. executes the plan batch-by-batch: gathers the packed requests'
     states, runs ONE batched decode_step, scatters back.

Per-request KV caches are kept unbatched (B=1) and stacked on demand —
the CPU-scale analogue of slot-based continuous batching.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import ModelConfig, RunConfig
from repro.core.delay_model import DelayModel
from repro.core.plan import BatchPlan
from repro.core.quality_model import QualityModel
from repro.core.service import ServiceRequest
from repro.models import api


@dataclasses.dataclass(frozen=True)
class TokenQuality:
    """Monotone diminishing-returns 'FID-like' penalty for LLM serving:
    fewer generated tokens = worse response.  Same interface as
    PowerLawFID so STACKING is reused unmodified (it is quality-function
    agnostic — the paper's own selling point)."""
    target_tokens: int = 64
    penalty_at_zero: float = 100.0

    def fid(self, steps: int) -> float:
        if steps <= 0:
            return self.penalty_at_zero
        return self.penalty_at_zero / (1.0 + steps)

    def mean_fid(self, step_counts) -> float:
        return float(np.mean([self.fid(t) for t in step_counts]))


@dataclasses.dataclass
class Request:
    id: int
    prompt: np.ndarray            # (S,) int32
    deadline: float               # seconds from submission
    generated: List[int] = dataclasses.field(default_factory=list)
    cache: Optional[dict] = None


class ServingEngine:
    def __init__(self, cfg: ModelConfig, params, run: RunConfig,
                 max_len: int, delay: Optional[DelayModel] = None,
                 quality: Optional[QualityModel] = None,
                 extras=None, scheduler="stacking"):
        # registry name or Scheduler callable (repro.api); lazy import
        # keeps serving -> api -> serving from becoming an import cycle
        from repro.api.registry import SCHEDULERS
        self.cfg, self.params, self.run = cfg, params, run
        self.max_len = max_len
        self.delay = delay or DelayModel(a=0.002, b=0.02)
        self.quality = quality or TokenQuality()
        self.scheduler = SCHEDULERS.resolve(scheduler)
        self.extras = extras
        self.requests: Dict[int, Request] = {}
        self.last_timings: List[tuple] = []
        self._next_id = 0
        self._prefill = jax.jit(api.make_prefill_step(cfg, run, max_len))
        self._decode = jax.jit(api.make_decode_step(cfg, run))
        # batch axis per cache leaf, derived structurally: the axis whose
        # size changes between an abstract batch=1 and batch=2 cache
        mod = api.get_model(cfg)
        c1 = mod.init_cache(cfg, 1, max_len, run, abstract=True)
        c2 = mod.init_cache(cfg, 2, max_len, run, abstract=True)
        self._batch_axes = jax.tree_util.tree_map(
            lambda a, b: next(i for i, (x, y) in
                              enumerate(zip(a.shape, b.shape)) if x != y),
            c1, c2)

    # ------------------------------------------------------------------
    def submit(self, prompt: np.ndarray, deadline: float) -> int:
        rid = self._next_id
        self._next_id += 1
        self.requests[rid] = Request(id=rid, prompt=np.asarray(prompt),
                                     deadline=deadline)
        return rid

    def measure_decode_delay(self, batch_sizes=(1, 2, 4, 8),
                             reps: int = 2) -> DelayModel:
        """Fig.-1a-style calibration for decode steps on this hardware."""
        from repro.core.delay_model import fit
        S = min(32, self.max_len - 2)
        xs, ys = [], []
        for X in batch_sizes:
            toks = np.zeros((X, S), np.int32)
            _, cache = self._prefill(self.params, toks, self.extras)
            tok = jnp.zeros((X, 1), jnp.int32)
            out = self._decode(self.params, tok, cache, self.extras)
            jax.block_until_ready(out)
            best = float("inf")
            for _ in range(reps):
                t0 = time.perf_counter()
                out = self._decode(self.params, tok, cache, self.extras)
                jax.block_until_ready(out)
                best = min(best, time.perf_counter() - t0)
            xs.append(X)
            ys.append(best)
        self.delay = fit(xs, ys)
        return self.delay

    # ------------------------------------------------------------------
    def plan(self) -> BatchPlan:
        """Scheduler (default STACKING) over queued requests: token
        budget from deadlines."""
        svcs = [ServiceRequest(id=r.id, deadline=r.deadline,
                               spectral_eff=1.0)
                for r in self.requests.values()]
        tau_prime = {r.id: r.deadline for r in self.requests.values()}
        return self.scheduler(svcs, tau_prime, self.delay, self.quality)

    def _ensure_prefilled(self, rids: List[int]) -> None:
        todo = [rid for rid in rids if self.requests[rid].cache is None]
        if not todo:
            return
        # group equal-length prompts into one prefill call
        by_len: Dict[int, List[int]] = {}
        for rid in todo:
            by_len.setdefault(len(self.requests[rid].prompt), []).append(rid)
        for L, group in by_len.items():
            toks = np.stack([self.requests[rid].prompt for rid in group])
            _, cache = self._prefill(self.params, toks, self.extras)
            for i, rid in enumerate(group):
                self.requests[rid].cache = jax.tree_util.tree_map(
                    lambda ax, x: x[_slice_at(x.ndim, ax, i)],
                    self._batch_axes, cache)

    def step_batch(self, rids: List[int], timed: bool = False) -> float:
        """One batched decode step for ``rids``: gather their B=1 KV
        caches, decode, scatter back, append the argmax token.  Returns
        the steady-state wall-clock seconds when ``timed`` (also logged
        to ``self.last_timings``); 0.0 otherwise."""
        self._ensure_prefilled(rids)
        caches = [self.requests[rid].cache for rid in rids]
        stacked = jax.tree_util.tree_map(
            lambda ax, *xs: jnp.concatenate(xs, axis=ax),
            self._batch_axes, *caches)
        last = np.stack(
            [[self.requests[rid].generated[-1]
              if self.requests[rid].generated
              else self.requests[rid].prompt[-1]] for rid in rids])
        toks = jnp.asarray(last, jnp.int32)
        dt = 0.0
        if timed:
            warm = self._decode(self.params, toks, stacked, self.extras)
            jax.block_until_ready(warm)
            t0 = time.perf_counter()
            out = self._decode(self.params, toks, stacked, self.extras)
            jax.block_until_ready(out)
            dt = time.perf_counter() - t0
            self.last_timings.append((len(rids), dt))
            logits, stacked = out
        else:
            logits, stacked = self._decode(self.params, toks,
                                           stacked, self.extras)
        nxt = np.asarray(jnp.argmax(logits[:, -1], axis=-1))
        for i, rid in enumerate(rids):
            self.requests[rid].generated.append(int(nxt[i]))
            self.requests[rid].cache = jax.tree_util.tree_map(
                lambda ax, x: x[_slice_at(x.ndim, ax, i)],
                self._batch_axes, stacked)
        return dt

    def execute(self, plan: BatchPlan, sample_key=None,
                timed: bool = False) -> Dict[int, list]:
        """Run the plan: one batched decode_step per plan batch.

        timed: record steady-state (batch_size, seconds) per batch in
        ``self.last_timings`` (Fig.-1a measurement during serving; the
        provisioner's calibrate->replan loop refits g(X) from these).
        """
        self.last_timings = []
        for batch in plan.batches:
            self.step_batch([k for k, _ in batch], timed=timed)
        return {rid: r.generated for rid, r in self.requests.items()}

    def open_session(self, plan: BatchPlan) -> "DecodeSession":
        """Stepwise execution handle for the EXECUTORS registry (see
        ``repro.api.execution``): the closed loop drives one
        ``run_batch`` at a time and may retarget token totals between
        batches."""
        self.last_timings = []
        return DecodeSession(self, plan)

    def serve(self) -> Dict[int, list]:
        return self.execute(self.plan())


class DecodeSession:
    """One plan execution on a ``ServingEngine``, batch by batch.

    Decoding is memoryless per step (no schedule table to rebuild), so
    ``retarget`` only has to validate the new token totals against the
    KV-cache capacity and the no-resurrection rule.
    """

    def __init__(self, engine: ServingEngine, plan: BatchPlan):
        self.engine = engine
        self.steps_done: Dict[int, int] = {
            k: 0 for k in plan.steps_completed}

    def run_batch(self, rids: List[int], timed: bool = False) -> float:
        dt = self.engine.step_batch(list(rids), timed=timed)
        for k in rids:
            self.steps_done[k] += 1
        return dt

    def retarget(self, totals: Dict[int, int]) -> None:
        for k, total in totals.items():
            if total < self.steps_done[k]:
                raise ValueError(
                    f"request {k}: retarget total {total} < "
                    f"{self.steps_done[k]} tokens already decoded")
            req = self.engine.requests[k]
            if len(req.prompt) + int(total) > self.engine.max_len:
                raise ValueError(
                    f"request {k}: prompt {len(req.prompt)} + "
                    f"{total} tokens exceeds max_len="
                    f"{self.engine.max_len}")

    def finish(self) -> Dict[int, list]:
        return {k: list(self.engine.requests[k].generated)
                for k in self.steps_done}


def _slice_at(ndim: int, ax: int, i: int):
    idx = [slice(None)] * ndim
    idx[ax] = slice(i, i + 1)
    return tuple(idx)
