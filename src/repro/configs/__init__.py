"""Architecture registry: importing this package registers every assigned
architecture (plus the paper's own DDIM/CIFAR-10 diffusion config)."""

from repro.configs import (  # noqa: F401
    xlstm_125m,
    deepseek_moe_16b,
    tinyllama_1_1b,
    codeqwen1_5_7b,
    minitron_4b,
    zamba2_2_7b,
    whisper_tiny,
    llama_3_2_vision_90b,
    granite_34b,
    qwen3_moe_30b_a3b,
    ddim_cifar10,
)

ASSIGNED_ARCHS = [
    "xlstm-125m",
    "deepseek-moe-16b",
    "tinyllama-1.1b",
    "codeqwen1.5-7b",
    "minitron-4b",
    "zamba2-2.7b",
    "whisper-tiny",
    "llama-3.2-vision-90b",
    "granite-34b",
    "qwen3-moe-30b-a3b",
]
