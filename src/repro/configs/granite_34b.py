"""Granite-34B-Code [arXiv:2405.04324] — GPT-BigCode style, MQA.

88L d_model=6144 48H (kv=1, multi-query) d_ff=24576 vocab=49152.
Adaptation: learned absolute positions -> RoPE so the 32k/500k assigned
shapes are representable (noted in DESIGN.md §7).
"""

from repro.config import ModelConfig, register

CONFIG = register(ModelConfig(
    name="granite-34b",
    family="dense",
    num_layers=88,
    d_model=6144,
    num_heads=48,
    num_kv_heads=1,
    d_ff=24576,
    vocab_size=49152,
    source="arXiv:2405.04324",
    norm="layernorm",
    activation="gelu",
    gated_mlp=False,
    rope_theta=10000.0,
))
