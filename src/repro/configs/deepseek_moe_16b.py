"""DeepSeekMoE-16B [arXiv:2401.06066] — fine-grained experts.

28L d_model=2048 16H (kv=16) d_ff_expert=1408 vocab=102400,
64 routed experts top-6 + 2 shared experts.
"""

from repro.config import ModelConfig, register

CONFIG = register(ModelConfig(
    name="deepseek-moe-16b",
    family="moe",
    num_layers=28,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    d_ff=1408,                # kept equal to expert dim for the dense path
    vocab_size=102400,
    source="arXiv:2401.06066",
    num_experts=64,
    experts_per_token=6,
    num_shared_experts=2,
    d_ff_expert=1408,
    norm="rmsnorm",
    activation="silu",
    gated_mlp=True,
    rope_theta=10000.0,
))
