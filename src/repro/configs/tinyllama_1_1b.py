"""TinyLlama-1.1B [arXiv:2401.02385] — llama2-architecture small model.

22L d_model=2048 32H (GQA kv=4) d_ff=5632 vocab=32000.
"""

from repro.config import ModelConfig, register

CONFIG = register(ModelConfig(
    name="tinyllama-1.1b",
    family="dense",
    num_layers=22,
    d_model=2048,
    num_heads=32,
    num_kv_heads=4,
    d_ff=5632,
    vocab_size=32000,
    source="arXiv:2401.02385",
    norm="rmsnorm",
    activation="silu",
    gated_mlp=True,
    rope_theta=10000.0,
))
