"""The paper's own GenAI substrate: DDIM pretrained on CIFAR-10.

Not part of the assigned-architecture pool; this is the diffusion U-Net the
paper's batch-denoising measurements (Fig. 1a/1b) are taken from.  Sizes
follow the DDPM/DDIM CIFAR-10 U-Net (~35M params); the `-smoke` variant is
what CPU tests/benches instantiate.
"""

from dataclasses import dataclass
from typing import Tuple


@dataclass(frozen=True)
class UNetConfig:
    name: str = "ddim-cifar10"
    image_size: int = 32
    in_channels: int = 3
    base_channels: int = 128
    channel_mults: Tuple[int, ...] = (1, 2, 2, 2)
    num_res_blocks: int = 2
    attn_resolutions: Tuple[int, ...] = (16,)
    num_groups: int = 32
    dropout: float = 0.0
    num_train_timesteps: int = 1000
    dtype: str = "float32"


CONFIG = UNetConfig()

SMOKE = UNetConfig(
    name="ddim-cifar10-smoke",
    image_size=16,
    base_channels=32,
    channel_mults=(1, 2),
    num_res_blocks=1,
    attn_resolutions=(8,),
    num_groups=8,
)
