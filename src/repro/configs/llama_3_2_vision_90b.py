"""Llama-3.2-Vision-90B [hf:meta-llama/Llama-3.2-11B-Vision, 90B sibling].

100L d_model=8192 64H (GQA kv=8) d_ff=28672 vocab=128256.
100 layers = 80 self-attn + 20 cross-attn (one every 5th layer) consuming
vision tokens.  The ViT vision encoder + projector is a STUB per the
assignment carve-out: input_specs() provides precomputed, projected patch
embeddings (batch, num_vision_tokens, d_model).
"""

from repro.config import ModelConfig, register

CONFIG = register(ModelConfig(
    name="llama-3.2-vision-90b",
    family="vlm",
    num_layers=100,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    d_ff=28672,
    vocab_size=128256,
    source="hf:meta-llama/Llama-3.2-11B-Vision (90B sibling)",
    cross_attn_every=5,
    num_vision_tokens=1601,
    norm="rmsnorm",
    activation="silu",
    gated_mlp=True,
    rope_theta=500000.0,
))
