"""Zamba2-2.7B [arXiv:2411.15242] — Mamba2 backbone + weight-shared attention.

54L d_model=2560 32H (kv=32) d_ff=10240 vocab=32000, ssm_state=64.
One *shared* (single set of weights) attention+MLP block is applied every
6th backbone layer, zamba-style.
"""

from repro.config import ModelConfig, register

CONFIG = register(ModelConfig(
    name="zamba2-2.7b",
    family="hybrid",
    num_layers=54,
    d_model=2560,
    num_heads=32,
    num_kv_heads=32,
    d_ff=10240,
    vocab_size=32000,
    source="arXiv:2411.15242",
    ssm_state=64,
    ssm_conv=4,
    ssm_expand=2,
    ssm_head_dim=64,          # 80 ssm heads = 5120 / 64
    shared_attn_every=6,
    norm="rmsnorm",
    activation="silu",
    gated_mlp=True,
    rope_theta=10000.0,
))
