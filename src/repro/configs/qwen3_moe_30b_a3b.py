"""Qwen3-30B-A3B [hf:Qwen/Qwen3-30B-A3B] — 128-expert MoE, top-8.

48L d_model=2048 32H (GQA kv=4) d_ff_expert=768 vocab=151936,
head_dim=128 (decoupled from d_model/num_heads), per-head q/k RMSNorm,
no shared experts.
"""

from repro.config import ModelConfig, register

CONFIG = register(ModelConfig(
    name="qwen3-moe-30b-a3b",
    family="moe",
    num_layers=48,
    d_model=2048,
    num_heads=32,
    num_kv_heads=4,
    d_ff=768,
    vocab_size=151936,
    source="hf:Qwen/Qwen3-30B-A3B",
    head_dim=128,
    qk_norm=True,
    num_experts=128,
    experts_per_token=8,
    num_shared_experts=0,
    d_ff_expert=768,
    norm="rmsnorm",
    activation="silu",
    gated_mlp=True,
    rope_theta=1000000.0,
))
