"""Minitron-4B [arXiv:2407.14679] — pruned Nemotron.

32L d_model=3072 24H (GQA kv=8) d_ff=9216 vocab=256000.
Nemotron family: squared-ReLU MLP (non-gated), head_dim=128.
"""

from repro.config import ModelConfig, register

CONFIG = register(ModelConfig(
    name="minitron-4b",
    family="dense",
    num_layers=32,
    d_model=3072,
    num_heads=24,
    num_kv_heads=8,
    d_ff=9216,
    vocab_size=256000,
    source="arXiv:2407.14679",
    head_dim=128,
    norm="layernorm",
    activation="relu2",
    gated_mlp=False,
    rope_theta=10000.0,
))
