"""xLSTM-125M [arXiv:2405.04517] — sLSTM + mLSTM blocks, attention-free.

12L d_model=768 4H d_ff=0 vocab=50304.  d_ff=0: xLSTM blocks carry their own
up-projections (mLSTM pre-up x2, sLSTM gated post-FFN) instead of a separate
transformer FFN.  We alternate mLSTM and sLSTM (one sLSTM every 2nd block),
matching the paper's mixed xLSTM[a:b] notation at small scale.
"""

from repro.config import ModelConfig, register

CONFIG = register(ModelConfig(
    name="xlstm-125m",
    family="ssm",
    num_layers=12,
    d_model=768,
    num_heads=4,
    num_kv_heads=4,
    d_ff=0,
    vocab_size=50304,
    source="arXiv:2405.04517",
    # mLSTM internals: matrix-memory heads; state per head = head_dim.
    ssm_expand=2,            # mLSTM pre-up-projection factor
    ssm_head_dim=384,        # d_inner / num_heads = 1536 / 4
    ssm_state=384,           # matrix memory is head_dim x head_dim
    xlstm_slstm_every=2,     # blocks 1,3,5,... are sLSTM
    norm="layernorm",
    gated_mlp=True,
    tie_embeddings=True,
))
