"""Whisper-tiny [arXiv:2212.04356] — encoder-decoder audio backbone.

4L enc + 4L dec, d_model=384 6H d_ff=1536 vocab=51865.
The mel-spectrogram + conv feature extractor is a STUB per the assignment
carve-out: input_specs() provides precomputed frame embeddings
(batch, num_audio_frames, d_model).
"""

from repro.config import ModelConfig, register

CONFIG = register(ModelConfig(
    name="whisper-tiny",
    family="audio",
    num_layers=4,              # decoder layers
    d_model=384,
    num_heads=6,
    num_kv_heads=6,
    d_ff=1536,
    vocab_size=51865,
    source="arXiv:2212.04356",
    encoder_layers=4,
    num_audio_frames=1500,
    norm="layernorm",
    activation="gelu",
    gated_mlp=False,
    rope_theta=10000.0,        # adaptation: sinusoidal/learned -> RoPE for
                               # long decode shapes (noted in DESIGN.md)
))
