"""Unified provisioner API (see docs/API.md).

Public surface: three protocols (Workload / Scheduler / Allocator), a
string-keyed registry per protocol, and the ``Provisioner`` facade whose
``run`` is the one-call end-to-end pipeline.
"""

from repro.api.protocols import (Allocator, Scheduler, Workload,
                                 WorkloadOutput)
from repro.api.registry import (ALLOCATORS, SCHEDULERS, WORKLOADS,
                                get_allocator, get_scheduler, get_workload,
                                list_allocators, list_schedulers,
                                list_workloads, register_allocator,
                                register_scheduler, register_workload)
# entry modules populate the registries on import
from repro.api import allocators as _allocators   # noqa: F401
from repro.api import schedulers as _schedulers   # noqa: F401
from repro.api import workloads as _workloads     # noqa: F401
from repro.api.workloads import DecodeWorkload, DiffusionWorkload
from repro.api.provisioner import Provisioner, ProvisionReport

__all__ = [
    "Allocator", "Scheduler", "Workload", "WorkloadOutput",
    "ALLOCATORS", "SCHEDULERS", "WORKLOADS",
    "register_allocator", "register_scheduler", "register_workload",
    "get_allocator", "get_scheduler", "get_workload",
    "list_allocators", "list_schedulers", "list_workloads",
    "DecodeWorkload", "DiffusionWorkload",
    "Provisioner", "ProvisionReport",
]
