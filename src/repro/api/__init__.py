"""Unified provisioner API (see docs/API.md).

Public surface: three pipeline protocols (Workload / Scheduler /
Allocator) plus online admission policies and multi-server placements,
a string-keyed registry per component kind, the ``Provisioner`` facade
whose ``run`` is the one-call static pipeline, its event-driven sibling
``OnlineProvisioner`` (arrivals over time + on-arrival replanning,
docs/SCENARIOS.md), ``MultiServerProvisioner`` (placement x
per-cell provisioning over M edge servers), ``FleetProvisioner``
(population-scale fleets with named arrival processes,
docs/SCENARIOS.md "Fleet-scale simulation"), and the closed execution
loop (``execute_plan`` / ``execute_report`` behind the EXECUTORS
registry — STACKING plans driven on the real denoiser with online
delay refit, docs/SCENARIOS.md "Sim-to-real").

``provision(scenario, ...)`` is the single front door: it dispatches
on scenario shape and reproduces the matching facade's ``run()``.
"""

from repro.api.base import BaseProvisioner, provision
from repro.api.protocols import (Allocator, OffsetScheduler, Scheduler,
                                 Workload, WorkloadOutput)
from repro.api.registry import (ADMISSIONS, ALLOCATORS, ARRIVALS,
                                EXECUTORS, PLACEMENTS, SCHEDULERS,
                                WORKLOADS,
                                get_admission, get_allocator,
                                get_arrival, get_executor,
                                get_placement, get_scheduler,
                                get_workload,
                                list_admissions, list_allocators,
                                list_arrivals, list_executors,
                                list_placements, list_schedulers,
                                list_workloads,
                                register_admission, register_allocator,
                                register_arrival, register_executor,
                                register_placement, register_scheduler,
                                register_workload)
# entry modules populate the registries on import
from repro.api import allocators as _allocators   # noqa: F401
from repro.api import schedulers as _schedulers   # noqa: F401
from repro.api import workloads as _workloads     # noqa: F401
from repro.api import online as _online           # noqa: F401
from repro.api import placements as _placements   # noqa: F401
from repro.api import fleet as _fleet             # noqa: F401
from repro.api import execution as _execution     # noqa: F401
from repro.api.workloads import DecodeWorkload, DiffusionWorkload
from repro.api.provisioner import Provisioner, ProvisionReport
from repro.api.online import OnlineProvisioner, OnlineReport
from repro.api.multiserver import (MultiOnlineReport,
                                   MultiProvisionReport,
                                   MultiServerProvisioner)
from repro.api.fleet import (FleetProvisioner, FleetReport,
                             make_fleet_scenario)
from repro.api.execution import (execute_plan, execute_report,
                                 make_session, replay_result)
from repro.core.execution import (ExecutionLoop, ExecutionResult,
                                  SimulatedSession)

__all__ = [
    "Allocator", "OffsetScheduler", "Scheduler", "Workload",
    "WorkloadOutput",
    "ADMISSIONS", "ALLOCATORS", "ARRIVALS", "EXECUTORS", "PLACEMENTS",
    "SCHEDULERS", "WORKLOADS",
    "register_admission", "register_allocator", "register_arrival",
    "register_executor", "register_placement", "register_scheduler",
    "register_workload",
    "get_admission", "get_allocator", "get_arrival", "get_executor",
    "get_placement", "get_scheduler", "get_workload",
    "list_admissions", "list_allocators", "list_arrivals",
    "list_executors", "list_placements", "list_schedulers",
    "list_workloads",
    "DecodeWorkload", "DiffusionWorkload",
    "BaseProvisioner", "provision",
    "Provisioner", "ProvisionReport",
    "OnlineProvisioner", "OnlineReport",
    "MultiServerProvisioner", "MultiProvisionReport", "MultiOnlineReport",
    "FleetProvisioner", "FleetReport", "make_fleet_scenario",
    "execute_plan", "execute_report", "make_session", "replay_result",
    "ExecutionLoop", "ExecutionResult", "SimulatedSession",
]
