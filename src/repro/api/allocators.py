"""Allocator registry entries: uniform adapters over the P1 solvers in
``repro.core.bandwidth``.

Every entry has the ``Allocator`` signature
``(scenario, scheduler, delay, quality, **kwargs) -> np.ndarray``;
the closed-form splits simply ignore the scheduler/models, and the
search-based ones pass ``**kwargs`` through (``num_particles``,
``iters``, ``seed``, ...), so registry users keep full control of the
underlying solvers.
"""

from __future__ import annotations

import numpy as np

from repro.api.registry import register_allocator
from repro.core.bandwidth import (coordinate_refine, equal_allocate,
                                  inv_se_allocate, pso_allocate)
from repro.core.delay_model import DelayModel
from repro.core.quality_model import QualityModel
from repro.core.service import Scenario


@register_allocator("equal")
def equal(scn: Scenario, scheduler=None, delay: DelayModel = None,
          quality: QualityModel = None, **_) -> np.ndarray:
    return equal_allocate(scn)


@register_allocator("inv_se")
def inv_se(scn: Scenario, scheduler=None, delay: DelayModel = None,
           quality: QualityModel = None, **_) -> np.ndarray:
    return inv_se_allocate(scn)


@register_allocator("pso")
def pso(scn: Scenario, scheduler, delay: DelayModel,
        quality: QualityModel, *, seed: int = 0, **kw) -> np.ndarray:
    # seed is explicit (not swallowed by **kw) so the facades' seed=
    # kwarg can find it by signature (BaseProvisioner._seeded_kwargs)
    return pso_allocate(scn, scheduler, delay, quality, seed=seed,
                        **kw).alloc


@register_allocator("coordinate")
def coordinate(scn: Scenario, scheduler, delay: DelayModel,
               quality: QualityModel, *, init: str = "inv_se",
               **kw) -> np.ndarray:
    """Deterministic hill-climb refinement of a closed-form split
    (``init``: any registered allocator name, default ``inv_se``)."""
    from repro.api.registry import get_allocator
    start = get_allocator(init)(scn, scheduler, delay, quality)
    return coordinate_refine(scn, start, scheduler, delay, quality,
                             **kw).alloc
