"""String-keyed registries behind the provisioner API.

Seven registries — schedulers (P2 solvers), allocators (P1 solvers),
workloads (step executors), admissions (online accept/reject policies),
placements (multi-server assignment strategies), arrivals (traffic
processes for fleet simulation) and executors (stepwise session
factories for closed-loop plan execution, ``repro.api.execution``) — so
every pipeline component is addressable by name
(``Provisioner(scn, scheduler="stacking", allocator="pso")``,
``OnlineProvisioner(scn, admission="deadline_feasible")``,
``MultiServerProvisioner(scn, placement="greedy_fid")``) and new
variants plug in with a one-line decorator:

    @register_scheduler("my_sched")
    def my_sched(services, tau_prime, delay, quality): ...
"""

from __future__ import annotations

from typing import Any, Callable, Dict, List, Sequence


class Registry:
    """Name -> object map with decorator registration and helpful errors."""

    def __init__(self, kind: str):
        self.kind = kind
        self._items: Dict[str, Any] = {}

    def register(self, name: str, obj: Any = None,
                 *, aliases: Sequence[str] = ()) -> Any:
        """Register ``obj`` (or decorate) under ``name`` and any aliases."""
        def deco(o):
            for n in (name, *aliases):
                if n in self._items:
                    raise ValueError(
                        f"{self.kind} '{n}' is already registered")
                self._items[n] = o
            return o
        return deco(obj) if obj is not None else deco

    def get(self, name: str) -> Any:
        try:
            return self._items[name]
        except KeyError:
            raise KeyError(
                f"unknown {self.kind} '{name}'; registered: "
                f"{', '.join(sorted(self._items)) or '(none)'}") from None

    def resolve(self, spec: Any) -> Any:
        """Look up a string; pass anything else (callable/instance) through."""
        return self.get(spec) if isinstance(spec, str) else spec

    def names(self) -> List[str]:
        return sorted(self._items)

    def __contains__(self, name: str) -> bool:
        return name in self._items


def display_name(spec: Any) -> str:
    """Human-readable name for a registry spec: the string itself, or a
    callable/instance's best-effort name (report headers use this)."""
    if isinstance(spec, str):
        return spec
    return getattr(spec, "__name__", type(spec).__name__)


SCHEDULERS = Registry("scheduler")
ALLOCATORS = Registry("allocator")
WORKLOADS = Registry("workload")
ADMISSIONS = Registry("admission")
PLACEMENTS = Registry("placement")
ARRIVALS = Registry("arrival process")
EXECUTORS = Registry("executor")


def register_scheduler(name: str, obj: Any = None, **kw):
    return SCHEDULERS.register(name, obj, **kw)


def register_allocator(name: str, obj: Any = None, **kw):
    return ALLOCATORS.register(name, obj, **kw)


def register_workload(name: str, obj: Any = None, **kw):
    return WORKLOADS.register(name, obj, **kw)


def register_admission(name: str, obj: Any = None, **kw):
    return ADMISSIONS.register(name, obj, **kw)


def register_placement(name: str, obj: Any = None, **kw):
    return PLACEMENTS.register(name, obj, **kw)


def register_arrival(name: str, obj: Any = None, **kw):
    return ARRIVALS.register(name, obj, **kw)


def register_executor(name: str, obj: Any = None, **kw):
    return EXECUTORS.register(name, obj, **kw)


def get_scheduler(name: str) -> Callable:
    return SCHEDULERS.get(name)


def get_allocator(name: str) -> Callable:
    return ALLOCATORS.get(name)


def get_workload(name: str) -> Any:
    return WORKLOADS.get(name)


def get_admission(name: str) -> Callable:
    return ADMISSIONS.get(name)


def get_placement(name: str) -> Callable:
    return PLACEMENTS.get(name)


def get_arrival(name: str) -> Callable:
    return ARRIVALS.get(name)


def get_executor(name: str) -> Callable:
    return EXECUTORS.get(name)


def list_schedulers() -> List[str]:
    return SCHEDULERS.names()


def list_allocators() -> List[str]:
    return ALLOCATORS.names()


def list_workloads() -> List[str]:
    return WORKLOADS.names()


def list_admissions() -> List[str]:
    return ADMISSIONS.names()


def list_placements() -> List[str]:
    return PLACEMENTS.names()


def list_arrivals() -> List[str]:
    return ARRIVALS.names()


def list_executors() -> List[str]:
    return EXECUTORS.names()
