"""Closed-loop plan execution behind the EXECUTORS registry.

The seventh registry maps executor names to *session factories*: a
session is a stepwise execution handle (``run_batch`` / ``retarget`` /
``finish`` — see ``repro.core.execution``) that
``repro.core.execution.ExecutionLoop`` drives batch by batch, measuring
wall-clock, refitting the delay model and replanning on drift.

Built-in entries:

  * ``"diffusion"``  — ``BatchDenoisingExecutor`` sessions (the DDIM
                       U-Net with the Pallas kernels)
  * ``"llm_decode"`` — ``ServingEngine`` decode sessions
  * ``"simulated"``  — synthetic wall-clock from a hidden true
                       ``DelayModel`` (fast deterministic tests /
                       what-if drift studies); takes ``true_delay=``,
                       ``noise=``, ``seed=`` via ``executor_kwargs``

Entry points:

  * ``execute_plan``   — run a (scenario, plan, allocation) on a
                         workload's executor, open or closed loop
  * ``execute_report`` — the same, resolving everything from a
                         ``ProvisionReport``
  * ``replay_result``  — re-run an online result's committed batch
                         sequence on a real executor (open loop)
"""

from __future__ import annotations

import functools
from typing import Optional

from repro.api.protocols import WorkloadOutput
from repro.api.registry import (ALLOCATORS, EXECUTORS, SCHEDULERS,
                                WORKLOADS, register_executor)
from repro.core.delay_model import DelayModel
from repro.core.execution import (ExecutionLoop, ExecutionResult,
                                  SimulatedSession)
from repro.core.plan import BatchPlan


@register_executor("diffusion")
def _diffusion_session(workload, plan, key, **kw):
    return workload.open_session(plan, key, **kw)


@register_executor("llm_decode")
def _llm_decode_session(workload, plan, key,
                        exec_engine: Optional[str] = None, **kw):
    if exec_engine not in (None, "dict"):
        raise ValueError(f"llm_decode executor has no "
                         f"exec_engine={exec_engine!r} (the bucketed "
                         f"engine is diffusion-only)")
    return workload.open_session(plan, key, **kw)


@register_executor("simulated")
def _simulated_session(workload, plan, key, *, true_delay: DelayModel,
                       noise: float = 0.0, seed: int = 0,
                       exec_engine: Optional[str] = None):
    if exec_engine not in (None, "dict"):
        raise ValueError(f"simulated executor has no "
                         f"exec_engine={exec_engine!r} (the bucketed "
                         f"engine is diffusion-only)")
    return SimulatedSession(plan, true_delay, noise=noise, seed=seed)


def make_session(workload, plan: BatchPlan, key=None, *,
                 executor=None, executor_kwargs: Optional[dict] = None):
    """Open a stepwise execution session.  ``executor`` is an EXECUTORS
    name or factory; ``None`` uses the workload's own name (so a
    ``DiffusionWorkload`` opens a ``DenoiseSession`` etc.)."""
    if executor is None:
        executor = getattr(workload, "name", None)
        if executor is None:
            raise ValueError(
                "no executor: attach a named workload or pass "
                f"executor= (registered: {EXECUTORS.names()})")
    factory = EXECUTORS.resolve(executor)
    return factory(workload, plan, key, **(executor_kwargs or {}))


def execute_plan(scenario, plan: BatchPlan, alloc, workload=None, *,
                 mode: str = "closed", key=None, scheduler="stacking",
                 allocator="inv_se", delay: Optional[DelayModel] = None,
                 quality=None, engine: Optional[str] = None,
                 validate: bool = True, executor=None,
                 executor_kwargs: Optional[dict] = None,
                 window: int = 32, drift_tol: float = 0.25,
                 min_batches: int = 3, max_replans: int = 8,
                 headroom: float = 1.0,
                 exec_engine: Optional[str] = None) -> ExecutionResult:
    """Execute a planned batch schedule on a real (or simulated)
    executor.  ``mode="open"`` runs the plan as given (telemetry +
    rolling refit only); ``mode="closed"`` replans mid-flight through
    the offset-aware path when measured delay drifts (``drift_tol``,
    ``min_batches``, ``max_replans``, ``headroom`` tune the loop).
    ``exec_engine`` picks the denoising session engine (``"dict"`` /
    ``"bucketed"``; ``None`` = the executor's default) and is recorded
    in the result telemetry."""
    if exec_engine is not None:
        executor_kwargs = dict(executor_kwargs or {})
        executor_kwargs.setdefault("exec_engine", exec_engine)
    session = make_session(workload, plan, key, executor=executor,
                           executor_kwargs=executor_kwargs)
    loop = ExecutionLoop(
        scenario, plan, alloc, session, delay=delay, quality=quality,
        scheduler=SCHEDULERS.resolve(scheduler),
        allocator=ALLOCATORS.resolve(allocator),
        mode=mode, window=window, drift_tol=drift_tol,
        min_batches=min_batches, max_replans=max_replans,
        headroom=headroom, validate=validate, engine=engine,
        exec_engine=(executor_kwargs or {}).get("exec_engine"))
    return loop.run()


def execute_report(report, workload=None, *, mode: str = "closed",
                   key=None, **kwargs) -> ExecutionResult:
    """``execute_plan`` with everything resolved from a
    ``ProvisionReport``: its scenario, allocation, plan, delay/quality
    models and component names.  ``workload`` is a WORKLOADS name or
    instance (``None`` works with ``executor="simulated"``); remaining
    keywords are ``execute_plan``'s."""
    wl = WORKLOADS.resolve(workload) if workload is not None else None
    if isinstance(wl, type):
        wl = wl()
    scheduler = kwargs.pop("scheduler", None)
    if scheduler is None:
        name = getattr(report, "scheduler_name", "")
        scheduler = name if name in SCHEDULERS else "stacking"
    allocator = kwargs.pop("allocator", None)
    if allocator is None:
        name = getattr(report, "allocator_name", "")
        allocator = name if name in ALLOCATORS else "inv_se"
    kwargs.setdefault("delay", report.delay)
    kwargs.setdefault("quality", report.quality)
    return execute_plan(report.scenario, report.plan, report.allocation,
                        wl, mode=mode, key=key, scheduler=scheduler,
                        allocator=allocator, **kwargs)


def replay_plan(executed_batches, steps_completed,
                delay: DelayModel) -> BatchPlan:
    """A ``BatchPlan`` replaying an online run's committed batch
    sequence (``OnlineResult.executed_batches``): same batches, same
    order, simulated start instants as start times."""
    counters: dict = {}
    batches, starts = [], []
    for t_start, ids in executed_batches:
        batch = []
        for k in ids:
            batch.append((k, counters.get(k, 0)))
            counters[k] = counters.get(k, 0) + 1
        batches.append(batch)
        starts.append(float(t_start))
    assert counters == {k: v for k, v in steps_completed.items() if v}, \
        "executed batch log disagrees with final step counts"
    return BatchPlan(batches=batches, start_times=starts,
                     steps_completed=dict(counters), delay=delay)


def replay_result(workload, result, delay: DelayModel, key=None, *,
                  executor=None,
                  executor_kwargs: Optional[dict] = None) \
        -> WorkloadOutput:
    """Re-run an ``OnlineResult``'s committed batch sequence on a real
    executor, open loop, with per-batch timing — the online facades'
    ``execute=True`` path."""
    if result.executed_batches is None:
        raise ValueError("this result carries no executed-batch log "
                         "(multi-server results interleave per cell)")
    steps = {o.id: o.steps for o in result.outcomes}
    plan = replay_plan(result.executed_batches, steps, delay)
    session = make_session(workload, plan, key, executor=executor,
                           executor_kwargs=executor_kwargs)
    timings = []
    for _, ids in result.executed_batches:
        timings.append((len(ids), session.run_batch(ids, timed=True)))
    return WorkloadOutput(content=session.finish(), timings=timings)


def with_kwargs(fn, kwargs: Optional[dict]):
    """Bind component kwargs (allocator seeds etc.) onto a protocol
    callable — shared by the facades."""
    return functools.partial(fn, **kwargs) if kwargs else fn
