"""Multi-server front end: placement x per-cell provisioning.

    from repro.api import MultiServerProvisioner
    from repro.core.service import make_scenario

    scn = make_scenario(K=12, n_servers=3,
                        server_speed_range=(0.7, 1.3), seed=0)
    multi = MultiServerProvisioner(scn, placement="greedy_fid",
                                   scheduler="stacking",
                                   allocator="inv_se").run()
    print(multi.summary())

``MultiServerProvisioner`` is ``Provisioner`` scaled out to M edge
cells: a fifth registry of *placement* strategies decides which cell
hosts each service, then every cell runs the familiar per-cell
allocate -> plan -> simulate pipeline (on its own bandwidth budget and
speed-scaled delay model).  ``run`` returns a ``MultiProvisionReport``
bundling one ``ProvisionReport`` per non-empty server plus the merged
per-service view; ``run_online`` is the event-driven counterpart
(arrivals routed to a server at admission time, one plan track per
cell — see ``repro.core.multiserver``).

With ``n_servers == 1`` both paths reproduce the single-server
``Provisioner`` / ``OnlineProvisioner`` results exactly
(tests/test_multiserver.py enforces bit-equality).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Dict, List, Optional

import numpy as np

from repro.api.base import BaseProvisioner, report_dict
from repro.api.registry import (ADMISSIONS, ALLOCATORS, PLACEMENTS,
                                SCHEDULERS, display_name)
# entry modules populate the underlying registries on import
from repro.api import allocators as _allocators   # noqa: F401
from repro.api import placements as _placements   # noqa: F401
from repro.api import schedulers as _schedulers   # noqa: F401
from repro.api import online as _online           # noqa: F401
from repro.api.provisioner import ProvisionReport
from repro.core.delay_model import DelayModel
from repro.core.multiserver import (MultiOnlineResult, MultiSimResult,
                                    provision_multi, simulate_online_multi)
from repro.core.quality_model import PowerLawFID, QualityModel
from repro.core.service import Scenario
from repro.core.simulator import SimResult


@dataclasses.dataclass
class MultiProvisionReport:
    """Everything one multi-server round produced: the assignment, one
    ``ProvisionReport`` per non-empty cell, and the merged view."""
    scenario: Scenario
    assignment: np.ndarray                 # server index per service
    reports: List[ProvisionReport]         # one per non-empty server
    server_ids: List[int]                  # reports[i] ran on server_ids[i]
    sim: SimResult                         # merged, scenario order
    placement_name: str = ""
    scheduler_name: str = ""
    allocator_name: str = ""

    @property
    def mean_fid(self) -> float:
        return self.sim.mean_fid

    @property
    def outage_rate(self) -> float:
        return self.sim.outage_rate

    @property
    def n_servers(self) -> int:
        return self.scenario.n_servers

    def report_for(self, server_id: int) -> Optional[ProvisionReport]:
        for sid, rep in zip(self.server_ids, self.reports):
            if sid == server_id:
                return rep
        return None

    def summary(self) -> str:
        counts = {sid: rep.scenario.K
                  for sid, rep in zip(self.server_ids, self.reports)}
        head = (f"[multi x{self.n_servers}] "
                f"placement={self.placement_name} "
                f"scheduler={self.scheduler_name} "
                f"allocator={self.allocator_name} "
                f"services/server={counts}")
        return head + "\n" + self.sim.summary()

    def to_dict(self) -> dict:
        """Common report protocol (``repro.api.base.report_dict``)."""
        makespans = [r.plan.makespan() for r in self.reports]
        return report_dict(
            "multi", mean_fid=self.mean_fid,
            outage_rate=self.outage_rate,
            makespan=max(makespans) if makespans else None,
            components={"placement": self.placement_name,
                        "scheduler": self.scheduler_name,
                        "allocator": self.allocator_name},
            telemetry={"services_per_server": {
                str(sid): rep.scenario.K
                for sid, rep in zip(self.server_ids, self.reports)}},
            n_servers=self.n_servers)


@dataclasses.dataclass
class MultiOnlineReport:
    """Online multi-server run: outcomes + admission log + where every
    admitted service ran."""
    scenario: Scenario
    result: MultiOnlineResult
    placement_name: str = ""
    scheduler_name: str = ""
    allocator_name: str = ""
    admission_name: str = ""

    @property
    def assignment(self) -> Dict[int, int]:
        return self.result.assignment

    @property
    def mean_fid(self) -> float:
        return self.result.mean_fid

    @property
    def outage_rate(self) -> float:
        return self.result.outage_rate

    @property
    def reject_rate(self) -> float:
        return self.result.reject_rate

    @property
    def handoffs(self) -> int:
        return self.result.handoffs

    def summary(self) -> str:
        head = (f"[multi-online x{self.scenario.n_servers}] "
                f"placement={self.placement_name} "
                f"scheduler={self.scheduler_name} "
                f"allocator={self.allocator_name} "
                f"admission={self.admission_name} "
                f"handoffs={self.handoffs}")
        return head + "\n" + self.result.result.summary()

    def to_dict(self) -> dict:
        """Common report protocol (``repro.api.base.report_dict``)."""
        arrival = {s.id: s.arrival for s in self.scenario.services}
        times = [arrival[o.id] + o.e2e_delay
                 for o in self.result.result.outcomes if o.steps > 0]
        return report_dict(
            "multi_online", mean_fid=self.mean_fid,
            outage_rate=self.outage_rate,
            makespan=max(times) if times else None,
            components={"placement": self.placement_name,
                        "scheduler": self.scheduler_name,
                        "allocator": self.allocator_name,
                        "admission": self.admission_name},
            telemetry={"handoffs": self.handoffs},
            reject_rate=self.reject_rate,
            n_servers=self.scenario.n_servers)


class MultiServerProvisioner(BaseProvisioner):
    """Facade binding a (multi-server) scenario to one
    (placement, scheduler, allocator) choice.  All three accept registry
    names or protocol instances; ``placement_kwargs`` /
    ``allocator_kwargs`` pass through to the underlying strategies.
    ``engine``/``devices``/``seed``/``execute`` are the unified facade
    kwargs (``repro.api.base``).

    The static ``run`` is analytic (allocation + plans + simulated
    timelines); attach workloads per cell by feeding each
    ``reports[i]`` sub-scenario to a ``Provisioner`` if execution on a
    real model is needed (``execute=`` here raises
    ``NotImplementedError`` pointing at that per-cell path).

    The ``placement`` strategy is a *static* full-assignment solver and
    applies to ``run`` only; ``run_online`` routes arrivals one at a
    time with its own ``online_placement`` hook (default
    earliest-free), since a static placement cannot see arrivals it
    does not know about yet.
    """

    _LEGACY = ("placement", "scheduler", "allocator", "delay", "quality",
               "placement_kwargs", "allocator_kwargs", "engine")
    _LEGACY_DEFAULTS = {"placement": "least_loaded",
                        "scheduler": "stacking", "allocator": "pso",
                        "delay": None, "quality": None,
                        "placement_kwargs": None,
                        "allocator_kwargs": None, "engine": None}

    def __init__(self, scenario: Scenario, *args,
                 placement="least_loaded", scheduler="stacking",
                 allocator="pso", delay: Optional[DelayModel] = None,
                 quality: Optional[QualityModel] = None,
                 placement_kwargs: Optional[dict] = None,
                 allocator_kwargs: Optional[dict] = None,
                 engine: Optional[str] = None, devices=None,
                 seed: Optional[int] = None, execute=None,
                 execute_kwargs: Optional[dict] = None):
        kw = self._legacy_positionals(args, dict(
            placement=placement, scheduler=scheduler, allocator=allocator,
            delay=delay, quality=quality,
            placement_kwargs=placement_kwargs,
            allocator_kwargs=allocator_kwargs, engine=engine))
        placement, scheduler = kw["placement"], kw["scheduler"]
        allocator, delay, quality = (kw["allocator"], kw["delay"],
                                     kw["quality"])
        placement_kwargs, allocator_kwargs = (kw["placement_kwargs"],
                                              kw["allocator_kwargs"])
        super().__init__(scenario, engine=kw["engine"], devices=devices,
                         seed=seed, execute=execute,
                         execute_kwargs=execute_kwargs)
        self.placement_name = display_name(placement)
        self.scheduler_name = display_name(scheduler)
        self.allocator_name = display_name(allocator)
        self.placement = PLACEMENTS.resolve(placement)
        self.scheduler = SCHEDULERS.resolve(scheduler)
        self.allocator = ALLOCATORS.resolve(allocator)
        self.delay = delay if delay is not None else DelayModel()
        self.quality = quality if quality is not None else PowerLawFID()
        self.placement_kwargs = dict(placement_kwargs or {})
        self.allocator_kwargs = self._seeded_kwargs(allocator,
                                                    allocator_kwargs)

    def _check_no_execute(self, execute):
        mode = self._resolve_execute(execute)
        if mode:
            raise NotImplementedError(
                "multi-server execution is per cell: run() then feed "
                "each reports[i] to repro.api.execution.execute_report "
                "(or a per-cell Provisioner with execute=)")

    def _allocator(self):
        if self.allocator_kwargs:
            return functools.partial(self.allocator,
                                     **self.allocator_kwargs)
        return self.allocator

    def place(self) -> np.ndarray:
        """The placement stage alone: server index per service."""
        return np.asarray(self.placement(
            self.scenario, self.scheduler, self._allocator(), self.delay,
            self.quality, **self.placement_kwargs))

    def run(self, *, assignment=None, validate: bool = True,
            execute=None) -> MultiProvisionReport:
        """Place -> per-cell allocate -> plan -> validate -> simulate.

        ``assignment`` overrides the placement stage (a precomputed
        server index per service), mirroring ``Provisioner.run``'s
        compositionality.
        """
        self._check_no_execute(execute)
        if assignment is None:
            assignment = self.place()
        assignment = np.asarray(assignment)
        multi: MultiSimResult = provision_multi(
            self.scenario, assignment, self.scheduler, self._allocator(),
            self.delay, self.quality, validate=validate,
            engine=self.engine)
        reports, server_ids = [], []
        for rep in multi.per_server:
            reports.append(ProvisionReport(
                scenario=rep.scenario, allocation=rep.allocation,
                tau_prime=rep.tau_prime, plan=rep.plan, sim=rep.sim,
                delay=rep.server.delay_model(self.delay),
                quality=self.quality,
                scheduler_name=self.scheduler_name,
                allocator_name=self.allocator_name,
                workload_name=f"server{rep.server.id}"))
            server_ids.append(rep.server.id)
        merged = SimResult(outcomes=multi.outcomes,
                           mean_fid=multi.mean_fid,
                           outage_rate=multi.outage_rate)
        return MultiProvisionReport(
            scenario=self.scenario, assignment=assignment,
            reports=reports, server_ids=server_ids, sim=merged,
            placement_name=self.placement_name,
            scheduler_name=self.scheduler_name,
            allocator_name=self.allocator_name)

    def run_online(self, admission="admit_all", online_placement=None,
                   admission_kwargs: Optional[dict] = None, *,
                   handoff: bool = False, validate: bool = True,
                   execute=None) -> MultiOnlineReport:
        """Event-driven arrivals over the M cells.

        ``online_placement`` is a per-arrival router
        ``(svc, sim) -> server index`` (default: earliest-free cell;
        ``repro.core.multiserver.best_projection`` trial-replans on
        every cell).  The constructor's static ``placement`` does NOT
        apply here — it solves a full assignment, which has no meaning
        when requests are revealed one at a time.  ``admission`` takes
        registry names or callables as in ``OnlineProvisioner``.
        ``handoff=True`` lets pending not-yet-started services migrate
        to a strictly better cell at each replan instant (the report's
        ``handoffs`` counts the moves).
        """
        self._check_no_execute(execute)
        adm = ADMISSIONS.resolve(admission)
        if admission_kwargs:
            adm = functools.partial(adm, **admission_kwargs)
        result = simulate_online_multi(
            self.scenario, self.scheduler, self._allocator(),
            delay=self.delay, quality=self.quality, admission=adm,
            placement=online_placement, handoff=handoff,
            validate=validate, engine=self.engine)
        return MultiOnlineReport(
            scenario=self.scenario, result=result,
            placement_name=(display_name(online_placement)
                            if online_placement else "earliest_free"),
            scheduler_name=self.scheduler_name,
            allocator_name=self.allocator_name,
            admission_name=display_name(admission))
