"""The one-call end-to-end pipeline.

    from repro.api import Provisioner
    report = Provisioner(scenario, workload="diffusion",
                         scheduler="stacking", allocator="pso").run(key)

runs P1 (bandwidth allocation) -> P2 (batch-denoising plan) -> execution
on the workload's real model, and bundles everything a figure script or
serving loop needs into a ``ProvisionReport``.  Components are registry
names or protocol instances; omitting the workload gives the pure
analytic pipeline (allocation + plan + simulated timeline, no model).

For requests arriving *over time* instead of a static batch, the
event-driven sibling ``repro.api.online.OnlineProvisioner`` replays this
same allocate -> plan composition on every admitted arrival
(docs/SCENARIOS.md).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from repro.api.base import BaseProvisioner, report_dict
from repro.api.protocols import WorkloadOutput
from repro.api.registry import (ALLOCATORS, SCHEDULERS, WORKLOADS,
                                display_name)
# importing the entry modules populates the registries
from repro.api import allocators as _allocators   # noqa: F401
from repro.api import schedulers as _schedulers   # noqa: F401
from repro.api import workloads as _workloads     # noqa: F401
from repro.core.bandwidth import make_plan
from repro.core.delay_model import DelayModel, fit
from repro.core.execution import ExecutionResult
from repro.core.plan import BatchPlan
from repro.core.quality_model import PowerLawFID, QualityModel
from repro.core.service import Scenario
from repro.core.simulator import SimResult, simulate


@dataclasses.dataclass
class ProvisionReport:
    """Everything one provisioning round produced."""
    scenario: Scenario
    allocation: np.ndarray                    # B_k (Hz), sums to budget
    tau_prime: Dict[int, float]               # generation budgets
    plan: BatchPlan                           # P2 solution
    sim: SimResult                            # analytic timeline + quality
    content: Optional[Dict[int, Any]] = None  # per-service artifacts
    timings: List[Tuple[int, float]] = dataclasses.field(
        default_factory=list)                 # measured (batch_size, s)
    delay: Optional[DelayModel] = None
    quality: Optional[QualityModel] = None
    scheduler_name: str = ""
    allocator_name: str = ""
    workload_name: str = ""
    execution: Optional[ExecutionResult] = None  # closed/open-loop run

    @property
    def mean_fid(self) -> float:
        return self.sim.mean_fid

    @property
    def outage_rate(self) -> float:
        return self.sim.outage_rate

    def refit_delay(self) -> DelayModel:
        """Fit g(X) = aX + b from this run's measured per-batch timings
        (requires a timed execution with >= 2 distinct batch sizes) —
        the calibrate->replan loop's measurement half."""
        sizes = [x for x, _ in self.timings]
        if len(set(sizes)) < 2:
            raise ValueError(
                "need timed batches of >= 2 distinct sizes to refit; "
                "run with timed=True on a plan with varied batch sizes")
        m = fit(sizes, [s for _, s in self.timings])
        # least squares can extrapolate a (slightly) negative slope or
        # intercept from noisy timings; delays are physically nonnegative
        # and the schedulers require g(X) > 0
        return DelayModel(a=max(m.a, 0.0), b=max(m.b, 1e-6))

    def summary(self) -> str:
        head = (f"[{self.workload_name or 'analytic'}] "
                f"scheduler={self.scheduler_name} "
                f"allocator={self.allocator_name} "
                f"batches={self.plan.num_batches}")
        body = head + "\n" + self.sim.summary()
        if self.execution is not None:
            body += "\n" + self.execution.summary()
        return body

    def to_dict(self) -> dict:
        """Common report protocol (see ``repro.api.base.report_dict``):
        JSON-serializable aggregates, no model artifacts."""
        d = report_dict(
            "provision", mean_fid=self.mean_fid,
            outage_rate=self.outage_rate, makespan=self.plan.makespan(),
            components={"scheduler": self.scheduler_name,
                        "allocator": self.allocator_name,
                        "workload": self.workload_name},
            telemetry={"batches": self.plan.num_batches,
                       "timings": [[int(x), float(s)]
                                   for x, s in self.timings]},
            n_services=self.scenario.K)
        if self.execution is not None:
            d["execution"] = self.execution.to_dict()
            # per-kernel attribution (ROADMAP follow-up from PR 9):
            # measured wall-clock grouped by padded batch-shape bucket,
            # so drift points at a groupnorm/attention shape regime
            d["telemetry"]["exec_engine"] = d["execution"]["exec_engine"]
            d["telemetry"]["per_bucket"] = \
                d["execution"]["telemetry"]["per_bucket"]
        return d


class Provisioner(BaseProvisioner):
    """Facade binding a scenario to one (workload, scheduler, allocator)
    choice.  ``scheduler``/``allocator``/``workload`` accept registry
    names or protocol instances; ``allocator_kwargs`` pass through to the
    underlying P1 solver (``num_particles``, ``iters``, ``seed``, ...).
    ``engine``/``devices``/``seed``/``execute`` are the unified facade
    kwargs (``repro.api.base``); ``execute_kwargs`` tunes the closed
    loop (``window``, ``drift_tol``, ``min_batches``, ``max_replans``,
    ``headroom``, ``executor``, ``executor_kwargs``, plus
    ``exec_engine="bucketed"`` to run the diffusion sessions on the
    device-resident bucketed engine — docs/PERFORMANCE.md)."""

    _LEGACY = ("workload", "scheduler", "allocator", "delay", "quality",
               "allocator_kwargs", "engine")
    _LEGACY_DEFAULTS = {"workload": None, "scheduler": "stacking",
                        "allocator": "pso", "delay": None,
                        "quality": None, "allocator_kwargs": None,
                        "engine": None}

    def __init__(self, scenario: Scenario, *args, workload=None,
                 scheduler="stacking", allocator="pso",
                 delay: Optional[DelayModel] = None,
                 quality: Optional[QualityModel] = None,
                 allocator_kwargs: Optional[dict] = None,
                 engine: Optional[str] = None, devices=None,
                 seed: Optional[int] = None, execute=None,
                 execute_kwargs: Optional[dict] = None):
        kw = self._legacy_positionals(args, dict(
            workload=workload, scheduler=scheduler, allocator=allocator,
            delay=delay, quality=quality,
            allocator_kwargs=allocator_kwargs, engine=engine))
        workload, scheduler = kw["workload"], kw["scheduler"]
        allocator, delay, quality = (kw["allocator"], kw["delay"],
                                     kw["quality"])
        allocator_kwargs, engine = kw["allocator_kwargs"], kw["engine"]
        super().__init__(scenario, engine=engine, devices=devices,
                         seed=seed, execute=execute,
                         execute_kwargs=execute_kwargs)
        self.scheduler_name = display_name(scheduler)
        self.allocator_name = display_name(allocator)
        self.scheduler = SCHEDULERS.resolve(scheduler)
        self.allocator = ALLOCATORS.resolve(allocator)
        wl = WORKLOADS.resolve(workload) if workload is not None else None
        if isinstance(wl, type):
            wl = wl()
        self.workload = wl
        self.workload_name = getattr(wl, "name", "") if wl else ""
        self.delay = delay if delay is not None else (
            wl.default_delay() if wl else DelayModel())
        self.quality = quality if quality is not None else (
            wl.default_quality() if wl else PowerLawFID())
        self.allocator_kwargs = self._seeded_kwargs(allocator,
                                                    allocator_kwargs)

    # -- pipeline stages ------------------------------------------------
    def allocate(self) -> np.ndarray:
        """P1: bandwidth allocation under the current delay/quality."""
        from repro.core import arrays
        with arrays.engine_scope(self.engine):
            return np.asarray(self.allocator(
                self.scenario, self.scheduler, self.delay, self.quality,
                **self.allocator_kwargs))

    def plan(self, alloc: np.ndarray) -> Tuple[Dict[int, float], BatchPlan]:
        """P2: generation budgets + batch plan under an allocation."""
        from repro.core import arrays
        with arrays.engine_scope(self.engine):
            return make_plan(self.scenario, alloc, self.scheduler,
                             self.delay, self.quality)

    def calibrate(self, key=None, **kw) -> DelayModel:
        """Measure the workload's real g(X) and adopt it for planning."""
        if self.workload is None:
            raise ValueError("no workload to calibrate against")
        self.delay = self.workload.calibrate(key, **kw)
        return self.delay

    # -- one-call end-to-end --------------------------------------------
    def run(self, key=None, *, execute=None, timed: bool = False,
            calibrate: bool = False, refit: bool = False,
            validate: bool = True) -> ProvisionReport:
        """Allocate -> plan -> (validate) -> simulate -> execute.

        execute: ``None`` falls back to the constructor's ``execute=``
            (default: legacy one-shot workload execution).  ``True``
            runs ``workload.execute`` open loop; ``"open"``/``"closed"``
            drive the plan through ``repro.core.execution.ExecutionLoop``
            (measured wall-clock, rolling delay refit; ``"closed"`` also
            replans mid-flight on drift) and attach the
            ``ExecutionResult`` as ``report.execution``.
        calibrate: measure the workload's delay curve first and plan with
            the fitted model (Fig.-1a loop).
        timed: record per-batch wall clock during execution.
        refit: refit ``self.delay`` in place from the measured timings so
            the *next* ``run`` replans with them (the calibrate->replan
            loop's update half); implies ``timed=True`` and requires an
            executing workload.
        """
        mode = self._resolve_execute(execute)
        if mode is None:
            mode = True                    # legacy default: execute
        key = self._resolve_key(key)
        if refit:
            if mode is False or self.workload is None:
                raise ValueError(
                    "refit=True needs measured timings: attach a workload "
                    "and keep execute=True")
            timed = True                   # refit is meaningless untimed
        if calibrate:
            self.calibrate(key)
        alloc = self.allocate()
        tp, plan = self.plan(alloc)
        if validate:
            plan.validate(gen_deadlines=tp)
        sim = simulate(self.scenario, alloc, plan, self.quality)
        out = WorkloadOutput(content=None)
        execution = None
        if mode is True and self.workload is not None:
            out = self.workload.execute(plan, key, timed=timed)
        elif mode in ("open", "closed"):
            from repro.api.execution import execute_plan, with_kwargs
            execution = execute_plan(
                self.scenario, plan, alloc, self.workload, mode=mode,
                key=key, scheduler=self.scheduler,
                allocator=with_kwargs(self.allocator,
                                      self.allocator_kwargs),
                delay=self.delay, quality=self.quality,
                engine=self.engine, validate=validate,
                **self.execute_kwargs)
            out = WorkloadOutput(content=execution.content,
                                 timings=execution.timings)
        report = ProvisionReport(
            scenario=self.scenario, allocation=alloc, tau_prime=tp,
            plan=plan, sim=sim, content=out.content, timings=out.timings,
            delay=self.delay, quality=self.quality,
            scheduler_name=self.scheduler_name,
            allocator_name=self.allocator_name,
            workload_name=self.workload_name, execution=execution)
        if refit:
            self.delay = report.refit_delay()
        return report
