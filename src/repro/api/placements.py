"""Placement registry entries: multi-server assignment strategies.

A placement has the uniform signature

    (scenario, scheduler, allocator, delay, quality, **kwargs)
        -> np.ndarray of server indices (one per service)

mirroring the Allocator protocol one level up: it decides *which cell*
hosts each service, and delegates the within-cell bandwidth split to
the given allocator (the per-cell P1).  All strategies respect
``EdgeServer.capacity`` and are deterministic.

  * ``round_robin``   — service i -> server i mod M (scenario order);
                        the obvious baseline, blind to speeds/deadlines.
  * ``least_loaded``  — scenario order, each service to the cell with
                        the least speed-normalized load.
  * ``greedy_fid``    — marginal-gain: tightest-deadline services
                        first, each to the cell whose summed FID (via a
                        real per-cell allocate -> plan evaluation)
                        increases the least.
  * ``alternating``   — coordinate descent alternating placement moves
                        with per-cell bandwidth refinement by the
                        existing ``coordinate`` allocator.
"""

from __future__ import annotations

import functools
from typing import Dict, FrozenSet, List, Tuple

import numpy as np

from repro.api.registry import get_allocator, register_placement
from repro.core.delay_model import DelayModel
from repro.core.multiserver import cell_objective
from repro.core.quality_model import QualityModel
from repro.core.service import Scenario


def _capacities_ok(scn: Scenario) -> None:
    caps = [s.capacity for s in scn.server_list]
    room = sum(c if c is not None else scn.K for c in caps)
    assert room >= scn.K, \
        f"server capacities admit {room} < K={scn.K} services"


def _eligible(counts: List[int], scn: Scenario) -> List[int]:
    return [m for m, sv in enumerate(scn.server_list)
            if sv.has_room(counts[m])]


@register_placement("round_robin", aliases=("rr",))
def round_robin(scn: Scenario, scheduler=None, allocator=None,
                delay: DelayModel = None, quality: QualityModel = None,
                **_) -> np.ndarray:
    """Service i -> server i mod M in scenario order, skipping full
    cells.  Ignores speeds and deadlines entirely — the baseline every
    smarter placement must beat."""
    _capacities_ok(scn)
    M = scn.n_servers
    counts = [0] * M
    out = np.zeros(scn.K, dtype=int)
    nxt = 0
    for i in range(scn.K):
        for probe in range(M):
            m = (nxt + probe) % M
            if scn.server_list[m].has_room(counts[m]):
                out[i] = m
                counts[m] += 1
                nxt = (m + 1) % M
                break
    return out


@register_placement("least_loaded")
def least_loaded(scn: Scenario, scheduler=None, allocator=None,
                 delay: DelayModel = None, quality: QualityModel = None,
                 **_) -> np.ndarray:
    """Scenario order; each service to the cell with the least
    speed-normalized load (hosted services / speed), ties by id.  A fast
    speed-aware heuristic needing no inner planning."""
    _capacities_ok(scn)
    servers = scn.server_list
    counts = [0] * len(servers)
    out = np.zeros(scn.K, dtype=int)
    for i in range(scn.K):
        m = min(_eligible(counts, scn),
                key=lambda j: (counts[j] / servers[j].speed, j))
        out[i] = m
        counts[m] += 1
    return out


class _CellCache:
    """Memoized per-cell objective: (server, member-id set) -> summed FID
    via the cell's own allocate -> plan pipeline."""

    def __init__(self, scn: Scenario, scheduler, allocator,
                 delay: DelayModel, quality: QualityModel):
        self.scn = scn
        self.scheduler = scheduler
        self.allocator = allocator
        self.delay = delay
        self.quality = quality
        self._memo: Dict[Tuple[int, FrozenSet[int]], float] = {}

    def sub_scenario(self, m: int, ids: FrozenSet[int]) -> Scenario:
        server = self.scn.server_list[m]
        members = [s for s in self.scn.services if s.id in ids]
        return Scenario(services=members,
                        total_bandwidth_hz=server.bandwidth_hz,
                        content_bits=self.scn.content_bits)

    def objective(self, m: int, ids: FrozenSet[int]) -> float:
        key = (m, ids)
        if key not in self._memo:
            server = self.scn.server_list[m]
            self._memo[key] = cell_objective(
                self.sub_scenario(m, ids), self.scheduler, self.allocator,
                server.delay_model(self.delay), self.quality)
        return self._memo[key]


@register_placement("greedy_fid")
def greedy_fid(scn: Scenario, scheduler=None, allocator=None,
               delay: DelayModel = None, quality: QualityModel = None,
               **_) -> np.ndarray:
    """Marginal-gain assignment: services in tightest-deadline-first
    order; each goes to the cell whose summed FID — evaluated by
    actually allocating and planning the cell — rises the least."""
    _capacities_ok(scn)
    delay = delay if delay is not None else DelayModel()
    cache = _CellCache(scn, scheduler, allocator, delay, quality)
    servers = scn.server_list
    members: List[FrozenSet[int]] = [frozenset() for _ in servers]
    obj = [0.0] * len(servers)
    out = np.zeros(scn.K, dtype=int)
    order = sorted(range(scn.K),
                   key=lambda i: (scn.services[i].deadline,
                                  scn.services[i].id))
    for i in order:
        svc = scn.services[i]
        counts = [len(ms) for ms in members]
        best_m, best_delta = None, None
        for m in _eligible(counts, scn):
            trial = members[m] | {svc.id}
            delta = cache.objective(m, trial) - obj[m]
            if best_delta is None or delta < best_delta - 1e-12:
                best_m, best_delta = m, delta
        members[best_m] = members[best_m] | {svc.id}
        obj[best_m] = cache.objective(best_m, members[best_m])
        out[i] = best_m
    return out


@register_placement("alternating", aliases=("coord_desc",))
def alternating(scn: Scenario, scheduler=None, allocator=None,
                delay: DelayModel = None, quality: QualityModel = None,
                *, init: str = "least_loaded", sweeps: int = 2,
                inner_rounds: int = 1, **_) -> np.ndarray:
    """Placement <-> bandwidth coordinate descent.

    Starts from ``init`` (any registered placement), then alternates:
    the bandwidth coordinate is re-optimized per cell by the existing
    ``coordinate`` allocator (pairwise-transfer hill climb with
    ``inner_rounds`` sweeps), and the placement coordinate tries moving
    each service to every other cell, keeping moves that lower the
    system objective under those refined per-cell allocations.  Stops
    after ``sweeps`` full passes or at the first pass with no move.

    Because moves are scored under coordinate-refined per-cell
    allocations, pair this placement with ``allocator="coordinate"``
    so the provisioner realizes the same bandwidth the descent
    optimized (the benchmark suite does); under a different allocator
    only the assignment carries over and an accepted move is not
    guaranteed to help.
    """
    _capacities_ok(scn)
    delay = delay if delay is not None else DelayModel()
    from repro.api.registry import PLACEMENTS
    assign = np.asarray(PLACEMENTS.get(init)(
        scn, scheduler, allocator, delay, quality)).copy()
    refine = functools.partial(get_allocator("coordinate"),
                               rounds=inner_rounds)
    cache = _CellCache(scn, scheduler, refine, delay, quality)
    servers = scn.server_list
    M = len(servers)
    members = [frozenset(s.id for s, a in zip(scn.services, assign)
                         if a == m) for m in range(M)]
    obj = [cache.objective(m, members[m]) for m in range(M)]
    for _ in range(sweeps):
        moved = False
        for i in range(scn.K):
            svc = scn.services[i]
            src = int(assign[i])
            best = None            # (delta, dst, new_src_obj, new_dst_obj)
            for dst in range(M):
                if dst == src or \
                        not servers[dst].has_room(len(members[dst])):
                    continue
                new_src = cache.objective(src, members[src] - {svc.id})
                new_dst = cache.objective(dst, members[dst] | {svc.id})
                delta = (new_src + new_dst) - (obj[src] + obj[dst])
                if delta < -1e-9 and (best is None or delta < best[0]):
                    best = (delta, dst, new_src, new_dst)
            if best is not None:
                _, dst, new_src, new_dst = best
                members[src] = members[src] - {svc.id}
                members[dst] = members[dst] | {svc.id}
                obj[src], obj[dst] = new_src, new_dst
                assign[i] = dst
                moved = True
        if not moved:
            break
    return assign
