"""Workload registry entries.

``DiffusionWorkload`` wraps the DDIM ``BatchDenoisingExecutor`` (the
paper's image-generation workload); ``DecodeWorkload`` wraps the LLM
``ServingEngine`` decode path (DESIGN.md §4: one denoising task == one
decode token).  Both satisfy the ``Workload`` protocol, so a
``Provisioner`` drives either through the identical
allocate -> schedule -> execute pipeline.

Model construction is lazy: importing this module (e.g. just to list
registry names) never touches jax or initializes parameters.
"""

from __future__ import annotations

from typing import Any, Optional, Sequence

import numpy as np

from repro.api.protocols import WorkloadOutput
from repro.api.registry import register_workload
from repro.core.delay_model import DelayModel, fit
from repro.core.plan import BatchPlan
from repro.core.quality_model import PowerLawFID, QualityModel


@register_workload("diffusion")
class DiffusionWorkload:
    """Batch denoising on the DDIM U-Net (the paper's workload)."""

    name = "diffusion"

    def __init__(self, cfg=None, params=None, executor=None,
                 init_seed: int = 0,
                 exec_engine: Optional[str] = None):
        self.cfg = cfg
        self.params = params
        self._executor = executor
        self.init_seed = init_seed
        # denoising engine default for every session this workload
        # opens ("dict"/"bucketed"; None = executor/process default)
        self.exec_engine = exec_engine

    def _ex(self):
        if self._executor is None:
            import jax
            from repro.configs.ddim_cifar10 import SMOKE
            from repro.diffusion import unet
            from repro.diffusion.executor import BatchDenoisingExecutor
            from repro.models.params import init_params
            cfg = self.cfg if self.cfg is not None else SMOKE
            params = self.params
            if params is None:
                params = init_params(unet.schema(cfg),
                                     jax.random.PRNGKey(self.init_seed))
            self.cfg, self.params = cfg, params
            self._executor = BatchDenoisingExecutor(
                cfg, params, exec_engine=self.exec_engine)
        return self._executor

    def default_delay(self) -> DelayModel:
        return DelayModel()                    # paper's RTX-3050 constants

    def default_quality(self) -> QualityModel:
        return PowerLawFID()

    def measure_delay_curve(self, key: Optional[Any] = None,
                            batch_sizes: Sequence[int] = (1, 2, 4, 8),
                            reps: int = 3,
                            exec_engine: Optional[str] = None):
        """Fig. 1a raw data: steady-state per-step delay vs batch size.
        Compile time never lands in the readings; the executor's
        ``last_compile_log`` carries it separately."""
        import jax
        key = key if key is not None else jax.random.PRNGKey(1)
        return self._ex().measure_delay_curve(key, batch_sizes=batch_sizes,
                                              reps=reps,
                                              exec_engine=exec_engine)

    def calibrate(self, key: Optional[Any] = None, *,
                  batch_sizes: Sequence[int] = (1, 2, 4, 8),
                  reps: int = 3,
                  exec_engine: Optional[str] = None) -> DelayModel:
        curve = self.measure_delay_curve(key, batch_sizes, reps,
                                         exec_engine=exec_engine)
        return fit([c[0] for c in curve], [c[1] for c in curve])

    def execute(self, plan: BatchPlan, key: Optional[Any] = None,
                *, timed: bool = False,
                exec_engine: Optional[str] = None) -> WorkloadOutput:
        import jax
        key = key if key is not None else jax.random.PRNGKey(0)
        images, timings = self._ex().run(plan, key, timed=timed,
                                         exec_engine=exec_engine)
        return WorkloadOutput(content=images, timings=timings)

    def open_session(self, plan: BatchPlan, key: Optional[Any] = None,
                     exec_engine: Optional[str] = None):
        """Stepwise execution handle (EXECUTORS registry entry): the
        closed loop in ``repro.core.execution`` drives batches itself.
        ``exec_engine`` overrides the workload-level engine for this
        session."""
        import jax
        key = key if key is not None else jax.random.PRNGKey(0)
        return self._ex().open_session(plan, key,
                                       exec_engine=exec_engine)


@register_workload("llm_decode")
class DecodeWorkload:
    """Deadline-aware autoregressive decoding on the ServingEngine."""

    name = "llm_decode"

    def __init__(self, cfg=None, params=None, run=None,
                 max_len: int = 128, prompt_len: int = 8,
                 arch: str = "tinyllama-1.1b", engine=None,
                 init_seed: int = 0):
        self.cfg = cfg
        self.params = params
        self.run = run
        self.max_len = max_len
        self.prompt_len = prompt_len
        self.arch = arch
        self._engine = engine
        self.init_seed = init_seed

    def _eng(self):
        if self._engine is None:
            import jax
            from repro.config import RunConfig, get_config, smoke_variant
            from repro.models import api as models_api
            from repro.serving.engine import ServingEngine
            cfg = self.cfg
            if cfg is None:
                cfg = smoke_variant(get_config(self.arch))
            params = self.params
            if params is None:
                params = models_api.init_model(
                    cfg, jax.random.PRNGKey(self.init_seed))
            run = self.run if self.run is not None else RunConfig()
            self.cfg, self.params, self.run = cfg, params, run
            self._engine = ServingEngine(cfg, params, run, self.max_len,
                                         delay=self.default_delay())
        return self._engine

    def default_delay(self) -> DelayModel:
        return DelayModel(a=0.002, b=0.02)     # CPU-scale decode constants

    def default_quality(self) -> QualityModel:
        from repro.serving.engine import TokenQuality
        return TokenQuality()

    def calibrate(self, key: Optional[Any] = None, *,
                  batch_sizes: Sequence[int] = (1, 2, 4),
                  reps: int = 2) -> DelayModel:
        return self._eng().measure_decode_delay(batch_sizes=batch_sizes,
                                                reps=reps)

    def _prompt(self, service_id: int, vocab: int) -> np.ndarray:
        rng = np.random.default_rng(self.init_seed * 7919 + service_id)
        return rng.integers(0, vocab, self.prompt_len).astype(np.int32)

    def _load_requests(self, plan: BatchPlan) -> None:
        from repro.serving.engine import Request
        eng = self._eng()
        top = max(plan.steps_completed.values(), default=0)
        if self.prompt_len + top > self.max_len:
            raise ValueError(
                f"plan wants {top} tokens but max_len={self.max_len} "
                f"leaves room for {self.max_len - self.prompt_len}; "
                f"raise max_len or tighten deadlines")
        eng.requests.clear()
        for k in sorted(plan.steps_completed):
            eng.requests[k] = Request(
                id=k, prompt=self._prompt(k, eng.cfg.vocab_size),
                deadline=float("inf"))

    def execute(self, plan: BatchPlan, key: Optional[Any] = None,
                *, timed: bool = False) -> WorkloadOutput:
        self._load_requests(plan)
        eng = self._eng()
        out = eng.execute(plan, sample_key=key, timed=timed)
        return WorkloadOutput(content={k: list(v) for k, v in out.items()},
                              timings=list(eng.last_timings))

    def open_session(self, plan: BatchPlan, key: Optional[Any] = None):
        """Stepwise decode handle (EXECUTORS registry entry); ``key`` is
        unused — decoding is greedy argmax."""
        self._load_requests(plan)
        return self._eng().open_session(plan)
