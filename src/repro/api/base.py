"""Shared facade machinery: one resolution path for the common kwargs.

Every facade — ``Provisioner``, ``OnlineProvisioner``,
``MultiServerProvisioner``, ``FleetProvisioner`` — derives from
``BaseProvisioner`` and accepts the same keyword set:

    engine=    planning-engine pin ("vec"/"scalar"/"jax",
               repro.core.arrays; None = process default)
    devices=   device list for sharded jax planning (consumed by the
               fleet/jax-batched paths; harmless elsewhere)
    seed=      one deterministic seed: injected into the allocator's
               kwargs when its signature takes ``seed`` and used as the
               default PRNG key for workload execution (fleet scenarios
               adopt it as their arrival seed)
    execute=   default execution mode for ``run()``:
               False/None (analytic), True (legacy one-shot workload
               execution), "open" (ExecutionLoop, no replanning) or
               "closed" (ExecutionLoop with drift-triggered replanning)

``execute_kwargs`` passes loop tuning through to ``execute_plan``
(``window``, ``drift_tol``, ...) plus ``exec_engine=`` to pick the
denoising session engine (``"dict"`` reference / ``"bucketed"``
device-resident — docs/PERFORMANCE.md).

``provision(scenario, ...)`` is the single front door: it dispatches on
scenario shape (fleet / multi-server / online / static) and reproduces
the corresponding facade's ``run()`` output exactly.

The pre-unification positional constructor signatures still work
through ``_legacy_positionals`` (a ``DeprecationWarning`` shim,
test-enforced in tests/test_facades.py).
"""

from __future__ import annotations

import inspect
import warnings
from typing import Any, Dict, Optional, Tuple

import numpy as np

from repro.api.registry import ALLOCATORS

EXECUTE_MODES = (None, False, True, "open", "closed")


def jsonable(v):
    """Recursively convert numpy scalars/arrays so ``to_dict`` output
    survives ``json.dumps`` round-trips."""
    if isinstance(v, dict):
        return {str(k): jsonable(x) for k, x in v.items()}
    if isinstance(v, (list, tuple)):
        return [jsonable(x) for x in v]
    if isinstance(v, np.ndarray):
        return [jsonable(x) for x in v.tolist()]
    if isinstance(v, (np.floating,)):
        return float(v)
    if isinstance(v, (np.integer,)):
        return int(v)
    if isinstance(v, (np.bool_,)):
        return bool(v)
    return v


def report_dict(kind: str, *, mean_fid: float, outage_rate: float,
                makespan: Optional[float] = None,
                components: Optional[Dict[str, str]] = None,
                telemetry: Optional[dict] = None, **extra) -> dict:
    """The common report ``to_dict`` protocol: every report kind carries
    at least kind / mean_fid / outage_rate / makespan / components /
    telemetry (JSON-serializable; benchmarks consume this instead of
    hand-picking fields)."""
    out = {
        "kind": kind,
        "mean_fid": None if mean_fid is None or np.isnan(mean_fid)
        else float(mean_fid),
        "outage_rate": float(outage_rate),
        "makespan": None if makespan is None else float(makespan),
        "components": {k: str(v) for k, v in (components or {}).items()},
        "telemetry": jsonable(telemetry or {}),
    }
    out.update(jsonable(extra))
    return out


class BaseProvisioner:
    """Common constructor surface + helpers for the four facades."""

    # pre-unification positional order (after ``scenario``) and the
    # defaults those parameters had — drives the deprecation shim
    _LEGACY: Tuple[str, ...] = ()
    _LEGACY_DEFAULTS: Dict[str, Any] = {}

    def __init__(self, scenario, *, engine: Optional[str] = None,
                 devices=None, seed: Optional[int] = None,
                 execute=None, execute_kwargs: Optional[dict] = None):
        self.scenario = scenario
        self.engine = engine
        self.devices = devices
        self.seed = seed
        self.execute_default = self._check_execute(execute)
        self.execute_kwargs = dict(execute_kwargs or {})

    @staticmethod
    def _check_execute(execute):
        if execute not in EXECUTE_MODES:
            raise ValueError(
                f"execute must be one of {EXECUTE_MODES}, got "
                f"{execute!r}")
        return execute

    def _resolve_execute(self, execute):
        """run(execute=None) falls back to the constructor default —
        the one resolution path for the knob."""
        if execute is None:
            return self.execute_default
        return self._check_execute(execute)

    @classmethod
    def _legacy_positionals(cls, args: tuple, given: Dict[str, Any]) \
            -> Dict[str, Any]:
        """Deprecation shim: map old positional component arguments
        onto their keywords.  ``given`` holds the keyword values as
        received so positional/keyword duplicates fail loudly."""
        if not args:
            return given
        names = cls._LEGACY
        if len(args) > len(names):
            raise TypeError(
                f"{cls.__name__}() takes at most {1 + len(names)} "
                f"positional arguments ({1 + len(args)} given)")
        shown = ", ".join(names[:len(args)])
        warnings.warn(
            f"positional {cls.__name__}(scenario, {shown}) is "
            f"deprecated; pass component arguments as keywords",
            DeprecationWarning, stacklevel=3)
        out = dict(given)
        for name, val in zip(names, args):
            default = cls._LEGACY_DEFAULTS.get(name)
            if given.get(name, default) != default:
                raise TypeError(
                    f"{cls.__name__}() got multiple values for "
                    f"argument '{name}'")
            out[name] = val
        return out

    # -- seed resolution --------------------------------------------------

    def _seeded_kwargs(self, allocator, kwargs: Optional[dict]) -> dict:
        """Inject ``seed=`` into the allocator's kwargs when its
        signature takes one (PSO etc.) and the caller didn't pin it."""
        kwargs = dict(kwargs or {})
        if self.seed is None or "seed" in kwargs:
            return kwargs
        try:
            params = inspect.signature(
                ALLOCATORS.resolve(allocator)).parameters
        except (TypeError, ValueError):
            params = {}
        if "seed" in params:
            kwargs["seed"] = int(self.seed)
        return kwargs

    def _resolve_key(self, key):
        """Default PRNG key for workload execution from ``seed=``."""
        if key is not None or self.seed is None:
            return key
        import jax
        return jax.random.PRNGKey(int(self.seed))


def provision(scenario, **kwargs):
    """The unified front door: dispatch on scenario shape and run.

    * ``FleetScenario``                      -> ``FleetProvisioner``
    * multi-server ``Scenario`` + arrivals/admission/handoff
                                             -> ``MultiServerProvisioner.run_online``
    * multi-server ``Scenario``              -> ``MultiServerProvisioner.run``
    * arrivals over time or ``admission=``   -> ``OnlineProvisioner``
    * static single-server ``Scenario``      -> ``Provisioner``

    Remaining keyword arguments split automatically between the chosen
    facade's constructor and its ``run()``; the result is exactly what
    calling that facade directly would return (test-enforced).
    """
    from repro.api.fleet import FleetProvisioner
    from repro.api.multiserver import MultiServerProvisioner
    from repro.api.online import OnlineProvisioner
    from repro.api.provisioner import Provisioner
    from repro.core.fleet import FleetScenario

    kw = dict(kwargs)

    def split(*run_keys):
        return {k: kw.pop(k) for k in run_keys if k in kw}

    if isinstance(scenario, FleetScenario):
        run_kw = split("mode", "epoch", "placement", "reservoir")
        return FleetProvisioner(scenario, **kw).run(**run_kw)

    dynamic = (not scenario.is_static or "admission" in kw
               or "admission_kwargs" in kw or "handoff" in kw
               or "online_placement" in kw)
    if scenario.n_servers > 1:
        if dynamic:
            run_kw = split("admission", "online_placement",
                           "admission_kwargs", "handoff", "validate")
            return MultiServerProvisioner(scenario, **kw) \
                .run_online(**run_kw)
        run_kw = split("assignment", "validate")
        return MultiServerProvisioner(scenario, **kw).run(**run_kw)
    if dynamic:
        run_kw = split("validate", "execute", "key")
        return OnlineProvisioner(scenario, **kw).run(**run_kw)
    run_kw = split("key", "execute", "timed", "calibrate", "refit",
                   "validate")
    return Provisioner(scenario, **kw).run(**run_kw)
