"""Online admission front end: arrivals over time, replanning on each.

    from repro.api import OnlineProvisioner
    from repro.core.service import make_scenario

    scn = make_scenario(K=12, arrival_rate=0.2, seed=0)
    report = OnlineProvisioner(scn, scheduler="stacking",
                               allocator="inv_se",
                               admission="deadline_feasible").run()
    print(report.summary())

``OnlineProvisioner`` is the online sibling of ``Provisioner``: the same
registry-named schedulers and allocators, plus a fourth registry of
*admission policies* deciding accept/reject per arrival.  Each arrival
triggers a trial replan (allocate -> plan over the residual scenario —
see ``repro.core.online``); the policy inspects the outcome that replan
projects for the newcomer and every prior in-flight state.

Built-in policies:

  * ``admit_all``          — accept everything (the baseline; with all
                             arrivals at t=0 this reproduces the static
                             pipeline exactly)
  * ``deadline_feasible``  — accept iff the trial plan completes the
                             newcomer within its deadline
  * ``fid_threshold``      — accept iff the projected FID clears a bar
                             (default 50.0; tune via ``admission_kwargs``)

Custom policies register like any other component:

    from repro.api import register_admission

    @register_admission("vip_only")
    def vip_only(svc, projected, states):
        return svc.id % 2 == 0 or projected.met_deadline
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Tuple

from repro.api.base import BaseProvisioner, report_dict
from repro.api.registry import (ADMISSIONS, ALLOCATORS, SCHEDULERS,
                                WORKLOADS, display_name,
                                register_admission)
# entry modules populate the scheduler/allocator registries on import
from repro.api import allocators as _allocators   # noqa: F401
from repro.api import schedulers as _schedulers   # noqa: F401
from repro.core.delay_model import DelayModel
from repro.core.online import OnlineResult, simulate_online
from repro.core.quality_model import PowerLawFID, QualityModel
from repro.core.service import Scenario, ServiceRequest
from repro.core.simulator import ServiceOutcome


# -- admission policies ---------------------------------------------------

@register_admission("admit_all", aliases=("all",))
def admit_all(svc: ServiceRequest, projected: ServiceOutcome,
              states: Dict) -> bool:
    return True


@register_admission("deadline_feasible", aliases=("feasible",))
def deadline_feasible(svc: ServiceRequest, projected: ServiceOutcome,
                      states: Dict) -> bool:
    return projected.steps > 0 and projected.met_deadline


@register_admission("fid_threshold")
def fid_threshold(svc: ServiceRequest, projected: ServiceOutcome,
                  states: Dict, *, threshold: float = 50.0) -> bool:
    return projected.steps > 0 and projected.fid <= threshold


# -- report + facade ------------------------------------------------------

@dataclasses.dataclass
class OnlineReport:
    """Everything one online run produced (summary mirrors
    ``ProvisionReport.summary`` with an admission column)."""
    scenario: Scenario
    result: OnlineResult
    delay: DelayModel
    quality: QualityModel
    scheduler_name: str = ""
    allocator_name: str = ""
    admission_name: str = ""
    content: Optional[Dict[int, Any]] = None  # execute=True replay output
    timings: List[Tuple[int, float]] = dataclasses.field(
        default_factory=list)                 # measured (batch_size, s)

    @property
    def mean_fid(self) -> float:
        return self.result.mean_fid

    @property
    def outage_rate(self) -> float:
        return self.result.outage_rate

    @property
    def reject_rate(self) -> float:
        return self.result.reject_rate

    def makespan(self) -> Optional[float]:
        """Absolute completion time of the last admitted service (e2e
        delays are arrival-relative)."""
        arrival = {s.id: s.arrival for s in self.scenario.services}
        times = [arrival[o.id] + o.e2e_delay for o in self.result.outcomes
                 if o.steps > 0]
        return max(times) if times else None

    def summary(self) -> str:
        head = (f"[online] scheduler={self.scheduler_name} "
                f"allocator={self.allocator_name} "
                f"admission={self.admission_name}")
        return head + "\n" + self.result.summary()

    def to_dict(self) -> dict:
        """Common report protocol (``repro.api.base.report_dict``)."""
        nb = len(self.result.executed_batches or [])
        return report_dict(
            "online", mean_fid=self.mean_fid,
            outage_rate=self.outage_rate, makespan=self.makespan(),
            components={"scheduler": self.scheduler_name,
                        "allocator": self.allocator_name,
                        "admission": self.admission_name},
            telemetry={"batches": nb,
                       "timings": [[int(x), float(s)]
                                   for x, s in self.timings]},
            reject_rate=self.reject_rate,
            n_admitted=len(self.result.outcomes))


class OnlineProvisioner(BaseProvisioner):
    """Event-driven counterpart of ``Provisioner``: requests arrive at
    ``ServiceRequest.arrival``, each admitted arrival re-runs
    allocate -> plan over the residual scenario with in-flight batches
    pinned.  ``scheduler`` / ``allocator`` / ``admission`` take registry
    names or protocol instances; ``allocator_kwargs`` /
    ``admission_kwargs`` pass through to the underlying callables.
    ``engine``/``devices``/``seed``/``execute`` are the unified facade
    kwargs (``repro.api.base``); ``execute=True`` replays the committed
    batch sequence on ``workload``'s real executor after the simulation
    (``repro.api.execution.replay_result``)."""

    _LEGACY = ("scheduler", "allocator", "admission", "delay", "quality",
               "allocator_kwargs", "admission_kwargs", "engine")
    _LEGACY_DEFAULTS = {"scheduler": "stacking", "allocator": "pso",
                        "admission": "admit_all", "delay": None,
                        "quality": None, "allocator_kwargs": None,
                        "admission_kwargs": None, "engine": None}

    def __init__(self, scenario: Scenario, *args, scheduler="stacking",
                 allocator="pso", admission="admit_all",
                 delay: Optional[DelayModel] = None,
                 quality: Optional[QualityModel] = None,
                 allocator_kwargs: Optional[dict] = None,
                 admission_kwargs: Optional[dict] = None,
                 engine: Optional[str] = None, workload=None,
                 devices=None, seed: Optional[int] = None, execute=None,
                 execute_kwargs: Optional[dict] = None):
        kw = self._legacy_positionals(args, dict(
            scheduler=scheduler, allocator=allocator, admission=admission,
            delay=delay, quality=quality,
            allocator_kwargs=allocator_kwargs,
            admission_kwargs=admission_kwargs, engine=engine))
        scheduler, allocator = kw["scheduler"], kw["allocator"]
        admission, delay, quality = (kw["admission"], kw["delay"],
                                     kw["quality"])
        allocator_kwargs, admission_kwargs = (kw["allocator_kwargs"],
                                              kw["admission_kwargs"])
        super().__init__(scenario, engine=kw["engine"], devices=devices,
                         seed=seed, execute=execute,
                         execute_kwargs=execute_kwargs)
        self.scheduler_name = display_name(scheduler)
        self.allocator_name = display_name(allocator)
        self.admission_name = display_name(admission)
        self.scheduler = SCHEDULERS.resolve(scheduler)
        self.allocator = ALLOCATORS.resolve(allocator)
        self.admission = ADMISSIONS.resolve(admission)
        wl = WORKLOADS.resolve(workload) if workload is not None else None
        if isinstance(wl, type):
            wl = wl()
        self.workload = wl
        self.delay = delay if delay is not None else (
            wl.default_delay() if wl else DelayModel())
        self.quality = quality if quality is not None else (
            wl.default_quality() if wl else PowerLawFID())
        self.allocator_kwargs = self._seeded_kwargs(allocator,
                                                    allocator_kwargs)
        self.admission_kwargs = dict(admission_kwargs or {})

    def run(self, *, validate: bool = True, execute=None,
            key=None) -> OnlineReport:
        """Simulate the arrival sequence; with ``execute=True`` (or a
        constructor default), replay the committed batches on the
        workload's executor and attach content + measured timings."""
        from repro.api.execution import with_kwargs
        mode = self._resolve_execute(execute)
        if mode in ("open", "closed"):
            raise ValueError(
                "online execution replays the simulated batch sequence; "
                "use execute=True (closed-loop modes apply to the static "
                "Provisioner)")
        allocator = with_kwargs(self.allocator, self.allocator_kwargs)
        admission = with_kwargs(self.admission, self.admission_kwargs)
        result = simulate_online(
            self.scenario, self.scheduler, allocator,
            delay=self.delay, quality=self.quality,
            admission=admission, validate=validate, engine=self.engine)
        report = OnlineReport(
            scenario=self.scenario, result=result, delay=self.delay,
            quality=self.quality, scheduler_name=self.scheduler_name,
            allocator_name=self.allocator_name,
            admission_name=self.admission_name)
        if mode is True:
            if self.workload is None and \
                    "executor" not in self.execute_kwargs:
                raise ValueError(
                    "execute=True needs a workload= to replay on "
                    "(or an executor= in execute_kwargs)")
            from repro.api.execution import replay_result
            out = replay_result(self.workload, result, self.delay,
                                key=self._resolve_key(key),
                                **self.execute_kwargs)
            report.content = out.content
            report.timings = list(out.timings or [])
        return report
