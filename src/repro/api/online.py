"""Online admission front end: arrivals over time, replanning on each.

    from repro.api import OnlineProvisioner
    from repro.core.service import make_scenario

    scn = make_scenario(K=12, arrival_rate=0.2, seed=0)
    report = OnlineProvisioner(scn, scheduler="stacking",
                               allocator="inv_se",
                               admission="deadline_feasible").run()
    print(report.summary())

``OnlineProvisioner`` is the online sibling of ``Provisioner``: the same
registry-named schedulers and allocators, plus a fourth registry of
*admission policies* deciding accept/reject per arrival.  Each arrival
triggers a trial replan (allocate -> plan over the residual scenario —
see ``repro.core.online``); the policy inspects the outcome that replan
projects for the newcomer and every prior in-flight state.

Built-in policies:

  * ``admit_all``          — accept everything (the baseline; with all
                             arrivals at t=0 this reproduces the static
                             pipeline exactly)
  * ``deadline_feasible``  — accept iff the trial plan completes the
                             newcomer within its deadline
  * ``fid_threshold``      — accept iff the projected FID clears a bar
                             (default 50.0; tune via ``admission_kwargs``)

Custom policies register like any other component:

    from repro.api import register_admission

    @register_admission("vip_only")
    def vip_only(svc, projected, states):
        return svc.id % 2 == 0 or projected.met_deadline
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Dict, Optional

from repro.api.registry import (ADMISSIONS, ALLOCATORS, SCHEDULERS,
                                display_name, register_admission)
# entry modules populate the scheduler/allocator registries on import
from repro.api import allocators as _allocators   # noqa: F401
from repro.api import schedulers as _schedulers   # noqa: F401
from repro.core.delay_model import DelayModel
from repro.core.online import OnlineResult, simulate_online
from repro.core.quality_model import PowerLawFID, QualityModel
from repro.core.service import Scenario, ServiceRequest
from repro.core.simulator import ServiceOutcome


# -- admission policies ---------------------------------------------------

@register_admission("admit_all", aliases=("all",))
def admit_all(svc: ServiceRequest, projected: ServiceOutcome,
              states: Dict) -> bool:
    return True


@register_admission("deadline_feasible", aliases=("feasible",))
def deadline_feasible(svc: ServiceRequest, projected: ServiceOutcome,
                      states: Dict) -> bool:
    return projected.steps > 0 and projected.met_deadline


@register_admission("fid_threshold")
def fid_threshold(svc: ServiceRequest, projected: ServiceOutcome,
                  states: Dict, *, threshold: float = 50.0) -> bool:
    return projected.steps > 0 and projected.fid <= threshold


# -- report + facade ------------------------------------------------------

@dataclasses.dataclass
class OnlineReport:
    """Everything one online run produced (summary mirrors
    ``ProvisionReport.summary`` with an admission column)."""
    scenario: Scenario
    result: OnlineResult
    delay: DelayModel
    quality: QualityModel
    scheduler_name: str = ""
    allocator_name: str = ""
    admission_name: str = ""

    @property
    def mean_fid(self) -> float:
        return self.result.mean_fid

    @property
    def outage_rate(self) -> float:
        return self.result.outage_rate

    @property
    def reject_rate(self) -> float:
        return self.result.reject_rate

    def summary(self) -> str:
        head = (f"[online] scheduler={self.scheduler_name} "
                f"allocator={self.allocator_name} "
                f"admission={self.admission_name}")
        return head + "\n" + self.result.summary()


class OnlineProvisioner:
    """Event-driven counterpart of ``Provisioner``: requests arrive at
    ``ServiceRequest.arrival``, each admitted arrival re-runs
    allocate -> plan over the residual scenario with in-flight batches
    pinned.  ``scheduler`` / ``allocator`` / ``admission`` take registry
    names or protocol instances; ``allocator_kwargs`` /
    ``admission_kwargs`` pass through to the underlying callables."""

    def __init__(self, scenario: Scenario, scheduler="stacking",
                 allocator="pso", admission="admit_all",
                 delay: Optional[DelayModel] = None,
                 quality: Optional[QualityModel] = None,
                 allocator_kwargs: Optional[dict] = None,
                 admission_kwargs: Optional[dict] = None,
                 engine: Optional[str] = None):
        # engine: planning-engine pin for every replan of a run
        # ("vec"/"scalar", repro.core.arrays; None = process default)
        self.engine = engine
        self.scenario = scenario
        self.scheduler_name = display_name(scheduler)
        self.allocator_name = display_name(allocator)
        self.admission_name = display_name(admission)
        self.scheduler = SCHEDULERS.resolve(scheduler)
        self.allocator = ALLOCATORS.resolve(allocator)
        self.admission = ADMISSIONS.resolve(admission)
        self.delay = delay if delay is not None else DelayModel()
        self.quality = quality if quality is not None else PowerLawFID()
        self.allocator_kwargs = dict(allocator_kwargs or {})
        self.admission_kwargs = dict(admission_kwargs or {})

    def run(self, *, validate: bool = True) -> OnlineReport:
        allocator = self.allocator
        if self.allocator_kwargs:
            allocator = functools.partial(allocator,
                                          **self.allocator_kwargs)
        admission = self.admission
        if self.admission_kwargs:
            admission = functools.partial(admission,
                                          **self.admission_kwargs)
        result = simulate_online(
            self.scenario, self.scheduler, allocator,
            delay=self.delay, quality=self.quality,
            admission=admission, validate=validate, engine=self.engine)
        return OnlineReport(
            scenario=self.scenario, result=result, delay=self.delay,
            quality=self.quality, scheduler_name=self.scheduler_name,
            allocator_name=self.allocator_name,
            admission_name=self.admission_name)
