"""Scheduler registry entries: the paper's Algorithm 1, its Sec.-IV
baselines, the balanced ``equal_steps`` baseline, the exact
``optimal`` search for tiny instances, and the offset-native
``stacking_offset`` (progress-aware replanning, ``repro.core.offset``).

All share the uniform ``Scheduler`` signature
``(services, tau_prime, delay, quality) -> BatchPlan``;
``stacking_offset`` additionally satisfies ``OffsetScheduler`` (a
``plan(..., offsets)`` method the online replanner dispatches to).

Engine note (docs/PERFORMANCE.md): ``stacking``, ``equal_steps`` and
``stacking_offset`` dispatch to the array-native engine
(``repro.core.arrays``) by default; the ``*_scalar`` entries pin the
reference per-level loops — bit-identical plans, kept as ground truth
and for the ``planner_speed`` benchmark's baseline side.  The
``*_jax`` entries pin the jit-compiled ``repro.core.jaxplan`` backend
(tolerance-equivalent, not bit-identical); they register
unconditionally and resolve the backend lazily at call time, so
importing ``repro.api`` never imports jax and a checkout without jax
fails with a ValueError naming the missing backend only when a
``*_jax`` scheduler is actually invoked.
"""

from __future__ import annotations

from typing import Dict, Sequence

from repro.api.registry import register_scheduler
from repro.core import arrays
from repro.core.baselines import (fixed_size_batching, greedy_batching,
                                  single_instance)
from repro.core.delay_model import DelayModel
from repro.core.offset import StackingOffset, stacking_offset
from repro.core.optimal import optimal_plan
from repro.core.plan import BatchPlan
from repro.core.quality_model import QualityModel
from repro.core.service import ServiceRequest
from repro.core.stacking import stacking

register_scheduler("stacking", stacking)
register_scheduler("greedy", greedy_batching)
register_scheduler("fixed_size", fixed_size_batching, aliases=("fixed",))
register_scheduler("single_instance", single_instance, aliases=("single",))
register_scheduler("optimal", optimal_plan)
# the OffsetScheduler instance: statically identical to `stacking`
# (zero offsets delegate), offset-native under online replanning
register_scheduler("stacking_offset", stacking_offset,
                   aliases=("offset",))
# engine-pinned reference entries (scalar ground-truth paths)
register_scheduler("stacking_offset_scalar", StackingOffset("scalar"),
                   aliases=("offset_scalar",))
# engine-pinned jit-compiled entries (repro.core.jaxplan backend)
register_scheduler("stacking_offset_jax", StackingOffset("jax"),
                   aliases=("offset_jax",))


@register_scheduler("stacking_scalar")
def stacking_scalar(services: Sequence[ServiceRequest],
                    tau_prime: Dict[int, float], delay: DelayModel,
                    quality: QualityModel) -> BatchPlan:
    """Algorithm 1 pinned to the scalar reference loop — what the
    array-native engine is tested against and what
    ``benchmarks/planner_speed.py`` measures the speedup over."""
    return stacking(services, tau_prime, delay, quality, engine="scalar")


@register_scheduler("stacking_jax")
def stacking_jax(services: Sequence[ServiceRequest],
                 tau_prime: Dict[int, float], delay: DelayModel,
                 quality: QualityModel) -> BatchPlan:
    """Algorithm 1 pinned to the jit-compiled ``repro.core.jaxplan``
    backend: the whole T* sweep runs as one XLA program.  Equivalent to
    ``stacking`` within the documented tolerance (docs/PERFORMANCE.md);
    raises ValueError if the jax backend is unavailable."""
    return stacking(services, tau_prime, delay, quality, engine="jax")


@register_scheduler("equal_steps")
def equal_steps(services: Sequence[ServiceRequest],
                tau_prime: Dict[int, float], delay: DelayModel,
                quality: QualityModel) -> BatchPlan:
    """Balanced baseline: every service targets the *same* step count T*,
    batched together each step; T* searched like Algorithm 1's outer loop.
    Isolates the paper's insight (ii) — balanced step counts — from its
    clustering/packing machinery.  Dispatches to the active engine's
    lockstep sweep (array-native or a registered backend such as
    ``jax``) unless the scalar engine is selected."""
    eng = arrays.get_engine()
    impl = arrays.engine_impl(eng)
    if impl is not None:
        return impl.equal_steps(services, tau_prime, delay, quality)
    if eng == "vec":
        return arrays.equal_steps_vec(services, tau_prime, delay, quality)
    ids = [s.id for s in services]
    feasible = [k for k in ids if delay.max_steps(tau_prime[k]) > 0]
    t_max = max([delay.max_steps(tau_prime[k]) for k in feasible],
                default=1)

    best_plan, best_q = None, float("inf")
    for t_star in range(1, max(1, t_max) + 1):
        taup = {k: float(tau_prime[k]) for k in ids}
        Tc = {k: 0 for k in ids}
        active = [k for k in ids if taup[k] >= delay.min_task_delay()]
        batches, starts, t = [], [], 0.0
        while active:
            # drop members that cannot afford the current shared batch
            while active:
                g = delay.g(len(active))
                drop = [k for k in active if taup[k] + 1e-12 < g]
                if not drop:
                    break
                for k in drop:
                    active.remove(k)
            if not active:
                break
            g = delay.g(len(active))
            batches.append([(k, Tc[k]) for k in active])
            starts.append(t)
            t += g
            for k in active:
                taup[k] -= g
                Tc[k] += 1
            active = [k for k in active
                      if Tc[k] < t_star
                      and taup[k] + 1e-12 >= delay.min_task_delay()]
        q = quality.mean_fid([Tc[k] for k in ids])
        if q < best_q - 1e-12:
            best_plan, best_q = BatchPlan(
                batches=batches, start_times=starts, steps_completed=Tc,
                delay=delay), q
    assert best_plan is not None
    return best_plan
