"""The provisioner API's three protocols.

The paper's pipeline is a three-stage composition

    Allocator (P1)  ->  Scheduler (P2)  ->  Workload (execution)

and these protocols pin down the one calling convention per stage that
every implementation — paper method, baseline, or beyond-paper variant —
must share.  Anything satisfying them can be dropped into a
``Provisioner`` (and registered by name, see ``repro.api.registry``).
"""

from __future__ import annotations

import dataclasses
from typing import (Any, Dict, List, Optional, Protocol, Sequence, Tuple,
                    runtime_checkable)

import numpy as np

from repro.core.delay_model import DelayModel
from repro.core.plan import BatchPlan
from repro.core.quality_model import QualityModel
from repro.core.service import Scenario, ServiceRequest


@runtime_checkable
class Scheduler(Protocol):
    """P2 solver: generation budgets -> batch-denoising plan."""

    def __call__(self, services: Sequence[ServiceRequest],
                 tau_prime: Dict[int, float], delay: DelayModel,
                 quality: QualityModel) -> BatchPlan: ...


@runtime_checkable
class OffsetScheduler(Scheduler, Protocol):
    """Optional P2 extension: a scheduler that reasons natively about
    per-service progress.

    ``plan`` receives ``offsets`` — denoising steps each service has
    already executed, positional, aligned with ``services`` — and must
    return a plan of *additional* steps whose quality is judged as
    ``fid(offset + new)``.  The online replanner
    (``repro.core.online``) dispatches to ``plan`` whenever progress
    exists, instead of wrapping the quality model; calling the
    instance itself is the plain ``Scheduler`` path (zero offsets), so
    every ``OffsetScheduler`` still drops into static pipelines
    unchanged.

    ``supports_offsets`` must be ``True``: it is the dispatch marker
    the replanner probes for (an unrelated ``plan`` helper on a custom
    scheduler must never be mistaken for this protocol)."""

    supports_offsets: bool

    def plan(self, services: Sequence[ServiceRequest],
             tau_prime: Dict[int, float], delay: DelayModel,
             quality: QualityModel,
             offsets: Sequence[int]) -> BatchPlan: ...


@runtime_checkable
class Allocator(Protocol):
    """P1 solver: scenario (+ inner scheduler for fitness) -> bandwidth
    allocation, one entry per service, summing to the scenario budget."""

    def __call__(self, scenario: Scenario, scheduler: Scheduler,
                 delay: DelayModel, quality: QualityModel,
                 **kwargs) -> np.ndarray: ...


@dataclasses.dataclass
class WorkloadOutput:
    """What executing a plan produced.

    content: per-service generated artifact (image array, token list, ...)
    timings: per-batch ``(batch_size, seconds)`` measurements (empty unless
             the workload was asked to time itself) — the raw material for
             refitting the affine DelayModel g(X) = aX + b.
    """
    content: Dict[int, Any]
    timings: List[Tuple[int, float]] = dataclasses.field(
        default_factory=list)


@runtime_checkable
class Workload(Protocol):
    """A generative step executor: owns the model that turns a BatchPlan
    into content, plus the hardware-calibration hooks (Fig. 1a) and the
    quality model (Fig. 1b) that parameterize the optimization for it."""

    name: str

    def default_delay(self) -> DelayModel: ...

    def default_quality(self) -> QualityModel: ...

    def calibrate(self, key: Optional[Any] = None, *,
                  batch_sizes: Sequence[int] = (1, 2, 4, 8),
                  reps: int = 2) -> DelayModel: ...

    def execute(self, plan: BatchPlan, key: Optional[Any] = None,
                *, timed: bool = False) -> WorkloadOutput: ...
