"""Fleet front end: population-scale provisioning by name.

    from repro.api import FleetProvisioner, make_fleet_scenario

    fleet = make_fleet_scenario(n_cells=500, horizon=200.0,
                                arrival="diurnal",
                                arrival_kwargs={"base_rate": 0.4},
                                bandwidth_hz=1.2e6, seed=7)
    report = FleetProvisioner(fleet, allocator="inv_se",
                              engine="jax").run(mode="epoch")
    print(report.summary())

``make_fleet_scenario`` builds a ``repro.core.fleet.FleetScenario``
from registry names: the sixth registry, ARRIVALS, maps traffic-model
names ("poisson", "diurnal", "flash_crowd", "inhomogeneous", "trace")
to the ``repro.core.traffic`` constructors, so scenario configs stay
plain strings + kwargs like every other pipeline component.  Cell
hardware (bandwidth, speed, capacity) and arrival specs accept either
one value for the whole fleet or one per cell; ``correlation > 0``
draws per-cell Poisson rates from the log-normal shared-factor model
(``traffic.correlated_rates``) instead of a uniform rate.

``FleetProvisioner`` wraps ``repro.core.fleet.simulate_fleet`` the way
``OnlineProvisioner`` wraps ``simulate_online``: component names are
resolved up front (fail fast on typos), ``run`` returns a
``FleetReport`` whose ``summary()`` is one line per fleet run —
streaming aggregates only, never per-service rows.
"""

from __future__ import annotations

import dataclasses
import inspect
from typing import Callable, List, Optional, Sequence, Union

import numpy as np

from repro.api.base import BaseProvisioner, report_dict
from repro.api.registry import (ARRIVALS, display_name, register_arrival)
from repro.core.delay_model import DelayModel
from repro.core.fleet import (FleetCell, FleetResult, FleetScenario,
                              simulate_fleet)
from repro.core.quality_model import QualityModel
from repro.core.traffic import (ArrivalProcess, DiurnalPoisson, FlashCrowd,
                                InhomogeneousPoisson, PoissonProcess,
                                TraceArrivals, correlated_rates, load_trace)

# -- arrival-process registry entries -------------------------------------
# Each entry is a *factory* (name -> constructor); make_fleet_scenario
# instantiates it with the user's kwargs, so configs serialize as
# ("diurnal", {"base_rate": 0.4}) rather than live objects.

register_arrival("poisson", PoissonProcess, aliases=("homogeneous",))
register_arrival("inhomogeneous", InhomogeneousPoisson)
register_arrival("diurnal", DiurnalPoisson)
register_arrival("flash_crowd", FlashCrowd, aliases=("flash",))
register_arrival("trace", load_trace, aliases=("csv", "json"))
register_arrival("trace_times", TraceArrivals)


ArrivalSpec = Union[None, str, ArrivalProcess, Callable]


def _make_process(spec: ArrivalSpec, kwargs: Optional[dict]) -> \
        Optional[ArrivalProcess]:
    """One cell's arrival process from a registry spec: a name is
    looked up in ARRIVALS and called with ``kwargs``; an existing
    process (anything with ``sample``) passes through; ``None`` means
    no local load (shared-stream-only cell)."""
    if spec is None:
        return None
    obj = ARRIVALS.resolve(spec)
    if not isinstance(obj, type) and hasattr(obj, "sample"):
        if kwargs:
            raise ValueError(
                f"arrival process {display_name(spec)!r} is already "
                f"constructed; arrival_kwargs={kwargs} would be ignored")
        return obj
    return obj(**(kwargs or {}))


def _with_rate(spec: ArrivalSpec, kwargs: Optional[dict],
               value: float) -> Optional[dict]:
    """Apply the ``rate=`` sugar to one cell's kwargs under the
    factory's own parameter name (``rate`` for Poisson, ``base_rate``
    for diurnal/flash-crowd curves); loud errors for factories that
    take no rate and for conflicts with explicit kwargs."""
    if spec is None:
        return kwargs
    obj = ARRIVALS.resolve(spec)
    if not isinstance(obj, type) and hasattr(obj, "sample"):
        raise ValueError(
            f"rate= cannot be applied to the already constructed "
            f"arrival process {display_name(spec)!r}")
    try:
        params = inspect.signature(obj).parameters
    except (TypeError, ValueError):  # builtins without signatures
        params = {}
    name = next((p for p in ("rate", "base_rate") if p in params), None)
    if name is None:
        raise ValueError(
            f"arrival {display_name(spec)!r} takes neither rate= nor "
            f"base_rate=; configure it via arrival_kwargs instead")
    if kwargs and name in kwargs:
        raise ValueError(
            f"{name}={kwargs[name]} in arrival_kwargs conflicts with "
            f"the fleet-level rate= sugar")
    return dict(kwargs or {}, **{name: value})


def _per_cell(value, n: int, name: str) -> List:
    """Broadcast a scalar fleet-wide setting to ``n`` cells, or
    validate a per-cell sequence's length."""
    if isinstance(value, (list, tuple, np.ndarray)):
        if len(value) != n:
            raise ValueError(f"{name} has {len(value)} entries for "
                             f"{n} cells")
        return list(value)
    return [value] * n


def make_fleet_scenario(n_cells: int, horizon: float, *,
                        arrival: Union[ArrivalSpec, Sequence] = "poisson",
                        arrival_kwargs: Optional[Union[dict, Sequence]]
                        = None,
                        rate: Optional[Union[float, Sequence[float]]]
                        = None,
                        correlation: float = 0.0,
                        spread: float = 0.3,
                        bandwidth_hz: Union[float, Sequence[float]]
                        = 1.0e6,
                        speed: Union[float, Sequence[float]] = 1.0,
                        capacity: Union[None, int, Sequence] = None,
                        seed: int = 0,
                        deadline_range=(1.0, 3.0),
                        spectral_eff_range=(1.0, 4.0),
                        content_bits: float = 2.0e6,
                        shared_arrival: ArrivalSpec = None,
                        shared_kwargs: Optional[dict] = None
                        ) -> FleetScenario:
    """Build a ``FleetScenario`` from registry names.

    ``arrival`` / ``arrival_kwargs`` / ``bandwidth_hz`` / ``speed`` /
    ``capacity`` each take one value for the whole fleet or a per-cell
    sequence.  ``rate`` is sugar for the rate-parameterized factories
    (it binds to ``rate`` for Poisson, ``base_rate`` for the
    diurnal/flash-crowd curves): a scalar (every cell), a per-cell
    sequence, or — with ``correlation > 0`` — the
    mean of the correlated log-normal rate model
    (``traffic.correlated_rates`` on substream ``[seed, "rates"]``;
    ``spread`` is its dispersion).  ``shared_arrival`` adds the
    fleet-wide stream that ``simulate_fleet(placement=...)`` routes.
    """
    if n_cells < 1:
        raise ValueError(f"n_cells must be >= 1, got {n_cells}")
    specs = _per_cell(arrival, n_cells, "arrival") \
        if isinstance(arrival, (list, tuple)) else [arrival] * n_cells
    if isinstance(arrival_kwargs, (list, tuple)):
        kwlist = _per_cell(arrival_kwargs, n_cells, "arrival_kwargs")
    else:
        kwlist = [arrival_kwargs] * n_cells

    if rate is not None:
        if correlation > 0.0:
            if not np.isscalar(rate):
                raise ValueError("correlation needs a scalar base rate")
            rng = np.random.default_rng([seed, 0x7A7E])
            rates = correlated_rates(rng, n_cells, float(rate),
                                     correlation=correlation,
                                     spread=spread)
        else:
            rates = np.asarray(_per_cell(rate, n_cells, "rate"),
                               dtype=float)
        kwlist = [_with_rate(specs[c], kw, float(rates[c]))
                  for c, kw in enumerate(kwlist)]
    elif correlation > 0.0:
        raise ValueError("correlation requires rate= (the base rate "
                         "the correlated per-cell rates are drawn "
                         "around)")

    bws = _per_cell(bandwidth_hz, n_cells, "bandwidth_hz")
    spds = _per_cell(speed, n_cells, "speed")
    caps = _per_cell(capacity, n_cells, "capacity")
    cells = tuple(
        FleetCell(bandwidth_hz=float(bws[c]), speed=float(spds[c]),
                  capacity=caps[c],
                  process=_make_process(specs[c], kwlist[c]))
        for c in range(n_cells))
    return FleetScenario(
        cells=cells, horizon=horizon, seed=seed,
        deadline_range=tuple(deadline_range),
        spectral_eff_range=tuple(spectral_eff_range),
        content_bits=content_bits,
        shared_process=_make_process(shared_arrival, shared_kwargs))


# -- report + facade ------------------------------------------------------

@dataclasses.dataclass
class FleetReport:
    """One fleet run: the scenario, the streaming aggregates, and the
    component names that produced them."""
    fleet: FleetScenario
    result: FleetResult
    allocator_name: str = ""
    admission_name: str = ""
    placement_name: str = ""

    @property
    def mean_fid(self) -> float:
        return self.result.mean_fid

    @property
    def outage_rate(self) -> float:
        return self.result.outage_rate

    @property
    def reject_rate(self) -> float:
        return self.result.reject_rate

    def summary(self) -> str:
        r = self.result
        return (f"[fleet x{self.fleet.n_cells} {r.mode}/{r.engine}] "
                f"allocator={self.allocator_name} "
                f"admission={self.admission_name or 'admit_all'} | "
                f"arrivals={r.arrivals} admitted={r.admitted} "
                f"rejected={r.rejected} | mean_fid={r.mean_fid:.3f} "
                f"outage={r.outage_rate:.3%} "
                f"p95_delay={r.delay_p95:.3f}s | "
                f"peak_rows={r.peak_live_rows} "
                f"planner_calls={r.planner_calls}")

    def to_dict(self) -> dict:
        """Common report protocol (``repro.api.base.report_dict``)."""
        r = self.result
        return report_dict(
            "fleet", mean_fid=self.mean_fid,
            outage_rate=self.outage_rate, makespan=self.fleet.horizon,
            components={"allocator": self.allocator_name,
                        "admission": self.admission_name or "admit_all",
                        "placement": self.placement_name},
            telemetry={"arrivals": r.arrivals, "admitted": r.admitted,
                       "rejected": r.rejected, "delay_p95": r.delay_p95,
                       "peak_live_rows": r.peak_live_rows,
                       "planner_calls": r.planner_calls,
                       "mode": r.mode, "engine": r.engine},
            reject_rate=self.reject_rate,
            n_cells=self.fleet.n_cells)


class FleetProvisioner(BaseProvisioner):
    """``simulate_fleet`` behind names — the population-scale sibling
    of ``OnlineProvisioner``.  ``engine``/``devices``/``seed``/
    ``execute`` are the unified facade kwargs (``repro.api.base``);
    ``seed=`` re-seeds the fleet's arrival streams, and execution on a
    real model is not defined at fleet scale (``execute=`` truthy
    raises).

    ``admission`` is a fleet policy ``(cell_index, projected
    ServiceOutcome) -> bool`` or ``None`` (admit all); the single-cell
    ADMISSIONS registry is not reused because fleet policies see the
    cell, not the global state dict.
    """

    def __init__(self, fleet: FleetScenario, *,
                 allocator: Union[str, Callable] = "equal",
                 admission: Optional[Callable] = None,
                 delay: Optional[DelayModel] = None,
                 quality: Optional[QualityModel] = None,
                 engine: Optional[str] = None,
                 devices=None, seed: Optional[int] = None,
                 execute=None, execute_kwargs: Optional[dict] = None):
        if seed is not None:
            fleet = dataclasses.replace(fleet, seed=int(seed))
        super().__init__(fleet, engine=engine, devices=devices,
                         seed=seed, execute=execute,
                         execute_kwargs=execute_kwargs)
        self.fleet = fleet
        self.allocator = allocator
        self.admission = admission
        self.delay = delay
        self.quality = quality

    def run(self, mode: str = "epoch", *,
            epoch: Optional[float] = None,
            placement: str = "least_busy",
            reservoir: int = 4096, execute=None) -> FleetReport:
        if self._resolve_execute(execute):
            raise NotImplementedError(
                "fleet runs are streaming aggregates over thousands of "
                "simulated cells; execution on a real model is per "
                "cell/scenario (Provisioner execute=)")
        result = simulate_fleet(
            self.fleet, allocator=self.allocator,
            admission=self.admission, delay=self.delay,
            quality=self.quality, mode=mode, epoch=epoch,
            placement=placement, engine=self.engine,
            devices=self.devices, reservoir=reservoir)
        return FleetReport(
            fleet=self.fleet, result=result,
            allocator_name=display_name(self.allocator),
            admission_name=(display_name(self.admission)
                            if self.admission is not None else ""),
            placement_name=placement)
