"""KV-cache storage helpers.

Supports bf16 (default) and int8 (beyond-paper memory optimization:
symmetric per-(position, head) quantization — halves decode HBM traffic,
the dominant roofline term for the decode shapes).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def alloc(batch: int, max_len: int, kv_heads: int, head_dim: int,
          dtype_str: str = "bfloat16", abstract: bool = False):
    """One direction (k or v) of a single layer-stacked cache is allocated
    by the caller; this allocates an unstacked (B, S, KV, D) buffer."""
    shape = (batch, max_len, kv_heads, head_dim)
    if dtype_str == "int8":
        if abstract:
            return {"q": jax.ShapeDtypeStruct(shape, jnp.int8),
                    "s": jax.ShapeDtypeStruct(shape[:-1], jnp.float32)}
        return {"q": jnp.zeros(shape, jnp.int8),
                "s": jnp.zeros(shape[:-1], jnp.float32)}
    dt = jnp.bfloat16 if dtype_str == "bfloat16" else jnp.float32
    if abstract:
        return jax.ShapeDtypeStruct(shape, dt)
    return jnp.zeros(shape, dt)


def write(cache, new, pos):
    """Write new (B, S_new, KV, D) at positions pos (B,) .. pos+S_new."""
    B, S_new = new.shape[0], new.shape[1]
    idx = pos[:, None] + jnp.arange(S_new)[None]          # (B, S_new)
    b_idx = jnp.arange(B)[:, None]
    if isinstance(cache, dict):                            # int8
        scale = jnp.max(jnp.abs(new.astype(jnp.float32)),
                        axis=-1) / 127.0                   # (B,S_new,KV)
        q = jnp.round(new.astype(jnp.float32)
                      / jnp.maximum(scale, 1e-8)[..., None]).astype(jnp.int8)
        return {"q": cache["q"].at[b_idx, idx].set(q, mode="drop"),
                "s": cache["s"].at[b_idx, idx].set(scale, mode="drop")}
    return cache.at[b_idx, idx].set(new.astype(cache.dtype), mode="drop")


def read(cache):
    """Return a dense (B, S, KV, D) view (dequantized if int8)."""
    if isinstance(cache, dict):
        return cache["q"].astype(jnp.float32) * cache["s"][..., None]
    return cache


# ---------------------------------------------------------------------------
# Layer-stacked in-place variants (decode_inplace_cache): the cache keeps
# its (lead..., B, S, KV, D) stacked layout and lives in the layer-scan
# CARRY; writes scatter one token slice, reads dynamic-slice one layer.
# ---------------------------------------------------------------------------

def write_layer(cache_all, lead_idx, new, pos, uniform: bool = False):
    """cache_all: (lead..., B, S, KV, D); lead_idx: tuple of (traced) layer
    indices; new: (B, S_new, KV, D); pos: (B,) write positions.

    uniform=True: all batch rows share pos[0] (serve_step semantics) --
    lowers to one contiguous dynamic-update-slice instead of a scatter.
    (XLA:CPU expands bf16 scatters through a full-buffer f32 round trip;
    DUS is in-place on every backend.  §Perf.)"""
    if isinstance(cache_all, dict):
        scale = jnp.max(jnp.abs(new.astype(jnp.float32)), axis=-1) / 127.0
        q = jnp.round(new.astype(jnp.float32)
                      / jnp.maximum(scale, 1e-8)[..., None]).astype(jnp.int8)
        return {"q": write_layer(cache_all["q"], lead_idx, q, pos, uniform),
                "s": _write_layer_arr(cache_all["s"], lead_idx, scale, pos,
                                      uniform)}
    return _write_layer_arr(cache_all, lead_idx, new.astype(cache_all.dtype),
                            pos, uniform)


def _write_layer_arr(buf, lead_idx, new, pos, uniform: bool = False):
    B, S_new = new.shape[0], new.shape[1]
    if uniform:
        upd = new
        for _ in lead_idx:
            upd = upd[None]
        zero = jnp.zeros((), jnp.int32)
        start = (*[jnp.asarray(i, jnp.int32) for i in lead_idx],
                 zero, pos[0].astype(jnp.int32)) + (zero,) * (new.ndim - 2)
        return jax.lax.dynamic_update_slice(buf, upd.astype(buf.dtype),
                                            start)
    idx = pos[:, None] + jnp.arange(S_new)[None]          # (B, S_new)
    b_idx = jnp.arange(B)[:, None]
    return buf.at[(*lead_idx, b_idx, idx)].set(new, mode="drop")


def layer_view(cache_all, lead_idx):
    """One layer's (B, S, KV, D) buffer (same storage structure, no
    dequantization; a dynamic-slice, not a copy of the stack)."""
    if isinstance(cache_all, dict):
        return {"q": cache_all["q"][lead_idx],
                "s": cache_all["s"][lead_idx]}
    return cache_all[lead_idx]


def read_layer(cache_all, lead_idx):
    """Dense dequantized (B, S, KV, D) view of one layer."""
    return read(layer_view(cache_all, lead_idx))


def slice_window(layer_cache, start, window):
    """Dynamic-slice a window [start, start+window) along the seq axis of a
    (B, S, KV, D) layer view (decode_slice_reads)."""
    def sl(x, seq_axis=1):
        return jax.lax.dynamic_slice_in_dim(x, start, window, axis=seq_axis)
    if isinstance(layer_cache, dict):
        return {"q": sl(layer_cache["q"]), "s": sl(layer_cache["s"])}
    return sl(layer_cache)
