"""Whisper-tiny backbone [arXiv:2212.04356]: encoder-decoder transformer.

The mel-spectrogram + conv feature extractor is a STUB per the assignment
carve-out — the model consumes precomputed frame embeddings
(batch, num_audio_frames, d_model) supplied by ``input_specs``.

Encoder: bidirectional self-attention blocks over frames.
Decoder: causal self-attention + cross-attention to the encoder output,
every layer (standard enc-dec).  Decode shapes exercise the decoder with a
KV cache of the assigned seq_len; cross-KV is computed once at prefill.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.config import ModelConfig, RunConfig
from repro.models import kv_cache
from repro.models.layers import (
    apply_mlp, apply_norm, attn_schema, chunked_attention, decode_attention,
    embed, embed_schema, mlp_schema, norm_schema, out_project, qkv_project,
    unembed)
from repro.models.params import constrain
from repro.models.transformer import stack_schema


def _enc_layer_schema(cfg):
    return {"ln1": norm_schema(cfg), "attn": attn_schema(cfg),
            "ln2": norm_schema(cfg), "mlp": mlp_schema(cfg)}


def schema(cfg: ModelConfig):
    dec_layer = {"ln1": norm_schema(cfg), "attn": attn_schema(cfg),
                 "ln_cross": norm_schema(cfg), "cross": attn_schema(cfg),
                 "ln2": norm_schema(cfg), "mlp": mlp_schema(cfg)}
    return {
        "embed": embed_schema(cfg),
        "enc_layers": stack_schema(_enc_layer_schema(cfg),
                                   cfg.encoder_layers),
        "enc_norm": norm_schema(cfg),
        "dec_layers": stack_schema(dec_layer, cfg.num_layers),
        "final_norm": norm_schema(cfg),
    }


def encode(cfg: ModelConfig, params, frames, run: RunConfig):
    """frames: (B, F, d) stub embeddings -> encoder output (B, F, d)."""
    x = frames.astype(params["embed"]["tok"].dtype)

    def body(x, lp):
        h = apply_norm(cfg, lp["ln1"], x)
        q, k, v = qkv_project(cfg, lp["attn"], h, rope=False)
        o = chunked_attention(q, k, v, causal=False)
        x = x + out_project(lp["attn"], o)
        x = x + apply_mlp(cfg, lp["mlp"], apply_norm(cfg, lp["ln2"], x))
        return constrain(x, ("batch", "seq", "embed")), None

    x, _ = jax.lax.scan(body, x, params["enc_layers"])
    return apply_norm(cfg, params["enc_norm"], x)


def forward(cfg: ModelConfig, params, tokens, run: RunConfig,
            extras: Optional[dict] = None, collect_kv: bool = False,
            last_only: bool = False):
    """Teacher-forced full-sequence decode (training)."""
    B, S = tokens.shape
    enc_out = encode(cfg, params, extras["audio_frames"], run)
    x = embed(params["embed"], tokens)
    positions = jnp.arange(S, dtype=jnp.float32)[None]

    def body(carry, lp):
        x, aux = carry
        h = apply_norm(cfg, lp["ln1"], x)
        q, k, v = qkv_project(cfg, lp["attn"], h, positions=positions)
        o = chunked_attention(q, k, v, causal=True,
                              window=run.decode_window)
        x = x + out_project(lp["attn"], o)
        h = apply_norm(cfg, lp["ln_cross"], x)
        cq, ck, cv = qkv_project(cfg, lp["cross"], h, kv_x=enc_out,
                                 rope=False)
        co = chunked_attention(cq, ck, cv, causal=False)
        x = x + out_project(lp["cross"], co)
        x = x + apply_mlp(cfg, lp["mlp"], apply_norm(cfg, lp["ln2"], x))
        x = constrain(x, ("batch", "seq", "embed"))
        return (x, aux), ((k, v, ck, cv) if collect_kv else None)

    if run.remat == "block":
        body = jax.checkpoint(body)

    (x, aux), kvs = jax.lax.scan(body, (x, 0.0), params["dec_layers"])
    if last_only:
        x = x[:, -1:]
    x = apply_norm(cfg, params["final_norm"], x)
    return unembed(cfg, params["embed"], x), aux, kvs


def init_cache(cfg: ModelConfig, batch: int, max_len: int, run: RunConfig,
               abstract: bool = False):
    L, KV, hd = cfg.num_layers, cfg.num_kv_heads, cfg.resolved_head_dim

    def kv_buf(length):
        buf = kv_cache.alloc(batch, length, KV, hd, run.kv_cache_dtype,
                             abstract=abstract)
        return jax.tree_util.tree_map(
            lambda x: (jax.ShapeDtypeStruct((L,) + x.shape, x.dtype)
                       if abstract else jnp.zeros((L,) + x.shape, x.dtype)),
            buf)

    pos = (jax.ShapeDtypeStruct((batch,), jnp.int32) if abstract
           else jnp.zeros((batch,), jnp.int32))
    return {"pos": pos, "k": kv_buf(max_len), "v": kv_buf(max_len),
            "cross_k": kv_buf(cfg.num_audio_frames),
            "cross_v": kv_buf(cfg.num_audio_frames)}


def prefill(cfg: ModelConfig, params, tokens, max_len: int, run: RunConfig,
            extras: Optional[dict] = None):
    B, S = tokens.shape
    logits, aux, kvs = forward(cfg, params, tokens, run, extras,
                               collect_kv=True,
                               last_only=run.prefill_logits == "last")
    k, v, ck, cv = kvs
    cache = init_cache(cfg, B, max_len, run)
    pos0 = jnp.zeros((B,), jnp.int32)
    wr = jax.vmap(kv_cache.write, in_axes=(0, 0, None))
    cache["k"] = wr(cache["k"], k, pos0)
    cache["v"] = wr(cache["v"], v, pos0)
    cache["cross_k"] = wr(cache["cross_k"], ck, pos0)
    cache["cross_v"] = wr(cache["cross_v"], cv, pos0)
    cache["pos"] = jnp.full((B,), S, jnp.int32)
    return logits, cache


def decode_step(cfg: ModelConfig, params, token, cache, run: RunConfig,
                extras: Optional[dict] = None):
    B = token.shape[0]
    pos = cache["pos"]
    x = embed(params["embed"], token)
    mem_len = jnp.full((B,), cfg.num_audio_frames, jnp.int32)

    from repro.models.transformer import _decode_attend_prewrite

    if run.decode_inplace_cache:
        def body_ip(carry, xs):
            x, kc_all, vc_all = carry
            lp, ck, cv, li = xs
            h = apply_norm(cfg, lp["ln1"], x)
            q, k, v = qkv_project(
                cfg, lp["attn"], h,
                positions=pos[:, None].astype(jnp.float32))
            k_old = kv_cache.layer_view(kc_all, (li,))
            v_old = kv_cache.layer_view(vc_all, (li,))
            kc_all = kv_cache.write_layer(kc_all, (li,), k, pos,
                                          uniform=run.decode_uniform_pos)
            vc_all = kv_cache.write_layer(vc_all, (li,), v, pos,
                                          uniform=run.decode_uniform_pos)
            o = _decode_attend_prewrite(cfg, q, k_old, v_old, k, v, pos,
                                        run)
            x = x + out_project(lp["attn"], o)
            h = apply_norm(cfg, lp["ln_cross"], x)
            cq = jnp.einsum("bsd,dhk->bshk", h, lp["cross"]["wq"])
            co = decode_attention(cq, kv_cache.read(ck), kv_cache.read(cv),
                                  mem_len)
            x = x + out_project(lp["cross"], co)
            x = x + apply_mlp(cfg, lp["mlp"],
                              apply_norm(cfg, lp["ln2"], x))
            return (x, kc_all, vc_all), None

        (x, kc, vc), _ = jax.lax.scan(
            body_ip, (x, cache["k"], cache["v"]),
            (params["dec_layers"], cache["cross_k"], cache["cross_v"],
             jnp.arange(cfg.num_layers)))
    else:
        def body(x, xs):
            lp, kc, vc, ck, cv = xs
            h = apply_norm(cfg, lp["ln1"], x)
            q, k, v = qkv_project(
                cfg, lp["attn"], h,
                positions=pos[:, None].astype(jnp.float32))
            kc = kv_cache.write(kc, k, pos)
            vc = kv_cache.write(vc, v, pos)
            o = decode_attention(q, kv_cache.read(kc), kv_cache.read(vc),
                                 pos + 1, window=run.decode_window)
            x = x + out_project(lp["attn"], o)
            h = apply_norm(cfg, lp["ln_cross"], x)
            cq = jnp.einsum("bsd,dhk->bshk", h, lp["cross"]["wq"])
            co = decode_attention(cq, kv_cache.read(ck), kv_cache.read(cv),
                                  mem_len)
            x = x + out_project(lp["cross"], co)
            x = x + apply_mlp(cfg, lp["mlp"],
                              apply_norm(cfg, lp["ln2"], x))
            return x, (kc, vc)

        x, (kc, vc) = jax.lax.scan(
            body, x, (params["dec_layers"], cache["k"], cache["v"],
                      cache["cross_k"], cache["cross_v"]))
    x = apply_norm(cfg, params["final_norm"], x)
    logits = unembed(cfg, params["embed"], x)
    return logits, dict(cache, k=kc, v=vc, pos=pos + 1)
