"""Zamba2 [arXiv:2411.15242]: Mamba2 backbone + one *weight-shared*
attention+MLP block applied every `shared_attn_every`-th layer.

The backbone scans over groups of `shared_attn_every` Mamba2 layers; the
shared block's weights live outside the scan (a closure constant — this is
the weight sharing) and are applied once per group.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.config import ModelConfig, RunConfig
from repro.models import kv_cache
from repro.models.layers import (
    apply_mlp, apply_norm, attn_schema, chunked_attention, decode_attention,
    embed, embed_schema, mlp_schema, norm_schema, out_project, qkv_project,
    unembed)
from repro.models.params import constrain
from repro.models.ssm import (mamba2_forward, mamba2_init_state, mamba2_schema,
                              mamba2_step, ssm_dims)
from repro.models.transformer import stack_schema


def _groups(cfg: ModelConfig) -> int:
    assert cfg.num_layers % cfg.shared_attn_every == 0
    return cfg.num_layers // cfg.shared_attn_every


def schema(cfg: ModelConfig):
    mamba_layer = {"ln": norm_schema(cfg), "mamba": mamba2_schema(cfg)}
    return {
        "embed": embed_schema(cfg),
        "final_norm": norm_schema(cfg),
        "groups": stack_schema(
            stack_schema(mamba_layer, cfg.shared_attn_every), _groups(cfg)),
        "shared": {"ln1": norm_schema(cfg), "attn": attn_schema(cfg),
                   "ln2": norm_schema(cfg), "mlp": mlp_schema(cfg)},
    }


def _shared_block_seq(cfg, sp, x, positions, run):
    h = apply_norm(cfg, sp["ln1"], x)
    q, k, v = qkv_project(cfg, sp["attn"], h, positions=positions)
    o = chunked_attention(q, k, v, causal=True,
                          window=run.decode_window)
    x = x + out_project(sp["attn"], o)
    x = x + apply_mlp(cfg, sp["mlp"], apply_norm(cfg, sp["ln2"], x))
    return constrain(x, ("batch", "seq", "embed")), (k, v)


def forward(cfg: ModelConfig, params, tokens, run: RunConfig,
            extras: Optional[dict] = None, collect_kv: bool = False,
            last_only: bool = False):
    B, S = tokens.shape
    x = embed(params["embed"], tokens)
    positions = jnp.arange(S, dtype=jnp.float32)[None]
    sp = params["shared"]

    def mamba_layer(carry, lp):
        x = carry
        h, _ = mamba2_forward(cfg, lp["mamba"],
                              apply_norm(cfg, lp["ln"], x))
        return constrain(x + h, ("batch", "seq", "embed")), None

    def group_body(carry, gp):
        x, aux = carry
        x, kv = _shared_block_seq(cfg, sp, x, positions, run)
        x, _ = jax.lax.scan(mamba_layer, x, gp)
        return (x, aux), (kv if collect_kv else None)

    if run.remat in ("block", "group"):
        group_body = jax.checkpoint(group_body)

    (x, aux), kvs = jax.lax.scan(group_body, (x, 0.0), params["groups"])
    if last_only:
        x = x[:, -1:]
    x = apply_norm(cfg, params["final_norm"], x)
    return unembed(cfg, params["embed"], x), aux, kvs


def init_cache(cfg: ModelConfig, batch: int, max_len: int, run: RunConfig,
               abstract: bool = False):
    G = _groups(cfg)
    KV, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    d_in, H, N = ssm_dims(cfg)

    def kv_buf():
        buf = kv_cache.alloc(batch, max_len, KV, hd, run.kv_cache_dtype,
                             abstract=abstract)
        return jax.tree_util.tree_map(
            lambda x: (jax.ShapeDtypeStruct((G,) + x.shape, x.dtype)
                       if abstract else jnp.zeros((G,) + x.shape, x.dtype)),
            buf)

    if abstract:
        ssm = {"conv": jax.ShapeDtypeStruct(
                   (G, cfg.shared_attn_every, batch, cfg.ssm_conv - 1,
                    d_in + 2 * N), jnp.bfloat16),
               "ssm": jax.ShapeDtypeStruct(
                   (G, cfg.shared_attn_every, batch, H, cfg.ssm_head_dim, N),
                   jnp.float32)}
        pos = jax.ShapeDtypeStruct((batch,), jnp.int32)
    else:
        one = mamba2_init_state(cfg, batch, jnp.bfloat16)
        ssm = jax.tree_util.tree_map(
            lambda x: jnp.zeros((G, cfg.shared_attn_every) + x.shape,
                                x.dtype), one)
        pos = jnp.zeros((batch,), jnp.int32)
    return {"pos": pos, "k": kv_buf(), "v": kv_buf(), "ssm": ssm}


def prefill(cfg: ModelConfig, params, tokens, max_len: int, run: RunConfig,
            extras: Optional[dict] = None):
    """Prefill that also materializes SSM states: rerun forward collecting
    both attention KV and final mamba states per layer."""
    B, S = tokens.shape
    x = embed(params["embed"], tokens)
    positions = jnp.arange(S, dtype=jnp.float32)[None]
    sp = params["shared"]

    def mamba_layer(x, lp):
        h, st = mamba2_forward(cfg, lp["mamba"],
                               apply_norm(cfg, lp["ln"], x))
        return x + h, st

    def group_body(x, gp):
        x, kv = _shared_block_seq(cfg, sp, x, positions, run)
        x, states = jax.lax.scan(mamba_layer, x, gp)
        return x, (kv, states)

    x, (kvs, states) = jax.lax.scan(group_body, x, params["groups"])
    if run.prefill_logits == "last":
        x = x[:, -1:]
    x = apply_norm(cfg, params["final_norm"], x)
    logits = unembed(cfg, params["embed"], x)

    cache = init_cache(cfg, B, max_len, run)
    pos0 = jnp.zeros((B,), jnp.int32)
    wr = jax.vmap(kv_cache.write, in_axes=(0, 0, None))
    cache["k"] = wr(cache["k"], kvs[0], pos0)
    cache["v"] = wr(cache["v"], kvs[1], pos0)
    cache["ssm"] = jax.tree_util.tree_map(
        lambda z, s: s.astype(z.dtype), cache["ssm"], states)
    cache["pos"] = jnp.full((B,), S, jnp.int32)
    return logits, cache


def decode_step(cfg: ModelConfig, params, token, cache, run: RunConfig,
                extras: Optional[dict] = None):
    pos = cache["pos"]
    x = embed(params["embed"], token)
    sp = params["shared"]

    def mamba_layer(x, xs):
        lp, st = xs
        h, st = mamba2_step(cfg, lp["mamba"],
                            apply_norm(cfg, lp["ln"], x), st)
        return x + h, st

    from repro.models.transformer import _decode_attend_prewrite

    if run.decode_inplace_cache:
        def group_body_ip(carry, xs):
            x, kc_all, vc_all = carry
            gp, st, gi = xs
            h = apply_norm(cfg, sp["ln1"], x)
            q, k, v = qkv_project(
                cfg, sp["attn"], h,
                positions=pos[:, None].astype(jnp.float32))
            k_old = kv_cache.layer_view(kc_all, (gi,))
            v_old = kv_cache.layer_view(vc_all, (gi,))
            kc_all = kv_cache.write_layer(kc_all, (gi,), k, pos,
                                          uniform=run.decode_uniform_pos)
            vc_all = kv_cache.write_layer(vc_all, (gi,), v, pos,
                                          uniform=run.decode_uniform_pos)
            o = _decode_attend_prewrite(cfg, q, k_old, v_old, k, v, pos,
                                        run)
            x = x + out_project(sp["attn"], o)
            x = x + apply_mlp(cfg, sp["mlp"],
                              apply_norm(cfg, sp["ln2"], x))
            x, st = jax.lax.scan(mamba_layer, x, (gp, st))
            return (x, kc_all, vc_all), st

        G = _groups(cfg)
        (x, kc, vc), st = jax.lax.scan(
            group_body_ip, (x, cache["k"], cache["v"]),
            (params["groups"], cache["ssm"], jnp.arange(G)))
    else:
        def group_body(carry, xs):
            x = carry
            gp, kc, vc, st = xs
            h = apply_norm(cfg, sp["ln1"], x)
            q, k, v = qkv_project(
                cfg, sp["attn"], h,
                positions=pos[:, None].astype(jnp.float32))
            kc = kv_cache.write(kc, k, pos)
            vc = kv_cache.write(vc, v, pos)
            o = decode_attention(q, kv_cache.read(kc), kv_cache.read(vc),
                                 pos + 1, window=run.decode_window)
            x = x + out_project(sp["attn"], o)
            x = x + apply_mlp(cfg, sp["mlp"],
                              apply_norm(cfg, sp["ln2"], x))
            x, st = jax.lax.scan(mamba_layer, x, (gp, st))
            return x, (kc, vc, st)

        x, (kc, vc, st) = jax.lax.scan(
            group_body, x,
            (params["groups"], cache["k"], cache["v"], cache["ssm"]))
    x = apply_norm(cfg, params["final_norm"], x)
    logits = unembed(cfg, params["embed"], x)
    return logits, dict(cache, k=kc, v=vc, ssm=st, pos=pos + 1)
