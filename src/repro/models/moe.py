"""Mixture-of-Experts layer: top-k routing with static per-expert capacity.

TPU-native formulation (GShard/MaxText-style):
  * tokens are routed *within groups* (group = batch row) so the
    position-in-expert cumsum runs along an unsharded axis — no collective
    is needed for routing bookkeeping;
  * dispatch scatters tokens into a dense (B, E, C, d) buffer (overflow
    dropped at per-group capacity C); with the batch axis sharded on "data"
    and the expert axis on "model", the dispatched buffer's resharding
    lowers to the expected all-to-all;
  * experts run as one batched einsum sharded on the expert axis;
  * outputs are combined with the (renormalized) router gates.

Compute matches active-expert FLOPs x capacity_factor rather than the
dense-dispatch E-times blowup.  Supports DeepSeekMoE shared experts
(always-on dense experts added to the routed output) [arXiv:2401.06066].
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.params import P, constrain
from repro.models.layers import _act


def moe_schema(cfg):
    d, f, E = cfg.d_model, cfg.d_ff_expert, cfg.num_experts
    s = {
        "router": P((d, E), ("embed", "experts"), scale=0.02),
        "up": P((E, d, f), ("experts", "embed", "mlp")),
        "gate": P((E, d, f), ("experts", "embed", "mlp")),
        "down": P((E, f, d), ("experts", "mlp", "embed")),
    }
    if cfg.num_shared_experts:
        fs = f * cfg.num_shared_experts
        s["shared_up"] = P((d, fs), ("embed", "mlp"))
        s["shared_gate"] = P((d, fs), ("embed", "mlp"))
        s["shared_down"] = P((fs, d), ("mlp", "embed"))
    return s


def moe_capacity(cfg, seq_len: int, capacity_factor: float) -> int:
    E, K = cfg.num_experts, cfg.experts_per_token
    return max(1, int(seq_len * K * capacity_factor / E))


def apply_moe(cfg, p, x, *, capacity_factor: float = 1.25):
    """x: (B, S, d) -> (out (B, S, d), aux_loss scalar)."""
    B, S, d = x.shape
    E, K = cfg.num_experts, cfg.experts_per_token
    C = moe_capacity(cfg, S, capacity_factor)

    logits = jnp.einsum("bsd,de->bse", x.astype(jnp.float32),
                        p["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)                   # (B, S, E)
    gate_vals, expert_ids = jax.lax.top_k(probs, K)           # (B, S, K)
    gate_vals = gate_vals / jnp.maximum(
        gate_vals.sum(-1, keepdims=True), 1e-9)               # renormalize

    # Load-balance auxiliary loss (Switch-style), per group then averaged.
    me = probs.mean(axis=1)                                   # (B, E)
    sel = jax.nn.one_hot(expert_ids, E, dtype=jnp.float32)    # (B, S, K, E)
    ce = sel.sum(axis=(1, 2)) / (S * K)                       # (B, E)
    aux = cfg.router_aux_coef * E * jnp.mean(jnp.sum(me * ce, axis=-1))

    # ---- dispatch (within each group/batch-row) ---------------------------
    flat_e = expert_ids.reshape(B, S * K)                     # (B, SK)
    flat_g = gate_vals.reshape(B, S * K)
    flat_t = jnp.broadcast_to(
        jnp.repeat(jnp.arange(S), K)[None], (B, S * K))
    onehot = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)       # (B, SK, E)
    pos = jnp.take_along_axis(
        jnp.cumsum(onehot, axis=1) - 1, flat_e[..., None], axis=-1)[..., 0]
    keep = pos < C

    def scatter_row(e_row, p_row, t_row, g_row, k_row):
        tok = jnp.full((E, C), S, jnp.int32)                  # S = pad slot
        tok = tok.at[e_row, p_row].set(
            jnp.where(k_row, t_row, S), mode="drop")
        gt = jnp.zeros((E, C), jnp.float32).at[e_row, p_row].set(
            jnp.where(k_row, g_row, 0.0), mode="drop")
        return tok, gt

    disp_tok, disp_gate = jax.vmap(scatter_row)(
        flat_e, pos, flat_t, flat_g, keep)                    # (B, E, C)

    x_pad = jnp.concatenate(
        [x, jnp.zeros((B, 1, d), x.dtype)], axis=1)           # (B, S+1, d)
    expert_in = jnp.take_along_axis(
        x_pad[:, :, None, :],
        disp_tok.reshape(B, E * C, 1, 1).astype(jnp.int32), axis=1,
    ).reshape(B, E, C, d)
    expert_in = constrain(expert_in, ("batch", "experts", None, "embed"))

    act = _act(cfg.activation)
    h = jnp.einsum("becd,edf->becf", expert_in, p["up"])
    h = h * act(jnp.einsum("becd,edf->becf", expert_in, p["gate"]))
    h = constrain(h, ("batch", "experts", None, "mlp"))
    expert_out = jnp.einsum("becf,efd->becd", h, p["down"])   # (B, E, C, d)
    expert_out = constrain(expert_out, ("batch", "experts", None, "embed"))

    # ---- combine ----------------------------------------------------------
    weighted = expert_out.astype(jnp.float32) * disp_gate[..., None]

    def combine_row(tok, w):
        return jnp.zeros((S + 1, d), jnp.float32).at[
            tok.reshape(-1)].add(w.reshape(E * C, d))[:S]

    out = jax.vmap(combine_row)(disp_tok, weighted).astype(x.dtype)
    out = constrain(out, ("batch", "seq", "embed"))

    if cfg.num_shared_experts:
        sh = jnp.einsum("bsd,df->bsf", x, p["shared_up"])
        sh = sh * act(jnp.einsum("bsd,df->bsf", x, p["shared_gate"]))
        out = out + jnp.einsum("bsf,fd->bsd", sh, p["shared_down"])

    return out, aux
