"""xLSTM-125M model assembly: alternating mLSTM / sLSTM blocks.

With ``xlstm_slstm_every = 2`` the 12 layers form 6 groups of
(mLSTM block, sLSTM block); the model scans over groups (mixed param
shapes prevent a single flat scan).  Attention-free: the decode "cache" is
the recurrent state — O(1) in sequence length, so the long_500k shape is
native (no sliding-window carve-out needed).
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.config import ModelConfig, RunConfig
from repro.models.layers import apply_norm, embed, embed_schema, norm_schema, unembed
from repro.models.params import constrain
from repro.models.transformer import stack_schema
from repro.models.xlstm import (
    mlstm_forward, mlstm_init_state, mlstm_schema, mlstm_step,
    slstm_forward, slstm_init_state, slstm_schema, slstm_step)


def _groups(cfg: ModelConfig) -> int:
    every = cfg.xlstm_slstm_every or 2
    assert cfg.num_layers % every == 0
    return cfg.num_layers // every


def schema(cfg: ModelConfig):
    G = _groups(cfg)
    group = {"m_ln": norm_schema(cfg), "mlstm": mlstm_schema(cfg),
             "s_ln": norm_schema(cfg), "slstm": slstm_schema(cfg)}
    return {"embed": embed_schema(cfg), "final_norm": norm_schema(cfg),
            "groups": stack_schema(group, G)}


def forward(cfg: ModelConfig, params, tokens, run: RunConfig,
            extras: Optional[dict] = None, collect_kv: bool = False,
            last_only: bool = False):
    B, S = tokens.shape
    x = embed(params["embed"], tokens)

    def group_body(carry, gp):
        x = carry
        h, mst = mlstm_forward(cfg, gp["mlstm"],
                               apply_norm(cfg, gp["m_ln"], x))
        x = constrain(x + h, ("batch", "seq", "embed"))
        h, sst = slstm_forward(cfg, gp["slstm"],
                               apply_norm(cfg, gp["s_ln"], x))
        x = constrain(x + h, ("batch", "seq", "embed"))
        return x, ((mst, sst) if collect_kv else None)

    if run.remat in ("block", "group"):
        group_body = jax.checkpoint(group_body)

    x, states = jax.lax.scan(group_body, x, params["groups"])
    if last_only:
        x = x[:, -1:]
    x = apply_norm(cfg, params["final_norm"], x)
    return unembed(cfg, params["embed"], x), 0.0, states


def init_cache(cfg: ModelConfig, batch: int, max_len: int, run: RunConfig,
               abstract: bool = False):
    G = _groups(cfg)
    m = mlstm_init_state(cfg, batch)
    s = slstm_init_state(cfg, batch)
    state = {"mlstm": m, "slstm": s}
    stacked = jax.tree_util.tree_map(
        lambda x: jnp.zeros((G,) + x.shape, x.dtype), state)
    if abstract:
        stacked = jax.tree_util.tree_map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), stacked)
        return {"pos": jax.ShapeDtypeStruct((batch,), jnp.int32), **stacked}
    return {"pos": jnp.zeros((batch,), jnp.int32), **stacked}


def prefill(cfg: ModelConfig, params, tokens, max_len: int, run: RunConfig,
            extras: Optional[dict] = None):
    B, S = tokens.shape
    logits, _, states = forward(cfg, params, tokens, run, extras,
                                collect_kv=True,
                                last_only=run.prefill_logits == "last")
    mst, sst = states
    cache = init_cache(cfg, B, max_len, run)
    cache = dict(cache, mlstm=mst, slstm=sst,
                 pos=jnp.full((B,), S, jnp.int32))
    return logits, cache


def decode_step(cfg: ModelConfig, params, token, cache, run: RunConfig,
                extras: Optional[dict] = None):
    x = embed(params["embed"], token)

    def group_body(x, xs):
        gp, mst, sst = xs
        h, mst = mlstm_step(cfg, gp["mlstm"],
                            apply_norm(cfg, gp["m_ln"], x), mst)
        x = x + h
        h, sst = slstm_step(cfg, gp["slstm"],
                            apply_norm(cfg, gp["s_ln"], x), sst)
        return x + h, (mst, sst)

    x, (mst, sst) = jax.lax.scan(
        group_body, x, (params["groups"], cache["mlstm"], cache["slstm"]))
    x = apply_norm(cfg, params["final_norm"], x)
    logits = unembed(cfg, params["embed"], x)
    return logits, dict(cache, mlstm=mst, slstm=sst, pos=cache["pos"] + 1)
