"""Generic decoder-only transformer LM covering the dense, MoE and VLM
(cross-attention) assigned architectures.

Layer weights are *stacked* and the model scans over layers
(``jax.lax.scan``) so HLO size is layer-count-independent — required to
compile 88-100 layer configs in the dry-run, and the production-correct
choice anyway.  The VLM variant scans over *groups* of
(cross_attn_every - 1) self layers + 1 cross-attn layer.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.config import ModelConfig, RunConfig
from repro.models import kv_cache
from repro.models.layers import (
    apply_mlp, apply_norm, attn_schema, chunked_attention, decode_attention,
    embed, embed_schema, mlp_schema, norm_schema, out_project, qkv_project,
    unembed)
from repro.models.moe import apply_moe, moe_schema
from repro.models.params import P, constrain, tree_map_schema


# ---------------------------------------------------------------------------
# Schema
# ---------------------------------------------------------------------------

def stack_schema(sub, n: int):
    return tree_map_schema(
        lambda p: P((n,) + p.shape, ("layers",) + p.axes,
                    init=p.init, scale=p.scale), sub)


def _layer_schema(cfg: ModelConfig):
    s = {"ln1": norm_schema(cfg), "attn": attn_schema(cfg),
         "ln2": norm_schema(cfg)}
    if cfg.is_moe:
        s["moe"] = moe_schema(cfg)
    else:
        s["mlp"] = mlp_schema(cfg)
    return s


def _cross_layer_schema(cfg: ModelConfig):
    return {"ln1": norm_schema(cfg), "attn": attn_schema(cfg),
            "ln2": norm_schema(cfg), "mlp": mlp_schema(cfg),
            "gate_attn": P((1,), (None,), init="zeros"),
            "gate_mlp": P((1,), (None,), init="zeros")}


def schema(cfg: ModelConfig):
    s = {"embed": embed_schema(cfg), "final_norm": norm_schema(cfg)}
    if cfg.cross_attn_every:
        n_self = cfg.cross_attn_every - 1
        n_groups = cfg.num_layers // cfg.cross_attn_every
        s["groups"] = {
            "self": stack_schema(stack_schema(_layer_schema(cfg), n_self),
                                 n_groups),
            "cross": stack_schema(_cross_layer_schema(cfg), n_groups),
        }
    else:
        s["layers"] = stack_schema(_layer_schema(cfg), cfg.num_layers)
    return s


# ---------------------------------------------------------------------------
# Blocks
# ---------------------------------------------------------------------------

def _self_attn_seq(cfg, lp, x, positions, run: RunConfig, window: int = 0,
                   causal: bool = True):
    """Full-sequence self attention; returns (out, (k, v))."""
    q, k, v = qkv_project(cfg, lp, x, positions=positions)
    o = chunked_attention(q, k, v, causal=causal, window=window,
                          parallel_q=run.prefill_parallel_q)
    return out_project(lp, o), (k, v)


def _block_seq(cfg, lp, x, positions, run: RunConfig, causal=True,
               window=0):
    h, kv = _self_attn_seq(cfg, lp["attn"],
                           apply_norm(cfg, lp["ln1"], x), positions, run,
                           window=window, causal=causal)
    x = x + h
    x = constrain(x, ("batch", "seq", "embed"))
    h = apply_norm(cfg, lp["ln2"], x)
    if cfg.is_moe:
        h, aux = apply_moe(cfg, lp["moe"], h,
                           capacity_factor=run.moe_capacity_factor)
    else:
        h, aux = apply_mlp(cfg, lp["mlp"], h), 0.0
    x = x + h
    return constrain(x, ("batch", "seq", "embed")), aux, kv


def _cross_attn_seq(cfg, lp, x, memory):
    """Cross attention to a precomputed memory (vision/audio tokens)."""
    h = apply_norm(cfg, lp["ln1"], x)
    q, k, v = qkv_project(cfg, lp["attn"], h, kv_x=memory, rope=False)
    o = chunked_attention(q, k, v, causal=False)
    h = out_project(lp["attn"], o)
    x = x + jnp.tanh(lp["gate_attn"]) * h
    h = apply_mlp(cfg, lp["mlp"], apply_norm(cfg, lp["ln2"], x))
    x = x + jnp.tanh(lp["gate_mlp"]) * h
    return constrain(x, ("batch", "seq", "embed")), (k, v)


def _block_decode(cfg, lp, x, pos, kc, vc, run: RunConfig):
    """Single-token decode for one layer.  x: (B,1,d); pos: (B,) write index.
    kc/vc: cache buffers.  Returns (x, aux, new_kc, new_vc)."""
    h = apply_norm(cfg, lp["ln1"], x)
    q, k, v = qkv_project(cfg, lp["attn"], h,
                          positions=pos[:, None].astype(jnp.float32))
    kc = kv_cache.write(kc, k, pos)
    vc = kv_cache.write(vc, v, pos)
    o = _decode_attend(cfg, q, kc, vc, pos, run)
    x = x + out_project(lp["attn"], o)
    h = apply_norm(cfg, lp["ln2"], x)
    if cfg.is_moe:
        h, aux = apply_moe(cfg, lp["moe"], h,
                           capacity_factor=run.moe_capacity_factor)
    else:
        h, aux = apply_mlp(cfg, lp["mlp"], h), 0.0
    return x + h, aux, kc, vc


def _decode_attend(cfg, q, kc_view, vc_view, pos, run: RunConfig):
    """Attention over one layer's cache view, honoring decode_slice_reads:
    with a sliding window, dynamic-slice only the window out of the cache
    instead of masked full-cache reads (64x less HBM traffic at 500k)."""
    if run.decode_slice_reads and run.decode_window:
        S = (kc_view["q"] if isinstance(kc_view, dict) else kc_view).shape[1]
        w = min(run.decode_window, S)
        start = jnp.clip(jnp.min(pos) + 1 - w, 0, S - w)
        kc_view = kv_cache.slice_window(kc_view, start, w)
        vc_view = kv_cache.slice_window(vc_view, start, w)
        cur = pos + 1 - start
        return decode_attention(q, kv_cache.read(kc_view),
                                kv_cache.read(vc_view), cur,
                                window=run.decode_window)
    return decode_attention(q, kv_cache.read(kc_view),
                            kv_cache.read(vc_view), pos + 1,
                            window=run.decode_window)


def _decode_attend_prewrite(cfg, q, k_old, v_old, k_new, v_new, pos,
                            run: RunConfig):
    """Attention over the pre-write cache view + the new token handled
    out-of-band (layers.decode_attention_with_new).  The updated cache is
    then only consumed by the NEXT step, so XLA cannot hoist the attention
    read's dtype-convert across the in-place update (on CPU that hoist
    materializes an f32 mirror of the whole cache stack; §Perf)."""
    from repro.models.layers import decode_attention_with_new
    if run.decode_slice_reads and run.decode_window:
        S = (k_old["q"] if isinstance(k_old, dict) else k_old).shape[1]
        w = min(run.decode_window, S)
        start = jnp.clip(jnp.min(pos) + 1 - w, 0, S - w)
        k_old = kv_cache.slice_window(k_old, start, w)
        v_old = kv_cache.slice_window(v_old, start, w)
        return decode_attention_with_new(
            q, kv_cache.read(k_old), kv_cache.read(v_old), k_new, v_new,
            pos - start, window=run.decode_window)
    return decode_attention_with_new(
        q, kv_cache.read(k_old), kv_cache.read(v_old), k_new, v_new, pos,
        window=run.decode_window)


def _block_decode_inplace(cfg, lp, x, pos, kc_all, vc_all, lead_idx,
                          run: RunConfig):
    """Like _block_decode, but the stacked cache buffers stay in the scan
    carry and are updated in place (one token-slice write per layer); the
    attention read uses the pre-write view + the new token out-of-band."""
    h = apply_norm(cfg, lp["ln1"], x)
    q, k, v = qkv_project(cfg, lp["attn"], h,
                          positions=pos[:, None].astype(jnp.float32))
    k_old = kv_cache.layer_view(kc_all, lead_idx)
    v_old = kv_cache.layer_view(vc_all, lead_idx)
    kc_all = kv_cache.write_layer(kc_all, lead_idx, k, pos,
                                  uniform=run.decode_uniform_pos)
    vc_all = kv_cache.write_layer(vc_all, lead_idx, v, pos,
                                  uniform=run.decode_uniform_pos)
    o = _decode_attend_prewrite(cfg, q, k_old, v_old, k, v, pos, run)
    x = x + out_project(lp["attn"], o)
    h = apply_norm(cfg, lp["ln2"], x)
    if cfg.is_moe:
        h, aux = apply_moe(cfg, lp["moe"], h,
                           capacity_factor=run.moe_capacity_factor)
    else:
        h, aux = apply_mlp(cfg, lp["mlp"], h), 0.0
    return x + h, aux, kc_all, vc_all


def _cross_attn_decode(cfg, lp, x, ck, cv, memory_len):
    h = apply_norm(cfg, lp["ln1"], x)
    q = jnp.einsum("bsd,dhk->bshk", h, lp["attn"]["wq"])
    if cfg.use_qkv_bias:
        q = q + lp["attn"]["bq"]
    o = decode_attention(q, kv_cache.read(ck), kv_cache.read(cv), memory_len)
    x = x + jnp.tanh(lp["gate_attn"]) * out_project(lp["attn"], o)
    h = apply_mlp(cfg, lp["mlp"], apply_norm(cfg, lp["ln2"], x))
    return x + jnp.tanh(lp["gate_mlp"]) * h


# ---------------------------------------------------------------------------
# Full-sequence forward (train / prefill)
# ---------------------------------------------------------------------------

def forward(cfg: ModelConfig, params, tokens, run: RunConfig,
            extras: Optional[dict] = None, collect_kv: bool = False,
            last_only: bool = False):
    """tokens: (B, S) -> (logits, aux, kvs or None).

    last_only: emit logits for the final position only (prefill_logits=
    "last": kills the (B, S, V) logits tensor and its collectives).

    kvs (when collect_kv): stacked per-layer (L, B, S, KV, D) pairs — the
    prefill cache."""
    B, S = tokens.shape
    x = embed(params["embed"], tokens)
    x = constrain(x, ("batch", "seq", "embed"))
    positions = jnp.arange(S, dtype=jnp.float32)[None]

    window = run.decode_window if run.decode_window else 0

    def body(carry, lp):
        x, aux = carry
        x, a, kv = _block_seq(cfg, lp, x, positions, run, window=window)
        return (x, aux + a), (kv if collect_kv else None)

    if run.remat == "block":
        body = jax.checkpoint(body)

    if cfg.cross_attn_every:
        memory = extras["vision_embeds"].astype(x.dtype)

        def group_body(carry, gp):
            x, aux = carry
            (x, aux), kvs = jax.lax.scan(body, (x, aux), gp["self"])
            x, ckv = _cross_attn_seq(cfg, gp["cross"], x, memory)
            return (x, aux), ((kvs, ckv) if collect_kv else None)

        if run.remat == "group":
            group_body = jax.checkpoint(group_body)
        (x, aux), kvs = jax.lax.scan(group_body, (x, 0.0), params["groups"])
    else:
        (x, aux), kvs = jax.lax.scan(body, (x, 0.0), params["layers"])

    if last_only:
        x = x[:, -1:]
    x = apply_norm(cfg, params["final_norm"], x)
    logits = unembed(cfg, params["embed"], x)
    return logits, aux, (kvs if collect_kv else None)


# ---------------------------------------------------------------------------
# Decode (single token, KV cache)
# ---------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch: int, max_len: int, run: RunConfig,
               abstract: bool = False):
    KV, hd = cfg.num_kv_heads, cfg.resolved_head_dim

    def kv_buf(extra_lead=(), length=max_len):
        buf = kv_cache.alloc(batch, length, KV, hd, run.kv_cache_dtype,
                             abstract=abstract)

        def lead(x):
            if abstract:
                return jax.ShapeDtypeStruct(extra_lead + x.shape, x.dtype)
            return jnp.broadcast_to(x, extra_lead + x.shape).copy() \
                if extra_lead else x
        return jax.tree_util.tree_map(lead, buf)

    pos = (jax.ShapeDtypeStruct((batch,), jnp.int32) if abstract
           else jnp.zeros((batch,), jnp.int32))
    if cfg.cross_attn_every:
        G = cfg.num_layers // cfg.cross_attn_every
        n_self = cfg.cross_attn_every - 1
        return {"pos": pos,
                "k": kv_buf((G, n_self)), "v": kv_buf((G, n_self)),
                "cross_k": kv_buf((G,), cfg.num_vision_tokens),
                "cross_v": kv_buf((G,), cfg.num_vision_tokens)}
    L = cfg.num_layers
    return {"pos": pos, "k": kv_buf((L,)), "v": kv_buf((L,))}


def prefill(cfg: ModelConfig, params, tokens, max_len: int, run: RunConfig,
            extras: Optional[dict] = None):
    """Run the full prompt, build a max_len cache.  Returns (logits, cache)."""
    B, S = tokens.shape
    logits, aux, kvs = forward(cfg, params, tokens, run, extras,
                               collect_kv=True,
                               last_only=run.prefill_logits == "last")
    cache = init_cache(cfg, B, max_len, run)
    pos0 = jnp.zeros((B,), jnp.int32)

    if cfg.cross_attn_every:
        (self_kvs, cross_kvs) = kvs
        k_new, v_new = self_kvs                # (G, n_self, B, S, KV, D)
        ck, cv = cross_kvs                     # (G, B, Tv, KV, D)
        cache["k"] = _write_stacked(cache["k"], k_new, pos0, lead=2)
        cache["v"] = _write_stacked(cache["v"], v_new, pos0, lead=2)
        cache["cross_k"] = _write_stacked(
            cache["cross_k"], ck, pos0, lead=1, full=True)
        cache["cross_v"] = _write_stacked(
            cache["cross_v"], cv, pos0, lead=1, full=True)
    else:
        k_new, v_new = kvs                     # (L, B, S, KV, D)
        cache["k"] = _write_stacked(cache["k"], k_new, pos0, lead=1)
        cache["v"] = _write_stacked(cache["v"], v_new, pos0, lead=1)
    cache["pos"] = jnp.full((B,), S, jnp.int32)
    return logits, cache


def _write_stacked(buf, new, pos, lead: int, full: bool = False):
    """Vectorized kv_cache.write over `lead` leading (layer) axes."""
    fn = kv_cache.write
    if full:
        fn = lambda c, n, p: kv_cache.write(c, n, jnp.zeros_like(p))
    for _ in range(lead):
        fn = jax.vmap(fn, in_axes=(0, 0, None))
    return fn(buf, new, pos)


def decode_step(cfg: ModelConfig, params, token, cache, run: RunConfig,
                extras: Optional[dict] = None):
    """token: (B, 1) -> (logits (B, 1, V), updated cache)."""
    B = token.shape[0]
    pos = cache["pos"]
    x = embed(params["embed"], token)
    x = constrain(x, ("batch", None, "embed"))

    if run.decode_inplace_cache:
        # caches stay in the scan CARRY, written in place -- no per-step
        # full-cache restacking copy (EXPERIMENTS.md §Perf)
        if cfg.cross_attn_every:
            n_self = cfg.cross_attn_every - 1
            mem_len = jnp.full((B,), cfg.num_vision_tokens, jnp.int32)

            def inner(carry, xs):
                x, aux, kc, vc, gi = carry
                lp, si = xs
                x, a, kc, vc = _block_decode_inplace(
                    cfg, lp, x, pos, kc, vc, (gi, si), run)
                return (x, aux + a, kc, vc, gi), None

            def group_body(carry, xs):
                x, aux, kc, vc = carry
                gp, ck, cv, gi = xs
                (x, aux, kc, vc, _), _ = jax.lax.scan(
                    inner, (x, aux, kc, vc, gi),
                    (gp["self"], jnp.arange(n_self)))
                x = _cross_attn_decode(cfg, gp["cross"], x, ck, cv, mem_len)
                return (x, aux, kc, vc), None

            G = cfg.num_layers // cfg.cross_attn_every
            (x, aux, kc, vc), _ = jax.lax.scan(
                group_body, (x, 0.0, cache["k"], cache["v"]),
                (params["groups"], cache["cross_k"], cache["cross_v"],
                 jnp.arange(G)))
        else:
            def body_ip(carry, xs):
                x, aux, kc, vc = carry
                lp, li = xs
                x, a, kc, vc = _block_decode_inplace(
                    cfg, lp, x, pos, kc, vc, (li,), run)
                return (x, aux + a, kc, vc), None

            (x, aux, kc, vc), _ = jax.lax.scan(
                body_ip, (x, 0.0, cache["k"], cache["v"]),
                (params["layers"], jnp.arange(cfg.num_layers)))
        cache = dict(cache, k=kc, v=vc)
    else:
        def body(carry, xs):
            x, aux = carry
            lp, kc, vc = xs
            x, a, kc, vc = _block_decode(cfg, lp, x, pos, kc, vc, run)
            return (x, aux + a), (kc, vc)

        if cfg.cross_attn_every:
            def group_body(carry, xs):
                x, aux = carry
                gp, kc, vc, ck, cv = xs
                (x, aux), kvs = jax.lax.scan(body, (x, aux),
                                             (gp["self"], kc, vc))
                mem_len = jnp.full((B,), cfg.num_vision_tokens, jnp.int32)
                x = _cross_attn_decode(cfg, gp["cross"], x, ck, cv, mem_len)
                return (x, aux), kvs

            (x, aux), kvs = jax.lax.scan(
                group_body, (x, 0.0),
                (params["groups"], cache["k"], cache["v"],
                 cache["cross_k"], cache["cross_v"]))
            cache = dict(cache, k=kvs[0], v=kvs[1])
        else:
            (x, aux), kvs = jax.lax.scan(
                body, (x, 0.0), (params["layers"], cache["k"], cache["v"]))
            cache = dict(cache, k=kvs[0], v=kvs[1])

    x = apply_norm(cfg, params["final_norm"], x)
    logits = unembed(cfg, params["embed"], x)
    cache["pos"] = pos + 1
    return logits, cache
