"""Unified model API: every assigned architecture exposes

    schema(cfg)                          parameter schema (declarative)
    forward(cfg, params, tokens, run, extras, collect_kv)
    prefill(cfg, params, tokens, max_len, run, extras)
    decode_step(cfg, params, token, cache, run, extras)
    init_cache(cfg, batch, max_len, run, abstract)

plus framework-level helpers here: model lookup, input_specs (the
ShapeDtypeStruct stand-ins used by the dry-run), and smoke-scale
end-to-end step functions.
"""

from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.config import ModelConfig, RunConfig, ShapeConfig
from repro.models import transformer, whisper, xlstm_model, zamba2
from repro.models.params import abstract_params, init_params, param_pspecs


def get_model(cfg: ModelConfig):
    if cfg.family in ("dense", "moe", "vlm"):
        return transformer
    if cfg.family == "audio":
        return whisper
    if cfg.family == "hybrid":
        return zamba2
    if cfg.family == "ssm":
        return xlstm_model
    raise ValueError(f"unknown family {cfg.family!r}")


def extra_input_specs(cfg: ModelConfig, batch: int, abstract: bool = True,
                      dtype=jnp.bfloat16):
    """Modality-frontend STUBS (the one allowed carve-out): precomputed
    frame/patch embeddings with the correct shapes."""
    extras = {}
    if cfg.family == "audio":
        shape = (batch, cfg.num_audio_frames, cfg.d_model)
        extras["audio_frames"] = (jax.ShapeDtypeStruct(shape, dtype)
                                  if abstract else jnp.zeros(shape, dtype))
    if cfg.family == "vlm":
        shape = (batch, cfg.num_vision_tokens, cfg.d_model)
        extras["vision_embeds"] = (jax.ShapeDtypeStruct(shape, dtype)
                                   if abstract else
                                   0.02 * jnp.ones(shape, dtype))
    return extras or None


def input_specs(cfg: ModelConfig, shape: ShapeConfig, run: RunConfig,
                abstract: bool = True):
    """ShapeDtypeStruct stand-ins for every model input of a step function.

    train  -> {tokens, labels, extras...}
    prefill-> {tokens, extras...}
    decode -> {token (B,1), cache (seq_len), extras...}
    """
    B, S = shape.global_batch, shape.seq_len
    mod = get_model(cfg)

    def arr(shp, dt):
        return (jax.ShapeDtypeStruct(shp, dt) if abstract
                else jnp.zeros(shp, dt))

    specs = {}
    if shape.kind == "train":
        specs["tokens"] = arr((B, S), jnp.int32)
        specs["labels"] = arr((B, S), jnp.int32)
    elif shape.kind == "prefill":
        specs["tokens"] = arr((B, S), jnp.int32)
    else:  # decode: ONE new token against a seq_len cache
        specs["token"] = arr((B, 1), jnp.int32)
        specs["cache"] = mod.init_cache(cfg, B, S, run, abstract=abstract)
    extras = extra_input_specs(cfg, B, abstract=abstract)
    if extras:
        specs["extras"] = extras
    return specs


# ---------------------------------------------------------------------------
# Step functions (shared by smoke tests, the dry-run and the launchers)
# ---------------------------------------------------------------------------

def make_train_step(cfg: ModelConfig, run: RunConfig):
    mod = get_model(cfg)

    def loss_fn(params, tokens, labels, extras=None):
        logits, aux, _ = mod.forward(cfg, params, tokens, run, extras)
        logits = logits.astype(jnp.float32)
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, labels[..., None],
                                   axis=-1)[..., 0]
        nll = (logz - gold).mean()
        return nll + aux, nll

    return loss_fn


def make_prefill_step(cfg: ModelConfig, run: RunConfig, max_len: int):
    mod = get_model(cfg)

    def step(params, tokens, extras=None):
        return mod.prefill(cfg, params, tokens, max_len, run, extras)

    return step


def make_decode_step(cfg: ModelConfig, run: RunConfig):
    mod = get_model(cfg)

    def step(params, token, cache, extras=None):
        return mod.decode_step(cfg, params, token, cache, run, extras)

    return step


def init_model(cfg: ModelConfig, key, dtype=jnp.float32):
    return init_params(get_model(cfg).schema(cfg), key, dtype)


def abstract_model(cfg: ModelConfig, dtype=jnp.bfloat16):
    return abstract_params(get_model(cfg).schema(cfg), dtype)


def model_pspecs(cfg: ModelConfig, rules: dict):
    return param_pspecs(get_model(cfg).schema(cfg), rules)
