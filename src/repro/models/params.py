"""Declarative parameter schemas.

A schema is a nested dict whose leaves are ``P(shape, logical_axes,
init=...)``.  From one schema we derive
  * ``init_params``      -- materialized random arrays (smoke tests, examples)
  * ``abstract_params``  -- ShapeDtypeStructs (dry-run: never allocates)
  * ``param_pspecs``     -- matching PartitionSpec tree from sharding rules

Logical axes are resolved against ``repro.config.sharding_rules_for`` so the
same model code lowers on any mesh.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec


@dataclasses.dataclass(frozen=True)
class P:
    """A parameter leaf: shape + logical axis names (same length)."""
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]
    init: str = "normal"      # normal | zeros | ones | embed
    scale: Optional[float] = None

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def _is_leaf(x) -> bool:
    return isinstance(x, P)


def tree_map_schema(fn, schema):
    return jax.tree_util.tree_map(fn, schema, is_leaf=_is_leaf)


def init_params(schema, key, dtype=jnp.float32):
    leaves, treedef = jax.tree_util.tree_flatten(schema, is_leaf=_is_leaf)
    keys = jax.random.split(key, len(leaves))

    def make(p: P, k):
        if p.init == "zeros":
            return jnp.zeros(p.shape, dtype)
        if p.init == "ones":
            return jnp.ones(p.shape, dtype)
        if p.init == "embed":
            return (jax.random.normal(k, p.shape, dtype)
                    * (p.scale or 0.02)).astype(dtype)
        fan_in = p.shape[-2] if len(p.shape) >= 2 else p.shape[-1]
        scale = p.scale if p.scale is not None else 1.0 / np.sqrt(max(fan_in, 1))
        return (jax.random.normal(k, p.shape, jnp.float32) * scale).astype(dtype)

    return jax.tree_util.tree_unflatten(
        treedef, [make(p, k) for p, k in zip(leaves, keys)])


def abstract_params(schema, dtype=jnp.bfloat16):
    return tree_map_schema(
        lambda p: jax.ShapeDtypeStruct(p.shape, dtype), schema)


def param_pspecs(schema, rules: dict):
    def spec(p: P) -> PartitionSpec:
        parts = []
        for ax in p.axes:
            m = rules.get(ax) if ax is not None else None
            if m is None:
                parts.append(None)
            elif isinstance(m, (tuple, list)):
                parts.append(m[0] if len(m) == 1 else tuple(m))
            else:
                parts.append(m)
        # dedup: for weights the FIRST occurrence of a mesh axis wins (e.g.
        # MoE (experts, embed, mlp): expert parallelism outranks the inner
        # mlp tensor split on the same axis)
        used = set()
        for i, part in enumerate(parts):
            names = (part,) if isinstance(part, str) else tuple(part or ())
            if any(n in used for n in names):
                parts[i] = None
            else:
                used.update(names)
        return PartitionSpec(*parts)

    return tree_map_schema(spec, schema)


def param_bytes(schema, bytes_per_el=2) -> int:
    total = 0
    for p in jax.tree_util.tree_leaves(schema, is_leaf=_is_leaf):
        total += int(np.prod(p.shape)) * bytes_per_el
    return total


# ---------------------------------------------------------------------------
# Activation-sharding helper: models call ``constrain(x, ("batch","seq",...))``
# and the launch layer installs the rules via ``use_rules``.
# ---------------------------------------------------------------------------

_ACTIVE_RULES: Optional[dict] = None


class use_rules:
    """Context manager installing logical->mesh rules for ``constrain``."""

    def __init__(self, rules: Optional[dict]):
        self.rules = rules

    def __enter__(self):
        global _ACTIVE_RULES
        self._prev = _ACTIVE_RULES
        _ACTIVE_RULES = self.rules
        return self

    def __exit__(self, *exc):
        global _ACTIVE_RULES
        _ACTIVE_RULES = self._prev
        return False


def rule_active(name: str) -> bool:
    """True when the installed rules map this logical axis to a mesh axis."""
    return bool(_ACTIVE_RULES) and _ACTIVE_RULES.get(name) is not None


def constrain(x, axes: Tuple[Optional[str], ...]):
    """Apply with_sharding_constraint from logical axes; no-op without rules
    or outside a mesh context."""
    if _ACTIVE_RULES is None:
        return x
    parts = []
    for ax in axes:
        m = _ACTIVE_RULES.get(ax) if ax is not None else None
        if m is None:
            parts.append(None)
        elif isinstance(m, (tuple, list)):
            parts.append(m[0] if len(m) == 1 else tuple(m))
        else:
            parts.append(m)
    # A mesh axis may appear only once per spec.  When two logical axes map
    # to the same mesh axis (e.g. Megatron-style seq-parallel residuals vs.
    # tensor-parallel inner activations), the LAST logical axis wins — inner
    # activations keep their tensor sharding and seq is gathered, matching
    # Megatron sequence-parallel semantics.
    used = set()
    for i in range(len(parts) - 1, -1, -1):
        names = (parts[i],) if isinstance(parts[i], str) else \
            tuple(parts[i] or ())
        if any(n in used for n in names):
            parts[i] = None
        else:
            used.update(names)
    try:
        return jax.lax.with_sharding_constraint(x, PartitionSpec(*parts))
    except (ValueError, RuntimeError):
        return x  # no mesh in scope (pure-CPU smoke tests)
