"""xLSTM blocks [arXiv:2405.04517]: mLSTM (matrix memory, parallelizable)
and sLSTM (scalar memory, strictly sequential recurrence).

The mLSTM recurrence

    C_t = f_t C_{t-1} + i_t v_t k_t^T ,   n_t = f_t n_{t-1} + i_t k_t
    y_t = C_t q_t / max(|n_t . q_t|, 1)

is exactly the SSD form (state = matrix memory, per-head scalar decay), so
train/prefill reuses ``ssm.ssd_chunked`` with per-head B/C; the normalizer
``n`` rides along as an extra ones-channel of ``v``.  Stabilization
simplification vs. the paper: the input gate uses exp(clip(i, -8, 8)) and
the forget gate log-sigmoid (always-stable log-space decay) instead of the
paper's running max-state m_t; the normalizer bound max(|n.q|, 1) is kept.
Noted in DESIGN.md §7.

sLSTM: per-head block-diagonal recurrent mixing, stabilized exp gating,
lax.scan over time (inherently sequential — the paper says the same).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.params import P
from repro.models.layers import rmsnorm
from repro.models.ssm import ssd_chunked


# ---------------------------------------------------------------------------
# mLSTM block
# ---------------------------------------------------------------------------

def mlstm_dims(cfg):
    d_in = cfg.ssm_expand * cfg.d_model
    H = cfg.num_heads
    Dh = d_in // H
    return d_in, H, Dh


def mlstm_schema(cfg):
    d = cfg.d_model
    d_in, H, Dh = mlstm_dims(cfg)
    W = 4
    return {
        "up": P((d, 2 * d_in), ("embed", "ssm_inner")),
        "conv_w": P((W, d_in), (None, None), scale=0.5),
        "conv_b": P((d_in,), (None,), init="zeros"),
        "wq": P((d_in, d_in), ("ssm_inner", None)),
        "wk": P((d_in, d_in), ("ssm_inner", None)),
        "wv": P((d_in, d_in), ("ssm_inner", None)),
        "wi": P((d_in, H), ("ssm_inner", None), scale=0.02),
        "wf": P((d_in, H), ("ssm_inner", None), scale=0.02),
        "bi": P((H,), (None,), init="zeros"),
        "bf": P((H,), (None,), init="ones"),   # bias toward remembering
        "norm": P((d_in,), (None,), init="ones"),
        "down": P((d_in, d), ("ssm_inner", "embed")),
    }


def _mlstm_qkvif(cfg, p, u, conv_state=None):
    B, S, _ = u.shape
    d_in, H, Dh = mlstm_dims(cfg)
    zx = u @ p["up"]
    x, z = jnp.split(zx, 2, axis=-1)
    # causal depthwise conv on the mLSTM input path
    W = p["conv_w"].shape[0]
    pad = (jnp.zeros((B, W - 1, d_in), x.dtype) if conv_state is None
           else conv_state.astype(x.dtype))
    full = jnp.concatenate([pad, x], axis=1)
    xc = sum(full[:, i:i + S] * p["conv_w"][i] for i in range(W))
    xc = jax.nn.silu(xc + p["conv_b"])
    new_conv = full[:, -(W - 1):]

    q = (xc @ p["wq"]).reshape(B, S, H, Dh)
    k = (xc @ p["wk"]).reshape(B, S, H, Dh) / (Dh ** 0.5)
    v = (x @ p["wv"]).reshape(B, S, H, Dh)
    logf = jax.nn.log_sigmoid((xc @ p["wf"] + p["bf"]).astype(jnp.float32))
    i_gate = jnp.exp(jnp.clip((xc @ p["wi"] + p["bi"]).astype(jnp.float32),
                              -8.0, 8.0))
    return x, z, q, k, v, logf, i_gate, new_conv


def mlstm_forward(cfg, p, u, state=None, *, chunk: int = 128):
    """u: (B, S, d) -> (y, new_state)."""
    B, S, d = u.shape
    d_in, H, Dh = mlstm_dims(cfg)
    conv_in = state["conv"] if state is not None else None
    x, z, q, k, v, logf, i_gate, new_conv = _mlstm_qkvif(cfg, p, u, conv_in)

    # v extended with a ones channel -> the scan also produces n . q
    v_ext = jnp.concatenate(
        [v.astype(jnp.float32) * i_gate[..., None],
         i_gate[..., None]], axis=-1)                       # (B,S,H,Dh+1)
    h0 = (state["mem"] if state is not None
          else jnp.zeros((B, H, Dh + 1, Dh), jnp.float32))
    y_ext, h_fin = ssd_chunked(v_ext, logf, k, q, h0, chunk=chunk)
    y, nq = y_ext[..., :Dh], y_ext[..., Dh:]
    y = y / jnp.maximum(jnp.abs(nq), 1.0)
    y = y.reshape(B, S, d_in).astype(u.dtype)
    y = rmsnorm(y * jax.nn.silu(z), p["norm"])
    return y @ p["down"], {"conv": new_conv, "mem": h_fin}


def mlstm_step(cfg, p, u, state):
    """Single decode step; u: (B, 1, d)."""
    B, _, d = u.shape
    d_in, H, Dh = mlstm_dims(cfg)
    x, z, q, k, v, logf, i_gate, new_conv = _mlstm_qkvif(
        cfg, p, u, state["conv"])
    f = jnp.exp(logf[:, 0])                                 # (B,H)
    iv = v[:, 0].astype(jnp.float32) * i_gate[:, 0][..., None]
    v_ext = jnp.concatenate([iv, i_gate[:, 0][..., None]], axis=-1)
    h = (state["mem"] * f[..., None, None]
         + jnp.einsum("bhp,bhn->bhpn", v_ext,
                      k[:, 0].astype(jnp.float32)))
    y_ext = jnp.einsum("bhpn,bhn->bhp", h, q[:, 0].astype(jnp.float32))
    y, nq = y_ext[..., :Dh], y_ext[..., Dh:]
    y = (y / jnp.maximum(jnp.abs(nq), 1.0)).reshape(B, 1, d_in)
    y = rmsnorm(y.astype(u.dtype) * jax.nn.silu(z), p["norm"])
    return y @ p["down"], {"conv": new_conv, "mem": h}


def mlstm_init_state(cfg, batch: int, dtype=jnp.float32):
    d_in, H, Dh = mlstm_dims(cfg)
    return {"conv": jnp.zeros((batch, 3, d_in), dtype),
            "mem": jnp.zeros((batch, H, Dh + 1, Dh), jnp.float32)}


# ---------------------------------------------------------------------------
# sLSTM block
# ---------------------------------------------------------------------------

def slstm_schema(cfg):
    d = cfg.d_model
    H = cfg.num_heads
    Dh = d // H
    d_ff = int(round(4 * d / 3 / 64)) * 64 or 64     # paper's 4/3 post-FFN
    gates = {}
    for g in ("i", "f", "z", "o"):
        gates[f"w{g}"] = P((d, d), ("embed", None), scale=0.02)
        gates[f"r{g}"] = P((H, Dh, Dh), (None, None, None), scale=0.02)
        gates[f"b{g}"] = P((d,), (None,),
                           init="ones" if g == "f" else "zeros")
    return {
        **gates,
        "norm": P((d,), (None,), init="ones"),
        "ffn_up": P((d, d_ff), ("embed", "mlp")),
        "ffn_gate": P((d, d_ff), ("embed", "mlp")),
        "ffn_down": P((d_ff, d), ("mlp", "embed")),
    }


def _slstm_cell(cfg, p, xt, carry):
    """One sLSTM step.  xt: (B, d) pre-activations already include W x.
    carry = (c, n, h, m) each (B, d) [m per unit for simplicity]."""
    B, d = xt["i"].shape
    H = cfg.num_heads
    Dh = d // H
    c, n, h, m = carry
    hh = h.reshape(B, H, Dh)

    def rec(g):
        return jnp.einsum("bhx,hxy->bhy", hh, p[f"r{g}"]).reshape(B, d)

    it = xt["i"] + rec("i")
    ft = xt["f"] + rec("f")
    zt = jnp.tanh(xt["z"] + rec("z"))
    ot = jax.nn.sigmoid(xt["o"] + rec("o"))
    logf = jax.nn.log_sigmoid(ft)
    m_new = jnp.maximum(logf + m, it)
    i_p = jnp.exp(it - m_new)
    f_p = jnp.exp(logf + m - m_new)
    c_new = f_p * c + i_p * zt
    n_new = f_p * n + i_p
    h_new = ot * c_new / jnp.maximum(n_new, 1e-6)
    return (c_new, n_new, h_new, m_new), h_new


def slstm_forward(cfg, p, u, state=None):
    """u: (B, S, d) -> (y, new_state).  Sequential scan over time."""
    B, S, d = u.shape
    pre = {g: (u @ p[f"w{g}"] + p[f"b{g}"]).astype(jnp.float32)
           for g in ("i", "f", "z", "o")}
    carry = state["cell"] if state is not None else _slstm_zero(cfg, B)

    def step(cr, t):
        xt = {g: pre[g][:, t] for g in pre}
        return _slstm_cell(cfg, p, xt, cr)

    carry, hs = jax.lax.scan(step, carry, jnp.arange(S))
    y = jnp.moveaxis(hs, 0, 1).astype(u.dtype)               # (B, S, d)
    y = rmsnorm(y, p["norm"])
    ff = (y @ p["ffn_up"]) * jax.nn.silu(y @ p["ffn_gate"])
    return ff @ p["ffn_down"], {"cell": carry}


def slstm_step(cfg, p, u, state):
    B, _, d = u.shape
    xt = {g: (u[:, 0] @ p[f"w{g}"] + p[f"b{g}"]).astype(jnp.float32)
          for g in ("i", "f", "z", "o")}
    carry, h = _slstm_cell(cfg, p, xt, state["cell"])
    y = rmsnorm(h[:, None].astype(u.dtype), p["norm"])
    ff = (y @ p["ffn_up"]) * jax.nn.silu(y @ p["ffn_gate"])
    return ff @ p["ffn_down"], {"cell": carry}


def _slstm_zero(cfg, batch):
    d = cfg.d_model
    z = jnp.zeros((batch, d), jnp.float32)
    return (z, z, z, z - 1e30 * 0.0)


def slstm_init_state(cfg, batch: int):
    return {"cell": _slstm_zero(cfg, batch)}
