"""Shared transformer layer primitives (pure JAX, functional).

Attention is implemented flash-style in pure jnp (chunked online-softmax via
nested lax.scan) so the lowered HLO never materializes an S x S score tensor
-- required for the 32k prefill / 4k train shapes to fit, and mirrors the
Pallas kernel (kernels/flash_attention) which replaces it on real TPUs.
"""

from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.models.params import P, constrain, rule_active

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def rmsnorm(x, scale, eps=1e-6):
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    out = x.astype(jnp.float32) * jax.lax.rsqrt(var + eps)
    return (out * scale.astype(jnp.float32)).astype(x.dtype)


def layernorm(x, scale, bias, eps=1e-5):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    out = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (out * scale.astype(jnp.float32)
            + bias.astype(jnp.float32)).astype(x.dtype)


def norm_schema(cfg, d=None):
    d = d or cfg.d_model
    if cfg.norm == "rmsnorm":
        return {"scale": P((d,), (None,), init="ones")}
    return {"scale": P((d,), (None,), init="ones"),
            "bias": P((d,), (None,), init="zeros")}


def apply_norm(cfg, p, x):
    if cfg.norm == "rmsnorm":
        return rmsnorm(x, p["scale"])
    return layernorm(x, p["scale"], p["bias"])


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x, positions, theta: float):
    """x: (..., S, H, D); positions: broadcastable to (..., S)."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)                       # (D/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs   # (..., S, D/2)
    cos = jnp.cos(angles)[..., None, :]                # (..., S, 1, D/2)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------

def _act(name: str):
    if name == "silu":
        return jax.nn.silu
    if name == "gelu":
        return jax.nn.gelu
    if name == "relu2":
        return lambda x: jnp.square(jax.nn.relu(x))
    raise ValueError(name)


def mlp_schema(cfg, d_ff=None):
    d, f = cfg.d_model, (d_ff or cfg.d_ff)
    s = {"up": P((d, f), ("embed", "mlp")),
         "down": P((f, d), ("mlp", "embed"))}
    if cfg.gated_mlp:
        s["gate"] = P((d, f), ("embed", "mlp"))
    return s


def apply_mlp(cfg, p, x):
    act = _act(cfg.activation)
    h = x @ p["up"]
    if cfg.gated_mlp:
        h = h * act(x @ p["gate"])
    else:
        h = act(h)
    h = constrain(h, ("batch", "seq", "mlp"))
    return h @ p["down"]


# ---------------------------------------------------------------------------
# Attention projections
# ---------------------------------------------------------------------------

def attn_schema(cfg, cross=False):
    d, H, KV, hd = (cfg.d_model, cfg.num_heads, cfg.num_kv_heads,
                    cfg.resolved_head_dim)
    s = {"wq": P((d, H, hd), ("embed", "heads", "head_dim")),
         "wk": P((d, KV, hd), ("embed", "kv_heads", "head_dim")),
         "wv": P((d, KV, hd), ("embed", "kv_heads", "head_dim")),
         "wo": P((H, hd, d), ("heads", "head_dim", "embed"))}
    if cfg.use_qkv_bias:
        s["bq"] = P((H, hd), ("heads", "head_dim"), init="zeros")
        s["bk"] = P((KV, hd), ("kv_heads", "head_dim"), init="zeros")
        s["bv"] = P((KV, hd), ("kv_heads", "head_dim"), init="zeros")
    if cfg.qk_norm:
        s["q_norm"] = P((hd,), (None,), init="ones")
        s["k_norm"] = P((hd,), (None,), init="ones")
    return s


def qkv_project(cfg, p, x, kv_x=None, positions=None, rope=True):
    """Returns q (B,S,H,D), k/v (B,Skv,KV,D)."""
    kv_x = x if kv_x is None else kv_x
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", kv_x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", kv_x, p["wv"])
    if cfg.use_qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    if cfg.qk_norm:
        q = rmsnorm(q, p["q_norm"])
        k = rmsnorm(k, p["k_norm"])
    if rope and positions is not None:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def out_project(p, o):
    return jnp.einsum("bshk,hkd->bsd", o, p["wo"])


# ---------------------------------------------------------------------------
# Flash-style chunked attention (full-sequence: train / prefill)
# ---------------------------------------------------------------------------

def _kernel_mode() -> str:
    from repro.kernels import use_pallas
    return use_pallas()


def chunked_attention(q, k, v, *, causal: bool, window: int = 0,
                      q_chunk: int = 512, kv_chunk: int = 512,
                      q_offset=0, parallel_q: bool = False):
    """Online-softmax attention without an S x S intermediate.

    q: (B, Sq, H, D);  k, v: (B, Skv, KV, D) with H = KV * q_per_kv.
    window > 0 masks keys older than `window` positions (sliding window).
    q_offset: absolute position of q[0] (for cross-chunk causal masks).
    parallel_q: vectorize over q chunks (one kv scan, q-chunk axis is a
    pure data dim) instead of an outer sequential scan — this makes the
    q axis shardable (sequence parallelism for archs whose heads don't
    divide the model axis; §Perf).  Costs O(nq) more live accumulator
    memory, so it is only used when the per-shard nq is small.
    Returns (B, Sq, H, D).

    On a real TPU (or under REPRO_FORCE_PALLAS=1) this dispatches to the
    Pallas flash-attention kernel; the jnp path below is its XLA fallback
    and the dry-run/compile-time reference.
    """
    mode = _kernel_mode()
    if mode in ("tpu", "interpret") and q.shape[1] == k.shape[1]:
        from repro.kernels.flash_attention.kernel import \
            flash_attention_pallas
        bq = min(128, q.shape[1])
        bk = min(128, k.shape[1])
        if q.shape[1] % bq == 0 and k.shape[1] % bk == 0:
            return flash_attention_pallas(
                q, k, v, causal=causal, window=window, bq=bq, bk=bk,
                interpret=(mode == "interpret"))
    B, Sq, H, D = q.shape
    _, Skv, KV, _ = k.shape
    G = H // KV
    q_chunk = min(q_chunk, Sq)
    kv_chunk = min(kv_chunk, Skv)
    sq_valid, skv_valid = Sq, Skv
    qpad = (-Sq) % q_chunk
    kpad = (-Skv) % kv_chunk
    if qpad:
        q = jnp.pad(q, ((0, 0), (0, qpad), (0, 0), (0, 0)))
        Sq += qpad
    if kpad:
        k = jnp.pad(k, ((0, 0), (0, kpad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, kpad), (0, 0), (0, 0)))
        Skv += kpad
    nq, nk = Sq // q_chunk, Skv // kv_chunk
    scale = 1.0 / (D ** 0.5)

    qr = q.reshape(B, nq, q_chunk, KV, G, D)
    kr = k.reshape(B, nk, kv_chunk, KV, D)
    vr = v.reshape(B, nk, kv_chunk, KV, D)

    q_pos_base = jnp.arange(q_chunk)
    k_pos_base = jnp.arange(kv_chunk)

    if parallel_q:
        # all q chunks ride as one batched axis through a single kv scan
        qp = qr                                             # (B,nq,qc,KV,G,D)
        qpos = (q_offset + jnp.arange(nq)[:, None] * q_chunk
                + q_pos_base[None])                         # (nq, qc)

        def kv_block_all(acc, ki):
            m, l, o = acc
            kc, vc = kr[:, ki], vr[:, ki]                   # (B,kc,KV,D)
            kpos = ki * kv_chunk + k_pos_base               # (kc,)
            s = jnp.einsum("bnqhgd,bkhd->bnhgqk", qp, kc,
                           preferred_element_type=jnp.float32) * scale
            mask = jnp.ones((nq, q_chunk, kv_chunk), bool)
            if kpad:
                mask &= (kpos < skv_valid)[None, None, :]
            if causal:
                mask &= qpos[..., None] >= kpos[None, None, :]
            if window:
                mask &= (qpos[..., None] - kpos[None, None, :]) < window
            s = jnp.where(mask[None, :, None, None], s, NEG_INF)
            m_new = jnp.maximum(m, s.max(axis=-1))
            alpha = jnp.exp(m - m_new)
            pexp = jnp.exp(s - m_new[..., None])
            l_new = l * alpha + pexp.sum(axis=-1)
            o_new = (o * alpha[..., None]
                     + jnp.einsum("bnhgqk,bkhd->bnhgqd", pexp,
                                  vc.astype(jnp.float32)))
            return (m_new, l_new, o_new), None

        init = (jnp.full((B, nq, KV, G, q_chunk), NEG_INF, jnp.float32),
                jnp.zeros((B, nq, KV, G, q_chunk), jnp.float32),
                jnp.zeros((B, nq, KV, G, q_chunk, D), jnp.float32))
        (m, l, o), _ = jax.lax.scan(kv_block_all, init, jnp.arange(nk))
        o = o / jnp.maximum(l[..., None], 1e-30)
        # (B,nq,KV,G,qc,D) -> (B,nq,qc,KV,G,D)
        out = jnp.transpose(o, (0, 1, 4, 2, 3, 5)).reshape(B, Sq, H, D)
        if qpad:
            out = out[:, :sq_valid]
        return out.astype(q.dtype)

    def q_block(carry, qi):
        qc = qr[:, qi]                                       # (B,qc,KV,G,D)
        qpos = q_offset + qi * q_chunk + q_pos_base          # (qc,)

        def kv_block(acc, ki):
            m, l, o = acc
            kc, vc = kr[:, ki], vr[:, ki]                    # (B,kc,KV,D)
            kpos = ki * kv_chunk + k_pos_base                # (kc,)
            s = jnp.einsum("bqhgd,bkhd->bhgqk", qc, kc,
                           preferred_element_type=jnp.float32) * scale
            mask = jnp.ones((q_chunk, kv_chunk), bool)
            if kpad:
                mask &= (kpos < skv_valid)[None, :]
            if causal:
                mask &= qpos[:, None] >= kpos[None, :]
            if window:
                mask &= (qpos[:, None] - kpos[None, :]) < window
            s = jnp.where(mask[None, None, None], s, NEG_INF)
            m_new = jnp.maximum(m, s.max(axis=-1))           # (B,KV,G,qc)
            alpha = jnp.exp(m - m_new)
            pexp = jnp.exp(s - m_new[..., None])
            l_new = l * alpha + pexp.sum(axis=-1)
            o_new = (o * alpha[..., None]
                     + jnp.einsum("bhgqk,bkhd->bhgqd", pexp,
                                  vc.astype(jnp.float32)))
            return (m_new, l_new, o_new), None

        init = (jnp.full((B, KV, G, q_chunk), NEG_INF, jnp.float32),
                jnp.zeros((B, KV, G, q_chunk), jnp.float32),
                jnp.zeros((B, KV, G, q_chunk, D), jnp.float32))
        (m, l, o), _ = jax.lax.scan(kv_block, init, jnp.arange(nk))
        o = o / jnp.maximum(l[..., None], 1e-30)
        # (B,KV,G,qc,D) -> (B,qc,KV,G,D)
        return carry, jnp.transpose(o, (0, 3, 1, 2, 4))

    _, outs = jax.lax.scan(q_block, None, jnp.arange(nq))     # (nq,B,qc,KV,G,D)
    out = jnp.transpose(outs, (1, 0, 2, 3, 4, 5)).reshape(B, Sq, H, D)
    if qpad:
        out = out[:, :sq_valid]
    return out.astype(q.dtype)


# ---------------------------------------------------------------------------
# Decode attention (single query token vs. KV cache)
# ---------------------------------------------------------------------------

def decode_attention(q, k_cache, v_cache, cur_len, *, window: int = 0):
    """q: (B, 1, H, D); caches: (B, S, KV, D); cur_len: scalar or (B,)
    number of valid cache entries *including* the new token already written.
    Masks positions >= cur_len and (optionally) < cur_len - window.
    Softmax over the cache axis is sharding-friendly: reductions over a
    sequence-sharded cache lower to all-reduces (context parallelism).

    On a real TPU (or under REPRO_FORCE_PALLAS=1) this dispatches to the
    Pallas flash-decode kernel.
    """
    mode = _kernel_mode()
    if mode in ("tpu", "interpret") and not isinstance(k_cache, dict):
        from repro.kernels.decode_attention.kernel import \
            decode_attention_pallas
        bs = min(512, k_cache.shape[1])
        if k_cache.shape[1] % bs == 0:
            return decode_attention_pallas(
                q, k_cache, v_cache, cur_len, window=window, bs=bs,
                interpret=(mode == "interpret"))
    B, _, H, D = q.shape
    _, S, KV, _ = k_cache.shape
    G = H // KV
    scale = 1.0 / (D ** 0.5)
    cur = jnp.asarray(cur_len)
    if cur.ndim == 0:
        cur = jnp.full((B,), cur)

    qr = q.reshape(B, KV, G, D)
    s = jnp.einsum("bhgd,bshd->bhgs", qr, k_cache,
                   preferred_element_type=jnp.float32) * scale
    pos = jnp.arange(S)
    valid = pos[None, :] < cur[:, None]                       # (B,S)
    if window:
        valid &= pos[None, :] >= (cur[:, None] - window)
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    # no materialized f32 cast of the cache: MXU/dot accumulates in f32 via
    # preferred_element_type (a full-cache f32 convert would double the
    # dominant HBM-read term of decode; §Perf)
    o = jnp.einsum("bhgs,bshd->bhgd", p.astype(v_cache.dtype), v_cache,
                   preferred_element_type=jnp.float32)
    return o.reshape(B, 1, H, D).astype(q.dtype)


def decode_attention_with_new(q, k_cache, v_cache, k_new, v_new, cur_len,
                              *, window: int = 0):
    """Decode attention over the PRE-WRITE cache plus the current token
    handled out-of-band.  Semantically identical to writing the token first
    and attending over the updated cache, but the cache update is then only
    consumed by the *next* step — XLA cannot hoist the attention read's
    dtype-convert across the in-place update (which on CPU materializes an
    f32 mirror of the whole cache; §Perf).

    q: (B,1,H,D); caches: (B,S,KV,D) with cur_len (B,) valid entries
    (NOT including the new token); k_new/v_new: (B,1,KV,D).
    """
    B, _, H, D = q.shape
    _, S, KV, _ = k_cache.shape
    G = H // KV
    scale = 1.0 / (D ** 0.5)
    cur = jnp.asarray(cur_len)
    if cur.ndim == 0:
        cur = jnp.full((B,), cur)

    qr = q.reshape(B, KV, G, D)
    s_old = jnp.einsum("bhgd,bshd->bhgs", qr, k_cache,
                       preferred_element_type=jnp.float32) * scale
    pos = jnp.arange(S)
    valid = pos[None, :] < cur[:, None]
    if window:
        # the new token occupies position cur; window covers
        # (cur - window, cur] -> old entries >= cur - window + 1
        valid &= pos[None, :] >= (cur[:, None] - window + 1)
    s_old = jnp.where(valid[:, None, None, :], s_old, NEG_INF)
    s_new = jnp.einsum("bhgd,bohd->bhgo", qr, k_new,
                       preferred_element_type=jnp.float32) * scale
    s = jnp.concatenate([s_old, s_new], axis=-1)          # (B,KV,G,S+1)
    p = jax.nn.softmax(s, axis=-1)
    p_old, p_new = p[..., :S], p[..., S:]
    o = jnp.einsum("bhgs,bshd->bhgd", p_old.astype(v_cache.dtype), v_cache,
                   preferred_element_type=jnp.float32)
    o = o + jnp.einsum("bhgo,bohd->bhgd", p_new.astype(v_new.dtype), v_new,
                       preferred_element_type=jnp.float32)
    return o.reshape(B, 1, H, D).astype(q.dtype)


# ---------------------------------------------------------------------------
# Embedding / unembedding
# ---------------------------------------------------------------------------

def embed_schema(cfg):
    s = {"tok": P((cfg.vocab_size, cfg.d_model), ("vocab", "embed"),
                  init="embed")}
    if not cfg.tie_embeddings:
        s["head"] = P((cfg.d_model, cfg.vocab_size), ("embed", "vocab"))
    return s


def embed(p, tokens):
    return jnp.take(p["tok"], tokens, axis=0)


def unembed(cfg, p, x):
    w = p["tok"].T if cfg.tie_embeddings else p["head"]
    logits = x @ w
    # With Megatron-style sequence-parallel activations ("seq" mapped to the
    # model axis, used for the train shapes) the logits stay seq-sharded;
    # otherwise shard the vocab dim (both would collide on the model axis).
    if rule_active("seq"):
        return constrain(logits, ("batch", "seq", None))
    return constrain(logits, ("batch", "seq", "vocab"))
