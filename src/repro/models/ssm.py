"""Mamba2 (SSD) block — chunked scan for train/prefill, recurrence for decode.

Follows the state-space-duality formulation [Mamba2, arXiv:2405.21060],
single B/C group (G=1):

    h_t = exp(dt_t * A_h) * h_{t-1} + dt_t * x_t  (outer) B_t     (H, P, N)
    y_t = C_t . h_t + D_h * x_t

Train/prefill uses a *sequential scan over chunks* (length Q): within a
chunk the quadratic masked-decay form is used (Q x Q per chunk, never
S x S), across chunks the state is carried.  This bounds HLO size and peak
memory regardless of sequence length, which is what makes the 32k prefill
shape lower.  Decode is the O(1) recurrence above.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.models.params import P
from repro.models.layers import rmsnorm

NEG_INF = -1e30


def ssm_dims(cfg) -> Tuple[int, int, int]:
    d_in = cfg.ssm_expand * cfg.d_model
    nheads = d_in // cfg.ssm_head_dim
    return d_in, nheads, cfg.ssm_state


def mamba2_schema(cfg):
    d = cfg.d_model
    d_in, H, N = ssm_dims(cfg)
    W = cfg.ssm_conv
    conv_ch = d_in + 2 * N
    return {
        # separate projections so the big (z, x) part shards cleanly on the
        # model axis while the small (B, C, dt) part stays replicated
        "in_zx": P((d, 2 * d_in), ("embed", "ssm_inner")),
        "in_bcdt": P((d, 2 * N + H), ("embed", None)),
        "conv_w": P((W, conv_ch), (None, None), scale=0.5),
        "conv_b": P((conv_ch,), (None,), init="zeros"),
        "A_log": P((H,), (None,), init="zeros"),
        "dt_bias": P((H,), (None,), init="zeros"),
        "D": P((H,), (None,), init="ones"),
        "norm": P((d_in,), (None,), init="ones"),
        "out": P((d_in, d), ("ssm_inner", "embed")),
    }


def _split_proj(cfg, p, u):
    """u: (B, S, d) -> z, xBC (pre-conv), dt."""
    d_in, H, N = ssm_dims(cfg)
    zx = u @ p["in_zx"]
    z, x = jnp.split(zx, 2, axis=-1)                       # (B,S,d_in) each
    bcdt = u @ p["in_bcdt"]
    bmat, cmat, dt = jnp.split(bcdt, [N, 2 * N], axis=-1)  # (B,S,N),(B,S,N),(B,S,H)
    xbc = jnp.concatenate([x, bmat, cmat], axis=-1)
    return z, xbc, dt


def _causal_conv(p, xbc, conv_state=None):
    """Depthwise causal conv, width W.  xbc: (B, S, C).
    conv_state: (B, W-1, C) previous inputs (decode) or None (train).
    Returns (out, new_conv_state)."""
    W = p["conv_w"].shape[0]
    if conv_state is None:
        pad = jnp.zeros((xbc.shape[0], W - 1, xbc.shape[2]), xbc.dtype)
    else:
        pad = conv_state.astype(xbc.dtype)
    full = jnp.concatenate([pad, xbc], axis=1)             # (B, S+W-1, C)
    out = sum(full[:, i:i + xbc.shape[1]] * p["conv_w"][i]
              for i in range(W))
    out = jax.nn.silu(out + p["conv_b"])
    new_state = full[:, -(W - 1):]
    return out, new_state


def ssd_chunked(xh, dt_a, bmat, cmat, h0, *, chunk: int = 128):
    """Chunked SSD scan.

    xh:   (B, S, H, P)   inputs (already scaled by dt)
    dt_a: (B, S, H)      per-step log decay (dt * A, negative)
    bmat, cmat: (B, S, N) shared across heads (Mamba2 G=1) or
                (B, S, H, N) per-head (mLSTM keys/queries)
    h0:   (B, H, P, N)   incoming state
    Returns y (B, S, H, P), h_final.
    """
    B, S, H, Pd = xh.shape
    N = bmat.shape[-1]
    per_head = bmat.ndim == 4
    Q = min(chunk, S)
    assert S % Q == 0
    nc = S // Q

    # Pallas SSD kernel on TPU (shared-BC / Mamba2 form only)
    from repro.kernels import use_pallas
    mode = use_pallas()
    if mode in ("tpu", "interpret") and not per_head and S % Q == 0:
        from repro.kernels.ssd_scan.kernel import ssd_scan_pallas
        return ssd_scan_pallas(xh, dt_a, bmat, cmat,
                               h0.astype(jnp.float32), chunk=Q,
                               interpret=(mode == "interpret"))

    xc = jnp.moveaxis(xh.reshape(B, nc, Q, H, Pd), 1, 0)
    ac = jnp.moveaxis(dt_a.reshape(B, nc, Q, H), 1, 0)
    bshape = (B, nc, Q, H, N) if per_head else (B, nc, Q, N)
    bc = jnp.moveaxis(bmat.reshape(bshape), 1, 0)
    cc = jnp.moveaxis(cmat.reshape(bshape), 1, 0)

    idx = jnp.arange(Q)
    tri = idx[:, None] >= idx[None, :]                     # (Q, Q) k <= q

    def chunk_step(h, inp):
        x_, a_, b_, c_ = inp                               # per-chunk slices
        b_, c_, x32 = (b_.astype(jnp.float32), c_.astype(jnp.float32),
                       x_.astype(jnp.float32))
        cum = jnp.cumsum(a_.astype(jnp.float32), axis=1)   # (B,Q,H) inclusive
        total = cum[:, -1]                                 # (B,H)
        # off-diagonal: contribution of the incoming state
        if per_head:
            y_off = jnp.einsum("bqhn,bhpn->bqhp", c_, h)
            scores = jnp.einsum("bqhn,bkhn->bqkh", c_, b_)  # (B,Q,Q,H)
        else:
            y_off = jnp.einsum("bqn,bhpn->bqhp", c_, h)
            scores = jnp.einsum("bqn,bkn->bqk", c_, b_)[..., None]
        y_off = y_off * jnp.exp(cum)[..., None]            # decay e^{cum_q}
        # intra-chunk quadratic
        logdec = cum[:, :, None, :] - cum[:, None, :, :]   # (B,Q,Q,H)
        logdec = jnp.where(tri[None, :, :, None], logdec, NEG_INF)
        y_diag = jnp.einsum("bqkh,bkhp->bqhp", scores * jnp.exp(logdec), x32)
        # state update
        w = jnp.exp(total[:, None] - cum)                  # (B,Q,H)
        if per_head:
            h_new = (h * jnp.exp(total)[..., None, None]
                     + jnp.einsum("bqhp,bqhn,bqh->bhpn", x32, b_, w))
        else:
            h_new = (h * jnp.exp(total)[..., None, None]
                     + jnp.einsum("bqhp,bqn,bqh->bhpn", x32, b_, w))
        return h_new, (y_off + y_diag).astype(xh.dtype)

    h_fin, ys = jax.lax.scan(chunk_step, h0.astype(jnp.float32),
                             (xc, ac, bc, cc))
    y = jnp.moveaxis(ys, 0, 1).reshape(B, S, H, Pd)
    return y, h_fin


def mamba2_forward(cfg, p, u, state=None, *, chunk: int = 128):
    """Full-sequence forward.  u: (B, S, d).
    state: None (fresh) or dict(conv, ssm) for continued prefill.
    Returns y (B, S, d), new_state."""
    B, S, d = u.shape
    d_in, H, N = ssm_dims(cfg)
    Pd = cfg.ssm_head_dim

    z, xbc, dt = _split_proj(cfg, p, u)
    conv_in = state["conv"] if state is not None else None
    xbc, conv_state = _causal_conv(p, xbc, conv_in)
    x, bmat, cmat = jnp.split(xbc, [d_in, d_in + N], axis=-1)

    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # (B,S,H)
    a = -jnp.exp(p["A_log"].astype(jnp.float32))                 # (H,) < 0
    dt_a = dt * a                                                # log decay

    xh = x.reshape(B, S, H, Pd)
    xh_dt = xh.astype(jnp.float32) * dt[..., None]
    h0 = (state["ssm"] if state is not None
          else jnp.zeros((B, H, Pd, N), jnp.float32))
    y, h_fin = ssd_chunked(xh_dt, dt_a, bmat, cmat, h0, chunk=chunk)
    y = y + xh * p["D"][None, None, :, None]
    y = y.reshape(B, S, d_in).astype(u.dtype)
    y = rmsnorm(y * jax.nn.silu(z), p["norm"])
    out = (y @ p["out"]).astype(u.dtype)
    return out, {"conv": conv_state, "ssm": h_fin}


def mamba2_step(cfg, p, u, state):
    """Single decode step.  u: (B, 1, d).  Returns y (B,1,d), new state."""
    B, _, d = u.shape
    d_in, H, N = ssm_dims(cfg)
    Pd = cfg.ssm_head_dim

    z, xbc, dt = _split_proj(cfg, p, u)
    xbc, conv_state = _causal_conv(p, xbc, state["conv"])
    x, bmat, cmat = jnp.split(xbc, [d_in, d_in + N], axis=-1)

    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # (B,1,H)
    a = -jnp.exp(p["A_log"].astype(jnp.float32))
    decay = jnp.exp(dt[:, 0] * a)                                # (B,H)

    xh = x.reshape(B, H, Pd).astype(jnp.float32) * dt[:, 0, :, None]
    h = state["ssm"]                                             # (B,H,P,N)
    h = (h * decay[..., None, None]
         + jnp.einsum("bhp,bn->bhpn", xh, bmat[:, 0].astype(jnp.float32)))
    y = jnp.einsum("bn,bhpn->bhp", cmat[:, 0].astype(jnp.float32), h)
    y = y + x.reshape(B, H, Pd).astype(jnp.float32) * p["D"][None, :, None]
    y = y.reshape(B, 1, d_in).astype(u.dtype)
    y = rmsnorm(y * jax.nn.silu(z), p["norm"])
    return y @ p["out"], {"conv": conv_state, "ssm": h}


def mamba2_init_state(cfg, batch: int, dtype=jnp.float32):
    d_in, H, N = ssm_dims(cfg)
    W = cfg.ssm_conv
    return {
        "conv": jnp.zeros((batch, W - 1, d_in + 2 * N), dtype),
        "ssm": jnp.zeros((batch, H, cfg.ssm_head_dim, N), jnp.float32),
    }
