"""Training step: loss -> grads -> AdamW, built once per (cfg, run) and
usable directly, under jax.jit, or under pjit with sharded params/opt
state (the dry-run lowers exactly this function)."""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.config import ModelConfig, RunConfig
from repro.models import api
from repro.training import optimizer as opt


def make_loss_fn(cfg: ModelConfig, run: RunConfig):
    mod = api.get_model(cfg)

    def loss_fn(params, tokens, labels, extras=None):
        logits, aux, _ = mod.forward(cfg, params, tokens, run, extras)
        logits = logits.astype(jnp.float32)
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, labels[..., None],
                                   axis=-1)[..., 0]
        nll = (logz - gold).mean()
        return nll + aux, nll

    return loss_fn


def make_train_step(cfg: ModelConfig, run: RunConfig,
                    ocfg: Optional[opt.AdamWConfig] = None):
    ocfg = ocfg or opt.AdamWConfig()
    loss_fn = make_loss_fn(cfg, run)

    def train_step(params, opt_state, tokens, labels, extras=None):
        (loss, nll), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params, tokens, labels, extras)
        params, opt_state, metrics = opt.apply_updates(
            ocfg, params, grads, opt_state)
        metrics = dict(metrics, loss=loss, nll=nll)
        return params, opt_state, metrics

    return train_step


def train_loop(cfg: ModelConfig, run: RunConfig, data_iter, *,
               steps: int, ocfg: Optional[opt.AdamWConfig] = None,
               params=None, key=None, log_every: int = 10,
               extras=None, callback=None):
    """Single-host training loop (examples / smoke tests)."""
    key = key if key is not None else jax.random.PRNGKey(0)
    if params is None:
        params = api.init_model(cfg, key)
    opt_state = opt.init_state(params)
    step_fn = jax.jit(make_train_step(cfg, run, ocfg))
    history = []
    for i in range(steps):
        tokens, labels = next(data_iter)
        params, opt_state, m = step_fn(params, opt_state,
                                       jnp.asarray(tokens),
                                       jnp.asarray(labels), extras)
        if i % log_every == 0 or i == steps - 1:
            entry = {k: float(v) for k, v in m.items()}
            entry["step"] = i
            history.append(entry)
            if callback:
                callback(entry)
    return params, opt_state, history
