"""Sharding-aware npz checkpointing (no orbax dependency).

Pytrees are flattened to path-keyed arrays; restore rebuilds the exact
tree structure and validates shapes/dtypes.  Device-sharded arrays are
gathered via np.asarray on save and re-sharded by the caller's pjit on the
first step after restore (standard single-controller pattern).
"""

from __future__ import annotations

import os
from typing import Any, Dict

import numpy as np


def _flatten(tree, prefix="") -> Dict[str, Any]:
    out = {}
    if isinstance(tree, dict):
        for k in sorted(tree):
            out.update(_flatten(tree[k], f"{prefix}{k}/"))
    elif isinstance(tree, (list, tuple)):
        tag = "T" if isinstance(tree, tuple) else "L"
        for i, v in enumerate(tree):
            out.update(_flatten(v, f"{prefix}{tag}{i}/"))
    else:
        out[prefix.rstrip("/")] = np.asarray(tree)
    return out


def save(path: str, tree) -> None:
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    flat = _flatten(tree)
    np.savez(path, **flat)


def restore(path: str, like):
    """Restore into the structure of `like` (values replaced)."""
    with np.load(path) as data:
        flat = dict(data)

    def rebuild(sub, prefix=""):
        if isinstance(sub, dict):
            return {k: rebuild(sub[k], f"{prefix}{k}/") for k in sub}
        if isinstance(sub, (list, tuple)):
            tag = "T" if isinstance(sub, tuple) else "L"
            vals = [rebuild(v, f"{prefix}{tag}{i}/")
                    for i, v in enumerate(sub)]
            return tuple(vals) if isinstance(sub, tuple) else vals
        key = prefix.rstrip("/")
        arr = flat[key]
        want = np.asarray(sub)
        assert arr.shape == want.shape, f"{key}: {arr.shape} != {want.shape}"
        return arr

    return rebuild(like)
