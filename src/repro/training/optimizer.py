"""AdamW + LR schedules in pure JAX (no optax dependency)."""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    schedule: str = "cosine"      # cosine | linear | constant


def lr_at(cfg: AdamWConfig, step):
    step = jnp.asarray(step, jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    frac = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
                    0.0, 1.0)
    if cfg.schedule == "cosine":
        decay = 0.5 * (1.0 + jnp.cos(jnp.pi * frac))
    elif cfg.schedule == "linear":
        decay = 1.0 - frac
    else:
        decay = 1.0
    return cfg.lr * warm * decay


def init_state(params):
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "step": jnp.zeros((), jnp.int32),
        "m": jax.tree_util.tree_map(zeros, params),
        "v": jax.tree_util.tree_map(zeros, params),
    }


def global_norm(tree):
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in leaves))


def apply_updates(cfg: AdamWConfig, params, grads, state):
    """One AdamW step.  Returns (new_params, new_state, metrics)."""
    step = state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9)) \
        if cfg.grad_clip > 0 else 1.0
    lr = lr_at(cfg, step)
    b1, b2 = cfg.beta1, cfg.beta2
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * jnp.square(g)
        mh = m / bc1
        vh = v / bc2
        delta = mh / (jnp.sqrt(vh) + cfg.eps) \
            + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state["m"])
    flat_v = treedef.flatten_up_to(state["v"])
    out = [upd(p, g, m, v)
           for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree_util.tree_unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree_util.tree_unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree_util.tree_unflatten(treedef, [o[2] for o in out])
    new_state = {"step": step, "m": new_m, "v": new_v}
    return new_p, new_state, {"grad_norm": gnorm, "lr": lr}
