"""Token data pipeline: synthetic (deterministic PRNG) and file-backed
(uint16/uint32 memmap) sources, yielding (tokens, labels) next-token pairs.

Sharding: callers slice the global batch by data-parallel rank via
``shard_batch`` (host-local feeding) or hand the full batch to pjit (the
dry-run path).
"""

from __future__ import annotations

import dataclasses
from typing import Iterator, Optional, Tuple

import numpy as np


@dataclasses.dataclass
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    source: str = "synthetic"     # synthetic | file
    path: Optional[str] = None
    seed: int = 0


def synthetic_batches(cfg: DataConfig) -> Iterator[Tuple[np.ndarray,
                                                         np.ndarray]]:
    """Zipf-ish synthetic tokens — deterministic, infinitely repeatable."""
    rng = np.random.default_rng(cfg.seed)
    ranks = np.arange(1, cfg.vocab_size + 1, dtype=np.float64)
    probs = 1.0 / ranks
    probs /= probs.sum()
    while True:
        toks = rng.choice(cfg.vocab_size, size=(cfg.global_batch,
                                                cfg.seq_len + 1), p=probs)
        toks = toks.astype(np.int32)
        yield toks[:, :-1], toks[:, 1:]


def file_batches(cfg: DataConfig) -> Iterator[Tuple[np.ndarray, np.ndarray]]:
    data = np.memmap(cfg.path, dtype=np.uint16, mode="r")
    n = len(data) - cfg.seq_len - 1
    rng = np.random.default_rng(cfg.seed)
    while True:
        starts = rng.integers(0, n, size=cfg.global_batch)
        toks = np.stack([data[s:s + cfg.seq_len + 1] for s in starts])
        toks = toks.astype(np.int32) % cfg.vocab_size
        yield toks[:, :-1], toks[:, 1:]


def batches(cfg: DataConfig):
    if cfg.source == "file":
        return file_batches(cfg)
    return synthetic_batches(cfg)


def shard_batch(batch: np.ndarray, rank: int, world: int) -> np.ndarray:
    assert batch.shape[0] % world == 0
    per = batch.shape[0] // world
    return batch[rank * per:(rank + 1) * per]
