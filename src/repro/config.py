"""Configuration system for the repro framework.

Three config families:
  * ModelConfig  -- architecture hyperparameters (one per assigned arch).
  * ShapeConfig  -- the four assigned input shapes (train/prefill/decode).
  * RunConfig    -- execution knobs: mesh, sharding rules, remat, kernels.

Configs are frozen dataclasses so they can be used as static args /
hashables for jax.jit.
"""

from __future__ import annotations

from dataclasses import dataclass, replace
from typing import Optional, Tuple


# ---------------------------------------------------------------------------
# Model configuration
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid | audio | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    source: str = ""                 # citation (arXiv / hf model card)

    # -- attention ----------------------------------------------------------
    head_dim: int = 0                # 0 -> d_model // num_heads
    rope_theta: float = 10000.0
    use_qkv_bias: bool = False       # qwen1.5-style
    qk_norm: bool = False            # qwen3-style per-head RMSNorm on q/k
    sliding_window: int = 0          # 0 = full attention (dense archs get a
                                     # windowed variant for long_500k at the
                                     # RunConfig level, not here)

    # -- MoE ------------------------------------------------------------
    num_experts: int = 0             # routed experts (0 = dense FFN)
    experts_per_token: int = 0       # top-k
    num_shared_experts: int = 0      # DeepSeekMoE shared experts
    d_ff_expert: int = 0             # per-expert hidden dim
    router_aux_coef: float = 0.01    # load-balance loss coefficient

    # -- SSM (Mamba2 / xLSTM) ------------------------------------------------
    ssm_state: int = 0               # state dim per head (Mamba2 N)
    ssm_conv: int = 4                # depthwise conv width
    ssm_expand: int = 2              # d_inner = expand * d_model
    ssm_head_dim: int = 64           # Mamba2 P (head dim of inner channels)
    xlstm_slstm_every: int = 0       # xLSTM: place an sLSTM block every k-th
                                     # layer (0 = no sLSTM, pure mLSTM)

    # -- hybrid (zamba2) ------------------------------------------------------
    shared_attn_every: int = 0       # one *weight-shared* attn block applied
                                     # every k-th backbone layer

    # -- VLM (mllama) ---------------------------------------------------------
    cross_attn_every: int = 0        # insert a cross-attn layer every k-th
    num_vision_tokens: int = 0       # stub frontend: precomputed patch embeds

    # -- audio (whisper) -------------------------------------------------------
    encoder_layers: int = 0          # >0 -> encoder-decoder model
    num_audio_frames: int = 0        # stub frontend: precomputed frame embeds

    # -- norms / activations ---------------------------------------------------
    norm: str = "rmsnorm"            # rmsnorm | layernorm
    activation: str = "silu"         # silu | gelu | relu2
    gated_mlp: bool = True           # SwiGLU-style gate (False: plain MLP)
    tie_embeddings: bool = False

    dtype: str = "bfloat16"

    # ----------------------------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or (self.d_model // self.num_heads)

    @property
    def is_moe(self) -> bool:
        return self.num_experts > 0

    @property
    def is_encdec(self) -> bool:
        return self.encoder_layers > 0

    @property
    def q_per_kv(self) -> int:
        return self.num_heads // max(self.num_kv_heads, 1)

    def param_count(self) -> int:
        """Analytic parameter count (embedding + blocks + head)."""
        d, L, H, KV, hd = (self.d_model, self.num_layers, self.num_heads,
                           self.num_kv_heads, self.resolved_head_dim)
        n = self.vocab_size * d                      # embed
        if not self.tie_embeddings:
            n += self.vocab_size * d                 # lm head
        attn = d * H * hd + 2 * d * KV * hd + H * hd * d
        if self.family == "ssm" and self.xlstm_slstm_every >= 0 and self.ssm_state == 0:
            # xLSTM: handled by its own counter below
            pass
        if self.is_moe:
            ffn = 3 * d * self.d_ff_expert * (self.num_experts
                                              + self.num_shared_experts)
            ffn += d * self.num_experts              # router
        elif self.gated_mlp:
            ffn = 3 * d * self.d_ff
        else:
            ffn = 2 * d * self.d_ff
        if self.family in ("ssm", "hybrid"):
            d_in = self.ssm_expand * d
            nheads = d_in // self.ssm_head_dim if self.ssm_head_dim else 0
            ssm = (d * (2 * d_in + 2 * self.ssm_state * (d_in // self.ssm_head_dim if False else 1)) )
            # simpler: in_proj (d -> 2*d_in + 2*groups*state + heads), out_proj
            ssm = d * (2 * d_in + 2 * self.ssm_state + nheads) + d_in * d
            per_layer = ssm
            if self.family == "hybrid":
                n += attn + ffn                      # one shared attn block
                per_layer += 0
            n += L * per_layer
        else:
            n += L * (attn + ffn)
        if self.cross_attn_every:
            n_cross = L // self.cross_attn_every
            n += n_cross * attn                      # cross-attn layers extra
        if self.is_encdec:
            n += self.encoder_layers * (attn + ffn)
        return n

    def active_param_count(self) -> int:
        """Parameters touched per token (MoE: shared + top-k routed)."""
        if not self.is_moe:
            return self.param_count()
        d, L = self.d_model, self.num_layers
        full = self.param_count()
        all_expert = 3 * d * self.d_ff_expert * self.num_experts * L
        active_expert = 3 * d * self.d_ff_expert * self.experts_per_token * L
        return full - all_expert + active_expert


# ---------------------------------------------------------------------------
# Input shapes
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                        # train | prefill | decode


TRAIN_4K = ShapeConfig("train_4k", 4096, 256, "train")
PREFILL_32K = ShapeConfig("prefill_32k", 32768, 32, "prefill")
DECODE_32K = ShapeConfig("decode_32k", 32768, 128, "decode")
LONG_500K = ShapeConfig("long_500k", 524288, 1, "decode")

SHAPES = {s.name: s for s in (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)}


# ---------------------------------------------------------------------------
# Run / parallelism configuration
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class RunConfig:
    """Execution knobs; orthogonal to the architecture."""
    use_pallas: bool = False         # True on TPU; CPU uses ref impls
    remat: str = "none"              # none | block | full
    fsdp: bool = False               # shard weights over the data axis too
    decode_window: int = 0           # >0: sliding-window decode attention
                                     # (enables long_500k for dense archs)
    kv_cache_dtype: str = "bfloat16" # or "int8" (beyond-paper)
    shard_kv_seq: bool = False       # sequence-shard the KV cache over data
                                     # axis (long_500k context parallelism)
    moe_capacity_factor: float = 1.25
    matmul_precision: str = "default"
    # ---- beyond-paper perf knobs (EXPERIMENTS.md §Perf) -------------------
    prefill_logits: str = "all"      # "last": only final-position logits
                                     # (vLLM semantics; kills the (B,S,V)
                                     # logits tensor + its collectives)
    decode_inplace_cache: bool = False
                                     # keep KV cache in the layer-scan CARRY
                                     # and update in place (donated buffer)
                                     # instead of restacking it through
                                     # scan ys -- removes a full-cache
                                     # copy per decode step
    decode_slice_reads: bool = False # with decode_window: dynamic-slice
                                     # only the window out of the cache
                                     # instead of masked full-cache reads
    prefill_parallel_q: bool = False # vectorize q chunks in chunked
                                     # attention (shardable seq axis for
                                     # archs whose heads don't divide the
                                     # model axis)
    decode_uniform_pos: bool = False # all sequences share one decode
                                     # position (serve_step): KV writes
                                     # lower to contiguous in-place DUS
                                     # instead of (CPU: f32-round-trip)
                                     # scatters


# Logical axis -> mesh axes mapping (MaxText-style sharding rules).
# Values are mesh-axis names or None (replicated).
DEFAULT_RULES: Tuple[Tuple[str, Optional[Tuple[str, ...]]], ...] = (
    ("batch", ("pod", "data")),
    ("seq", None),
    ("kv_seq", None),
    ("embed", None),
    ("heads", ("model",)),
    ("kv_heads", ("model",)),
    ("head_dim", None),
    ("mlp", ("model",)),
    ("experts", ("model",)),
    ("vocab", ("model",)),
    ("ssm_inner", ("model",)),
    ("ssm_state", None),
)


def sharding_rules_for(cfg: ModelConfig, mesh_axis_sizes: dict,
                       run: RunConfig = RunConfig()) -> dict:
    """Resolve DEFAULT_RULES against an arch: drop a 'model' mapping when the
    corresponding dimension is not divisible by the model-axis size, falling
    back to replication for that logical axis. This keeps every arch
    lowerable on the 16-way model axis (e.g. xlstm has 4 heads, whisper has
    6 heads and vocab 51865)."""
    model = mesh_axis_sizes.get("model", 1)
    rules = {}
    for name, axes in DEFAULT_RULES:
        if isinstance(axes, (tuple, list)):
            kept = tuple(a for a in axes if a in mesh_axis_sizes)
            rules[name] = kept or None
        else:
            rules[name] = axes if (axes is None or axes in mesh_axis_sizes) \
                else None

    def ok(dim: int) -> bool:
        return dim > 0 and dim % model == 0

    if not ok(cfg.num_heads * cfg.resolved_head_dim) or not ok(cfg.num_heads):
        rules["heads"] = None
    if not ok(cfg.num_kv_heads):
        rules["kv_heads"] = None
    ff = cfg.d_ff_expert if cfg.is_moe else cfg.d_ff
    if not ok(ff):
        rules["mlp"] = None
    if cfg.is_moe and not ok(cfg.num_experts):
        rules["experts"] = None
    if not ok(cfg.vocab_size):
        rules["vocab"] = None
    if cfg.family in ("ssm", "hybrid") and not ok(cfg.ssm_expand * cfg.d_model):
        rules["ssm_inner"] = None
    if run.shard_kv_seq:
        rules["kv_seq"] = ("data",)
    return rules


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

_REGISTRY: dict = {}


def register(cfg: ModelConfig) -> ModelConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ModelConfig:
    _ensure_loaded()
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def list_archs() -> list:
    _ensure_loaded()
    return sorted(_REGISTRY)


def _ensure_loaded() -> None:
    if not _REGISTRY:
        from repro import configs as _configs  # noqa: F401  (side-effect import)


def smoke_variant(cfg: ModelConfig) -> ModelConfig:
    """Reduced config of the same family: 2 layers, d_model<=512, <=4 experts.

    Used by per-arch smoke tests; the full config is only exercised via the
    dry-run (ShapeDtypeStruct, no allocation)."""
    d_model = min(cfg.d_model, 256)
    heads = max(2, min(cfg.num_heads, 4))
    kv = max(1, min(cfg.num_kv_heads, heads))
    while heads % kv:
        kv -= 1
    updates = dict(
        name=cfg.name + "-smoke",
        num_layers=2,
        d_model=d_model,
        num_heads=heads,
        num_kv_heads=kv,
        head_dim=(64 if cfg.head_dim else 0),
        d_ff=min(cfg.d_ff, 512) if cfg.d_ff else 0,
        vocab_size=min(cfg.vocab_size, 512),
    )
    if cfg.is_moe:
        updates.update(num_experts=4,
                       experts_per_token=min(2, cfg.experts_per_token),
                       num_shared_experts=min(1, cfg.num_shared_experts),
                       d_ff_expert=128)
    if cfg.family in ("ssm", "hybrid"):
        updates.update(ssm_state=min(cfg.ssm_state, 16) or 16)
    if cfg.shared_attn_every:
        updates.update(shared_attn_every=2)
    if cfg.cross_attn_every:
        updates.update(cross_attn_every=2)
    if cfg.is_encdec:
        updates.update(encoder_layers=2, num_audio_frames=32)
    if cfg.num_vision_tokens:
        updates.update(num_vision_tokens=16)
    if cfg.xlstm_slstm_every:
        updates.update(xlstm_slstm_every=2)
    return replace(cfg, **updates)


__all__ = [
    "ModelConfig", "ShapeConfig", "RunConfig",
    "TRAIN_4K", "PREFILL_32K", "DECODE_32K", "LONG_500K", "SHAPES",
    "DEFAULT_RULES", "sharding_rules_for",
    "register", "get_config", "list_archs", "smoke_variant", "replace",
]
