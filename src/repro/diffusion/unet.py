"""DDPM++-style U-Net for CIFAR-scale image diffusion, in pure JAX.

Supports *per-sample* timestep conditioning — the property batch denoising
relies on: one batched forward can mix denoising tasks of different
services at different step indices (STACKING's batches are exactly such
mixtures).

Layout: NHWC.  GroupNorm+SiLU chains are the compute hot spot the
kernels/groupnorm_silu Pallas kernel targets on TPU.
"""

from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.configs.ddim_cifar10 import UNetConfig
from repro.models.params import P


# ---------------------------------------------------------------------------
# Primitives
# ---------------------------------------------------------------------------

def conv2d(x, w, b=None, stride=1):
    out = jax.lax.conv_general_dilated(
        x, w, window_strides=(stride, stride), padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    if b is not None:
        out = out + b
    return out


def group_norm(x, scale, bias, num_groups: int, eps: float = 1e-6):
    B, H, W, C = x.shape
    G = min(num_groups, C)
    while C % G:
        G -= 1
    xg = x.reshape(B, H, W, G, C // G).astype(jnp.float32)
    mu = xg.mean(axis=(1, 2, 4), keepdims=True)
    var = xg.var(axis=(1, 2, 4), keepdims=True)
    xg = (xg - mu) * jax.lax.rsqrt(var + eps)
    out = xg.reshape(B, H, W, C) * scale + bias
    return out.astype(x.dtype)


def gn_silu(x, scale, bias, num_groups: int):
    """Fused GroupNorm+SiLU; dispatches to the Pallas kernel on TPU (or
    under REPRO_FORCE_PALLAS=1) — the U-Net's HBM hot spot."""
    from repro.kernels import use_pallas
    mode = use_pallas()
    if mode in ("tpu", "interpret"):
        from repro.kernels.groupnorm_silu.kernel import groupnorm_silu_pallas
        return groupnorm_silu_pallas(x, scale, bias, num_groups,
                                     interpret=(mode == "interpret"))
    return jax.nn.silu(group_norm(x, scale, bias, num_groups))


def timestep_embedding(t, dim: int, max_period: float = 10000.0):
    """t: (B,) float timesteps -> (B, dim) sinusoidal embedding."""
    half = dim // 2
    freqs = jnp.exp(-jnp.log(max_period)
                    * jnp.arange(half, dtype=jnp.float32) / half)
    args = t[:, None].astype(jnp.float32) * freqs[None]
    return jnp.concatenate([jnp.cos(args), jnp.sin(args)], axis=-1)


# ---------------------------------------------------------------------------
# Schema
# ---------------------------------------------------------------------------

def _conv_p(kh, kw, cin, cout, scale=None):
    return P((kh, kw, cin, cout), (None, None, None, None), scale=scale)


def _res_block_schema(cin, cout, temb_dim):
    return {
        "gn1_s": P((cin,), (None,), init="ones"),
        "gn1_b": P((cin,), (None,), init="zeros"),
        "conv1": _conv_p(3, 3, cin, cout),
        "temb": P((temb_dim, cout), (None, None)),
        "gn2_s": P((cout,), (None,), init="ones"),
        "gn2_b": P((cout,), (None,), init="zeros"),
        "conv2": _conv_p(3, 3, cout, cout, scale=0.05),
        **({"skip": _conv_p(1, 1, cin, cout)} if cin != cout else {}),
    }


def _attn_schema(ch):
    return {
        "gn_s": P((ch,), (None,), init="ones"),
        "gn_b": P((ch,), (None,), init="zeros"),
        "wq": P((ch, ch), (None, None)),
        "wk": P((ch, ch), (None, None)),
        "wv": P((ch, ch), (None, None)),
        "wo": P((ch, ch), (None, None), scale=0.05),
    }


def schema(cfg: UNetConfig):
    ch = cfg.base_channels
    temb = 4 * ch
    s = {
        "temb1": P((ch, temb), (None, None)),
        "temb2": P((temb, temb), (None, None)),
        "conv_in": _conv_p(3, 3, cfg.in_channels, ch),
        "gn_out_s": P((ch,), (None,), init="ones"),
        "gn_out_b": P((ch,), (None,), init="zeros"),
        "conv_out": _conv_p(3, 3, ch, cfg.in_channels, scale=1e-10),
    }
    res = cfg.image_size
    cin = ch
    downs, chans = [], [(cin, res)]
    for li, mult in enumerate(cfg.channel_mults):
        cout = ch * mult
        level = {"res": []}
        for bi in range(cfg.num_res_blocks):
            blk = {"res": _res_block_schema(cin, cout, temb)}
            if res in cfg.attn_resolutions:
                blk["attn"] = _attn_schema(cout)
            level["res"].append(blk)
            cin = cout
            chans.append((cin, res))
        if li != len(cfg.channel_mults) - 1:
            level["down"] = _conv_p(3, 3, cin, cin)
            res //= 2
            chans.append((cin, res))
        downs.append(level)
    s["downs"] = downs
    s["mid1"] = _res_block_schema(cin, cin, temb)
    s["mid_attn"] = _attn_schema(cin)
    s["mid2"] = _res_block_schema(cin, cin, temb)

    ups = []
    for li, mult in reversed(list(enumerate(cfg.channel_mults))):
        cout = ch * mult
        level = {"res": []}
        for bi in range(cfg.num_res_blocks + 1):
            skip_c, skip_res = chans.pop()
            blk = {"res": _res_block_schema(cin + skip_c, cout, temb)}
            if skip_res in cfg.attn_resolutions:
                blk["attn"] = _attn_schema(cout)
            level["res"].append(blk)
            cin = cout
        if li != 0:
            level["up"] = _conv_p(3, 3, cin, cin)
            res *= 2
        ups.append(level)
    s["ups"] = ups
    return s


# ---------------------------------------------------------------------------
# Forward
# ---------------------------------------------------------------------------

def _res_block(cfg, p, x, temb):
    h = gn_silu(x, p["gn1_s"], p["gn1_b"], cfg.num_groups)
    h = conv2d(h, p["conv1"])
    h = h + (jax.nn.silu(temb) @ p["temb"])[:, None, None, :]
    h = gn_silu(h, p["gn2_s"], p["gn2_b"], cfg.num_groups)
    h = conv2d(h, p["conv2"])
    skip = conv2d(x, p["skip"]) if "skip" in p else x
    return skip + h


def _attn_block(cfg, p, x):
    B, H, W, C = x.shape
    h = group_norm(x, p["gn_s"], p["gn_b"], cfg.num_groups)
    flat = h.reshape(B, H * W, C)
    q, k, v = flat @ p["wq"], flat @ p["wk"], flat @ p["wv"]
    attn = jax.nn.softmax(
        jnp.einsum("bqc,bkc->bqk", q, k) / jnp.sqrt(C), axis=-1)
    out = jnp.einsum("bqk,bkc->bqc", attn, v) @ p["wo"]
    return x + out.reshape(B, H, W, C)


def forward(cfg: UNetConfig, params, x, t):
    """x: (B, H, W, C) noisy images; t: (B,) per-sample timesteps.
    Returns predicted noise eps, same shape as x."""
    temb = timestep_embedding(t, cfg.base_channels)
    temb = jax.nn.silu(temb @ params["temb1"]) @ params["temb2"]

    h = conv2d(x, params["conv_in"])
    skips = [h]
    for level in params["downs"]:
        for blk in level["res"]:
            h = _res_block(cfg, blk["res"], h, temb)
            if "attn" in blk:
                h = _attn_block(cfg, blk["attn"], h)
            skips.append(h)
        if "down" in level:
            h = conv2d(h, level["down"], stride=2)
            skips.append(h)

    h = _res_block(cfg, params["mid1"], h, temb)
    h = _attn_block(cfg, params["mid_attn"], h)
    h = _res_block(cfg, params["mid2"], h, temb)

    for level in params["ups"]:
        for blk in level["res"]:
            h = jnp.concatenate([h, skips.pop()], axis=-1)
            h = _res_block(cfg, blk["res"], h, temb)
            if "attn" in blk:
                h = _attn_block(cfg, blk["attn"], h)
        if "up" in level:
            B, H, W, C = h.shape
            h = jax.image.resize(h, (B, 2 * H, 2 * W, C), "nearest")
            h = conv2d(h, level["up"])

    h = gn_silu(h, params["gn_out_s"], params["gn_out_b"], cfg.num_groups)
    return conv2d(h, params["conv_out"])
