"""Device-resident bucketed denoising engine (``exec_engine="bucketed"``).

The dict engine round-trips latents through a per-service Python dict on
every step: stack K slices host-side, dispatch, scatter K slices back.
This engine keeps all K latents in ONE device array for the whole
session and drives each batch with a single jitted
gather→DDIM-step→scatter program:

  * **Pool layout** — ``(K+1, H, W, C)``: row i holds service
    ``ids[i]``'s latent (seeded identically to the dict path), row K is
    a scratch row for padded lanes.
  * **Power-of-two buckets** — a batch of B services runs at padded
    width ``shape_bucket(B)`` (min 2), the same bucketing trick as
    ``jaxplan/kernels.py``.  Padded lanes gather the scratch row with
    ``t_now = -1``; ``ddim_step``'s inactive-passthrough returns them
    unchanged, and the duplicate scatter indices all write that same
    unchanged value, so padding is deterministic and invisible.  Any
    plan over K services compiles at most ⌈log2 K⌉ step programs.
  * **Donated buffers** — the pool is donated into every program, so
    steps update latents in place instead of allocating K slice views.
  * **Scan megasteps** — ``run_plan`` fuses runs of consecutive batches
    with identical service composition (a stable phase of a STACKING
    plan) into ``lax.scan`` programs over chunk lengths
    ``_SCAN_CHUNKS``, so a stable phase costs one dispatch per chunk,
    not one per step.  Timed execution stays stepwise — the closed loop
    needs one wall-clock reading per batch.

Numerical contract: per-row results match the dict engine within
``MATCH_TOL`` (XLA may fuse a padded-width batch differently from the
exact-width batch, so bit-exactness across engines is NOT promised; the
dict engine remains the bit-exact-per-row reference).  The property test
in ``tests/test_exec_bucketed.py`` and the ``exec_bucketed_images_match``
e2e gate both pin this tolerance.
"""

from __future__ import annotations

import time
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.execution import shape_bucket
from repro.diffusion.executor import BatchDenoisingExecutor, \
    DenoiseSession

# bucketed-vs-dict per-row tolerance (docs/PERFORMANCE.md): padded-width
# XLA programs may fuse differently from exact-width ones, but per-row
# math is identical up to float32 reassociation
MATCH_TOL = {"atol": 1e-5, "rtol": 1e-5}

# scan chunk lengths, largest first: a stable phase of C steps runs as
# greedy chunks (e.g. C=23 -> 16+4+2+1 step), so each bucket compiles at
# most len(_SCAN_CHUNKS) scan programs ever
_SCAN_CHUNKS = (32, 16, 8, 4, 2)


def pool_step(step_fn):
    """Build the gather→step→scatter program body over a latent pool."""
    def f(pool, idx, t_now, t_next):
        y = step_fn(pool[idx], t_now, t_next)
        return pool.at[idx].set(y)
    return f


def pool_scan(step_fn):
    """Scan ``pool_step`` over a ``(C, 2, Bp)`` timestep stack."""
    def f(pool, idx, ts):
        def body(p, t):
            y = step_fn(p[idx], t[0], t[1])
            return p.at[idx].set(y), None
        out, _ = jax.lax.scan(body, pool, ts)
        return out
    return f


class BucketedDenoiseSession(DenoiseSession):
    """``DenoiseSession`` with device-resident pool execution.  Same
    interface and scheduling semantics (``retarget`` is inherited
    untouched); only the step dispatch differs."""

    def __init__(self, executor: BatchDenoisingExecutor, plan, key):
        super().__init__(executor, plan, key)
        ids = sorted(self.steps_done)
        self._ids = ids
        self._row = {k: i for i, k in enumerate(ids)}
        self._scratch = len(ids)
        self._pool_rows = len(ids) + 1
        cfg = executor.cfg
        shape = (cfg.image_size, cfg.image_size, cfg.in_channels)
        rows = [self.latents[k] for k in ids]
        self._pool = jnp.stack(rows + [jnp.zeros(shape, jnp.float32)])
        # the pool is now the single source of truth; fail loudly if
        # anything still pokes the dict
        self.latents = None
        self._step_prog_body = pool_step(executor.step_fn)
        self._scan_prog_body = pool_scan(executor.step_fn)
        self._scan_dispatch: Dict[tuple, int] = {}
        self._scan_steps = 0

    def _lanes(self, ks: List[int]):
        """Padded (idx, t_now, t_next) lane arrays for one batch;
        validates remaining schedules like the dict path."""
        Bp = shape_bucket(len(ks))
        idx = np.full((Bp,), self._scratch, np.int32)
        t_now = np.full((Bp,), -1, np.int32)
        t_next = np.full((Bp,), -1, np.int32)
        for lane, k in enumerate(ks):
            rem = self._remaining[k]
            if not rem:
                raise ValueError(
                    f"service {k} has no remaining denoising steps")
            idx[lane] = self._row[k]
            t_now[lane] = rem[0]
            t_next[lane] = rem[1] if len(rem) > 1 else -1
        return idx, t_now, t_next

    def run_batch(self, ks: List[int], timed: bool = False) -> float:
        idx, t_now, t_next = self._lanes(ks)
        Bp = len(idx)
        prog = self.executor.program(
            ("bstep", self._pool_rows, Bp), self._step_prog_body,
            (self._pool, idx, t_now, t_next), donate=(0,))
        dt = 0.0
        if timed:
            t0 = time.perf_counter()
            pool = prog(self._pool, idx, t_now, t_next)
            pool.block_until_ready()
            dt = time.perf_counter() - t0
        else:
            pool = prog(self._pool, idx, t_now, t_next)
        self._pool = pool
        self.executor.dispatches += 1
        self._dispatch[Bp] = self._dispatch.get(Bp, 0) + 1
        for k in ks:
            self._remaining[k].pop(0)
            self.steps_done[k] += 1
        return dt

    def run_plan(self, batches: List[List[int]]) -> None:
        """Fuse runs of consecutive identical-composition batches into
        scan megasteps; mixed phases fall back to single steps."""
        i, n = 0, len(batches)
        while i < n:
            ks = list(batches[i])
            sig = tuple(sorted(ks))
            j = i + 1
            while j < n and tuple(sorted(batches[j])) == sig:
                j += 1
            run = j - i
            if run >= 2 and ks:
                # never scan past a service's remaining schedule — the
                # shortfall surfaces as the same per-batch error the
                # stepwise path would raise
                run = min([run] + [len(self._remaining[k])
                                   for k in ks])
            if run >= 2:
                self._run_scan(ks, run)
                i += run
            else:
                self.run_batch(ks)
                i += 1

    def _run_scan(self, ks: List[int], C: int) -> None:
        Bp = shape_bucket(len(ks))
        idx = np.full((Bp,), self._scratch, np.int32)
        ts = np.full((C, 2, Bp), -1, np.int32)
        for lane, k in enumerate(ks):
            idx[lane] = self._row[k]
            rem = self._remaining[k]
            for c in range(C):
                ts[c, 0, lane] = rem[c]
                ts[c, 1, lane] = rem[c + 1] if c + 1 < len(rem) else -1
        off = 0
        for chunk in _SCAN_CHUNKS:
            while C - off >= chunk:
                prog = self.executor.program(
                    ("bscan", self._pool_rows, Bp, chunk),
                    self._scan_prog_body,
                    (self._pool, idx, ts[off:off + chunk]), donate=(0,))
                self._pool = prog(self._pool, idx, ts[off:off + chunk])
                self.executor.dispatches += 1
                key = (Bp, chunk)
                self._scan_dispatch[key] = \
                    self._scan_dispatch.get(key, 0) + 1
                self._scan_steps += chunk
                off += chunk
        for k in ks:
            del self._remaining[k][:off]
            self.steps_done[k] += off
        while off < C:     # _SCAN_CHUNKS ends at 2, so at most 1 step
            self.run_batch(ks)
            off += 1

    def telemetry(self) -> dict:
        mine = self.executor.compile_log[self._clog0:]
        compile_by_bucket: Dict[int, float] = {}
        for key, s in mine:
            if key[0] in ("bstep", "bscan"):
                b = int(key[2])
                compile_by_bucket[b] = compile_by_bucket.get(b, 0.0) + s
        return {
            "exec_engine": "bucketed",
            "dispatches": int(sum(self._dispatch.values())
                              + sum(self._scan_dispatch.values())),
            "by_bucket": {str(b): int(n)
                          for b, n in sorted(self._dispatch.items())},
            "scan_dispatches": {
                f"b{b}_c{c}": int(n)
                for (b, c), n in sorted(self._scan_dispatch.items())},
            "scan_fused_steps": int(self._scan_steps),
            "compiles": len(mine),
            "compile_s": float(sum(s for _, s in mine)),
            "compile_s_by_bucket": {
                str(b): float(s)
                for b, s in sorted(compile_by_bucket.items())},
        }

    def finish(self) -> Dict[int, np.ndarray]:
        pool = np.asarray(self._pool)
        return {k: pool[self._row[k]] for k in self._ids}


def measure_bucketed_curve(executor: BatchDenoisingExecutor, key,
                           batch_sizes, reps: int):
    """Fig. 1a sweep through the bucket programs: sizes sharing a bucket
    share one compiled program, so sweeping 1..16 compiles 4 programs
    instead of 16.  The reading for size X is the padded bucket's cost —
    exactly what the bucketed engine pays for a size-X batch."""
    cfg = executor.cfg
    sizes = [int(X) for X in batch_sizes]
    max_bucket = max(shape_bucket(X) for X in sizes)
    pool_rows = max_bucket + 1
    pool = jax.random.normal(
        key, (pool_rows, cfg.image_size, cfg.image_size,
              cfg.in_channels), jnp.float32)
    body = pool_step(executor.step_fn)
    t_mid = executor.T_train // 2
    out = []
    for X in sizes:
        Bp = shape_bucket(X)
        idx = np.full((Bp,), pool_rows - 1, np.int32)
        idx[:X] = np.arange(X, dtype=np.int32)
        t_now = np.full((Bp,), -1, np.int32)
        t_next = np.full((Bp,), -1, np.int32)
        t_now[:X] = t_mid
        t_next[:X] = t_mid - 1
        prog = executor.program(("bstep", pool_rows, Bp), body,
                                (pool, idx, t_now, t_next), donate=(0,))
        # warm dispatch (the pool is donated, so rethread it)
        pool = prog(pool, idx, t_now, t_next)
        pool.block_until_ready()
        executor.dispatches += 1
        best = float("inf")
        for _ in range(reps):
            t0 = time.perf_counter()
            pool = prog(pool, idx, t_now, t_next)
            pool.block_until_ready()
            best = min(best, time.perf_counter() - t0)
            executor.dispatches += 1
        out.append((X, best))
    return out
