"""DDIM sampling [arXiv:2010.02502] with arbitrary step-subsequences and
per-sample schedules.

The paper's service model: service k runs T_k denoising steps; DDIM
supports any sub-sequence of the 1000 training timesteps, so a service
assigned T_k steps uses the evenly-spaced subsequence of length T_k.
Quality increases monotonically (with diminishing returns) in T_k — the
paper's Fig. 1b.

``ddim_step`` is written per-sample-timestep so the batch-denoising
executor can advance a *mixed* batch (different services, different step
indices, different schedules) in ONE batched U-Net call.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np


def make_betas(num_timesteps: int = 1000, beta_start: float = 1e-4,
               beta_end: float = 0.02) -> np.ndarray:
    return np.linspace(beta_start, beta_end, num_timesteps,
                       dtype=np.float64)


def alphas_cumprod(num_timesteps: int = 1000) -> np.ndarray:
    return np.cumprod(1.0 - make_betas(num_timesteps))


def ddim_timesteps(T: int, num_train_timesteps: int = 1000) -> np.ndarray:
    """Evenly spaced T-step subsequence (descending, t_1 > ... > t_T)."""
    if T >= num_train_timesteps:
        return np.arange(num_train_timesteps)[::-1].copy()
    step = num_train_timesteps / T
    ts = (np.arange(T) * step).round().astype(np.int64)
    return ts[::-1].copy()


def schedule_table(T: int, num_train_timesteps: int = 1000) -> np.ndarray:
    """(T+1,) timestep table: entry i = timestep for step index i; the last
    entry is -1 ("fully denoised")."""
    ts = ddim_timesteps(T, num_train_timesteps)
    return np.concatenate([ts, [-1]])


def retarget_timesteps(t_start: int, T: int) -> np.ndarray:
    """Evenly spaced descending T-step subsequence from ``t_start`` down
    to 0 — rescheduling a partially denoised chain mid-run when a replan
    changes its total step count.  With ``t_start`` the next timestep the
    original schedule would have denoised from, the rebuilt chain ends at
    0 like ``ddim_timesteps`` (repeats, possible when T > t_start + 1,
    are identity DDIM updates)."""
    if T <= 0:
        return np.zeros((0,), np.int64)
    return np.round(np.linspace(float(t_start), 0.0, T)).astype(np.int64)


def ddim_step(eps_fn, x, t_now, t_next, num_train_timesteps: int = 1000):
    """One deterministic DDIM update with *per-sample* timesteps.

    x: (B, H, W, C); t_now, t_next: (B,) int32 (t_next = -1 -> alpha_bar=1).
    eps_fn(x, t) -> predicted noise.
    """
    acp = jnp.asarray(alphas_cumprod(num_train_timesteps), jnp.float32)
    a_now = acp[jnp.clip(t_now, 0)]
    a_next = jnp.where(t_next < 0, 1.0, acp[jnp.clip(t_next, 0)])
    eps = eps_fn(x, t_now.astype(jnp.float32))
    bshape = (-1,) + (1,) * (x.ndim - 1)
    a_now = a_now.reshape(bshape)
    a_next = a_next.reshape(bshape)
    x0 = (x - jnp.sqrt(1.0 - a_now) * eps) / jnp.sqrt(a_now)
    x_next = jnp.sqrt(a_next) * x0 + jnp.sqrt(1.0 - a_next) * eps
    # inactive samples (t_now < 0) pass through unchanged
    active = (t_now >= 0).reshape(bshape)
    return jnp.where(active, x_next, x)


def sample(eps_fn, key, shape: Tuple[int, ...], T: int,
           num_train_timesteps: int = 1000):
    """Plain (single-service) DDIM sampling loop: T steps, batch `shape`."""
    x = jax.random.normal(key, shape, jnp.float32)
    ts = ddim_timesteps(T, num_train_timesteps)
    ts_next = np.concatenate([ts[1:], [-1]])
    B = shape[0]
    for t_now, t_next in zip(ts, ts_next):
        tn = jnp.full((B,), t_now, jnp.int32)
        tx = jnp.full((B,), t_next, jnp.int32)
        x = ddim_step(eps_fn, x, tn, tx, num_train_timesteps)
    return x
