"""Batch-denoising executor: runs a BatchPlan against a real DDIM U-Net.

Each service k ends the plan with T_k steps; its DDIM schedule is the
evenly-spaced T_k-step subsequence.  Batch n gathers the current latents
of its packed services (which sit at *different* step indices of
*different* schedules), advances them with ONE batched U-Net call using
per-sample timesteps, and scatters the results back — this is exactly the
parallelism the paper's Fig. 1a measures.

Also the measurement rig for refitting the delay model (Fig. 1a): `timed`
mode records per-batch wall-clock vs batch size.
"""

from __future__ import annotations

import time
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.ddim_cifar10 import UNetConfig
from repro.core.plan import BatchPlan
from repro.diffusion import ddim, unet


class BatchDenoisingExecutor:
    def __init__(self, cfg: UNetConfig, params,
                 num_train_timesteps: Optional[int] = None):
        self.cfg = cfg
        self.params = params
        self.T_train = num_train_timesteps or cfg.num_train_timesteps

        def eps(x, t):
            return unet.forward(cfg, params, x, t)

        def step(x, t_now, t_next):
            return ddim.ddim_step(eps, x, t_now, t_next, self.T_train)

        self._step = jax.jit(step)

    def run(self, plan: BatchPlan, key,
            timed: bool = False) -> Tuple[Dict[int, np.ndarray], List]:
        """Execute the plan.  Returns ({service: final image}, timings).

        timings: list of (batch_size, seconds) when timed=True.
        """
        cfg = self.cfg
        shape = (cfg.image_size, cfg.image_size, cfg.in_channels)
        ids = sorted(plan.steps_completed)
        keys = jax.random.split(key, max(len(ids), 1))
        latents = {k: jax.random.normal(kk, shape, jnp.float32)
                   for k, kk in zip(ids, keys)}
        # per-service schedule table: step s -> timestep (last entry -1)
        tables = {k: ddim.schedule_table(max(plan.steps_completed[k], 1),
                                         self.T_train)
                  for k in ids}

        timings = []
        for batch in plan.batches:
            ks = [k for k, _ in batch]
            x = jnp.stack([latents[k] for k in ks])
            t_now = jnp.array([tables[k][s] for k, s in batch], jnp.int32)
            t_next = jnp.array([tables[k][s + 1] for k, s in batch],
                               jnp.int32)
            if timed:
                # timing must be side-effect-free: `y` IS this batch's
                # one step (also the compile warm-up); the timed call
                # re-runs the same inputs for a steady-state reading and
                # its result is discarded, so timed and untimed runs
                # produce identical images (tests/test_diffusion.py)
                y = self._step(x, t_now, t_next)
                y.block_until_ready()
                t0 = time.perf_counter()
                self._step(x, t_now, t_next).block_until_ready()
                timings.append((len(ks), time.perf_counter() - t0))
                x = y
            else:
                x = self._step(x, t_now, t_next)
            for i, k in enumerate(ks):
                latents[k] = x[i]
        images = {k: np.asarray(v) for k, v in latents.items()}
        return images, timings

    def measure_delay_curve(self, key, batch_sizes=range(1, 17),
                            reps: int = 3) -> List[Tuple[int, float]]:
        """Fig. 1a measurement: steady-state per-step delay vs batch size."""
        cfg = self.cfg
        out = []
        for X in batch_sizes:
            x = jax.random.normal(key, (X, cfg.image_size, cfg.image_size,
                                        cfg.in_channels), jnp.float32)
            t = jnp.full((X,), self.T_train // 2, jnp.int32)
            tn = jnp.full((X,), self.T_train // 2 - 1, jnp.int32)
            self._step(x, t, tn).block_until_ready()   # compile
            best = float("inf")
            for _ in range(reps):
                t0 = time.perf_counter()
                self._step(x, t, tn).block_until_ready()
                best = min(best, time.perf_counter() - t0)
            out.append((int(X), best))
        return out
