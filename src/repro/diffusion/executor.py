"""Batch-denoising executor: runs a BatchPlan against a real DDIM U-Net.

Each service k ends the plan with T_k steps; its DDIM schedule is the
evenly-spaced T_k-step subsequence.  Batch n gathers the current latents
of its packed services (which sit at *different* step indices of
*different* schedules), advances them with ONE batched U-Net call using
per-sample timesteps, and scatters the results back — this is exactly the
parallelism the paper's Fig. 1a measures.

Also the measurement rig for refitting the delay model (Fig. 1a): `timed`
mode records per-batch wall-clock vs batch size.
"""

from __future__ import annotations

import time
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.ddim_cifar10 import UNetConfig
from repro.core.plan import BatchPlan
from repro.diffusion import ddim, unet


class BatchDenoisingExecutor:
    def __init__(self, cfg: UNetConfig, params,
                 num_train_timesteps: Optional[int] = None):
        self.cfg = cfg
        self.params = params
        self.T_train = num_train_timesteps or cfg.num_train_timesteps

        def eps(x, t):
            return unet.forward(cfg, params, x, t)

        def step(x, t_now, t_next):
            return ddim.ddim_step(eps, x, t_now, t_next, self.T_train)

        self._step = jax.jit(step)

    def open_session(self, plan: BatchPlan, key) -> "DenoiseSession":
        """Stepwise execution handle for the EXECUTORS registry: batches
        are driven one ``run_batch`` call at a time so a closed loop
        (``repro.core.execution``) can observe wall-clock and retarget
        remaining schedules between batches."""
        return DenoiseSession(self, plan, key)

    def run(self, plan: BatchPlan, key,
            timed: bool = False) -> Tuple[Dict[int, np.ndarray], List]:
        """Execute the plan.  Returns ({service: final image}, timings).

        timings: list of (batch_size, seconds) when timed=True.
        Zero-step services (the planner retired them) are never batched;
        their latent comes back untouched.
        """
        sess = self.open_session(plan, key)
        timings = []
        for batch in plan.batches:
            dt = sess.run_batch([k for k, _ in batch], timed=timed)
            if timed:
                timings.append((len(batch), dt))
        return sess.finish(), timings

    def step_batch(self, latents: Dict[int, "jax.Array"],
                   schedule: Dict[int, Tuple[int, int]],
                   ks: List[int], timed: bool) -> float:
        """Advance ``ks`` one DDIM step in ONE batched U-Net call,
        scattering results back into ``latents``.  Returns measured
        seconds when ``timed`` (0.0 otherwise)."""
        x = jnp.stack([latents[k] for k in ks])
        t_now = jnp.array([schedule[k][0] for k in ks], jnp.int32)
        t_next = jnp.array([schedule[k][1] for k in ks], jnp.int32)
        dt = 0.0
        if timed:
            # timing must be side-effect-free: `y` IS this batch's
            # one step (also the compile warm-up); the timed call
            # re-runs the same inputs for a steady-state reading and
            # its result is discarded, so timed and untimed runs
            # produce identical images (tests/test_diffusion.py)
            y = self._step(x, t_now, t_next)
            y.block_until_ready()
            t0 = time.perf_counter()
            self._step(x, t_now, t_next).block_until_ready()
            dt = time.perf_counter() - t0
            x = y
        else:
            x = self._step(x, t_now, t_next)
        for i, k in enumerate(ks):
            latents[k] = x[i]
        return dt

    def measure_delay_curve(self, key, batch_sizes=range(1, 17),
                            reps: int = 3) -> List[Tuple[int, float]]:
        """Fig. 1a measurement: steady-state per-step delay vs batch size."""
        cfg = self.cfg
        out = []
        for X in batch_sizes:
            x = jax.random.normal(key, (X, cfg.image_size, cfg.image_size,
                                        cfg.in_channels), jnp.float32)
            t = jnp.full((X,), self.T_train // 2, jnp.int32)
            tn = jnp.full((X,), self.T_train // 2 - 1, jnp.int32)
            self._step(x, t, tn).block_until_ready()   # compile
            best = float("inf")
            for _ in range(reps):
                t0 = time.perf_counter()
                self._step(x, t, tn).block_until_ready()
                best = min(best, time.perf_counter() - t0)
            out.append((int(X), best))
        return out


class DenoiseSession:
    """One plan execution, one batch at a time (the diffusion entry of
    the EXECUTORS registry — see ``repro.api.execution``).

    Latents are seeded per service from ``jax.random.split(key)`` in
    sorted-id order (identical to the one-shot ``run``), and each
    service carries its *remaining* DDIM timesteps.  ``retarget`` swaps
    those remaining timesteps for a fresh evenly-spaced chain when a
    mid-flight replan changes a service's total step count; services
    retired at zero steps keep their noise latent untouched and are
    never batched.
    """

    def __init__(self, executor: BatchDenoisingExecutor, plan: BatchPlan,
                 key):
        self.executor = executor
        cfg = executor.cfg
        shape = (cfg.image_size, cfg.image_size, cfg.in_channels)
        ids = sorted(plan.steps_completed)
        keys = jax.random.split(key, max(len(ids), 1))
        self.latents = {k: jax.random.normal(kk, shape, jnp.float32)
                        for k, kk in zip(ids, keys)}
        self.steps_done: Dict[int, int] = {k: 0 for k in ids}
        # remaining timesteps, next-to-run first; [] = done denoising
        self._remaining: Dict[int, List[int]] = {
            k: list(ddim.ddim_timesteps(T, executor.T_train)) if T > 0
            else []
            for k, T in plan.steps_completed.items()}

    def run_batch(self, ks: List[int], timed: bool = False) -> float:
        """Advance each service in ``ks`` by one step of its remaining
        schedule, in one batched U-Net call.  Returns the measured
        wall-clock seconds when ``timed`` (0.0 otherwise)."""
        schedule = {}
        for k in ks:
            rem = self._remaining[k]
            if not rem:
                raise ValueError(
                    f"service {k} has no remaining denoising steps")
            schedule[k] = (rem[0], rem[1] if len(rem) > 1 else -1)
        dt = self.executor.step_batch(self.latents, schedule, list(ks),
                                      timed)
        for k in ks:
            self._remaining[k].pop(0)
            self.steps_done[k] += 1
        return dt

    def retarget(self, totals: Dict[int, int]) -> None:
        """Re-aim services at new TOTAL step counts (executed steps
        included — the no-resurrection crediting of ``_ServerTrack``).
        A total equal to ``steps_done`` retires the service where it
        stands; a total below it, or new steps for a fully denoised
        chain, is a resurrection and raises."""
        for k, total in totals.items():
            done = self.steps_done[k]
            extra = int(total) - done
            if extra < 0:
                raise ValueError(
                    f"service {k}: retarget total {total} < "
                    f"{done} steps already executed")
            if extra == 0:
                self._remaining[k] = []
            elif done == 0:
                self._remaining[k] = list(
                    ddim.ddim_timesteps(extra, self.executor.T_train))
            elif not self._remaining[k]:
                raise ValueError(
                    f"service {k} already fully denoised; cannot "
                    f"schedule {extra} more steps")
            else:
                self._remaining[k] = list(ddim.retarget_timesteps(
                    self._remaining[k][0], extra))

    def finish(self) -> Dict[int, np.ndarray]:
        """Final images (zero-step services: their untouched latent)."""
        return {k: np.asarray(v) for k, v in self.latents.items()}
