"""Batch-denoising executor: runs a BatchPlan against a real DDIM U-Net.

Each service k ends the plan with T_k steps; its DDIM schedule is the
evenly-spaced T_k-step subsequence.  Batch n gathers the current latents
of its packed services (which sit at *different* step indices of
*different* schedules), advances them with ONE batched U-Net call using
per-sample timesteps, and scatters the results back — this is exactly the
parallelism the paper's Fig. 1a measures.

Two execution engines share the ``DenoiseSession`` interface:

  * ``"dict"`` (default) — latents live in a per-service Python dict;
    each batch stacks/scatters through host round-trips.  Bit-exact
    per-row reference.
  * ``"bucketed"`` (``repro.diffusion.bucketed``) — all K latents live
    in one device-resident pool; batches run through power-of-two
    padded gather→step→scatter programs with donated buffers, and
    stable plan phases fuse into ``lax.scan`` megasteps.

Step programs are AOT-compiled (``jit(f).lower(...).compile()``) and
cached on the executor in ``_programs``; compile wall-clock is recorded
in ``compile_log`` separately from execution, so timed readings are
steady-state by construction — ``timed`` mode runs the U-Net exactly
once per batch (the pre-PR-10 path ran it twice and discarded one).

Also the measurement rig for refitting the delay model (Fig. 1a):
``timed`` mode records per-batch wall-clock vs batch size, and
``measure_delay_curve`` sweeps batch sizes without paying one compile
per size on the bucketed engine.
"""

from __future__ import annotations

import time
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.ddim_cifar10 import UNetConfig
from repro.core.execution import EXEC_ENGINES, exec_engine_default
from repro.core.plan import BatchPlan
from repro.diffusion import ddim, unet


class BatchDenoisingExecutor:
    def __init__(self, cfg: UNetConfig, params,
                 num_train_timesteps: Optional[int] = None,
                 exec_engine: Optional[str] = None):
        self.cfg = cfg
        self.params = params
        self.T_train = num_train_timesteps or cfg.num_train_timesteps
        if exec_engine is not None and exec_engine not in EXEC_ENGINES:
            raise ValueError(f"unknown exec_engine {exec_engine!r}; "
                             f"expected one of {EXEC_ENGINES}")
        self.exec_engine = exec_engine
        # AOT-compiled step programs, keyed by (kind, *static shape info).
        # Compiling via lower().compile() keeps compilation OUT of the
        # execution path: the first timed call through a program is
        # already warm, so per-bucket warm-up state is simply "is the
        # key present here".
        self._programs: Dict[tuple, object] = {}
        # [(program key, compile seconds)] in compile order — the
        # per-bucket compile columns the e2e suite reports so gated
        # speedups exclude cold compiles
        self.compile_log: List[Tuple[tuple, float]] = []
        # compile entries added by the most recent measure_delay_curve
        self.last_compile_log: List[Tuple[tuple, float]] = []
        # total jitted step-program executions (all engines, all
        # sessions) — the regression counter proving timed mode no
        # longer double-runs the U-Net
        self.dispatches = 0

    def eps_fn(self, x, t):
        return unet.forward(self.cfg, self.params, x, t)

    def step_fn(self, x, t_now, t_next):
        """One batched DDIM step with per-sample timesteps — the
        function every engine's programs are built from."""
        return ddim.ddim_step(self.eps_fn, x, t_now, t_next,
                              self.T_train)

    def resolve_engine(self, exec_engine: Optional[str] = None) -> str:
        """Call-site override > constructor knob > process default."""
        eng = exec_engine or self.exec_engine or exec_engine_default()
        if eng not in EXEC_ENGINES:
            raise ValueError(f"unknown exec_engine {eng!r}; "
                             f"expected one of {EXEC_ENGINES}")
        return eng

    def program(self, key: tuple, build, example_args,
                donate: tuple = ()):
        """AOT-compiled executable for ``key``, compiling (and logging
        compile wall-clock) on first use.  ``lower()`` only traces —
        example args are never executed or donated at compile time —
        so fetching a program is always side-effect-free."""
        prog = self._programs.get(key)
        if prog is None:
            t0 = time.perf_counter()
            prog = jax.jit(build, donate_argnums=donate) \
                .lower(*example_args).compile()
            self.compile_log.append((key, time.perf_counter() - t0))
            self._programs[key] = prog
        return prog

    def open_session(self, plan: BatchPlan, key,
                     exec_engine: Optional[str] = None
                     ) -> "DenoiseSession":
        """Stepwise execution handle for the EXECUTORS registry: batches
        are driven one ``run_batch`` call at a time so a closed loop
        (``repro.core.execution``) can observe wall-clock and retarget
        remaining schedules between batches."""
        eng = self.resolve_engine(exec_engine)
        if eng == "bucketed":
            # imported lazily: bucketed.py subclasses DenoiseSession
            from repro.diffusion.bucketed import BucketedDenoiseSession
            return BucketedDenoiseSession(self, plan, key)
        return DenoiseSession(self, plan, key)

    def run(self, plan: BatchPlan, key, timed: bool = False,
            exec_engine: Optional[str] = None
            ) -> Tuple[Dict[int, np.ndarray], List]:
        """Execute the plan.  Returns ({service: final image}, timings).

        timings: list of (batch_size, seconds) when timed=True.
        Zero-step services (the planner retired them) are never batched;
        their latent comes back untouched.  Untimed runs go through
        ``run_plan`` so the bucketed engine can fuse stable plan phases
        into scan megasteps; timed runs stay stepwise (one reading per
        batch).
        """
        sess = self.open_session(plan, key, exec_engine)
        batches = [[k for k, _ in batch] for batch in plan.batches]
        timings = []
        if timed:
            for ks in batches:
                timings.append((len(ks), sess.run_batch(ks, timed=True)))
        else:
            sess.run_plan(batches)
        return sess.finish(), timings

    def step_batch(self, latents: Dict[int, "jax.Array"],
                   schedule: Dict[int, Tuple[int, int]],
                   ks: List[int], timed: bool) -> float:
        """Advance ``ks`` one DDIM step in ONE batched U-Net call,
        scattering results back into ``latents``.  Returns measured
        seconds when ``timed`` (0.0 otherwise).

        The program is AOT-compiled per exact batch size (the dict
        engine is the bit-exact unpadded reference), so the timed call
        is the real step — executed once, never re-run."""
        x = jnp.stack([latents[k] for k in ks])
        t_now = jnp.array([schedule[k][0] for k in ks], jnp.int32)
        t_next = jnp.array([schedule[k][1] for k in ks], jnp.int32)
        prog = self.program(("dstep", len(ks)), self.step_fn,
                            (x, t_now, t_next))
        dt = 0.0
        if timed:
            t0 = time.perf_counter()
            x = prog(x, t_now, t_next)
            x.block_until_ready()
            dt = time.perf_counter() - t0
        else:
            x = prog(x, t_now, t_next)
        self.dispatches += 1
        for i, k in enumerate(ks):
            latents[k] = x[i]
        return dt

    def measure_delay_curve(self, key, batch_sizes=range(1, 17),
                            reps: int = 3,
                            exec_engine: Optional[str] = None
                            ) -> List[Tuple[int, float]]:
        """Fig. 1a measurement: steady-state per-step delay vs batch
        size.  Compile time never lands in the readings (programs are
        AOT-compiled first) and is reported separately in
        ``last_compile_log``.  On the bucketed engine sizes share
        power-of-two bucket programs — sweeping 1..16 compiles 4
        programs, not 16 — and the reading for size X is honestly the
        padded bucket's cost, because that IS what the engine pays."""
        eng = self.resolve_engine(exec_engine)
        clog0 = len(self.compile_log)
        if eng == "bucketed":
            from repro.diffusion.bucketed import measure_bucketed_curve
            out = measure_bucketed_curve(self, key, batch_sizes, reps)
        else:
            cfg = self.cfg
            out = []
            for X in batch_sizes:
                x = jax.random.normal(
                    key, (X, cfg.image_size, cfg.image_size,
                          cfg.in_channels), jnp.float32)
                t = jnp.full((X,), self.T_train // 2, jnp.int32)
                tn = jnp.full((X,), self.T_train // 2 - 1, jnp.int32)
                prog = self.program(("dstep", int(X)), self.step_fn,
                                    (x, t, tn))
                prog(x, t, tn).block_until_ready()   # warm dispatch
                best = float("inf")
                for _ in range(reps):
                    t0 = time.perf_counter()
                    prog(x, t, tn).block_until_ready()
                    best = min(best, time.perf_counter() - t0)
                out.append((int(X), best))
        self.last_compile_log = self.compile_log[clog0:]
        return out


class DenoiseSession:
    """One plan execution, one batch at a time (the diffusion entry of
    the EXECUTORS registry — see ``repro.api.execution``).

    Latents are seeded per service from ``jax.random.split(key)`` in
    sorted-id order (identical to the one-shot ``run``), and each
    service carries its *remaining* DDIM timesteps.  ``retarget`` swaps
    those remaining timesteps for a fresh evenly-spaced chain when a
    mid-flight replan changes a service's total step count; services
    retired at zero steps keep their noise latent untouched and are
    never batched.
    """

    def __init__(self, executor: BatchDenoisingExecutor, plan: BatchPlan,
                 key):
        self.executor = executor
        cfg = executor.cfg
        shape = (cfg.image_size, cfg.image_size, cfg.in_channels)
        ids = sorted(plan.steps_completed)
        keys = jax.random.split(key, max(len(ids), 1))
        self.latents = {k: jax.random.normal(kk, shape, jnp.float32)
                        for k, kk in zip(ids, keys)}
        self.steps_done: Dict[int, int] = {k: 0 for k in ids}
        # remaining timesteps, next-to-run first; [] = done denoising
        self._remaining: Dict[int, List[int]] = {
            k: list(ddim.ddim_timesteps(T, executor.T_train)) if T > 0
            else []
            for k, T in plan.steps_completed.items()}
        # telemetry: dispatches per exact batch size, and the compile
        # log watermark so telemetry() reports only THIS session's
        # compiles (a warm second session reports zero)
        self._dispatch: Dict[int, int] = {}
        self._clog0 = len(executor.compile_log)

    def run_batch(self, ks: List[int], timed: bool = False) -> float:
        """Advance each service in ``ks`` by one step of its remaining
        schedule, in one batched U-Net call.  Returns the measured
        wall-clock seconds when ``timed`` (0.0 otherwise)."""
        schedule = {}
        for k in ks:
            rem = self._remaining[k]
            if not rem:
                raise ValueError(
                    f"service {k} has no remaining denoising steps")
            schedule[k] = (rem[0], rem[1] if len(rem) > 1 else -1)
        dt = self.executor.step_batch(self.latents, schedule, list(ks),
                                      timed)
        self._dispatch[len(ks)] = self._dispatch.get(len(ks), 0) + 1
        for k in ks:
            self._remaining[k].pop(0)
            self.steps_done[k] += 1
        return dt

    def run_plan(self, batches: List[List[int]]) -> None:
        """Execute a whole list of batches untimed.  The dict engine
        just loops ``run_batch``; the bucketed engine overrides this to
        fuse stable phases into scan megasteps."""
        for ks in batches:
            self.run_batch(ks)

    def retarget(self, totals: Dict[int, int]) -> None:
        """Re-aim services at new TOTAL step counts (executed steps
        included — the no-resurrection crediting of ``_ServerTrack``).
        A total equal to ``steps_done`` retires the service where it
        stands; a total below it, or new steps for a fully denoised
        chain, is a resurrection and raises."""
        for k, total in totals.items():
            done = self.steps_done[k]
            extra = int(total) - done
            if extra < 0:
                raise ValueError(
                    f"service {k}: retarget total {total} < "
                    f"{done} steps already executed")
            if extra == 0:
                self._remaining[k] = []
            elif done == 0:
                self._remaining[k] = list(
                    ddim.ddim_timesteps(extra, self.executor.T_train))
            elif not self._remaining[k]:
                raise ValueError(
                    f"service {k} already fully denoised; cannot "
                    f"schedule {extra} more steps")
            else:
                self._remaining[k] = list(ddim.retarget_timesteps(
                    self._remaining[k][0], extra))

    def telemetry(self) -> dict:
        """Engine + dispatch/compile counters for this session (surfaced
        through ``ExecutionResult.to_dict()['telemetry']['session']``)."""
        mine = self.executor.compile_log[self._clog0:]
        return {
            "exec_engine": "dict",
            "dispatches": int(sum(self._dispatch.values())),
            "by_size": {str(b): int(n)
                        for b, n in sorted(self._dispatch.items())},
            "compiles": len(mine),
            "compile_s": float(sum(s for _, s in mine)),
        }

    def finish(self) -> Dict[int, np.ndarray]:
        """Final images (zero-step services: their untouched latent)."""
        return {k: np.asarray(v) for k, v in self.latents.items()}
