"""Pallas TPU kernels for the framework's compute hot spots.

Each kernel package ships three files:
  kernel.py  -- pl.pallas_call + explicit BlockSpec VMEM tiling (TPU target)
  ref.py     -- pure-jnp oracle (also the XLA path used on CPU / dry-run)
  ops.py     -- jit'd dispatch wrapper: pallas on TPU (or interpret=True
                when forced via REPRO_FORCE_PALLAS=1), ref otherwise

Kernels: rmsnorm, flash_attention (prefill/train), decode_attention
(flash-decode over a KV cache), ssd_scan (Mamba2/mLSTM chunk recurrence),
groupnorm_silu (diffusion U-Net hot spot).
"""

import os


def use_pallas(default: bool = False) -> str:
    """Dispatch mode: 'tpu' on real TPUs, 'interpret' when forced via
    REPRO_FORCE_PALLAS=1 (tests), else 'ref'."""
    import jax
    if os.environ.get("REPRO_FORCE_PALLAS") == "1":
        return "interpret"
    try:
        if jax.devices()[0].platform == "tpu":
            return "tpu"
    except RuntimeError:
        pass
    return "tpu" if default else "ref"
