"""Pure-jnp oracle for fused GroupNorm + SiLU (NHWC)."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def groupnorm_silu_ref(x, scale, bias, num_groups: int, eps: float = 1e-6):
    B, H, W, C = x.shape
    G = min(num_groups, C)
    while C % G:
        G -= 1
    xg = x.reshape(B, H, W, G, C // G).astype(jnp.float32)
    mu = xg.mean(axis=(1, 2, 4), keepdims=True)
    var = xg.var(axis=(1, 2, 4), keepdims=True)
    out = (xg - mu) * jax.lax.rsqrt(var + eps)
    out = out.reshape(B, H, W, C) * scale + bias
    return jax.nn.silu(out).astype(x.dtype)
