"""Dispatching wrapper for fused GroupNorm + SiLU."""

from __future__ import annotations

from repro.kernels import use_pallas
from repro.kernels.groupnorm_silu.kernel import groupnorm_silu_pallas
from repro.kernels.groupnorm_silu.ref import groupnorm_silu_ref


def groupnorm_silu(x, scale, bias, num_groups: int, eps: float = 1e-6):
    mode = use_pallas()
    if mode == "tpu":
        return groupnorm_silu_pallas(x, scale, bias, num_groups, eps)
    if mode == "interpret":
        return groupnorm_silu_pallas(x, scale, bias, num_groups, eps,
                                     interpret=True)
    return groupnorm_silu_ref(x, scale, bias, num_groups, eps)
