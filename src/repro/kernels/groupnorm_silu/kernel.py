"""Fused GroupNorm+SiLU Pallas TPU kernel — the diffusion U-Net hot spot.

The U-Net applies GN->SiLU->conv twice per residual block; unfused, each
GN materializes mean/var intermediates and a normalized tensor in HBM.
Fused: one VMEM pass per image computes group statistics and writes the
activated output directly.

Tiling: grid = (B,), block = one full image (H, W, C).  At CIFAR scale a
(32, 32, 256) f32 image is 1 MB — comfortably VMEM-resident; for larger
resolutions the grid would add an H-split with a two-pass Welford, which
this kernel documents as its scaling path (not needed for the paper's
32x32 workload).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, s_ref, b_ref, o_ref, *, groups: int, eps: float):
    x = x_ref[0].astype(jnp.float32)                # (H, W, C)
    H, W, C = x.shape
    cg = C // groups
    xg = x.reshape(H * W, groups, cg)
    mu = xg.mean(axis=(0, 2), keepdims=True)
    var = ((xg - mu) ** 2).mean(axis=(0, 2), keepdims=True)
    xn = (xg - mu) * jax.lax.rsqrt(var + eps)
    out = xn.reshape(H, W, C) * s_ref[...] + b_ref[...]
    o_ref[0] = (out * jax.nn.sigmoid(out)).astype(o_ref.dtype)


def groupnorm_silu_pallas(x, scale, bias, num_groups: int,
                          eps: float = 1e-6, interpret: bool = False):
    B, H, W, C = x.shape
    G = min(num_groups, C)
    while C % G:
        G -= 1
    return pl.pallas_call(
        functools.partial(_kernel, groups=G, eps=eps),
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        grid=(B,),
        in_specs=[
            pl.BlockSpec((1, H, W, C), lambda b: (b, 0, 0, 0)),
            pl.BlockSpec((C,), lambda b: (0,)),
            pl.BlockSpec((C,), lambda b: (0,)),
        ],
        out_specs=pl.BlockSpec((1, H, W, C), lambda b: (b, 0, 0, 0)),
        interpret=interpret,
    )(x, scale, bias)
