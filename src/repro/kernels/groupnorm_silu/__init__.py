from repro.kernels.groupnorm_silu.ops import groupnorm_silu  # noqa: F401
