"""Dispatching wrapper for the SSD chunk scan."""

from __future__ import annotations

from repro.kernels import use_pallas
from repro.kernels.ssd_scan.kernel import ssd_scan_pallas


def ssd_scan(x, a, bmat, cmat, h0, *, chunk: int = 128):
    mode = use_pallas()
    if mode == "tpu":
        return ssd_scan_pallas(x, a, bmat, cmat, h0, chunk=chunk)
    if mode == "interpret":
        return ssd_scan_pallas(x, a, bmat, cmat, h0,
                               chunk=min(chunk, 32), interpret=True)
    # XLA path: the chunked jnp implementation in repro.models.ssm
    from repro.models.ssm import ssd_chunked
    return ssd_chunked(x, a, bmat, cmat, h0, chunk=chunk)
