"""Pure-jnp oracle for the SSD scan: naive sequential recurrence.

    h_t = exp(a_t) h_{t-1} + x_t (outer) B_t ;  y_t = C_t . h_t

Shapes: x (B,S,H,P), a (B,S,H) log-decay, b/c (B,S,N) shared across heads.
Slow (lax.scan over every step) but unambiguous.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def ssd_scan_ref(x, a, bmat, cmat, h0):
    B, S, H, P = x.shape

    def step(h, t):
        xt = x[:, t].astype(jnp.float32)             # (B,H,P)
        at = a[:, t].astype(jnp.float32)             # (B,H)
        bt = bmat[:, t].astype(jnp.float32)          # (B,N)
        ct = cmat[:, t].astype(jnp.float32)
        h = h * jnp.exp(at)[..., None, None] \
            + jnp.einsum("bhp,bn->bhpn", xt, bt)
        y = jnp.einsum("bn,bhpn->bhp", ct, h)
        return h, y

    h, ys = jax.lax.scan(step, h0.astype(jnp.float32), jnp.arange(S))
    return jnp.moveaxis(ys, 0, 1).astype(x.dtype), h
