"""Chunked SSD scan Pallas TPU kernel (Mamba2 / mLSTM backbone).

Grid = (B, H, S/Q): chunk axis innermost/sequential; the running state
(P x N per head) lives in VMEM scratch across chunk iterations.  Within a
chunk the masked-decay quadratic form runs on the MXU:

    y_off  = (C h_in^T) * e^{cum}                    (Q,P)
    y_diag = ((C B^T) o decay) @ (x * dt)            (Q,Q)@(Q,P)
    h_out  = e^{total} h_in + (w * B)^T @ x          (N,Q)@(Q,P)

Q defaults to 128 (MXU-aligned); VMEM per (b,h) program:
Q*(P+2N)*4B + P*N*4B  ~=  a few hundred KB for the assigned configs
(zamba2: P=64, N=64; xlstm: P=N=384).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(x_ref, a_ref, b_ref, c_ref, h0_ref, y_ref, hout_ref, h_scr, *,
            Q: int, nc: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        h_scr[...] = h0_ref[0, 0].astype(jnp.float32)

    x = x_ref[0, :, 0].astype(jnp.float32)          # (Q, P)
    a = a_ref[0, :, 0].astype(jnp.float32)          # (Q,)
    b = b_ref[0].astype(jnp.float32)                # (Q, N)
    c = c_ref[0].astype(jnp.float32)                # (Q, N)

    cum = jnp.cumsum(a)                             # (Q,) inclusive
    total = cum[-1]
    h = h_scr[...]                                  # (P, N)

    # off-diagonal: incoming state
    y_off = jax.lax.dot_general(
        c, h, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)         # (Q, P)
    y_off = y_off * jnp.exp(cum)[:, None]

    # intra-chunk quadratic with masked decays
    scores = jax.lax.dot_general(
        c, b, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)         # (Q, Q)
    logdec = cum[:, None] - cum[None, :]
    iq = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 0)
    ik = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 1)
    logdec = jnp.where(iq >= ik, logdec, NEG_INF)
    y_diag = jax.lax.dot_general(
        scores * jnp.exp(logdec), x, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)         # (Q, P)
    y_ref[0, :, 0] = (y_off + y_diag).astype(y_ref.dtype)

    # state update
    w = jnp.exp(total - cum)                        # (Q,)
    h_new = h * jnp.exp(total) + jax.lax.dot_general(
        x, b * w[:, None], (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)         # (P, N)
    h_scr[...] = h_new

    @pl.when(ci == nc - 1)
    def _finalize():
        hout_ref[0, 0] = h_new


def ssd_scan_pallas(x, a, bmat, cmat, h0, *, chunk: int = 128,
                    interpret: bool = False):
    """x: (B,S,H,P); a: (B,S,H); bmat/cmat: (B,S,N); h0: (B,H,P,N).
    Returns y (B,S,H,P), h_final (B,H,P,N)."""
    B, S, H, P = x.shape
    N = bmat.shape[-1]
    Q = min(chunk, S)
    assert S % Q == 0
    nc = S // Q

    y, h_fin = pl.pallas_call(
        functools.partial(_kernel, Q=Q, nc=nc),
        out_shape=(jax.ShapeDtypeStruct((B, S, H, P), x.dtype),
                   jax.ShapeDtypeStruct((B, H, P, N), jnp.float32)),
        grid=(B, H, nc),
        in_specs=[
            pl.BlockSpec((1, Q, 1, P), lambda b, h, ci: (b, ci, h, 0)),
            pl.BlockSpec((1, Q, 1), lambda b, h, ci: (b, ci, h)),
            pl.BlockSpec((1, Q, N), lambda b, h, ci: (b, ci, 0)),
            pl.BlockSpec((1, Q, N), lambda b, h, ci: (b, ci, 0)),
            pl.BlockSpec((1, 1, P, N), lambda b, h, ci: (b, h, 0, 0)),
        ],
        out_specs=(
            pl.BlockSpec((1, Q, 1, P), lambda b, h, ci: (b, ci, h, 0)),
            pl.BlockSpec((1, 1, P, N), lambda b, h, ci: (b, h, 0, 0)),
        ),
        scratch_shapes=[pltpu.VMEM((P, N), jnp.float32)],
        interpret=interpret,
    )(x, a, bmat, cmat, h0)
    return y, h_fin
