"""Dispatching wrapper for decode attention."""

from __future__ import annotations

from repro.kernels import use_pallas
from repro.kernels.decode_attention.kernel import decode_attention_pallas
from repro.kernels.decode_attention.ref import decode_attention_ref


def decode_attention(q, k_cache, v_cache, cur_len, *, window: int = 0):
    mode = use_pallas()
    if mode == "tpu":
        return decode_attention_pallas(q, k_cache, v_cache, cur_len,
                                       window=window)
    if mode == "interpret":
        bs = min(128, k_cache.shape[1])
        return decode_attention_pallas(q, k_cache, v_cache, cur_len,
                                       window=window, bs=bs, interpret=True)
    return decode_attention_ref(q, k_cache, v_cache, cur_len, window=window)
