"""Pure-jnp oracle for single-token decode attention over a KV cache."""

from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def decode_attention_ref(q, k_cache, v_cache, cur_len, *, window: int = 0):
    """q: (B, 1, H, D); caches: (B, S, KV, D); cur_len: (B,) valid entries.
    Masks positions >= cur_len and (optionally) < cur_len - window."""
    B, _, H, D = q.shape
    _, S, KV, _ = k_cache.shape
    G = H // KV
    cur = jnp.asarray(cur_len)
    if cur.ndim == 0:
        cur = jnp.full((B,), cur)
    qr = q.reshape(B, KV, G, D).astype(jnp.float32)
    s = jnp.einsum("bhgd,bshd->bhgs", qr,
                   k_cache.astype(jnp.float32)) / jnp.sqrt(D)
    pos = jnp.arange(S)
    valid = pos[None] < cur[:, None]
    if window:
        valid &= pos[None] >= (cur[:, None] - window)
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgs,bshd->bhgd", p, v_cache.astype(jnp.float32))
    return o.reshape(B, 1, H, D).astype(q.dtype)
