"""Flash-decode Pallas TPU kernel: one query token vs. a long KV cache.

Decode attention is HBM-bandwidth-bound (the cache is read once per step,
arithmetic intensity ~ O(1) FLOPs/byte), so the tiling goal is purely to
stream the cache through VMEM in large sequential blocks:

  grid = (B, KV, S/bs); the cache-sequence axis is innermost/sequential,
  the online-softmax state (m, l, acc) lives in VMEM scratch across those
  iterations.  The G query heads of a KV group ride in one (G, D) tile so
  each cache block is read once for all of them (GQA's point).  Blocks
  wholly outside [cur_len - window, cur_len) are skipped with @pl.when —
  with a sliding window this turns O(S) traffic into O(window).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(cur_ref, q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
            bs: int, scale: float, window: int, ns: int):
    b = pl.program_id(0)
    si = pl.program_id(2)

    @pl.when(si == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    cur = cur_ref[b]
    s_start = si * bs
    needed = s_start < cur
    if window:
        needed = jnp.logical_and(needed, s_start + bs - 1 >= cur - window)

    @pl.when(needed)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)              # (G, D)
        k = k_ref[0, 0].astype(jnp.float32)              # (bs, D)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale  # (G, bs)
        pos = s_start + jax.lax.broadcasted_iota(jnp.int32, (1, bs), 1)
        valid = pos < cur
        if window:
            valid = jnp.logical_and(valid, pos >= cur - window)
        s = jnp.where(valid, s, NEG_INF)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)
        l_scr[...] = l_scr[...] * alpha + p.sum(axis=-1, keepdims=True)
        acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_scr[...] = m_new

    @pl.when(si == ns - 1)
    def _finalize():
        o_ref[0, 0] = (acc_scr[...]
                       / jnp.maximum(l_scr[...], 1e-30)).astype(o_ref.dtype)


def decode_attention_pallas(q, k_cache, v_cache, cur_len, *,
                            window: int = 0, bs: int = 512,
                            interpret: bool = False):
    """q: (B, 1, H, D); caches: (B, S, KV, D); cur_len: (B,) int32."""
    B, _, H, D = q.shape
    _, S, KV, _ = k_cache.shape
    G = H // KV
    bs = min(bs, S)
    assert S % bs == 0
    ns = S // bs
    scale = 1.0 / (D ** 0.5)
    cur = jnp.asarray(cur_len, jnp.int32)
    if cur.ndim == 0:
        cur = jnp.full((B,), cur, jnp.int32)

    qt = q.reshape(B, KV, G, D)                          # (B, KV, G, D)
    kt = jnp.swapaxes(k_cache, 1, 2)                     # (B, KV, S, D)
    vt = jnp.swapaxes(v_cache, 1, 2)

    out = pl.pallas_call(
        functools.partial(_kernel, bs=bs, scale=scale, window=window,
                          ns=ns),
        out_shape=jax.ShapeDtypeStruct((B, KV, G, D), q.dtype),
        grid=(B, KV, ns),
        in_specs=[
            pl.BlockSpec(memory_space=pl.ANY),           # cur_len (SMEM-ish)
            pl.BlockSpec((1, 1, G, D), lambda b, h, si: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, bs, D), lambda b, h, si: (b, h, si, 0)),
            pl.BlockSpec((1, 1, bs, D), lambda b, h, si: (b, h, si, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, D), lambda b, h, si: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, D), jnp.float32),
        ],
        interpret=interpret,
    )(cur, qt, kt, vt)
    return out.reshape(B, 1, H, D)
