"""Fused RMSNorm Pallas kernel.

Tiling: rows are processed in blocks of `block_rows`; the full feature
dimension stays resident in VMEM (d <= 8192 * 4B = 32 KB per row — well
inside the ~16 MB VMEM budget at the default 256-row block).  One pass:
load tile -> mean of squares -> rsqrt -> scale -> store; never
materializes the normalized intermediate in HBM (the fusion the XLA path
does not guarantee across dtype boundaries).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, s_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    o_ref[...] = (x * jax.lax.rsqrt(var + eps)
                  * s_ref[...].astype(jnp.float32)).astype(o_ref.dtype)


def rmsnorm_pallas(x, scale, eps: float = 1e-6, block_rows: int = 256,
                   interpret: bool = False):
    """x: (..., d); scale: (d,)."""
    orig_shape = x.shape
    d = x.shape[-1]
    rows = 1
    for s in x.shape[:-1]:
        rows *= s
    x2 = x.reshape(rows, d)
    block_rows = min(block_rows, rows)
    # pad rows to a multiple of the block
    pad = (-rows) % block_rows
    if pad:
        x2 = jnp.pad(x2, ((0, pad), (0, 0)))
    grid = (x2.shape[0] // block_rows,)

    out = pl.pallas_call(
        functools.partial(_kernel, eps=eps),
        out_shape=jax.ShapeDtypeStruct(x2.shape, x.dtype),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
        interpret=interpret,
    )(x2, scale)
    if pad:
        out = out[:rows]
    return out.reshape(orig_shape)
