"""Dispatching wrapper for fused RMSNorm."""

from __future__ import annotations


from repro.kernels import use_pallas
from repro.kernels.rmsnorm.kernel import rmsnorm_pallas
from repro.kernels.rmsnorm.ref import rmsnorm_ref


def rmsnorm(x, scale, eps: float = 1e-6):
    mode = use_pallas()
    if mode == "tpu":
        return rmsnorm_pallas(x, scale, eps)
    if mode == "interpret":
        return rmsnorm_pallas(x, scale, eps, interpret=True)
    return rmsnorm_ref(x, scale, eps)
