"""Flash attention (forward) Pallas TPU kernel — GQA, causal, windowed.

Tiling (DESIGN.md §3: HBM->VMEM->MXU):
  grid = (B, H, Sq/bq, Skv/bk); the kv axis is innermost, so on TPU the
  grid executes kv blocks sequentially per (b, h, iq) and the VMEM
  scratch accumulators (m, l, acc) implement the online softmax across
  those iterations.  Block shapes are MXU-aligned: bq x D and bk x D
  tiles with D padded to >= 128 by the caller (all assigned archs have
  head_dim in {64, 128, 192, 384}; 64 still maps onto the MXU via lane
  packing — we keep D whole in VMEM).

  Causal masking skips *entire* kv blocks past the diagonal with
  @pl.when (no wasted MXU work — this is the "causal chunk skip" the
  pure-XLA chunked_attention path lacks; see EXPERIMENTS.md §Perf).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
            bq: int, bk: int, scale: float, causal: bool, window: int,
            q_offset: int, nk: int):
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_start = q_offset + iq * bq
    k_start = ik * bk

    # whole-block causal/window skip
    needed = jnp.bool_(True)
    if causal:
        needed &= k_start <= q_start + bq - 1
    if window:
        needed &= (k_start + bk - 1) >= (q_start - window + 1)

    @pl.when(needed)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)            # (bq, D)
        k = k_ref[0, 0].astype(jnp.float32)            # (bk, D)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale  # (bq, bk)
        if causal or window:
            qpos = q_start + jax.lax.broadcasted_iota(jnp.int32,
                                                      (bq, bk), 0)
            kpos = k_start + jax.lax.broadcasted_iota(jnp.int32,
                                                      (bq, bk), 1)
            mask = jnp.ones((bq, bk), jnp.bool_)
            if causal:
                mask &= qpos >= kpos
            if window:
                mask &= (qpos - kpos) < window
            s = jnp.where(mask, s, NEG_INF)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)
        l_scr[...] = l_scr[...] * alpha + p.sum(axis=-1, keepdims=True)
        acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_scr[...] = m_new

    @pl.when(ik == nk - 1)
    def _finalize():
        o_ref[0, 0] = (acc_scr[...]
                       / jnp.maximum(l_scr[...], 1e-30)).astype(o_ref.dtype)


def flash_attention_pallas(q, k, v, *, causal: bool = True,
                           window: int = 0, bq: int = 128, bk: int = 128,
                           interpret: bool = False):
    """q: (B, Sq, H, D); k, v: (B, Skv, KV, D) -> (B, Sq, H, D)."""
    B, Sq, H, D = q.shape
    _, Skv, KV, _ = k.shape
    G = H // KV
    bq = min(bq, Sq)
    bk = min(bk, Skv)
    assert Sq % bq == 0 and Skv % bk == 0
    nq, nk = Sq // bq, Skv // bk
    scale = 1.0 / (D ** 0.5)
    q_offset = Skv - Sq                      # align sequence ends

    # layout: (B, H, S, D) blocks
    qt = jnp.swapaxes(q, 1, 2)               # (B, H, Sq, D)
    kt = jnp.swapaxes(k, 1, 2)               # (B, KV, Skv, D)
    vt = jnp.swapaxes(v, 1, 2)

    out = pl.pallas_call(
        functools.partial(_kernel, bq=bq, bk=bk, scale=scale,
                          causal=causal, window=window,
                          q_offset=q_offset, nk=nk),
        out_shape=jax.ShapeDtypeStruct((B, H, Sq, D), q.dtype),
        grid=(B, H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, bq, D), lambda b, h, iq, ik: (b, h, iq, 0)),
            pl.BlockSpec((1, 1, bk, D),
                         lambda b, h, iq, ik, G=G: (b, h // G, ik, 0)),
            pl.BlockSpec((1, 1, bk, D),
                         lambda b, h, iq, ik, G=G: (b, h // G, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, D),
                               lambda b, h, iq, ik: (b, h, iq, 0)),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, D), jnp.float32),
        ],
        interpret=interpret,
    )(qt, kt, vt)
    return jnp.swapaxes(out, 1, 2)
