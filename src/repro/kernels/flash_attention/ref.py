"""Pure-jnp oracle for (GQA, causal, optionally sliding-window) attention.

Materializes the full S x S score tensor — correct but O(S^2) memory;
only for validation at test scales.  The production XLA path is
``repro.models.layers.chunked_attention`` (same math, online softmax).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def attention_ref(q, k, v, *, causal: bool = True, window: int = 0):
    """q: (B, Sq, H, D); k, v: (B, Skv, KV, D); H = KV * G."""
    B, Sq, H, D = q.shape
    _, Skv, KV, _ = k.shape
    G = H // KV
    qr = q.reshape(B, Sq, KV, G, D).astype(jnp.float32)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qr, k.astype(jnp.float32))
    s = s / jnp.sqrt(D)
    qpos = jnp.arange(Sq)[:, None] + (Skv - Sq)   # align ends (prefill)
    kpos = jnp.arange(Skv)[None, :]
    mask = jnp.ones((Sq, Skv), bool)
    if causal:
        mask &= qpos >= kpos
    if window:
        mask &= (qpos - kpos) < window
    s = jnp.where(mask[None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgqk,bkhd->bqhgd", p, v.astype(jnp.float32))
    return o.reshape(B, Sq, H, D).astype(q.dtype)
