"""Dispatching wrapper for flash attention."""

from __future__ import annotations

from repro.kernels import use_pallas
from repro.kernels.flash_attention.kernel import flash_attention_pallas
from repro.kernels.flash_attention.ref import attention_ref


def flash_attention(q, k, v, *, causal: bool = True, window: int = 0):
    mode = use_pallas()
    if mode == "tpu":
        return flash_attention_pallas(q, k, v, causal=causal, window=window)
    if mode == "interpret":
        bq = min(128, q.shape[1])
        bk = min(128, k.shape[1])
        return flash_attention_pallas(q, k, v, causal=causal, window=window,
                                      bq=bq, bk=bk, interpret=True)
    return attention_ref(q, k, v, causal=causal, window=window)
