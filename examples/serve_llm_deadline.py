"""Deadline-aware LLM serving with STACKING (the paper's technique lifted
to autoregressive decoding, DESIGN.md §4).

Serves a reduced TinyLlama with batched requests under heterogeneous
deadlines: the engine calibrates a decode-step delay model (the paper's
Fig.-1a procedure), plans token budgets with STACKING, and executes the
plan with batched decode steps.

    PYTHONPATH=src python examples/serve_llm_deadline.py
"""

import jax
import numpy as np

from repro.config import RunConfig, get_config, smoke_variant
from repro.core.baselines import greedy_batching
from repro.core.service import ServiceRequest
from repro.models import api
from repro.serving.engine import ServingEngine


def main():
    cfg = smoke_variant(get_config("tinyllama-1.1b"))
    params = api.init_model(cfg, jax.random.PRNGKey(0))
    eng = ServingEngine(cfg, params, RunConfig(), max_len=128)

    print("calibrating decode delay model (Fig. 1a procedure)...")
    dm = eng.measure_decode_delay(batch_sizes=(1, 2, 4))
    print(f"  a={dm.a * 1e3:.2f} ms/seq  b={dm.b * 1e3:.2f} ms/step")

    rng = np.random.default_rng(0)
    deadlines = [0.3, 0.5, 0.8, 1.5]
    print(f"\nsubmitting {len(deadlines)} requests, deadlines {deadlines} s")
    ids = [eng.submit(rng.integers(0, cfg.vocab_size, 12).astype(np.int32),
                      d) for d in deadlines]

    plan = eng.plan()
    plan.validate()
    print(f"STACKING plan: {plan.num_batches} decode batches; token "
          f"budgets {dict(sorted(plan.steps_completed.items()))}")

    out = eng.execute(plan)
    for rid in ids:
        toks = out[rid]
        print(f"  request {rid}: {len(toks):3d} tokens -> {toks[:10]}...")

    # vs. greedy batching at the same deadlines
    tq = eng.quality
    svcs = [ServiceRequest(id=i, deadline=d, spectral_eff=1.0)
            for i, d in enumerate(deadlines)]
    tp = {s.id: s.deadline for s in svcs}
    greedy = greedy_batching(svcs, tp, eng.delay)
    q_st = tq.mean_fid(list(plan.steps_completed.values()))
    q_gr = tq.mean_fid(list(greedy.steps_completed.values()))
    print(f"\nmean quality penalty: stacking={q_st:.2f} greedy={q_gr:.2f} "
          f"({'stacking wins' if q_st <= q_gr else 'greedy wins'})")


if __name__ == "__main__":
    main()
