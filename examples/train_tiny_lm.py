"""End-to-end training driver: train a ~1M-param reduced TinyLlama for a
few hundred steps on synthetic data, with AdamW, cosine schedule,
checkpointing, and loss reporting.

    PYTHONPATH=src python examples/train_tiny_lm.py [--steps 300]
"""

import argparse
import time


from repro.config import RunConfig, get_config, smoke_variant
from repro.training import checkpoint
from repro.training.data import DataConfig, batches
from repro.training.optimizer import AdamWConfig
from repro.training.train import train_loop


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--ckpt", default="/tmp/repro_tiny_lm.npz")
    args = ap.parse_args()

    cfg = smoke_variant(get_config(args.arch))
    print(f"arch {cfg.name}: {cfg.num_layers}L d={cfg.d_model} "
          f"H={cfg.num_heads}/{cfg.num_kv_heads} vocab={cfg.vocab_size}")

    dc = DataConfig(vocab_size=cfg.vocab_size, seq_len=64, global_batch=8,
                    seed=0)
    ocfg = AdamWConfig(lr=1e-3, warmup_steps=20, total_steps=args.steps)

    t0 = time.time()
    params, opt_state, hist = train_loop(
        cfg, RunConfig(), batches(dc), steps=args.steps, ocfg=ocfg,
        log_every=max(args.steps // 10, 1),
        callback=lambda e: print(
            f"  step {e['step']:4d}  loss {e['loss']:.4f}  "
            f"lr {e['lr']:.2e}  |g| {e['grad_norm']:.2f}"))
    dt = time.time() - t0
    print(f"\n{args.steps} steps in {dt:.1f}s "
          f"({args.steps * dc.global_batch * dc.seq_len / dt:.0f} tok/s)")
    print(f"loss: {hist[0]['loss']:.4f} -> {hist[-1]['loss']:.4f}")

    checkpoint.save(args.ckpt, {"params": params, "opt": opt_state})
    print(f"checkpoint written to {args.ckpt}")
    checkpoint.restore(args.ckpt, {"params": params,
                                   "opt": opt_state})
    print("checkpoint restore round-trip OK")


if __name__ == "__main__":
    main()
