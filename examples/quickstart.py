"""Quickstart: the paper's full pipeline on one page, via the unified
provisioner API (docs/API.md).

1. Calibrate the delay model g(X) = aX + b on this machine (Fig. 1a).
2. Build a K-service scenario with heterogeneous deadlines (Sec. IV).
3-4. One `Provisioner.run` call: allocate bandwidth (PSO, Sec. III-C),
   schedule batch denoising with STACKING (Alg. 1), validate the plan,
   and execute it on a real DDIM U-Net with mixed-step batches.
5. Compare against the paper's baselines by registry name.

    PYTHONPATH=src python examples/quickstart.py
"""

import jax

from repro.api import DiffusionWorkload, Provisioner, get_scheduler
from repro.configs.ddim_cifar10 import SMOKE
from repro.core.delay_model import DelayModel
from repro.core.quality_model import PowerLawFID
from repro.core.service import make_scenario
from repro.core.simulator import run_scheme


def main():
    key = jax.random.PRNGKey(0)

    # 1. calibrate g(X) = aX + b on this hardware --------------------------
    workload = DiffusionWorkload(cfg=SMOKE, init_seed=0)
    measured = workload.calibrate(key, batch_sizes=(1, 2, 4, 8))
    print(f"measured delay model: a={measured.a * 1e3:.2f} ms/sample, "
          f"b={measured.b * 1e3:.2f} ms")
    # paper constants (RTX-3050) for the simulation below:
    delay = DelayModel()
    quality = PowerLawFID()

    # 2. scenario -----------------------------------------------------------
    scn = make_scenario(K=8, tau_min=4.0, tau_max=12.0, seed=1)
    print(f"\n{scn.K} services, deadlines "
          f"{[round(s.deadline, 1) for s in scn.services]}")

    # 3+4. bandwidth + batch plan + execution on the real U-Net, one call ---
    prov = Provisioner(scn, workload=workload, scheduler="stacking",
                       allocator="pso", delay=delay, quality=quality,
                       allocator_kwargs=dict(num_particles=10, iters=8))
    report = prov.run(jax.random.PRNGKey(7))       # validates the plan too
    plan = report.plan
    print(f"STACKING plan: {plan.num_batches} batches, "
          f"sizes {plan.batch_sizes()[:12]}...")
    print(f"steps per service: {dict(sorted(plan.steps_completed.items()))}")
    print(f"generated {len(report.content)} images, shape "
          f"{next(iter(report.content.values())).shape}")
    print("\n" + report.sim.summary())

    # 5. baselines, by registry name ----------------------------------------
    print("\nscheme comparison (mean FID, lower is better):")
    for name in ("stacking", "greedy", "fixed_size", "single_instance"):
        r = run_scheme(scn, get_scheduler(name), delay, quality,
                       report.allocation)
        print(f"  {name:16s} {r.mean_fid:8.2f}  "
              f"(outage {r.outage_rate:.0%})")


if __name__ == "__main__":
    main()
