"""Quickstart: the paper's full pipeline on one page.

1. Calibrate the delay model g(X) = aX + b on this machine (Fig. 1a).
2. Build a K-service scenario with heterogeneous deadlines (Sec. IV).
3. Allocate bandwidth (PSO, Sec. III-C) and schedule batch denoising
   with STACKING (Alg. 1).
4. Execute the plan on a real DDIM U-Net with mixed-step batches.
5. Compare against the paper's three baselines.

    PYTHONPATH=src python examples/quickstart.py
"""

import jax

from repro.configs.ddim_cifar10 import SMOKE
from repro.core.baselines import (fixed_size_batching, greedy_batching,
                                  single_instance)
from repro.core.bandwidth import pso_allocate, tau_prime_of
from repro.core.delay_model import DelayModel, fit
from repro.core.quality_model import PowerLawFID
from repro.core.service import make_scenario
from repro.core.simulator import run_scheme, simulate
from repro.core.stacking import stacking
from repro.diffusion import unet
from repro.diffusion.executor import BatchDenoisingExecutor
from repro.models.params import init_params


def main():
    key = jax.random.PRNGKey(0)

    # 1. calibrate g(X) = aX + b on this hardware --------------------------
    params = init_params(unet.schema(SMOKE), key)
    executor = BatchDenoisingExecutor(SMOKE, params)
    curve = executor.measure_delay_curve(key, batch_sizes=[1, 2, 4, 8])
    measured = fit([c[0] for c in curve], [c[1] for c in curve])
    print(f"measured delay model: a={measured.a * 1e3:.2f} ms/sample, "
          f"b={measured.b * 1e3:.2f} ms")
    # paper constants (RTX-3050) for the simulation below:
    delay = DelayModel()
    quality = PowerLawFID()

    # 2. scenario -----------------------------------------------------------
    scn = make_scenario(K=8, tau_min=4.0, tau_max=12.0, seed=1)
    print(f"\n{scn.K} services, deadlines "
          f"{[round(s.deadline, 1) for s in scn.services]}")

    # 3. bandwidth + batch plan ---------------------------------------------
    res = pso_allocate(scn, stacking, delay, quality,
                       num_particles=10, iters=8)
    tp = tau_prime_of(scn, res.alloc)
    plan = stacking(scn.services, tp, delay, quality)
    plan.validate(gen_deadlines=tp)
    print(f"STACKING plan: {plan.num_batches} batches, "
          f"sizes {plan.batch_sizes()[:12]}...")
    print(f"steps per service: {dict(sorted(plan.steps_completed.items()))}")

    # 4. execute on the real U-Net -----------------------------------------
    images, _ = executor.run(plan, jax.random.PRNGKey(7))
    print(f"generated {len(images)} images, shape "
          f"{next(iter(images.values())).shape}")
    sim = simulate(scn, res.alloc, plan, quality)
    print("\n" + sim.summary())

    # 5. baselines ------------------------------------------------------------
    print("\nscheme comparison (mean FID, lower is better):")
    for name, sched in [("stacking", stacking),
                        ("greedy", greedy_batching),
                        ("fixed", fixed_size_batching),
                        ("single", single_instance)]:
        r = run_scheme(scn, sched, delay, quality, res.alloc)
        print(f"  {name:10s} {r.mean_fid:8.2f}  (outage {r.outage_rate:.0%})")


if __name__ == "__main__":
    main()
