"""Per-architecture smoke tests (deliverable f): each assigned arch, in a
REDUCED variant of the same family (2 layers, d_model<=512, <=4 experts),
runs one forward/train step + prefill + decode on CPU, asserting output
shapes and finiteness; and incremental decode must match the full-sequence
forward (f32 KV cache)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import RunConfig, get_config, list_archs, smoke_variant
from repro.configs import ASSIGNED_ARCHS
from repro.models import api
from repro.training.train import make_train_step
from repro.training import optimizer as opt

RUN = RunConfig(kv_cache_dtype="float32")


def test_all_assigned_archs_registered():
    assert set(ASSIGNED_ARCHS) <= set(list_archs())
    assert len(ASSIGNED_ARCHS) == 10


@pytest.fixture(scope="module")
def built():
    cache = {}

    def get(name):
        if name not in cache:
            cfg = smoke_variant(get_config(name))
            params = api.init_model(cfg, jax.random.PRNGKey(0))
            cache[name] = (cfg, params)
        return cache[name]

    return get


@pytest.mark.parametrize("name", ASSIGNED_ARCHS)
def test_forward_shapes_and_finiteness(name, built):
    cfg, params = built(name)
    B, S = 2, 32
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                                cfg.vocab_size)
    extras = api.extra_input_specs(cfg, B, abstract=False)
    mod = api.get_model(cfg)
    logits, aux, _ = mod.forward(cfg, params, tokens, RUN, extras)
    assert logits.shape == (B, S, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits)))
    if cfg.is_moe:
        assert float(aux) >= 0.0


@pytest.mark.parametrize("name", ASSIGNED_ARCHS)
def test_one_train_step(name, built):
    cfg, params = built(name)
    B, S = 2, 16
    tokens = jax.random.randint(jax.random.PRNGKey(2), (B, S), 0,
                                cfg.vocab_size)
    extras = api.extra_input_specs(cfg, B, abstract=False)
    step = make_train_step(cfg, RUN)
    opt_state = opt.init_state(params)
    new_params, new_state, metrics = step(params, opt_state, tokens,
                                          tokens, extras)
    assert np.isfinite(float(metrics["loss"]))
    assert int(new_state["step"]) == 1
    # parameters actually moved
    moved = any(
        not np.allclose(np.asarray(a), np.asarray(b))
        for a, b in zip(jax.tree_util.tree_leaves(params),
                        jax.tree_util.tree_leaves(new_params)))
    assert moved


@pytest.mark.parametrize("name", ASSIGNED_ARCHS)
def test_decode_matches_forward(name, built):
    cfg, params = built(name)
    B, S, extra_steps = 2, 16, 3
    tokens = jax.random.randint(jax.random.PRNGKey(3), (B, S + extra_steps),
                                0, cfg.vocab_size)
    extras = api.extra_input_specs(cfg, B, abstract=False)
    mod = api.get_model(cfg)
    full, _, _ = mod.forward(cfg, params, tokens, RUN, extras)
    logits, cache = mod.prefill(cfg, params, tokens[:, :S],
                                S + extra_steps + 2, RUN, extras)
    assert logits.shape == (B, S, cfg.vocab_size)
    # MoE capacity dropping differs between S-token and 1-token calls;
    # measure agreement in top-1 tokens for MoE, logits for the rest.
    errs = [float(np.abs(np.asarray(full[:, S - 1] - logits[:, -1])).max())]
    agree = []
    for i in range(extra_steps):
        step_logits, cache = mod.decode_step(
            cfg, params, tokens[:, S + i:S + i + 1], cache, RUN, extras)
        assert step_logits.shape == (B, 1, cfg.vocab_size)
        errs.append(float(np.abs(
            np.asarray(full[:, S + i] - step_logits[:, 0])).max()))
        agree.append(np.mean(
            np.asarray(jnp.argmax(full[:, S + i], -1))
            == np.asarray(jnp.argmax(step_logits[:, 0], -1))))
    if cfg.is_moe:
        assert np.mean(agree) >= 0.5
    else:
        assert max(errs) < 2e-2, f"incremental decode diverges: {errs}"


@pytest.mark.parametrize("name", ["tinyllama-1.1b", "zamba2-2.7b"])
def test_sliding_window_decode_runs(name, built):
    """long_500k carve-out path: windowed decode attention."""
    cfg, params = built(name)
    run = RunConfig(kv_cache_dtype="float32", decode_window=8)
    B, S = 1, 12
    tokens = jax.random.randint(jax.random.PRNGKey(4), (B, S + 2), 0,
                                cfg.vocab_size)
    mod = api.get_model(cfg)
    logits, cache = mod.prefill(cfg, params, tokens[:, :S], S + 4, run,
                                None)
    out, cache = mod.decode_step(cfg, params, tokens[:, S:S + 1], cache,
                                 run, None)
    assert bool(jnp.all(jnp.isfinite(out)))


def test_int8_kv_cache_decode(built):
    """Beyond-paper int8 KV cache: decode stays close to f32 cache."""
    cfg, params = built("tinyllama-1.1b")
    B, S = 2, 24
    tokens = jax.random.randint(jax.random.PRNGKey(5), (B, S + 1), 0,
                                cfg.vocab_size)
    mod = api.get_model(cfg)
    outs = {}
    for kvd in ("float32", "int8"):
        run = RunConfig(kv_cache_dtype=kvd)
        _, cache = mod.prefill(cfg, params, tokens[:, :S], S + 4, run, None)
        logits, _ = mod.decode_step(cfg, params, tokens[:, S:S + 1], cache,
                                    run, None)
        outs[kvd] = np.asarray(logits)
    top_f32 = outs["float32"].argmax(-1)
    top_int8 = outs["int8"].argmax(-1)
    assert (top_f32 == top_int8).mean() >= 0.5
    assert np.abs(outs["float32"] - outs["int8"]).max() < 1.0
