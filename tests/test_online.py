"""Online admission: static-equivalence, event-queue determinism,
admission-policy invariants, Poisson arrivals and heterogeneous content
sizes (ISSUE: tentpole test coverage)."""


import numpy as np
import pytest

from repro.api import (ADMISSIONS, OnlineProvisioner, Provisioner,
                       get_admission, get_allocator, get_scheduler,
                       list_admissions)
from repro.core.bandwidth import tau_prime_of
from repro.core.delay_model import DelayModel
from repro.core.online import OnlineSimulation, simulate_online
from repro.core.quality_model import PowerLawFID
from repro.core.service import Scenario, ServiceRequest, make_scenario

DELAY = DelayModel()
QUALITY = PowerLawFID()


class TestAdmissionRegistry:
    def test_expected_entries_present(self):
        for name in ("admit_all", "deadline_feasible", "fid_threshold"):
            assert name in ADMISSIONS
        assert "feasible" in ADMISSIONS          # alias
        assert list_admissions() == sorted(list_admissions())

    def test_unknown_name_raises_with_candidates(self):
        with pytest.raises(KeyError, match="unknown admission"):
            get_admission("bouncer")


class TestStaticEquivalence:
    """All arrivals at t=0 must reproduce the static pipeline exactly."""

    @pytest.mark.parametrize("scheduler", ["stacking", "greedy",
                                           "equal_steps"])
    @pytest.mark.parametrize("allocator", ["inv_se", "equal"])
    def test_outcomes_identical(self, scheduler, allocator):
        scn = make_scenario(K=8, seed=3)
        assert scn.is_static
        static = Provisioner(scn, scheduler=scheduler,
                             allocator=allocator).run()
        online = OnlineProvisioner(scn, scheduler=scheduler,
                                   allocator=allocator).run()
        assert online.result.outcomes == static.sim.outcomes
        assert online.mean_fid == static.mean_fid
        assert online.outage_rate == static.outage_rate
        assert online.reject_rate == 0.0

    def test_outcomes_identical_under_pso(self):
        scn = make_scenario(K=6, tau_min=4, tau_max=10, seed=7)
        kw = dict(num_particles=6, iters=4, seed=0)
        static = Provisioner(scn, scheduler="stacking", allocator="pso",
                             allocator_kwargs=kw).run()
        online = OnlineProvisioner(scn, scheduler="stacking",
                                   allocator="pso",
                                   allocator_kwargs=kw).run()
        assert online.result.outcomes == static.sim.outcomes

    def test_infeasible_service_is_an_outage_row_in_both(self):
        svcs = [ServiceRequest(id=0, deadline=10.0, spectral_eff=7.0),
                ServiceRequest(id=1, deadline=0.01, spectral_eff=7.0)]
        scn = Scenario(services=svcs)
        static = Provisioner(scn, scheduler="stacking",
                             allocator="equal").run()
        online = OnlineProvisioner(scn, scheduler="stacking",
                                   allocator="equal").run()
        assert online.result.outcomes == static.sim.outcomes
        dead = online.result.outcomes[1]
        assert dead.steps == 0 and not dead.met_deadline
        assert dead.fid == QUALITY.fid_at_zero


class TestEventQueue:
    def test_deterministic_under_fixed_seed(self):
        scn = make_scenario(K=10, arrival_rate=0.3, seed=11)
        runs = [OnlineProvisioner(scn, scheduler="stacking",
                                  allocator="inv_se").run()
                for _ in range(2)]
        assert runs[0].result.outcomes == runs[1].result.outcomes
        assert [d.admitted for d in runs[0].result.decisions] == \
               [d.admitted for d in runs[1].result.decisions]

    def test_arrivals_processed_in_time_order(self):
        scn = make_scenario(K=9, arrival_rate=1.0, seed=2)
        rep = OnlineProvisioner(scn, scheduler="greedy",
                                allocator="equal").run()
        arr = [d.arrival for d in rep.result.decisions]
        assert arr == sorted(arr)

    def test_in_flight_batch_is_pinned(self):
        """A batch running when an arrival lands always finishes; the
        newcomer's first step starts no earlier than that batch's end."""
        delay = DelayModel(a=0.0, b=1.0)           # every batch takes 1 s
        svcs = [ServiceRequest(id=0, deadline=4.5, spectral_eff=1e9),
                ServiceRequest(id=1, deadline=4.5, spectral_eff=1e9,
                               arrival=0.5)]
        scn = Scenario(services=svcs)
        sim = OnlineSimulation(scn, get_scheduler("greedy"),
                               get_allocator("equal"), delay, QUALITY,
                               admission=lambda *a: True)
        res = sim.run()
        by_id = {o.id: o for o in res.outcomes}
        # svc 0's first batch (t=0..1) ran alone; svc 1 starts at t>=1,
        # so its generation ends on whole-second boundaries after 1 s
        assert by_id[0].steps >= 1
        assert sim.states[1].gen_end >= 1.0 + 1.0 - 1e-9
        # replanning happened once per arrival
        assert sim.replan_count == 2

    def test_progress_carries_across_replans(self):
        """Steps executed before a replan count toward the final total."""
        delay = DelayModel(a=0.0, b=1.0)
        svcs = [ServiceRequest(id=0, deadline=6.2, spectral_eff=1e9),
                ServiceRequest(id=1, deadline=4.2, spectral_eff=1e9,
                               arrival=2.5)]
        scn = Scenario(services=svcs)
        res = simulate_online(scn, get_scheduler("greedy"),
                              get_allocator("equal"), delay, QUALITY)
        by_id = {o.id: o for o in res.outcomes}
        # svc 0 ran solo batches at t=0,1,2 (pinned through the arrival),
        # then shared batches until its budget ran out
        assert by_id[0].steps >= 4
        assert by_id[0].met_deadline and by_id[1].met_deadline


class TestAdmissionPolicies:
    def test_admit_all_rejects_nothing(self):
        scn = make_scenario(K=8, arrival_rate=2.0, seed=0)
        rep = OnlineProvisioner(scn, scheduler="stacking",
                                allocator="inv_se").run()
        assert rep.reject_rate == 0.0
        assert len(rep.result.outcomes) == scn.K

    def test_deadline_feasible_admitted_implies_projected_feasible(self):
        scn = make_scenario(K=14, tau_min=1.0, tau_max=3.0,
                            arrival_rate=4.0, seed=1)
        rep = OnlineProvisioner(scn, scheduler="stacking",
                                allocator="inv_se",
                                admission="deadline_feasible").run()
        for d in rep.result.decisions:
            if d.admitted:
                # the invariant the policy enforces: the adopted trial
                # plan (which validate()d) met the newcomer's deadline
                assert d.projected.steps > 0
                assert d.projected.met_deadline
            else:
                assert not d.projected.met_deadline

    def test_fid_threshold_respects_threshold_and_kwargs(self):
        scn = make_scenario(K=14, tau_min=1.0, tau_max=3.0,
                            arrival_rate=4.0, seed=1)
        strict = OnlineProvisioner(
            scn, scheduler="stacking", allocator="inv_se",
            admission="fid_threshold",
            admission_kwargs=dict(threshold=20.0)).run()
        for d in strict.result.decisions:
            assert d.admitted == (d.projected.steps > 0
                                  and d.projected.fid <= 20.0)
        lax = OnlineProvisioner(
            scn, scheduler="stacking", allocator="inv_se",
            admission="fid_threshold",
            admission_kwargs=dict(threshold=1e9)).run()
        assert lax.reject_rate <= strict.reject_rate

    def test_rejected_services_do_not_consume_the_server(self):
        scn = make_scenario(K=10, tau_min=1.0, tau_max=2.0,
                            arrival_rate=5.0, seed=3)
        none = OnlineProvisioner(
            scn, scheduler="stacking", allocator="inv_se",
            admission=lambda svc, projected, states: False).run()
        assert none.reject_rate == 1.0
        assert none.result.outcomes == []
        assert np.isnan(none.mean_fid)

    def test_custom_policy_instance_passes_through(self):
        scn = make_scenario(K=6, arrival_rate=1.0, seed=4)
        evens = OnlineProvisioner(
            scn, scheduler="greedy", allocator="equal",
            admission=lambda svc, projected, states: svc.id % 2 == 0).run()
        assert evens.result.admitted_ids == [0, 2, 4]
        assert evens.result.rejected_ids == [1, 3, 5]


class TestPoissonArrivals:
    def test_default_scenarios_are_bit_identical_to_older_seeds(self):
        """Adding the arrival machinery must not disturb existing draws."""
        base = make_scenario(K=12, seed=5)
        timed = make_scenario(K=12, arrival_rate=0.5, seed=5)
        assert all(s.arrival == 0.0 for s in base.services)
        for a, b in zip(base.services, timed.services):
            assert a.deadline == b.deadline
            assert a.spectral_eff == b.spectral_eff
        assert not timed.is_static

    def test_arrivals_are_increasing_and_rate_scaled(self):
        slow = make_scenario(K=200, arrival_rate=0.1, seed=0)
        fast = make_scenario(K=200, arrival_rate=10.0, seed=0)
        for scn in (slow, fast):
            arr = [s.arrival for s in scn.services]
            assert all(b > a for a, b in zip(arr, arr[1:]))
        # mean inter-arrival gap ~ 1/rate (law of large numbers, K=200)
        gap = lambda scn: scn.services[-1].arrival / scn.K   # noqa: E731
        assert gap(slow) == pytest.approx(10.0, rel=0.25)
        assert gap(fast) == pytest.approx(0.1, rel=0.25)

    def test_invalid_rate_rejected(self):
        with pytest.raises(AssertionError, match="arrival_rate"):
            make_scenario(K=4, arrival_rate=0.0)


class TestHeterogeneousContentSizes:
    def test_per_service_bits_override_tx_delay(self):
        small = ServiceRequest(id=0, deadline=10.0, spectral_eff=5.0,
                               content_bits=1024.0)
        dflt = ServiceRequest(id=1, deadline=10.0, spectral_eff=5.0)
        bw = 1000.0
        assert small.tx_delay(bw, content_bits=8192.0) == \
            pytest.approx(1024.0 / (bw * 5.0))
        assert dflt.tx_delay(bw, content_bits=8192.0) == \
            pytest.approx(8192.0 / (bw * 5.0))

    def test_tau_prime_reflects_per_service_bits(self):
        svcs = [ServiceRequest(id=0, deadline=10.0, spectral_eff=5.0,
                               content_bits=1024.0),
                ServiceRequest(id=1, deadline=10.0, spectral_eff=5.0)]
        scn = Scenario(services=svcs, content_bits=8192.0)
        alloc = np.array([1000.0, 1000.0])
        tp = tau_prime_of(scn, alloc)
        assert tp[0] > tp[1]                       # smaller content, more
        assert tp[0] == pytest.approx(10.0 - 1024.0 / 5000.0)
        assert tp[1] == pytest.approx(10.0 - 8192.0 / 5000.0)

    def test_make_scenario_samples_in_range_without_disturbing_seeds(self):
        base = make_scenario(K=10, seed=9)
        hetero = make_scenario(K=10, seed=9,
                               content_bits_range=(1024.0, 65536.0))
        for a, b in zip(base.services, hetero.services):
            assert a.deadline == b.deadline
            assert b.content_bits is not None
            assert 1024.0 <= b.content_bits <= 65536.0
        assert base.services[0].content_bits is None

    def test_search_allocators_never_starve_in_progress_services(self):
        """Regression: coordinate_refine could drive a donor *negative*
        (floor only checked once per donor sweep), and the progress-aware
        objective made starving an almost-finished service look free —
        its content then transmitted over ~0 Hz and arrived years late."""
        scn = make_scenario(K=10, arrival_rate=0.4, seed=5,
                            content_bits_range=(2048.0, 65536.0))
        sim = OnlineSimulation(scn, get_scheduler("stacking"),
                               get_allocator("coordinate"), DELAY,
                               QUALITY, admission=lambda *a: True)
        res = sim.run()
        for st in sim.states.values():
            if st.gen_complete:
                assert st.bandwidth > 0.0
        assert all(o.tx_delay < 1e3 for o in res.outcomes)
        assert res.outage_rate == 0.0

    def test_concurrent_transmissions_never_exceed_the_budget(self):
        """The paper's P1 constraint (sum B_k = B) must hold at every
        instant: replans allocate only the bandwidth not committed to
        transmissions still in the air (docs/SCENARIOS.md rule 5)."""
        scn = make_scenario(K=16, tau_min=1.0, tau_max=3.0,
                            arrival_rate=4.0, seed=0,
                            content_bits_range=(65536.0, 262144.0))
        sim = OnlineSimulation(scn, get_scheduler("stacking"),
                               get_allocator("inv_se"), DELAY, QUALITY,
                               admission=lambda *a: True)
        sim.run()
        spans = [(st.gen_end, st.tx_end, st.bandwidth)
                 for st in sim.states.values() if st.gen_complete]
        B = scn.total_bandwidth_hz
        for t0, _, _ in spans:       # check at every transmission start
            in_air = sum(bw for s, e, bw in spans if s <= t0 < e)
            assert in_air <= B + 1e-6

    def test_online_runs_with_heterogeneous_sizes(self):
        scn = make_scenario(K=8, arrival_rate=0.5, seed=2,
                            content_bits_range=(1024.0, 131072.0))
        rep = OnlineProvisioner(scn, scheduler="stacking",
                                allocator="inv_se").run()
        assert len(rep.result.outcomes) == 8
        tx = [o.tx_delay for o in rep.result.outcomes if o.steps > 0]
        assert len(set(round(t, 9) for t in tx)) > 1   # sizes visible
