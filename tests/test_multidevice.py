"""Real sharded EXECUTION (not just lowering): run train/prefill/decode of
a reduced arch on an 8-fake-device (2 data x 4 model) mesh in a
subprocess, with the production sharding rules, and check numerics match
the single-device run."""

import json
import os
import subprocess
import sys

import numpy as np
import pytest

SCRIPT = r"""
import os
if os.environ.get("FAKE_DEVICES"):
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json, sys
import jax, jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as PS
from repro.config import RunConfig, get_config, smoke_variant, \
    sharding_rules_for
from repro.launch import shardings as shd
from repro.models import api
from repro.models.params import use_rules
from repro.training.train import make_train_step
from repro.training import optimizer as opt

name = sys.argv[1]
cfg = smoke_variant(get_config(name))
run = RunConfig(kv_cache_dtype="float32")
params = api.init_model(cfg, jax.random.PRNGKey(0))
B, S = 4, 32
tokens = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                            cfg.vocab_size)
extras = api.extra_input_specs(cfg, B, abstract=False)

if os.environ.get("FAKE_DEVICES"):
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    rules = sharding_rules_for(cfg, {"data": 2, "model": 4}, run)
    p_spec = shd.model_param_pspecs(cfg, rules, fsdp=False)
    with mesh:
        with use_rules(rules):
            p_sh = shd.to_shardings(mesh, p_spec)
            params = jax.device_put(params, p_sh)
            step = jax.jit(make_train_step(cfg, run),
                           in_shardings=(p_sh, None, NamedSharding(
                               mesh, PS("data")), NamedSharding(
                               mesh, PS("data")), None))
            opt_state = opt.init_state(params)
            new_p, new_s, metrics = step(params, opt_state, tokens, tokens,
                                         extras)
            loss = float(metrics["loss"])
            pre = jax.jit(api.make_prefill_step(cfg, run, S + 4))
            logits, cache = pre(params, tokens, extras)
            dec = jax.jit(api.make_decode_step(cfg, run))
            stepl, cache = dec(params, tokens[:, :1], cache, extras)
else:
    step = jax.jit(make_train_step(cfg, run))
    opt_state = opt.init_state(params)
    new_p, new_s, metrics = step(params, opt_state, tokens, tokens, extras)
    loss = float(metrics["loss"])
    logits, cache = jax.jit(api.make_prefill_step(cfg, run, S + 4))(
        params, tokens, extras)
    stepl, cache = jax.jit(api.make_decode_step(cfg, run))(
        params, tokens[:, :1], cache, extras)

print(json.dumps({
    "loss": loss,
    "logit_slice": np.asarray(logits[:, -1, :6], np.float64).tolist(),
    "decode_slice": np.asarray(stepl[:, 0, :6], np.float64).tolist(),
    "n_devices": len(jax.devices()),
}))
"""


def _run(name, fake):
    env = dict(os.environ, PYTHONPATH="src", JAX_PLATFORMS="cpu")
    if fake:
        env["FAKE_DEVICES"] = "1"
    else:
        env.pop("FAKE_DEVICES", None)
    out = subprocess.run(
        [sys.executable, "-c", SCRIPT, name], env=env, capture_output=True,
        text=True, timeout=900, cwd=os.path.dirname(os.path.dirname(
            os.path.abspath(__file__))))
    assert out.returncode == 0, out.stderr[-2000:]
    return json.loads(out.stdout.strip().splitlines()[-1])


@pytest.mark.slow
@pytest.mark.parametrize("name", ["tinyllama-1.1b", "deepseek-moe-16b"])
def test_sharded_execution_matches_single_device(name):
    single = _run(name, fake=False)
    sharded = _run(name, fake=True)
    assert sharded["n_devices"] == 8
    assert abs(single["loss"] - sharded["loss"]) < 5e-3
    np.testing.assert_allclose(np.array(sharded["logit_slice"]),
                               np.array(single["logit_slice"]),
                               atol=2e-2, rtol=2e-2)
    np.testing.assert_allclose(np.array(sharded["decode_slice"]),
                               np.array(single["decode_slice"]),
                               atol=2e-2, rtol=2e-2)
