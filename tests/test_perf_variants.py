"""The §Perf optimization flags must be semantics-preserving:
  * decode_inplace_cache: in-place carried cache == restacked cache
  * decode_slice_reads (+ window): windowed slice == masked full read
  * prefill_logits="last": equals the last column of full prefill logits
"""

import jax
import numpy as np
import pytest

from repro.config import RunConfig, get_config, smoke_variant
from repro.models import api

BASE = RunConfig(kv_cache_dtype="float32")

ARCHS = ["tinyllama-1.1b", "deepseek-moe-16b", "zamba2-2.7b",
         "whisper-tiny", "llama-3.2-vision-90b"]


@pytest.fixture(scope="module")
def built():
    cache = {}

    def get(name):
        if name not in cache:
            cfg = smoke_variant(get_config(name))
            params = api.init_model(cfg, jax.random.PRNGKey(0))
            cache[name] = (cfg, params)
        return cache[name]

    return get


def _decode_tokens(cfg, params, run, tokens, S, n, extras):
    mod = api.get_model(cfg)
    logits, cache = mod.prefill(cfg, params, tokens[:, :S], S + n + 2,
                                run, extras)
    outs = [logits[:, -1]]
    for i in range(n):
        lg, cache = mod.decode_step(cfg, params, tokens[:, S + i:S + i + 1],
                                    cache, run, extras)
        outs.append(lg[:, 0])
    return np.stack([np.asarray(o) for o in outs])


@pytest.mark.parametrize("name", ARCHS)
def test_inplace_cache_matches_baseline(name, built):
    cfg, params = built(name)
    B, S, n = 2, 12, 3
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, S + n), 0,
                                cfg.vocab_size)
    extras = api.extra_input_specs(cfg, B, abstract=False)
    base = _decode_tokens(cfg, params, BASE, tokens, S, n, extras)
    opt = _decode_tokens(
        cfg, params,
        RunConfig(kv_cache_dtype="float32", decode_inplace_cache=True),
        tokens, S, n, extras)
    np.testing.assert_allclose(opt, base, atol=2e-4, rtol=2e-4)


@pytest.mark.parametrize("name", ["tinyllama-1.1b", "whisper-tiny"])
def test_slice_reads_match_masked_window(name, built):
    cfg, params = built(name)
    B, S, n, w = 2, 12, 3, 8
    tokens = jax.random.randint(jax.random.PRNGKey(2), (B, S + n), 0,
                                cfg.vocab_size)
    extras = api.extra_input_specs(cfg, B, abstract=False)
    masked = _decode_tokens(
        cfg, params,
        RunConfig(kv_cache_dtype="float32", decode_window=w,
                  decode_inplace_cache=True),
        tokens, S, n, extras)
    sliced = _decode_tokens(
        cfg, params,
        RunConfig(kv_cache_dtype="float32", decode_window=w,
                  decode_inplace_cache=True, decode_slice_reads=True),
        tokens, S, n, extras)
    np.testing.assert_allclose(sliced, masked, atol=2e-4, rtol=2e-4)


@pytest.mark.parametrize("name", ARCHS)
def test_prefill_last_logits(name, built):
    cfg, params = built(name)
    B, S = 2, 10
    tokens = jax.random.randint(jax.random.PRNGKey(3), (B, S), 0,
                                cfg.vocab_size)
    extras = api.extra_input_specs(cfg, B, abstract=False)
    mod = api.get_model(cfg)
    full, c1 = mod.prefill(cfg, params, tokens, S + 4, BASE, extras)
    last, c2 = mod.prefill(
        cfg, params, tokens, S + 4,
        RunConfig(kv_cache_dtype="float32", prefill_logits="last"), extras)
    assert last.shape == (B, 1, cfg.vocab_size)
    np.testing.assert_allclose(np.asarray(last[:, 0]),
                               np.asarray(full[:, -1]),
                               atol=2e-4, rtol=2e-4)
    # caches identical
    for a, b in zip(jax.tree_util.tree_leaves(c1),
                    jax.tree_util.tree_leaves(c2)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   atol=1e-6, rtol=1e-6)
