"""Serving engine (STACKING-scheduled decoding) + training substrate +
end-to-end simulator tests."""

import jax
import numpy as np
import pytest

from repro.config import RunConfig, get_config, smoke_variant
from repro.core.bandwidth import equal_allocate, inv_se_allocate, tau_prime_of
from repro.core.delay_model import DelayModel
from repro.core.quality_model import PowerLawFID
from repro.core.service import make_scenario
from repro.core.simulator import run_scheme, simulate
from repro.core.stacking import stacking
from repro.models import api
from repro.serving.engine import ServingEngine, TokenQuality
from repro.training import checkpoint, optimizer as opt
from repro.training.data import DataConfig, batches
from repro.training.train import train_loop

RUN = RunConfig(kv_cache_dtype="float32")


@pytest.fixture(scope="module")
def tiny():
    cfg = smoke_variant(get_config("tinyllama-1.1b"))
    params = api.init_model(cfg, jax.random.PRNGKey(0))
    return cfg, params


class TestServingEngine:
    def test_deadlines_drive_token_budgets(self, tiny):
        cfg, params = tiny
        eng = ServingEngine(cfg, params, RUN, max_len=96,
                            delay=DelayModel(a=0.002, b=0.02))
        ids = [eng.submit(np.arange(8, dtype=np.int32), d)
               for d in (0.2, 0.6, 1.2)]
        plan = eng.plan()
        steps = plan.steps_completed
        assert steps[ids[0]] < steps[ids[1]] < steps[ids[2]]
        out = eng.execute(plan)
        for rid in ids:
            assert len(out[rid]) == steps[rid]

    def test_batched_decode_matches_sequential(self, tiny):
        """Scheduler-batched execution must produce the same tokens as
        serving each request alone (batching is semantically lossless)."""
        cfg, params = tiny
        delay = DelayModel(a=0.002, b=0.02)
        prompts = [np.arange(6, dtype=np.int32) + i for i in range(3)]

        eng = ServingEngine(cfg, params, RUN, max_len=64, delay=delay)
        ids = [eng.submit(p, 0.5) for p in prompts]
        batched = eng.execute(eng.plan())

        for i, p in enumerate(prompts):
            solo = ServingEngine(cfg, params, RUN, max_len=64, delay=delay)
            rid = solo.submit(p, 0.5)
            n = len(batched[ids[i]])
            plan = solo.plan()
            # force the same number of steps for comparison
            plan.steps_completed[rid] = n
            plan.batches = plan.batches[:n]
            out = solo.execute(plan)
            assert out[rid][:n] == batched[ids[i]][:n]

    def test_token_quality_interface(self):
        q = TokenQuality()
        vals = [q.fid(t) for t in range(0, 50)]
        assert all(a >= b for a, b in zip(vals, vals[1:]))

    @pytest.mark.parametrize("sched_name", ["greedy", "fixed_size"])
    def test_registry_scheduler_plans_and_serves(self, tiny, sched_name):
        """ISSUE 5: the engine must work with registry schedulers other
        than stacking — the plan validates, executes, and every request
        gets exactly its planned token count."""
        cfg, params = tiny
        eng = ServingEngine(cfg, params, RUN, max_len=64,
                            delay=DelayModel(a=0.002, b=0.02),
                            scheduler=sched_name)
        ids = [eng.submit(np.arange(6, dtype=np.int32), d)
               for d in (0.15, 0.3)]
        plan = eng.plan()
        plan.validate()
        assert sum(plan.steps_completed.values()) > 0
        out = eng.execute(plan)
        for rid in ids:
            assert len(out[rid]) == plan.steps_completed[rid]

    def test_timed_execute_populates_last_timings(self, tiny):
        """The timed decode path: one steady-state (batch_size, s)
        reading per batch in ``last_timings``, sizes matching the plan,
        and the same tokens as an untimed run (timing must be
        side-effect-free)."""
        cfg, params = tiny
        delay = DelayModel(a=0.002, b=0.02)
        prompts = [np.arange(5, dtype=np.int32) + i for i in range(2)]

        eng = ServingEngine(cfg, params, RUN, max_len=64, delay=delay,
                            scheduler="greedy")
        ids = [eng.submit(p, 0.2) for p in prompts]
        plan = eng.plan()
        out = eng.execute(plan, timed=True)
        assert len(eng.last_timings) == plan.num_batches
        assert [x for x, _ in eng.last_timings] == plan.batch_sizes()
        assert all(s > 0 for _, s in eng.last_timings)

        ref = ServingEngine(cfg, params, RUN, max_len=64, delay=delay,
                            scheduler="greedy")
        ref_ids = [ref.submit(p, 0.2) for p in prompts]
        ref_out = ref.execute(ref.plan())
        for rid, ref_rid in zip(ids, ref_ids):
            assert out[rid] == ref_out[ref_rid]


class TestTraining:
    def test_loss_decreases_on_memorizable_data(self, tiny):
        cfg, _ = tiny
        dc = DataConfig(vocab_size=cfg.vocab_size, seq_len=32,
                        global_batch=4, seed=0)
        it = batches(dc)
        fixed = next(it)                      # one batch, memorize it
        params, _, hist = train_loop(
            cfg, RUN, iter(lambda: fixed, None), steps=30, log_every=29)
        assert hist[-1]["loss"] < hist[0]["loss"] - 0.3

    def test_grad_clip_and_lr_schedule(self):
        ocfg = opt.AdamWConfig(lr=1e-3, warmup_steps=10, total_steps=100)
        assert float(opt.lr_at(ocfg, 0)) == 0.0
        assert float(opt.lr_at(ocfg, 10)) == pytest.approx(1e-3, rel=1e-3)
        assert float(opt.lr_at(ocfg, 100)) == pytest.approx(0.0, abs=1e-9)

    def test_checkpoint_roundtrip_with_opt_state(self, tiny, tmp_path):
        cfg, params = tiny
        state = opt.init_state(params)
        blob = {"params": params, "opt": state, "meta": [1, (2, 3)]}
        path = str(tmp_path / "ck.npz")
        checkpoint.save(path, blob)
        back = checkpoint.restore(path, blob)
        for a, b in zip(jax.tree_util.tree_leaves(blob),
                        jax.tree_util.tree_leaves(back)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


class TestSimulator:
    def test_fig2a_properties(self):
        """Fig. 2a: all deadlines met; tight services processed first."""
        delay, quality = DelayModel(), PowerLawFID()
        scn = make_scenario(K=10, seed=4)
        alloc = inv_se_allocate(scn)
        tp = tau_prime_of(scn, alloc)
        plan = stacking(scn.services, tp, delay, quality)
        res = simulate(scn, alloc, plan, quality)
        assert res.outage_rate == 0.0
        for o in res.outcomes:
            assert o.e2e_delay <= o.deadline + 1e-6
        # tightest-deadline service appears in the first batch
        tightest = min(scn.services, key=lambda s: s.deadline).id
        assert any(k == tightest for k, _ in plan.batches[0])

    def test_scheme_ordering_fig2b(self):
        """Fig. 2b ordering: stacking <= greedy/fixed << single."""
        from repro.core.baselines import (fixed_size_batching,
                                          greedy_batching, single_instance)
        delay, quality = DelayModel(), PowerLawFID()
        scn = make_scenario(K=16, seed=9)
        alloc = equal_allocate(scn)
        r_stack = run_scheme(scn, stacking, delay, quality, alloc)
        r_greedy = run_scheme(scn, greedy_batching, delay, quality, alloc)
        r_fixed = run_scheme(scn, fixed_size_batching, delay, quality,
                             alloc)
        r_single = run_scheme(scn, single_instance, delay, quality, alloc)
        assert r_stack.mean_fid <= r_greedy.mean_fid + 1e-9
        assert r_stack.mean_fid <= r_fixed.mean_fid + 1e-9
        assert r_single.mean_fid > 2 * r_stack.mean_fid
