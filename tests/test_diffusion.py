"""Diffusion substrate: U-Net, DDIM schedules, batch-denoising executor."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.ddim_cifar10 import SMOKE
from repro.core.delay_model import DelayModel
from repro.core.plan import BatchPlan
from repro.core.quality_model import PowerLawFID
from repro.core.service import make_scenario
from repro.core.stacking import stacking
from repro.core.bandwidth import inv_se_allocate, tau_prime_of
from repro.diffusion import ddim, unet
from repro.diffusion.executor import BatchDenoisingExecutor
from repro.models.params import init_params


@pytest.fixture(scope="module")
def unet_params():
    return init_params(unet.schema(SMOKE), jax.random.PRNGKey(0))


class TestUNet:
    def test_forward_shape_per_sample_t(self, unet_params):
        x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, 16, 3))
        t = jnp.array([0.0, 10.0, 500.0, 999.0])
        eps = unet.forward(SMOKE, unet_params, x, t)
        assert eps.shape == x.shape
        assert bool(jnp.all(jnp.isfinite(eps)))

    def test_per_sample_t_matters(self, unet_params):
        """Different timesteps change the output (conditioning works).
        The final conv is ~zero-init (DDPM convention), so give it real
        weights for this sensitivity check."""
        params = dict(unet_params)
        params["conv_out"] = jax.random.normal(
            jax.random.PRNGKey(9), params["conv_out"].shape) * 0.1
        x = jnp.broadcast_to(
            jax.random.normal(jax.random.PRNGKey(2), (1, 16, 16, 3)),
            (2, 16, 16, 3))
        t = jnp.array([10.0, 900.0])
        eps = unet.forward(SMOKE, params, x, t)
        assert float(jnp.abs(eps[0] - eps[1]).max()) > 1e-4

    def test_mixed_batch_equals_individual(self, unet_params):
        """Batch denoising invariant: running two services in one batch
        gives the same result as running them separately (Fig. 1a's
        parallelism is lossless)."""
        x = jax.random.normal(jax.random.PRNGKey(3), (2, 16, 16, 3))
        t = jnp.array([100.0, 700.0])
        together = unet.forward(SMOKE, unet_params, x, t)
        alone0 = unet.forward(SMOKE, unet_params, x[:1], t[:1])
        alone1 = unet.forward(SMOKE, unet_params, x[1:], t[1:])
        np.testing.assert_allclose(np.asarray(together),
                                   np.asarray(jnp.concatenate([alone0,
                                                               alone1])),
                                   atol=1e-5, rtol=1e-5)


class TestDDIM:
    def test_timestep_subsequence(self):
        ts = ddim.ddim_timesteps(10, 1000)
        assert len(ts) == 10
        assert ts[0] > ts[-1]                     # descending
        assert ts[-1] == 0
        assert all(0 <= t < 1000 for t in ts)

    def test_schedule_table_ends_done(self):
        tab = ddim.schedule_table(5)
        assert len(tab) == 6 and tab[-1] == -1

    def test_step_reduces_noise_towards_x0(self, unet_params):
        """DDIM with a perfect eps predictor recovers x0 in one step."""
        x0 = jax.random.normal(jax.random.PRNGKey(4), (2, 16, 16, 3))
        eps_true = jax.random.normal(jax.random.PRNGKey(5), x0.shape)
        acp = ddim.alphas_cumprod()
        t = 600
        a = acp[t]
        xt = np.sqrt(a) * x0 + np.sqrt(1 - a) * eps_true
        out = ddim.ddim_step(lambda x, tt: eps_true, xt,
                             jnp.full((2,), t), jnp.full((2,), -1))
        np.testing.assert_allclose(np.asarray(out), np.asarray(x0),
                                   atol=1e-4, rtol=1e-4)

    def test_inactive_passthrough(self):
        x = jnp.ones((2, 4, 4, 3))
        out = ddim.ddim_step(lambda x, t: x * 0 + 1.0, x,
                             jnp.array([-1, 500]), jnp.array([-1, 250]))
        np.testing.assert_allclose(np.asarray(out[0]), np.asarray(x[0]))
        assert float(jnp.abs(out[1] - x[1]).max()) > 1e-5


class TestExecutor:
    def test_plan_execution_matches_plain_sampling(self, unet_params):
        """A single-service STACKING plan must produce exactly the same
        image as plain DDIM sampling with the same step count."""
        delay, quality = DelayModel(), PowerLawFID()
        scn = make_scenario(K=1, tau_min=3, tau_max=3, seed=0)
        tp = tau_prime_of(scn, inv_se_allocate(scn))
        plan = stacking(scn.services, tp, delay, quality)
        T = plan.steps_completed[0]
        assert T > 0

        ex = BatchDenoisingExecutor(SMOKE, unet_params)
        key = jax.random.PRNGKey(7)
        imgs, _ = ex.run(plan, key)

        eps_fn = lambda x, t: unet.forward(SMOKE, unet_params, x, t)
        k0 = jax.random.split(key, 1)[0]
        want = ddim.sample(eps_fn, k0, (1, 16, 16, 3), T)
        np.testing.assert_allclose(imgs[0], np.asarray(want[0]),
                                   atol=1e-3, rtol=1e-3)

    def test_multi_service_plan_executes_all(self, unet_params):
        delay, quality = DelayModel(), PowerLawFID()
        scn = make_scenario(K=5, tau_min=2, tau_max=6, seed=1)
        tp = tau_prime_of(scn, inv_se_allocate(scn))
        plan = stacking(scn.services, tp, delay, quality)
        ex = BatchDenoisingExecutor(SMOKE, unet_params)
        imgs, _ = ex.run(plan, jax.random.PRNGKey(8))
        assert set(imgs) == set(plan.steps_completed)
        for v in imgs.values():
            assert v.shape == (16, 16, 3)
            assert np.isfinite(v).all()

    def test_timed_run_matches_untimed(self, unet_params):
        """Regression (ISSUE 5): timed mode used to re-run the step
        after the timing pair, advancing every batch TWO DDIM steps.
        Timing must be side-effect-free — identical images for a fixed
        key, one timing entry per batch.  Since ISSUE 10 the timed call
        IS the only U-Net execution (AOT compile is separate), so a
        timed run costs exactly one dispatch per batch, not two."""
        delay, quality = DelayModel(), PowerLawFID()
        scn = make_scenario(K=3, tau_min=2, tau_max=4, seed=2)
        tp = tau_prime_of(scn, inv_se_allocate(scn))
        plan = stacking(scn.services, tp, delay, quality)
        assert plan.num_batches > 0
        ex = BatchDenoisingExecutor(SMOKE, unet_params)
        key = jax.random.PRNGKey(11)
        before = ex.dispatches
        imgs_timed, timings = ex.run(plan, key, timed=True)
        assert ex.dispatches - before == plan.num_batches
        imgs_plain, no_timings = ex.run(plan, key)
        assert no_timings == []
        assert len(timings) == plan.num_batches
        assert all(x == len(b) for (x, _), b in zip(timings,
                                                    plan.batches))
        for k in imgs_plain:
            np.testing.assert_array_equal(imgs_timed[k], imgs_plain[k])

    def test_zero_step_service_returns_untouched_latent(self,
                                                        unet_params):
        """Regression: `run` used to force every service through
        max(T_k, 1) steps, denoising services the planner had retired
        at T_k = 0.  A zero-step service must never be batched and its
        latent must come back exactly as seeded."""
        plan = BatchPlan(batches=[[(1, 0)], [(1, 1)]],
                         start_times=[0.0, 1.0],
                         steps_completed={0: 0, 1: 2},
                         delay=DelayModel())
        ex = BatchDenoisingExecutor(SMOKE, unet_params)
        key = jax.random.PRNGKey(13)
        imgs, _ = ex.run(plan, key)
        assert set(imgs) == {0, 1}
        # service 0: the raw seeded noise, untouched (ids are seeded
        # in sorted order, exactly as DenoiseSession does it)
        k0 = jax.random.split(key, 2)[0]
        raw = jax.random.normal(k0, (16, 16, 3), jnp.float32)
        np.testing.assert_array_equal(imgs[0], np.asarray(raw))
        assert not np.array_equal(imgs[1], np.asarray(
            jax.random.normal(jax.random.split(key, 2)[1], (16, 16, 3),
                              jnp.float32)))


class TestDenoiseSession:
    """The stepwise execution handle behind the EXECUTORS registry."""

    def _plan(self, K=3, seed=2):
        scn = make_scenario(K=K, tau_min=2, tau_max=4, seed=seed)
        tp = tau_prime_of(scn, inv_se_allocate(scn))
        return stacking(scn.services, tp, DelayModel(), PowerLawFID())

    def test_session_matches_one_shot_run(self, unet_params):
        plan = self._plan()
        ex = BatchDenoisingExecutor(SMOKE, unet_params)
        key = jax.random.PRNGKey(21)
        want, _ = ex.run(plan, key)
        sess = ex.open_session(plan, key)
        for batch in plan.batches:
            sess.run_batch([k for k, _ in batch])
        got = sess.finish()
        for k in want:
            np.testing.assert_array_equal(got[k], want[k])

    def test_retarget_no_resurrection(self, unet_params):
        plan = self._plan()
        ex = BatchDenoisingExecutor(SMOKE, unet_params)
        sess = ex.open_session(plan, jax.random.PRNGKey(22))
        k = min(plan.steps_completed)
        sess.run_batch([k])
        with pytest.raises(ValueError, match="already executed"):
            sess.retarget({k: 0})
        # retiring at exactly the executed count is legal...
        sess.retarget({k: sess.steps_done[k]})
        with pytest.raises(ValueError, match="no remaining"):
            sess.run_batch([k])
        # ...but re-growing a fully retired chain is a resurrection
        with pytest.raises(ValueError, match="fully denoised"):
            sess.retarget({k: sess.steps_done[k] + 3})

    def test_retarget_mid_flight_completes(self, unet_params):
        plan = self._plan(K=2, seed=3)
        ex = BatchDenoisingExecutor(SMOKE, unet_params)
        sess = ex.open_session(plan, jax.random.PRNGKey(23))
        k = min(plan.steps_completed)
        sess.run_batch([k])
        total = sess.steps_done[k] + 2   # shrink/stretch to done+2
        sess.retarget({k: total})
        sess.run_batch([k])
        sess.run_batch([k])
        assert sess.steps_done[k] == total
        with pytest.raises(ValueError, match="no remaining"):
            sess.run_batch([k])
        imgs = sess.finish()
        assert np.isfinite(imgs[k]).all()
