"""Array-native planning engine: bit-identical equivalence suite.

The contract of ``repro.core.arrays`` (ISSUE 5) is that the vectorized
kernels return exactly the scalar reference's plans — same batches,
same start times, same ``steps_completed``, same objective — across
every planning entry point: the raw pass, the T* search, the balanced
baseline, the offset-native replanner, and the full online / offset /
multi-server pipelines.  ``assert_plans_equal`` compares with ``==``
on floats on purpose: "close enough" is not the bar.
"""

import os
import subprocess
import sys

import numpy as np
import pytest

from repro.core import arrays
from repro.core.arrays import (ServiceArrays, engine_scope,
                               equal_steps_vec, first_best, get_engine,
                               offset_pass_vec, set_engine,
                               stacking_pass_vec, sweep_clustered,
                               sweep_lockstep)
from repro.core.delay_model import DelayModel
from repro.core.multiserver import provision_multi, simulate_online_multi
from repro.core.offset import StackingOffset, offset_pass
from repro.core.online import simulate_online
from repro.core.quality_model import PowerLawFID
from repro.core.service import make_scenario
from repro.core.stacking import stacking, stacking_pass

DELAY = DelayModel()          # paper constants
QUALITY = PowerLawFID()


def assert_plans_equal(a, b):
    assert a.batches == b.batches
    assert a.start_times == b.start_times
    assert a.steps_completed == b.steps_completed
    assert a.makespan() == b.makespan()


def _tau_prime(scn, slack):
    return {s.id: s.deadline - slack for s in scn.services}


# ---------------------------------------------------------------------------
# Engine selection
# ---------------------------------------------------------------------------

class TestEngineToggle:
    def test_default_is_vec(self):
        assert get_engine() == "vec"

    def test_set_and_scope(self):
        assert get_engine() == "vec"
        with engine_scope("scalar"):
            assert get_engine() == "scalar"
            with engine_scope(None):          # None = leave as-is
                assert get_engine() == "scalar"
        assert get_engine() == "vec"
        set_engine("scalar")
        try:
            assert get_engine() == "scalar"
        finally:
            set_engine("vec")

    def test_invalid_engine_rejected(self):
        with pytest.raises(ValueError):
            set_engine("gpu")
        with pytest.raises(ValueError):
            arrays.resolve_engine("turbo")
        with pytest.raises(ValueError):
            stacking(make_scenario(K=2, seed=0).services,
                     {0: 5.0, 1: 5.0}, DELAY, QUALITY, engine="nope")

    def test_env_var_sets_process_default(self):
        env = dict(os.environ, REPRO_PLANNER_ENGINE="scalar",
                   PYTHONPATH="src")
        out = subprocess.run(
            [sys.executable, "-c",
             "from repro.core import arrays; print(arrays.get_engine())"],
            capture_output=True, text=True, env=env,
            cwd=os.path.dirname(os.path.dirname(__file__)))
        assert out.stdout.strip() == "scalar", out.stderr

    def test_bad_env_var_fails_loudly(self):
        env = dict(os.environ, REPRO_PLANNER_ENGINE="typo",
                   PYTHONPATH="src")
        out = subprocess.run(
            [sys.executable, "-c", "import repro.core.arrays"],
            capture_output=True, text=True, env=env,
            cwd=os.path.dirname(os.path.dirname(__file__)))
        assert out.returncode != 0
        assert "REPRO_PLANNER_ENGINE" in out.stderr


# ---------------------------------------------------------------------------
# Kernel-level equivalence: passes and sweeps
# ---------------------------------------------------------------------------

class TestPassEquivalence:
    def test_stacking_pass_grid(self):
        rng = np.random.default_rng(0)
        for seed in range(8):
            for K in (1, 3, 8, 20):
                scn = make_scenario(K=K, seed=seed)
                tp = _tau_prime(scn, float(rng.uniform(0, 2)))
                ids = [s.id for s in scn.services]
                for t_star in (1, 2, 5, 13, 40):
                    assert_plans_equal(
                        stacking_pass(ids, tp, DELAY, t_star),
                        stacking_pass_vec(ids, tp, DELAY, t_star))

    def test_stacking_pass_with_offsets(self):
        rng = np.random.default_rng(1)
        for seed in range(8):
            scn = make_scenario(K=10, tau_min=3.0, tau_max=9.0, seed=seed)
            tp = _tau_prime(scn, 0.5)
            ids = [s.id for s in scn.services]
            off = {k: int(rng.integers(0, 9)) for k in ids}
            for t_star in (1, 4, 9, 22):
                assert_plans_equal(
                    stacking_pass(ids, tp, DELAY, t_star, offsets=off),
                    stacking_pass_vec(ids, tp, DELAY, t_star,
                                      offsets=off))

    def test_tight_deadlines_and_infeasible(self):
        for seed in range(6):
            scn = make_scenario(K=6, tau_min=0.05, tau_max=2.5, seed=seed)
            tp = _tau_prime(scn, 0.3)       # some tau' go negative
            ids = [s.id for s in scn.services]
            for t_star in (0, 1, 3, 7):     # 0 = the degenerate branch
                assert_plans_equal(
                    stacking_pass(ids, tp, DELAY, t_star),
                    stacking_pass_vec(ids, tp, DELAY, t_star))

    def test_zero_services(self):
        """The drop-in contract covers the empty set: both passes
        return an empty plan instead of crashing on an empty
        reduction."""
        assert_plans_equal(stacking_pass([], {}, DELAY, 1),
                           stacking_pass_vec([], {}, DELAY, 1))
        assert stacking_pass_vec([], {}, DELAY, 1).batches == []

    def test_equal_deadline_ties(self):
        """Equal deadlines force Tp AND tau' ties — the id tie-break
        must match the scalar sort exactly."""
        for taus in ([10.0] * 8, [3.0, 3.0, 3.0, 15.0], [5.0] * 6):
            tp = {i: t for i, t in enumerate(taus)}
            ids = list(tp)
            for t_star in (1, 3, 9):
                assert_plans_equal(
                    stacking_pass(ids, tp, DELAY, t_star),
                    stacking_pass_vec(ids, tp, DELAY, t_star))

    def test_offset_pass_targets(self):
        rng = np.random.default_rng(2)
        for seed in range(8):
            scn = make_scenario(K=9, tau_min=2.0, tau_max=8.0, seed=seed)
            tp = _tau_prime(scn, 0.4)
            ids = [s.id for s in scn.services]
            targets = {k: int(rng.integers(0, 12)) for k in ids}
            assert_plans_equal(
                offset_pass(ids, tp, DELAY, targets),
                offset_pass_vec(ids, tp, DELAY, targets))

    def test_sweep_rows_match_single_passes(self):
        """Every row of the batched sweep equals the standalone pass for
        that level — candidates in a batch can't contaminate each
        other."""
        scn = make_scenario(K=12, seed=3)
        tp = _tau_prime(scn, 0.6)
        ids = [s.id for s in scn.services]
        off = {k: k % 4 for k in ids}
        arr = ServiceArrays.build(ids, tp, off)
        levels = list(range(1, 31))
        Tc, ms = sweep_clustered(arr, DELAY, levels)
        for i, level in enumerate(levels):
            plan = stacking_pass(ids, tp, DELAY, level, offsets=off)
            assert [plan.steps_completed[k] for k in ids] == \
                Tc[i].tolist()
            assert plan.makespan() == float(ms[i])
        targets = np.maximum(
            np.asarray(levels)[:, None] - arr.offsets[None, :], 0)
        Tc2, ms2 = sweep_lockstep(arr, DELAY, targets)
        for i, level in enumerate(levels):
            tgt = {k: max(0, level - off[k]) for k in ids}
            plan = offset_pass(ids, tp, DELAY, tgt)
            assert [plan.steps_completed[k] for k in ids] == \
                Tc2[i].tolist()
            assert plan.makespan() == float(ms2[i])


class TestSearchEquivalence:
    def test_stacking_full_search(self):
        for seed in range(10):
            for K in (1, 4, 12, 24):
                scn = make_scenario(K=K, seed=seed)
                tp = _tau_prime(scn, 0.7)
                assert_plans_equal(
                    stacking(scn.services, tp, DELAY, QUALITY,
                             engine="scalar"),
                    stacking(scn.services, tp, DELAY, QUALITY,
                             engine="vec"))

    def test_equal_steps_search(self):
        from repro.api.schedulers import equal_steps
        for seed in range(8):
            scn = make_scenario(K=9, seed=seed)
            tp = _tau_prime(scn, 0.8)
            with engine_scope("scalar"):
                ref = equal_steps(scn.services, tp, DELAY, QUALITY)
            assert_plans_equal(
                ref, equal_steps_vec(scn.services, tp, DELAY, QUALITY))

    def test_first_best_matches_linear_scan(self):
        rng = np.random.default_rng(4)
        rows = rng.integers(0, 6, (40, 5))
        rows[7] = rows[3]                    # force duplicates
        best_i, best_q = first_best(rows, QUALITY)
        ref_i, ref_q = -1, float("inf")
        for i, counts in enumerate(rows.tolist()):
            q = QUALITY.mean_fid(counts)
            if q < ref_q - 1e-12:
                ref_i, ref_q = i, q
        assert (best_i, best_q) == (ref_i, ref_q)

    def test_registry_stacking_scalar_reference(self):
        from repro.api.registry import get_scheduler
        scn = make_scenario(K=6, seed=5)
        tp = _tau_prime(scn, 0.5)
        assert_plans_equal(
            get_scheduler("stacking_scalar")(scn.services, tp, DELAY,
                                             QUALITY),
            get_scheduler("stacking")(scn.services, tp, DELAY, QUALITY))


# ---------------------------------------------------------------------------
# Offset-native replanner equivalence
# ---------------------------------------------------------------------------

class TestOffsetEquivalence:
    def test_plan_with_progress(self):
        rng = np.random.default_rng(5)
        sc, ve = StackingOffset("scalar"), StackingOffset("vec")
        for seed in range(10):
            for K in (1, 2, 5, 12):
                for window in ((3.0, 8.0), (0.3, 2.0), (7.0, 20.0)):
                    scn = make_scenario(K=K, tau_min=window[0],
                                        tau_max=window[1], seed=seed)
                    tp = _tau_prime(scn, float(rng.uniform(0, 1.5)))
                    offs = [int(x) for x in rng.integers(0, 9, K)]
                    assert_plans_equal(
                        sc.plan(scn.services, tp, DELAY, QUALITY, offs),
                        ve.plan(scn.services, tp, DELAY, QUALITY, offs))

    def test_doomed_services(self):
        """A partially-generated service with a negative residual budget
        scores fid(0) — the doomed rule must bind identically."""
        sc, ve = StackingOffset("scalar"), StackingOffset("vec")
        scn = make_scenario(K=5, tau_min=3.0, tau_max=8.0, seed=6)
        tp = _tau_prime(scn, 0.5)
        tp[scn.services[0].id] = -0.5
        offs = [3, 0, 2, 0, 1]
        assert_plans_equal(
            sc.plan(scn.services, tp, DELAY, QUALITY, offs),
            ve.plan(scn.services, tp, DELAY, QUALITY, offs))

    def test_zero_offsets_delegate(self):
        for eng in ("scalar", "vec"):
            so = StackingOffset(eng)
            scn = make_scenario(K=8, seed=7)
            tp = _tau_prime(scn, 0.6)
            assert_plans_equal(
                so(scn.services, tp, DELAY, QUALITY),
                stacking(scn.services, tp, DELAY, QUALITY, engine=eng))


# ---------------------------------------------------------------------------
# Pipeline-level equivalence: online, multi-server, handoff
# ---------------------------------------------------------------------------

class TestPipelineEquivalence:
    def _inv_se(self, scn, scheduler, delay, quality):
        from repro.core.bandwidth import inv_se_allocate
        return inv_se_allocate(scn)

    @pytest.mark.parametrize("sched_name",
                             ["stacking", "stacking_offset",
                              "equal_steps"])
    def test_online_runs_bit_identical(self, sched_name):
        from repro.api.registry import get_scheduler
        sched = get_scheduler(sched_name)
        for seed in range(3):
            scn = make_scenario(K=9, tau_min=3.0, tau_max=8.0,
                                arrival_rate=1.0, seed=seed)
            rs = simulate_online(scn, sched, self._inv_se,
                                 engine="scalar")
            rv = simulate_online(scn, sched, self._inv_se, engine="vec")
            assert rs.outcomes == rv.outcomes
            assert rs.decisions == rv.decisions
            assert rs.mean_fid == rv.mean_fid

    def test_provision_multi_bit_identical(self):
        from repro.core.stacking import stacking as sched
        for seed in range(3):
            scn = make_scenario(K=9, n_servers=3,
                                server_speed_range=(0.6, 1.4), seed=seed)
            assignment = [i % 3 for i in range(scn.K)]
            a = provision_multi(scn, assignment, sched, self._inv_se,
                                engine="scalar")
            b = provision_multi(scn, assignment, sched, self._inv_se,
                                engine="vec")
            assert a.outcomes == b.outcomes
            assert a.mean_fid == b.mean_fid

    @pytest.mark.parametrize("handoff", [False, True])
    def test_online_multi_bit_identical(self, handoff):
        from repro.core.offset import stacking_offset as sched
        for seed in range(2):
            scn = make_scenario(K=9, n_servers=3, arrival_rate=1.0,
                                tau_min=3.0, tau_max=8.0,
                                server_speed_range=(0.6, 1.4), seed=seed)
            a = simulate_online_multi(scn, sched, self._inv_se,
                                      handoff=handoff, engine="scalar")
            b = simulate_online_multi(scn, sched, self._inv_se,
                                      handoff=handoff, engine="vec")
            assert a.result.outcomes == b.result.outcomes
            assert a.result.decisions == b.result.decisions
            assert a.handoffs == b.handoffs
            assert a.handoff_log == b.handoff_log


# ---------------------------------------------------------------------------
# ServiceArrays plumbing
# ---------------------------------------------------------------------------

class TestServiceArrays:
    def test_build_and_index(self):
        arr = ServiceArrays.build([7, 3, 11], {7: 1.5, 3: 2.5, 11: 0.5},
                                  offsets={3: 4})
        assert arr.K == 3
        assert arr.ids.tolist() == [7, 3, 11]
        assert arr.tau_prime.tolist() == [1.5, 2.5, 0.5]
        assert arr.offsets.tolist() == [0, 4, 0]
        assert arr.index == {7: 0, 3: 1, 11: 2}

    def test_vec_plans_validate(self):
        """The vectorized plans satisfy the paper's constraints
        directly, not just by matching the scalar output."""
        for seed in range(4):
            scn = make_scenario(K=10, tau_min=1.0, tau_max=9.0,
                                seed=seed)
            tp = _tau_prime(scn, 0.4)
            plan = stacking(scn.services, tp, DELAY, QUALITY,
                            engine="vec")
            plan.validate(gen_deadlines=tp)
