"""Unified provisioner API: registries, protocols, Provisioner end-to-end
on both workloads, and old-path/new-path equivalence on fixed seeds."""

import numpy as np
import pytest

from repro.api import (ALLOCATORS, SCHEDULERS, WORKLOADS, Provisioner,
                       get_allocator, get_scheduler, get_workload,
                       list_allocators, list_schedulers, list_workloads,
                       register_scheduler)
from repro.core.bandwidth import evaluate, make_plan, pso_allocate
from repro.core.delay_model import DelayModel
from repro.core.optimal import optimal_mean_fid
from repro.core.quality_model import PowerLawFID
from repro.core.service import ServiceRequest, make_scenario
from repro.core.simulator import run_scheme
from repro.core.stacking import stacking

DELAY = DelayModel()
QUALITY = PowerLawFID()


class TestRegistries:
    def test_expected_entries_present(self):
        for name in ("stacking", "greedy", "equal_steps", "optimal",
                     "fixed_size", "single_instance"):
            assert name in SCHEDULERS
        for name in ("equal", "inv_se", "pso", "coordinate"):
            assert name in ALLOCATORS
        for name in ("diffusion", "llm_decode"):
            assert name in WORKLOADS
        assert list_schedulers() == sorted(list_schedulers())
        assert "pso" in list_allocators()
        assert "diffusion" in list_workloads()

    def test_lookup_returns_the_underlying_callable(self):
        assert get_scheduler("stacking") is stacking

    def test_unknown_name_raises_with_candidates(self):
        with pytest.raises(KeyError, match="unknown scheduler 'nope'"):
            get_scheduler("nope")
        with pytest.raises(KeyError, match="registered:.*pso"):
            get_allocator("psso")
        with pytest.raises(KeyError, match="unknown workload"):
            get_workload("video")

    def test_duplicate_registration_rejected(self):
        with pytest.raises(ValueError, match="already registered"):
            register_scheduler("stacking", stacking)

    def test_resolve_passes_instances_through(self):
        def my_sched(services, tau_prime, delay, quality):
            return stacking(services, tau_prime, delay, quality)
        assert SCHEDULERS.resolve(my_sched) is my_sched


class TestSharedPlanHelper:
    def test_evaluate_and_run_scheme_agree_via_make_plan(self):
        """The dedup satellite: both paths must see the identical plan."""
        scn = make_scenario(K=8, seed=5)
        alloc = get_allocator("inv_se")(scn)
        tp, plan = make_plan(scn, alloc, stacking, DELAY, QUALITY)
        fid = evaluate(scn, alloc, stacking, DELAY, QUALITY)
        sim = run_scheme(scn, stacking, DELAY, QUALITY, alloc)
        assert fid == pytest.approx(QUALITY.mean_fid(
            [plan.steps_completed[s.id] for s in scn.services]))
        assert sim.mean_fid == pytest.approx(fid)


class TestNewSchedulers:
    def test_equal_steps_valid_and_balanced(self):
        taus = {i: 10.0 for i in range(6)}
        svcs = [ServiceRequest(id=i, deadline=10.0, spectral_eff=7.0)
                for i in range(6)]
        plan = get_scheduler("equal_steps")(svcs, taus, DELAY, QUALITY)
        plan.validate(gen_deadlines=taus)
        steps = list(plan.steps_completed.values())
        assert max(steps) - min(steps) <= 1

    @pytest.mark.parametrize("taus", [
        [2.0, 3.0, 4.0],
        # boundary case: 5 solo steps for service 0 cost exactly
        # 5*(a+b) = 1.8915 <= 1.894 — grid-quantized DPs got this wrong
        [1.894, 7.944],
    ])
    def test_optimal_matches_dp_bound_and_beats_stacking(self, taus):
        tp = {i: t for i, t in enumerate(taus)}
        svcs = [ServiceRequest(id=i, deadline=t, spectral_eff=7.0)
                for i, t in enumerate(taus)]
        plan = get_scheduler("optimal")(svcs, tp, DELAY, QUALITY)
        plan.validate(gen_deadlines=tp)
        got = QUALITY.mean_fid(list(plan.steps_completed.values()))
        bound = optimal_mean_fid(taus, DELAY, QUALITY)
        st = QUALITY.mean_fid(list(stacking(
            svcs, tp, DELAY, QUALITY).steps_completed.values()))
        assert got <= st + 1e-9           # exact search never loses to Alg.1
        assert got == pytest.approx(bound, abs=1e-9)  # plan == scalar DP

    def test_optimal_never_loses_on_random_instances(self):
        rng = np.random.default_rng(0)
        for _ in range(10):
            taus = list(rng.uniform(1.5, 6.0, size=3))
            tp = {i: t for i, t in enumerate(taus)}
            svcs = [ServiceRequest(id=i, deadline=t, spectral_eff=7.0)
                    for i, t in enumerate(taus)]
            plan = get_scheduler("optimal")(svcs, tp, DELAY, QUALITY)
            plan.validate(gen_deadlines=tp)
            got = QUALITY.mean_fid(list(plan.steps_completed.values()))
            assert got == pytest.approx(
                optimal_mean_fid(taus, DELAY, QUALITY), abs=1e-9)
            st = QUALITY.mean_fid(list(stacking(
                svcs, tp, DELAY, QUALITY).steps_completed.values()))
            assert got <= st + 1e-9

    def test_optimal_refuses_large_instances(self):
        svcs = [ServiceRequest(id=i, deadline=9.0, spectral_eff=7.0)
                for i in range(9)]
        tp = {i: 9.0 for i in range(9)}
        with pytest.raises(AssertionError, match="exact search"):
            get_scheduler("optimal")(svcs, tp, DELAY, QUALITY)


class TestProvisionerAnalytic:
    def test_matches_legacy_pso_path_on_fixed_seed(self):
        scn = make_scenario(K=6, tau_min=4, tau_max=10, seed=3)
        res = pso_allocate(scn, stacking, DELAY, QUALITY,
                           num_particles=6, iters=4, seed=0)
        legacy_sim = run_scheme(scn, stacking, DELAY, QUALITY, res.alloc)

        prov = Provisioner(scn, scheduler="stacking", allocator="pso",
                           allocator_kwargs=dict(num_particles=6, iters=4,
                                                 seed=0))
        report = prov.run()
        assert np.allclose(report.allocation, res.alloc)
        assert report.sim.mean_fid == pytest.approx(legacy_sim.mean_fid)
        assert report.plan.steps_completed == {
            o.id: o.steps for o in legacy_sim.outcomes}
        assert report.content is None          # no workload attached
        assert report.workload_name == ""

    def test_allocator_names_interchangeable(self):
        scn = make_scenario(K=5, seed=9)
        for name in ("equal", "inv_se", "coordinate"):
            report = Provisioner(scn, scheduler="greedy",
                                 allocator=name).run()
            assert report.allocation.sum() == pytest.approx(
                scn.total_bandwidth_hz, rel=1e-6)
            report.plan.validate(gen_deadlines=report.tau_prime)

    def test_refit_requires_timings(self):
        scn = make_scenario(K=4, seed=0)
        report = Provisioner(scn, allocator="equal").run()
        with pytest.raises(ValueError, match="distinct sizes"):
            report.refit_delay()

    def test_refit_without_workload_fails_before_running(self):
        scn = make_scenario(K=4, seed=0)
        with pytest.raises(ValueError, match="attach a workload"):
            Provisioner(scn, allocator="equal").run(refit=True)


@pytest.mark.slow
class TestProvisionerWorkloads:
    def test_diffusion_end_to_end(self):
        import jax
        from repro.api import DiffusionWorkload
        from repro.configs.ddim_cifar10 import SMOKE
        scn = make_scenario(K=3, tau_min=3.0, tau_max=6.0, seed=2)
        prov = Provisioner(scn, workload=DiffusionWorkload(cfg=SMOKE),
                           scheduler="stacking", allocator="inv_se")
        report = prov.run(jax.random.PRNGKey(1), timed=True)
        report.plan.validate(gen_deadlines=report.tau_prime)
        assert set(report.content) == {s.id for s in scn.services}
        assert all(np.isfinite(v).all() for v in report.content.values())
        assert len(report.timings) == report.plan.num_batches
        # calibrate->replan: timings refit the delay model in place
        if len({x for x, _ in report.timings}) >= 2:
            refit = report.refit_delay()
            assert refit.b >= 0 or refit.a >= 0    # a sane affine fit

    def test_llm_decode_end_to_end(self):
        import jax
        scn = make_scenario(K=3, tau_min=0.8, tau_max=1.5,
                            content_bits=1024.0, seed=4)
        prov = Provisioner(scn, workload="llm_decode",
                           scheduler="stacking", allocator="inv_se")
        report = prov.run(jax.random.PRNGKey(0))
        report.plan.validate(gen_deadlines=report.tau_prime)
        assert set(report.content) == {s.id for s in scn.services}
        for sid, toks in report.content.items():
            assert len(toks) == report.plan.steps_completed[sid]
        assert report.workload_name == "llm_decode"
        # the LLM quality model drove the plan, not the FID power law
        assert type(report.quality).__name__ == "TokenQuality"
