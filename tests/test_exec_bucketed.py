"""Device-resident bucketed denoising engine vs the dict reference.

Contract (docs/PERFORMANCE.md): per-row results match the dict engine
within ``MATCH_TOL`` — padded-width XLA programs may fuse differently
from exact-width ones, so bit identity across engines is not promised
(the dict path stays the bit-exact-per-row reference).  The property
test generates arbitrary plan shapes; the fixed-plan tests pin the
scheduling edge cases (retarget mid-scan, composition breaks, zero-step
services) and the compile economics (≤ ⌈log2 K⌉ step programs, warm
second sessions).  Hypothesis is optional — the parametrized fixed
plans cover the same property deterministically when it is absent.
"""

import math

import numpy as np
import pytest

pytest.importorskip("jax")

import jax
import jax.numpy as jnp

from repro.configs.ddim_cifar10 import UNetConfig
from repro.core.delay_model import DelayModel
from repro.core.execution import (EXEC_ENGINES, exec_engine_default,
                                  shape_bucket)
from repro.core.plan import BatchPlan
from repro.diffusion import unet
from repro.diffusion.bucketed import (MATCH_TOL, BucketedDenoiseSession,
                                      _SCAN_CHUNKS)
from repro.diffusion.executor import BatchDenoisingExecutor
from repro.models.params import init_params

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

# tiny U-Net: per-program compile is cheap, so the suite affords the
# handful of (pool_rows, bucket) shapes these tests touch
MICRO = UNetConfig(name="ddim-micro-test", image_size=8, base_channels=8,
                   channel_mults=(1,), num_res_blocks=1,
                   attn_resolutions=(), num_groups=4)


@pytest.fixture(scope="module")
def ex():
    params = init_params(unet.schema(MICRO), jax.random.PRNGKey(0))
    return BatchDenoisingExecutor(MICRO, params)


def make_plan(counts, batches):
    """A BatchPlan from explicit (service -> total steps) counts and an
    explicit batch sequence (list of id-lists)."""
    idx = {k: 0 for k in counts}
    bb = []
    for ks in batches:
        bb.append([(k, idx[k]) for k in ks])
        for k in ks:
            idx[k] += 1
    assert idx == dict(counts), "batches disagree with step counts"
    return BatchPlan(batches=bb, start_times=[0.0] * len(bb),
                     steps_completed=dict(counts), delay=DelayModel())


def stacking_batches(counts):
    """All-active-together rounds (the STACKING shape: composition
    shrinks as services retire)."""
    rem = dict(counts)
    out = []
    while any(v > 0 for v in rem.values()):
        ks = sorted(k for k, v in rem.items() if v > 0)
        out.append(ks)
        for k in ks:
            rem[k] -= 1
    return out


def assert_rows_match(got, want):
    assert set(got) == set(want)
    for k in want:
        np.testing.assert_allclose(got[k], want[k], **MATCH_TOL,
                                   err_msg=f"service {k}")


class TestEnginesMatch:
    @pytest.mark.parametrize("counts", [
        {0: 3, 1: 3, 2: 3},                    # one stable phase
        {0: 5, 1: 3, 2: 1, 3: 0},              # staggered + zero-step
        {0: 1, 1: 2, 2: 3, 3: 4, 4: 7},        # many distinct sizes
    ])
    def test_fixed_plans(self, ex, counts):
        plan = make_plan(counts, stacking_batches(counts))
        key = jax.random.PRNGKey(42)
        want, _ = ex.run(plan, key, exec_engine="dict")
        got, _ = ex.run(plan, key, exec_engine="bucketed")
        assert_rows_match(got, want)

    def test_timed_matches_untimed_bucketed(self, ex):
        """Timed execution is stepwise (no scan fusion) but must land
        on the same images as the scan-fused untimed path."""
        counts = {0: 4, 1: 4, 2: 2}
        plan = make_plan(counts, stacking_batches(counts))
        key = jax.random.PRNGKey(7)
        plain, no_t = ex.run(plan, key, exec_engine="bucketed")
        timed, ts = ex.run(plan, key, timed=True, exec_engine="bucketed")
        assert no_t == [] and len(ts) == plan.num_batches
        assert_rows_match(timed, plain)

    def test_zero_step_latent_untouched(self, ex):
        """Parity with the dict regression: a service the planner
        retired at T=0 comes back as its seeded noise, exactly."""
        plan = make_plan({0: 0, 1: 2}, [[1], [1]])
        key = jax.random.PRNGKey(13)
        imgs, _ = ex.run(plan, key, exec_engine="bucketed")
        k0 = jax.random.split(key, 2)[0]
        raw = jax.random.normal(k0, (8, 8, 3), jnp.float32)
        np.testing.assert_array_equal(imgs[0], np.asarray(raw))

    if HAVE_HYPOTHESIS:
        @settings(max_examples=15, deadline=None)
        @given(counts=st.lists(st.integers(0, 5), min_size=1,
                               max_size=4),
               drop=st.integers(0, 2 ** 16 - 1),
               seed=st.integers(0, 2 ** 16 - 1))
        def test_property_bucket_equals_dict(self, ex, counts, drop,
                                             seed):
            """Arbitrary plan shapes: per-row bucketed == dict within
            MATCH_TOL.  ``drop`` perturbs the all-active composition by
            deferring one service's steps, so compositions mix."""
            counts = {k: c for k, c in enumerate(counts)}
            victim = drop % max(len(counts), 1)
            deferred = min(counts.get(victim, 0), drop // 7 % 3)
            head = dict(counts)
            head[victim] = counts[victim] - deferred
            batches = stacking_batches(head)
            batches += [[victim]] * deferred
            plan = make_plan(counts, batches)
            key = jax.random.PRNGKey(seed)
            want, _ = ex.run(plan, key, exec_engine="dict")
            got, _ = ex.run(plan, key, exec_engine="bucketed")
            assert_rows_match(got, want)


class TestScheduling:
    def test_retarget_mid_scan(self, ex):
        """Retargeting between run_plan calls (i.e. between fused scan
        megasteps) lands on the same images as the dict session driven
        identically."""
        counts = {0: 6, 1: 6, 2: 6}
        key = jax.random.PRNGKey(3)
        sessions = [ex.open_session(make_plan(counts,
                                              stacking_batches(counts)),
                                    key, exec_engine=e)
                    for e in ("dict", "bucketed")]
        for sess in sessions:
            sess.run_plan([[0, 1, 2]] * 3)        # fused on bucketed
            sess.retarget({0: 4, 1: 8})           # shrink / stretch
            sess.run_batch([0, 1, 2])
            sess.run_plan([[1, 2]] * 2)           # 0 retired at 4
            sess.run_plan([[1]] * 2)
            assert sess.steps_done == {0: 4, 1: 8, 2: 6}
        want, got = sessions[0].finish(), sessions[1].finish()
        assert_rows_match(got, want)

    def test_scan_breaks_on_composition_change(self, ex):
        """A composition change must end the fused run — and the same
        batch SIZE with different members is a different composition."""
        counts = {0: 5, 1: 4, 2: 2}
        batches = [[0, 1], [0, 1], [0, 1], [0, 2], [0, 2], [1]]
        plan = make_plan(counts, batches)
        sess = ex.open_session(plan, jax.random.PRNGKey(9),
                               exec_engine="bucketed")
        sess.run_plan([list(b) for b in batches])
        tele = sess.telemetry()
        # [0,1]x3 -> scan(2)+step; [0,2]x2 -> scan(2); [1] -> step
        assert tele["scan_fused_steps"] == 4
        assert tele["scan_dispatches"] == {"b2_c2": 2}
        assert tele["by_bucket"] == {"2": 2}
        # and the images still match the dict path
        want, _ = ex.run(plan, jax.random.PRNGKey(9), exec_engine="dict")
        assert_rows_match(sess.finish(), want)

    def test_retarget_errors_preserved(self, ex):
        """The inherited no-resurrection rules hold on the pool path."""
        counts = {0: 3, 1: 3}
        plan = make_plan(counts, stacking_batches(counts))
        sess = ex.open_session(plan, jax.random.PRNGKey(1),
                               exec_engine="bucketed")
        sess.run_batch([0, 1])
        with pytest.raises(ValueError, match="already executed"):
            sess.retarget({0: 0})
        sess.retarget({0: 1})
        with pytest.raises(ValueError, match="no remaining"):
            sess.run_batch([0])


class TestCompileEconomics:
    def test_recompile_bound(self):
        """A mixed-size plan over K services compiles at most
        ⌈log2 K⌉ step programs (power-of-two buckets, min 2)."""
        params = init_params(unet.schema(MICRO), jax.random.PRNGKey(0))
        fresh = BatchDenoisingExecutor(MICRO, params)
        K = 8
        counts = {k: k + 1 for k in range(K)}    # sizes 8,7,...,1
        plan = make_plan(counts, stacking_batches(counts))
        sess = fresh.open_session(plan, jax.random.PRNGKey(2),
                                  exec_engine="bucketed")
        for b in plan.batches:                   # stepwise: no scans
            sess.run_batch([k for k, _ in b])
        steps = [k for k, _ in fresh.compile_log if k[0] == "bstep"]
        assert len(steps) <= math.ceil(math.log2(K))
        assert {k[2] for k in steps} <= \
            {shape_bucket(n) for n in range(1, K + 1)}

    def test_second_session_is_warm(self, ex):
        counts = {0: 2, 1: 2, 2: 1}
        plan = make_plan(counts, stacking_batches(counts))
        ex.run(plan, jax.random.PRNGKey(4), exec_engine="bucketed")
        sess = ex.open_session(plan, jax.random.PRNGKey(5),
                               exec_engine="bucketed")
        sess.run_plan(stacking_batches(counts))
        tele = sess.telemetry()
        assert tele["compiles"] == 0 and tele["compile_s"] == 0.0
        assert tele["dispatches"] > 0

    def test_delay_curve_shares_bucket_programs(self):
        """Sweeping 1..8 compiles 3 bucket programs, not 8, and the
        compile time lands in last_compile_log, not the readings."""
        params = init_params(unet.schema(MICRO), jax.random.PRNGKey(0))
        fresh = BatchDenoisingExecutor(MICRO, params)
        curve = fresh.measure_delay_curve(jax.random.PRNGKey(6),
                                          batch_sizes=range(1, 9),
                                          reps=2, exec_engine="bucketed")
        assert [x for x, _ in curve] == list(range(1, 9))
        assert len(fresh.last_compile_log) == 3      # buckets 2, 4, 8
        # same-bucket sizes pay the same padded cost; readings are
        # steady-state, far under any compile time
        assert all(s < c for _, s in curve
                   for _, c in fresh.last_compile_log)


class TestEngineKnob:
    def test_registry_and_default(self, monkeypatch):
        assert EXEC_ENGINES == ("dict", "bucketed")
        monkeypatch.delenv("REPRO_EXEC_ENGINE", raising=False)
        assert exec_engine_default() == "dict"
        monkeypatch.setenv("REPRO_EXEC_ENGINE", "bucketed")
        assert exec_engine_default() == "bucketed"

    def test_env_default_opens_bucketed(self, ex, monkeypatch):
        monkeypatch.setenv("REPRO_EXEC_ENGINE", "bucketed")
        plan = make_plan({0: 1}, [[0]])
        sess = ex.open_session(plan, jax.random.PRNGKey(0))
        assert isinstance(sess, BucketedDenoiseSession)

    def test_unknown_engine_rejected(self, ex):
        plan = make_plan({0: 1}, [[0]])
        with pytest.raises(ValueError, match="unknown exec_engine"):
            ex.open_session(plan, jax.random.PRNGKey(0),
                            exec_engine="gpu")
        with pytest.raises(ValueError, match="unknown exec_engine"):
            BatchDenoisingExecutor(MICRO, ex.params, exec_engine="gpu")

    def test_shape_bucket_grid(self):
        assert [shape_bucket(n) for n in (1, 2, 3, 4, 5, 8, 9, 16)] == \
            [2, 2, 4, 4, 8, 8, 16, 16]
        assert _SCAN_CHUNKS[-1] == 2      # remainder is at most 1 step
