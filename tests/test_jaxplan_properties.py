"""Hypothesis properties for the jit-compiled jax planner engine,
mirroring tests/test_arrays_properties.py with the jax engine's
contract: for ARBITRARY inputs the objective must match the NumPy
reference within the documented tolerance (docs/PERFORMANCE.md) and
the returned plan must satisfy the paper's constraints.  Bit identity
is *not* asserted — XLA reassociation can flip exactly-tied candidate
choices.  Skipped when hypothesis or jax is missing.

Budgets are kept small (tau' <= 4) so the candidate-level axis stays
within a couple of jit shape buckets — the suite pays a handful of
compiles, not one per example.
"""

import numpy as np
import pytest

pytest.importorskip("hypothesis")
pytest.importorskip("jax")

from hypothesis import given, settings, strategies as st

import repro.core.jaxplan as jaxplan
from repro.core import arrays
from repro.core.delay_model import DelayModel
from repro.core.offset import StackingOffset
from repro.core.online import _OffsetQuality
from repro.core.quality_model import PowerLawFID
from repro.core.service import ServiceRequest
from repro.core.stacking import stacking

DELAY = DelayModel()          # paper constants
QUALITY = PowerLawFID()
TOL = 1e-9                    # documented objective tolerance


def _services(taus):
    return [ServiceRequest(id=i, deadline=t, spectral_eff=7.0)
            for i, t in enumerate(taus)]


def _tau_prime(taus):
    return {i: t for i, t in enumerate(taus)}


def _fid(plan, ids, oq=QUALITY):
    return oq.mean_fid([plan.steps_completed[k] for k in ids])


taus_strategy = st.lists(
    st.floats(min_value=0.05, max_value=4.0, allow_nan=False,
              allow_infinity=False),
    min_size=1, max_size=8)


@settings(max_examples=25, deadline=None)
@given(taus=taus_strategy)
def test_full_search_jax_matches_vec(taus):
    svcs, tp = _services(taus), _tau_prime(taus)
    ids = list(range(len(taus)))
    vec = stacking(svcs, tp, DELAY, QUALITY, engine="vec")
    jx = stacking(svcs, tp, DELAY, QUALITY, engine="jax")
    assert abs(_fid(vec, ids) - _fid(jx, ids)) < TOL
    jx.validate(gen_deadlines=tp)   # and the paper's constraints hold


@settings(max_examples=20, deadline=None)
@given(taus=taus_strategy, data=st.data())
def test_offset_scheduler_jax_matches_vec(taus, data):
    svcs, tp = _services(taus), _tau_prime(taus)
    ids = list(range(len(taus)))
    offs = [data.draw(st.integers(0, 8)) for _ in taus]
    pv = StackingOffset("vec").plan(svcs, tp, DELAY, QUALITY, offs)
    pj = StackingOffset("jax").plan(svcs, tp, DELAY, QUALITY, offs)
    oq = _OffsetQuality(QUALITY, offs)
    oq.refresh_doomed(svcs, tp)
    assert abs(_fid(pv, ids, oq) - _fid(pj, ids, oq)) < TOL


@settings(max_examples=20, deadline=None)
@given(taus=taus_strategy)
def test_equal_steps_jax_matches_vec(taus):
    from repro.api.schedulers import equal_steps
    svcs, tp = _services(taus), _tau_prime(taus)
    ids = list(range(len(taus)))
    ref = arrays.equal_steps_vec(svcs, tp, DELAY, QUALITY)
    with arrays.engine_scope("jax"):
        jx = equal_steps(svcs, tp, DELAY, QUALITY)
    assert abs(_fid(ref, ids) - _fid(jx, ids)) < TOL
    jx.validate(gen_deadlines=tp)


# -- the sort-free per-round selection (ISSUE 7) ------------------------
#
# kernels._select_kth_key replaced the full composite-key sort inside
# the clustered sweep.  Its decision contract: for composite keys
# ``Tp * M + tie`` (tie a permutation of 0..K-1, so keys are unique
# even when every Tp collides) it returns exactly the x_n-th smallest
# key — the batching threshold — for EVERY x_n in 1..n_active.  The
# instances below are adversarially tie-heavy: Tp drawn from a tiny
# value set so most keys differ only in their tie rank.

@settings(max_examples=30, deadline=None)
@given(data=st.data())
def test_radix_select_matches_full_sort_on_tie_heavy_keys(data):
    from repro.core.jaxplan import kernels
    import jax.numpy as jnp

    K = data.draw(st.integers(2, 24))
    L = data.draw(st.integers(1, 4))
    # duplicate-heavy Tp rows: values from a set much smaller than K
    tp_vals = data.draw(st.lists(st.integers(0, 6), min_size=1,
                                 max_size=3))
    rng = np.random.default_rng(data.draw(st.integers(0, 2**31)))
    Tp = rng.choice(tp_vals, size=(L, K)).astype(np.int64)
    tie = rng.permutation(K).astype(np.int64)     # permuted tie ranks
    M = np.int64(1) << np.int64(max(K, 1).bit_length())
    key_np = Tp * M + tie[None, :]
    key_bits = int((int(key_np.max()) + 1).bit_length())

    # every batch size x_n in 1..K for every row, as one stacked call
    key_all = np.repeat(key_np, K, axis=0)        # (L*K, K)
    x_all = np.tile(np.arange(1, K + 1, dtype=np.int64), L)
    with kernels.enable_x64():
        key = jnp.asarray(key_all)
        x_n = jnp.asarray(x_all)
        sel = np.asarray(kernels._select_kth_key(key, x_n, key_bits))
        ref = np.asarray(kernels._sort_kth_key(key, x_n))
    assert np.array_equal(sel, ref)
    # and the decision it feeds — the round's membership set — is
    # identical too
    assert np.array_equal(key_all <= sel[:, None],
                          key_all <= ref[:, None])


@settings(max_examples=20, deadline=None)
@given(data=st.data())
def test_tie_heavy_stacking_jax_matches_vec(data):
    """End to end: budgets drawn from a tiny value set (maximal tau'
    ties -> maximal tie-break pressure on the selection) still meet
    the engine contract."""
    vals = data.draw(st.lists(
        st.floats(min_value=0.5, max_value=4.0, allow_nan=False),
        min_size=1, max_size=2))
    taus = [vals[i % len(vals)]
            for i in range(data.draw(st.integers(2, 10)))]
    svcs, tp = _services(taus), _tau_prime(taus)
    ids = list(range(len(taus)))
    vec = stacking(svcs, tp, DELAY, QUALITY, engine="vec")
    jx = stacking(svcs, tp, DELAY, QUALITY, engine="jax")
    assert abs(_fid(vec, ids) - _fid(jx, ids)) < TOL
    jx.validate(gen_deadlines=tp)


@settings(max_examples=15, deadline=None)
@given(scenarios=st.lists(taus_strategy, min_size=1, max_size=6))
def test_plan_many_matches_per_scenario_vec(scenarios):
    K = max(len(t) for t in scenarios)
    S = len(scenarios)
    taus = np.zeros((S, K))
    valid = np.zeros((S, K), dtype=bool)
    for s, row in enumerate(scenarios):
        taus[s, :len(row)] = row
        valid[s, :len(row)] = True
    res = jaxplan.plan_many(taus, delay=DELAY, quality=QUALITY,
                            valid=valid)
    for s, row in enumerate(scenarios):
        tp = _tau_prime(row)
        ids = list(range(len(row)))
        pv = arrays.stacking_vec(_services(row), tp, DELAY, QUALITY)
        assert abs(_fid(pv, ids) - res.mean_fid[s]) < TOL
