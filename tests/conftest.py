import os

# Smoke tests and benches must see the single real CPU device; ONLY
# launch/dryrun.py sets the 512-device placeholder flag (and runs in its
# own process).
os.environ.setdefault("JAX_PLATFORMS", "cpu")
