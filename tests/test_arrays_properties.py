"""Hypothesis properties for the array-native planning engine.

The invariant is stronger than "both valid": for ARBITRARY inputs the
vectorized kernels must return bit-identical plans to the scalar
reference — batches, start times, step counts, makespan.  Skipped (not
a collection error) when ``hypothesis`` is not installed.
"""

import pytest

pytest.importorskip("hypothesis")

from hypothesis import given, settings, strategies as st

from repro.core.arrays import (equal_steps_vec, offset_pass_vec,
                               stacking_pass_vec)
from repro.core.delay_model import DelayModel
from repro.core.offset import StackingOffset, offset_pass
from repro.core.quality_model import PowerLawFID
from repro.core.service import ServiceRequest
from repro.core.stacking import stacking, stacking_pass

DELAY = DelayModel()          # paper constants
QUALITY = PowerLawFID()


def _services(taus):
    return [ServiceRequest(id=i, deadline=t, spectral_eff=7.0)
            for i, t in enumerate(taus)]


def _tau_prime(taus):
    return {i: t for i, t in enumerate(taus)}


def _assert_same(a, b):
    assert a.batches == b.batches
    assert a.start_times == b.start_times
    assert a.steps_completed == b.steps_completed


taus_strategy = st.lists(
    st.floats(min_value=0.05, max_value=30.0, allow_nan=False,
              allow_infinity=False),
    min_size=1, max_size=12)


@settings(max_examples=60, deadline=None)
@given(taus=taus_strategy, t_star=st.integers(1, 50))
def test_pass_vec_equals_scalar(taus, t_star):
    tp = _tau_prime(taus)
    ids = list(range(len(taus)))
    _assert_same(stacking_pass(ids, tp, DELAY, t_star),
                 stacking_pass_vec(ids, tp, DELAY, t_star))


@settings(max_examples=40, deadline=None)
@given(taus=taus_strategy, t_star=st.integers(1, 40),
       data=st.data())
def test_pass_vec_equals_scalar_with_offsets(taus, t_star, data):
    tp = _tau_prime(taus)
    ids = list(range(len(taus)))
    off = {k: data.draw(st.integers(0, 10)) for k in ids}
    _assert_same(stacking_pass(ids, tp, DELAY, t_star, offsets=off),
                 stacking_pass_vec(ids, tp, DELAY, t_star, offsets=off))


@settings(max_examples=30, deadline=None)
@given(taus=taus_strategy)
def test_full_search_vec_equals_scalar(taus):
    svcs = _services(taus)
    tp = _tau_prime(taus)
    vec = stacking(svcs, tp, DELAY, QUALITY, engine="vec")
    _assert_same(stacking(svcs, tp, DELAY, QUALITY, engine="scalar"),
                 vec)
    vec.validate(gen_deadlines=tp)   # and the paper's constraints hold


@settings(max_examples=30, deadline=None)
@given(taus=taus_strategy, data=st.data())
def test_lockstep_vec_equals_scalar(taus, data):
    tp = _tau_prime(taus)
    ids = list(range(len(taus)))
    targets = {k: data.draw(st.integers(0, 15)) for k in ids}
    _assert_same(offset_pass(ids, tp, DELAY, targets),
                 offset_pass_vec(ids, tp, DELAY, targets))


@settings(max_examples=25, deadline=None)
@given(taus=st.lists(st.floats(min_value=0.3, max_value=15.0),
                     min_size=1, max_size=8),
       data=st.data())
def test_offset_scheduler_vec_equals_scalar(taus, data):
    svcs = _services(taus)
    tp = _tau_prime(taus)
    offs = [data.draw(st.integers(0, 8)) for _ in taus]
    plan_s = StackingOffset("scalar").plan(svcs, tp, DELAY, QUALITY,
                                           offs)
    plan_v = StackingOffset("vec").plan(svcs, tp, DELAY, QUALITY, offs)
    _assert_same(plan_s, plan_v)


@settings(max_examples=25, deadline=None)
@given(taus=taus_strategy)
def test_equal_steps_vec_equals_scalar(taus):
    from repro.api.schedulers import equal_steps
    from repro.core.arrays import engine_scope
    svcs = _services(taus)
    tp = _tau_prime(taus)
    with engine_scope("scalar"):
        ref = equal_steps(svcs, tp, DELAY, QUALITY)
    _assert_same(ref, equal_steps_vec(svcs, tp, DELAY, QUALITY))
