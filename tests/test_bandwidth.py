"""Bandwidth allocation (P1): PSO, closed-form splits, refinement."""

import numpy as np
import pytest

from repro.core.bandwidth import (coordinate_refine, equal_allocate,
                                  evaluate, inv_se_allocate, pso_allocate,
                                  tau_prime_of)
from repro.core.delay_model import DelayModel
from repro.core.quality_model import PowerLawFID
from repro.core.service import make_scenario
from repro.core.stacking import stacking

DELAY = DelayModel()
QUALITY = PowerLawFID()


def _sched(svcs, tp, d, q):
    return stacking(svcs, tp, d, q)


class TestAllocators:
    def test_budget_respected(self):
        scn = make_scenario(K=8, seed=3)
        for alloc in (equal_allocate(scn), inv_se_allocate(scn)):
            assert alloc.sum() == pytest.approx(scn.total_bandwidth_hz)
            assert (alloc > 0).all()

    def test_inv_se_equalizes_tx_delay(self):
        scn = make_scenario(K=6, seed=1)
        alloc = inv_se_allocate(scn)
        delays = [s.tx_delay(alloc[i], scn.content_bits)
                  for i, s in enumerate(scn.services)]
        assert np.ptp(delays) < 1e-9

    def test_tau_prime_positive_for_sane_scenarios(self):
        scn = make_scenario(K=20, seed=0)
        tp = tau_prime_of(scn, equal_allocate(scn))
        assert all(v > 0 for v in tp.values())

    def test_pso_improves_on_equal(self):
        scn = make_scenario(K=10, tau_min=4, tau_max=18, seed=7)
        f_equal = evaluate(scn, equal_allocate(scn), _sched, DELAY, QUALITY)
        res = pso_allocate(scn, _sched, DELAY, QUALITY,
                           num_particles=10, iters=8, seed=0)
        assert res.fid <= f_equal + 1e-9
        assert res.alloc.sum() == pytest.approx(scn.total_bandwidth_hz,
                                                rel=1e-6)
        # history is monotone non-increasing (gbest tracking)
        assert all(a >= b - 1e-12 for a, b in
                   zip(res.history, res.history[1:]))

    def test_coordinate_refine_never_worse(self):
        scn = make_scenario(K=8, tau_min=4, tau_max=15, seed=11)
        start = inv_se_allocate(scn)
        f0 = evaluate(scn, start, _sched, DELAY, QUALITY)
        res = coordinate_refine(scn, start, _sched, DELAY, QUALITY,
                                rounds=2)
        assert res.fid <= f0 + 1e-9
        assert res.alloc.sum() == pytest.approx(scn.total_bandwidth_hz,
                                                rel=1e-6)

    def test_coordinate_refine_respects_floor_per_transfer(self):
        """Regression: the min_frac floor was only checked once per donor
        sweep, so several accepted transfers from one donor could push it
        below the floor — even negative.  Make many transfers profitable
        with a quality model that loves a single service."""
        class FavoriteOnly:
            def fid(self, steps):
                return QUALITY.fid(steps)

            def mean_fid(self, counts):
                return QUALITY.fid(counts[0])   # only service 0 matters

        scn = make_scenario(K=6, tau_min=4, tau_max=8, seed=2)
        min_frac = 1e-3
        res = coordinate_refine(scn, equal_allocate(scn), _sched, DELAY,
                                FavoriteOnly(), rounds=6,
                                step_frac=0.2, min_frac=min_frac)
        assert (res.alloc >= min_frac * scn.total_bandwidth_hz - 1e-9).all()
        assert res.alloc.sum() == pytest.approx(scn.total_bandwidth_hz,
                                                rel=1e-6)
