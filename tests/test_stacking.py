"""STACKING + baselines unit tests.

The hypothesis property tests (constraints (1), (2), (6), (7), (14) via
``BatchPlan.validate`` on arbitrary inputs) live in
``test_stacking_properties.py``, guarded by ``pytest.importorskip`` so a
missing ``hypothesis`` skips them instead of erroring collection."""

from repro.core.baselines import (fixed_size_batching, greedy_batching,
                                  single_instance)
from repro.core.delay_model import DelayModel
from repro.core.optimal import optimal_mean_fid
from repro.core.quality_model import PowerLawFID
from repro.core.service import ServiceRequest, make_scenario
from repro.core.stacking import stacking

DELAY = DelayModel()          # paper constants
QUALITY = PowerLawFID()


def _services(taus):
    return [ServiceRequest(id=i, deadline=t, spectral_eff=7.0)
            for i, t in enumerate(taus)]


def _tau_prime(taus):
    return {i: t for i, t in enumerate(taus)}


# ---------------------------------------------------------------------------
# Unit
# ---------------------------------------------------------------------------

class TestStackingBasics:
    def test_single_service(self):
        svcs = _services([5.0])
        plan = stacking(svcs, _tau_prime([5.0]), DELAY, QUALITY)
        plan.validate(gen_deadlines=_tau_prime([5.0]))
        # 5.0 / (a+b) = 13.2 -> 13 dedicated steps
        assert plan.steps_completed[0] == DELAY.max_steps(5.0) == 13

    def test_infeasible_service_gets_zero(self):
        taus = [0.1, 10.0]
        plan = stacking(_services(taus), _tau_prime(taus), DELAY, QUALITY)
        plan.validate(gen_deadlines=_tau_prime(taus))
        assert plan.steps_completed[0] == 0
        assert plan.steps_completed[1] > 0

    def test_equal_deadlines_equal_steps(self):
        """Fig. 2a: similar deadlines -> similar step counts."""
        taus = [10.0] * 8
        plan = stacking(_services(taus), _tau_prime(taus), DELAY, QUALITY)
        steps = list(plan.steps_completed.values())
        assert max(steps) - min(steps) <= 1

    def test_beats_or_matches_greedy_and_fixed(self):
        for seed in range(5):
            scn = make_scenario(K=12, seed=seed)
            tp = {s.id: s.deadline - 1.0 for s in scn.services}
            q_stack = QUALITY.mean_fid(list(stacking(
                scn.services, tp, DELAY, QUALITY).steps_completed.values()))
            q_greedy = QUALITY.mean_fid(list(greedy_batching(
                scn.services, tp, DELAY).steps_completed.values()))
            q_fixed = QUALITY.mean_fid(list(fixed_size_batching(
                scn.services, tp, DELAY).steps_completed.values()))
            assert q_stack <= q_greedy + 1e-9
            assert q_stack <= q_fixed + 1e-9

    def test_tight_deadlines_prioritized(self):
        """Fig. 2a: the first batches contain the tight services."""
        taus = [3.0, 3.5, 15.0, 16.0]
        plan = stacking(_services(taus), _tau_prime(taus), DELAY, QUALITY)
        first_ids = {k for k, _ in plan.batches[0]}
        assert 0 in first_ids and 1 in first_ids

    def test_empty_priority_cluster_tight_deadlines(self):
        """The no-priority-cluster packing branch (ISSUE 5): with tight
        deadlines and a small T*, every projected count sits above the
        water level, so F is empty and packing falls through to the
        tp_min-based cap.  The branch must still pack at least the most
        urgent service each round (cap clamped >= 1 — an empty F forces
        tp_min > T*, so the cap is mathematically >= 1, and the clamp
        keeps adversarial direct calls from a degenerate count)."""
        from repro.core.stacking import stacking_pass
        taus = [1.6, 1.7, 1.9, 2.1]
        tp = _tau_prime(taus)
        ids = list(tp)
        # t_star=1: every Tp = Te >= 4 > 1 at round 0 -> F empty
        plan = stacking_pass(ids, tp, DELAY, t_star=1)
        plan.validate(gen_deadlines=tp)
        assert plan.num_batches > 0
        assert all(len(b) >= 1 for b in plan.batches)
        # the most urgent service leads the first batch
        assert plan.batches[0][0][0] == 0
        # the degenerate-input guard: t_star <= 0 must not crash and
        # must still produce a valid plan (cap would be meaningless)
        for t_star in (0, -3):
            p = stacking_pass(ids, tp, DELAY, t_star)
            p.validate(gen_deadlines=tp)

    def test_near_optimal_small_instance(self):
        """Optimality gap vs. exact DP on a tiny instance (beyond-paper)."""
        taus = [2.0, 3.0, 4.0]
        plan = stacking(_services(taus), _tau_prime(taus), DELAY, QUALITY)
        got = QUALITY.mean_fid(list(plan.steps_completed.values()))
        opt = optimal_mean_fid(taus, DELAY, QUALITY)
        assert got <= opt * 1.10 + 1e-9   # within 10% of optimal


class TestBaselines:
    def test_single_instance_processes_in_deadline_order(self):
        taus = [9.0, 3.0, 6.0]
        plan = single_instance(_services(taus), _tau_prime(taus), DELAY,
                               QUALITY)
        plan.validate(gen_deadlines=_tau_prime(taus))
        order = [k for b in plan.batches for k, _ in b]
        first_of = {k: order.index(k) for k in set(order)}
        assert first_of[1] < first_of[2] < first_of[0]
        assert all(len(b) == 1 for b in plan.batches)

    def test_greedy_batches_everyone(self):
        taus = [10.0] * 6
        plan = greedy_batching(_services(taus), _tau_prime(taus), DELAY)
        plan.validate(gen_deadlines=_tau_prime(taus))
        assert all(len(b) == 6 for b in plan.batches)

    def test_fixed_size_cap(self):
        taus = [12.0] * 10
        plan = fixed_size_batching(_services(taus), _tau_prime(taus), DELAY)
        plan.validate(gen_deadlines=_tau_prime(taus))
        assert max(len(b) for b in plan.batches) <= 5
