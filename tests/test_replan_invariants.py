"""Property-style replan invariants (ISSUE 3 satellite): across seeds,
schedulers and allocators, online replanning must never double-execute
or resurrect work, never oversubscribe a (per-cell) bandwidth budget,
and must degenerate to the static pipeline when nothing is online."""

import pytest

from repro.api import Provisioner, get_allocator, get_scheduler
from repro.core.delay_model import DelayModel
from repro.core.multiserver import MultiOnlineSimulation
from repro.core.online import OnlineSimulation
from repro.core.quality_model import PowerLawFID
from repro.core.service import make_scenario

DELAY = DelayModel()
QUALITY = PowerLawFID()

CASES = [("stacking", "inv_se", 0), ("stacking", "coordinate", 1),
         ("greedy", "equal", 2), ("equal_steps", "inv_se", 3),
         ("stacking", "inv_se", 4)]


def _run_online(scheduler, allocator, seed, **scn_kw):
    scn = make_scenario(arrival_rate=1.0, seed=seed, **scn_kw)
    sim = OnlineSimulation(scn, get_scheduler(scheduler),
                           get_allocator(allocator), DELAY, QUALITY,
                           admission=lambda *a: True)
    res = sim.run()
    return scn, sim, res


class TestNoResurrection:
    """A replan schedules *additional* steps: the executed-step log per
    service must be exactly 1, 2, ..., T with strictly increasing start
    times — a preempted (replaced-before-start) batch never runs, and
    no step is ever counted twice."""

    @pytest.mark.parametrize("scheduler,allocator,seed", CASES)
    def test_steps_contiguous_and_monotone(self, scheduler, allocator,
                                           seed):
        _, sim, res = _run_online(scheduler, allocator, seed, K=10,
                                  tau_min=2.0, tau_max=6.0)
        per_svc = {}
        for t_start, k, cum in sim.track.executed_log:
            per_svc.setdefault(k, []).append((t_start, cum))
        for k, entries in per_svc.items():
            counts = [c for _, c in entries]
            assert counts == list(range(1, len(counts) + 1)), \
                f"service {k} steps not contiguous: {counts}"
            starts = [t for t, _ in entries]
            assert all(b >= a - 1e-12
                       for a, b in zip(starts, starts[1:]))
        # the log and the final outcomes agree on totals
        by_id = {o.id: o for o in res.outcomes}
        for k, entries in per_svc.items():
            assert by_id[k].steps == len(entries)

    @pytest.mark.parametrize("scheduler,allocator,seed", CASES)
    def test_batch_starts_monotone_within_track(self, scheduler,
                                                allocator, seed):
        """The server executes one batch at a time: distinct start times
        never interleave backwards (an adopted replan can only append
        *after* everything already run)."""
        _, sim, _ = _run_online(scheduler, allocator, seed, K=8,
                                tau_min=2.0, tau_max=5.0)
        starts = [t for t, _, _ in sim.track.executed_log]
        assert all(b >= a - 1e-12 for a, b in zip(starts, starts[1:]))


class TestBudgetNeverExceeded:
    """After any chain of replans (including coordinate_refine moving
    bandwidth between services), concurrent transmissions never sum past
    the channel budget — per cell in the multi-server case."""

    @pytest.mark.parametrize("allocator", ["inv_se", "coordinate"])
    def test_single_server_concurrent_tx_within_budget(self, allocator):
        scn, sim, _ = _run_online("stacking", allocator, 0, K=12,
                                  tau_min=1.0, tau_max=3.0,
                                  content_bits_range=(65536.0, 262144.0))
        spans = [(st.gen_end, st.tx_end, st.bandwidth)
                 for st in sim.states.values() if st.gen_complete]
        for t0, _, _ in spans:
            in_air = sum(bw for s, e, bw in spans if s <= t0 < e)
            assert in_air <= scn.total_bandwidth_hz + 1e-6

    @pytest.mark.parametrize("allocator", ["inv_se", "coordinate"])
    def test_per_cell_tx_within_cell_budget(self, allocator):
        scn = make_scenario(K=10, n_servers=2, tau_min=1.0, tau_max=3.0,
                            arrival_rate=3.0, seed=1,
                            content_bits_range=(65536.0, 262144.0))
        sim = MultiOnlineSimulation(scn, get_scheduler("stacking"),
                                    get_allocator(allocator), DELAY,
                                    QUALITY, admission=lambda *a: True)
        res = sim.run()
        for m, server in enumerate(scn.server_list):
            spans = [(st.gen_end, st.tx_end, st.bandwidth)
                     for sid, st in sim.states.items()
                     if st.gen_complete and res.assignment.get(sid) == m]
            for t0, _, _ in spans:
                in_air = sum(bw for s, e, bw in spans if s <= t0 < e)
                assert in_air <= server.bandwidth_hz + 1e-6

    @pytest.mark.parametrize("allocator", ["inv_se", "coordinate"])
    def test_every_adopted_allocation_sums_to_residual_budget(
            self, allocator):
        """Each replan's allocation hands out at most the uncommitted
        bandwidth (checked indirectly: the winning transmission
        bandwidths are positive and individually within budget)."""
        scn, sim, res = _run_online("stacking", allocator, 2, K=10,
                                    tau_min=1.0, tau_max=4.0)
        for o in res.outcomes:
            if o.steps > 0:
                st = sim.states[o.id]
                assert 0.0 < st.bandwidth <= scn.total_bandwidth_hz + 1e-6


class TestStaticDegeneration:
    """With every arrival at t=0 the event loop must reproduce the
    static pipeline exactly — single- and multi-server alike."""

    @pytest.mark.parametrize("scheduler,allocator,seed",
                             [("stacking", "inv_se", 0),
                              ("stacking", "coordinate", 1),
                              ("greedy", "equal", 2)])
    def test_online_equals_static_when_all_at_zero(self, scheduler,
                                                   allocator, seed):
        scn = make_scenario(K=8, seed=seed)
        static = Provisioner(scn, scheduler=scheduler,
                             allocator=allocator).run()
        sim = OnlineSimulation(scn, get_scheduler(scheduler),
                               get_allocator(allocator), DELAY, QUALITY,
                               admission=lambda *a: True)
        assert sim.run().outcomes == static.sim.outcomes
        msim = MultiOnlineSimulation(scn, get_scheduler(scheduler),
                                     get_allocator(allocator), DELAY,
                                     QUALITY, admission=lambda *a: True)
        assert msim.run().result.outcomes == static.sim.outcomes

    def test_multi_online_is_deterministic(self):
        scn = make_scenario(K=10, n_servers=3, arrival_rate=1.0,
                            server_speed_range=(0.6, 1.4), seed=5)
        runs = []
        for _ in range(2):
            sim = MultiOnlineSimulation(
                scn, get_scheduler("stacking"), get_allocator("inv_se"),
                DELAY, QUALITY, admission=lambda *a: True)
            runs.append(sim.run())
        assert runs[0].result.outcomes == runs[1].result.outcomes
        assert runs[0].assignment == runs[1].assignment


class TestExecutedLogConsistency:
    def test_steps_done_equals_log_length_multi(self):
        scn = make_scenario(K=9, n_servers=3, arrival_rate=2.0, seed=3)
        sim = MultiOnlineSimulation(scn, get_scheduler("stacking"),
                                    get_allocator("inv_se"), DELAY,
                                    QUALITY, admission=lambda *a: True)
        sim.run()
        logged = {}
        for tr in sim.tracks:
            for _, k, _ in tr.executed_log:
                logged[k] = logged.get(k, 0) + 1
        for k, st in sim.states.items():
            assert st.steps_done == logged.get(k, 0)

    def test_services_never_execute_on_two_tracks(self):
        scn = make_scenario(K=9, n_servers=3, arrival_rate=2.0, seed=4)
        sim = MultiOnlineSimulation(scn, get_scheduler("stacking"),
                                    get_allocator("inv_se"), DELAY,
                                    QUALITY, admission=lambda *a: True)
        sim.run()
        seen = {}
        for m, tr in enumerate(sim.tracks):
            for _, k, _ in tr.executed_log:
                assert seen.setdefault(k, m) == m, \
                    f"service {k} ran on tracks {seen[k]} and {m}"
