"""Validate the trip-count-aware HLO cost model against unrolled
references (where XLA's own cost_analysis is correct)."""

import jax
import jax.numpy as jnp
import pytest

from repro.launch.hlo_cost import analyze_hlo


def _cost(fn, *specs):
    compiled = jax.jit(fn).lower(*specs).compile()
    xla = compiled.cost_analysis()
    if isinstance(xla, list):        # older jax wraps the dict in a list
        xla = xla[0]
    return analyze_hlo(compiled.as_text()), xla


def test_scan_matches_unrolled_flops():
    d, L = 128, 8

    def body(x, w):
        return jnp.tanh(x @ w), None

    def scanned(x, ws):
        return jax.lax.scan(body, x, ws)[0]

    def unrolled(x, ws):
        for i in range(L):
            x, _ = body(x, ws[i])
        return x

    x = jax.ShapeDtypeStruct((4, d), jnp.float32)
    ws = jax.ShapeDtypeStruct((L, d, d), jnp.float32)
    ours_scan, _ = _cost(scanned, x, ws)
    ours_unroll, xla_unroll = _cost(unrolled, x, ws)

    matmul_flops = L * 2 * 4 * d * d
    assert ours_scan["flops"] == pytest.approx(matmul_flops, rel=0.05)
    assert ours_unroll["flops"] == pytest.approx(matmul_flops, rel=0.05)
    # XLA's own count agrees on the unrolled program
    assert xla_unroll["flops"] == pytest.approx(matmul_flops, rel=0.3)


def test_dot_flops_formula():
    def f(a, b):
        return a @ b

    a = jax.ShapeDtypeStruct((64, 32), jnp.float32)
    b = jax.ShapeDtypeStruct((32, 48), jnp.float32)
    ours, xla = _cost(f, a, b)
    want = 2 * 64 * 32 * 48
    assert ours["flops"] == pytest.approx(want, rel=0.01)
    assert xla["flops"] == pytest.approx(want, rel=0.01)


def test_dus_counts_slice_not_buffer():
    """KV-cache-style update: with buffer donation the update is in-place
    and traffic must be O(slice), not O(buffer).  Without donation XLA
    inserts a defensive copy, which the model must also see."""
    def f(buf, x):
        return jax.lax.dynamic_update_slice(buf, x, (0, 0))

    buf = jax.ShapeDtypeStruct((4096, 256), jnp.float32)
    x = jax.ShapeDtypeStruct((1, 256), jnp.float32)
    buffer_bytes = 4096 * 256 * 4
    slice_bytes = 256 * 4

    donated = jax.jit(f, donate_argnums=(0,)).lower(buf, x).compile()
    ours = analyze_hlo(donated.as_text())
    assert ours["bytes"] <= 4 * slice_bytes, \
        f"in-place DUS should cost O(slice), got {ours['bytes']}"

    undonated = jax.jit(f).lower(buf, x).compile()
    ours2 = analyze_hlo(undonated.as_text())
    assert ours2["bytes"] >= buffer_bytes   # the defensive copy is real


def test_collectives_counted_through_loops():
    # needs >1 device; skip if the test process pinned to 1
    if len(jax.devices()) < 2:
        pytest.skip("single device")


def test_scan_bytes_scale_with_trip_count():
    d = 64

    def body(x, w):
        return jnp.tanh(x @ w), None

    def make(L):
        def f(x, ws):
            return jax.lax.scan(body, x, ws)[0]
        xs = jax.ShapeDtypeStruct((2, d), jnp.float32)
        ws = jax.ShapeDtypeStruct((L, d, d), jnp.float32)
        return _cost(f, xs, ws)[0]

    c4, c16 = make(4), make(16)
    assert c16["flops"] == pytest.approx(4 * c4["flops"], rel=0.05)
    assert c16["bytes"] == pytest.approx(4 * c4["bytes"], rel=0.35)
