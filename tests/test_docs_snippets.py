"""Documented examples cannot rot: every fenced ``python`` block in
README.md and docs/*.md is executed.

Blocks within one file share a namespace (they are concatenated in
order, so later snippets may reuse earlier names — the docs read like
one session).  Each file runs in its own subprocess so registry
registrations and jax state cannot leak between docs or into other
tests."""

import os
import re
import subprocess
import sys
from pathlib import Path

import pytest

ROOT = Path(__file__).resolve().parents[1]
FENCE = re.compile(r"^```python[ \t]*\n(.*?)^```", re.M | re.S)


def _doc_files():
    docs = [ROOT / "README.md"] + sorted((ROOT / "docs").glob("*.md"))
    return [p for p in docs if p.exists()]


def _params():
    heavy = {"API.md"}                    # executes the real U-Net
    return [pytest.param(p, id=p.name,
                         marks=[pytest.mark.slow] if p.name in heavy
                         else [])
            for p in _doc_files()]


def test_docs_exist_and_have_snippets():
    names = {p.name for p in _doc_files()}
    assert {"README.md", "API.md", "SCENARIOS.md"} <= names
    for p in _doc_files():
        if p.name in ("README.md", "API.md", "SCENARIOS.md"):
            assert FENCE.findall(p.read_text()), f"no snippets in {p.name}"


@pytest.mark.parametrize("doc", _params())
def test_doc_snippets_execute(doc):
    blocks = FENCE.findall(doc.read_text())
    if not blocks:
        pytest.skip(f"{doc.name} has no python snippets")
    source = "\n\n".join(blocks)
    env = dict(os.environ)
    env["PYTHONPATH"] = str(ROOT / "src")
    env.setdefault("JAX_PLATFORMS", "cpu")
    proc = subprocess.run(
        [sys.executable, "-c", source], cwd=ROOT, env=env,
        capture_output=True, text=True, timeout=900)
    assert proc.returncode == 0, (
        f"snippets from {doc.name} failed "
        f"(blocks are concatenated in file order):\n"
        f"--- stdout ---\n{proc.stdout[-2000:]}\n"
        f"--- stderr ---\n{proc.stderr[-4000:]}")
