"""Unit tests: delay model (Eq. 4) and quality model (Fig. 1b fit)."""

import numpy as np
import pytest

from repro.core.delay_model import (DelayModel, PAPER_A, PAPER_B, fit,
                                    tpu_estimate)
from repro.core.quality_model import PowerLawFID, fit_power_law


class TestDelayModel:
    def test_paper_constants(self):
        d = DelayModel()
        assert d.a == pytest.approx(PAPER_A)
        assert d.b == pytest.approx(PAPER_B)

    def test_g_affine(self):
        d = DelayModel(a=0.1, b=0.5)
        assert d.g(0) == 0.0
        assert d.g(1) == pytest.approx(0.6)
        assert d.g(10) == pytest.approx(1.5)

    def test_batching_amortizes(self):
        """Core premise: per-task delay decreases with batch size."""
        d = DelayModel()
        per_task = [d.g(x) / x for x in range(1, 21)]
        assert all(a > b for a, b in zip(per_task, per_task[1:]))

    def test_max_steps(self):
        d = DelayModel(a=0.1, b=0.4)
        assert d.max_steps(1.0) == 2
        assert d.max_steps(0.49) == 0
        assert d.max_steps(-1.0) == 0

    def test_fit_recovers(self):
        d = DelayModel(a=0.024, b=0.354)
        xs = np.arange(1, 33)
        ys = [d.g(int(x)) for x in xs]
        f = fit(xs, ys)
        assert f.a == pytest.approx(d.a, rel=1e-6)
        assert f.b == pytest.approx(d.b, rel=1e-6)

    def test_fit_noisy(self):
        rng = np.random.default_rng(0)
        d = DelayModel(a=0.02, b=0.3)
        xs = np.arange(1, 65)
        ys = [d.g(int(x)) + rng.normal(0, 1e-3) for x in xs]
        f = fit(xs, ys)
        assert f.a == pytest.approx(d.a, rel=0.05)
        assert f.b == pytest.approx(d.b, rel=0.05)

    def test_tpu_estimate_structure(self):
        """b (weight stream) should dominate a (per-sample slope) for the
        paper's U-Net on v5e, same structural property as the GPU fit."""
        m = tpu_estimate(flops_per_sample=6.1e9, param_bytes=71e6)
        assert m.b > m.a
        assert m.g(2) > m.g(1) > 0


class TestQualityModel:
    def test_monotone_diminishing(self):
        q = PowerLawFID()
        fids = [q.fid(t) for t in range(0, 101)]
        assert all(a >= b for a, b in zip(fids, fids[1:]))
        gains = [fids[t] - fids[t + 1] for t in range(1, 99)]
        assert all(a >= b - 1e-9 for a, b in zip(gains, gains[1:]))

    def test_matches_ddim_table(self):
        """Default constants reproduce the DDIM paper's CIFAR-10 FIDs."""
        q = PowerLawFID()
        assert q.fid(10) == pytest.approx(13.36, abs=0.6)
        assert q.fid(20) == pytest.approx(6.84, abs=0.6)
        assert q.fid(50) == pytest.approx(4.67, abs=0.3)
        assert q.fid(100) == pytest.approx(4.16, abs=0.3)

    def test_zero_steps_is_outage(self):
        q = PowerLawFID()
        assert q.fid(0) == q.fid_at_zero > q.fid(1)

    def test_fit_power_law_recovers(self):
        true = PowerLawFID(alpha=300.0, beta=1.5, gamma=4.2)
        ts = [5, 10, 20, 40, 80, 160]
        fids = [true.fid(t) for t in ts]
        fitted = fit_power_law(ts, fids)
        for t in (7, 15, 30, 100):
            assert fitted.fid(t) == pytest.approx(true.fid(t), rel=0.08)

    def test_mean_fid(self):
        q = PowerLawFID()
        assert q.mean_fid([10, 10]) == pytest.approx(q.fid(10))
