"""The unified facade surface (repro.api.base): shared kwargs, legacy
positional shims, the ``provision`` front door, and the common
``to_dict``/``summary`` report protocol."""

import json
import warnings

import numpy as np
import pytest

from repro.api import (FleetProvisioner, MultiServerProvisioner,
                       OnlineProvisioner, Provisioner,
                       make_fleet_scenario, provision)
from repro.core.delay_model import DelayModel
from repro.core.service import make_scenario

DELAY = DelayModel(a=0.05, b=0.1)


def _static(K=6, seed=0, **kw):
    return make_scenario(K=K, seed=seed, **kw)


class TestLegacyPositionalShims:
    """Pre-unification positional constructor calls keep working, warn,
    and produce bit-identical results to the keyword spelling."""

    def test_provisioner_positional_warns_and_matches(self):
        scn = _static()
        with pytest.warns(DeprecationWarning, match="positional"):
            old = Provisioner(scn, None, "stacking", "inv_se", DELAY)
        new = Provisioner(scn, workload=None, scheduler="stacking",
                          allocator="inv_se", delay=DELAY)
        a, b = old.run(execute=False), new.run(execute=False)
        assert a.mean_fid == b.mean_fid
        assert a.plan.batches == b.plan.batches

    def test_online_positional_warns_and_matches(self):
        scn = _static(K=6, seed=1, arrival_rate=0.5)
        with pytest.warns(DeprecationWarning, match="positional"):
            old = OnlineProvisioner(scn, "stacking", "inv_se",
                                    "admit_all", DELAY)
        new = OnlineProvisioner(scn, scheduler="stacking",
                                allocator="inv_se",
                                admission="admit_all", delay=DELAY)
        assert old.run().mean_fid == new.run().mean_fid

    def test_multiserver_positional_warns_and_matches(self):
        scn = _static(K=8, seed=2, n_servers=3,
                      server_speed_range=(0.7, 1.3))
        with pytest.warns(DeprecationWarning, match="positional"):
            old = MultiServerProvisioner(scn, "least_loaded", "stacking",
                                         "inv_se", DELAY)
        new = MultiServerProvisioner(scn, placement="least_loaded",
                                     scheduler="stacking",
                                     allocator="inv_se", delay=DELAY)
        assert old.run().mean_fid == new.run().mean_fid

    def test_positional_keyword_conflict_raises(self):
        scn = _static()
        with warnings.catch_warnings():
            warnings.simplefilter("ignore", DeprecationWarning)
            with pytest.raises(TypeError, match="multiple values"):
                Provisioner(scn, None, "stacking",
                            scheduler="stacking_offset")

    def test_too_many_positionals_raise(self):
        scn = _static()
        with pytest.raises(TypeError, match="positional"):
            Provisioner(scn, None, "stacking", "inv_se", DELAY, None,
                        None, None, "extra")


class TestSharedKwargs:
    def test_seed_reaches_seeded_allocator(self):
        scn = _static()
        p = Provisioner(scn, allocator="pso", seed=5, delay=DELAY)
        assert p.allocator_kwargs["seed"] == 5
        # explicit allocator_kwargs seed wins over the facade seed
        q = Provisioner(scn, allocator="pso", seed=5, delay=DELAY,
                        allocator_kwargs={"seed": 9})
        assert q.allocator_kwargs["seed"] == 9

    def test_seed_skipped_for_unseeded_allocator(self):
        p = Provisioner(_static(), allocator="inv_se", seed=5,
                        delay=DELAY)
        assert "seed" not in p.allocator_kwargs

    def test_seed_determinism_pso(self):
        scn = _static()
        a = Provisioner(scn, allocator="pso", seed=3, delay=DELAY,
                        allocator_kwargs={"iters": 5}).allocate()
        b = Provisioner(scn, allocator="pso", seed=3, delay=DELAY,
                        allocator_kwargs={"iters": 5}).allocate()
        np.testing.assert_array_equal(a, b)

    def test_fleet_seed_reseeds_arrivals(self):
        fleet = make_fleet_scenario(n_cells=3, horizon=4.0, rate=1.0,
                                    seed=0)
        p = FleetProvisioner(fleet, seed=42)
        assert p.fleet.seed == 42

    def test_execute_validation_at_construction(self):
        with pytest.raises(ValueError, match="execute"):
            Provisioner(_static(), execute="sideways")

    def test_fleet_execute_raises(self):
        fleet = make_fleet_scenario(n_cells=2, horizon=2.0, rate=1.0)
        with pytest.raises(NotImplementedError):
            FleetProvisioner(fleet, execute=True).run()

    def test_multiserver_execute_raises(self):
        scn = _static(K=6, n_servers=2)
        with pytest.raises(NotImplementedError, match="per cell"):
            MultiServerProvisioner(scn, delay=DELAY).run(execute="closed")
        with pytest.raises(NotImplementedError, match="per cell"):
            MultiServerProvisioner(scn, delay=DELAY).run_online(
                execute=True)


class TestProvisionFrontDoor:
    """provision() reproduces each facade's run() on fixed seeds."""

    def test_static_scenario(self):
        scn = _static()
        want = Provisioner(scn, scheduler="stacking", allocator="inv_se",
                           delay=DELAY).run(execute=False)
        got = provision(scn, scheduler="stacking", allocator="inv_se",
                        delay=DELAY, execute=False)
        assert type(got) is type(want)
        assert got.mean_fid == want.mean_fid
        assert got.plan.batches == want.plan.batches

    def test_dynamic_scenario_dispatches_online(self):
        scn = _static(K=6, seed=1, arrival_rate=0.5)
        want = OnlineProvisioner(scn, scheduler="stacking",
                                 allocator="inv_se", delay=DELAY).run()
        got = provision(scn, scheduler="stacking", allocator="inv_se",
                        delay=DELAY)
        assert type(got) is type(want)
        assert got.mean_fid == want.mean_fid
        assert got.result.executed_batches == \
            want.result.executed_batches

    def test_admission_kwarg_forces_online(self):
        scn = _static()   # static, but admission= means online
        got = provision(scn, allocator="inv_se", delay=DELAY,
                        admission="deadline_feasible")
        want = OnlineProvisioner(scn, allocator="inv_se", delay=DELAY,
                                 admission="deadline_feasible").run()
        assert type(got) is type(want)
        assert got.mean_fid == want.mean_fid

    def test_multiserver_static(self):
        scn = _static(K=8, seed=2, n_servers=3,
                      server_speed_range=(0.7, 1.3))
        want = MultiServerProvisioner(scn, allocator="inv_se",
                                      delay=DELAY).run()
        got = provision(scn, allocator="inv_se", delay=DELAY)
        assert type(got) is type(want)
        assert got.mean_fid == want.mean_fid
        np.testing.assert_array_equal(got.assignment, want.assignment)

    def test_multiserver_online(self):
        scn = _static(K=8, seed=3, n_servers=2, arrival_rate=0.5)
        want = MultiServerProvisioner(scn, allocator="inv_se",
                                      delay=DELAY).run_online(
            admission="admit_all")
        got = provision(scn, allocator="inv_se", delay=DELAY,
                        admission="admit_all")
        assert type(got) is type(want)
        assert got.mean_fid == want.mean_fid

    def test_fleet_scenario(self):
        fleet = make_fleet_scenario(n_cells=3, horizon=4.0, rate=1.0,
                                    seed=5)
        want = FleetProvisioner(fleet, allocator="equal").run()
        got = provision(fleet, allocator="equal")
        assert type(got) is type(want)
        assert got.mean_fid == want.mean_fid
        assert got.result.arrivals == want.result.arrivals


class TestReportProtocol:
    """Every report kind serializes through the same to_dict shape."""

    REQUIRED = {"kind", "mean_fid", "outage_rate", "makespan",
                "components", "telemetry"}

    def _check(self, d, kind):
        assert self.REQUIRED <= set(d)
        assert d["kind"] == kind
        json.loads(json.dumps(d))     # round-trips as plain JSON

    def test_provision_report(self):
        rep = Provisioner(_static(), allocator="inv_se",
                          delay=DELAY).run(execute=False)
        d = rep.to_dict()
        self._check(d, "provision")
        assert d["components"]["allocator"] == "inv_se"
        assert rep.summary()

    def test_provision_report_with_execution(self):
        rep = Provisioner(
            _static(), scheduler="stacking_offset", allocator="inv_se",
            delay=DELAY,
            execute_kwargs={"executor": "simulated",
                            "executor_kwargs": {
                                "true_delay": DELAY.scaled(2)},
                            "min_batches": 2}).run(execute="closed")
        d = rep.to_dict()
        self._check(d, "provision")
        assert d["execution"]["kind"] == "execution"
        assert "execution closed" in rep.summary()

    def test_online_report(self):
        rep = OnlineProvisioner(_static(K=6, seed=1, arrival_rate=0.5),
                                allocator="inv_se", delay=DELAY).run()
        d = rep.to_dict()
        self._check(d, "online")
        assert 0.0 <= d["reject_rate"] <= 1.0
        assert d["makespan"] is None or d["makespan"] > 0
        assert rep.summary()

    def test_multi_reports(self):
        scn = _static(K=8, seed=2, n_servers=3,
                      server_speed_range=(0.7, 1.3))
        ms = MultiServerProvisioner(scn, allocator="inv_se", delay=DELAY)
        self._check(ms.run().to_dict(), "multi")
        scn2 = _static(K=8, seed=3, n_servers=2, arrival_rate=0.5)
        ms2 = MultiServerProvisioner(scn2, allocator="inv_se",
                                     delay=DELAY)
        self._check(ms2.run_online().to_dict(), "multi_online")

    def test_fleet_report(self):
        fleet = make_fleet_scenario(n_cells=3, horizon=4.0, rate=1.0,
                                    seed=5)
        rep = FleetProvisioner(fleet, allocator="equal").run()
        d = rep.to_dict()
        self._check(d, "fleet")
        assert d["telemetry"]["arrivals"] == rep.result.arrivals
        assert rep.summary()
