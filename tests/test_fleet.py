"""repro.core.fleet (ISSUE 8 tentpole): the population-scale harness.

The contract under test, in order of importance:

* event-mode fleet == ``simulate_online_multi`` on the identical
  workload within 1e-9 mean FID (the fleet harness is a
  re-implementation for scale, not a new model);
* the jax batched-replan path == the vec per-cell loop within 1e-9;
* event and epoch modes agree exactly on trace-driven workloads
  (chunk-independent sampling);
* memory is bounded by the working set, never the horizon;
* seeded runs are deterministic; admission/capacity account for every
  arrival; the api facade resolves everything by name.
"""

import numpy as np
import pytest

from repro.core import fleet as fl
from repro.core import traffic
from repro.core.bandwidth import equal_allocate, inv_se_allocate
from repro.core.multiserver import simulate_online_multi
from repro.core.stacking import stacking

pytestmark = pytest.mark.filterwarnings("ignore::DeprecationWarning")


def small_fleet(n_cells=3, rate=2.0, horizon=8.0, seed=11, **kw):
    cells = [fl.FleetCell(bandwidth_hz=1.2e6 * (c + 1),
                          speed=1.0 + 0.25 * c,
                          process=traffic.PoissonProcess(rate))
             for c in range(n_cells)]
    return fl.FleetScenario(cells=cells, horizon=horizon, seed=seed,
                            **kw)


CORE_ALLOC = {"equal": lambda scn, *a, **k: equal_allocate(scn),
              "inv_se": lambda scn, *a, **k: inv_se_allocate(scn)}


class TestMultiserverEquivalence:
    """Acceptance: mean FID within 1e-9 of ``simulate_online_multi``
    with the placement pinned to the fleet's per-cell assignment."""

    @pytest.mark.parametrize("alloc", ["equal", "inv_se"])
    @pytest.mark.parametrize("engine", ["vec", "jax"])
    def test_event_mode_matches(self, alloc, engine):
        if engine == "jax":
            pytest.importorskip("jax")
        fleet = small_fleet()
        res = fl.simulate_fleet(fleet, allocator=alloc, mode="event",
                                engine=engine)
        scn, assignment = fl.fleet_to_scenario(fleet)
        assert len(scn.services) > 30    # non-trivial workload
        cell_of = {s.id: assignment[i]
                   for i, s in enumerate(scn.services)}
        ref = simulate_online_multi(
            scn, stacking, CORE_ALLOC[alloc],
            placement=lambda svc, sim: cell_of[svc.id], engine="vec")
        assert res.mean_fid == pytest.approx(ref.mean_fid, abs=1e-9)
        assert res.outage_rate == pytest.approx(ref.outage_rate,
                                                abs=1e-12)
        assert res.admitted == len(ref.outcomes)

    def test_fleet_to_scenario_id_order(self):
        """Global ids in (arrival, cell) order — per-cell ids ascend
        with arrival time, the tie-break invariant both simulators
        share."""
        scn, assignment = fl.fleet_to_scenario(small_fleet())
        arrivals = [s.arrival for s in scn.services]
        keys = list(zip(arrivals, assignment))
        assert keys == sorted(keys)
        assert [s.id for s in scn.services] == \
            list(range(len(scn.services)))


class TestEngineParity:
    def test_epoch_jax_matches_vec(self):
        pytest.importorskip("jax")
        cells = [fl.FleetCell(bandwidth_hz=2e6,
                              process=traffic.PoissonProcess(5.0))
                 for _ in range(10)]
        fleet = fl.FleetScenario(cells=cells, horizon=30.0, seed=1)
        vec = fl.simulate_fleet(fleet, mode="epoch", engine="vec")
        jax_ = fl.simulate_fleet(fleet, mode="epoch", engine="jax")
        assert jax_.mean_fid == pytest.approx(vec.mean_fid, abs=1e-9)
        assert jax_.completed == vec.completed
        assert jax_.outage_rate == pytest.approx(vec.outage_rate,
                                                 abs=1e-12)
        # the whole point of the batched path: far fewer planner calls
        # than per-cell replans
        assert jax_.planner_calls < vec.planner_calls
        assert jax_.replans == vec.replans

    def test_event_jax_batches_rounds(self):
        pytest.importorskip("jax")
        fleet = small_fleet(n_cells=4, rate=3.0, horizon=6.0)
        vec = fl.simulate_fleet(fleet, mode="event", engine="vec")
        jax_ = fl.simulate_fleet(fleet, mode="event", engine="jax")
        assert jax_.mean_fid == pytest.approx(vec.mean_fid, abs=1e-9)
        assert jax_.planner_calls <= vec.planner_calls


class TestCrossMode:
    def test_trace_event_equals_epoch(self):
        """Trace-driven workloads sample chunk-independently, so the
        two modes see identical services; with arrivals spaced wider
        than the drain time the plans coincide too — exact agreement."""
        times = [0.0, 5.0, 10.0, 15.0]
        cells = [fl.FleetCell(
            bandwidth_hz=2e6,
            process=traffic.TraceArrivals([t + 0.3 * c for t in times]))
            for c in range(2)]
        fleet = fl.FleetScenario(cells=cells, horizon=20.0, seed=3,
                                 deadline_range=(1.0, 2.0))
        ev = fl.simulate_fleet(fleet, mode="event")
        ep = fl.simulate_fleet(fleet, mode="epoch", epoch=5.0)
        assert ev.mean_fid == pytest.approx(ep.mean_fid, abs=1e-12)
        assert (ev.arrivals, ev.completed) == (ep.arrivals, ep.completed)
        assert ev.outage_rate == pytest.approx(ep.outage_rate,
                                               abs=1e-12)

    def test_epoch_chunking_invariant(self):
        """Halving the epoch width must not change which services a
        trace-driven fleet sees (attribute substreams are
        chunk-independent)."""
        tr = traffic.TraceArrivals(np.linspace(0.5, 39.5, 40))
        fleet = fl.FleetScenario(
            cells=[fl.FleetCell(bandwidth_hz=3e6, process=tr)],
            horizon=40.0, seed=9)
        a = fl.simulate_fleet(fleet, mode="epoch", epoch=10.0)
        b = fl.simulate_fleet(fleet, mode="epoch", epoch=5.0)
        assert a.arrivals == b.arrivals == 40


class TestDeterminismAndAccounting:
    def test_seeded_run_is_reproducible(self):
        a = fl.simulate_fleet(small_fleet(seed=5))
        b = fl.simulate_fleet(small_fleet(seed=5))
        assert a == b

    def test_different_seed_differs(self):
        a = fl.simulate_fleet(small_fleet(seed=5))
        b = fl.simulate_fleet(small_fleet(seed=6))
        assert a.mean_fid != b.mean_fid

    @pytest.mark.parametrize("mode", ["event", "epoch"])
    def test_every_arrival_accounted(self, mode):
        res = fl.simulate_fleet(small_fleet(), mode=mode)
        assert res.arrivals > 0
        assert res.admitted + res.rejected == res.arrivals
        assert res.completed == res.admitted

    def test_capacity_rejects(self):
        cells = [fl.FleetCell(bandwidth_hz=2e6, capacity=3,
                              process=traffic.PoissonProcess(3.0))]
        fleet = fl.FleetScenario(cells=cells, horizon=10.0, seed=0)
        for mode in ("event", "epoch"):
            res = fl.simulate_fleet(fleet, mode=mode)
            assert res.admitted <= 3
            assert res.rejected == res.arrivals - res.admitted
            assert res.rejected > 0

    def test_admission_policy_applies(self):
        fleet = small_fleet()
        deny = fl.simulate_fleet(fleet, admission=lambda c, p: False)
        assert deny.rejected == deny.arrivals
        assert deny.completed == deny.admitted == 0
        feasible = fl.simulate_fleet(
            fleet, admission=lambda c, p: p.steps > 0 and p.met_deadline)
        assert feasible.outage_rate <= \
            fl.simulate_fleet(fleet).outage_rate + 1e-12
        assert feasible.rejected > 0


class TestBoundedMemory:
    def test_peak_rows_track_working_set_not_horizon(self):
        peaks = {}
        for horizon in (25.0, 100.0):
            cells = [fl.FleetCell(bandwidth_hz=1.5e6,
                                  process=traffic.PoissonProcess(2.0))
                     for _ in range(8)]
            fleet = fl.FleetScenario(cells=cells, horizon=horizon,
                                     seed=7)
            res = fl.simulate_fleet(fleet, mode="epoch", epoch=5.0)
            peaks[horizon] = res.peak_live_rows
        assert peaks[100.0] <= 2 * peaks[25.0]

    def test_reservoir_is_fixed_size(self):
        r = fl.ReservoirQuantiles(capacity=64, seed=0)
        rng = np.random.default_rng(0)
        for x in rng.random(10_000):
            r.add(float(x))
        assert r.count == 10_000
        assert r._buf.size == 64
        # a uniform stream's median lands near 0.5 even from a
        # 64-sample reservoir
        assert r.percentile(50) == pytest.approx(0.5, abs=0.2)

    def test_reservoir_small_stream_exact(self):
        r = fl.ReservoirQuantiles(capacity=64, seed=0)
        for x in [1.0, 2.0, 3.0]:
            r.add(x)
        assert r.percentile(50) == 2.0
        assert np.isnan(fl.ReservoirQuantiles().percentile(50))


class TestSharedStreamPlacement:
    def test_shared_stream_routes(self):
        shared = traffic.PoissonProcess(4.0)
        cells = [fl.FleetCell(bandwidth_hz=2e6) for _ in range(3)]
        fleet = fl.FleetScenario(cells=cells, horizon=20.0, seed=2,
                                 shared_process=shared)
        for placement in ("round_robin", "least_busy", "rate_aware"):
            res = fl.simulate_fleet(fleet, mode="epoch",
                                    placement=placement)
            assert res.arrivals > 0
            assert res.admitted + res.rejected == res.arrivals

    def test_event_mode_rejects_shared(self):
        fleet = fl.FleetScenario(
            cells=[fl.FleetCell(bandwidth_hz=1e6)], horizon=5.0,
            shared_process=traffic.PoissonProcess(1.0))
        with pytest.raises(ValueError, match="event"):
            fl.simulate_fleet(fleet, mode="event")


class TestValidation:
    def test_bad_mode(self):
        with pytest.raises(ValueError, match="mode"):
            fl.simulate_fleet(small_fleet(), mode="turbo")

    def test_bad_epoch(self):
        with pytest.raises(ValueError, match="epoch"):
            fl.simulate_fleet(small_fleet(), mode="epoch", epoch=0.0)

    def test_iterative_allocators_rejected(self):
        with pytest.raises(ValueError, match="closed-form"):
            fl.simulate_fleet(small_fleet(), allocator="pso")

    def test_scenario_validation(self):
        with pytest.raises(ValueError, match="at least one cell"):
            fl.FleetScenario(cells=[], horizon=1.0)
        with pytest.raises(ValueError, match="horizon"):
            fl.FleetScenario(cells=[fl.FleetCell(1e6)], horizon=0.0)
        with pytest.raises(ValueError, match="deadline_range"):
            fl.FleetScenario(cells=[fl.FleetCell(1e6)], horizon=1.0,
                             deadline_range=(3.0, 1.0))


class TestApiFacade:
    def test_make_fleet_scenario_and_run(self):
        from repro.api import FleetProvisioner, make_fleet_scenario
        fleet = make_fleet_scenario(
            4, 20.0, rate=1.0, bandwidth_hz=[1e6, 2e6, 3e6, 4e6],
            speed=1.2, seed=3)
        assert fleet.n_cells == 4
        assert fleet.cells[2].bandwidth_hz == 3e6
        report = FleetProvisioner(fleet, allocator="inv_se").run()
        assert report.result.arrivals > 0
        assert "fleet x4" in report.summary()
        assert "inv_se" in report.summary()

    def test_arrivals_registry(self):
        from repro.api import get_arrival, list_arrivals
        names = list_arrivals()
        for name in ("poisson", "diurnal", "flash_crowd", "trace"):
            assert name in names
        assert get_arrival("poisson") is traffic.PoissonProcess

    def test_correlated_rates_spec(self):
        from repro.api import make_fleet_scenario
        fleet = make_fleet_scenario(8, 10.0, rate=2.0, correlation=0.7,
                                    seed=4)
        rates = [c.process.rate for c in fleet.cells]
        assert len(set(rates)) > 1          # heterogeneous
        assert min(rates) > 0
        # reproducible from the seed
        again = make_fleet_scenario(8, 10.0, rate=2.0, correlation=0.7,
                                    seed=4)
        assert [c.process.rate for c in again.cells] == rates

    def test_trace_spec_loads_file(self, tmp_path):
        from repro.api import make_fleet_scenario
        p = tmp_path / "t.json"
        p.write_text("[1.0, 2.0]")
        fleet = make_fleet_scenario(
            1, 5.0, arrival="trace", arrival_kwargs={"path": str(p)})
        assert fleet.cells[0].process.times.tolist() == [1.0, 2.0]

    def test_per_cell_mismatch_raises(self):
        from repro.api import make_fleet_scenario
        with pytest.raises(ValueError, match="bandwidth_hz"):
            make_fleet_scenario(3, 5.0, rate=1.0,
                                bandwidth_hz=[1e6, 2e6])

    def test_kwargs_on_instance_raises(self):
        from repro.api import make_fleet_scenario
        with pytest.raises(ValueError, match="already constructed"):
            make_fleet_scenario(1, 5.0,
                                arrival=traffic.PoissonProcess(1.0),
                                arrival_kwargs={"rate": 2.0})

    def test_correlation_without_rate_raises(self):
        from repro.api import make_fleet_scenario
        with pytest.raises(ValueError, match="rate"):
            make_fleet_scenario(2, 5.0, correlation=0.5)

    def test_rate_sugar_binds_base_rate_factories(self):
        # rate= must land on DiurnalPoisson's base_rate, not `rate`
        from repro.api import make_fleet_scenario
        fleet = make_fleet_scenario(
            4, 20.0, arrival="diurnal", rate=2.0, correlation=0.6,
            seed=3, arrival_kwargs={"amplitude": 0.6, "period": 10.0})
        rates = [c.process.mean_rate(0.0, 10.0) for c in fleet.cells]
        assert len(set(rates)) > 1 and min(rates) > 0

    def test_rate_sugar_rejects_rateless_factory(self):
        from repro.api import make_fleet_scenario
        with pytest.raises(ValueError, match="neither rate"):
            make_fleet_scenario(1, 5.0, arrival="trace_times", rate=1.0,
                                arrival_kwargs={"times": [1.0]})

    def test_rate_sugar_conflict_raises(self):
        from repro.api import make_fleet_scenario
        with pytest.raises(ValueError, match="conflicts"):
            make_fleet_scenario(1, 5.0, rate=1.0,
                                arrival_kwargs={"rate": 2.0})
