"""jax engine ("repro.core.jaxplan") equivalence against the NumPy
reference, across every entry point the engine dispatch reaches:
static Algorithm 1, equal_steps, offset replanning, the online and
multi-server pipelines, the exact DP, and the batched plan_many.

The contract (docs/PERFORMANCE.md, "jax engine") is *tolerance*
equivalence of objectives — XLA may reassociate reductions and its
``pow`` may drift in the last ulp, so candidate scores can differ by
~1e-15 and, on exact ties, a different (equally optimal) candidate may
win.  Plans must always satisfy the paper's constraints regardless:
the jax engine materializes every winner through the exact NumPy
single-level passes.  Skipped wholesale when jax is not installed.
"""

import os
import subprocess
import sys

import numpy as np
import pytest

pytest.importorskip("jax")

import repro.core.jaxplan as jaxplan
from repro.api.registry import get_scheduler
from repro.core import arrays
from repro.core.delay_model import DelayModel
from repro.core.multiserver import provision_multi
from repro.core.offset import StackingOffset
from repro.core.online import simulate_online
from repro.core.optimal import optimal_mean_fid, optimal_plan
from repro.core.quality_model import PowerLawFID
from repro.core.service import ServiceRequest, make_scenario
from repro.core.stacking import stacking

DELAY = DelayModel()
QUALITY = PowerLawFID()

# the documented equivalence tolerance on objectives (mean FID)
TOL = 1e-9


def _services(taus):
    return [ServiceRequest(id=i, deadline=float(t), spectral_eff=7.0)
            for i, t in enumerate(taus)]


def _tau_prime(taus):
    return {i: float(t) for i, t in enumerate(taus)}


def _mean_fid(plan, ids, quality=QUALITY):
    return quality.mean_fid([plan.steps_completed[k] for k in ids])


def _inv_se(scn, scheduler, delay, quality):
    from repro.core.bandwidth import inv_se_allocate
    return inv_se_allocate(scn)


# ---------------------------------------------------------------------------
# Registration / dispatch plumbing
# ---------------------------------------------------------------------------

class TestRegistration:
    def test_jax_engine_registered(self):
        assert "jax" in arrays.registered_engines()
        assert arrays.engine_impl("jax") is jaxplan.IMPL

    def test_engine_toggle_roundtrip(self):
        prev = arrays.get_engine()
        try:
            arrays.set_engine("jax")
            assert arrays.get_engine() == "jax"
        finally:
            arrays.set_engine(prev)

    def test_engine_scope(self):
        with arrays.engine_scope("jax"):
            assert arrays.get_engine() == "jax"
        assert arrays.get_engine() != "jax"

    def test_unknown_engine_error_lists_jax(self):
        with pytest.raises(ValueError, match="jax"):
            arrays.set_engine("turbo")

    def test_env_var_selects_jax(self):
        env = dict(os.environ, REPRO_PLANNER_ENGINE="jax",
                   PYTHONPATH="src")
        out = subprocess.run(
            [sys.executable, "-c",
             "from repro.core import arrays; print(arrays.get_engine())"],
            capture_output=True, text=True, env=env,
            cwd=os.path.dirname(os.path.dirname(__file__)))
        assert out.returncode == 0, out.stderr
        assert out.stdout.strip() == "jax"

    def test_jax_schedulers_registered(self):
        assert get_scheduler("stacking_jax") is not None
        assert get_scheduler("stacking_offset_jax") is not None
        assert get_scheduler("offset_jax") is not None


# ---------------------------------------------------------------------------
# Static entry points
# ---------------------------------------------------------------------------

class TestStaticEquivalence:
    def test_stacking_matches_vec(self):
        rng = np.random.default_rng(0)
        for _ in range(6):
            K = int(rng.integers(1, 14))
            taus = rng.uniform(0.1, 6.0, size=K)
            svcs, tp = _services(taus), _tau_prime(taus)
            pv = stacking(svcs, tp, DELAY, QUALITY, engine="vec")
            pj = stacking(svcs, tp, DELAY, QUALITY, engine="jax")
            assert abs(_mean_fid(pv, range(K))
                       - _mean_fid(pj, range(K))) < TOL
            pj.validate(gen_deadlines=tp)

    def test_stacking_jax_scheduler_entry(self):
        scn = make_scenario(K=10, tau_min=2.0, tau_max=6.0, seed=1)
        tp = {s.id: s.deadline * 0.4 for s in scn.services}
        pv = get_scheduler("stacking")(scn.services, tp, DELAY, QUALITY)
        pj = get_scheduler("stacking_jax")(scn.services, tp, DELAY,
                                           QUALITY)
        ids = [s.id for s in scn.services]
        assert abs(_mean_fid(pv, ids) - _mean_fid(pj, ids)) < TOL

    def test_equal_steps_matches_vec(self):
        sched = get_scheduler("equal_steps")
        rng = np.random.default_rng(2)
        for _ in range(4):
            K = int(rng.integers(1, 12))
            taus = rng.uniform(0.1, 6.0, size=K)
            svcs, tp = _services(taus), _tau_prime(taus)
            pv = sched(svcs, tp, DELAY, QUALITY)
            with arrays.engine_scope("jax"):
                pj = sched(svcs, tp, DELAY, QUALITY)
            assert abs(_mean_fid(pv, range(K))
                       - _mean_fid(pj, range(K))) < TOL
            pj.validate(gen_deadlines=tp)


# ---------------------------------------------------------------------------
# Offset replanning
# ---------------------------------------------------------------------------

class TestOffsetEquivalence:
    def test_offset_plans_match_vec(self):
        sv, sj = StackingOffset("vec"), StackingOffset("jax")
        rng = np.random.default_rng(3)
        for seed in range(4):
            K = int(rng.integers(2, 10))
            taus = rng.uniform(0.3, 6.0, size=K)
            svcs, tp = _services(taus), _tau_prime(taus)
            offs = [int(x) for x in rng.integers(0, 9, K)]
            pv = sv.plan(svcs, tp, DELAY, QUALITY, offs)
            pj = sj.plan(svcs, tp, DELAY, QUALITY, offs)
            from repro.core.online import _OffsetQuality
            oq = _OffsetQuality(QUALITY, offs)
            qv = oq.mean_fid([pv.steps_completed[k] for k in range(K)])
            qj = oq.mean_fid([pj.steps_completed[k] for k in range(K)])
            assert abs(qv - qj) < TOL

    def test_doomed_services_match(self):
        sv, sj = StackingOffset("vec"), StackingOffset("jax")
        scn = make_scenario(K=5, tau_min=3.0, tau_max=8.0, seed=6)
        tp = {s.id: s.deadline * 0.1 for s in scn.services}
        tp[scn.services[0].id] = -0.5
        offs = [3, 0, 2, 0, 1]
        pv = sv.plan(scn.services, tp, DELAY, QUALITY, offs)
        pj = sj.plan(scn.services, tp, DELAY, QUALITY, offs)
        from repro.core.online import _OffsetQuality
        ids = [s.id for s in scn.services]
        oq = _OffsetQuality(QUALITY, offs)
        oq.refresh_doomed(scn.services, tp)
        qv = oq.mean_fid([pv.steps_completed[k] for k in ids])
        qj = oq.mean_fid([pj.steps_completed[k] for k in ids])
        assert abs(qv - qj) < TOL

    def test_zero_offsets_delegate_to_stacking(self):
        so = StackingOffset("jax")
        scn = make_scenario(K=8, tau_min=2.0, tau_max=6.0, seed=7)
        tp = {s.id: s.deadline * 0.5 for s in scn.services}
        a = so(scn.services, tp, DELAY, QUALITY)
        b = stacking(scn.services, tp, DELAY, QUALITY, engine="jax")
        assert a.steps_completed == b.steps_completed


# ---------------------------------------------------------------------------
# Pipelines: online + multi-server
# ---------------------------------------------------------------------------

class TestPipelineEquivalence:
    @pytest.mark.parametrize("sched_name",
                             ["stacking", "stacking_offset"])
    def test_online_matches_vec(self, sched_name):
        sched = get_scheduler(sched_name)
        for seed in range(2):
            scn = make_scenario(K=9, tau_min=3.0, tau_max=8.0,
                                arrival_rate=1.0, seed=seed)
            rv = simulate_online(scn, sched, _inv_se, engine="vec")
            rj = simulate_online(scn, sched, _inv_se, engine="jax")
            assert abs(rv.mean_fid - rj.mean_fid) < TOL

    def test_provision_multi_matches_vec(self):
        scn = make_scenario(K=9, n_servers=3, tau_min=3.0, tau_max=8.0,
                            server_speed_range=(0.6, 1.4), seed=0)
        assignment = [i % 3 for i in range(scn.K)]
        a = provision_multi(scn, assignment, stacking, _inv_se,
                            engine="vec")
        b = provision_multi(scn, assignment, stacking, _inv_se,
                            engine="jax")
        assert abs(a.mean_fid - b.mean_fid) < TOL


# ---------------------------------------------------------------------------
# Exact DP
# ---------------------------------------------------------------------------

class TestOptimal:
    def test_optimal_mean_fid_matches_dp(self):
        rng = np.random.default_rng(5)
        for _ in range(5):
            K = int(rng.integers(1, 7))
            taus = [float(t) for t in rng.uniform(0.1, 3.0, size=K)]
            v_ref = optimal_mean_fid(taus, DELAY, QUALITY)
            v_jax = optimal_mean_fid(taus, DELAY, QUALITY, engine="jax")
            assert abs(v_ref - v_jax) < TOL

    def test_optimal_plan_achieves_bound_and_validates(self):
        rng = np.random.default_rng(6)
        for _ in range(4):
            K = int(rng.integers(1, 7))
            taus = rng.uniform(0.1, 3.0, size=K)
            svcs, tp = _services(taus), _tau_prime(taus)
            plan = optimal_plan(svcs, tp, DELAY, QUALITY, engine="jax")
            bound = optimal_mean_fid([tp[k] for k in range(K)], DELAY,
                                     QUALITY)
            assert abs(_mean_fid(plan, range(K)) - bound) < TOL
            plan.validate(gen_deadlines=tp)

    def test_optimal_plan_refuses_large_instances(self):
        taus = np.full(9, 2.0)
        with pytest.raises(AssertionError):
            optimal_plan(_services(taus), _tau_prime(taus), DELAY,
                         QUALITY, engine="jax")


# ---------------------------------------------------------------------------
# Batched plan_many
# ---------------------------------------------------------------------------

class TestPlanMany:
    def test_matches_per_scenario_vec(self):
        S, K = 64, 8
        taus = np.random.default_rng(7).uniform(0.2, 5.0, size=(S, K))
        res = jaxplan.plan_many(taus, delay=DELAY, quality=QUALITY)
        assert res.num_scenarios == S
        for s in range(0, S, 7):
            tp = _tau_prime(taus[s])
            pv = arrays.stacking_vec(_services(taus[s]), tp, DELAY,
                                     QUALITY)
            assert abs(_mean_fid(pv, range(K)) - res.mean_fid[s]) < TOL

    def test_ragged_scenarios_via_valid_mask(self):
        # two scenarios, the second padded from K=3 to K=5
        taus = np.array([[2.0, 3.0, 1.5, 2.5, 4.0],
                         [2.0, 3.0, 1.5, 0.0, 0.0]])
        valid = np.array([[True] * 5,
                          [True, True, True, False, False]])
        res = jaxplan.plan_many(taus, delay=DELAY, quality=QUALITY,
                                valid=valid)
        tp = _tau_prime(taus[1][:3])
        pv = arrays.stacking_vec(_services(taus[1][:3]), tp, DELAY,
                                 QUALITY)
        assert abs(_mean_fid(pv, range(3)) - res.mean_fid[1]) < TOL
        assert (res.steps[1, 3:] == 0).all()

    def test_winning_level_materializes_to_same_counts(self):
        S, K = 16, 6
        taus = np.random.default_rng(8).uniform(0.2, 5.0, size=(S, K))
        res = jaxplan.plan_many(taus, delay=DELAY, quality=QUALITY)
        for s in range(S):
            tp = _tau_prime(taus[s])
            plan = arrays.stacking_pass_vec(list(range(K)), tp, DELAY,
                                            int(res.best_level[s]))
            got = np.array([plan.steps_completed[k] for k in range(K)])
            assert (got == res.steps[s]).all()
            plan.validate(gen_deadlines=tp)

    def test_rejects_non_powerlaw_quality(self):
        class Weird:
            def fid(self, t):
                return -t

        with pytest.raises(TypeError, match="PowerLawFID"):
            jaxplan.plan_many(np.ones((2, 3)), delay=DELAY,
                              quality=Weird())

    def test_offsets_shift_the_search(self):
        taus = np.full((4, 5), 3.0)
        off = np.zeros((4, 5), dtype=np.int64)
        off[2:] = 4          # two scenarios carry prior progress
        res = jaxplan.plan_many(taus, delay=DELAY, quality=QUALITY,
                                offsets=off)
        # progress-carrying scenarios score better: fid(4 + new) < fid(new)
        assert res.mean_fid[2] < res.mean_fid[0] - 1e-6
