"""Kernel-in-model integration: with REPRO_FORCE_PALLAS=1 the models run
through the Pallas kernels (interpret mode) and must agree with the
pure-jnp path.  Runs in a subprocess so the env var is seen before the
kernels dispatch."""

import json
import os
import subprocess
import sys

import pytest

SCRIPT = r"""
import os, json, sys
import jax, jax.numpy as jnp, numpy as np
from repro.config import RunConfig, get_config, smoke_variant
from repro.models import api

name = sys.argv[1]
cfg = smoke_variant(get_config(name))
params = api.init_model(cfg, jax.random.PRNGKey(0))
run = RunConfig(kv_cache_dtype="float32")
B, S = 2, 32
tokens = jax.random.randint(jax.random.PRNGKey(1), (B, S + 1), 0,
                            cfg.vocab_size)
extras = api.extra_input_specs(cfg, B, abstract=False)
mod = api.get_model(cfg)
logits, _, _ = mod.forward(cfg, params, tokens[:, :S], run, extras)
_, cache = mod.prefill(cfg, params, tokens[:, :S], S + 4, run, extras)
step, cache = mod.decode_step(cfg, params, tokens[:, S:], cache, run,
                              extras)
print(json.dumps({
    "logits_slice": np.asarray(logits[:, -1, :8], np.float64).tolist(),
    "step_slice": np.asarray(step[:, 0, :8], np.float64).tolist(),
    "finite": bool(jnp.all(jnp.isfinite(logits))
                   and jnp.all(jnp.isfinite(step))),
}))
"""


def _run(name, force_pallas):
    env = dict(os.environ, PYTHONPATH="src", JAX_PLATFORMS="cpu")
    if force_pallas:
        env["REPRO_FORCE_PALLAS"] = "1"
    else:
        env.pop("REPRO_FORCE_PALLAS", None)
    out = subprocess.run(
        [sys.executable, "-c", SCRIPT, name], env=env, capture_output=True,
        text=True, timeout=600, cwd=os.path.dirname(os.path.dirname(
            os.path.abspath(__file__))))
    assert out.returncode == 0, out.stderr[-2000:]
    return json.loads(out.stdout.strip().splitlines()[-1])


@pytest.mark.slow
@pytest.mark.parametrize("name", ["tinyllama-1.1b", "zamba2-2.7b"])
def test_model_through_pallas_kernels_matches_jnp(name):
    ref = _run(name, force_pallas=False)
    pal = _run(name, force_pallas=True)
    assert pal["finite"]
    import numpy as np
    np.testing.assert_allclose(np.array(pal["logits_slice"]),
                               np.array(ref["logits_slice"]),
                               atol=5e-3, rtol=5e-3)
    np.testing.assert_allclose(np.array(pal["step_slice"]),
                               np.array(ref["step_slice"]),
                               atol=5e-3, rtol=5e-3)
