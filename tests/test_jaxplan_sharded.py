"""Multi-device equivalence suite for ``repro.core.jaxplan.sharded``
(ISSUE 7 tentpole): ``plan_many`` with the scenario axis sharded
across host devices must match the single-device call and the vec
loop within the documented 1e-9 mean-FID tolerance — across device
counts, non-divisible S, empty shards, and the pmap fallback.

The fast CI matrix exports
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` so every
parameterization actually runs there; locally without that flag the
multi-device cases skip with a reason saying exactly what to export.
"""

import os

import numpy as np
import pytest

pytest.importorskip("jax")

import jax  # noqa: E402

import repro.core.jaxplan as jaxplan  # noqa: E402
from repro.core import arrays  # noqa: E402
from repro.core.delay_model import DelayModel  # noqa: E402
from repro.core.jaxplan import sharded  # noqa: E402
from repro.core.quality_model import PowerLawFID  # noqa: E402

DELAY = DelayModel()
QUALITY = PowerLawFID()
TOL = 1e-9          # documented mean-FID tolerance (docs/PERFORMANCE.md)

N_DEV = len(jax.devices())


def needs_devices(n):
    """Skip marker whose reason tells the reader how to get n devices."""
    return pytest.mark.skipif(
        N_DEV < n,
        reason=f"needs {n} jax devices, have {N_DEV}: export "
               f"XLA_FLAGS=--xla_force_host_platform_device_count=8 "
               f"before jax initializes (the CI fast matrix does)")


def _instance(S, K, seed=0):
    rng = np.random.default_rng(seed)
    return rng.uniform(5.0, 20.0, size=(S, K))


def _assert_matches(a, b):
    """Sharded-vs-unsharded: same winners, objectives within TOL."""
    assert np.array_equal(a.best_level, b.best_level)
    assert np.array_equal(a.steps, b.steps)
    assert np.max(np.abs(a.mean_fid - b.mean_fid)) < TOL
    assert np.max(np.abs(a.makespan - b.makespan)) < TOL


@pytest.mark.parametrize("n_dev", [
    pytest.param(1, marks=needs_devices(1)),
    pytest.param(2, marks=needs_devices(2)),
    pytest.param(8, marks=needs_devices(8)),
])
@pytest.mark.parametrize("S", [5, 37, 64])
def test_sharded_matches_single_device(n_dev, S):
    """Device counts {1, 2, 8} x S divisible and not: identical plans."""
    taus = _instance(S, K=12, seed=S)
    single = jaxplan.plan_many(taus, delay=DELAY, quality=QUALITY)
    shard = sharded.plan_many_sharded(taus, delay=DELAY,
                                      quality=QUALITY, devices=n_dev)
    assert shard.num_scenarios == S
    _assert_matches(single, shard)


@needs_devices(2)
def test_sharded_matches_vec_loop():
    """The 1e-9 contract holds transitively against the vec engine."""
    S, K = 23, 9
    taus = _instance(S, K, seed=3)
    res = sharded.plan_many_sharded(taus, delay=DELAY, quality=QUALITY,
                                    devices=min(N_DEV, 8))
    ids = list(range(K))
    for s in range(S):
        tp = {i: float(taus[s, i]) for i in ids}
        pv = arrays.stacking_pass_vec(ids, tp, DELAY,
                                      int(res.best_level[s]))
        q = QUALITY.mean_fid([pv.steps_completed[k] for k in ids])
        assert abs(q - res.mean_fid[s]) < TOL


@needs_devices(8)
def test_empty_scenario_shards():
    """S smaller than the device count: whole shards are padding and
    must plan to nothing without disturbing the real rows."""
    taus = _instance(3, K=7, seed=5)
    single = jaxplan.plan_many(taus, delay=DELAY, quality=QUALITY)
    shard = sharded.plan_many_sharded(taus, delay=DELAY,
                                      quality=QUALITY, devices=8)
    assert shard.num_scenarios == 3
    _assert_matches(single, shard)


@needs_devices(2)
def test_valid_mask_and_offsets_shard_correctly():
    """Padding-within-scenario (valid mask) and replan offsets ride
    through the device split unchanged."""
    S, K = 11, 8
    taus = _instance(S, K, seed=7)
    rng = np.random.default_rng(8)
    valid = rng.random((S, K)) < 0.7
    valid[:, 0] = True                      # no all-invalid scenario
    offs = rng.integers(0, 4, size=(S, K))
    kw = dict(delay=DELAY, quality=QUALITY, offsets=offs, valid=valid)
    single = jaxplan.plan_many(taus, **kw)
    shard = sharded.plan_many_sharded(taus, devices=2, **kw)
    _assert_matches(single, shard)


@needs_devices(2)
def test_plan_many_devices_kwarg_dispatches():
    """``plan_many(devices=...)`` routes to the sharded module; int,
    explicit device list and None all mean what resolve_devices says."""
    taus = _instance(10, K=6, seed=9)
    base = jaxplan.plan_many(taus, delay=DELAY, quality=QUALITY)
    by_int = jaxplan.plan_many(taus, delay=DELAY, quality=QUALITY,
                               devices=2)
    by_list = jaxplan.plan_many(taus, delay=DELAY, quality=QUALITY,
                                devices=jax.devices()[:2])
    _assert_matches(base, by_int)
    _assert_matches(base, by_list)


def test_resolve_devices_contract():
    devs = sharded.resolve_devices(None)
    assert len(devs) == N_DEV
    assert sharded.resolve_devices(0) == devs
    assert sharded.resolve_devices(1) == devs[:1]
    assert sharded.resolve_devices(devs[:1]) == devs[:1]
    with pytest.raises(ValueError, match="XLA_FLAGS"):
        sharded.resolve_devices(N_DEV + 1)
    with pytest.raises(ValueError):
        sharded.resolve_devices([])


@needs_devices(2)
def test_pmap_fallback_matches(monkeypatch):
    """Pinning the pmap backend (what older jax falls back to) gives
    the same plans as shard_map."""
    taus = _instance(13, K=5, seed=11)
    via_smap = sharded.plan_many_sharded(taus, delay=DELAY,
                                         quality=QUALITY, devices=2)
    monkeypatch.setattr(sharded, "_BACKEND", "pmap")
    via_pmap = sharded.plan_many_sharded(taus, delay=DELAY,
                                         quality=QUALITY, devices=2)
    _assert_matches(via_smap, via_pmap)


@needs_devices(2)
def test_sharded_engine_registry_exposure():
    """The engine registry namespace carries plan_many_sharded, so
    registry users reach it the same way they reach plan_many."""
    impl = arrays.engine_impl("jax")
    assert impl.plan_many_sharded is sharded.plan_many_sharded
    taus = _instance(6, K=4, seed=13)
    a = impl.plan_many(taus, delay=DELAY, quality=QUALITY)
    b = impl.plan_many_sharded(taus, delay=DELAY, quality=QUALITY,
                               devices=2)
    _assert_matches(a, b)


def test_ci_exports_host_device_flag():
    """The fast CI matrix must actually run the multi-device cases —
    guard the workflow wiring so they can never silently start
    skipping (ISSUE 7 acceptance)."""
    ci = os.path.join(os.path.dirname(__file__), os.pardir, ".github",
                      "workflows", "ci.yml")
    if not os.path.exists(ci):
        pytest.skip("no CI workflow in this checkout")
    with open(ci) as fh:
        text = fh.read()
    assert "tier1:" in text
    tier1 = text.split("tier1:", 1)[1].split("\n  bench:", 1)[0]
    assert "--xla_force_host_platform_device_count=8" in tier1
