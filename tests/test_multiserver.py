"""Multi-server provisioning (ISSUE 3 tentpole): scenario sampling,
single-server bit-equivalence, placements, capacity, and the per-cell
bandwidth invariant."""

import numpy as np
import pytest

from repro.api import (MultiServerProvisioner, OnlineProvisioner,
                       PLACEMENTS, Provisioner, get_allocator,
                       get_placement, get_scheduler, list_placements)
from repro.core.delay_model import DelayModel
from repro.core.multiserver import (MultiOnlineSimulation, best_projection,
                                    cell_objective, provision_multi,
                                    simulate_online_multi, split_scenario)
from repro.core.quality_model import PowerLawFID
from repro.core.service import (EdgeServer, Scenario, ServiceRequest,
                                make_scenario)

DELAY = DelayModel()
QUALITY = PowerLawFID()


class TestScenarioSampling:
    def test_default_is_single_server_and_bit_identical(self):
        base = make_scenario(K=10, seed=4)
        assert base.servers is None
        assert base.n_servers == 1
        multi = make_scenario(K=10, n_servers=3, seed=4)
        for a, b in zip(base.services, multi.services):
            assert a.deadline == b.deadline
            assert a.spectral_eff == b.spectral_eff

    def test_servers_split_bandwidth_equally(self):
        scn = make_scenario(K=6, n_servers=4, seed=0)
        assert scn.n_servers == 4
        assert all(s.bandwidth_hz ==
                   pytest.approx(scn.total_bandwidth_hz / 4)
                   for s in scn.server_list)
        assert all(s.speed == 1.0 for s in scn.server_list)

    def test_speed_range_sampled_after_base_draws(self):
        plain = make_scenario(K=6, n_servers=3, seed=7)
        fast = make_scenario(K=6, n_servers=3,
                             server_speed_range=(0.5, 2.0), seed=7)
        for a, b in zip(plain.services, fast.services):
            assert a.deadline == b.deadline
        assert all(0.5 <= s.speed <= 2.0 for s in fast.server_list)
        assert len({s.speed for s in fast.server_list}) > 1

    def test_server_delay_model_scales_with_speed(self):
        sv = EdgeServer(id=0, bandwidth_hz=1e4, speed=2.0)
        d = sv.delay_model(DELAY)
        assert d.a == pytest.approx(DELAY.a / 2.0)
        assert d.b == pytest.approx(DELAY.b / 2.0)
        assert sv.delay_model(DELAY).g(4) == pytest.approx(DELAY.g(4) / 2)
        one = EdgeServer(id=1, bandwidth_hz=1e4)
        assert one.delay_model(DELAY) is DELAY

    def test_implicit_server_owns_whole_budget(self):
        scn = make_scenario(K=4, seed=0)
        (srv,) = scn.server_list
        assert srv.bandwidth_hz == scn.total_bandwidth_hz

    def test_invalid_n_servers_rejected(self):
        with pytest.raises(AssertionError, match="n_servers"):
            make_scenario(K=4, n_servers=0)


class TestSplitScenario:
    def test_partition_preserves_order_and_budget(self):
        scn = make_scenario(K=9, n_servers=3, seed=1)
        assignment = [i % 3 for i in range(9)]
        subs = split_scenario(scn, assignment)
        assert sum(sub.K for sub in subs) == 9
        for m, sub in enumerate(subs):
            assert [s.id for s in sub.services] == \
                [s.id for s, a in zip(scn.services, assignment) if a == m]
            assert sub.total_bandwidth_hz == \
                pytest.approx(scn.server_list[m].bandwidth_hz)

    def test_capacity_violation_raises(self):
        scn = make_scenario(K=4, n_servers=2, server_capacity=2, seed=0)
        with pytest.raises(AssertionError, match="capacity"):
            split_scenario(scn, [0, 0, 0, 1])

    def test_unknown_server_raises(self):
        scn = make_scenario(K=2, n_servers=2, seed=0)
        with pytest.raises(AssertionError):
            split_scenario(scn, [0, 5])


class TestSingleServerEquivalence:
    """The acceptance bar: n_servers=1 through the multi-server pipeline
    reproduces the single-server results exactly."""

    @pytest.mark.parametrize("scheduler", ["stacking", "greedy",
                                           "equal_steps"])
    @pytest.mark.parametrize("allocator", ["inv_se", "equal"])
    def test_static_pipeline_matches_provisioner(self, scheduler,
                                                 allocator):
        scn = make_scenario(K=8, seed=3)
        single = Provisioner(scn, scheduler=scheduler,
                             allocator=allocator).run()
        multi = MultiServerProvisioner(scn, placement="round_robin",
                                       scheduler=scheduler,
                                       allocator=allocator).run()
        assert multi.sim.outcomes == single.sim.outcomes
        assert multi.mean_fid == single.mean_fid
        assert multi.outage_rate == single.outage_rate
        assert list(multi.assignment) == [0] * scn.K
        assert len(multi.reports) == 1
        np.testing.assert_array_equal(multi.reports[0].allocation,
                                      single.allocation)

    @pytest.mark.parametrize("placement", ["round_robin", "least_loaded",
                                           "greedy_fid", "alternating"])
    def test_every_placement_degenerates_on_one_server(self, placement):
        scn = make_scenario(K=6, seed=5)
        single = Provisioner(scn, scheduler="stacking",
                             allocator="inv_se").run()
        multi = MultiServerProvisioner(scn, placement=placement,
                                       scheduler="stacking",
                                       allocator="inv_se").run()
        assert multi.sim.outcomes == single.sim.outcomes

    def test_online_matches_simulate_online(self):
        scn = make_scenario(K=8, arrival_rate=0.5, seed=3)
        single = OnlineProvisioner(scn, scheduler="stacking",
                                   allocator="inv_se").run()
        multi = simulate_online_multi(scn, get_scheduler("stacking"),
                                      get_allocator("inv_se"),
                                      DELAY, QUALITY)
        assert multi.result.outcomes == single.result.outcomes
        assert multi.assignment == {o.id: 0
                                    for o in single.result.outcomes}

    def test_online_all_arrivals_at_zero_matches_static_simulate(self):
        """Extends the PR 2 equivalence test to the multi-server path:
        one server + all arrivals at t=0 == the static pipeline."""
        scn = make_scenario(K=8, seed=6)
        assert scn.is_static
        static = Provisioner(scn, scheduler="stacking",
                             allocator="inv_se").run()
        multi = simulate_online_multi(scn, get_scheduler("stacking"),
                                      get_allocator("inv_se"),
                                      DELAY, QUALITY)
        assert multi.result.outcomes == static.sim.outcomes


class TestPlacements:
    def test_registry_entries_present(self):
        for name in ("round_robin", "least_loaded", "greedy_fid",
                     "alternating"):
            assert name in PLACEMENTS
        assert "rr" in PLACEMENTS                  # alias
        assert list_placements() == sorted(list_placements())

    def test_unknown_name_raises_with_candidates(self):
        with pytest.raises(KeyError, match="unknown placement"):
            get_placement("teleport")

    def test_round_robin_cycles(self):
        scn = make_scenario(K=7, n_servers=3, seed=0)
        out = get_placement("round_robin")(scn)
        assert list(out) == [0, 1, 2, 0, 1, 2, 0]

    def test_least_loaded_prefers_fast_servers(self):
        scn = Scenario(
            services=[ServiceRequest(id=k, deadline=10.0,
                                     spectral_eff=7.0) for k in range(4)],
            servers=[EdgeServer(id=0, bandwidth_hz=2e4, speed=1.0),
                     EdgeServer(id=1, bandwidth_hz=2e4, speed=3.0)])
        out = get_placement("least_loaded")(scn)
        # the 3x server absorbs three services per one on the baseline
        assert list(out).count(1) == 3

    @pytest.mark.parametrize("placement", ["round_robin", "least_loaded",
                                           "greedy_fid"])
    def test_capacity_respected(self, placement):
        scn = make_scenario(K=6, n_servers=3, server_capacity=2, seed=2)
        out = get_placement(placement)(
            scn, get_scheduler("stacking"), get_allocator("inv_se"),
            DELAY, QUALITY)
        counts = np.bincount(np.asarray(out), minlength=3)
        assert counts.max() <= 2

    def test_insufficient_capacity_raises(self):
        scn = make_scenario(K=6, n_servers=2, server_capacity=2, seed=0)
        with pytest.raises(AssertionError, match="capacities"):
            get_placement("round_robin")(scn)

    def test_greedy_fid_no_worse_than_round_robin(self):
        """The benchmark ordering claim, pinned as a unit test on a
        heterogeneous scenario."""
        scn = make_scenario(K=9, n_servers=3,
                            server_speed_range=(0.6, 1.4), seed=0)
        sched, alloc = get_scheduler("stacking"), get_allocator("inv_se")
        fids = {}
        for placement in ("round_robin", "greedy_fid"):
            a = get_placement(placement)(scn, sched, alloc, DELAY,
                                         QUALITY)
            fids[placement] = provision_multi(scn, a, sched, alloc,
                                              DELAY, QUALITY).mean_fid
        assert fids["greedy_fid"] <= fids["round_robin"] + 1e-9

    def test_alternating_no_worse_than_its_init(self):
        scn = make_scenario(K=6, n_servers=2,
                            server_speed_range=(0.5, 1.5), seed=1)
        sched, alloc = get_scheduler("stacking"), get_allocator("inv_se")
        init = get_placement("least_loaded")(scn, sched, alloc, DELAY,
                                             QUALITY)
        out = get_placement("alternating")(scn, sched, alloc, DELAY,
                                           QUALITY, sweeps=1)
        f_init = provision_multi(scn, init, sched, alloc, DELAY,
                                 QUALITY).mean_fid
        f_alt = provision_multi(scn, out, sched, alloc, DELAY,
                                QUALITY).mean_fid
        assert f_alt <= f_init + 1e-9


class TestMultiProvisionReport:
    def test_per_server_bundle_is_consistent(self):
        scn = make_scenario(K=9, n_servers=3,
                            server_speed_range=(0.7, 1.3), seed=0)
        rep = MultiServerProvisioner(scn, placement="least_loaded",
                                     scheduler="stacking",
                                     allocator="inv_se").run()
        assert len(rep.sim.outcomes) == 9
        assert sorted(o.id for o in rep.sim.outcomes) == list(range(9))
        assert sum(r.scenario.K for r in rep.reports) == 9
        for sid, sub in zip(rep.server_ids, rep.reports):
            server = scn.server_list[sid]
            # each cell's allocation sums to its own budget
            assert sub.allocation.sum() == \
                pytest.approx(server.bandwidth_hz)
            # and plans with the cell's speed-scaled delay model
            assert sub.delay == server.delay_model(DELAY)
            assert rep.report_for(sid) is sub
        assert rep.report_for(99) is None
        assert "placement=least_loaded" in rep.summary()

    def test_explicit_assignment_overrides_placement(self):
        scn = make_scenario(K=4, n_servers=2, seed=0)
        rep = MultiServerProvisioner(scn, placement="least_loaded",
                                     scheduler="greedy",
                                     allocator="equal").run(
                                         assignment=[1, 1, 1, 1])
        assert rep.server_ids == [1]
        assert rep.reports[0].scenario.K == 4

    def test_cell_objective_empty_is_zero(self):
        empty = Scenario(services=[], total_bandwidth_hz=1e4)
        assert cell_objective(empty, get_scheduler("greedy"),
                              get_allocator("equal"), DELAY,
                              QUALITY) == 0.0


class TestMultiOnline:
    def test_arrivals_route_across_cells(self):
        scn = make_scenario(K=12, n_servers=3, arrival_rate=2.0, seed=0)
        rep = MultiServerProvisioner(scn, scheduler="stacking",
                                     allocator="inv_se").run_online()
        assert len(rep.result.outcomes) == 12
        assert set(rep.assignment.values()) == {0, 1, 2}
        assert rep.reject_rate == 0.0

    def test_capacity_respected_online(self):
        scn = make_scenario(K=6, n_servers=3, server_capacity=2,
                            arrival_rate=1.0, seed=1)
        sim = MultiOnlineSimulation(scn, get_scheduler("greedy"),
                                    get_allocator("equal"), DELAY,
                                    QUALITY, admission=lambda *a: True)
        res = sim.run()
        counts = {}
        for m in res.assignment.values():
            counts[m] = counts.get(m, 0) + 1
        assert max(counts.values()) <= 2

    def test_full_cluster_force_rejects_arrivals(self):
        """Capacity is hard online: once every cell hosts its cap, the
        remaining arrivals are rejected even under admit_all — never
        silently oversubscribed (the static path asserts instead)."""
        scn = make_scenario(K=10, n_servers=2, server_capacity=3,
                            arrival_rate=1.0, seed=0)
        sim = MultiOnlineSimulation(scn, get_scheduler("greedy"),
                                    get_allocator("equal"), DELAY,
                                    QUALITY, admission=lambda *a: True)
        res = sim.run()
        assert len(res.assignment) == 6          # 2 cells x capacity 3
        assert res.reject_rate == pytest.approx(0.4)
        for m in (0, 1):
            hosted = sum(1 for v in res.assignment.values() if v == m)
            assert hosted <= 3
        # the rejected four are the latest arrivals, with outage rows
        rejected = [d for d in res.result.decisions if not d.admitted]
        assert len(rejected) == 4
        assert all(d.projected.steps == 0 for d in rejected)

    def test_custom_placement_cannot_oversubscribe(self):
        scn = make_scenario(K=4, n_servers=2, server_capacity=1,
                            arrival_rate=1.0, seed=2)
        sim = MultiOnlineSimulation(scn, get_scheduler("greedy"),
                                    get_allocator("equal"), DELAY,
                                    QUALITY, admission=lambda *a: True,
                                    placement=lambda svc, s: 0)
        res = sim.run()
        assert list(res.assignment.values()) == [0]   # cap 1 on cell 0
        assert res.reject_rate == pytest.approx(0.75)

    def test_best_projection_no_worse_than_earliest_free(self):
        scn = make_scenario(K=10, n_servers=3, arrival_rate=1.5,
                            server_speed_range=(0.5, 1.5), seed=2)
        free = simulate_online_multi(scn, get_scheduler("stacking"),
                                     get_allocator("inv_se"), DELAY,
                                     QUALITY)
        best = simulate_online_multi(scn, get_scheduler("stacking"),
                                     get_allocator("inv_se"), DELAY,
                                     QUALITY, placement=best_projection)
        assert best.mean_fid <= free.mean_fid + 1e-9

    def test_per_cell_transmissions_never_exceed_cell_budget(self):
        """The P1 constraint holds per cell at every instant: replans on
        one server only hand out that cell's uncommitted bandwidth."""
        scn = make_scenario(K=12, n_servers=2, tau_min=1.0, tau_max=3.0,
                            arrival_rate=4.0, seed=0,
                            content_bits_range=(65536.0, 262144.0))
        sim = MultiOnlineSimulation(scn, get_scheduler("stacking"),
                                    get_allocator("inv_se"), DELAY,
                                    QUALITY, admission=lambda *a: True)
        res = sim.run()
        for m, server in enumerate(scn.server_list):
            spans = [(st.gen_end, st.tx_end, st.bandwidth)
                     for sid, st in sim.states.items()
                     if st.gen_complete and res.assignment.get(sid) == m]
            for t0, _, _ in spans:
                in_air = sum(bw for s, e, bw in spans if s <= t0 < e)
                assert in_air <= server.bandwidth_hz + 1e-6
