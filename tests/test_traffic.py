"""repro.core.traffic: arrival-process contract (sorted, in-window,
seed-deterministic), inhomogeneous-Poisson empirical rates, trace
loading with loud malformed-row errors (ISSUE 8 satellite)."""

import json

import numpy as np
import pytest

from repro.core.traffic import (DiurnalPoisson, FlashCrowd,
                                InhomogeneousPoisson, PoissonProcess,
                                TraceArrivals, correlated_rates,
                                load_trace)

ALL_PROCESSES = [
    PoissonProcess(2.0),
    DiurnalPoisson(2.0, amplitude=0.8, period=10.0),
    FlashCrowd(0.5, 4.0, start=3.0, duration=2.0),
    InhomogeneousPoisson(lambda t: 1.0 + 0.5 * np.cos(np.asarray(t)),
                         rate_max=1.5),
    TraceArrivals([0.5, 1.5, 2.5, 9.9]),
]


@pytest.mark.parametrize("proc", ALL_PROCESSES,
                         ids=lambda p: type(p).__name__)
class TestSampleContract:
    def test_sorted_float64_in_window(self, proc):
        t = proc.sample(np.random.default_rng(0), 0.0, 10.0)
        assert t.dtype == np.float64
        assert np.all(np.diff(t) >= 0)
        assert t.size == 0 or (t[0] >= 0.0 and t[-1] < 10.0)

    def test_seed_determinism(self, proc):
        a = proc.sample(np.random.default_rng(42), 0.0, 10.0)
        b = proc.sample(np.random.default_rng(42), 0.0, 10.0)
        np.testing.assert_array_equal(a, b)

    def test_empty_window(self, proc):
        assert proc.sample(np.random.default_rng(0), 3.0, 3.0).size == 0

    def test_bad_window_raises(self, proc):
        with pytest.raises(ValueError):
            proc.sample(np.random.default_rng(0), 5.0, 4.0)
        with pytest.raises(ValueError):
            proc.mean_rate(0.0, float("inf"))


class TestPoisson:
    def test_rejects_bad_rate(self):
        with pytest.raises(ValueError):
            PoissonProcess(-1.0)
        with pytest.raises(ValueError):
            PoissonProcess(float("nan"))

    def test_empirical_rate(self):
        rng = np.random.default_rng(1)
        n = PoissonProcess(3.0).sample(rng, 0.0, 10_000.0).size
        assert n == pytest.approx(30_000, rel=0.02)

    def test_mean_rate(self):
        assert PoissonProcess(3.0).mean_rate(0.0, 5.0) == 3.0


class TestInhomogeneous:
    def test_empirical_rate_tracks_intensity(self):
        """Thinning must reproduce the intensity empirically: compare
        per-bin arrival counts of a diurnal curve against its
        integrated rate over many windows."""
        proc = DiurnalPoisson(5.0, amplitude=1.0, period=8.0)
        rng = np.random.default_rng(7)
        t = proc.sample(rng, 0.0, 4_000.0)
        # fold onto one period, 8 bins of width 1
        counts, _ = np.histogram(t % 8.0, bins=8, range=(0.0, 8.0))
        w = 2 * np.pi / 8.0
        edges = np.arange(9.0)
        # integral of 5(1+sin(wt)) over each bin
        expect = np.diff(5.0 * (edges - (np.cos(w * edges)
                                         - 1.0) / w)) * 500
        # ~4 Poisson sigmas of slack on the smallest bin (seeded run)
        np.testing.assert_allclose(counts, expect, rtol=0.05, atol=65)

    def test_overall_rate_matches_base(self):
        proc = DiurnalPoisson(5.0, amplitude=1.0, period=8.0)
        n = proc.sample(np.random.default_rng(3), 0.0, 4_000.0).size
        assert n == pytest.approx(20_000, rel=0.03)
        assert proc.mean_rate(0.0, 8.0) == pytest.approx(5.0, rel=1e-3)

    def test_flash_crowd_surges(self):
        proc = FlashCrowd(0.5, 20.0, start=100.0, duration=10.0)
        rng = np.random.default_rng(11)
        t = proc.sample(rng, 0.0, 200.0)
        in_surge = ((t >= 100.0) & (t < 110.0)).sum()
        outside = t.size - in_surge
        assert in_surge == pytest.approx(200, rel=0.25)
        assert outside == pytest.approx(95, rel=0.35)

    def test_envelope_violation_raises(self):
        proc = InhomogeneousPoisson(lambda t: np.full(np.shape(t), 5.0),
                                    rate_max=1.0)
        with pytest.raises(ValueError, match="envelope"):
            proc.sample(np.random.default_rng(0), 0.0, 100.0)

    def test_negative_rate_raises(self):
        proc = InhomogeneousPoisson(lambda t: np.full(np.shape(t), -1.0),
                                    rate_max=1.0)
        with pytest.raises(ValueError):
            proc.sample(np.random.default_rng(0), 0.0, 100.0)

    def test_rejects_bad_params(self):
        with pytest.raises(ValueError):
            DiurnalPoisson(1.0, amplitude=1.5)
        with pytest.raises(ValueError):
            FlashCrowd(2.0, 1.0, start=0.0, duration=1.0)  # peak < base


class TestTrace:
    def test_chunking_is_exact(self):
        """Any partition of the horizon replays the identical trace —
        the property the fleet event/epoch cross-check rests on."""
        tr = TraceArrivals([3.0, 0.5, 7.2, 5.0, 5.0 + 1e-12])
        rng = np.random.default_rng(0)
        whole = tr.sample(rng, 0.0, 10.0)
        chunks = np.concatenate([tr.sample(rng, a, b) for a, b in
                                 [(0.0, 2.5), (2.5, 5.0), (5.0, 10.0)]])
        np.testing.assert_array_equal(whole, chunks)
        assert whole.size == 5

    def test_window_is_half_open(self):
        tr = TraceArrivals([1.0, 2.0, 3.0])
        assert tr.sample(np.random.default_rng(0), 1.0,
                         3.0).tolist() == [1.0, 2.0]

    def test_rejects_non_finite(self):
        with pytest.raises(ValueError):
            TraceArrivals([1.0, float("nan")])
        with pytest.raises(ValueError):
            TraceArrivals([[1.0, 2.0]])


class TestLoadTrace:
    def test_csv_roundtrip(self, tmp_path):
        p = tmp_path / "trace.csv"
        p.write_text("cell,arrival,extra\n0,1.5,x\n1,9.0,y\n0,0.25,z\n")
        assert load_trace(p, cell=0).times.tolist() == [0.25, 1.5]
        assert load_trace(p, cell=1).times.tolist() == [9.0]
        assert load_trace(p, cell=2).times.size == 0

    def test_csv_missing_columns(self, tmp_path):
        p = tmp_path / "trace.csv"
        p.write_text("time\n1.5\n")
        with pytest.raises(ValueError, match="'cell' and 'arrival'"):
            load_trace(p)

    def test_csv_malformed_rows_name_the_row(self, tmp_path):
        p = tmp_path / "trace.csv"
        p.write_text("cell,arrival\n0,1.5\n0,oops\n")
        with pytest.raises(ValueError, match="row 3.*not a number"):
            load_trace(p)
        p.write_text("cell,arrival\n0,\n")
        with pytest.raises(ValueError, match="row 2.*missing"):
            load_trace(p)
        p.write_text("cell,arrival\nzero,1.5\n")
        with pytest.raises(ValueError, match="not an integer"):
            load_trace(p)
        p.write_text("cell,arrival\n0,-2.0\n")
        with pytest.raises(ValueError, match="finite and >= 0"):
            load_trace(p)

    def test_json_flat_and_keyed(self, tmp_path):
        p = tmp_path / "trace.json"
        p.write_text(json.dumps([2.0, 1.0]))
        assert load_trace(p).times.tolist() == [1.0, 2.0]
        p.write_text(json.dumps({"0": [1.0], "3": [4.0, 2.0]}))
        assert load_trace(p, cell=3).times.tolist() == [2.0, 4.0]

    def test_json_errors_name_the_problem(self, tmp_path):
        p = tmp_path / "trace.json"
        p.write_text("not json {")
        with pytest.raises(ValueError, match="not valid JSON"):
            load_trace(p)
        p.write_text(json.dumps([1.0, "x"]))
        with pytest.raises(ValueError, match="entry 1.*not a number"):
            load_trace(p)
        p.write_text(json.dumps([1.0]))
        with pytest.raises(ValueError, match="cell=2"):
            load_trace(p, cell=2)
        p.write_text(json.dumps({"0": [1.0]}))
        with pytest.raises(ValueError, match="no trace for cell 5"):
            load_trace(p, cell=5)
        p.write_text(json.dumps({"0": 17}))
        with pytest.raises(ValueError, match="list of timestamps"):
            load_trace(p)
        p.write_text(json.dumps(42))
        with pytest.raises(ValueError, match="list of times"):
            load_trace(p)


class TestCorrelatedRates:
    def test_mean_and_positivity(self):
        rates = np.concatenate([
            correlated_rates(np.random.default_rng(s), 64, 2.0,
                             correlation=0.5)
            for s in range(200)])
        assert np.all(rates > 0)
        assert rates.mean() == pytest.approx(2.0, rel=0.02)

    def test_full_correlation_moves_together(self):
        rates = correlated_rates(np.random.default_rng(5), 16, 2.0,
                                 correlation=1.0)
        assert np.ptp(rates) == pytest.approx(0.0, abs=1e-12)

    def test_rejects_bad_params(self):
        with pytest.raises(ValueError):
            correlated_rates(np.random.default_rng(0), 4, 1.0,
                             correlation=1.5)
        with pytest.raises(ValueError):
            correlated_rates(np.random.default_rng(0), 0, 1.0)
