"""End-to-end behaviour tests for the paper's system: the full
generation+transmission pipeline, plan->executor consistency, and the
dry-run path on the real (single) device."""


import jax
import numpy as np
import pytest

from repro.config import RunConfig, SHAPES, get_config, smoke_variant
from repro.configs.ddim_cifar10 import SMOKE
from repro.core.bandwidth import pso_allocate, tau_prime_of
from repro.core.delay_model import DelayModel
from repro.core.quality_model import PowerLawFID
from repro.core.service import make_scenario
from repro.core.simulator import simulate
from repro.core.stacking import stacking
from repro.diffusion import unet
from repro.diffusion.executor import BatchDenoisingExecutor
from repro.models import api
from repro.models.params import init_params


def test_full_paper_pipeline_end_to_end():
    """Scenario -> PSO bandwidth -> STACKING -> execute on the U-Net ->
    all deadlines met, plan constraints hold, images produced."""
    delay, quality = DelayModel(), PowerLawFID()
    scn = make_scenario(K=6, tau_min=4, tau_max=10, seed=3)
    res = pso_allocate(scn, stacking, delay, quality,
                       num_particles=6, iters=4)
    tp = tau_prime_of(scn, res.alloc)
    plan = stacking(scn.services, tp, delay, quality)
    plan.validate(gen_deadlines=tp)

    sim = simulate(scn, res.alloc, plan, quality)
    assert sim.outage_rate == 0.0
    assert all(o.steps > 0 for o in sim.outcomes)

    params = init_params(unet.schema(SMOKE), jax.random.PRNGKey(0))
    ex = BatchDenoisingExecutor(SMOKE, params)
    images, _ = ex.run(plan, jax.random.PRNGKey(1))
    assert set(images) == {s.id for s in scn.services}
    assert all(np.isfinite(v).all() for v in images.values())


def test_input_specs_cover_all_shapes():
    """Every (arch x shape) produces well-formed abstract input specs."""
    run = RunConfig()
    for arch in ("tinyllama-1.1b", "whisper-tiny", "llama-3.2-vision-90b",
                 "xlstm-125m", "qwen3-moe-30b-a3b"):
        cfg = get_config(arch)
        for shape in SHAPES.values():
            specs = api.input_specs(cfg, shape, run, abstract=True)
            if shape.kind == "decode":
                assert "cache" in specs and "token" in specs
                assert specs["token"].shape == (shape.global_batch, 1)
            else:
                assert specs["tokens"].shape == (shape.global_batch,
                                                 shape.seq_len)


def test_dryrun_smoke_on_host_mesh():
    """The dry-run machinery itself (1-device mesh, reduced arch):
    lower+compile+analyze must succeed in-process."""
    import repro.launch.hlo_cost as hc
    cfg = smoke_variant(get_config("tinyllama-1.1b"))
    run = RunConfig()
    params_abs = api.abstract_model(cfg)
    import jax.numpy as jnp
    step = api.make_decode_step(cfg, run)
    cache = api.get_model(cfg).init_cache(cfg, 2, 64, run, abstract=True)
    tok = jax.ShapeDtypeStruct((2, 1), jnp.int32)
    compiled = jax.jit(step).lower(params_abs, tok, cache).compile()
    rec = hc.analyze_hlo(compiled.as_text())
    assert rec["flops"] > 0 and rec["bytes"] > 0


def test_dryrun_artifacts_complete_if_present():
    """If the full sweep has been run, all 80 artifacts must exist and
    agree on schema."""
    import glob
    import json
    import os
    art = os.path.join(os.path.dirname(__file__), "..", "artifacts",
                       "dryrun")
    files = glob.glob(os.path.join(art, "*.json"))
    if len(files) < 80:
        pytest.skip("full dry-run sweep not present")
    single = [f for f in files if f.endswith("_16x16.json")]
    multi = [f for f in files if f.endswith("_2x16x16.json")]
    assert len(single) == 40 and len(multi) == 40
    for f in files:
        rec = json.load(open(f))
        assert rec["roofline"]["dominant"] in ("compute_s", "memory_s",
                                               "collective_s")
        assert rec["hlo_flops_per_chip"] > 0
