"""Closed-loop plan execution: ``repro.core.execution`` +
``repro.api.execution`` (sim-to-real loop on the simulated executor,
plus the calibrate -> refit -> replan pieces)."""

import numpy as np
import pytest

from repro.api import (OnlineProvisioner, Provisioner, execute_report,
                       list_executors)
from repro.core.delay_model import DelayModel, RollingDelayFit
from repro.core.execution import SimulatedSession
from repro.core.service import make_scenario

TRUE = DelayModel(a=0.1, b=0.2)
HALF = DelayModel(a=0.05, b=0.1)   # the planner's 2x-fast misestimate

SIM_KW = {"executor": "simulated",
          "executor_kwargs": {"true_delay": TRUE},
          "min_batches": 2, "drift_tol": 0.2}


def _provisioner(scn, delay=HALF, **kw):
    return Provisioner(scn, scheduler="stacking_offset",
                       allocator="inv_se", delay=delay,
                       execute_kwargs=dict(SIM_KW, **kw))


class TestDelayRefit:
    def test_scaled(self):
        m = DelayModel(a=0.2, b=0.4).scaled(0.5)
        assert m.a == pytest.approx(0.1) and m.b == pytest.approx(0.2)

    def test_refit_recovers_affine(self):
        sizes = [1, 2, 4, 8]
        m = DelayModel(a=1.0, b=1.0).refit(sizes,
                                           [TRUE.g(x) for x in sizes])
        assert m.a == pytest.approx(TRUE.a)
        assert m.b == pytest.approx(TRUE.b)

    def test_refit_single_size_scales(self):
        prior = DelayModel(a=0.1, b=0.2)
        m = prior.refit([4, 4], [2 * prior.g(4), 2 * prior.g(4)])
        assert m.a == pytest.approx(0.2) and m.b == pytest.approx(0.4)

    def test_refit_rejects_empty_and_mismatch(self):
        with pytest.raises(ValueError):
            DelayModel().refit([], [])
        with pytest.raises(ValueError):
            DelayModel().refit([1, 2], [0.1])

    def test_rolling_fit_window(self):
        fit = RollingDelayFit(window=4, prior=HALF)
        assert not fit.ready
        assert fit.model().g(2) == pytest.approx(HALF.g(2))
        for x in (1, 2, 3, 4, 5):
            fit.observe(x, TRUE.g(x))
        assert fit.ready and len(fit) == 4     # oldest rolled out
        m = fit.model()
        assert m.a == pytest.approx(TRUE.a)
        assert m.b == pytest.approx(TRUE.b)
        assert fit.model(headroom=1.5).g(3) == \
            pytest.approx(1.5 * m.g(3))


class TestSimulatedSession:
    def test_runs_and_credits(self):
        scn = make_scenario(K=3, seed=0)
        rep = _provisioner(scn).run(execute=False)
        sess = SimulatedSession(rep.plan, TRUE)
        batch = [k for k, _ in rep.plan.batches[0]]
        dt = sess.run_batch(batch, timed=True)
        assert dt == pytest.approx(TRUE.g(len(batch)))
        assert all(sess.steps_done[k] == 1 for k in batch)

    def test_exhausted_steps_raise(self):
        scn = make_scenario(K=2, seed=0)
        rep = _provisioner(scn).run(execute=False)
        sess = SimulatedSession(rep.plan, TRUE)
        k = next(iter(rep.plan.steps_completed))
        for _ in range(rep.plan.steps_completed[k]):
            sess.run_batch([k])
        with pytest.raises(ValueError, match="no remaining"):
            sess.run_batch([k])

    def test_retarget_no_resurrection(self):
        scn = make_scenario(K=2, seed=0)
        rep = _provisioner(scn).run(execute=False)
        sess = SimulatedSession(rep.plan, TRUE)
        k = next(iter(rep.plan.steps_completed))
        sess.run_batch([k])
        with pytest.raises(ValueError, match="retarget"):
            sess.retarget({k: 0})


class TestExecutionLoop:
    def test_open_loop_runs_plan_as_given(self):
        scn = make_scenario(K=5, seed=1)
        rep = _provisioner(scn).run(execute="open")
        ex = rep.execution
        assert ex.mode == "open" and ex.replans == 0
        assert len(ex.records) == rep.plan.num_batches
        assert [r.size for r in ex.records] == \
            [len(b) for b in rep.plan.batches]
        assert ex.wall_clock == pytest.approx(
            sum(r.measured_s for r in ex.records))
        # deterministic session: every batch took exactly g_true(X)
        for r in ex.records:
            assert r.measured_s == pytest.approx(TRUE.g(r.size))

    def test_final_refit_in_both_modes(self):
        """result.delay reflects the measured hardware, so
        predicted_wall agrees with wall_clock even open loop."""
        scn = make_scenario(K=5, seed=1)
        for mode in ("open", "closed"):
            ex = _provisioner(scn).run(execute=mode).execution
            assert ex.refits >= 1
            assert ex.predicted_wall() == pytest.approx(ex.wall_clock,
                                                        rel=1e-6)

    def test_closed_beats_open_under_misestimate(self):
        """The tentpole claim: under a 2x-slow hardware reality the
        closed loop replans and delivers, the open loop overruns."""
        scn = make_scenario(K=5, seed=1)
        open_ex = _provisioner(scn).run(execute="open").execution
        closed_ex = _provisioner(scn).run(execute="closed").execution
        assert closed_ex.replans >= 1 and closed_ex.refits >= 1
        assert closed_ex.delivered_fid < open_ex.delivered_fid
        assert closed_ex.outage_rate < open_ex.outage_rate

    def test_no_drift_no_replan(self):
        """A perfect delay model never triggers a replan."""
        scn = make_scenario(K=5, seed=1)
        ex = _provisioner(scn, delay=TRUE).run(execute="closed").execution
        assert ex.replans == 0
        assert ex.outage_rate == 0.0

    def test_executed_log_monotone_no_resurrection(self):
        scn = make_scenario(K=6, seed=2)
        ex = _provisioner(scn).run(execute="closed").execution
        seen = {}
        for t, k, steps in ex.executed_log:
            assert steps == seen.get(k, 0) + 1    # one step per entry
            seen[k] = steps
        by_id = {o.id: o for o in ex.outcomes}
        # content (the simulated session's step counts) == credited
        assert ex.content == {k: by_id[k].steps for k in ex.content}
        times = [t for t, _, _ in ex.executed_log]
        assert times == sorted(times)

    def test_telemetry_timings_shape(self):
        scn = make_scenario(K=4, seed=3)
        rep = _provisioner(scn).run(execute="closed")
        ex = rep.execution
        assert rep.timings == ex.timings
        assert all(x >= 1 and s > 0 for x, s in ex.timings)
        d = ex.to_dict()
        assert d["kind"] == "execution"
        assert d["telemetry"]["batches"] == len(ex.records)
        # the simulated session names its engine through telemetry
        assert d["exec_engine"] == "simulated"
        assert d["telemetry"]["session"] == {"exec_engine": "simulated"}

    def test_per_bucket_telemetry(self):
        """Per-kernel attribution: measured wall-clock grouped by the
        padded batch-shape bucket, counts and totals consistent with
        the raw records."""
        from repro.core.execution import shape_bucket
        scn = make_scenario(K=5, seed=1)
        ex = _provisioner(scn).run(execute="closed").execution
        pb = ex.per_bucket()
        assert sum(b["batches"] for b in pb.values()) == len(ex.records)
        assert sum(b["total_s"] for b in pb.values()) == \
            pytest.approx(sum(r.measured_s for r in ex.records))
        for bucket, agg in pb.items():
            sizes = [r.size for r in ex.records
                     if shape_bucket(r.size) == bucket]
            assert len(sizes) == agg["batches"]
            # float rounding: a bucket's mean can land an ulp under
            # its min when every batch measured the same duration
            assert agg["min_s"] <= agg["mean_s"] + 1e-9
        d = ex.to_dict()["telemetry"]["per_bucket"]
        assert set(d) == {str(b) for b in pb}

    def test_noise_does_not_break_loop(self):
        scn = make_scenario(K=5, seed=4)
        ex = _provisioner(
            scn, executor_kwargs={"true_delay": TRUE, "noise": 0.1,
                                  "seed": 7}).run(
            execute="closed").execution
        assert np.isfinite(ex.delivered_fid)
        assert ex.wall_clock > 0

    def test_mode_validation(self):
        scn = make_scenario(K=3, seed=0)
        with pytest.raises(ValueError, match="execute"):
            _provisioner(scn).run(execute="sideways")
        with pytest.raises(ValueError, match="execute"):
            Provisioner(scn, execute="sideways")


class TestExecuteReport:
    def test_from_report(self):
        scn = make_scenario(K=4, seed=5)
        rep = _provisioner(scn).run(execute=False)
        ex = execute_report(rep, mode="closed", executor="simulated",
                            executor_kwargs={"true_delay": TRUE},
                            min_batches=2, drift_tol=0.2)
        assert ex.mode == "closed"
        assert len(ex.records) > 0

    def test_registry_names(self):
        assert {"diffusion", "llm_decode", "simulated"} <= \
            set(list_executors())


class TestOnlineReplay:
    def test_execute_true_replays_committed_batches(self):
        scn = make_scenario(K=6, arrival_rate=0.5, seed=6)
        p = OnlineProvisioner(
            scn, scheduler="stacking_offset", allocator="inv_se",
            delay=TRUE,
            execute_kwargs={"executor": "simulated",
                            "executor_kwargs": {"true_delay": TRUE}})
        rep = p.run(execute=True)
        assert rep.result.executed_batches is not None
        assert len(rep.timings) == len(rep.result.executed_batches)
        # the replayed sessions' step counts match the online outcomes
        steps = {o.id: o.steps for o in rep.result.outcomes}
        assert rep.content == {k: steps[k] for k in rep.content}

    def test_closed_mode_rejected_online(self):
        scn = make_scenario(K=4, arrival_rate=0.5, seed=6)
        p = OnlineProvisioner(scn, allocator="inv_se", delay=TRUE)
        with pytest.raises(ValueError, match="replays"):
            p.run(execute="closed")


class TestCalibrateReplanDecode:
    """The sim-to-real measurement loop on the tiny decode engine:
    measured delay -> DelayModel.refit -> the replanned schedule
    actually changes (the Fig.-1a calibrate -> replan satellite)."""

    def test_measured_refit_changes_plan(self):
        from repro.api import DecodeWorkload
        workload = DecodeWorkload(max_len=32)
        # raw least squares on a tiny engine can extrapolate a slightly
        # negative slope; it still measures a positive per-step delay
        raw = workload.calibrate(batch_sizes=(1, 2, 4), reps=2)
        assert raw.g(1) > 0 and raw.g(4) > 0

        # deadlines sized for the CPU-scale planning model: a handful
        # of decode steps each, comfortably under max_len
        scn = make_scenario(K=3, tau_min=0.15, tau_max=0.3,
                            total_bandwidth_hz=4.0e5, seed=7)
        p = Provisioner(scn, workload=workload, scheduler="stacking",
                        allocator="inv_se", delay=workload.default_delay())
        rep = p.run(execute=True, timed=True)
        assert len(rep.timings) == rep.plan.num_batches

        # the refit protocol clamps to a physical (a >= 0, b > 0) model
        measured = rep.delay.refit([x for x, _ in rep.timings],
                                   [s for _, s in rep.timings])
        assert measured.a >= 0 and measured.b > 0
        fast = Provisioner(scn, scheduler="stacking",
                           allocator="inv_se", delay=measured)
        slow = Provisioner(scn, scheduler="stacking",
                           allocator="inv_se", delay=measured.scaled(4))
        plan_fast = fast.run(execute=False).plan
        plan_slow = slow.run(execute=False).plan
        # 4x-slower model -> strictly fewer total steps fit the budget
        assert sum(plan_slow.steps_completed.values()) < \
            sum(plan_fast.steps_completed.values())

    def test_report_refit_closes_the_loop(self):
        """Timed simulated execution -> report.refit_delay recovers the
        true model -> the next run plans with it."""
        scn = make_scenario(K=5, seed=8)
        p = _provisioner(scn)
        rep = p.run(execute="open")
        refit = rep.refit_delay()
        assert refit.a == pytest.approx(TRUE.a, rel=1e-6)
        assert refit.b == pytest.approx(TRUE.b, rel=1e-6)
