"""Hypothesis property tests for STACKING + baselines.

The properties are the paper's constraints (1), (2), (6), (7), (14) —
``BatchPlan.validate`` checks them all — plus dominance relations the
algorithm is designed to satisfy.  Skipped (not a collection error) when
``hypothesis`` is not installed; ``pip install -r requirements-dev.txt``
brings it in.
"""

import pytest

pytest.importorskip("hypothesis")

from hypothesis import given, settings, strategies as st

from repro.core.baselines import (fixed_size_batching, greedy_batching,
                                  single_instance)
from repro.core.delay_model import DelayModel
from repro.core.quality_model import PowerLawFID
from repro.core.service import ServiceRequest
from repro.core.stacking import stacking, stacking_pass

DELAY = DelayModel()          # paper constants
QUALITY = PowerLawFID()


def _services(taus):
    return [ServiceRequest(id=i, deadline=t, spectral_eff=7.0)
            for i, t in enumerate(taus)]


def _tau_prime(taus):
    return {i: t for i, t in enumerate(taus)}


taus_strategy = st.lists(
    st.floats(min_value=0.05, max_value=30.0, allow_nan=False,
              allow_infinity=False),
    min_size=1, max_size=12)


@settings(max_examples=60, deadline=None)
@given(taus=taus_strategy, t_star=st.integers(1, 50))
def test_stacking_pass_satisfies_constraints(taus, t_star):
    """One T* sweep satisfies (1),(2),(6),(7),(14) for arbitrary inputs."""
    tp = _tau_prime(taus)
    plan = stacking_pass(list(range(len(taus))), tp, DELAY, t_star)
    plan.validate(gen_deadlines=tp)


@settings(max_examples=30, deadline=None)
@given(taus=taus_strategy)
def test_stacking_full_search_valid_and_bounded(taus):
    svcs = _services(taus)
    tp = _tau_prime(taus)
    plan = stacking(svcs, tp, DELAY, QUALITY)
    plan.validate(gen_deadlines=tp)
    for k, t in tp.items():
        # no service exceeds its dedicated-batch upper bound
        assert plan.steps_completed[k] <= max(0, DELAY.max_steps(t))


@settings(max_examples=30, deadline=None)
@given(taus=st.lists(st.floats(min_value=1.0, max_value=25.0),
                     min_size=2, max_size=10))
def test_monotone_in_deadline(taus):
    """Growing every deadline can't hurt mean quality (dominance)."""
    svcs = _services(taus)
    tp = _tau_prime(taus)
    plan1 = stacking(svcs, tp, DELAY, QUALITY)
    q1 = QUALITY.mean_fid(list(plan1.steps_completed.values()))
    tp2 = {k: v + 5.0 for k, v in tp.items()}
    plan2 = stacking(svcs, tp2, DELAY, QUALITY)
    q2 = QUALITY.mean_fid(list(plan2.steps_completed.values()))
    assert q2 <= q1 + 1e-6


@settings(max_examples=25, deadline=None)
@given(taus=taus_strategy)
def test_baselines_satisfy_constraints(taus):
    svcs = _services(taus)
    tp = _tau_prime(taus)
    for sched in (greedy_batching, fixed_size_batching):
        plan = sched(svcs, tp, DELAY)
        plan.validate(gen_deadlines=tp)
    plan = single_instance(svcs, tp, DELAY, QUALITY)
    plan.validate(gen_deadlines=tp)
