"""Benchmark tooling (ISSUE 3 satellites): the --json artifact writer,
the compare.py regression gate (must demonstrably fail on a synthetic
regression), and the benchmarks package's src-path shim running from a
clean subprocess with no PYTHONPATH."""

import json
import os
import subprocess
import sys
from pathlib import Path

import pytest

ROOT = Path(__file__).resolve().parents[1]
if str(ROOT) not in sys.path:           # `benchmarks` lives at the root,
    sys.path.insert(0, str(ROOT))       # not under pythonpath=src

from benchmarks import compare, run as bench_run   # noqa: E402


def _bench_file(tmp_path, suite, rows):
    payload = {"suite": suite, "git_sha": "deadbeef", "elapsed_s": 0.1,
               "rows": [{"name": n, "value": v, "derived": d}
                        for n, v, d in rows]}
    p = tmp_path / f"BENCH_{suite}.json"
    p.write_text(json.dumps(payload))
    return p


BASELINE = {"metrics": {
    "online_r0.5_stacking": {"value": 6.0, "kind": "lower_is_better",
                             "rel_tol": 0.05},
    "online_stacking_best": {"value": 1.0, "kind": "flag"},
}}


class TestCompareGate:
    def test_passes_within_tolerance(self, tmp_path):
        p = _bench_file(tmp_path, "online",
                        [("online_r0.5_stacking", 6.2, ""),
                         ("online_stacking_best", 1.0, "")])
        assert compare.compare(BASELINE, compare.load_measured([p])) == []

    def test_improvement_always_passes(self, tmp_path):
        p = _bench_file(tmp_path, "online",
                        [("online_r0.5_stacking", 1.0, ""),
                         ("online_stacking_best", 1.0, "")])
        assert compare.compare(BASELINE, compare.load_measured([p])) == []

    def test_fid_regression_fails(self, tmp_path):
        p = _bench_file(tmp_path, "online",
                        [("online_r0.5_stacking", 6.5, ""),
                         ("online_stacking_best", 1.0, "")])
        findings = compare.compare(BASELINE, compare.load_measured([p]))
        assert len(findings) == 1
        assert "online_r0.5_stacking" in findings[0]

    def test_flag_drop_fails(self, tmp_path):
        p = _bench_file(tmp_path, "online",
                        [("online_r0.5_stacking", 6.0, ""),
                         ("online_stacking_best", 0.0, "")])
        findings = compare.compare(BASELINE, compare.load_measured([p]))
        assert len(findings) == 1
        assert "flag dropped" in findings[0]

    def test_missing_metric_fails(self, tmp_path):
        p = _bench_file(tmp_path, "online",
                        [("online_r0.5_stacking", 6.0, "")])
        findings = compare.compare(BASELINE, compare.load_measured([p]))
        assert any("missing" in f for f in findings)

    def test_missing_flag_reports_suite_not_keyerror(self, tmp_path):
        """A gated FLAG absent from the artifacts (vs merely 0) must
        come back as a readable 'missing flag' finding naming the
        owning suite — never a KeyError."""
        base = {"metrics": {
            "planner_jax_sharded_ok": {"value": 1.0, "kind": "flag"},
            "churn_handoff_sane": {"value": 1.0, "kind": "flag"},
        }}
        p = _bench_file(tmp_path, "online",
                        [("online_r0.5_stacking", 6.0, "")])
        findings = compare.compare(base, compare.load_measured([p]))
        assert len(findings) == 2
        sharded = next(f for f in findings
                       if "planner_jax_sharded_ok" in f)
        assert "missing flag" in sharded
        assert "suite 'planner_speed'" in sharded
        churn = next(f for f in findings if "churn_handoff_sane" in f)
        assert "missing flag" in churn
        assert "suite 'churn'" in churn

    def test_suite_of_prefix_map(self):
        assert compare.suite_of("planner_tstar_K64_vec_ms") \
            == "planner_speed"
        assert compare.suite_of("offset_beats_shared_under_churn") \
            == "churn"
        assert compare.suite_of("multiserver_greedy") == "multiserver"
        assert compare.suite_of("api_schedulers") == "api"
        assert compare.suite_of("something_else") == "unknown"

    def test_unknown_kind_fails(self):
        base = {"metrics": {"x": {"value": 1.0, "kind": "sideways"}}}
        assert compare.compare(base, {"x": 1.0})

    def test_main_exit_codes(self, tmp_path):
        base_path = tmp_path / "baseline.json"
        base_path.write_text(json.dumps(BASELINE))
        good = _bench_file(tmp_path, "good",
                           [("online_r0.5_stacking", 6.0, ""),
                            ("online_stacking_best", 1.0, "")])
        assert compare.main([str(good),
                             "--baseline", str(base_path)]) == 0
        bad = _bench_file(tmp_path, "bad",
                          [("online_r0.5_stacking", 99.0, ""),
                           ("online_stacking_best", 1.0, "")])
        assert compare.main([str(bad),
                             "--baseline", str(base_path)]) == 1

    def test_update_refreshes_values_keeping_specs(self, tmp_path):
        base_path = tmp_path / "baseline.json"
        base_path.write_text(json.dumps(BASELINE))
        p = _bench_file(tmp_path, "online",
                        [("online_r0.5_stacking", 4.2, ""),
                         ("online_stacking_best", 1.0, "")])
        assert compare.main([str(p), "--baseline", str(base_path),
                             "--update"]) == 0
        refreshed = json.loads(base_path.read_text())
        m = refreshed["metrics"]["online_r0.5_stacking"]
        assert m["value"] == 4.2
        assert m["rel_tol"] == 0.05
        assert m["kind"] == "lower_is_better"

    def test_committed_baseline_gates_known_suites(self):
        """The repo baseline must only gate metrics the CI bench job
        actually produces (api, online, multiserver, churn, fleet,
        e2e, planner_speed suites)."""
        baseline = json.loads(
            (ROOT / "benchmarks" / "baseline.json").read_text())
        assert baseline["metrics"], "baseline must gate something"
        for name, spec in baseline["metrics"].items():
            # "exec_*" rows come out of the e2e suite (the execution
            # engine comparison), see _SUITE_PREFIXES in compare.py
            assert name.split("_")[0] in ("online", "multiserver",
                                          "api", "churn", "offset",
                                          "planner", "fleet", "e2e",
                                          "exec")
            assert spec["kind"] in ("flag", "lower_is_better")
        # every required suite is one the CI bench job runs (ci.yml)
        assert set(baseline["required_suites"]) == \
            {"api", "online", "multiserver", "churn", "fleet",
             "planner_speed", "e2e"}

    def test_fleet_flags_are_gated(self):
        """ISSUE 8 acceptance: the bench gate must pin the fleet
        population/memory/equivalence claims at 1."""
        baseline = json.loads(
            (ROOT / "benchmarks" / "baseline.json").read_text())
        m = baseline["metrics"]
        for flag in ("fleet_matches_multiserver",
                     "fleet_1m_services_ok", "fleet_bounded_memory"):
            assert m[flag] == {"value": 1.0, "kind": "flag"}
        # jax-vs-vec parity is gated at the documented 1e-9 tolerance
        parity = m["fleet_jax_vs_vec_fid_diff"]
        assert parity["kind"] == "lower_is_better"
        assert parity["tolerance"] == 0.0
        assert parity["abs_tol"] == 1e-9

    def test_planner_speed_flags_are_gated(self):
        """ISSUE 5 acceptance: the bench gate must pin the >=5x
        vec-speedup claim and the bit-identical-plans flag at 1."""
        baseline = json.loads(
            (ROOT / "benchmarks" / "baseline.json").read_text())
        m = baseline["metrics"]
        assert m["planner_vec_speedup_5x"] == \
            {"value": 1.0, "kind": "flag"}
        assert m["planner_vec_equivalent"] == \
            {"value": 1.0, "kind": "flag"}

    def test_churn_dominance_flag_is_gated(self):
        """ISSUE 4 acceptance: the bench gate must pin the offset-vs-
        shared dominance claim and the handoff sanity flag at 1."""
        baseline = json.loads(
            (ROOT / "benchmarks" / "baseline.json").read_text())
        m = baseline["metrics"]
        assert m["offset_beats_shared_under_churn"] == \
            {"value": 1.0, "kind": "flag"}
        assert m["churn_handoff_sane"] == {"value": 1.0, "kind": "flag"}


class TestToleranceOverride:
    """Per-row ``tolerance`` key: overrides the 5% default (and any
    ``rel_tol``), survives ``--update``."""

    def test_tolerance_overrides_default(self):
        base = {"metrics": {"online_x": {
            "value": 10.0, "kind": "lower_is_better",
            "tolerance": 0.5}}}
        # 40% worse: fails the 5% default, passes the 50% override
        assert compare.compare(base, {"online_x": 14.0}) == []

    def test_zero_tolerance_is_tight(self):
        base = {"metrics": {"online_x": {
            "value": 10.0, "kind": "lower_is_better",
            "tolerance": 0.0}}}
        assert compare.compare(base, {"online_x": 10.2})
        assert compare.compare(base, {"online_x": 10.0}) == []

    def test_tolerance_wins_over_rel_tol(self):
        base = {"metrics": {"online_x": {
            "value": 10.0, "kind": "lower_is_better",
            "rel_tol": 0.5, "tolerance": 0.01}}}
        assert compare.compare(base, {"online_x": 10.5})

    def test_gate_limit_default(self):
        rel, abs_tol, limit = compare.gate_limit(
            {"value": 10.0, "kind": "lower_is_better"})
        assert rel == compare.DEFAULT_REL_TOL
        assert limit == pytest.approx(10.5, abs=1e-6)

    def test_update_roundtrips_tolerance(self, tmp_path):
        base = {"metrics": {
            "online_r0.5_stacking": {"value": 6.0,
                                     "kind": "lower_is_better",
                                     "tolerance": 0.01,
                                     "abs_tol": 1e-6},
            "online_stacking_best": {"value": 1.0, "kind": "flag"},
        }}
        base_path = tmp_path / "baseline.json"
        base_path.write_text(json.dumps(base))
        p = _bench_file(tmp_path, "online",
                        [("online_r0.5_stacking", 5.5, ""),
                         ("online_stacking_best", 1.0, "")])
        assert compare.main([str(p), "--baseline", str(base_path),
                             "--update"]) == 0
        refreshed = json.loads(base_path.read_text())
        m = refreshed["metrics"]["online_r0.5_stacking"]
        assert m == {"value": 5.5, "kind": "lower_is_better",
                     "tolerance": 0.01, "abs_tol": 1e-6}


class TestGithubSummary:
    """--github-summary markdown rendering + the $GITHUB_STEP_SUMMARY
    append path."""

    BASE = {"metrics": {
        "online_r0.5_stacking": {"value": 6.0, "kind": "lower_is_better",
                                 "tolerance": 0.1},
        "online_stacking_best": {"value": 1.0, "kind": "flag"},
        "churn_handoff_sane": {"value": 1.0, "kind": "flag"},
    }}

    def test_all_pass_renders_green(self):
        md = compare.github_summary(
            self.BASE, {"online_r0.5_stacking": 6.2,
                        "online_stacking_best": 1.0,
                        "churn_handoff_sane": 1.0}, [])
        assert "**PASSED**" in md
        assert "❌" not in md
        assert md.count("✅") == 3
        # one table row per gated metric, with its gate limit
        assert "| `online_r0.5_stacking` | lower_is_better | 6.0000 " \
            "| 6.2000 | <= 6.6000 | ✅ |" in md

    def test_failures_render_red(self):
        md = compare.github_summary(
            self.BASE, {"online_r0.5_stacking": 9.0,
                        "online_stacking_best": 0.0}, [])
        assert "**FAILED**" in md
        # regressed metric, dropped flag, missing flag
        assert md.count("❌") == 3
        assert "_missing_" in md

    def test_suite_findings_listed(self):
        md = compare.github_summary(
            self.BASE, {"online_r0.5_stacking": 6.0,
                        "online_stacking_best": 1.0,
                        "churn_handoff_sane": 1.0},
            ["required suite 'fleet' has no BENCH_*.json among the "
             "measured files"])
        assert "**FAILED**" in md
        assert "⚠️" in md and "'fleet'" in md

    def test_main_appends_to_step_summary(self, tmp_path, monkeypatch):
        base_path = tmp_path / "baseline.json"
        base_path.write_text(json.dumps(BASELINE))
        p = _bench_file(tmp_path, "online",
                        [("online_r0.5_stacking", 6.0, ""),
                         ("online_stacking_best", 1.0, "")])
        summary = tmp_path / "summary.md"
        summary.write_text("prior content\n")
        monkeypatch.setenv("GITHUB_STEP_SUMMARY", str(summary))
        assert compare.main([str(p), "--baseline", str(base_path),
                             "--github-summary"]) == 0
        text = summary.read_text()
        assert text.startswith("prior content\n")   # appended, not clobbered
        assert "### Benchmark regression gate" in text
        assert "**PASSED**" in text

    def test_main_without_env_prints(self, tmp_path, monkeypatch,
                                     capsys):
        base_path = tmp_path / "baseline.json"
        base_path.write_text(json.dumps(BASELINE))
        p = _bench_file(tmp_path, "online",
                        [("online_r0.5_stacking", 6.0, ""),
                         ("online_stacking_best", 1.0, "")])
        monkeypatch.delenv("GITHUB_STEP_SUMMARY", raising=False)
        assert compare.main([str(p), "--baseline", str(base_path),
                             "--github-summary"]) == 0
        assert "### Benchmark regression gate" in capsys.readouterr().out


class TestRequiredSuites:
    """A suite dropped from the CI bench invocation must fail the gate
    even if its gated metrics were pruned from the baseline."""

    BASE = {"metrics": dict(BASELINE["metrics"]),
            "required_suites": ["online", "churn"]}

    def test_all_suites_present_passes(self, tmp_path):
        p1 = _bench_file(tmp_path, "online",
                         [("online_r0.5_stacking", 6.0, ""),
                          ("online_stacking_best", 1.0, "")])
        p2 = _bench_file(tmp_path, "churn", [("x", 1.0, "")])
        assert compare.check_suites(
            self.BASE, compare.load_suites([p1, p2])) == []

    def test_missing_suite_fails(self, tmp_path):
        p1 = _bench_file(tmp_path, "online",
                         [("online_r0.5_stacking", 6.0, ""),
                          ("online_stacking_best", 1.0, "")])
        findings = compare.check_suites(self.BASE,
                                        compare.load_suites([p1]))
        assert len(findings) == 1
        assert "churn" in findings[0]

    def test_main_fails_on_missing_suite(self, tmp_path):
        base_path = tmp_path / "baseline.json"
        base_path.write_text(json.dumps(self.BASE))
        p1 = _bench_file(tmp_path, "online",
                         [("online_r0.5_stacking", 6.0, ""),
                          ("online_stacking_best", 1.0, "")])
        assert compare.main([str(p1),
                             "--baseline", str(base_path)]) == 1

    def test_update_preserves_required_suites(self, tmp_path):
        base_path = tmp_path / "baseline.json"
        base_path.write_text(json.dumps(self.BASE))
        p1 = _bench_file(tmp_path, "online",
                         [("online_r0.5_stacking", 4.0, ""),
                          ("online_stacking_best", 1.0, "")])
        p2 = _bench_file(tmp_path, "churn", [("x", 1.0, "")])
        assert compare.main([str(p1), str(p2),
                             "--baseline", str(base_path),
                             "--update"]) == 0
        refreshed = json.loads(base_path.read_text())
        assert refreshed["required_suites"] == ["online", "churn"]

    def test_update_refuses_partial_measurement(self, tmp_path):
        """A refresh from files missing a required suite must fail
        instead of silently keeping that suite's stale values."""
        base_path = tmp_path / "baseline.json"
        base_path.write_text(json.dumps(self.BASE))
        p1 = _bench_file(tmp_path, "online",
                         [("online_r0.5_stacking", 4.0, ""),
                          ("online_stacking_best", 1.0, "")])
        assert compare.main([str(p1), "--baseline", str(base_path),
                             "--update"]) == 1
        unchanged = json.loads(base_path.read_text())
        assert unchanged["metrics"]["online_r0.5_stacking"]["value"] \
            == 6.0

    def test_update_refuses_crashed_suite(self, tmp_path):
        """A suite that crashed still writes its BENCH json (with only
        an <suite>_ERROR row), so the suite-name check passes — the
        refresh must still refuse because gated metrics are missing."""
        base_path = tmp_path / "baseline.json"
        base_path.write_text(json.dumps(self.BASE))
        p1 = _bench_file(tmp_path, "online",
                         [("online_ERROR", 0.0, "RuntimeError('x')")])
        p2 = _bench_file(tmp_path, "churn", [("x", 1.0, "")])
        assert compare.main([str(p1), str(p2),
                             "--baseline", str(base_path),
                             "--update"]) == 1
        unchanged = json.loads(base_path.read_text())
        assert unchanged["metrics"]["online_r0.5_stacking"]["value"] \
            == 6.0


class TestJsonWriter:
    def test_write_json_roundtrip(self, tmp_path):
        path = bench_run.write_json(
            tmp_path / "out", "demo",
            [("a", 1.0, "x"), ("b", 2.5, "y")], 1.234, "cafebabe")
        assert path.name == "BENCH_demo.json"
        payload = json.loads(path.read_text())
        assert payload["suite"] == "demo"
        assert payload["git_sha"] == "cafebabe"
        assert payload["elapsed_s"] == 1.234
        assert payload["rows"][1] == {"name": "b", "value": 2.5,
                                      "derived": "y"}
        # the active engines are stamped next to workers/devices so
        # nightly refreshes can tell configuration trends apart
        assert payload["engine"] in ("vec", "scalar", "jax")
        assert payload["exec_engine"] in ("dict", "bucketed")

    def test_write_json_exec_engine_env(self, tmp_path, monkeypatch):
        monkeypatch.setenv("REPRO_EXEC_ENGINE", "bucketed")
        path = bench_run.write_json(tmp_path / "out", "demo",
                                    [], 0.1, "cafebabe")
        assert json.loads(path.read_text())["exec_engine"] == "bucketed"

    def test_git_sha_is_nonempty(self):
        assert bench_run.git_sha()


class TestBenchShim:
    """The benchmarks/__init__.py src-path shim (ISSUE 3 satellite):
    idempotent, and sufficient for a clean subprocess with no
    PYTHONPATH."""

    @pytest.fixture
    def clean_env(self):
        env = {k: v for k, v in os.environ.items()
               if k not in ("PYTHONPATH",)}
        env["JAX_PLATFORMS"] = "cpu"
        return env

    def test_run_list_from_clean_subprocess(self, clean_env):
        proc = subprocess.run(
            [sys.executable, "-m", "benchmarks.run", "--list"],
            cwd=ROOT, env=clean_env, capture_output=True, text=True,
            timeout=120)
        assert proc.returncode == 0, proc.stderr[-2000:]
        suites = proc.stdout.split()
        assert "multiserver" in suites
        assert "online" in suites
        assert "api" in suites
        assert "churn" in suites

    def test_shim_is_idempotent(self, clean_env):
        src = ("import importlib, sys, benchmarks;"
               "importlib.reload(benchmarks);"
               "import benchmarks as b2;"
               "src = [p for p in sys.path if p.rstrip('/').endswith('src')];"
               "assert len(src) <= 1, sys.path;"
               "import repro;"
               "print('ok')")
        proc = subprocess.run(
            [sys.executable, "-c", src], cwd=ROOT, env=clean_env,
            capture_output=True, text=True, timeout=120)
        assert proc.returncode == 0, proc.stderr[-2000:]
        assert proc.stdout.strip() == "ok"
