"""Per-kernel validation: shape/dtype sweeps, Pallas (interpret=True)
vs. the pure-jnp ref oracle."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.kernels.decode_attention.kernel import decode_attention_pallas
from repro.kernels.decode_attention.ref import decode_attention_ref
from repro.kernels.flash_attention.kernel import flash_attention_pallas
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.groupnorm_silu.kernel import groupnorm_silu_pallas
from repro.kernels.groupnorm_silu.ref import groupnorm_silu_ref
from repro.kernels.rmsnorm.kernel import rmsnorm_pallas
from repro.kernels.rmsnorm.ref import rmsnorm_ref
from repro.kernels.ssd_scan.kernel import ssd_scan_pallas
from repro.kernels.ssd_scan.ref import ssd_scan_ref
from repro.models.layers import chunked_attention, decode_attention
from repro.models.ssm import ssd_chunked


def _tol(dtype):
    return dict(atol=2e-2, rtol=2e-2) if dtype == jnp.bfloat16 \
        else dict(atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("shape", [(4, 64), (3, 7, 96), (2, 5, 3, 128),
                                   (1, 256)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rmsnorm_sweep(shape, dtype):
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, shape, dtype)
    s = jax.random.normal(jax.random.PRNGKey(1), shape[-1:], dtype)
    got = rmsnorm_pallas(x, s, interpret=True, block_rows=4)
    want = rmsnorm_ref(x, s)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))


@pytest.mark.parametrize("B,Sq,Skv,H,KV,D", [
    (1, 64, 64, 2, 2, 32),       # MHA
    (2, 64, 64, 4, 2, 64),       # GQA
    (1, 32, 128, 4, 1, 64),      # MQA, longer kv (prefill continuation)
    (1, 128, 128, 2, 2, 128),
])
@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_sweep(B, Sq, Skv, H, KV, D, causal, dtype):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, Sq, H, D), dtype)
    k = jax.random.normal(ks[1], (B, Skv, KV, D), dtype)
    v = jax.random.normal(ks[2], (B, Skv, KV, D), dtype)
    got = flash_attention_pallas(q, k, v, causal=causal, bq=32, bk=32,
                                 interpret=True)
    want = attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))


@pytest.mark.parametrize("window", [16, 48])
def test_flash_attention_window(window):
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (2, 128, 4, 32))
    k = jax.random.normal(ks[1], (2, 128, 2, 32))
    v = jax.random.normal(ks[2], (2, 128, 2, 32))
    got = flash_attention_pallas(q, k, v, causal=True, window=window,
                                 bq=32, bk=32, interpret=True)
    want = attention_ref(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-5, rtol=2e-5)


def test_chunked_attention_matches_ref_nondivisible_kv():
    """XLA-path attention with kv padding (vision cross-attn: 1601 toks)."""
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    q = jax.random.normal(ks[0], (2, 40, 4, 32))
    k = jax.random.normal(ks[1], (2, 101, 2, 32))
    v = jax.random.normal(ks[2], (2, 101, 2, 32))
    got = chunked_attention(q, k, v, causal=False, q_chunk=16, kv_chunk=32)
    want = attention_ref(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("B,S,H,KV,D,bs", [
    (2, 256, 4, 2, 64, 64),
    (1, 128, 8, 8, 32, 32),      # MHA
    (3, 512, 4, 1, 128, 128),    # MQA
])
@pytest.mark.parametrize("window", [0, 64])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_decode_attention_sweep(B, S, H, KV, D, bs, window, dtype):
    ks = jax.random.split(jax.random.PRNGKey(3), 3)
    q = jax.random.normal(ks[0], (B, 1, H, D), dtype)
    kc = jax.random.normal(ks[1], (B, S, KV, D), dtype)
    vc = jax.random.normal(ks[2], (B, S, KV, D), dtype)
    cur = jnp.asarray(np.random.default_rng(0).integers(1, S + 1, B),
                      jnp.int32)
    got = decode_attention_pallas(q, kc, vc, cur, window=window, bs=bs,
                                  interpret=True)
    want = decode_attention_ref(q, kc, vc, cur, window=window)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))
    # the jnp model path agrees too
    model = decode_attention(q.astype(jnp.float32),
                             kc.astype(jnp.float32),
                             vc.astype(jnp.float32), cur, window=window)
    np.testing.assert_allclose(np.asarray(model),
                               np.asarray(want, np.float32),
                               atol=2e-2, rtol=2e-2)


@pytest.mark.parametrize("B,S,H,P,N,chunk", [
    (2, 64, 3, 16, 8, 16),
    (1, 128, 2, 32, 16, 32),
    (2, 32, 1, 8, 8, 32),       # single chunk
])
def test_ssd_scan_sweep(B, S, H, P, N, chunk):
    ks = jax.random.split(jax.random.PRNGKey(4), 5)
    x = jax.random.normal(ks[0], (B, S, H, P))
    a = -jnp.abs(jax.random.normal(ks[1], (B, S, H))) * 0.2
    bm = jax.random.normal(ks[2], (B, S, N)) * 0.3
    cm = jax.random.normal(ks[3], (B, S, N)) * 0.3
    h0 = jax.random.normal(ks[4], (B, H, P, N)) * 0.1
    yr, hr = ssd_scan_ref(x, a, bm, cm, h0)
    yp, hp = ssd_scan_pallas(x, a, bm, cm, h0, chunk=chunk, interpret=True)
    np.testing.assert_allclose(np.asarray(yp), np.asarray(yr),
                               atol=3e-5, rtol=3e-5)
    np.testing.assert_allclose(np.asarray(hp), np.asarray(hr),
                               atol=3e-5, rtol=3e-5)
    yc, hc = ssd_chunked(x, a, bm, cm, h0, chunk=chunk)
    np.testing.assert_allclose(np.asarray(yc), np.asarray(yr),
                               atol=3e-5, rtol=3e-5)
    np.testing.assert_allclose(np.asarray(hc), np.asarray(hr),
                               atol=3e-5, rtol=3e-5)


def test_ssd_chunked_per_head_bc():
    """mLSTM uses per-head B/C (ndim-4 path of ssd_chunked)."""
    ks = jax.random.split(jax.random.PRNGKey(5), 5)
    B, S, H, P, N = 2, 48, 2, 8, 8
    x = jax.random.normal(ks[0], (B, S, H, P))
    a = -jnp.abs(jax.random.normal(ks[1], (B, S, H))) * 0.2
    bm = jax.random.normal(ks[2], (B, S, H, N)) * 0.3
    cm = jax.random.normal(ks[3], (B, S, H, N)) * 0.3
    h0 = jnp.zeros((B, H, P, N))
    # oracle: run ref per head with shared-BC shapes
    ys = []
    hs = []
    for h in range(H):
        yr, hr = ssd_scan_ref(x[:, :, h:h + 1], a[:, :, h:h + 1],
                              bm[:, :, h], cm[:, :, h], h0[:, h:h + 1])
        ys.append(yr)
        hs.append(hr)
    want_y = jnp.concatenate(ys, axis=2)
    want_h = jnp.concatenate(hs, axis=1)
    got_y, got_h = ssd_chunked(x, a, bm, cm, h0, chunk=16)
    np.testing.assert_allclose(np.asarray(got_y), np.asarray(want_y),
                               atol=3e-5, rtol=3e-5)
    np.testing.assert_allclose(np.asarray(got_h), np.asarray(want_h),
                               atol=3e-5, rtol=3e-5)


@pytest.mark.parametrize("B,H,W,C,G", [
    (2, 8, 8, 32, 8), (1, 16, 16, 24, 6), (3, 4, 4, 16, 16),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_groupnorm_silu_sweep(B, H, W, C, G, dtype):
    ks = jax.random.split(jax.random.PRNGKey(6), 3)
    x = jax.random.normal(ks[0], (B, H, W, C), dtype)
    s = jax.random.normal(ks[1], (C,), jnp.float32)
    b = jax.random.normal(ks[2], (C,), jnp.float32)
    got = groupnorm_silu_pallas(x, s, b, G, interpret=True)
    want = groupnorm_silu_ref(x, s, b, G)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))
