"""Hypothesis property tests for the offset-native scheduler (ISSUE 4
satellite).

The anchor property: with all-zero offsets ``stacking_offset`` returns
a plan with *identical* mean FID to ``stacking`` on arbitrary random
scenarios (it must be Algorithm 1 exactly — the delegation is an
implementation detail, the property is the contract).  Plus: plans
with arbitrary offsets still satisfy the paper's constraints and never
score worse than the shared-horizon plan under the progress-aware
objective.  Skipped (not a collection error) when ``hypothesis`` is
not installed; ``pip install -r requirements-dev.txt`` brings it in.
"""

import numpy as np
import pytest

pytest.importorskip("hypothesis")

from hypothesis import given, settings, strategies as st

from repro.core.delay_model import DelayModel
from repro.core.offset import stacking_offset
from repro.core.quality_model import PowerLawFID
from repro.core.service import ServiceRequest
from repro.core.stacking import stacking

DELAY = DelayModel()          # paper constants
QUALITY = PowerLawFID()


def _services(taus):
    return [ServiceRequest(id=i, deadline=t, spectral_eff=7.0)
            for i, t in enumerate(taus)]


def _tau_prime(taus):
    return {i: t for i, t in enumerate(taus)}


def _offset_score(plan, taus, offsets):
    doomed = {i for i, (t, o) in enumerate(zip(taus, offsets))
              if o > 0 and t < 0}
    return float(np.mean([
        QUALITY.fid(0) if i in doomed
        else QUALITY.fid(offsets[i] + plan.steps_completed.get(i, 0))
        for i in range(len(taus))]))


taus_strategy = st.lists(
    st.floats(min_value=0.05, max_value=30.0, allow_nan=False,
              allow_infinity=False),
    min_size=1, max_size=12)


@settings(max_examples=40, deadline=None)
@given(taus=taus_strategy)
def test_zero_offsets_identical_mean_fid_to_stacking(taus):
    """The tentpole equivalence invariant, property-tested."""
    svcs = _services(taus)
    tp = _tau_prime(taus)
    a = stacking(svcs, tp, DELAY, QUALITY)
    b = stacking_offset.plan(svcs, tp, DELAY, QUALITY,
                             [0] * len(taus))
    qa = QUALITY.mean_fid([a.steps_completed[i]
                           for i in range(len(taus))])
    qb = QUALITY.mean_fid([b.steps_completed[i]
                           for i in range(len(taus))])
    assert qa == qb
    b.validate(gen_deadlines=tp)


@settings(max_examples=30, deadline=None)
@given(taus=taus_strategy,
       data=st.data())
def test_offset_plans_satisfy_constraints(taus, data):
    """(1),(2),(6),(7),(14) hold for arbitrary offsets too."""
    offsets = data.draw(st.lists(st.integers(0, 25),
                                 min_size=len(taus),
                                 max_size=len(taus)))
    svcs = _services(taus)
    tp = _tau_prime(taus)
    plan = stacking_offset.plan(svcs, tp, DELAY, QUALITY, offsets)
    plan.validate(gen_deadlines=tp)


@settings(max_examples=30, deadline=None)
@given(taus=taus_strategy, data=st.data())
def test_never_scores_worse_than_shared_horizon(taus, data):
    """stacking_offset's candidate set contains Algorithm 1's, scored
    under the same progress-aware objective — so it can't lose."""
    offsets = data.draw(st.lists(st.integers(0, 25),
                                 min_size=len(taus),
                                 max_size=len(taus)))
    svcs = _services(taus)
    tp = _tau_prime(taus)
    native = stacking_offset.plan(svcs, tp, DELAY, QUALITY, offsets)
    shared = stacking(svcs, tp, DELAY, QUALITY)
    assert _offset_score(native, taus, offsets) <= \
        _offset_score(shared, taus, offsets) + 1e-9
