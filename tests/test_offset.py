"""Offset-native replanning (ISSUE 4 tentpole): the OffsetScheduler
protocol, stacking_offset's equivalence invariants (zero offsets ==
static stacking; all-arrivals-at-t=0 == static simulate; n_servers=1 ==
single-server online, handoff included), offset-native dispatch in the
online replanner, and the cross-cell handoff pass."""

import numpy as np
import pytest

from repro.api import (OffsetScheduler, OnlineProvisioner, Provisioner,
                       SCHEDULERS, get_allocator, get_scheduler)
from repro.core.delay_model import DelayModel
from repro.core.multiserver import (MultiOnlineSimulation,
                                    simulate_online_multi)
from repro.core.offset import (StackingOffset, offset_pass,
                               offset_stacking_pass, stacking_offset)
from repro.core.online import simulate_online
from repro.core.quality_model import PowerLawFID
from repro.core.service import (EdgeServer, Scenario, ServiceRequest,
                                make_scenario)
from repro.core.stacking import stacking, stacking_pass

DELAY = DelayModel()
QUALITY = PowerLawFID()


def _score(plan, ids, off, tau_prime, quality=QUALITY):
    """The progress-aware replan objective (mirrors _OffsetQuality
    including the doomed rule)."""
    doomed = {k for k in ids if off[k] > 0 and tau_prime[k] < 0}
    return float(np.mean([
        quality.fid(0) if k in doomed
        else quality.fid(off[k] + plan.steps_completed.get(k, 0))
        for k in ids]))


class TestProtocolAndRegistry:
    def test_registered_with_alias(self):
        assert "stacking_offset" in SCHEDULERS
        assert "offset" in SCHEDULERS
        assert get_scheduler("stacking_offset") is stacking_offset
        assert get_scheduler("offset") is stacking_offset

    def test_satisfies_both_protocols(self):
        from repro.api import Scheduler
        assert isinstance(stacking_offset, Scheduler)
        assert isinstance(stacking_offset, OffsetScheduler)

    def test_plain_schedulers_are_not_offset_schedulers(self):
        assert not isinstance(get_scheduler("stacking"), OffsetScheduler)
        assert not isinstance(get_scheduler("greedy"), OffsetScheduler)


class TestZeroOffsetEquivalence:
    """Invariant 1: with zero offsets everywhere, stacking_offset IS
    Algorithm 1 (it delegates), so plans are bit-identical."""

    @pytest.mark.parametrize("seed", [0, 1, 7])
    def test_call_equals_stacking_plan(self, seed):
        scn = make_scenario(K=10, seed=seed)
        tp = {s.id: s.deadline * 0.6 for s in scn.services}
        a = stacking(scn.services, tp, DELAY, QUALITY)
        b = stacking_offset(scn.services, tp, DELAY, QUALITY)
        assert a.batches == b.batches
        assert a.start_times == b.start_times
        assert a.steps_completed == b.steps_completed

    def test_explicit_zero_offsets_delegate_too(self):
        scn = make_scenario(K=8, seed=3)
        tp = {s.id: s.deadline * 0.5 for s in scn.services}
        a = stacking(scn.services, tp, DELAY, QUALITY)
        b = stacking_offset.plan(scn.services, tp, DELAY, QUALITY,
                                 [0] * scn.K)
        assert a.steps_completed == b.steps_completed

    @pytest.mark.parametrize("allocator", ["inv_se", "equal"])
    def test_static_provisioner_identical(self, allocator):
        scn = make_scenario(K=8, seed=5)
        st = Provisioner(scn, scheduler="stacking",
                         allocator=allocator).run()
        of = Provisioner(scn, scheduler="stacking_offset",
                         allocator=allocator).run()
        assert of.sim.outcomes == st.sim.outcomes

    @pytest.mark.parametrize("t_star", [1, 3, 10])
    def test_offset_stacking_pass_degenerates(self, t_star):
        ids = list(range(6))
        tp = {k: 2.0 + 0.8 * k for k in ids}
        zero = {k: 0 for k in ids}
        a = stacking_pass(ids, tp, DELAY, t_star)
        b = offset_stacking_pass(ids, tp, DELAY, t_star, zero)
        assert a.batches == b.batches
        assert a.steps_completed == b.steps_completed


class TestStaticOnlineEquivalence:
    """Invariant 2: all arrivals at t=0 reproduce static simulate."""

    @pytest.mark.parametrize("seed", [0, 4])
    def test_online_equals_static(self, seed):
        scn = make_scenario(K=8, seed=seed)
        assert scn.is_static
        static = Provisioner(scn, scheduler="stacking_offset",
                             allocator="inv_se").run()
        online = OnlineProvisioner(scn, scheduler="stacking_offset",
                                   allocator="inv_se").run()
        assert online.result.outcomes == static.sim.outcomes
        assert online.mean_fid == static.mean_fid


class TestSingleServerEquivalence:
    """Invariant 3: n_servers=1 reproduces the single-server online
    path bit-for-bit — with the offset scheduler and with handoff
    enabled (no other cell exists to probe)."""

    @pytest.mark.parametrize("handoff", [False, True])
    def test_one_cell_multi_equals_single(self, handoff):
        scn = make_scenario(K=10, arrival_rate=1.0, seed=2)
        single = simulate_online(scn, get_scheduler("stacking_offset"),
                                 get_allocator("inv_se"), DELAY, QUALITY)
        multi = simulate_online_multi(
            scn, get_scheduler("stacking_offset"),
            get_allocator("inv_se"), DELAY, QUALITY, handoff=handoff)
        assert multi.result.outcomes == single.outcomes
        assert multi.handoffs == 0


class TestOffsetNativeDispatch:
    def test_replans_call_plan_with_real_offsets(self):
        calls = []

        class Spy(StackingOffset):
            def plan(self, services, tau_prime, delay, quality,
                     offsets):
                calls.append(list(offsets))
                return super().plan(services, tau_prime, delay,
                                    quality, offsets)

        scn = make_scenario(K=8, tau_min=3.0, tau_max=8.0,
                            arrival_rate=1.0, seed=1)
        res = simulate_online(scn, Spy(), get_allocator("inv_se"),
                              DELAY, QUALITY)
        assert len(res.outcomes) == scn.K
        # at least one replan saw executed steps and dispatched natively
        assert any(any(c) for c in calls)

    def test_unrelated_plan_helper_is_not_dispatched(self):
        """Dispatch needs the supports_offsets marker: a scheduler with
        an unrelated `plan` helper must stay on the wrapper path."""

        class WithHelper:
            def __call__(self, services, tau_prime, delay, quality):
                return stacking(services, tau_prime, delay, quality)

            def plan(self, *args):         # wrong-protocol helper
                raise AssertionError("must never be dispatched")

        scn = make_scenario(K=8, tau_min=3.0, tau_max=8.0,
                            arrival_rate=1.0, seed=1)
        ref = simulate_online(scn, get_scheduler("stacking"),
                              get_allocator("inv_se"), DELAY, QUALITY)
        got = simulate_online(scn, WithHelper(),
                              get_allocator("inv_se"), DELAY, QUALITY)
        assert got.outcomes == ref.outcomes

    def test_supports_offsets_marker_set(self):
        assert stacking_offset.supports_offsets is True

    def test_offset_plans_validate_and_respect_budgets(self):
        scn = make_scenario(K=8, seed=6)
        tp = {s.id: s.deadline * 0.4 for s in scn.services}
        offsets = [3, 0, 7, 1, 0, 12, 2, 5]
        plan = stacking_offset.plan(scn.services, tp, DELAY, QUALITY,
                                    offsets)
        plan.validate(gen_deadlines=tp)

    @pytest.mark.parametrize("seed", [0, 1, 2])
    def test_never_scores_worse_than_shared_horizon(self, seed):
        """The chosen plan's progress-aware objective is never worse
        than what the _OffsetQuality-wrapped stacking would pick (its
        candidates are a subset of stacking_offset's)."""
        rng = np.random.default_rng(seed)
        scn = make_scenario(K=8, seed=seed)
        tp = {s.id: float(s.deadline * rng.uniform(0.2, 0.7))
              for s in scn.services}
        offsets = [int(o) for o in rng.integers(0, 14, size=scn.K)]
        if not any(offsets):
            offsets[0] = 5
        ids = [s.id for s in scn.services]
        off = dict(zip(ids, offsets))
        native = stacking_offset.plan(scn.services, tp, DELAY, QUALITY,
                                      offsets)
        shared = stacking(scn.services, tp, DELAY, QUALITY)
        assert _score(native, ids, off, tp) <= \
            _score(shared, ids, off, tp) + 1e-9

    def test_water_level_retires_nearly_done_services(self):
        """A service far past the water level gets zero new steps in
        the level's target vector while young services still denoise."""
        ids = [0, 1]
        tp = {0: 2.0, 1: 2.0}
        plan = offset_pass(ids, tp, DELAY, targets={0: 0, 1: 4})
        assert plan.steps_completed[0] == 0
        assert plan.steps_completed[1] > 0
        plan.validate(gen_deadlines=tp)


class TestHandoff:
    def _two_cell_scn(self):
        # two identical cells; all arrivals forced onto cell 0 by the
        # placement below, so the handoff pass has obvious work to do
        svcs = [ServiceRequest(id=0, deadline=6.0, spectral_eff=7.0),
                ServiceRequest(id=1, deadline=6.0, spectral_eff=7.0,
                               arrival=0.5),
                ServiceRequest(id=2, deadline=6.0, spectral_eff=7.0,
                               arrival=0.6)]
        servers = [EdgeServer(id=0, bandwidth_hz=20_000.0),
                   EdgeServer(id=1, bandwidth_hz=20_000.0)]
        return Scenario(services=svcs, total_bandwidth_hz=40_000.0,
                        servers=servers)

    def test_handoff_moves_pending_service_to_idle_cell(self):
        scn = self._two_cell_scn()
        pin0 = lambda svc, sim: 0     # noqa: E731
        sim = MultiOnlineSimulation(
            scn, get_scheduler("stacking_offset"),
            get_allocator("inv_se"), DELAY, QUALITY,
            admission=lambda *a: True, placement=pin0, handoff=True)
        res = sim.run()
        assert res.handoffs >= 1
        assert any(dst == 1 for _, _, _, dst in res.handoff_log)
        # migrated services execute on their new cell only
        seen = {}
        for m, tr in enumerate(sim.tracks):
            for _, k, _ in tr.executed_log:
                assert seen.setdefault(k, m) == m
        assert set(res.assignment.values()) == {0, 1}

    def test_handoff_never_hurts_here(self):
        scn = self._two_cell_scn()
        pin0 = lambda svc, sim: 0     # noqa: E731
        runs = {}
        for ho in (False, True):
            sim = MultiOnlineSimulation(
                scn, get_scheduler("stacking_offset"),
                get_allocator("inv_se"), DELAY, QUALITY,
                admission=lambda *a: True, placement=pin0, handoff=ho)
            runs[ho] = sim.run()
        assert runs[False].handoffs == 0
        assert runs[True].result.mean_fid <= \
            runs[False].result.mean_fid + 1e-9

    def test_handoff_log_entries_well_formed(self):
        scn = make_scenario(K=12, n_servers=3, arrival_rate=1.0,
                            tau_min=3.0, tau_max=8.0,
                            server_speed_range=(0.6, 1.4), seed=0)
        res = simulate_online_multi(
            scn, get_scheduler("stacking_offset"),
            get_allocator("inv_se"), DELAY, QUALITY, handoff=True)
        assert res.handoffs == len(res.handoff_log)
        for t, k, src, dst in res.handoff_log:
            assert src != dst
            assert res.assignment[k] is not None
        # only never-started services move, so the no-resurrection
        # invariant cannot be violated by a migration: every admitted
        # service's executed steps all live on its final cell
        admitted = {o.id for o in res.outcomes}
        assert set(res.assignment) <= admitted

    def test_handoff_is_deterministic(self):
        scn = make_scenario(K=10, n_servers=3, arrival_rate=2.0,
                            tau_min=3.0, tau_max=8.0, seed=5)
        runs = [simulate_online_multi(
            scn, get_scheduler("stacking_offset"),
            get_allocator("inv_se"), DELAY, QUALITY, handoff=True)
            for _ in range(2)]
        assert runs[0].result.outcomes == runs[1].result.outcomes
        assert runs[0].handoff_log == runs[1].handoff_log

    def test_run_online_exposes_handoffs(self):
        from repro.api import MultiServerProvisioner
        scn = make_scenario(K=9, n_servers=3, arrival_rate=1.0,
                            tau_min=3.0, tau_max=8.0, seed=1)
        prov = MultiServerProvisioner(scn, scheduler="stacking_offset",
                                      allocator="inv_se")
        off = prov.run_online()
        on = prov.run_online(handoff=True)
        assert off.handoffs == 0
        assert on.handoffs >= 0
        assert "handoffs=" in on.summary()
