"""Fig. 1b: content quality vs. denoising steps.

True FID needs CIFAR-10 + a trained model (not available offline); the
paper's own point is that *any* accurate monotone fit works.  We (1) report
the power-law fit against the DDIM paper's published CIFAR-10 FIDs — the
same data source the paper measures — and (2) measure a quality *proxy* on
this container (distance of a T-step sample to a converged 64-step sample,
same seed/same untrained U-Net) and verify it follows the same power-law
shape."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.ddim_cifar10 import SMOKE
from repro.core.quality_model import PowerLawFID, fit_power_law
from repro.diffusion import ddim, unet
from repro.models.params import init_params

DDIM_TABLE = {10: 13.36, 20: 6.84, 50: 4.67, 100: 4.16}


def run(csv_rows):
    q = PowerLawFID()
    for t, fid in DDIM_TABLE.items():
        csv_rows.append((f"fig1b_fid_T{t}", q.fid(t),
                         f"ddim_paper={fid}"))
    fitted = fit_power_law(list(DDIM_TABLE), list(DDIM_TABLE.values()))
    csv_rows.append(("fig1b_fit_alpha", fitted.alpha, ""))
    csv_rows.append(("fig1b_fit_beta", fitted.beta, ""))
    csv_rows.append(("fig1b_fit_gamma", fitted.gamma, ""))

    # measured proxy on this container
    params = init_params(unet.schema(SMOKE), jax.random.PRNGKey(0))
    eps = jax.jit(lambda x, t: unet.forward(SMOKE, params, x, t))
    key = jax.random.PRNGKey(3)
    shape = (4, SMOKE.image_size, SMOKE.image_size, 3)
    ref = ddim.sample(eps, key, shape, 64)
    ts, dists = [], []
    for T in (1, 2, 4, 8, 16, 32):
        xT = ddim.sample(eps, key, shape, T)
        d = float(jnp.sqrt(jnp.mean((xT - ref) ** 2)))
        ts.append(T)
        dists.append(d)
        csv_rows.append((f"fig1b_proxy_T{T}", d * 1e3, "rmse_x1000"))
    # proxy must be monotone decreasing with diminishing returns
    mono = all(a >= b - 1e-6 for a, b in zip(dists, dists[1:]))
    csv_rows.append(("fig1b_proxy_monotone", float(mono), "1=yes"))
    prox_fit = fit_power_law(ts[:-1], [d + 1e-6 for d in dists[:-1]],
                             fid_at_zero=10.0)
    pred = [prox_fit.fid(t) for t in ts]
    rel = float(np.mean([abs(p - d) / max(d, 1e-9)
                         for p, d in zip(pred, dists)]))
    csv_rows.append(("fig1b_proxy_powerlaw_relerr", rel * 100, "percent"))
