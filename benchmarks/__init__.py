"""Benchmark suites (one module per paper figure/table + beyond-paper).

``python -m benchmarks.run`` from the repo root must be able to import
``repro`` even though nothing is pip-installed; pytest gets this from
``pythonpath = src`` in pyproject.toml, so this shim covers the plain
interpreter the same way.  The shim is idempotent — re-imports (or an
``importlib.reload``) never stack duplicate ``sys.path`` entries — and
installed or PYTHONPATH=src environments are left untouched
(tests/test_bench_tools.py runs it from a clean subprocess).
"""

import sys
from pathlib import Path

_SRC = str(Path(__file__).resolve().parent.parent / "src")

try:
    import repro  # noqa: F401
except ModuleNotFoundError:
    if _SRC not in sys.path:
        sys.path.insert(0, _SRC)
