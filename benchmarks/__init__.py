"""Benchmark suites (one module per paper figure/table + beyond-paper).

``python -m benchmarks.run`` from the repo root must be able to import
``repro`` even though nothing is pip-installed; pytest gets this from
``pythonpath = src`` in pyproject.toml, so this shim covers the plain
interpreter the same way.  Installed or PYTHONPATH=src environments are
left untouched.
"""

import sys
from pathlib import Path

try:
    import repro  # noqa: F401
except ModuleNotFoundError:
    sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))
