"""Benchmark regression gate: BENCH_*.json vs the committed baseline.

    python -m benchmarks.compare bench-artifacts/BENCH_*.json \
        --baseline benchmarks/baseline.json

Exit code 0 when every gated metric holds, 1 with a findings report
otherwise — CI runs this right after ``benchmarks.run --json`` so a PR
that regresses the scheduler-vs-baseline numbers fails visibly.

``baseline.json`` maps metric name -> gate spec:

    {"metrics": {
       "online_r0.5_stacking":    {"value": 11.2, "kind":
                                   "lower_is_better", "rel_tol": 0.05},
       "online_stacking_best":    {"value": 1.0, "kind": "flag"},
       "multiserver_greedy_beats_rr": {"value": 1.0, "kind": "flag"}}}

  * ``lower_is_better`` — fail when measured >
    value * (1 + rel_tol) + abs_tol (FID-style metrics; improvements
    always pass).  A per-row ``tolerance`` key overrides the 5%
    default relative tolerance (and any ``rel_tol``) — use it to
    tighten deterministic rows or loosen noisy ones without touching
    the global default; ``--update`` round-trips it.
  * ``flag``            — fail when measured < value (ordering claims
    pinned at 1.0 must stay 1.0).

``--github-summary`` additionally appends the whole gate table as
markdown to ``$GITHUB_STEP_SUMMARY`` (stdout when the env var is
unset), so the PR checks page shows per-metric baseline/measured/limit
without digging through job logs.

A gated metric missing from the measured rows fails too — a suite that
silently stops emitting its numbers is itself a regression.  The
baseline may additionally list ``required_suites``: every named suite
must appear among the BENCH_*.json files given, so dropping a suite
from the CI invocation (which would also sidestep its gated metrics if
they were ever pruned from the baseline) fails loudly.
``--update`` rewrites the baseline's values from the measured rows
(gate specs and the required_suites list are kept), for refreshing
after an intentional change; it refuses when a required suite or any
gated metric is missing from the measurement (a crashed suite still
writes its BENCH json, but only with an ``<suite>_ERROR`` row), so a
partial run can never produce a "refreshed" baseline that silently
keeps stale values.
"""

import argparse
import json
import os
import sys
from pathlib import Path
from typing import Dict, List

DEFAULT_REL_TOL = 0.05
DEFAULT_ABS_TOL = 1e-9

# metric-name prefix -> the suite whose BENCH json should carry it,
# so a missing gated row names the suite to re-run instead of leaving
# the reader to reverse-engineer the naming convention
_SUITE_PREFIXES = (
    ("planner_", "planner_speed"),
    ("offset_", "churn"),
    ("churn_", "churn"),
    ("online_", "online"),
    ("multiserver_", "multiserver"),
    ("fleet_", "fleet"),
    ("e2e_", "e2e"),
    ("exec_", "e2e"),
    ("api_", "api"),
)


def suite_of(name: str) -> str:
    """Best-effort owning suite of a gated metric name."""
    for prefix, suite in _SUITE_PREFIXES:
        if name.startswith(prefix):
            return suite
    return "unknown"


def gate_limit(spec: dict):
    """(rel_tol, abs_tol, limit) of one ``lower_is_better`` gate spec.

    ``tolerance`` is the per-row relative-tolerance override (it wins
    over the older ``rel_tol`` spelling when both appear); without
    either the 5% default applies.  ``--update`` round-trips every
    spec key, so a tightened row stays tightened across refreshes.
    """
    rel = float(spec.get("tolerance",
                         spec.get("rel_tol", DEFAULT_REL_TOL)))
    abs_tol = float(spec.get("abs_tol", DEFAULT_ABS_TOL))
    return rel, abs_tol, float(spec["value"]) * (1.0 + rel) + abs_tol


def load_measured(paths) -> Dict[str, float]:
    """name -> value over every row of every BENCH_*.json given."""
    measured: Dict[str, float] = {}
    for p in paths:
        payload = json.loads(Path(p).read_text())
        for row in payload.get("rows", []):
            measured[row["name"]] = float(row["value"])
    return measured


def load_suites(paths) -> set:
    """The suite names covered by the given BENCH_*.json files."""
    return {json.loads(Path(p).read_text()).get("suite") for p in paths}


def check_suites(baseline: dict, suites: set) -> List[str]:
    """Findings for baseline-required suites absent from the measured
    files (empty = pass)."""
    return [f"required suite '{s}' has no BENCH_*.json among the "
            f"measured files"
            for s in baseline.get("required_suites", [])
            if s not in suites]


def compare(baseline: dict, measured: Dict[str, float]) -> List[str]:
    """Every violated gate as a human-readable finding (empty = pass)."""
    findings = []
    for name, spec in baseline.get("metrics", {}).items():
        want = float(spec["value"])
        kind = spec.get("kind", "lower_is_better")
        if name not in measured:
            what = "flag" if kind == "flag" else "metric"
            findings.append(f"{name}: missing {what} — not in any "
                            f"measured row (suite "
                            f"'{suite_of(name)}')")
            continue
        got = measured[name]
        if kind == "flag":
            if got < want:
                findings.append(f"{name}: flag dropped to {got:g} "
                                f"(baseline {want:g})")
        elif kind == "lower_is_better":
            rel, abs_tol, limit = gate_limit(spec)
            if got > limit:
                findings.append(
                    f"{name}: {got:.4f} > {limit:.4f} "
                    f"(baseline {want:.4f}, rel_tol {rel:.0%})")
        else:
            findings.append(f"{name}: unknown gate kind '{kind}'")
    return findings


def update_baseline(baseline: dict,
                    measured: Dict[str, float]) -> dict:
    """Refresh gate values from measured rows, keeping specs (and any
    required_suites list)."""
    out = {"metrics": {}}
    for name, spec in baseline.get("metrics", {}).items():
        new = dict(spec)
        if name in measured:
            new["value"] = measured[name]
        out["metrics"][name] = new
    if "required_suites" in baseline:
        out["required_suites"] = baseline["required_suites"]
    return out


def github_summary(baseline: dict, measured: Dict[str, float],
                   suite_findings: List[str]) -> str:
    """The gate outcome as a GitHub step-summary markdown table —
    one row per gated metric, findings (missing suites/rows) called
    out above it.  Pure rendering: the pass/fail decision is the same
    ``compare`` logic the exit code uses."""
    lines = []
    n_fail = 0
    for name, spec in baseline.get("metrics", {}).items():
        want = float(spec["value"])
        kind = spec.get("kind", "lower_is_better")
        if name not in measured:
            lines.append(f"| `{name}` | {kind} | {want:g} | _missing_ "
                         f"| — | ❌ |")
            n_fail += 1
            continue
        got = measured[name]
        if kind == "flag":
            ok, limit = got >= want, f">= {want:g}"
        else:
            _, _, lim = gate_limit(spec)
            ok, limit = got <= lim, f"<= {lim:.4f}"
        n_fail += not ok
        lines.append(f"| `{name}` | {kind} | {want:.4f} | {got:.4f} "
                     f"| {limit} | {'✅' if ok else '❌'} |")
    gated = len(baseline.get("metrics", {}))
    failed = n_fail + len(suite_findings)
    verdict = ("**PASSED** — all gates hold" if failed == 0 else
               f"**FAILED** — {failed} finding(s)")
    out = ["### Benchmark regression gate", "", verdict, ""]
    out += [f"- ⚠️ {f}" for f in suite_findings]
    if suite_findings:
        out.append("")
    out += [f"{gated} gated metric(s):", "",
            "| metric | kind | baseline | measured | gate | ok |",
            "|---|---|---:|---:|---|:---:|"]
    out += lines
    return "\n".join(out) + "\n"


def _emit_summary(text: str) -> None:
    """Append to ``$GITHUB_STEP_SUMMARY`` when CI provides it, else
    print (local runs still get the table)."""
    path = os.environ.get("GITHUB_STEP_SUMMARY")
    if path:
        with open(path, "a", encoding="utf-8") as fh:
            fh.write(text)
    else:
        print(text, end="")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("bench", nargs="+", help="BENCH_*.json files")
    ap.add_argument("--baseline", default="benchmarks/baseline.json")
    ap.add_argument("--update", action="store_true",
                    help="rewrite the baseline's values from the "
                         "measured rows instead of gating")
    ap.add_argument("--github-summary", action="store_true",
                    help="append the gate table (markdown) to "
                         "$GITHUB_STEP_SUMMARY (stdout when unset)")
    args = ap.parse_args(argv)

    baseline = json.loads(Path(args.baseline).read_text())
    measured = load_measured(args.bench)
    suite_findings = check_suites(baseline, load_suites(args.bench))

    if args.update:
        # a refresh from an incomplete measurement would silently keep
        # stale values — refuse instead.  Both holes matter: a suite's
        # BENCH json absent entirely, and a suite that crashed (run.py
        # still writes its json, but only with an <suite>_ERROR row,
        # so the gated metrics are missing from the measured rows)
        stale = [f"{n}: gated metric missing from measured rows"
                 for n in baseline.get("metrics", {})
                 if n not in measured]
        refusals = suite_findings + stale
        if refusals:
            print(f"baseline NOT refreshed "
                  f"({len(refusals)} findings):")
            for f in refusals:
                print(f"  - {f}")
            return 1
        refreshed = update_baseline(baseline, measured)
        Path(args.baseline).write_text(
            json.dumps(refreshed, indent=2) + "\n")
        print(f"baseline refreshed: {args.baseline}")
        return 0

    if args.github_summary:
        _emit_summary(github_summary(baseline, measured,
                                     suite_findings))

    findings = suite_findings + compare(baseline, measured)
    gated = len(baseline.get("metrics", {}))
    if findings:
        print(f"benchmark regression gate FAILED "
              f"({len(findings)}/{gated} metrics):")
        for f in findings:
            print(f"  - {f}")
        return 1
    print(f"benchmark regression gate passed ({gated} metrics)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
