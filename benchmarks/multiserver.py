"""Multi-server suite (docs/SCENARIOS.md): placement strategies over
heterogeneous edge cells, static and online.

Emits, per placement, mean FID with outage in the derived column, plus
two ordering flags:

  * ``multiserver_greedy_beats_rr`` — 1 when ``greedy_fid`` is no worse
    than ``round_robin`` on mean FID at equal-or-better outage on every
    seed-averaged scenario (the CI regression gate pins this at 1).
  * ``multiserver_scaleout_ok``     — 1 when 3 cells serve the same
    demand (same total bandwidth, 3x the compute) at no worse mean FID
    and outage than 1 server — the scale-out axis actually paying off.

Every (placement, seed) cell is an independent seeded run, so
``run(..., workers=N)`` (the ``benchmarks.run --workers`` flag) fans
the grid out over N processes with byte-identical output
(``benchmarks/par.py``).
"""

import numpy as np

from benchmarks.par import parallel_map
from repro.api import MultiServerProvisioner, Provisioner
from repro.core.service import make_scenario

# (label, placement, placement_kwargs, allocator, allocator_kwargs);
# `alternating` scores moves under per-cell coordinate refinement, so it
# runs with the coordinate allocator to realize the bandwidth it
# optimized (see repro.api.placements)
PLACEMENTS = [("rr", "round_robin", None, "inv_se", None),
              ("ll", "least_loaded", None, "inv_se", None),
              ("greedy", "greedy_fid", None, "inv_se", None),
              ("alt", "alternating", dict(sweeps=1), "coordinate",
               dict(rounds=1))]


def _placement_cell(args):
    """One (placement, seed) static multi-server run -> (fid, outage)."""
    placement, kw, K, n_servers, seed, speed, allocator, alloc_kw = args
    scn = make_scenario(K=K, n_servers=n_servers,
                        server_speed_range=speed, seed=seed)
    rep = MultiServerProvisioner(scn, placement=placement,
                                 scheduler="stacking",
                                 allocator=allocator,
                                 placement_kwargs=kw,
                                 allocator_kwargs=alloc_kw).run()
    return rep.mean_fid, rep.outage_rate


def _scaleout_cell(args):
    """1-server vs 3-cell run on the same demand -> (fid1, out1, fid3,
    out3)."""
    K, n_servers, seed = args
    r1 = Provisioner(make_scenario(K=K, seed=seed),
                     scheduler="stacking", allocator="inv_se").run()
    r3 = MultiServerProvisioner(
        make_scenario(K=K, n_servers=n_servers, seed=seed),
        placement="least_loaded", scheduler="stacking",
        allocator="inv_se").run()
    return r1.mean_fid, r1.outage_rate, r3.mean_fid, r3.outage_rate


def _online_cell(args):
    """One online multi-server run -> (fid, outage)."""
    K, n_servers, seed = args
    scn = make_scenario(K=K, n_servers=n_servers, arrival_rate=1.0,
                        server_speed_range=(0.6, 1.4), seed=seed)
    rep = MultiServerProvisioner(scn, scheduler="stacking",
                                 allocator="inv_se").run_online()
    return rep.mean_fid, rep.outage_rate


def run(csv_rows, K=12, n_servers=3, seeds=(0, 1), workers=1):
    # tasks carry their (placement, seed) identity; results are looked
    # up by it so aggregation cannot mis-attribute cells if a loop
    # nesting changes
    tasks = [(placement, kw, K, n_servers, seed, (0.6, 1.4), alloc,
              alloc_kw)
             for _, placement, kw, alloc, alloc_kw in PLACEMENTS
             for seed in seeds]
    res = {(t[0], t[4]): r
           for t, r in zip(tasks, parallel_map(_placement_cell, tasks,
                                               workers))}
    stats = {}
    for label, placement, _, alloc, _ in PLACEMENTS:
        cells = [res[(placement, seed)] for seed in seeds]
        fid = float(np.mean([f for f, _ in cells]))
        out = float(np.mean([o for _, o in cells]))
        stats[label] = (fid, out)
        csv_rows.append((f"multiserver_{label}", fid,
                         f"outage={out:.3f},allocator={alloc}"))
    g_fid, g_out = stats["greedy"]
    r_fid, r_out = stats["rr"]
    csv_rows.append(("multiserver_greedy_beats_rr",
                     float(g_fid <= r_fid + 1e-9 and g_out <= r_out + 1e-9),
                     "1=greedy_fid <= round_robin FID at equal outage"))

    # scale-out check: the same demand, same total bandwidth, on 1
    # server vs 3 cells (a third of the bandwidth but its own compute
    # each) — tripled compute means more denoising steps inside the same
    # deadlines, so quality must not get worse
    so = parallel_map(_scaleout_cell,
                      [(K, n_servers, seed) for seed in seeds], workers)
    fid1 = float(np.mean([f1 for f1, _, _, _ in so]))
    out1 = float(np.mean([o1 for _, o1, _, _ in so]))
    fid3 = float(np.mean([f3 for _, _, f3, _ in so]))
    out3 = float(np.mean([o3 for _, _, _, o3 in so]))
    csv_rows.append(("multiserver_1srv_fid", fid1, f"outage={out1:.3f}"))
    csv_rows.append(("multiserver_3srv_fid", fid3, f"outage={out3:.3f}"))
    csv_rows.append(("multiserver_scaleout_ok",
                     float(fid3 <= fid1 + 1e-9 and out3 <= out1 + 1e-9),
                     "1=3 cells no worse than 1 server (FID, outage)"))

    # online: Poisson arrivals routed per-arrival across the cells
    on = parallel_map(_online_cell,
                      [(K, n_servers, seed) for seed in seeds], workers)
    csv_rows.append(("multiserver_online_earliest_free",
                     float(np.mean([f for f, _ in on])),
                     f"outage={float(np.mean([o for _, o in on])):.3f}"))
