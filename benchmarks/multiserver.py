"""Multi-server suite (docs/SCENARIOS.md): placement strategies over
heterogeneous edge cells, static and online.

Emits, per placement, mean FID with outage in the derived column, plus
two ordering flags:

  * ``multiserver_greedy_beats_rr`` — 1 when ``greedy_fid`` is no worse
    than ``round_robin`` on mean FID at equal-or-better outage on every
    seed-averaged scenario (the CI regression gate pins this at 1).
  * ``multiserver_scaleout_ok``     — 1 when 3 cells serve the same
    demand (same total bandwidth, 3x the compute) at no worse mean FID
    and outage than 1 server — the scale-out axis actually paying off.
"""

import numpy as np

from repro.api import MultiServerProvisioner, Provisioner
from repro.core.service import make_scenario

# (label, placement, placement_kwargs, allocator, allocator_kwargs);
# `alternating` scores moves under per-cell coordinate refinement, so it
# runs with the coordinate allocator to realize the bandwidth it
# optimized (see repro.api.placements)
PLACEMENTS = [("rr", "round_robin", None, "inv_se", None),
              ("ll", "least_loaded", None, "inv_se", None),
              ("greedy", "greedy_fid", None, "inv_se", None),
              ("alt", "alternating", dict(sweeps=1), "coordinate",
               dict(rounds=1))]


def _mean_stats(placement, kw, K, n_servers, seeds, speed=(0.6, 1.4),
                allocator="inv_se", allocator_kwargs=None):
    fids, outs = [], []
    for seed in seeds:
        scn = make_scenario(K=K, n_servers=n_servers,
                            server_speed_range=speed, seed=seed)
        rep = MultiServerProvisioner(scn, placement=placement,
                                     scheduler="stacking",
                                     allocator=allocator,
                                     placement_kwargs=kw,
                                     allocator_kwargs=allocator_kwargs
                                     ).run()
        fids.append(rep.mean_fid)
        outs.append(rep.outage_rate)
    return float(np.mean(fids)), float(np.mean(outs))


def run(csv_rows, K=12, n_servers=3, seeds=(0, 1)):
    stats = {}
    for label, placement, kw, alloc, alloc_kw in PLACEMENTS:
        fid, out = _mean_stats(placement, kw, K, n_servers, seeds,
                               allocator=alloc, allocator_kwargs=alloc_kw)
        stats[label] = (fid, out)
        csv_rows.append((f"multiserver_{label}", fid,
                         f"outage={out:.3f},allocator={alloc}"))
    g_fid, g_out = stats["greedy"]
    r_fid, r_out = stats["rr"]
    csv_rows.append(("multiserver_greedy_beats_rr",
                     float(g_fid <= r_fid + 1e-9 and g_out <= r_out + 1e-9),
                     "1=greedy_fid <= round_robin FID at equal outage"))

    # scale-out check: the same demand, same total bandwidth, on 1
    # server vs 3 cells (a third of the bandwidth but its own compute
    # each) — tripled compute means more denoising steps inside the same
    # deadlines, so quality must not get worse
    fid1s, fid3s, out1s, out3s = [], [], [], []
    for seed in seeds:
        r1 = Provisioner(make_scenario(K=K, seed=seed),
                         scheduler="stacking", allocator="inv_se").run()
        r3 = MultiServerProvisioner(
            make_scenario(K=K, n_servers=n_servers, seed=seed),
            placement="least_loaded", scheduler="stacking",
            allocator="inv_se").run()
        fid1s.append(r1.mean_fid)
        fid3s.append(r3.mean_fid)
        out1s.append(r1.outage_rate)
        out3s.append(r3.outage_rate)
    fid1, fid3 = float(np.mean(fid1s)), float(np.mean(fid3s))
    out1, out3 = float(np.mean(out1s)), float(np.mean(out3s))
    csv_rows.append(("multiserver_1srv_fid", fid1, f"outage={out1:.3f}"))
    csv_rows.append(("multiserver_3srv_fid", fid3, f"outage={out3:.3f}"))
    csv_rows.append(("multiserver_scaleout_ok",
                     float(fid3 <= fid1 + 1e-9 and out3 <= out1 + 1e-9),
                     "1=3 cells no worse than 1 server (FID, outage)"))

    # online: Poisson arrivals routed per-arrival across the cells
    on_fids, on_outs = [], []
    for seed in seeds:
        scn = make_scenario(K=K, n_servers=n_servers, arrival_rate=1.0,
                            server_speed_range=(0.6, 1.4), seed=seed)
        rep = MultiServerProvisioner(scn, scheduler="stacking",
                                     allocator="inv_se").run_online()
        on_fids.append(rep.mean_fid)
        on_outs.append(rep.outage_rate)
    csv_rows.append(("multiserver_online_earliest_free",
                     float(np.mean(on_fids)),
                     f"outage={float(np.mean(on_outs)):.3f}"))
