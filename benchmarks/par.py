"""Process-parallel fan-out for benchmark grids.

``parallel_map(fn, items, workers)`` runs ``fn`` over ``items`` in a
``ProcessPoolExecutor`` when ``workers > 1`` and serially otherwise,
always returning results in item order — so a suite's output is
byte-identical at any worker count (every grid cell is an independent,
seeded simulation).  ``fn`` must be a module-level function and every
item picklable (the suites pass registry *names*, not callables).

Wired into ``benchmarks/run.py --workers N``: suites whose ``run``
accepts a ``workers`` keyword (churn, multiserver) fan their
rate x deadline x seed grids out across cores.
"""

from __future__ import annotations

from typing import Callable, List, Sequence


def parallel_map(fn: Callable, items: Sequence, workers: int = 1) -> List:
    """``[fn(x) for x in items]``, fanned out over ``workers``
    processes when that actually buys anything."""
    items = list(items)
    if workers <= 1 or len(items) <= 1:
        return [fn(x) for x in items]
    from concurrent.futures import ProcessPoolExecutor
    with ProcessPoolExecutor(max_workers=min(workers, len(items))) as ex:
        return list(ex.map(fn, items))
