"""Planner speed suite: array-native engine vs the scalar reference.

Times the planning hot paths under both engines on identical inputs
(docs/PERFORMANCE.md):

  * ``planner_tstar_K{N}_*`` — the full Algorithm-1 T* search
    (``stacking``) at N services, scalar vs vec, plus the speedup;
  * ``planner_offset_K{N}_*`` — one offset-native replan
    (``StackingOffset.plan`` with synthetic progress), scalar vs vec;
  * ``planner_vec_speedup_5x`` — gated flag: the vec engine is at
    least 5x faster on the T* search at N >= 64 services (the ISSUE-5
    acceptance bar; pinned at 1 in ``baseline.json``);
  * ``planner_vec_equivalent`` — gated flag: on every timed scenario
    the two engines returned bit-identical plans (batches, start
    times, step counts).  Timing varies per machine; equivalence must
    not, so only the flags are gated, the ``*_ms`` rows are trend data
    for the nightly baseline refresh.
"""

import time

import numpy as np

from repro.core.delay_model import DelayModel
from repro.core.offset import StackingOffset
from repro.core.quality_model import PowerLawFID
from repro.core.service import make_scenario
from repro.core.stacking import stacking

GATE_K = 64          # the acceptance bar's "N >= 64 services" instance
GATE_SPEEDUP = 5.0


def _best_of(fn, reps: int) -> float:
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def _plans_equal(a, b) -> bool:
    return (a.batches == b.batches and a.start_times == b.start_times
            and a.steps_completed == b.steps_completed)


def run(csv_rows, sizes=(16, 64, 128, 256), reps=3):
    delay, quality = DelayModel(), PowerLawFID()
    equivalent = True
    gate_speedup = 0.0

    # -- the Algorithm-1 T* search, scalar vs vec -------------------------
    for K in sizes:
        scn = make_scenario(K=K, seed=0)
        tp = {s.id: s.deadline - 0.4 for s in scn.services}
        svcs = scn.services
        equivalent &= _plans_equal(
            stacking(svcs, tp, delay, quality, engine="scalar"),
            stacking(svcs, tp, delay, quality, engine="vec"))
        t_sc = _best_of(lambda: stacking(svcs, tp, delay, quality,
                                         engine="scalar"), reps)
        t_ve = _best_of(lambda: stacking(svcs, tp, delay, quality,
                                         engine="vec"), reps)
        speedup = t_sc / max(t_ve, 1e-12)
        csv_rows.append((f"planner_tstar_K{K}_scalar_ms", t_sc * 1e3,
                         "Alg-1 T* search, scalar reference"))
        csv_rows.append((f"planner_tstar_K{K}_vec_ms", t_ve * 1e3,
                         "Alg-1 T* search, array-native"))
        csv_rows.append((f"planner_tstar_K{K}_speedup", speedup,
                         "scalar_ms / vec_ms"))
        if K == GATE_K:
            gate_speedup = speedup

    # -- one offset-native replan (three candidate families) -------------
    K = GATE_K
    scn = make_scenario(K=K, tau_min=3.0, tau_max=8.0, seed=1)
    tp = {s.id: s.deadline - 0.4 for s in scn.services}
    offs = [int(x) for x in np.random.default_rng(0).integers(0, 6, K)]
    scalar_off, vec_off = StackingOffset("scalar"), StackingOffset("vec")
    equivalent &= _plans_equal(
        scalar_off.plan(scn.services, tp, delay, quality, offs),
        vec_off.plan(scn.services, tp, delay, quality, offs))
    t_sc = _best_of(lambda: scalar_off.plan(scn.services, tp, delay,
                                            quality, offs), reps)
    t_ve = _best_of(lambda: vec_off.plan(scn.services, tp, delay,
                                         quality, offs), reps)
    csv_rows.append((f"planner_offset_K{K}_scalar_ms", t_sc * 1e3,
                     "offset replan, scalar reference"))
    csv_rows.append((f"planner_offset_K{K}_vec_ms", t_ve * 1e3,
                     "offset replan, array-native"))
    csv_rows.append((f"planner_offset_K{K}_speedup",
                     t_sc / max(t_ve, 1e-12), "scalar_ms / vec_ms"))

    csv_rows.append(("planner_vec_speedup_5x",
                     float(gate_speedup >= GATE_SPEEDUP),
                     f"1=vec >= {GATE_SPEEDUP:g}x on T* search at "
                     f"K={GATE_K} (got {gate_speedup:.1f}x)"))
    csv_rows.append(("planner_vec_equivalent", float(equivalent),
                     "1=vec plans bit-identical to scalar on every "
                     "timed scenario"))
