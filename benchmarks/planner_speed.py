"""Planner speed suite: array-native engine vs the scalar reference.

Times the planning hot paths under both engines on identical inputs
(docs/PERFORMANCE.md):

  * ``planner_tstar_K{N}_*`` — the full Algorithm-1 T* search
    (``stacking``) at N services, scalar vs vec, plus the speedup;
  * ``planner_offset_K{N}_*`` — one offset-native replan
    (``StackingOffset.plan`` with synthetic progress), scalar vs vec;
  * ``planner_vec_speedup_5x`` — gated flag: the vec engine is at
    least 5x faster on the T* search at N >= 64 services (the ISSUE-5
    acceptance bar; pinned at 1 in ``baseline.json``);
  * ``planner_vec_equivalent`` — gated flag: on every timed scenario
    the two engines returned bit-identical plans (batches, start
    times, step counts).  Timing varies per machine; equivalence must
    not, so only the flags are gated, the ``*_ms`` rows are trend data
    for the nightly baseline refresh.

When the jit-compiled jax engine (``repro.core.jaxplan``) is
importable the suite additionally times it (docs/PERFORMANCE.md,
"jax engine"):

  * ``planner_tstar_K{1024,10000}_jax_ms`` — one jitted T* search at
    population scale, next to the matching ``_vec_ms`` rows;
  * ``planner_*_jax_compile_ms`` — jit compilation time (first call
    minus warm call) as its own column, so the warm ``_ms`` rows and
    every gated speedup flag measure runtime only and a cold jit
    cache can never flake a gate;
  * ``planner_jax_k10k_parity`` — gated flag: the jitted T* search is
    at parity or better with vec at K=10^4 (warm, 10% margin) — the
    radix-selection + level-chunked kernel replaced the XLA sort that
    used to lose this row;
  * ``planner_plan_many_S1000_*`` — 1000 stacked scenarios planned in
    ONE jitted ``plan_many`` call vs the same 1000 planned by a vec
    loop, with the amortized per-scenario times;
  * ``planner_jax_devices`` + ``planner_plan_many_S1000_sharded_ms``
    — the device count jax exposes and the same S=1000 instance with
    the scenario axis sharded across all of them
    (``plan_many(devices=...)``);
  * ``planner_jax_equivalent`` — gated flag: jax objectives match the
    vec reference within ``JAX_TOL`` on every timed instance
    (tolerance, not bit identity — the documented contract);
  * ``planner_jax_batched_ok`` — gated flag: the single jitted
    ``plan_many`` call beats the vec per-scenario loop end to end at
    S=1000 (the amortization claim of ISSUE 6);
  * ``planner_jax_sharded_ok`` — gated flag: sharded ``plan_many``
    matches the single-device call within ``JAX_TOL`` on every
    scenario (it is bit-identical by construction — same per-row
    arithmetic — so the tolerance is slack, not hope);
  * ``planner_jax_sharded_speedup_1_5x`` — gated flag: sharding is
    >= 1.5x over single-device at S=1000 with 8 host devices (the
    bench/nightly CI jobs export
    ``XLA_FLAGS=--xla_force_host_platform_device_count=8``); on a
    host that physically cannot parallelize (one usable core or one
    device) the flag passes vacuously and its derived string says so.
"""

import os
import time

import numpy as np

from repro.core.delay_model import DelayModel
from repro.core.offset import StackingOffset
from repro.core.quality_model import PowerLawFID
from repro.core.service import ServiceRequest, make_scenario
from repro.core.stacking import stacking

GATE_K = 64          # the acceptance bar's "N >= 64 services" instance
GATE_SPEEDUP = 5.0

JAX_TSTAR_SIZES = (1024, 10000)   # ISSUE-6 population scales
PLAN_MANY_S, PLAN_MANY_K = 1000, 20
JAX_TOL = 1e-9       # documented objective tolerance (docs/PERFORMANCE.md)


def _best_of(fn, reps: int) -> float:
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def _plans_equal(a, b) -> bool:
    return (a.batches == b.batches and a.start_times == b.start_times
            and a.steps_completed == b.steps_completed)


def run(csv_rows, sizes=(16, 64, 128, 256), reps=3):
    delay, quality = DelayModel(), PowerLawFID()
    equivalent = True
    gate_speedup = 0.0

    # -- the Algorithm-1 T* search, scalar vs vec -------------------------
    for K in sizes:
        scn = make_scenario(K=K, seed=0)
        tp = {s.id: s.deadline - 0.4 for s in scn.services}
        svcs = scn.services
        equivalent &= _plans_equal(
            stacking(svcs, tp, delay, quality, engine="scalar"),
            stacking(svcs, tp, delay, quality, engine="vec"))
        t_sc = _best_of(lambda: stacking(svcs, tp, delay, quality,
                                         engine="scalar"), reps)
        t_ve = _best_of(lambda: stacking(svcs, tp, delay, quality,
                                         engine="vec"), reps)
        speedup = t_sc / max(t_ve, 1e-12)
        csv_rows.append((f"planner_tstar_K{K}_scalar_ms", t_sc * 1e3,
                         "Alg-1 T* search, scalar reference"))
        csv_rows.append((f"planner_tstar_K{K}_vec_ms", t_ve * 1e3,
                         "Alg-1 T* search, array-native"))
        csv_rows.append((f"planner_tstar_K{K}_speedup", speedup,
                         "scalar_ms / vec_ms"))
        if K == GATE_K:
            gate_speedup = speedup

    # -- one offset-native replan (three candidate families) -------------
    K = GATE_K
    scn = make_scenario(K=K, tau_min=3.0, tau_max=8.0, seed=1)
    tp = {s.id: s.deadline - 0.4 for s in scn.services}
    offs = [int(x) for x in np.random.default_rng(0).integers(0, 6, K)]
    scalar_off, vec_off = StackingOffset("scalar"), StackingOffset("vec")
    equivalent &= _plans_equal(
        scalar_off.plan(scn.services, tp, delay, quality, offs),
        vec_off.plan(scn.services, tp, delay, quality, offs))
    t_sc = _best_of(lambda: scalar_off.plan(scn.services, tp, delay,
                                            quality, offs), reps)
    t_ve = _best_of(lambda: vec_off.plan(scn.services, tp, delay,
                                         quality, offs), reps)
    csv_rows.append((f"planner_offset_K{K}_scalar_ms", t_sc * 1e3,
                     "offset replan, scalar reference"))
    csv_rows.append((f"planner_offset_K{K}_vec_ms", t_ve * 1e3,
                     "offset replan, array-native"))
    csv_rows.append((f"planner_offset_K{K}_speedup",
                     t_sc / max(t_ve, 1e-12), "scalar_ms / vec_ms"))

    csv_rows.append(("planner_vec_speedup_5x",
                     float(gate_speedup >= GATE_SPEEDUP),
                     f"1=vec >= {GATE_SPEEDUP:g}x on T* search at "
                     f"K={GATE_K} (got {gate_speedup:.1f}x)"))
    csv_rows.append(("planner_vec_equivalent", float(equivalent),
                     "1=vec plans bit-identical to scalar on every "
                     "timed scenario"))

    _run_jax(csv_rows, delay, quality, reps)


def _mean_fid(plan, ids, quality):
    return quality.mean_fid([plan.steps_completed[k] for k in ids])


def _run_jax(csv_rows, delay, quality, reps):
    """jax-engine rows + gated flags; a no-op note when jax is absent
    (the gate then fails on the missing flags, loudly)."""
    try:
        import repro.core.jaxplan as jaxplan
    except ImportError:
        csv_rows.append(("planner_jax_unavailable", 1.0,
                         "jax not importable; jax rows and gated "
                         "flags not emitted"))
        return

    jax_equiv = True

    # -- one jitted T* search at population scale -------------------------
    for K in JAX_TSTAR_SIZES:
        scn = make_scenario(K=K, seed=0)
        tp = {s.id: s.deadline - 0.4 for s in scn.services}
        svcs, ids = scn.services, [s.id for s in scn.services]
        # explicit warmup: the first call pays jit compilation, timed
        # so it can be reported as its OWN column — warm rows and the
        # gated flags below never include compile time
        t0 = time.perf_counter()
        pj = stacking(svcs, tp, delay, quality, engine="jax")
        t_cold = time.perf_counter() - t0
        pv = stacking(svcs, tp, delay, quality, engine="vec")
        jax_equiv &= abs(_mean_fid(pv, ids, quality)
                         - _mean_fid(pj, ids, quality)) < JAX_TOL
        r = 1 if K >= 10_000 else reps
        t_ve = _best_of(lambda: stacking(svcs, tp, delay, quality,
                                         engine="vec"), r)
        t_jx = _best_of(lambda: stacking(svcs, tp, delay, quality,
                                         engine="jax"), r)
        csv_rows.append((f"planner_tstar_K{K}_vec_ms", t_ve * 1e3,
                         "Alg-1 T* search, array-native"))
        csv_rows.append((f"planner_tstar_K{K}_jax_ms", t_jx * 1e3,
                         "Alg-1 T* search, one jitted sweep (warm)"))
        csv_rows.append((f"planner_tstar_K{K}_jax_compile_ms",
                         max(t_cold - t_jx, 0.0) * 1e3,
                         "jit compile share of the first call"))
        csv_rows.append((f"planner_tstar_K{K}_jax_vs_vec",
                         t_ve / max(t_jx, 1e-12), "vec_ms / jax_ms"))
        if K >= 10_000:
            parity = float(t_jx <= t_ve * 1.1)
            csv_rows.append((
                "planner_jax_k10k_parity", parity,
                f"1=jax warm T* search within 10% of vec at K={K} "
                f"(got {t_ve / max(t_jx, 1e-12):.2f}x vec/jax)"))

    # -- 1000 stacked scenarios in ONE jitted plan_many call --------------
    rng = np.random.default_rng(2)
    taus = rng.uniform(7.0, 20.0, size=(PLAN_MANY_S, PLAN_MANY_K))
    scns = [({i: float(t) for i, t in enumerate(row)},
             [ServiceRequest(id=i, deadline=float(t), spectral_eff=7.0)
              for i, t in enumerate(row)])
            for row in taus]
    t0 = time.perf_counter()
    res = jaxplan.plan_many(taus, delay=delay, quality=quality)  # warmup
    t_cold = time.perf_counter() - t0
    t_jx = _best_of(lambda: jaxplan.plan_many(taus, delay=delay,
                                              quality=quality), reps)

    def vec_loop():
        for tp, svcs in scns:
            stacking(svcs, tp, delay, quality, engine="vec")

    t_ve = _best_of(vec_loop, 1)
    for s in range(0, PLAN_MANY_S, 100):       # sampled equivalence
        tp, svcs = scns[s]
        pv = stacking(svcs, tp, delay, quality, engine="vec")
        ids = [sv.id for sv in svcs]
        jax_equiv &= abs(_mean_fid(pv, ids, quality)
                         - float(res.mean_fid[s])) < JAX_TOL

    csv_rows.append(("planner_plan_many_S1000_vec_ms", t_ve * 1e3,
                     f"{PLAN_MANY_S} scenarios, per-scenario vec loop"))
    csv_rows.append(("planner_plan_many_S1000_jax_ms", t_jx * 1e3,
                     f"{PLAN_MANY_S} scenarios, ONE jitted plan_many "
                     f"call (warm)"))
    csv_rows.append(("planner_plan_many_S1000_jax_compile_ms",
                     max(t_cold - t_jx, 0.0) * 1e3,
                     "jit compile share of the first call"))
    csv_rows.append(("planner_plan_many_S1000_per_scenario_jax_ms",
                     t_jx * 1e3 / PLAN_MANY_S,
                     "amortized jax plan time per scenario"))
    csv_rows.append(("planner_plan_many_S1000_per_scenario_vec_ms",
                     t_ve * 1e3 / PLAN_MANY_S,
                     "vec plan time per scenario"))

    csv_rows.append(("planner_jax_equivalent", float(jax_equiv),
                     f"1=jax objectives within {JAX_TOL:g} of vec on "
                     f"every timed instance"))
    csv_rows.append(("planner_jax_batched_ok",
                     float(t_jx < t_ve),
                     "1=one jitted plan_many call beats the vec "
                     "per-scenario loop at S=1000"))

    _run_jax_sharded(csv_rows, jaxplan, taus, res, delay, quality,
                     t_jx, reps)


def _run_jax_sharded(csv_rows, jaxplan, taus, res_single, delay,
                     quality, t_single, reps):
    """Sharded plan_many rows: the same S=1000 instance with the
    scenario axis split across every device jax exposes, vs the
    single-device call just timed (``t_single``).  Equivalence is
    checked on EVERY scenario — the sharded path is the same per-row
    arithmetic, so the documented tolerance is slack, not hope."""
    import jax
    n_dev = len(jax.devices())
    csv_rows.append(("planner_jax_devices", float(n_dev),
                     "jax devices visible to the sharded planner "
                     "(bench CI exports XLA_FLAGS=--xla_force_host_"
                     "platform_device_count=8)"))
    t0 = time.perf_counter()
    res_sh = jaxplan.plan_many(taus, delay=delay, quality=quality,
                               devices=n_dev)            # warmup
    t_cold = time.perf_counter() - t0
    t_sh = _best_of(lambda: jaxplan.plan_many(
        taus, delay=delay, quality=quality, devices=n_dev), reps)
    sharded_ok = bool(
        np.array_equal(res_single.best_level, res_sh.best_level)
        and np.max(np.abs(res_single.mean_fid - res_sh.mean_fid))
        < JAX_TOL)
    speedup = t_single / max(t_sh, 1e-12)
    # the >= 1.5x claim is about parallel hardware: on a single-core
    # host (or a single device) sharding cannot parallelize, so the
    # flag passes vacuously there and the derived string says so —
    # the bench/nightly CI jobs run multi-core with 8 host devices,
    # where the claim is actually exercised
    cores = len(os.sched_getaffinity(0)) if hasattr(os,
                                                    "sched_getaffinity") \
        else (os.cpu_count() or 1)
    parallel_host = n_dev >= 2 and cores >= 2
    why = (f"got {speedup:.2f}x on {n_dev} device(s)" if parallel_host
           else f"vacuous: {cores} usable core(s) / {n_dev} device(s) "
                f"cannot parallelize (measured {speedup:.2f}x)")
    csv_rows.append(("planner_plan_many_S1000_sharded_ms", t_sh * 1e3,
                     f"S=1000 plan_many sharded over {n_dev} "
                     f"device(s) (warm)"))
    csv_rows.append(("planner_plan_many_S1000_sharded_compile_ms",
                     max(t_cold - t_sh, 0.0) * 1e3,
                     "jit compile share of the first sharded call"))
    csv_rows.append(("planner_jax_sharded_ok", float(sharded_ok),
                     f"1=sharded plan_many matches single-device "
                     f"within {JAX_TOL:g} on all scenarios "
                     f"({n_dev} device(s))"))
    csv_rows.append(("planner_jax_sharded_speedup_1_5x",
                     float(speedup >= 1.5 or not parallel_host),
                     f"1=sharded >= 1.5x single-device at S=1000 "
                     f"({why})"))
