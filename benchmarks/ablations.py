"""Ablations beyond the paper's figures:

* T*-search: quality of a single stacking_pass at each fixed T* vs. the
  searched optimum (why Alg. 1's outer loop matters).
* MoE capacity factor: token-drop rate vs. capacity (the serving-side
  twin of the paper's batch-size/quality trade-off).
* int8 KV cache: bytes saved vs. top-1 agreement on a smoke model.
"""

import numpy as np

from repro.core.delay_model import DelayModel
from repro.core.quality_model import PowerLawFID
from repro.core.service import make_scenario
from repro.core.stacking import stacking, stacking_pass


def run(csv_rows):
    delay, quality = DelayModel(), PowerLawFID()

    # ---- T* ablation -------------------------------------------------------
    scn = make_scenario(K=16, seed=5)
    tp = {s.id: s.deadline - 1.0 for s in scn.services}
    ids = [s.id for s in scn.services]
    best = stacking(scn.services, tp, delay, quality)
    q_best = quality.mean_fid([best.steps_completed[k] for k in ids])
    worst = -1.0
    for t_star in (1, 5, 10, 20, 40, 80):
        plan = stacking_pass(ids, tp, delay, t_star)
        q = quality.mean_fid([plan.steps_completed[k] for k in ids])
        worst = max(worst, q)
        csv_rows.append((f"ablate_tstar_{t_star}", q, "mean_fid (fixed T*)"))
    csv_rows.append(("ablate_tstar_searched", q_best, "Alg.1 outer search"))
    csv_rows.append(("ablate_tstar_search_gain", worst - q_best,
                     "fid vs worst fixed T*"))

    # ---- MoE capacity factor ------------------------------------------------
    import jax
    import jax.numpy as jnp
    from repro.config import get_config, smoke_variant
    from repro.models.moe import apply_moe, moe_capacity
    from repro.models.params import init_params
    from repro.models.moe import moe_schema

    cfg = smoke_variant(get_config("qwen3-moe-30b-a3b"))
    p = init_params(moe_schema(cfg), jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 64, cfg.d_model))
    ref, _ = apply_moe(cfg, p, x, capacity_factor=64.0)   # no drops
    for cf in (0.5, 1.0, 1.25, 2.0):
        out, aux = apply_moe(cfg, p, x, capacity_factor=cf)
        rel = float(jnp.linalg.norm(out - ref) / jnp.linalg.norm(ref))
        csv_rows.append((f"ablate_moe_cf{cf:g}", rel * 100,
                         f"rel err vs no-drop, C={moe_capacity(cfg, 64, cf)}"))

    # ---- int8 KV ------------------------------------------------------------
    from repro.config import RunConfig
    from repro.models import api
    cfg = smoke_variant(get_config("tinyllama-1.1b"))
    params = api.init_model(cfg, jax.random.PRNGKey(0))
    mod = api.get_model(cfg)
    toks = jax.random.randint(jax.random.PRNGKey(2), (2, 33), 0,
                              cfg.vocab_size)
    outs = {}
    for kvd in ("float32", "int8"):
        run_cfg = RunConfig(kv_cache_dtype=kvd)
        _, cache = mod.prefill(cfg, params, toks[:, :32], 40, run_cfg)
        lg, _ = mod.decode_step(cfg, params, toks[:, 32:], cache, run_cfg)
        outs[kvd] = np.asarray(lg)
    agree = float((outs["float32"].argmax(-1)
                   == outs["int8"].argmax(-1)).mean())
    csv_rows.append(("ablate_int8kv_top1_agree", agree * 100, "percent"))
    csv_rows.append(("ablate_int8kv_bytes_saved", 50.0,
                     "percent of bf16 cache (+scales)"))
