"""Roofline report: reads artifacts/dryrun/*.json (written by
repro.launch.dryrun) and emits the per-(arch x shape) three-term roofline
rows, the dominant bottleneck, and MODEL_FLOPS / HLO_FLOPs ratios."""

import glob
import json
import os

ART_DIR = os.path.join(os.path.dirname(__file__), "..", "artifacts",
                       "dryrun")


def load(mesh="16x16"):
    recs = []
    for f in sorted(glob.glob(os.path.join(ART_DIR, f"*_{mesh}.json"))):
        recs.append(json.load(open(f)))
    return recs


def run(csv_rows):
    recs = load("16x16")
    if not recs:
        csv_rows.append(("roofline_missing", 1.0,
                         "run repro.launch.dryrun --all first"))
        return
    for r in recs:
        tag = f"{r['arch']}_{r['shape']}"
        rf = r["roofline"]
        csv_rows.append((f"roofline_{tag}_compute", rf["compute_s"] * 1e6,
                         "us"))
        csv_rows.append((f"roofline_{tag}_memory", rf["memory_s"] * 1e6,
                         "us"))
        csv_rows.append((f"roofline_{tag}_collective",
                         rf["collective_s"] * 1e6, "us"))
        csv_rows.append((f"roofline_{tag}_dominant",
                         {"compute_s": 0, "memory_s": 1,
                          "collective_s": 2}[rf["dominant"]],
                         rf["dominant"]))
        csv_rows.append((f"roofline_{tag}_useful_flops_ratio",
                         r["useful_flops_ratio"], ""))
    n_multi = len(load("2x16x16"))
    csv_rows.append(("roofline_single_pod_lowered", float(len(recs)),
                     "of 40"))
    csv_rows.append(("roofline_multi_pod_lowered", float(n_multi),
                     "of 40"))


def markdown_table(mesh="16x16"):
    recs = load(mesh)
    lines = ["| arch | shape | compute (s) | memory (s) | collective (s) |"
             " dominant | MODEL/HLO flops | what would move it |",
             "|---|---|---|---|---|---|---|---|"]
    hints = {
        ("memory_s", "decode"): "larger batch / int8 KV to cut bytes/step",
        ("memory_s", "train"): "recompute less (remat) or raise intensity",
        ("memory_s", "prefill"): "fuse attention (Pallas flash) tiles",
        ("collective_s", "train"): "overlap grad reduce-scatter w/ compute",
        ("collective_s", "prefill"): "reshard: avoid seq<->head all-to-alls",
        ("collective_s", "decode"): "keep weights resident (no FSDP gather)",
        ("compute_s", "train"): "MXU-align tiles; drop causal waste",
    }
    for r in recs:
        rf = r["roofline"]
        hint = hints.get((rf["dominant"], r["kind"]), "shard differently")
        lines.append(
            f"| {r['arch']} | {r['shape']} | {rf['compute_s']:.3e} "
            f"| {rf['memory_s']:.3e} | {rf['collective_s']:.3e} "
            f"| {rf['dominant'].replace('_s', '')} "
            f"| {r['useful_flops_ratio']:.3f} | {hint} |")
    return "\n".join(lines)
