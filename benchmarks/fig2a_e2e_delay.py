"""Fig. 2a: end-to-end delay decomposition of 10 services under the full
pipeline (STACKING + PSO bandwidth), verifying the paper's qualitative
claims: tight deadlines first, similar deadlines -> similar step counts,
transmissions finish close to the deadline."""

import numpy as np

from repro.api import Provisioner
from repro.core.service import make_scenario


def run(csv_rows):
    scn = make_scenario(K=10, seed=42)
    prov = Provisioner(scn, scheduler="stacking", allocator="pso",
                       allocator_kwargs=dict(num_particles=12, iters=12,
                                             seed=0))
    report = prov.run()
    plan, sim = report.plan, report.sim

    for o in sim.outcomes:
        csv_rows.append((f"fig2a_svc{o.id}_e2e", o.e2e_delay,
                         f"tau={o.deadline:.2f},steps={o.steps},"
                         f"gen={o.gen_delay:.2f},tx={o.tx_delay:.2f}"))
    csv_rows.append(("fig2a_outage", sim.outage_rate * 100, "percent"))
    csv_rows.append(("fig2a_mean_fid", sim.mean_fid, ""))

    # claim 1: deadline slack (tau - e2e) is small on average
    slack = [o.deadline - o.e2e_delay for o in sim.outcomes if o.steps > 0]
    csv_rows.append(("fig2a_mean_slack", float(np.mean(slack)),
                     "s unused budget"))
    # claim 2: tightest service in first batch
    tight = min(scn.services, key=lambda s: s.deadline).id
    first = float(any(k == tight for k, _ in plan.batches[0]))
    csv_rows.append(("fig2a_tightest_first", first, "1=yes"))
    # claim 3: similar deadlines -> similar steps (corr of rank orders)
    taus = [s.deadline for s in scn.services]
    steps = [plan.steps_completed[s.id] for s in scn.services]
    corr = float(np.corrcoef(taus, steps)[0, 1])
    csv_rows.append(("fig2a_tau_steps_corr", corr, "pearson"))
