"""Fleet suite: population-scale simulation throughput + the
equivalence and memory contracts of ``repro.core.fleet``.

Three claims, each pinned by a flag in ``benchmarks/baseline.json``:

  * ``fleet_matches_multiserver`` — a small heterogeneous fleet run in
    ``mode="event"`` reproduces ``simulate_online_multi`` (the
    object-graph simulator) on the identical workload within 1e-9
    mean FID, for both closed-form allocators.  The fleet harness is a
    re-implementation for scale, not a new model — this row is the
    proof.
  * ``fleet_1m_services_ok`` — the epoch-mode scale run completes with
    every arrival accounted for (admitted + rejected == arrivals,
    completed == admitted) at the target population.  The blocking CI
    job runs the reduced target (~1e5 services); the nightly job sets
    ``FLEET_FULL=1`` for the full >= 10^6.
  * ``fleet_bounded_memory`` — quadrupling the horizon at a fixed
    epoch width and arrival rate leaves the peak number of
    concurrently-held service rows flat (within 2x), i.e. memory is
    bounded by the epoch working set, never by the total population.

Throughput rows (``fleet_services_per_s``, ``fleet_peak_rss_mb``) are
informational — wall-clock and RSS vary across runners, so they are
recorded in docs/PERFORMANCE.md but not gated.
"""

import os
import resource
import sys
import time

from repro.core.bandwidth import equal_allocate, inv_se_allocate
from repro.core.fleet import (FleetCell, FleetScenario, fleet_to_scenario,
                              simulate_fleet)
from repro.core.multiserver import simulate_online_multi
from repro.core.stacking import stacking
from repro.core.traffic import PoissonProcess

#: reduced target for the blocking CI job; FLEET_FULL=1 (nightly) runs
#: the paper-scale >= 10^6 population instead
REDUCED_SERVICES = 100_000
FULL_SERVICES = 1_000_000


def _peak_rss_mb() -> float:
    """Process-lifetime peak resident set, MB (``ru_maxrss`` is KB on
    Linux, bytes on macOS)."""
    peak = resource.getrusage(resource.RUSAGE_SELF).ru_maxrss
    div = 1024.0 ** 2 if sys.platform == "darwin" else 1024.0
    return peak / div


def _equivalence(csv_rows) -> None:
    """Event-mode fleet vs simulate_online_multi on the same workload."""
    worst = 0.0
    ok = True
    for alloc_name, core_alloc in (
            ("equal", lambda scn, *a, **k: equal_allocate(scn)),
            ("inv_se", lambda scn, *a, **k: inv_se_allocate(scn))):
        cells = [FleetCell(bandwidth_hz=1.2e6 * (c + 1),
                           speed=1.0 + 0.25 * c,
                           process=PoissonProcess(2.0))
                 for c in range(3)]
        fleet = FleetScenario(cells=cells, horizon=8.0, seed=11)
        res = simulate_fleet(fleet, allocator=alloc_name, mode="event")
        scn, assignment = fleet_to_scenario(fleet)
        cell_of = {s.id: assignment[i]
                   for i, s in enumerate(scn.services)}
        ref = simulate_online_multi(
            scn, stacking, core_alloc,
            placement=lambda svc, sim: cell_of[svc.id], engine="vec")
        dq = abs(res.mean_fid - ref.mean_fid)
        worst = max(worst, dq)
        ok &= dq <= 1e-9 and res.admitted == len(ref.outcomes)
        csv_rows.append((f"fleet_event_{alloc_name}_fid", res.mean_fid,
                         f"ref={ref.mean_fid:.9f},diff={dq:.2e},"
                         f"K={len(scn.services)}"))
    csv_rows.append(("fleet_matches_multiserver", float(ok),
                     f"1=event-mode fleet == simulate_online_multi "
                     f"within 1e-9 (worst diff {worst:.2e})"))


def _scale(csv_rows, full: bool) -> None:
    """The big epoch-mode run: throughput, accounting, peak RSS."""
    target = FULL_SERVICES if full else REDUCED_SERVICES
    # expected arrivals = n_cells * rate * horizon, sized ~5% above the
    # target so Poisson fluctuation cannot undershoot it
    n_cells = 512 if full else 128
    rate = 2.0
    horizon = (1.025 * target) / (n_cells * rate)
    fleet = FleetScenario(
        cells=tuple(FleetCell(bandwidth_hz=8.0e6,
                              process=PoissonProcess(rate))
                    for _ in range(n_cells)),
        horizon=horizon, seed=0)
    t0 = time.time()
    res = simulate_fleet(fleet, allocator="inv_se", mode="epoch",
                         epoch=horizon / 64.0)
    wall = time.time() - t0
    accounted = (res.admitted + res.rejected == res.arrivals
                 and res.completed == res.admitted)
    label = "full" if full else "reduced"
    csv_rows.append(("fleet_services", float(res.arrivals),
                     f"{label},target={target},cells={n_cells},"
                     f"horizon={horizon:.1f}"))
    csv_rows.append(("fleet_services_per_s", res.arrivals / wall,
                     f"wall={wall:.2f}s,mean_fid={res.mean_fid:.3f},"
                     f"planner_calls={res.planner_calls}"))
    csv_rows.append(("fleet_peak_live_rows", float(res.peak_live_rows),
                     f"arrivals={res.arrivals}"))
    csv_rows.append(("fleet_peak_rss_mb", _peak_rss_mb(),
                     f"{label},ru_maxrss"))
    csv_rows.append(("fleet_1m_services_ok",
                     float(accounted and res.arrivals >= target),
                     f"1={label} run >= {target} services, all "
                     f"accounted (admitted+rejected==arrivals, "
                     f"completed==admitted)"))


def _bounded_memory(csv_rows) -> None:
    """Peak live rows must track the epoch working set, not the
    horizon: 4x the horizon at fixed epoch width and rate may not even
    double the peak."""
    peaks = {}
    for horizon in (50.0, 200.0):
        fleet = FleetScenario(
            cells=tuple(FleetCell(bandwidth_hz=1.5e6,
                                  process=PoissonProcess(2.0))
                        for _ in range(32)),
            horizon=horizon, seed=7)
        res = simulate_fleet(fleet, mode="epoch", epoch=5.0)
        peaks[horizon] = res.peak_live_rows
        csv_rows.append((f"fleet_peak_rows_h{horizon:g}",
                         float(res.peak_live_rows),
                         f"arrivals={res.arrivals},epoch=5"))
    bounded = peaks[200.0] <= 2 * peaks[50.0]
    csv_rows.append(("fleet_bounded_memory", float(bounded),
                     f"1=peak rows flat under 4x horizon "
                     f"({peaks[50.0]} -> {peaks[200.0]})"))


def _engine_parity(csv_rows) -> None:
    """Batched-replan path (jax ``replan_many``) vs the vec loop on a
    moderate epoch-mode fleet — informational row; the 1e-9 contract
    itself is test-enforced (tests/test_fleet.py)."""
    fleet = FleetScenario(
        cells=tuple(FleetCell(bandwidth_hz=2.0e6,
                              process=PoissonProcess(5.0))
                    for _ in range(20)),
        horizon=50.0, seed=1)
    ref = simulate_fleet(fleet, mode="epoch", engine="vec")
    try:
        res = simulate_fleet(fleet, mode="epoch", engine="jax")
    except (ImportError, ValueError) as exc:   # pragma: no cover
        csv_rows.append(("fleet_jax_vs_vec_fid_diff", 0.0,
                         f"jax engine unavailable: {exc}"))
        return
    dq = abs(res.mean_fid - ref.mean_fid)
    csv_rows.append(("fleet_jax_vs_vec_fid_diff", dq,
                     f"vec={ref.mean_fid:.9f},jax={res.mean_fid:.9f},"
                     f"batched_calls={res.planner_calls} vs "
                     f"{ref.planner_calls}"))


def run(csv_rows):
    full = os.environ.get("FLEET_FULL", "") not in ("", "0")
    _equivalence(csv_rows)
    _scale(csv_rows, full)
    _bounded_memory(csv_rows)
    _engine_parity(csv_rows)
