"""Online admission suite (docs/SCENARIOS.md): Poisson arrival-rate
sweep comparing STACKING against the Sec.-IV baseline schedulers under
event-driven replanning, plus the admission-policy comparison.

Emits, per (rate, scheme), mean FID with outage in the derived column,
and an ``online_stacking_best`` flag: 1 when at least one swept rate has
stacking no worse than every baseline on mean FID at equal-or-better
outage (the paper's Fig.-2b ordering carried over to the online regime).
"""

import numpy as np

from repro.api import OnlineProvisioner
from repro.core.service import make_scenario

# CSV label -> scheduler registry name (same roster as fig2b)
SCHEMES = [("stacking", "stacking"), ("single", "single_instance"),
           ("greedy", "greedy"), ("fixed", "fixed_size")]


def _mean_stats(scheduler, rate, K, seeds, admission="admit_all",
                admission_kwargs=None, tau=(7.0, 20.0)):
    fids, outs, rejs = [], [], []
    for seed in seeds:
        scn = make_scenario(K=K, tau_min=tau[0], tau_max=tau[1],
                            arrival_rate=rate, seed=seed)
        rep = OnlineProvisioner(scn, scheduler=scheduler,
                                allocator="inv_se", admission=admission,
                                admission_kwargs=admission_kwargs).run()
        fids.append(rep.mean_fid)
        outs.append(rep.outage_rate)
        rejs.append(rep.reject_rate)
    return float(np.mean(fids)), float(np.mean(outs)), float(np.mean(rejs))


def run(csv_rows, rates=(0.15, 0.5, 2.0), K=12, seeds=(0, 1)):
    best_at_some_rate = False
    for rate in rates:
        stats = {}
        for label, sched in SCHEMES:
            fid, out, _ = _mean_stats(sched, rate, K, seeds)
            stats[label] = (fid, out)
            csv_rows.append((f"online_r{rate}_{label}", fid,
                             f"outage={out:.3f}"))
        s_fid, s_out = stats["stacking"]
        if all(s_fid <= f + 1e-9 and s_out <= o + 1e-9
               for f, o in stats.values()):
            best_at_some_rate = True
    csv_rows.append(("online_stacking_best", float(best_at_some_rate),
                     "1=beats all baselines at >=1 rate (FID, equal outage)"))

    # admission policies under stacking in a congested regime (tight
    # deadlines, heavy arrivals) where accept/reject actually differs:
    # deadline_feasible trades a few rejects for lower outage, while
    # fid_threshold turns away most of the flood to protect quality
    for pol, kw in (("admit_all", None), ("deadline_feasible", None),
                    ("fid_threshold", dict(threshold=30.0))):
        fid, out, rej = _mean_stats("stacking", 4.0, 16, seeds,
                                    admission=pol, admission_kwargs=kw,
                                    tau=(1.0, 3.0))
        csv_rows.append((f"online_adm_{pol}", fid,
                         f"outage={out:.3f},reject={rej:.3f}"))
